//! Chaos suite: the serving plane under injected network faults.
//!
//! A seeded `ChaosProxy` sits between every client and the server,
//! tearing frames, stalling mid-frame, delaying and throttling bytes,
//! and (in the retry test) killing connections mid-solve. The
//! contracts under test:
//!
//! - **exactly-once**: zero lost and zero duplicated replies, no
//!   matter how the byte stream is mistreated;
//! - **parity**: every delivered solve/gradient matches a direct
//!   engine call at the served iteration count to 1e-8 — chaos may
//!   delay answers, never corrupt them;
//! - **priority order**: under equal per-class pressure, Low sheds
//!   strictly before High, and the per-class server counters
//!   reconcile exactly with the client-observed tallies;
//! - **deadline accounting**: expired requests come back
//!   `DeadlineExceeded`, never consume a solve, and the server's
//!   deadline-shed counter equals the client's tally;
//! - **liveness**: `GET /metrics` and `GET /healthz` answer on the
//!   same port while the chaos run is in flight.

use altdiff::altdiff::{BackwardMode, DenseAltDiff, Options, Param};
use altdiff::coordinator::{
    Config, Coordinator, FailureKind, Priority, Reply,
};
use altdiff::net::{
    ChaosConfig, ChaosProxy, Client, NetConfig, NetServer,
    PipelinedClient, RetryPolicy,
};
use altdiff::prob::dense_qp;
use std::collections::BTreeSet;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

const ORD: Ordering = Ordering::Relaxed;

struct Loopback {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: std::thread::JoinHandle<Coordinator>,
}

fn start_server(config: Config, net: NetConfig) -> Loopback {
    let coord = Coordinator::builder(config)
        .register("dense12", dense_qp(12, 6, 3, 9), 1.0)
        .unwrap()
        .register("d64", dense_qp(64, 32, 12, 2), 1.0)
        .unwrap()
        .start();
    let server =
        NetServer::bind("127.0.0.1:0", coord, net).expect("bind");
    let addr = server.local_addr().expect("addr");
    let stop = server.stop_handle();
    let handle = std::thread::spawn(move || server.run());
    Loopback { addr, stop, handle }
}

impl Loopback {
    fn finish(self) -> Coordinator {
        self.stop.store(true, Ordering::SeqCst);
        self.handle.join().expect("server thread")
    }
}

/// Minimal HTTP/1.0 GET against the serving port; returns
/// (status line, body). The server closes after one response.
fn http_get(addr: SocketAddr, path: &str) -> (String, String) {
    let mut s = TcpStream::connect(addr).expect("http connect");
    s.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    s.write_all(format!("GET {path} HTTP/1.0\r\n\r\n").as_bytes())
        .unwrap();
    let mut raw = String::new();
    s.read_to_string(&mut raw).expect("http response");
    let (head, body) =
        raw.split_once("\r\n\r\n").expect("header terminator");
    let status = head.lines().next().unwrap_or("").to_string();
    (status, body.to_string())
}

/// Torn frames, mid-frame stalls, delays, and a slow-reader throttle:
/// every reply arrives exactly once and matches the direct engine to
/// 1e-8, while /metrics and /healthz answer mid-run on the same port.
#[test]
fn torn_frames_never_lose_or_corrupt_replies_and_http_stays_live() {
    let lb = start_server(
        Config {
            workers: 2,
            max_batch: 4,
            batch_timeout_us: 1_000,
            artifacts: None,
            ..Default::default()
        },
        NetConfig::default(),
    );
    let mut proxy = ChaosProxy::spawn(
        lb.addr,
        ChaosConfig {
            seed: 11,
            tear_prob: 0.6,
            stall_prob: 0.7,
            stall_us: 1_500,
            delay_prob: 0.3,
            delay_us: 800,
            throttle: 96,
            ..ChaosConfig::default()
        },
    )
    .expect("proxy");
    let paddr = proxy.addr();
    let qp = dense_qp(12, 6, 3, 9);

    const CLIENTS: u64 = 4;
    const PER: u64 = 12;
    let mut handles = Vec::new();
    for c in 0..CLIENTS {
        let qp = qp.clone();
        handles.push(std::thread::spawn(move || {
            let mut cl = PipelinedClient::connect(paddr, PER as usize)
                .expect("connect");
            cl.set_timeout(Some(Duration::from_secs(120))).unwrap();
            let mut replies = Vec::new();
            for i in 0..PER {
                // open-loop burst at mixed priorities: the window
                // holds the whole burst, replies never pace sends
                cl.set_priority(Priority::ALL[i as usize % 3]);
                let s = 1.0 + 0.02 * (c * PER + i) as f64;
                let grad_v = (i % 4 == 1).then(|| {
                    (0..12).map(|j| 1.0 - 0.1 * j as f64).collect()
                });
                replies.extend(
                    cl.submit(
                        "dense12",
                        qp.q.iter().map(|&v| v * s).collect(),
                        qp.b.clone(),
                        qp.h.clone(),
                        grad_v,
                        1e-3,
                    )
                    .expect("submit under chaos"),
                );
            }
            replies.extend(cl.drain().expect("drain under chaos"));
            (c, replies)
        }));
    }

    // liveness while the chaos traffic is in flight: the observability
    // endpoints share the serving socket and must answer immediately
    let (status, body) = http_get(lb.addr, "/metrics");
    assert!(status.contains("200"), "mid-run /metrics: {status}");
    assert!(body.contains("altdiff_requests_total"));
    assert!(body.contains("altdiff_class_served_total{class=\"high\"}"));
    let (status, body) = http_get(lb.addr, "/healthz");
    assert!(status.contains("200"), "mid-run /healthz: {status}");
    assert!(body.contains("\"status\""));
    assert!(body.contains("\"queue_depth\""));

    let direct = DenseAltDiff::new(qp.clone(), 1.0).unwrap();
    for h in handles {
        let (c, replies) = h.join().expect("client thread");
        assert_eq!(
            replies.len(),
            PER as usize,
            "client {c}: lost replies under chaos"
        );
        let ids: BTreeSet<u64> =
            replies.iter().map(|t| t.reply.id()).collect();
        assert_eq!(
            ids.len(),
            PER as usize,
            "client {c}: duplicated replies under chaos"
        );
        for t in &replies {
            let i = t.reply.id() - 1; // ids are 1-based, send order
            let s = 1.0 + 0.02 * (c * PER + i) as f64;
            let q: Vec<f64> = qp.q.iter().map(|&v| v * s).collect();
            match &t.reply {
                Reply::Ok(r) => {
                    let opts = Options {
                        tol: 0.0,
                        max_iter: r.k_used,
                        backward: BackwardMode::Forward(Param::B),
                        ..Default::default()
                    };
                    let want =
                        direct.solve_with(Some(&q), None, None, &opts);
                    for (a, b) in r.x.iter().zip(&want.x) {
                        assert!(
                            (a - b).abs() < 1e-8,
                            "chaos corrupted x: {a} vs {b}"
                        );
                    }
                }
                Reply::Grad(g) => {
                    let v: Vec<f64> = (0..12)
                        .map(|j| 1.0 - 0.1 * j as f64)
                        .collect();
                    let opts = Options {
                        tol: 0.0,
                        max_iter: g.k_used,
                        backward: BackwardMode::Adjoint,
                        ..Default::default()
                    };
                    let want = direct
                        .solve_vjp(Some(&q), None, None, &v, &opts);
                    for (a, b) in
                        g.grad_q.iter().zip(&want.vjp.grad_q)
                    {
                        assert!(
                            (a - b).abs() < 1e-8,
                            "chaos corrupted grad_q: {a} vs {b}"
                        );
                    }
                }
                Reply::Err(f) => {
                    panic!("unexpected failure under chaos: {}", f.error)
                }
            }
        }
    }
    proxy.stop();
    let coord = lb.finish();
    assert!(coord.metrics.requests.load(ORD) >= CLIENTS * PER);
    assert_eq!(coord.metrics.shed.load(ORD), 0, "no pressure, no sheds");
    assert!(proxy.stats().torn.load(ORD) > 0, "chaos never fired");
}

/// Equal per-class pressure against a small in-flight budget, through
/// the chaos proxy: Low sheds strictly before High, nothing is lost,
/// and the per-class server counters equal the client-side tallies.
#[test]
fn mixed_priority_bursts_shed_low_before_high_exactly_once() {
    let lb = start_server(
        Config {
            workers: 1,
            max_batch: 1,
            batch_timeout_us: 500,
            artifacts: None,
            ..Default::default()
        },
        // class budgets: High 16, Normal 14, Low 12
        NetConfig { max_inflight: 16, ..Default::default() },
    );
    let mut proxy = ChaosProxy::spawn(
        lb.addr,
        ChaosConfig {
            seed: 23,
            tear_prob: 0.3,
            stall_prob: 0.4,
            stall_us: 500,
            delay_prob: 0.1,
            delay_us: 300,
            ..ChaosConfig::default()
        },
    )
    .expect("proxy");
    let qp = dense_qp(64, 32, 12, 2);
    const N: u64 = 90;
    let mut cl = PipelinedClient::connect(proxy.addr(), N as usize)
        .expect("connect");
    cl.set_timeout(Some(Duration::from_secs(120))).unwrap();
    let mut replies = Vec::new();
    for i in 0..N {
        // strict H/N/L cycling = equal arrival pressure per class;
        // id (1-based) → class ALL[(id-1) % 3] is the reply oracle.
        // tol 1e-6 keeps each solve slow enough that the burst
        // saturates the in-flight budget long before the single
        // worker drains it — the shed bands must actually engage.
        cl.set_priority(Priority::ALL[i as usize % 3]);
        let s = 1.0 + 0.01 * i as f64;
        replies.extend(
            cl.submit(
                "d64",
                qp.q.iter().map(|&v| v * s).collect(),
                qp.b.clone(),
                qp.h.clone(),
                None,
                1e-6,
            )
            .expect("submit"),
        );
    }
    replies.extend(cl.drain().expect("drain"));
    assert_eq!(replies.len(), N as usize, "lost replies under pressure");
    let ids: BTreeSet<u64> =
        replies.iter().map(|t| t.reply.id()).collect();
    assert_eq!(ids.len(), N as usize, "duplicated replies");

    let direct = DenseAltDiff::new(qp.clone(), 1.0).unwrap();
    let mut served = [0u64; 3];
    let mut shed = [0u64; 3];
    for t in &replies {
        let class = Priority::ALL[(t.reply.id() - 1) as usize % 3];
        match &t.reply {
            Reply::Ok(r) => {
                served[class.idx()] += 1;
                // delivered replies stay exact even while shedding
                let s = 1.0 + 0.01 * (t.reply.id() - 1) as f64;
                let q: Vec<f64> =
                    qp.q.iter().map(|&v| v * s).collect();
                let opts = Options {
                    tol: 0.0,
                    max_iter: r.k_used,
                    backward: BackwardMode::Forward(Param::B),
                    ..Default::default()
                };
                let want =
                    direct.solve_with(Some(&q), None, None, &opts);
                for (a, b) in r.x.iter().zip(&want.x) {
                    assert!((a - b).abs() < 1e-8);
                }
            }
            Reply::Err(f) if f.kind == FailureKind::Overloaded => {
                assert!(f.error.contains("budget"), "{}", f.error);
                shed[class.idx()] += 1;
            }
            other => panic!("unexpected reply: {other:?}"),
        }
    }
    let (sh, sn, sl) = (
        shed[Priority::High.idx()],
        shed[Priority::Normal.idx()],
        shed[Priority::Low.idx()],
    );
    assert!(
        sl >= sn && sn >= sh,
        "shed order violated: low {sl} normal {sn} high {sh}"
    );
    assert!(sl > sh, "Low must shed strictly before High ({sl} vs {sh})");
    proxy.stop();
    let coord = lb.finish();
    for p in Priority::ALL {
        assert_eq!(
            coord.metrics.shed_by_class[p.idx()].load(ORD),
            shed[p.idx()],
            "{} shed counter != client tally",
            p.label()
        );
        assert_eq!(
            coord.metrics.served_by_class[p.idx()].load(ORD),
            served[p.idx()],
            "{} served counter != client tally",
            p.label()
        );
    }
    assert_eq!(
        coord.metrics.shed.load(ORD),
        shed.iter().sum::<u64>()
    );
}

/// Deadline budgets through the chaos proxy: a worker pinned by a live
/// solve means the 1µs-budget requests behind it are long expired at
/// every checkpoint — all come back `DeadlineExceeded`, the server's
/// deadline counter equals the client tally, and the execution
/// counters prove no expired request ever consumed a solve.
#[test]
fn deadline_sheds_reconcile_and_never_consume_a_solve() {
    let lb = start_server(
        Config {
            workers: 1,
            max_batch: 1,
            batch_timeout_us: 500,
            artifacts: None,
            ..Default::default()
        },
        NetConfig::default(),
    );
    let mut proxy = ChaosProxy::spawn(
        lb.addr,
        ChaosConfig {
            seed: 31,
            tear_prob: 0.4,
            stall_prob: 0.5,
            stall_us: 1_000,
            ..ChaosConfig::default()
        },
    )
    .expect("proxy");
    let qp = dense_qp(64, 32, 12, 2);
    const DOOMED: u64 = 12;
    let mut cl =
        PipelinedClient::connect(proxy.addr(), DOOMED as usize + 1)
            .expect("connect");
    cl.set_timeout(Some(Duration::from_secs(120))).unwrap();
    // id 1: no deadline, occupies the single worker for milliseconds
    let mut replies = cl
        .submit("d64", qp.q.clone(), qp.b.clone(), qp.h.clone(), None, 1e-3)
        .expect("live submit");
    // ids 2..: 1µs budgets, dead on arrival at whichever checkpoint
    // (shard queue or pre-execution) sees them first
    cl.set_deadline_us(1);
    for i in 0..DOOMED {
        cl.set_priority(Priority::ALL[i as usize % 3]);
        let s = 1.0 + 0.01 * i as f64;
        replies.extend(
            cl.submit(
                "d64",
                qp.q.iter().map(|&v| v * s).collect(),
                qp.b.clone(),
                qp.h.clone(),
                None,
                1e-3,
            )
            .expect("doomed submit"),
        );
    }
    replies.extend(cl.drain().expect("drain"));
    assert_eq!(replies.len(), DOOMED as usize + 1);
    let mut client_deadline_tally = 0u64;
    let direct = DenseAltDiff::new(qp.clone(), 1.0).unwrap();
    for t in &replies {
        match &t.reply {
            Reply::Ok(r) => {
                assert_eq!(t.reply.id(), 1, "only id 1 may be served");
                let opts = Options {
                    tol: 0.0,
                    max_iter: r.k_used,
                    backward: BackwardMode::Forward(Param::B),
                    ..Default::default()
                };
                let want = direct.solve_with(None, None, None, &opts);
                for (a, b) in r.x.iter().zip(&want.x) {
                    assert!((a - b).abs() < 1e-8);
                }
            }
            Reply::Err(f) => {
                assert_eq!(
                    f.kind,
                    FailureKind::DeadlineExceeded,
                    "id {}: {}",
                    f.id,
                    f.error
                );
                assert!(f.error.contains("deadline"), "{}", f.error);
                client_deadline_tally += 1;
            }
            other => panic!("unexpected reply {other:?}"),
        }
    }
    assert_eq!(client_deadline_tally, DOOMED);
    proxy.stop();
    let coord = lb.finish();
    let m = &coord.metrics;
    assert_eq!(
        m.deadline_shed.load(ORD),
        client_deadline_tally,
        "server deadline-shed counter != client DeadlineExceeded tally"
    );
    let by_class: u64 =
        (0..3).map(|i| m.deadline_by_class[i].load(ORD)).sum();
    assert_eq!(by_class, DOOMED);
    // only the live solve executed: one n=64 element, once
    assert_eq!(
        m.native_elems.load(ORD) + m.adjoint_elems.load(ORD),
        1,
        "an expired request consumed a solve"
    );
}

/// Connection kills mid-solve: a retry-armed blocking client keeps
/// its correctness contract — every answer it does deliver passes
/// 1e-8 parity, terminal failures are surfaced (not retried forever),
/// and the reconnect machinery demonstrably engaged.
#[test]
fn retry_client_survives_connection_kills_without_wrong_answers() {
    let lb = start_server(
        Config {
            workers: 2,
            max_batch: 4,
            batch_timeout_us: 1_000,
            artifacts: None,
            ..Default::default()
        },
        NetConfig::default(),
    );
    let mut proxy = ChaosProxy::spawn(
        lb.addr,
        ChaosConfig {
            seed: 47,
            tear_prob: 0.3,
            stall_prob: 0.3,
            stall_us: 500,
            reset_prob: 0.35,
            ..ChaosConfig::default()
        },
    )
    .expect("proxy");
    let qp = dense_qp(12, 6, 3, 9);
    let direct = DenseAltDiff::new(qp.clone(), 1.0).unwrap();
    let mut cl = Client::connect(proxy.addr()).expect("connect");
    cl.set_timeout(Some(Duration::from_secs(20))).unwrap();
    cl.set_retry(RetryPolicy {
        max_retries: 6,
        base_backoff: Duration::from_millis(2),
        max_backoff: Duration::from_millis(40),
        seed: 5,
    });
    let mut ok = 0u32;
    let mut transport_failures = 0u32;
    for i in 0..10u32 {
        let s = 1.0 + 0.03 * i as f64;
        let q: Vec<f64> = qp.q.iter().map(|&v| v * s).collect();
        match cl.solve(
            "dense12",
            q.clone(),
            qp.b.clone(),
            qp.h.clone(),
            1e-3,
        ) {
            Ok(Reply::Ok(r)) => {
                ok += 1;
                let opts = Options {
                    tol: 0.0,
                    max_iter: r.k_used,
                    backward: BackwardMode::Forward(Param::B),
                    ..Default::default()
                };
                let want =
                    direct.solve_with(Some(&q), None, None, &opts);
                for (a, b) in r.x.iter().zip(&want.x) {
                    assert!(
                        (a - b).abs() < 1e-8,
                        "retry delivered a wrong answer: {a} vs {b}"
                    );
                }
            }
            Ok(other) => panic!("unexpected reply {other:?}"),
            // retries exhausted against a kill-happy proxy: an honest
            // transport error, never a silent wrong answer
            Err(e) => {
                transport_failures += 1;
                let msg = e.to_string();
                assert!(!msg.is_empty());
            }
        }
    }
    assert!(
        ok >= 1,
        "bounded retry never completed a solve through resets \
         ({transport_failures} transport failures)"
    );
    let (retries, reconnects) = cl.retry_counts();
    assert!(
        retries >= 1 && reconnects >= 1,
        "reset_prob 0.35 over 10 ops must engage the retry path \
         (retries {retries}, reconnects {reconnects})"
    );
    proxy.stop();
    assert!(proxy.stats().resets.load(ORD) >= 1);
    lb.finish();
}
