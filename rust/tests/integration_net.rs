//! End-to-end tests of the network serving front end: loopback server,
//! concurrent pipelined clients, solve/VJP parity against direct engine
//! calls, overload shedding, malformed-frame isolation, admin ops, and
//! graceful drain.

use altdiff::altdiff::{
    BackwardMode, DenseAltDiff, Options, Param, SparseAltDiff,
};
use altdiff::coordinator::{
    Config, Coordinator, FailureKind, Reply,
};
use altdiff::net::frame::{blocking, header};
use altdiff::net::proto::op;
use altdiff::net::{Client, NetConfig, NetServer, PipelinedClient};
use altdiff::prob::{dense_qp, sparsemax_qp};
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Coordinator with one dense and one sparse layer (native backend).
fn test_coordinator() -> Coordinator {
    Coordinator::builder(Config {
        workers: 2,
        max_batch: 4,
        batch_timeout_us: 1_000,
        artifacts: None,
        ..Default::default()
    })
    .register("dense12", dense_qp(12, 6, 3, 9), 1.0)
    .unwrap()
    .register_sparse("smax40", sparsemax_qp(40, 11), 1.0)
    .unwrap()
    .start()
}

struct Loopback {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: std::thread::JoinHandle<Coordinator>,
}

fn start_server(cfg: NetConfig) -> Loopback {
    let coord = test_coordinator();
    let server =
        NetServer::bind("127.0.0.1:0", coord, cfg).expect("bind");
    let addr = server.local_addr().expect("addr");
    let stop = server.stop_handle();
    let handle = std::thread::spawn(move || server.run());
    Loopback { addr, stop, handle }
}

impl Loopback {
    fn finish(self) -> Coordinator {
        self.stop.store(true, Ordering::SeqCst);
        self.handle.join().expect("server thread")
    }
}

#[test]
fn concurrent_pipelined_clients_pin_solves_and_vjps_to_direct_calls() {
    let lb = start_server(NetConfig::default());
    let addr = lb.addr;
    let qp = dense_qp(12, 6, 3, 9);
    let sq = sparsemax_qp(40, 11);

    // ≥4 concurrent pipelined clients, mixing dense solves, dense
    // grads, and sparse solves
    let mut handles = Vec::new();
    for c in 0..4u64 {
        let qp = qp.clone();
        let sq = sq.clone();
        handles.push(std::thread::spawn(move || {
            let mut cl =
                PipelinedClient::connect(addr, 4).expect("connect");
            let mut replies = Vec::new();
            for i in 0..6 {
                let s = 1.0 + 0.02 * (c * 6 + i) as f64;
                let drained = match i % 3 {
                    0 => cl.submit(
                        "dense12",
                        qp.q.iter().map(|&v| v * s).collect(),
                        qp.b.clone(),
                        qp.h.clone(),
                        None,
                        1e-3,
                    ),
                    1 => cl.submit(
                        "dense12",
                        qp.q.iter().map(|&v| v * s).collect(),
                        qp.b.clone(),
                        qp.h.clone(),
                        Some((0..12)
                            .map(|j| 1.0 - 0.1 * j as f64)
                            .collect()),
                        1e-3,
                    ),
                    _ => cl.submit(
                        "smax40",
                        sq.q.iter().map(|&v| v * s).collect(),
                        sq.b.clone(),
                        sq.h.clone(),
                        None,
                        1e-3,
                    ),
                };
                replies.extend(drained.expect("submit"));
            }
            replies.extend(cl.drain().expect("drain"));
            (c, replies)
        }));
    }

    let dense = DenseAltDiff::new(qp.clone(), 1.0).unwrap();
    let sparse = SparseAltDiff::new(sq.clone(), 1.0).unwrap();
    let mut total = 0;
    for h in handles {
        let (c, replies) = h.join().expect("client thread");
        assert_eq!(replies.len(), 6, "client {c} lost replies");
        total += replies.len();
        for t in replies {
            match &t.reply {
                Reply::Ok(r) => {
                    // reconstruct this request's θ from its client id
                    // (ids are 1-based per connection, in send order)
                    let i = t.reply.id() - 1;
                    let s = 1.0 + 0.02 * (c * 6 + i) as f64;
                    let opts = Options {
                        tol: 0.0,
                        max_iter: r.k_used,
                        backward: BackwardMode::Forward(Param::B),
                        ..Default::default()
                    };
                    let direct = if i % 3 == 2 {
                        let q: Vec<f64> =
                            sq.q.iter().map(|&v| v * s).collect();
                        sparse.solve_with(Some(&q), None, None, &opts)
                    } else {
                        let q: Vec<f64> =
                            qp.q.iter().map(|&v| v * s).collect();
                        dense.solve_with(Some(&q), None, None, &opts)
                    };
                    assert_eq!(r.x.len(), direct.x.len());
                    for (a, b) in r.x.iter().zip(&direct.x) {
                        assert!(
                            (a - b).abs() < 1e-8,
                            "served x {a} vs direct {b}"
                        );
                    }
                    assert!(t.rtt > 0.0, "rtt measured");
                }
                Reply::Grad(g) => {
                    let i = t.reply.id() - 1;
                    let s = 1.0 + 0.02 * (c * 6 + i) as f64;
                    let q: Vec<f64> =
                        qp.q.iter().map(|&v| v * s).collect();
                    let v: Vec<f64> =
                        (0..12).map(|j| 1.0 - 0.1 * j as f64).collect();
                    let opts = Options {
                        tol: 0.0,
                        max_iter: g.k_used,
                        backward: BackwardMode::Adjoint,
                        ..Default::default()
                    };
                    let direct = dense
                        .solve_vjp(Some(&q), None, None, &v, &opts);
                    for (a, b) in
                        g.grad_q.iter().zip(&direct.vjp.grad_q)
                    {
                        assert!(
                            (a - b).abs() < 1e-8,
                            "served grad_q {a} vs direct {b}"
                        );
                    }
                    for (a, b) in
                        g.grad_h.iter().zip(&direct.vjp.grad_h)
                    {
                        assert!((a - b).abs() < 1e-8);
                    }
                }
                Reply::Err(f) => {
                    panic!("unexpected failure: {}", f.error)
                }
            }
        }
    }
    assert_eq!(total, 24);
    let coord = lb.finish();
    let ord = Ordering::Relaxed;
    assert!(coord.metrics.requests.load(ord) >= 24);
    assert_eq!(coord.metrics.shed.load(ord), 0);
}

#[test]
fn tiny_inflight_budget_sheds_with_overloaded_never_drops() {
    let lb = start_server(NetConfig {
        max_inflight: 1,
        ..Default::default()
    });
    let addr = lb.addr;
    let qp = dense_qp(12, 6, 3, 9);
    let mut handles = Vec::new();
    for c in 0..2u64 {
        let qp = qp.clone();
        handles.push(std::thread::spawn(move || {
            let mut cl =
                PipelinedClient::connect(addr, 32).expect("connect");
            let mut replies = Vec::new();
            for i in 0..32 {
                let s = 1.0 + 0.01 * (c * 32 + i) as f64;
                replies.extend(
                    cl.submit(
                        "dense12",
                        qp.q.iter().map(|&v| v * s).collect(),
                        qp.b.clone(),
                        qp.h.clone(),
                        None,
                        1e-3,
                    )
                    .expect("submit"),
                );
            }
            replies.extend(cl.drain().expect("drain"));
            replies
        }));
    }
    let mut ok = 0;
    let mut shed = 0;
    let mut answered = 0;
    for h in handles {
        let replies = h.join().expect("client");
        // never dropped: every request came back exactly once
        assert_eq!(replies.len(), 32, "replies lost under overload");
        answered += replies.len();
        for t in replies {
            match &t.reply {
                Reply::Ok(_) => ok += 1,
                Reply::Err(f) => {
                    assert_eq!(
                        f.kind,
                        FailureKind::Overloaded,
                        "unexpected failure kind: {}",
                        f.error
                    );
                    assert!(f.error.contains("budget"));
                    shed += 1;
                }
                Reply::Grad(_) => panic!("no grads sent"),
            }
        }
    }
    assert_eq!(answered, 64);
    assert!(ok >= 1, "budget of 1 still serves");
    assert!(shed >= 1, "64 pipelined requests at budget 1 must shed");
    let coord = lb.finish();
    assert_eq!(
        coord.metrics.shed.load(Ordering::Relaxed),
        shed as u64,
        "server-side shed counter matches client-observed sheds"
    );
}

#[test]
fn malformed_frames_close_the_connection_without_poisoning_the_rest() {
    let lb = start_server(NetConfig::default());
    let addr = lb.addr;

    // garbage bytes: server answers with a protocol Failure frame (or
    // just closes) and the connection dies
    let mut bad = TcpStream::connect(addr).expect("connect");
    blocking::write_frame(&mut bad, &[0xFFu8; 32]).unwrap();
    bad.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    match blocking::read_frame(&mut bad) {
        Ok(f) => {
            assert_eq!(f.op, op::R_ERR, "expected protocol failure");
        }
        Err(_) => {} // server may close before we read — also legal
    }

    // truncated-header frame followed by silence: no reply owed; just
    // make sure the server stays up
    let mut trunc = TcpStream::connect(addr).expect("connect");
    blocking::write_frame(&mut trunc, &header(op::SOLVE, 64)[..6])
        .unwrap();

    // valid frame with an oversized declared payload
    let mut big = TcpStream::connect(addr).expect("connect");
    let mut hdr = header(op::SOLVE, 0).to_vec();
    hdr[4..8].copy_from_slice(&u32::MAX.to_le_bytes());
    blocking::write_frame(&mut big, &hdr).unwrap();
    big.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    if let Ok(f) = blocking::read_frame(&mut big) {
        assert_eq!(f.op, op::R_ERR);
    }

    // ...and a healthy client is entirely unaffected
    let qp = dense_qp(12, 6, 3, 9);
    let mut good = Client::connect(addr).expect("connect");
    good.set_timeout(Some(Duration::from_secs(30))).unwrap();
    match good
        .solve("dense12", qp.q.clone(), qp.b.clone(), qp.h.clone(), 1e-3)
        .expect("healthy solve")
    {
        Reply::Ok(r) => {
            assert_eq!(r.x.len(), 12);
            assert!(r.x.iter().all(|v| v.is_finite()));
        }
        other => panic!("expected solve reply, got {other:?}"),
    }
    lb.finish();
}

#[test]
fn unknown_layer_and_bad_dims_come_back_as_invalid_failures() {
    let lb = start_server(NetConfig::default());
    let mut cl = Client::connect(lb.addr).expect("connect");
    cl.set_timeout(Some(Duration::from_secs(30))).unwrap();
    match cl
        .solve("nope", vec![0.0; 3], vec![], vec![], 1e-3)
        .expect("reply")
    {
        Reply::Err(f) => {
            assert_eq!(f.kind, FailureKind::Invalid);
            assert!(f.error.contains("unknown layer"));
        }
        other => panic!("expected failure, got {other:?}"),
    }
    match cl
        .solve("dense12", vec![0.0; 3], vec![0.0; 3], vec![0.0; 6], 1e-3)
        .expect("reply")
    {
        Reply::Err(f) => {
            assert_eq!(f.kind, FailureKind::Invalid);
            assert!(f.error.contains("dims"));
        }
        other => panic!("expected failure, got {other:?}"),
    }
    lb.finish();
}

#[test]
fn admin_ops_expose_layers_and_prometheus_stats() {
    let lb = start_server(NetConfig::default());
    let mut cl = Client::connect(lb.addr).expect("connect");
    cl.set_timeout(Some(Duration::from_secs(30))).unwrap();
    let layers = cl.layers().expect("layers");
    let names: Vec<&str> =
        layers.iter().map(|l| l.name.as_str()).collect();
    assert!(names.contains(&"dense12"));
    assert!(names.contains(&"smax40"));
    let d = layers.iter().find(|l| l.name == "dense12").unwrap();
    assert_eq!((d.n, d.m, d.p), (12, 6, 3));

    let qp = dense_qp(12, 6, 3, 9);
    cl.solve("dense12", qp.q.clone(), qp.b.clone(), qp.h.clone(), 1e-2)
        .expect("solve");
    let stats = cl.stats().expect("stats");
    assert!(stats.contains("altdiff_requests_total"));
    assert!(stats.contains("# TYPE altdiff_latency_us histogram"));
    assert!(stats.contains("altdiff_queue_depth"));
    assert!(stats.contains("le=\"+Inf\""));
    lb.finish();
}

#[test]
fn wire_stop_drains_gracefully_and_idle_peers_get_a_goodbye() {
    let lb = start_server(NetConfig::default());
    let addr = lb.addr;

    // an idle connection that just listens
    let mut idle = TcpStream::connect(addr).expect("connect");
    idle.set_read_timeout(Some(Duration::from_secs(30))).unwrap();

    // a working client completes a request, then stops the server
    let qp = dense_qp(12, 6, 3, 9);
    let mut cl = Client::connect(addr).expect("connect");
    cl.set_timeout(Some(Duration::from_secs(30))).unwrap();
    cl.solve("dense12", qp.q.clone(), qp.b.clone(), qp.h.clone(), 1e-3)
        .expect("solve");
    let final_stats = cl.stop_server().expect("stop ack");
    assert!(final_stats.contains("altdiff_responses_total"));

    // the idle peer receives the goodbye frame before close
    let f = blocking::read_frame(&mut idle).expect("goodbye frame");
    assert_eq!(f.op, op::R_GOODBYE);

    let coord = lb.handle.join().expect("server thread");
    let ord = Ordering::Relaxed;
    assert!(coord.metrics.responses.load(ord) >= 1);
    assert_eq!(coord.metrics.net_inflight.load(ord), 0);
    // the coordinator behind the server was shut down cleanly too:
    // its reply channel is drained and closed
    assert!(coord.try_recv().is_none());
}
