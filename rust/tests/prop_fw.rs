//! Frank–Wolfe-family instantiation of the shared cross-engine
//! conformance battery (`tests/common/conformance.rs`), plus the
//! FW-specific properties no other family has: ℓ1-ball solves with
//! non-unique duals, LMO vertex-tie determinism, away-step purging of a
//! polluted warm start, duality-gap traces, and the three-way router
//! (Alt-Diff / FW / ADMM) observable end to end over the `net/` stats.

#[path = "common/conformance.rs"]
mod conformance;

use altdiff::altdiff::{BackwardMode, DenseAltDiff, Options};
use altdiff::coordinator::{Config, Coordinator, Reply};
use altdiff::fw::FwQp;
use altdiff::net::{Client, NetConfig, NetServer};
use altdiff::obs::{IterObserver, IterSample, TraceCollector};
use altdiff::prob::{
    box_qp, dense_qp, ill_conditioned_qp, l1_ball_qp, simplex_qp, Qp,
};
use altdiff::warm::WarmStart;
use conformance::{counter, max_abs_diff, pseudo, tight, Cell};
use std::sync::atomic::Ordering;
use std::time::Duration;

// ------------------------------------------------------------- battery

/// The identical battery the other two families run, over the two
/// LMO structures whose duals the KKT system determines uniquely. The
/// ℓ1 ball is deliberately *not* a battery cell: its 2ⁿ-facet duals are
/// non-unique, so it gets the relaxed-tolerance extras below instead.
#[test]
fn fw_passes_the_shared_conformance_battery() {
    let cells = [
        Cell {
            name: "box(10)",
            qp: box_qp(10, 1),
            rho: 1.0,
            check_duals: true,
            perturb_b: false, // boxes have no equality block
            perturb_h: true,  // |δ| relaxation keeps l < u
        },
        Cell {
            name: "simplex(12)",
            qp: simplex_qp(12, 1.0, 7),
            rho: 1.0,
            perturb_b: true,  // r stays in [0.95, 1.05] > 0
            perturb_h: false, // the class pins h = 0
            check_duals: true,
        },
    ];
    conformance::run_battery(&cells, |cell| {
        let single =
            FwQp::new(cell.qp.clone(), cell.rho).expect("fw registration");
        let batched = altdiff::fw::BatchedFw::from_single(&single);
        (single, batched)
    });
}

// ------------------------------------------------------------ ℓ1 extras

/// ℓ1-ball solves against the dense oracle at relaxed tolerances: the
/// 2ⁿ sign facets make the duals non-unique (many facet combinations
/// certify the same vertex), so only x, the KKT residual, the unique
/// ∂L/∂q, and the *total* radius sensitivity Σᵢ ∂L/∂hᵢ are contracts.
#[test]
fn l1_ball_matches_the_oracle_with_relaxed_duals() {
    for seed in [3u64, 8] {
        let qp = l1_ball_qp(6, 1.5, seed);
        let fw = FwQp::new(qp.clone(), 1.0).unwrap();
        let oracle = DenseAltDiff::new(qp.clone(), 1.0).unwrap();
        let sol = fw.solve(&tight());
        let osol = oracle.solve(&tight());
        assert!(
            max_abs_diff(&sol.x, &osol.x) < 1e-6,
            "seed {seed}: x parity {}",
            max_abs_diff(&sol.x, &osol.x)
        );
        assert!(
            qp.kkt_residual(&sol.x, &sol.lam, &sol.nu) < 1e-6,
            "seed {seed}: recovered duals certify the solution"
        );

        // ∂L/∂q is unique even where the duals are not
        let aopts =
            Options { backward: BackwardMode::Adjoint, ..tight() };
        let v = pseudo(6, 17 + seed);
        let g = fw.vjp(&sol.s, &v, &aopts);
        let og = oracle.vjp(&osol.s, &v, &aopts);
        assert!(
            max_abs_diff(&g.grad_q, &og.grad_q) < 1e-5,
            "seed {seed}: grad_q parity {}",
            max_abs_diff(&g.grad_q, &og.grad_q)
        );

        // total radius sensitivity: every facet row shares h = r, so a
        // uniform bump is dL/dr and must match Σ grad_h by central FD
        // through the FW engine itself
        let dr: f64 = g.grad_h.iter().sum();
        let eps = 1e-5;
        let loss = |h: &[f64]| -> f64 {
            let s = fw.solve_with(None, None, Some(h), &tight());
            s.x.iter().zip(&v).map(|(x, w)| x * w).sum::<f64>()
        };
        let hp: Vec<f64> = qp.h.iter().map(|&x| x + eps).collect();
        let hm: Vec<f64> = qp.h.iter().map(|&x| x - eps).collect();
        let fd = (loss(&hp) - loss(&hm)) / (2.0 * eps);
        assert!(
            (dr - fd).abs() < 1e-4 * dr.abs().max(1.0),
            "seed {seed}: Σ grad_h {dr} vs FD dL/dr {fd}"
        );
    }
}

// ------------------------------------------------------------- LMO ties

/// Vertex ties resolve by the documented smallest-index rule, so a
/// problem symmetric in two coordinates solves to a symmetric point and
/// repeated solves are bitwise identical — no hidden iteration-order or
/// hash-order nondeterminism in the active set.
#[test]
fn simplex_vertex_ties_break_deterministically() {
    let mut qp = simplex_qp(8, 1.0, 5);
    // make the objective exactly symmetric in coordinates 0 and 1:
    // P = I and a shared linear pull
    for i in 0..8 {
        for j in 0..8 {
            qp.p[(i, j)] = if i == j { 1.0 } else { 0.0 };
        }
    }
    qp.q = (0..8).map(|i| 0.3 + 0.1 * i as f64).collect();
    qp.q[0] = -0.8;
    qp.q[1] = -0.8;
    let fw = FwQp::new(qp, 1.0).unwrap();
    let a = fw.solve(&tight());
    let mass: f64 = a.x.iter().sum();
    assert!((mass - 1.0).abs() < 1e-9, "simplex mass {mass}");
    assert!(
        (a.x[0] - a.x[1]).abs() < 1e-8,
        "symmetric coordinates diverged: {} vs {}",
        a.x[0],
        a.x[1]
    );
    let b = fw.solve(&tight());
    assert_eq!(a.x, b.x, "repeated solves are bitwise identical");
    assert_eq!(a.iters, b.iters);
}

// ----------------------------------------------------------- away steps

/// A warm start carrying mass on every vertex when the optimum is a
/// single vertex: the away/drop steps must purge the other nine weights
/// entirely, landing on the same fixed point as the cold solve.
#[test]
fn away_steps_purge_a_polluted_warm_start() {
    let mut qp = simplex_qp(10, 1.0, 13);
    qp.q = vec![0.5; 10];
    qp.q[0] = -8.0; // optimum pinned at vertex e₀ with a wide margin
    let fw = FwQp::new(qp.clone(), 1.0).unwrap();
    let cold = fw.solve(&tight());
    assert!(
        (cold.x[0] - 1.0).abs() < 1e-8,
        "vertex optimum: x₀ = {}",
        cold.x[0]
    );
    let uniform = WarmStart::new(
        vec![0.1; 10], // every vertex weighted — nine of them wrong
        vec![0.0; qp.p_eq()],
        vec![0.0; qp.m_ineq()],
    );
    let warm =
        fw.solve_from(None, None, None, Some(&uniform), &tight());
    assert!(
        max_abs_diff(&warm.x, &cold.x) < 1e-8,
        "away steps did not purge the polluted support: {}",
        max_abs_diff(&warm.x, &cold.x)
    );
    for (i, &xi) in warm.x.iter().enumerate().skip(1) {
        assert!(xi.abs() < 1e-8, "stale vertex {i} kept weight {xi}");
    }
}

// ---------------------------------------------------------------- traces

/// FW's observer convention: the primal slot carries the duality gap
/// gₖ = ∇f(xₖ)ᵀ(xₖ − vₖ) — a true convergence certificate — and it
/// falls over a fixed-k trace; observing never perturbs the solve.
#[test]
fn fw_traces_report_a_decreasing_duality_gap() {
    let k = 40;
    let fw = FwQp::new(simplex_qp(14, 1.0, 2), 1.0).unwrap();
    let opts = Options {
        rho: 1.0,
        tol: 0.0, // fixed-k: run exactly max_iter iterations
        max_iter: k,
        backward: BackwardMode::None,
        trace: false,
    };
    let mut coll = TraceCollector::new(1);
    coll.watch(0);
    let sol = fw.solve_observed(
        None,
        None,
        None,
        None,
        &opts,
        Some(&mut coll as &mut dyn IterObserver),
    );
    assert_eq!(sol.iters, k);
    let samples: Vec<IterSample> = coll.take(0).expect("watched");
    assert_eq!(samples.len(), k, "one gap sample per iteration");
    for (i, s) in samples.iter().enumerate() {
        assert_eq!(s.iter as usize, i, "iteration indices in order");
        // the gap is nonnegative by LMO optimality (float slack only)
        assert!(s.primal.is_finite() && s.primal >= -1e-10);
        assert!(s.dual.is_finite() && s.dual >= 0.0);
    }
    assert!(
        samples[0].primal > 1e-8,
        "cold LMO init should not already be optimal"
    );
    let head: f64 =
        samples[..5].iter().map(|s| s.primal).sum::<f64>() / 5.0;
    let tail: f64 =
        samples[k - 5..].iter().map(|s| s.primal).sum::<f64>() / 5.0;
    assert!(
        tail < head * 0.5,
        "duality gap did not fall: {head:.3e} → {tail:.3e}"
    );
    // observer transparency: bit-identical with and without
    let plain = fw.solve_from(None, None, None, None, &opts);
    assert_eq!(plain.x, sol.x);
    assert_eq!(plain.iters, sol.iters);
}

// ------------------------------------------------------------ the router

/// A simplex layer whose optimum sits exactly on the first vertex (FW's
/// cold LMO init — residual at float accuracy from rung one) while the
/// widened spectrum stalls the fixed-ρ Alt-Diff probe, exactly like the
/// `ill` layer does. FW therefore certifies every calibrated tolerance
/// at the first rung and must win the cell outright.
fn vertex_simplex_qp() -> Qp {
    let mut qp = simplex_qp(14, 1.0, 11);
    for i in 0..14 {
        qp.p[(i, i)] += 1e4 * i as f64 / 13.0;
    }
    for v in qp.q.iter_mut() {
        *v = v.abs() + 0.5;
    }
    qp.q[0] = -1e6;
    qp
}

/// Coordinator whose router faces all three outcomes: a well-behaved
/// dense layer (both probed families clear the first rung → tie →
/// Alt-Diff, the paper's engine), an ill-conditioned dense layer (ADMM
/// wins; FW is absent — the constraint block is not vertex-enumerable),
/// and a vertex-pinned simplex layer (FW wins from the first rung).
fn three_way_coordinator() -> Coordinator {
    Coordinator::builder(Config {
        workers: 2,
        max_batch: 4,
        batch_timeout_us: 1_000,
        artifacts: None,
        ..Default::default()
    })
    .ladder(vec![150, 600, 2400])
    .register_routed("well", dense_qp(12, 6, 3, 9), 1.0)
    .unwrap()
    .register_routed("ill", ill_conditioned_qp(10, 5, 2, 1e4, 7), 1.0)
    .unwrap()
    .register_routed("vertex14", vertex_simplex_qp(), 1.0)
    .unwrap()
    .start()
}

/// Three-way calibration: each layer routes to its winning family —
/// `native`, `native-admm`, `native-fw` — on solve AND gradient paths,
/// with the per-engine counters recording the split.
#[test]
fn router_splits_three_ways_across_engine_families() {
    let mut c = three_way_coordinator();
    let well = dense_qp(12, 6, 3, 9);
    let ill = ill_conditioned_qp(10, 5, 2, 1e4, 7);
    let vqp = vertex_simplex_qp();

    c.submit("well", well.q.clone(), well.b.clone(), well.h.clone(), 1e-1);
    c.submit("ill", ill.q.clone(), ill.b.clone(), ill.h.clone(), 1e-1);
    c.submit("vertex14", vqp.q.clone(), vqp.b.clone(), vqp.h.clone(), 1e-3);
    let (mut well_seen, mut ill_seen, mut fw_seen) = (false, false, false);
    for _ in 0..3 {
        match c.recv_timeout(Duration::from_secs(60)).expect("reply") {
            Reply::Ok(r) if r.x.len() == 12 => {
                assert_eq!(r.backend, "native", "well layer → Alt-Diff");
                well_seen = true;
            }
            Reply::Ok(r) if r.x.len() == 10 => {
                assert_eq!(r.backend, "native-admm", "ill layer → ADMM");
                ill_seen = true;
            }
            Reply::Ok(r) => {
                assert_eq!(r.x.len(), 14);
                assert_eq!(
                    r.backend, "native-fw",
                    "vertex simplex layer → FW"
                );
                assert!(
                    [150, 600, 2400].contains(&r.k_used),
                    "k_used is a ladder rung"
                );
                // the optimum IS the first vertex; FW serves it exactly
                assert!((r.x[0] - 1.0).abs() < 1e-6, "x₀ = {}", r.x[0]);
                assert!(r.x[1..].iter().all(|&v| v.abs() < 1e-6));
                fw_seen = true;
            }
            other => panic!("unexpected reply {other:?}"),
        }
    }
    assert!(well_seen && ill_seen && fw_seen);

    // gradient path routes through the same winner table
    let v14 = pseudo(14, 3);
    c.submit_grad(
        "vertex14",
        vqp.q.clone(),
        vqp.b.clone(),
        vqp.h.clone(),
        v14,
        1e-3,
    );
    match c.recv_timeout(Duration::from_secs(60)).expect("reply") {
        Reply::Grad(g) => {
            assert_eq!(g.backend, "native-fw");
            assert_eq!(g.x.len(), 14);
            assert_eq!(g.grad_q.len(), 14);
            assert_eq!(g.grad_b.len(), 1);
            assert_eq!(g.grad_h.len(), 14);
        }
        other => panic!("unexpected reply {other:?}"),
    }

    let ord = Ordering::Relaxed;
    assert!(c.metrics.router_fw_picks.load(ord) >= 2, "fw picks");
    assert!(c.metrics.router_admm_picks.load(ord) >= 1, "admm picks");
    assert!(
        c.metrics.router_altdiff_picks.load(ord) >= 1,
        "altdiff picks"
    );
    assert!(c.metrics.fw_execs.load(ord) >= 2, "fw launches");
    assert!(c.metrics.fw_elems.load(ord) >= 2);
    assert!(c.metrics.fw_iters.load(ord) > 0);
}

/// The FW counters reconcile over the wire protocol: solve the FW-won
/// layer and an Alt-Diff-won layer through a loopback server, then read
/// the per-family split back out of the Prometheus stats op.
#[test]
fn fw_counters_round_trip_through_net_stats() {
    let coord = three_way_coordinator();
    let server = NetServer::bind("127.0.0.1:0", coord, NetConfig::default())
        .expect("bind");
    let addr = server.local_addr().expect("addr");
    let stop = server.stop_handle();
    let handle = std::thread::spawn(move || server.run());

    let well = dense_qp(12, 6, 3, 9);
    let vqp = vertex_simplex_qp();
    let mut cl = Client::connect(addr).expect("connect");
    cl.set_timeout(Some(Duration::from_secs(60))).unwrap();
    match cl
        .solve("well", well.q.clone(), well.b.clone(), well.h.clone(), 1e-1)
        .expect("well solve")
    {
        Reply::Ok(r) => assert_eq!(r.backend, "native"),
        other => panic!("unexpected reply {other:?}"),
    }
    match cl
        .solve("vertex14", vqp.q.clone(), vqp.b.clone(), vqp.h.clone(), 1e-3)
        .expect("vertex solve")
    {
        Reply::Ok(r) => assert_eq!(r.backend, "native-fw"),
        other => panic!("unexpected reply {other:?}"),
    }

    let stats = cl.stats().expect("stats");
    assert!(counter(&stats, "altdiff_fw_execs_total") >= 1);
    assert!(counter(&stats, "altdiff_fw_elems_total") >= 1);
    assert!(counter(&stats, "altdiff_router_fw_picks_total") >= 1);
    assert!(counter(&stats, "altdiff_fw_iters_total") > 0);
    assert!(counter(&stats, "altdiff_router_altdiff_picks_total") >= 1);

    stop.store(true, Ordering::SeqCst);
    handle.join().expect("server thread");
}
