//! Shared cross-engine conformance suite.
//!
//! Every engine family (Alt-Diff, ADMM, Frank–Wolfe) serves the same
//! contracts: solve parity against the dense Alt-Diff oracle, ragged
//! batches reproducing sequential solves, fixed-k (tol = 0) running
//! exactly k iterations in lockstep, warm `None` bit-identity plus
//! mixed warm/cold isolation, and adjoint VJPs agreeing with central
//! finite differences. This module states each contract ONCE as a
//! generic component over two small traits; the per-family test files
//! (`prop_admm.rs`, `prop_batched.rs`, `prop_fw.rs`) only instantiate
//! the battery with their engines plus family-specific extras.
//!
//! Include from a test crate with
//! `#[path = "common/conformance.rs"] mod conformance;` — CI greps for
//! re-declared copies of the exported helpers in `tests/prop_*.rs`, so
//! parity thresholds live here and nowhere else.
#![allow(dead_code)]

use altdiff::altdiff::{
    BackwardMode, DenseAltDiff, Options, Param, Solution, Vjp,
};
use altdiff::batch::{BatchSolution, BatchVjp};
use altdiff::prob::Qp;
use altdiff::warm::WarmStart;

// ------------------------------------------------------------- helpers

/// Largest elementwise absolute difference (asserts equal lengths).
pub fn max_abs_diff(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len(), "length mismatch");
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max)
}

/// Elementwise closeness with a labelled failure message.
pub fn assert_close(a: &[f64], b: &[f64], tol: f64, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(
            (x - y).abs() < tol,
            "{what}[{i}]: {x} vs {y} (|Δ|={})",
            (x - y).abs()
        );
    }
}

/// Deterministic pseudo-random vector in [-0.5, 0.5) (splitmix-style).
pub fn pseudo(len: usize, seed: u64) -> Vec<f64> {
    let mut s = seed.wrapping_mul(0x9e3779b97f4a7c15).wrapping_add(1);
    (0..len)
        .map(|_| {
            s = s
                .wrapping_mul(6364136223846793005)
                .wrapping_add(1442695040888963407);
            ((s >> 11) as f64 / (1u64 << 53) as f64) - 0.5
        })
        .collect()
}

/// Forward-only options tight enough that every family's fixed point is
/// indistinguishable from exact at the parity thresholds below.
pub fn tight() -> Options {
    Options {
        rho: 1.0,
        tol: 1e-12,
        max_iter: 200_000,
        backward: BackwardMode::None,
        trace: false,
    }
}

/// Extract a Prometheus counter value from a `net/` stats text.
pub fn counter(stats: &str, name: &str) -> u64 {
    let prefix = format!("{name} ");
    stats
        .lines()
        .find_map(|l| l.strip_prefix(prefix.as_str()))
        .unwrap_or_else(|| panic!("counter {name} missing"))
        .trim()
        .parse()
        .expect("counter value")
}

// -------------------------------------------------------- engine traits

/// The sequential engine contract every family exposes (delegation-only
/// impls — the suite never reaches past these five calls).
pub trait SingleEngine {
    /// Engine-tagged adjoint resume state.
    type Seed: Clone;
    /// The registered problem.
    fn qp(&self) -> &Qp;
    /// The engine's genuine cold entry point (`solve_with`) — kept
    /// distinct from `solve_from(…, None, …)` so the warm=None
    /// bit-identity contract compares two real code paths.
    fn solve_cold(
        &self,
        q: Option<&[f64]>,
        b: Option<&[f64]>,
        h: Option<&[f64]>,
        opts: &Options,
    ) -> Solution;
    /// Solve with per-request θ overrides, resuming from `warm`.
    fn solve_from(
        &self,
        q: Option<&[f64]>,
        b: Option<&[f64]>,
        h: Option<&[f64]>,
        warm: Option<&WarmStart>,
        opts: &Options,
    ) -> Solution;
    /// Adjoint VJP gated by a forward solve's final slack, resuming
    /// from `seed`; returns the final state for the next caller.
    fn vjp_from(
        &self,
        slack: &[f64],
        v: &[f64],
        seed: Option<&Self::Seed>,
        opts: &Options,
    ) -> (Vjp, Self::Seed);
}

/// The batched engine contract (one launch, B elements, ragged
/// truncation, mixed warm/cold).
pub trait BatchEngine {
    /// Same engine-tagged seed type as the family's sequential engine.
    type Seed: Clone;
    /// One batched forward launch.
    fn solve_batch_from(
        &self,
        qs: Option<&[&[f64]]>,
        bs: Option<&[&[f64]]>,
        hs: Option<&[&[f64]]>,
        warms: Option<&[Option<WarmStart>]>,
        opts: &Options,
    ) -> BatchSolution;
    /// One batched adjoint launch.
    fn batch_vjp_from(
        &self,
        slacks: &[&[f64]],
        vs: &[&[f64]],
        seeds: Option<&[Option<Self::Seed>]>,
        opts: &Options,
    ) -> (BatchVjp, Vec<Self::Seed>);
}

impl SingleEngine for DenseAltDiff {
    type Seed = altdiff::warm::AdjointSeed;
    fn qp(&self) -> &Qp {
        &self.qp
    }
    fn solve_cold(
        &self,
        q: Option<&[f64]>,
        b: Option<&[f64]>,
        h: Option<&[f64]>,
        opts: &Options,
    ) -> Solution {
        DenseAltDiff::solve_with(self, q, b, h, opts)
    }
    fn solve_from(
        &self,
        q: Option<&[f64]>,
        b: Option<&[f64]>,
        h: Option<&[f64]>,
        warm: Option<&WarmStart>,
        opts: &Options,
    ) -> Solution {
        DenseAltDiff::solve_from(self, q, b, h, warm, opts)
    }
    fn vjp_from(
        &self,
        slack: &[f64],
        v: &[f64],
        seed: Option<&Self::Seed>,
        opts: &Options,
    ) -> (Vjp, Self::Seed) {
        DenseAltDiff::vjp_from(self, slack, v, seed, opts)
    }
}

impl BatchEngine for altdiff::batch::BatchedAltDiff {
    type Seed = altdiff::warm::AdjointSeed;
    fn solve_batch_from(
        &self,
        qs: Option<&[&[f64]]>,
        bs: Option<&[&[f64]]>,
        hs: Option<&[&[f64]]>,
        warms: Option<&[Option<WarmStart>]>,
        opts: &Options,
    ) -> BatchSolution {
        altdiff::batch::BatchedAltDiff::solve_batch_from(
            self, qs, bs, hs, warms, opts,
        )
    }
    fn batch_vjp_from(
        &self,
        slacks: &[&[f64]],
        vs: &[&[f64]],
        seeds: Option<&[Option<Self::Seed>]>,
        opts: &Options,
    ) -> (BatchVjp, Vec<Self::Seed>) {
        altdiff::batch::BatchedAltDiff::batch_vjp_from(
            self, slacks, vs, seeds, opts,
        )
    }
}

impl SingleEngine for altdiff::admm::AdmmQp {
    type Seed = altdiff::warm::AdmmSeed;
    fn qp(&self) -> &Qp {
        &self.qp
    }
    fn solve_cold(
        &self,
        q: Option<&[f64]>,
        b: Option<&[f64]>,
        h: Option<&[f64]>,
        opts: &Options,
    ) -> Solution {
        altdiff::admm::AdmmQp::solve_with(self, q, b, h, opts)
    }
    fn solve_from(
        &self,
        q: Option<&[f64]>,
        b: Option<&[f64]>,
        h: Option<&[f64]>,
        warm: Option<&WarmStart>,
        opts: &Options,
    ) -> Solution {
        altdiff::admm::AdmmQp::solve_from(self, q, b, h, warm, opts)
    }
    fn vjp_from(
        &self,
        slack: &[f64],
        v: &[f64],
        seed: Option<&Self::Seed>,
        opts: &Options,
    ) -> (Vjp, Self::Seed) {
        altdiff::admm::AdmmQp::vjp_from(self, slack, v, seed, opts)
    }
}

impl BatchEngine for altdiff::admm::BatchedAdmm {
    type Seed = altdiff::warm::AdmmSeed;
    fn solve_batch_from(
        &self,
        qs: Option<&[&[f64]]>,
        bs: Option<&[&[f64]]>,
        hs: Option<&[&[f64]]>,
        warms: Option<&[Option<WarmStart>]>,
        opts: &Options,
    ) -> BatchSolution {
        altdiff::admm::BatchedAdmm::solve_batch_from(
            self, qs, bs, hs, warms, opts,
        )
    }
    fn batch_vjp_from(
        &self,
        slacks: &[&[f64]],
        vs: &[&[f64]],
        seeds: Option<&[Option<Self::Seed>]>,
        opts: &Options,
    ) -> (BatchVjp, Vec<Self::Seed>) {
        altdiff::admm::BatchedAdmm::batch_vjp_from(
            self, slacks, vs, seeds, opts,
        )
    }
}

impl SingleEngine for altdiff::fw::FwQp {
    type Seed = altdiff::warm::FwSeed;
    fn qp(&self) -> &Qp {
        &self.qp
    }
    fn solve_cold(
        &self,
        q: Option<&[f64]>,
        b: Option<&[f64]>,
        h: Option<&[f64]>,
        opts: &Options,
    ) -> Solution {
        altdiff::fw::FwQp::solve_with(self, q, b, h, opts)
    }
    fn solve_from(
        &self,
        q: Option<&[f64]>,
        b: Option<&[f64]>,
        h: Option<&[f64]>,
        warm: Option<&WarmStart>,
        opts: &Options,
    ) -> Solution {
        altdiff::fw::FwQp::solve_from(self, q, b, h, warm, opts)
    }
    fn vjp_from(
        &self,
        slack: &[f64],
        v: &[f64],
        seed: Option<&Self::Seed>,
        opts: &Options,
    ) -> (Vjp, Self::Seed) {
        altdiff::fw::FwQp::vjp_from(self, slack, v, seed, opts)
    }
}

impl BatchEngine for altdiff::fw::BatchedFw {
    type Seed = altdiff::warm::FwSeed;
    fn solve_batch_from(
        &self,
        qs: Option<&[&[f64]]>,
        bs: Option<&[&[f64]]>,
        hs: Option<&[&[f64]]>,
        warms: Option<&[Option<WarmStart>]>,
        opts: &Options,
    ) -> BatchSolution {
        altdiff::fw::BatchedFw::solve_batch_from(
            self, qs, bs, hs, warms, opts,
        )
    }
    fn batch_vjp_from(
        &self,
        slacks: &[&[f64]],
        vs: &[&[f64]],
        seeds: Option<&[Option<Self::Seed>]>,
        opts: &Options,
    ) -> (BatchVjp, Vec<Self::Seed>) {
        altdiff::fw::BatchedFw::batch_vjp_from(
            self, slacks, vs, seeds, opts,
        )
    }
}

// ----------------------------------------------------------- the cells

/// One battery cell: a problem plus the perturbation/check flags its
/// constraint structure allows.
pub struct Cell {
    /// Label used in failure messages.
    pub name: &'static str,
    /// The registered problem.
    pub qp: Qp,
    /// Registration ρ.
    pub rho: f64,
    /// Check dual (λ, ν) and gradient parity against the oracle — off
    /// for structures whose duals are non-unique.
    pub check_duals: bool,
    /// Perturb b per element (off when p = 0 or the class pins b).
    pub perturb_b: bool,
    /// Relax h per element (off when the class pins h, e.g. simplex).
    pub perturb_h: bool,
}

/// Per-element feasible perturbations of the cell's registered θ:
/// q rescaled, b nudged (bounded ±5%, keeping class invariants like
/// r > 0), h only *relaxed* so strictly feasible points stay feasible.
pub fn perturb_thetas(
    cell: &Cell,
    bsz: usize,
) -> (Vec<Vec<f64>>, Vec<Vec<f64>>, Vec<Vec<f64>>) {
    let qp = &cell.qp;
    let mut qs = Vec::with_capacity(bsz);
    let mut bs = Vec::with_capacity(bsz);
    let mut hs = Vec::with_capacity(bsz);
    for e in 0..bsz as u64 {
        let dq = pseudo(qp.q.len(), 100 + e);
        qs.push(
            qp.q
                .iter()
                .zip(&dq)
                .map(|(v, d)| v * (1.0 + 0.2 * d))
                .collect::<Vec<_>>(),
        );
        if cell.perturb_b {
            let db = pseudo(qp.b.len(), 200 + e);
            bs.push(
                qp.b.iter()
                    .zip(&db)
                    .map(|(v, d)| v + 0.1 * d)
                    .collect::<Vec<_>>(),
            );
        } else {
            bs.push(qp.b.clone());
        }
        if cell.perturb_h {
            let dh = pseudo(qp.h.len(), 300 + e);
            hs.push(
                qp.h.iter()
                    .zip(&dh)
                    .map(|(v, d)| v + (0.2 * d).abs())
                    .collect::<Vec<_>>(),
            );
        } else {
            hs.push(qp.h.clone());
        }
    }
    (qs, bs, hs)
}

fn refs(v: &[Vec<f64>]) -> Vec<&[f64]> {
    v.iter().map(|x| x.as_slice()).collect()
}

// ----------------------------------------------------- the components

/// Solve parity: at tight tolerance the engine's primal/slack iterates
/// match the dense Alt-Diff oracle to 1e-8 (duals to 1e-7 when the
/// cell's structure determines them uniquely).
pub fn solve_parity_vs_dense<S: SingleEngine>(cell: &Cell, single: &S) {
    let oracle = DenseAltDiff::new(cell.qp.clone(), cell.rho)
        .expect("oracle registration")
        .solve(&tight());
    let sol = single.solve_from(None, None, None, None, &tight());
    assert!(
        max_abs_diff(&sol.x, &oracle.x) < 1e-8,
        "{}: x parity {}",
        cell.name,
        max_abs_diff(&sol.x, &oracle.x)
    );
    assert!(
        max_abs_diff(&sol.s, &oracle.s) < 1e-8,
        "{}: slack parity",
        cell.name
    );
    if cell.check_duals {
        assert!(
            max_abs_diff(&sol.lam, &oracle.lam) < 1e-7,
            "{}: λ parity",
            cell.name
        );
        assert!(
            max_abs_diff(&sol.nu, &oracle.nu) < 1e-7,
            "{}: ν parity",
            cell.name
        );
    }
}

/// Ragged batches: a 5-element batch of distinct θ reproduces the
/// sequential solves element-wise — x/s to 1e-8, duals to 1e-7 (gated),
/// forward-mode Jacobians to 1e-7, iteration counts within ±1.
pub fn ragged_batch_matches_singles<
    S: SingleEngine,
    B: BatchEngine<Seed = S::Seed>,
>(
    cell: &Cell,
    single: &S,
    batched: &B,
) {
    let bsz = 5;
    let (qs, bs, hs) = perturb_thetas(cell, bsz);
    let (qr, br, hr) = (refs(&qs), refs(&bs), refs(&hs));
    // track ∂x/∂b where the cell has equalities, ∂x/∂q otherwise
    let fparam =
        if cell.qp.p_eq() > 0 { Param::B } else { Param::Q };
    let opts = Options {
        rho: cell.rho,
        tol: 1e-11,
        max_iter: 200_000,
        backward: BackwardMode::Forward(fparam),
        trace: false,
    };
    let sol = batched.solve_batch_from(
        Some(&qr),
        Some(&br),
        Some(&hr),
        None,
        &opts,
    );
    let jacs = sol.jacobians.as_ref().expect("forward mode tracked");
    for e in 0..bsz {
        let one = single.solve_from(
            Some(&qs[e]),
            Some(&bs[e]),
            Some(&hs[e]),
            None,
            &opts,
        );
        let ctx = format!("{} elem {e}", cell.name);
        assert!(
            max_abs_diff(&sol.xs[e], &one.x) < 1e-8,
            "{ctx}: x parity {}",
            max_abs_diff(&sol.xs[e], &one.x)
        );
        assert!(max_abs_diff(&sol.ss[e], &one.s) < 1e-8, "{ctx}: s");
        if cell.check_duals {
            assert!(
                max_abs_diff(&sol.lams[e], &one.lam) < 1e-7,
                "{ctx}: λ"
            );
            assert!(
                max_abs_diff(&sol.nus[e], &one.nu) < 1e-7,
                "{ctx}: ν"
            );
        }
        let ja = one.jacobian.as_ref().expect("single jacobian");
        assert_eq!(
            (jacs[e].rows, jacs[e].cols),
            (ja.rows, ja.cols),
            "{ctx}: jacobian shape"
        );
        assert!(
            max_abs_diff(&jacs[e].data, &ja.data) < 1e-7,
            "{ctx}: jacobian parity"
        );
        assert!(
            sol.iters[e].abs_diff(one.iters) <= 1,
            "{ctx}: iters {} vs {}",
            sol.iters[e],
            one.iters
        );
    }
}

/// Fixed-k semantics (Thm 4.3, the compiled-artifact contract): tol = 0
/// with max_iter = k runs EXACTLY k iterations — no early exit — and
/// single/batched stay in lockstep at every k.
pub fn fixed_k_exact<S: SingleEngine, B: BatchEngine<Seed = S::Seed>>(
    cell: &Cell,
    single: &S,
    batched: &B,
) {
    for k in [1usize, 7, 23] {
        let opts = Options {
            rho: cell.rho,
            tol: 0.0,
            max_iter: k,
            backward: BackwardMode::None,
            trace: false,
        };
        let one = single.solve_from(None, None, None, None, &opts);
        assert_eq!(one.iters, k, "{}: single fixed-k", cell.name);
        let sol =
            batched.solve_batch_from(None, None, None, None, &opts);
        assert_eq!(
            sol.iters,
            vec![k],
            "{}: batched fixed-k",
            cell.name
        );
        assert!(
            max_abs_diff(&sol.xs[0], &one.x) < 1e-10,
            "{}: fixed-k lockstep at k={k}",
            cell.name
        );
    }
}

/// Warm contract: `warm = None` is bit-identical to the cold solve, a
/// converged iterate reproduces itself in ≤ 2 iterations, and a batch
/// may mix warm and cold members without cross-talk.
pub fn warm_equals_cold_and_mixed<
    S: SingleEngine,
    B: BatchEngine<Seed = S::Seed>,
>(
    cell: &Cell,
    single: &S,
    batched: &B,
) {
    let opts = Options {
        rho: cell.rho,
        tol: 1e-10,
        max_iter: 200_000,
        backward: BackwardMode::None,
        trace: false,
    };
    let cold = single.solve_cold(None, None, None, &opts);
    let resumed = single.solve_from(None, None, None, None, &opts);
    assert_eq!(cold.x, resumed.x, "{}: warm=None bit-identity", cell.name);
    assert_eq!(cold.iters, resumed.iters);

    let ws = WarmStart::of(&cold);
    let warm = single.solve_from(None, None, None, Some(&ws), &opts);
    assert!(
        warm.iters <= 2,
        "{}: fixed point reproduces itself ({} iters)",
        cell.name,
        warm.iters
    );
    assert!(
        max_abs_diff(&warm.x, &cold.x) < 1e-9,
        "{}: warm x parity",
        cell.name
    );

    // mixed batch: element 0 resumes the fixed point, element 1 is cold
    let warms = vec![Some(ws), None];
    let sol = batched.solve_batch_from(
        None,
        None,
        None,
        Some(&warms),
        &opts,
    );
    assert!(
        sol.iters[0] <= 2,
        "{}: warm element truncates early",
        cell.name
    );
    assert!(
        sol.iters[1] > sol.iters[0],
        "{}: cold element undisturbed by its warm neighbour",
        cell.name
    );
    assert!(max_abs_diff(&sol.xs[0], &cold.x) < 1e-8, "{}", cell.name);
    assert!(max_abs_diff(&sol.xs[1], &cold.x) < 1e-8, "{}", cell.name);
}

/// Adjoint correctness: the engine's VJP agrees with central finite
/// differences of L(θ) = vᵀx*(θ) through the engine itself along a
/// random direction per parameter, and grad_q matches the dense
/// Alt-Diff oracle's adjoint (gated on `check_duals` — grad_b/grad_h
/// parity rides the same gate since those flow through the duals).
pub fn vjp_vs_oracle_and_fd<S: SingleEngine>(cell: &Cell, single: &S) {
    let n = cell.qp.n();
    let v = pseudo(n, 999);
    let bopts = Options {
        rho: cell.rho,
        tol: 1e-12,
        max_iter: 200_000,
        backward: BackwardMode::Adjoint,
        trace: false,
    };
    let fwd = single.solve_from(None, None, None, None, &tight());
    let (vjp, _) = single.vjp_from(&fwd.s, &v, None, &bopts);

    if cell.check_duals {
        let oracle = DenseAltDiff::new(cell.qp.clone(), cell.rho)
            .expect("oracle registration");
        let osol = oracle.solve(&tight());
        let ovjp = oracle.vjp(&osol.s, &v, &bopts);
        assert!(
            max_abs_diff(&vjp.grad_q, &ovjp.grad_q) < 1e-6,
            "{}: grad_q oracle parity",
            cell.name
        );
        assert!(
            max_abs_diff(&vjp.grad_b, &ovjp.grad_b) < 1e-6,
            "{}: grad_b oracle parity",
            cell.name
        );
        assert!(
            max_abs_diff(&vjp.grad_h, &ovjp.grad_h) < 1e-6,
            "{}: grad_h oracle parity",
            cell.name
        );
    }

    // central differences through the engine itself, one random
    // direction per perturbable parameter
    let eps = 1e-6;
    let loss = |q: &[f64], b: &[f64], h: &[f64]| -> f64 {
        let s = single.solve_from(
            Some(q),
            Some(b),
            Some(h),
            None,
            &tight(),
        );
        s.x.iter().zip(&v).map(|(x, vv)| x * vv).sum()
    };
    let mut dirs = vec![(pseudo(n, 41), Param::Q)];
    if cell.perturb_b {
        dirs.push((pseudo(cell.qp.b.len(), 42), Param::B));
    }
    if cell.perturb_h {
        dirs.push((pseudo(cell.qp.h.len(), 43), Param::H));
    }
    for (dir, param) in &dirs {
        let perturb = |sign: f64| {
            let mut q = cell.qp.q.clone();
            let mut b = cell.qp.b.clone();
            let mut h = cell.qp.h.clone();
            let target: &mut Vec<f64> = match param {
                Param::Q => &mut q,
                Param::B => &mut b,
                Param::H => &mut h,
            };
            for (t, d) in target.iter_mut().zip(dir) {
                *t += sign * eps * d;
            }
            loss(&q, &b, &h)
        };
        let fd = (perturb(1.0) - perturb(-1.0)) / (2.0 * eps);
        let analytic: f64 = vjp
            .grad(*param)
            .iter()
            .zip(dir)
            .map(|(g, d)| g * d)
            .sum();
        assert!(
            (fd - analytic).abs() < 1e-4 * analytic.abs().max(1.0),
            "{} {param:?}: fd {fd} vs analytic {analytic}",
            cell.name
        );
    }
}

/// Batched adjoints reproduce the single VJPs to 1e-8, and a harvested
/// seed resumes the backward in a bounded restart (no slower than
/// cold, and near-instant from the converged state).
pub fn batch_vjp_matches_singles_and_seeds<
    S: SingleEngine,
    B: BatchEngine<Seed = S::Seed>,
>(
    cell: &Cell,
    single: &S,
    batched: &B,
) {
    let n = cell.qp.n();
    let bopts = Options {
        rho: cell.rho,
        tol: 1e-11,
        max_iter: 200_000,
        backward: BackwardMode::Adjoint,
        trace: false,
    };
    let fwd = single.solve_from(None, None, None, None, &tight());
    let vs: Vec<Vec<f64>> = (0..3).map(|e| pseudo(n, 700 + e)).collect();
    let vrefs: Vec<&[f64]> = vs.iter().map(|v| v.as_slice()).collect();
    let slacks: Vec<&[f64]> =
        (0..3).map(|_| fwd.s.as_slice()).collect();

    let (bv, _) = batched.batch_vjp_from(&slacks, &vrefs, None, &bopts);
    for e in 0..3 {
        let (one, _) = single.vjp_from(&fwd.s, &vs[e], None, &bopts);
        let ctx = format!("{} v{e}", cell.name);
        assert!(
            max_abs_diff(&bv.grads_q[e], &one.grad_q) < 1e-8,
            "{ctx}: grads_q"
        );
        assert!(
            max_abs_diff(&bv.grads_b[e], &one.grad_b) < 1e-8,
            "{ctx}: grads_b"
        );
        assert!(
            max_abs_diff(&bv.grads_h[e], &one.grad_h) < 1e-8,
            "{ctx}: grads_h"
        );
    }

    // seed round trip: the converged adjoint state reproduces itself
    // in a bounded restart
    let (cold, seed) = single.vjp_from(&fwd.s, &vs[0], None, &bopts);
    let (warm, _) =
        single.vjp_from(&fwd.s, &vs[0], Some(&seed), &bopts);
    assert!(
        warm.iters <= cold.iters && warm.iters <= 6,
        "{}: seeded adjoint restarts bounded ({} vs cold {})",
        cell.name,
        warm.iters,
        cold.iters
    );
    assert!(max_abs_diff(&warm.grad_q, &cold.grad_q) < 1e-8);
    assert!(max_abs_diff(&warm.grad_h, &cold.grad_h) < 1e-8);
}

// ------------------------------------------------------------ battery

/// Run every component on every cell. `mk` builds the family's
/// (sequential, batched) engine pair for a cell; each family's test
/// file calls this once — the contracts themselves live above and are
/// never copied per family.
pub fn run_battery<S, B>(cells: &[Cell], mk: impl Fn(&Cell) -> (S, B))
where
    S: SingleEngine,
    B: BatchEngine<Seed = S::Seed>,
{
    for cell in cells {
        let (single, batched) = mk(cell);
        solve_parity_vs_dense(cell, &single);
        ragged_batch_matches_singles(cell, &single, &batched);
        fixed_k_exact(cell, &single, &batched);
        warm_equals_cold_and_mixed(cell, &single, &batched);
        vjp_vs_oracle_and_fd(cell, &single);
        batch_vjp_matches_singles_and_seeds(cell, &single, &batched);
    }
}
