//! End-to-end coordinator tests: routing, batching, truncation policy,
//! fallback, failure handling — on both backends.

use altdiff::coordinator::{Config, Coordinator, Reply};
use altdiff::prob::{dense_qp, sparsemax_qp};
use std::path::{Path, PathBuf};
use std::time::Duration;

fn artifacts_dir() -> Option<PathBuf> {
    if cfg!(not(feature = "pjrt")) {
        // default build substitutes the stub Engine — the coordinator
        // would silently serve natively, so skip the pjrt assertions
        return None;
    }
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.tsv").exists().then_some(dir)
}

fn native_coordinator(n: usize, m: usize, p: usize) -> Coordinator {
    Coordinator::builder(Config {
        workers: 2,
        max_batch: 4,
        batch_timeout_us: 1_000,
        artifacts: None,
        ..Default::default()
    })
    .register("layer0", dense_qp(n, m, p, 9), 1.0)
    .unwrap()
    .start()
}

#[test]
fn native_roundtrip_single_request() {
    let mut c = native_coordinator(12, 6, 3);
    let qp = dense_qp(12, 6, 3, 9);
    c.submit("layer0", qp.q.clone(), qp.b.clone(), qp.h.clone(), 1e-3);
    let reply = c.recv_timeout(Duration::from_secs(30)).expect("reply");
    match reply {
        Reply::Ok(r) => {
            assert_eq!(r.x.len(), 12);
            assert_eq!(r.jx.len(), 12 * 3);
            assert_eq!(r.backend, "native");
            assert!(r.k_used >= 10);
            assert!(r.latency >= 0.0);
        }
        Reply::Err(f) => panic!("unexpected failure: {}", f.error),
        Reply::Grad(_) => panic!("unexpected grad reply"),
    }
}

#[test]
fn unknown_layer_yields_failure_not_hang() {
    let mut c = native_coordinator(8, 4, 2);
    c.submit("nope", vec![0.0; 8], vec![0.0; 2], vec![0.0; 4], 1e-3);
    match c.recv_timeout(Duration::from_secs(10)).expect("reply") {
        Reply::Err(f) => assert!(f.error.contains("unknown layer")),
        _ => panic!("expected failure"),
    }
}

#[test]
fn malformed_theta_dims_yield_failure_not_worker_panic() {
    let mut c = native_coordinator(8, 4, 2);
    // q too short for the registered layer: must come back as a Failure
    // (routed requests are validated before they can reach a batched
    // launch and panic the worker)
    c.submit("layer0", vec![0.0; 3], vec![0.0; 2], vec![0.0; 4], 1e-3);
    match c.recv_timeout(Duration::from_secs(10)).expect("reply") {
        Reply::Err(f) => assert!(f.error.contains("dims"), "{}", f.error),
        _ => panic!("expected failure"),
    }
    // and the coordinator still serves well-formed requests afterwards
    let qp = dense_qp(8, 4, 2, 9);
    c.submit("layer0", qp.q.clone(), qp.b.clone(), qp.h.clone(), 1e-3);
    match c.recv_timeout(Duration::from_secs(30)).expect("reply") {
        Reply::Ok(r) => assert_eq!(r.x.len(), 8),
        Reply::Err(f) => panic!("healthy request failed: {}", f.error),
        Reply::Grad(_) => panic!("unexpected grad reply"),
    }
}

#[test]
fn many_requests_all_answered_exactly_once() {
    let mut c = native_coordinator(10, 5, 2);
    let qp = dense_qp(10, 5, 2, 9);
    let thetas: Vec<_> = (0..17)
        .map(|i| {
            let s = 1.0 + 0.01 * i as f64;
            (
                qp.q.iter().map(|&v| v * s).collect::<Vec<_>>(),
                qp.b.clone(),
                qp.h.clone(),
            )
        })
        .collect();
    let replies = c.run_all("layer0", thetas, 1e-2);
    assert_eq!(replies.len(), 17);
    let mut seen = std::collections::BTreeSet::new();
    for r in &replies {
        assert!(seen.insert(r.id()), "duplicate reply id");
        if let Reply::Ok(ok) = r {
            assert!(ok.x.iter().all(|v| v.is_finite()));
        } else {
            panic!("failure in batch");
        }
    }
    assert!(
        c.metrics.batches.load(std::sync::atomic::Ordering::Relaxed) >= 5
    );
}

#[test]
fn native_fallback_is_one_batched_launch_per_batch() {
    // 8 same-layer/same-tol requests, max_batch 8, one worker: the
    // dispatcher forms full batches and the native path must execute
    // each as a single BatchedAltDiff launch — native_execs counts
    // launches, never requests.
    let qp = dense_qp(12, 6, 3, 9);
    let mut c = Coordinator::builder(Config {
        workers: 1,
        max_batch: 8,
        // generous deadline: the 8 requests below are submitted in a
        // tight loop, so they coalesce long before a flush can fire
        // even on a heavily loaded CI machine
        batch_timeout_us: 200_000,
        artifacts: None,
        ..Default::default()
    })
    .register("layer0", qp.clone(), 1.0)
    .unwrap()
    .start();
    let thetas: Vec<_> = (0..8)
        .map(|i| {
            let s = 1.0 + 0.02 * i as f64;
            (
                qp.q.iter().map(|&v| v * s).collect::<Vec<_>>(),
                qp.b.clone(),
                qp.h.clone(),
            )
        })
        .collect();
    let replies = c.run_all("layer0", thetas, 1e-2);
    assert_eq!(replies.len(), 8);
    for r in &replies {
        match r {
            Reply::Ok(ok) => {
                assert_eq!(ok.backend, "native");
                assert!(ok.x.iter().all(|v| v.is_finite()));
            }
            Reply::Err(f) => panic!("failure: {}", f.error),
            Reply::Grad(_) => panic!("unexpected grad reply"),
        }
    }
    let ord = std::sync::atomic::Ordering::Relaxed;
    let execs = c.metrics.native_execs.load(ord);
    let batches = c.metrics.batches.load(ord);
    let elems = c.metrics.native_elems.load(ord);
    assert_eq!(elems, 8, "every request flowed through a native launch");
    assert_eq!(
        execs, batches,
        "one native launch per dispatched batch"
    );
    assert!(
        execs <= 4,
        "burst of 8 compatible requests fragmented into {execs} launches"
    );
    assert!(c.metrics.native_batch_occupancy() >= 2.0);
}

#[test]
fn sparse_layer_batches_run_on_the_sparse_engine() {
    // a sparsemax layer served natively: every dispatched batch must be
    // ONE BatchedSparseAltDiff launch, counted by native_sparse_execs
    let sq = sparsemax_qp(40, 11);
    let mut c = Coordinator::builder(Config {
        workers: 1,
        max_batch: 8,
        batch_timeout_us: 200_000,
        artifacts: None,
        ..Default::default()
    })
    .register_sparse("smax40", sq.clone(), 1.0)
    .unwrap()
    .start();
    let thetas: Vec<_> = (0..8)
        .map(|i| {
            let s = 1.0 + 0.05 * i as f64;
            (
                sq.q.iter().map(|&v| v * s).collect::<Vec<_>>(),
                sq.b.clone(),
                sq.h.clone(),
            )
        })
        .collect();
    let replies = c.run_all("smax40", thetas, 1e-3);
    assert_eq!(replies.len(), 8);
    for r in &replies {
        match r {
            Reply::Ok(ok) => {
                assert_eq!(ok.backend, "native-sparse");
                assert_eq!(ok.x.len(), 40);
                // ∂x/∂b for the single equality row
                assert_eq!(ok.jx.len(), 40);
                assert!(ok.x.iter().all(|v| v.is_finite()));
                // simplex structure survives the serving path
                let sum: f64 = ok.x.iter().sum();
                assert!((sum - 1.0).abs() < 0.2, "sum {sum}");
            }
            Reply::Err(f) => panic!("failure: {}", f.error),
            Reply::Grad(_) => panic!("unexpected grad reply"),
        }
    }
    let ord = std::sync::atomic::Ordering::Relaxed;
    let sparse_execs = c.metrics.native_sparse_execs.load(ord);
    let execs = c.metrics.native_execs.load(ord);
    assert!(sparse_execs >= 1, "no sparse batched launch recorded");
    assert_eq!(
        sparse_execs, execs,
        "sparse layer must only run on the sparse engine"
    );
    assert_eq!(c.metrics.native_elems.load(ord), 8);
    assert!(
        execs <= 4,
        "burst of 8 compatible requests fragmented into {execs} launches"
    );
}

#[test]
fn dense_and_sparse_layers_coexist() {
    let qp = dense_qp(10, 5, 2, 9);
    let sq = sparsemax_qp(12, 3);
    let mut c = Coordinator::builder(Config {
        workers: 2,
        max_batch: 4,
        batch_timeout_us: 1_000,
        artifacts: None,
        ..Default::default()
    })
    .register("dense10", qp.clone(), 1.0)
    .unwrap()
    .register_sparse("smax12", sq.clone(), 1.0)
    .unwrap()
    .start();
    c.submit("dense10", qp.q.clone(), qp.b.clone(), qp.h.clone(), 1e-3);
    c.submit("smax12", sq.q.clone(), sq.b.clone(), sq.h.clone(), 1e-3);
    let mut backends = std::collections::BTreeSet::new();
    for _ in 0..2 {
        match c.recv_timeout(Duration::from_secs(30)).expect("reply") {
            Reply::Ok(r) => {
                backends.insert(r.backend);
            }
            Reply::Err(f) => panic!("failure: {}", f.error),
            Reply::Grad(_) => panic!("unexpected grad reply"),
        }
    }
    assert!(backends.contains("native"));
    assert!(backends.contains("native-sparse"));
}

#[test]
fn looser_tolerance_routes_to_fewer_iterations() {
    let mut c = native_coordinator(12, 6, 3);
    let qp = dense_qp(12, 6, 3, 9);
    c.submit("layer0", qp.q.clone(), qp.b.clone(), qp.h.clone(), 1e-1);
    let loose = match c.recv_timeout(Duration::from_secs(30)).unwrap() {
        Reply::Ok(r) => r.k_used,
        _ => panic!("expected solve reply"),
    };
    c.submit("layer0", qp.q.clone(), qp.b.clone(), qp.h.clone(), 1e-4);
    let tight = match c.recv_timeout(Duration::from_secs(30)).unwrap() {
        Reply::Ok(r) => r.k_used,
        _ => panic!("expected solve reply"),
    };
    assert!(
        loose <= tight,
        "k(1e-1)={loose} should be <= k(1e-4)={tight}"
    );
}

#[test]
fn pjrt_backend_serves_compiled_sizes() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("artifacts missing; skipping pjrt coordinator test");
        return;
    };
    let qp = dense_qp(16, 8, 4, 3);
    let mut c = Coordinator::builder(Config {
        workers: 1,
        max_batch: 8,
        batch_timeout_us: 1_000,
        artifacts: Some(dir),
        ..Default::default()
    })
    .register("qp16", qp.clone(), 1.0)
    .unwrap()
    .start();
    let thetas: Vec<_> = (0..8)
        .map(|i| {
            let s = 1.0 + 0.02 * i as f64;
            (
                qp.q.iter().map(|&v| v * s).collect::<Vec<_>>(),
                qp.b.clone(),
                qp.h.clone(),
            )
        })
        .collect();
    let replies = c.run_all("qp16", thetas, 1e-3);
    assert_eq!(replies.len(), 8);
    let mut pjrt_served = 0;
    for r in replies {
        if let Reply::Ok(ok) = r {
            if ok.backend == "pjrt" {
                pjrt_served += 1;
            }
            assert!(ok.x.iter().all(|v| v.is_finite()));
            assert!(ok.prim_residual.is_finite());
        } else {
            panic!("failure");
        }
    }
    assert!(pjrt_served > 0, "no request served by the compiled path");
}

#[test]
fn pjrt_and_native_agree_through_coordinator() {
    let Some(dir) = artifacts_dir() else { return };
    let qp = dense_qp(16, 8, 4, 5);
    let mk = |artifacts: Option<PathBuf>| {
        Coordinator::builder(Config {
            workers: 1,
            max_batch: 1,
            batch_timeout_us: 1_000,
            artifacts,
            ..Default::default()
        })
        .register("l", qp.clone(), 1.0)
        .unwrap()
        .start()
    };
    let solve = |c: &mut Coordinator| -> Vec<f64> {
        c.submit("l", qp.q.clone(), qp.b.clone(), qp.h.clone(), 1e-3);
        match c.recv_timeout(Duration::from_secs(30)).unwrap() {
            Reply::Ok(r) => r.x,
            _ => panic!("expected solve reply"),
        }
    };
    let mut cp = mk(Some(dir));
    let mut cn = mk(None);
    let xp = solve(&mut cp);
    let xn = solve(&mut cn);
    for i in 0..16 {
        assert!(
            (xp[i] - xn[i]).abs() < 1e-3,
            "x[{i}]: pjrt {} native {}",
            xp[i],
            xn[i]
        );
    }
}

#[test]
fn gradient_requests_round_trip_without_jacobians() {
    use altdiff::altdiff::{BackwardMode, DenseAltDiff, Options};
    let qp = dense_qp(10, 5, 2, 9);
    let mut c = native_coordinator(10, 5, 2);
    let v: Vec<f64> = (0..10).map(|i| 1.0 - 0.1 * i as f64).collect();
    c.submit_grad(
        "layer0",
        qp.q.clone(),
        qp.b.clone(),
        qp.h.clone(),
        v.clone(),
        1e-4,
    );
    let reply = c.recv_timeout(Duration::from_secs(30)).expect("reply");
    let (g, k_used) = match reply {
        Reply::Grad(g) => {
            assert_eq!(g.x.len(), 10);
            assert_eq!(g.grad_q.len(), 10);
            assert_eq!(g.grad_b.len(), 2);
            assert_eq!(g.grad_h.len(), 5);
            assert_eq!(g.backend, "native");
            assert!(g.grad_q.iter().all(|x| x.is_finite()));
            let k = g.k_used;
            (g, k)
        }
        Reply::Ok(_) => panic!("expected grad reply, got solve"),
        Reply::Err(f) => panic!("grad request failed: {}", f.error),
    };
    // parity with a direct engine call at the same fixed k
    let solver = DenseAltDiff::new(qp, 1.0).unwrap();
    let opts = Options {
        tol: 0.0,
        max_iter: k_used,
        backward: BackwardMode::Adjoint,
        ..Default::default()
    };
    let direct = solver.solve_vjp(None, None, None, &v, &opts);
    for i in 0..10 {
        assert!(
            (g.grad_q[i] - direct.vjp.grad_q[i]).abs() < 1e-8,
            "grad_q[{i}]: served {} direct {}",
            g.grad_q[i],
            direct.vjp.grad_q[i]
        );
    }
    let ord = std::sync::atomic::Ordering::Relaxed;
    assert!(c.metrics.adjoint_execs.load(ord) >= 1);
    assert_eq!(c.metrics.adjoint_elems.load(ord), 1);
}

#[test]
fn grad_and_solve_requests_share_the_server_but_not_batches() {
    let qp = dense_qp(10, 5, 2, 9);
    let mut c = Coordinator::builder(Config {
        workers: 1,
        max_batch: 4,
        batch_timeout_us: 5_000,
        artifacts: None,
        ..Default::default()
    })
    .register("layer0", qp.clone(), 1.0)
    .unwrap()
    .start();
    let v = vec![1.0; 10];
    for _ in 0..4 {
        c.submit("layer0", qp.q.clone(), qp.b.clone(), qp.h.clone(), 1e-2);
        c.submit_grad(
            "layer0",
            qp.q.clone(),
            qp.b.clone(),
            qp.h.clone(),
            v.clone(),
            1e-2,
        );
    }
    let mut solves = 0;
    let mut grads = 0;
    for _ in 0..8 {
        match c.recv_timeout(Duration::from_secs(30)).expect("reply") {
            Reply::Ok(r) => {
                solves += 1;
                // solve replies still carry the Jacobian
                assert_eq!(r.jx.len(), 10 * 2);
            }
            Reply::Grad(g) => {
                grads += 1;
                // grad replies never carry one — O(n+m+p) floats only
                assert_eq!(
                    g.grad_q.len() + g.grad_b.len() + g.grad_h.len(),
                    10 + 2 + 5
                );
            }
            Reply::Err(f) => panic!("failure: {}", f.error),
        }
    }
    assert_eq!((solves, grads), (4, 4));
    let ord = std::sync::atomic::Ordering::Relaxed;
    assert!(c.metrics.adjoint_execs.load(ord) >= 1);
    assert_eq!(c.metrics.adjoint_elems.load(ord), 4);
}

#[test]
fn malformed_grad_seed_yields_failure() {
    let mut c = native_coordinator(8, 4, 2);
    // v has the wrong length: must come back as a Failure reply
    c.submit_grad(
        "layer0",
        vec![0.0; 8],
        vec![0.0; 2],
        vec![0.0; 4],
        vec![1.0; 3],
        1e-3,
    );
    match c.recv_timeout(Duration::from_secs(10)).expect("reply") {
        Reply::Err(f) => assert!(f.error.contains("dims"), "{}", f.error),
        _ => panic!("expected failure"),
    }
}

#[test]
fn shutdown_is_clean_with_pending_work() {
    let mut c = native_coordinator(10, 5, 2);
    let qp = dense_qp(10, 5, 2, 9);
    for _ in 0..3 {
        c.submit("layer0", qp.q.clone(), qp.b.clone(), qp.h.clone(), 1e-2);
    }
    // immediate shutdown must not deadlock; pending work is flushed.
    c.shutdown();
}
