//! Property tests for the batched sparse engine: `BatchedSparseAltDiff`
//! must reproduce `SparseAltDiff` run element-by-element — solutions,
//! duals, slacks, and Jacobians to 1e-8 — across ragged batch sizes,
//! every Jacobian parameter, both x-update engines (batched
//! Sherman–Morrison and blocked CG), fixed-iteration (server)
//! semantics, and mixed per-element convergence speeds (the truncation
//! mask).

#[path = "common/conformance.rs"]
mod conformance;

use altdiff::altdiff::{BackwardMode, Options, Param, SparseAltDiff};
use altdiff::batch::BatchedSparseAltDiff;
use altdiff::prob::{sparse_qp, sparsemax_qp, SparseQp};
use altdiff::sparse::Csr;
use altdiff::util::Pcg64;
use conformance::max_abs_diff;

/// Per-element q perturbations (q is unconstrained, so any perturbation
/// keeps the problem feasible).
fn random_qs(base: &[f64], bsz: usize, rng: &mut Pcg64) -> Vec<Vec<f64>> {
    (0..bsz)
        .map(|_| {
            base.iter().map(|&v| v * (1.0 + 0.2 * rng.normal())).collect()
        })
        .collect()
}

fn refs(v: &[Vec<f64>]) -> Vec<&[f64]> {
    v.iter().map(|x| x.as_slice()).collect()
}

/// ∀ sparse problems (both engine picks), ragged batch sizes, and
/// Jacobian parameters: converged batched results match per-element
/// sequential results to 1e-8.
#[test]
fn prop_batched_sparse_matches_sequential_elementwise() {
    let mut rng = Pcg64::new(901);
    let params = [Param::Q, Param::B, Param::H];
    for case in 0..6u64 {
        // alternate engine picks: even cases sparsemax (SM), odd random
        // sparse (CG)
        let sq = if case % 2 == 0 {
            sparsemax_qp(10 + 2 * case as usize, 9000 + case)
        } else {
            sparse_qp(
                8 + 2 * case as usize,
                4 + case as usize,
                1 + (case as usize % 3),
                0.3,
                9100 + case,
            )
        };
        let seq = SparseAltDiff::new(sq.clone(), 1.0).unwrap();
        let batched = BatchedSparseAltDiff::from_sparse(&seq);
        assert_eq!(
            batched.uses_sherman_morrison(),
            case % 2 == 0,
            "engine pick case {case}"
        );
        let bsz = 1 + rng.below(9); // ragged: 1..=9
        let param = params[case as usize % 3];
        let opts = Options {
            tol: 1e-11,
            max_iter: 60_000,
            backward: BackwardMode::Forward(param),
            ..Default::default()
        };
        let qs = random_qs(&sq.q, bsz, &mut rng);
        let qr = refs(&qs);
        let sb = batched.solve_batch(Some(&qr), None, None, &opts);
        assert_eq!(sb.len(), bsz);
        for e in 0..bsz {
            let sd = seq.solve_with(Some(&qs[e]), None, None, &opts);
            let ctx = format!(
                "case {case} elem {e}/{bsz} n={} param {param:?}",
                sq.n()
            );
            assert!(
                max_abs_diff(&sb.xs[e], &sd.x) < 1e-8,
                "{ctx}: x diff {}",
                max_abs_diff(&sb.xs[e], &sd.x)
            );
            assert!(max_abs_diff(&sb.lams[e], &sd.lam) < 1e-8, "{ctx}: λ");
            assert!(max_abs_diff(&sb.nus[e], &sd.nu) < 1e-8, "{ctx}: ν");
            assert!(max_abs_diff(&sb.ss[e], &sd.s) < 1e-8, "{ctx}: s");
            let jb = &sb.jacobians.as_ref().unwrap()[e];
            let jd = sd.jacobian.as_ref().unwrap();
            assert!(
                jb.max_abs_diff(jd) < 1e-8,
                "{ctx}: jacobian diff {}",
                jb.max_abs_diff(jd)
            );
        }
    }
}

/// Server semantics (tol = 0, fixed k): every element runs exactly k
/// iterations and matches the sequential engine's fixed-k run to 1e-8,
/// on both engines.
#[test]
fn prop_batched_sparse_fixed_k_matches_sequential() {
    let mut rng = Pcg64::new(902);
    for &k in &[5usize, 25] {
        for (sq, label) in [
            (sparsemax_qp(18, 920 + k as u64), "sm"),
            (sparse_qp(14, 6, 3, 0.3, 930 + k as u64), "cg"),
        ] {
            let seq = SparseAltDiff::new(sq.clone(), 1.0).unwrap();
            let batched = BatchedSparseAltDiff::from_sparse(&seq);
            let bsz = 6;
            let qs = random_qs(&sq.q, bsz, &mut rng);
            let qr = refs(&qs);
            let opts = Options {
                tol: 0.0,
                max_iter: k,
                backward: BackwardMode::Forward(Param::B),
                ..Default::default()
            };
            let sb = batched.solve_batch(Some(&qr), None, None, &opts);
            assert!(
                sb.iters.iter().all(|&it| it == k),
                "{label}: {:?}",
                sb.iters
            );
            for e in 0..bsz {
                let sd = seq.solve_with(Some(&qs[e]), None, None, &opts);
                assert_eq!(sd.iters, k);
                assert!(
                    max_abs_diff(&sb.xs[e], &sd.x) < 1e-8,
                    "{label} k={k} elem {e}"
                );
                let jb = &sb.jacobians.as_ref().unwrap()[e];
                assert!(
                    jb.max_abs_diff(sd.jacobian.as_ref().unwrap()) < 1e-8,
                    "{label} k={k} elem {e}: jacobian"
                );
            }
        }
    }
}

/// Mixed convergence speeds: elements on very different objective
/// scales cross the relative-step threshold at different iterations;
/// the active mask must freeze fast elements without perturbing slow
/// ones, on both engines.
#[test]
fn prop_batched_sparse_mixed_convergence_speeds() {
    for (sq, label) in [
        (sparsemax_qp(20, 940), "sm"),
        (sparse_qp(16, 7, 2, 0.3, 941), "cg"),
    ] {
        let seq = SparseAltDiff::new(sq.clone(), 1.0).unwrap();
        let batched = BatchedSparseAltDiff::from_sparse(&seq);
        let scales = [1e-2, 1.0, 50.0, 0.1, 10.0];
        let qs: Vec<Vec<f64>> = scales
            .iter()
            .map(|&s| sq.q.iter().map(|&v| v * s).collect())
            .collect();
        let qr = refs(&qs);
        let opts = Options {
            tol: 1e-6,
            max_iter: 60_000,
            backward: BackwardMode::Forward(Param::Q),
            ..Default::default()
        };
        let sb = batched.solve_batch(Some(&qr), None, None, &opts);
        // the mask actually fired at different times
        let min_it = *sb.iters.iter().min().unwrap();
        let max_it = *sb.iters.iter().max().unwrap();
        assert!(
            min_it < max_it,
            "{label}: expected heterogeneous convergence, got {:?}",
            sb.iters
        );
        for (e, q) in qs.iter().enumerate() {
            let sd = seq.solve_with(Some(q), None, None, &opts);
            // identical stopping rule; ±2 iteration slack for blocked-
            // kernel vs unrolled-dot rounding at the threshold
            assert!(
                (sb.iters[e] as i64 - sd.iters as i64).abs() <= 2,
                "{label} elem {e}: batched {} vs sequential {} iters",
                sb.iters[e],
                sd.iters
            );
            for i in 0..sq.n() {
                let tol_here = 1e-4 * (1.0 + sd.x[i].abs());
                assert!(
                    (sb.xs[e][i] - sd.x[i]).abs() < tol_here,
                    "{label} elem {e} x[{i}]: {} vs {}",
                    sb.xs[e][i],
                    sd.x[i]
                );
            }
            assert!(sb.step_rel[e] < 1e-6);
        }
    }
}

/// Mixed engine picks on the same underlying problem: the sparsemax
/// structure run through the batched Sherman–Morrison path must agree
/// with a mathematically equivalent formulation (G rows rescaled by 2,
/// which defeats the ±1 box detection) run through the blocked-CG path
/// — same minimizer, same ∂x/∂b.
#[test]
fn prop_engine_picks_agree_on_equivalent_problems() {
    let sm_qp = sparsemax_qp(24, 950);
    // rescale every G row and its h entry by 2: {2gᵀx ≤ 2h} ≡ {gᵀx ≤ h}
    let n = sm_qp.n();
    let mut triplets = Vec::new();
    for i in 0..sm_qp.g.rows {
        for k in sm_qp.g.indptr[i]..sm_qp.g.indptr[i + 1] {
            triplets.push((i, sm_qp.g.indices[k], 2.0 * sm_qp.g.values[k]));
        }
    }
    let cg_qp = SparseQp {
        pdiag: sm_qp.pdiag.clone(),
        q: sm_qp.q.clone(),
        a: sm_qp.a.clone(),
        b: sm_qp.b.clone(),
        g: Csr::from_triplets(sm_qp.g.rows, n, &triplets),
        h: sm_qp.h.iter().map(|&v| 2.0 * v).collect(),
    };
    let sm = BatchedSparseAltDiff::new(sm_qp, 1.0).unwrap();
    let cg = BatchedSparseAltDiff::new(cg_qp, 1.0).unwrap();
    assert!(sm.uses_sherman_morrison());
    assert!(!cg.uses_sherman_morrison());
    let opts = Options {
        tol: 1e-11,
        max_iter: 80_000,
        backward: BackwardMode::Forward(Param::B),
        ..Default::default()
    };
    let qs: Vec<Vec<f64>> = (0..3)
        .map(|s| {
            sm.qp
                .q
                .iter()
                .enumerate()
                .map(|(i, &v)| v + 0.1 * ((i + s) as f64).sin())
                .collect()
        })
        .collect();
    let qr = refs(&qs);
    let a = sm.solve_batch(Some(&qr), None, None, &opts);
    let b = cg.solve_batch(Some(&qr), None, None, &opts);
    for e in 0..3 {
        assert!(
            max_abs_diff(&a.xs[e], &b.xs[e]) < 1e-6,
            "elem {e}: x diff {}",
            max_abs_diff(&a.xs[e], &b.xs[e])
        );
        let ja = &a.jacobians.as_ref().unwrap()[e];
        let jb = &b.jacobians.as_ref().unwrap()[e];
        assert!(
            ja.max_abs_diff(jb) < 1e-5,
            "elem {e}: jacobian diff {}",
            ja.max_abs_diff(jb)
        );
    }
}

/// Broadcast semantics: omitted θ falls back to the registered
/// parameters, matching an explicit broadcast element-for-element.
#[test]
fn prop_broadcast_equals_explicit_replication() {
    let sq = sparse_qp(12, 5, 2, 0.35, 960);
    let batched = BatchedSparseAltDiff::new(sq.clone(), 1.0).unwrap();
    let opts = Options {
        tol: 1e-10,
        max_iter: 40_000,
        backward: BackwardMode::Forward(Param::H),
        ..Default::default()
    };
    let qs: Vec<Vec<f64>> = vec![sq.q.clone(); 4];
    let qr = refs(&qs);
    // qs explicit, b/h broadcast vs everything explicit
    let bs: Vec<Vec<f64>> = vec![sq.b.clone(); 4];
    let hs: Vec<Vec<f64>> = vec![sq.h.clone(); 4];
    let br = refs(&bs);
    let hr = refs(&hs);
    let partial = batched.solve_batch(Some(&qr), None, None, &opts);
    let full =
        batched.solve_batch(Some(&qr), Some(&br), Some(&hr), &opts);
    for e in 0..4 {
        assert_eq!(partial.xs[e], full.xs[e], "elem {e}");
        assert_eq!(partial.iters[e], full.iters[e]);
    }
}
