//! Adjoint-correctness property tests: reverse mode must agree with
//! full-Jacobian-then-`gemv_t` across all four engines.
//!
//! Both modes converge to the same limit (vᵀJ* = vᵀ(I−M)⁻¹C =
//! ((I−Mᵀ)⁻¹Pᵀv)ᵀC), so at tight truncation tolerances the gradients
//! pin to 1e-8 — on the dense sequential/batched engines, the sparse
//! Sherman–Morrison path, and the blocked-CG path; under ragged batches
//! and mixed per-element convergence; and against a finite-difference
//! directional derivative of the solver itself.

#[path = "common/conformance.rs"]
mod conformance;

use altdiff::altdiff::{
    BackwardMode, DenseAltDiff, Options, Param, SparseAltDiff,
};
use altdiff::batch::{BatchedAltDiff, BatchedSparseAltDiff};
use altdiff::prob::{dense_qp, sparse_qp, sparsemax_qp};
use altdiff::util::rng::Pcg64;
use conformance::{max_abs_diff, tight};

/// The shared tight options with a backward pass attached.
fn rev(backward: BackwardMode) -> Options {
    Options { backward, ..tight() }
}

#[test]
fn dense_adjoint_matches_full_jacobian_every_param() {
    let solver = DenseAltDiff::new(dense_qp(14, 7, 3, 11), 1.0).unwrap();
    let mut rng = Pcg64::new(1);
    let v = rng.normal_vec(14);
    // one adjoint backward yields all three gradients at once
    let out = solver.solve_vjp(
        None,
        None,
        None,
        &v,
        &rev(BackwardMode::Adjoint),
    );
    assert!(out.solution.jacobian.is_none());
    for param in [Param::Q, Param::B, Param::H] {
        let sol = solver.solve(&rev(BackwardMode::Forward(param)));
        let want = sol.vjp(&v);
        let got = out.vjp.grad(param);
        assert!(
            max_abs_diff(got, &want) < 1e-8,
            "{param:?}: adjoint {got:?} vs forward-mode {want:?}"
        );
    }
}

#[test]
fn dense_adjoint_matches_finite_difference_direction() {
    let solver = DenseAltDiff::new(dense_qp(12, 6, 3, 21), 1.0).unwrap();
    let mut rng = Pcg64::new(2);
    let v = rng.normal_vec(12);
    let out = solver.solve_vjp(
        None,
        None,
        None,
        &v,
        &rev(BackwardMode::Adjoint),
    );
    let fopts = rev(BackwardMode::None);
    let eps = 1e-6;
    // directional derivative of L(θ) = vᵀx*(θ) along a random δ, per θ
    let dirs_q = rng.normal_vec(12);
    let dirs_b = rng.normal_vec(3);
    let dirs_h = rng.normal_vec(6);
    let dot = |a: &[f64], b: &[f64]| -> f64 {
        a.iter().zip(b).map(|(x, y)| x * y).sum()
    };
    // q
    let qp: Vec<f64> = solver
        .qp
        .q
        .iter()
        .zip(&dirs_q)
        .map(|(x, d)| x + eps * d)
        .collect();
    let qm: Vec<f64> = solver
        .qp
        .q
        .iter()
        .zip(&dirs_q)
        .map(|(x, d)| x - eps * d)
        .collect();
    let xp = solver.solve_with(Some(&qp), None, None, &fopts).x;
    let xm = solver.solve_with(Some(&qm), None, None, &fopts).x;
    let fd = (dot(&v, &xp) - dot(&v, &xm)) / (2.0 * eps);
    let an = dot(&out.vjp.grad_q, &dirs_q);
    assert!((fd - an).abs() < 1e-5 * (1.0 + fd.abs()), "q: {fd} vs {an}");
    // b
    let bp: Vec<f64> = solver
        .qp
        .b
        .iter()
        .zip(&dirs_b)
        .map(|(x, d)| x + eps * d)
        .collect();
    let bm: Vec<f64> = solver
        .qp
        .b
        .iter()
        .zip(&dirs_b)
        .map(|(x, d)| x - eps * d)
        .collect();
    let xp = solver.solve_with(None, Some(&bp), None, &fopts).x;
    let xm = solver.solve_with(None, Some(&bm), None, &fopts).x;
    let fd = (dot(&v, &xp) - dot(&v, &xm)) / (2.0 * eps);
    let an = dot(&out.vjp.grad_b, &dirs_b);
    assert!((fd - an).abs() < 1e-5 * (1.0 + fd.abs()), "b: {fd} vs {an}");
    // h
    let hp: Vec<f64> = solver
        .qp
        .h
        .iter()
        .zip(&dirs_h)
        .map(|(x, d)| x + eps * d)
        .collect();
    let hm: Vec<f64> = solver
        .qp
        .h
        .iter()
        .zip(&dirs_h)
        .map(|(x, d)| x - eps * d)
        .collect();
    let xp = solver.solve_with(None, None, Some(&hp), &fopts).x;
    let xm = solver.solve_with(None, None, Some(&hm), &fopts).x;
    let fd = (dot(&v, &xp) - dot(&v, &xm)) / (2.0 * eps);
    let an = dot(&out.vjp.grad_h, &dirs_h);
    assert!((fd - an).abs() < 1e-5 * (1.0 + fd.abs()), "h: {fd} vs {an}");
}

#[test]
fn sparse_adjoint_matches_full_jacobian_both_engines() {
    // Sherman–Morrison (sparsemax) and blocked-CG structures
    for (sq, label) in [
        (sparsemax_qp(24, 3), "sherman-morrison"),
        (sparse_qp(16, 7, 3, 0.3, 4), "cg"),
    ] {
        let solver = SparseAltDiff::new(sq, 1.0).unwrap();
        let mut rng = Pcg64::new(5);
        let v = rng.normal_vec(solver.qp.n());
        let out = solver.solve_vjp(
            None,
            None,
            None,
            &v,
            &rev(BackwardMode::Adjoint),
        );
        for param in [Param::Q, Param::B, Param::H] {
            let sol = solver.solve(&rev(BackwardMode::Forward(param)));
            let want = sol.vjp(&v);
            let got = out.vjp.grad(param);
            assert!(
                max_abs_diff(got, &want) < 1e-8,
                "{label}/{param:?} adjoint vs forward-mode"
            );
        }
    }
}

#[test]
fn batched_dense_adjoint_matches_sequential_and_forward_mode() {
    let dense = DenseAltDiff::new(dense_qp(12, 6, 3, 31), 1.0).unwrap();
    let batched = BatchedAltDiff::from_dense(&dense);
    let mut rng = Pcg64::new(6);
    // ragged batch: 3 elements, θ perturbed per element so iteration
    // counts differ (mixed convergence under per-element truncation)
    let qs: Vec<Vec<f64>> = (0..3)
        .map(|e| {
            dense
                .qp
                .q
                .iter()
                .map(|&x| x * (1.0 + 0.3 * e as f64) + 0.05 * rng.normal())
                .collect()
        })
        .collect();
    let vs: Vec<Vec<f64>> = (0..3).map(|_| rng.normal_vec(12)).collect();
    let qr: Vec<&[f64]> = qs.iter().map(|q| q.as_slice()).collect();
    let vr: Vec<&[f64]> = vs.iter().map(|x| x.as_slice()).collect();
    let out = batched.solve_batch_vjp(
        Some(&qr),
        None,
        None,
        &vr,
        &rev(BackwardMode::Adjoint),
    );
    assert!(out.forward.jacobians.is_none());
    let fwd = batched.solve_batch(
        Some(&qr),
        None,
        None,
        &rev(BackwardMode::Forward(Param::Q)),
    );
    for e in 0..3 {
        // vs the sequential adjoint
        let seq = dense.solve_vjp(
            Some(&qs[e]),
            None,
            None,
            &vs[e],
            &rev(BackwardMode::Adjoint),
        );
        assert!(
            max_abs_diff(&out.vjp.grads_q[e], &seq.vjp.grad_q) < 1e-8,
            "element {e}: batched vs sequential grad_q"
        );
        assert!(
            max_abs_diff(&out.vjp.grads_b[e], &seq.vjp.grad_b) < 1e-8,
            "element {e}: batched vs sequential grad_b"
        );
        assert!(
            max_abs_diff(&out.vjp.grads_h[e], &seq.vjp.grad_h) < 1e-8,
            "element {e}: batched vs sequential grad_h"
        );
        // vs full-Jacobian-then-gemv_t
        let want = fwd.vjp(e, &vs[e]);
        assert!(
            max_abs_diff(&out.vjp.grads_q[e], &want) < 1e-8,
            "element {e}: batched adjoint vs forward-mode"
        );
    }
}

#[test]
fn batched_sparse_adjoint_matches_sequential_both_engines() {
    for (sq, label) in [
        (sparsemax_qp(20, 7), "sherman-morrison"),
        (sparse_qp(14, 6, 3, 0.3, 8), "cg"),
    ] {
        let seq = SparseAltDiff::new(sq.clone(), 1.0).unwrap();
        let batched = BatchedSparseAltDiff::from_sparse(&seq);
        let n = sq.n();
        let mut rng = Pcg64::new(9);
        let qs: Vec<Vec<f64>> = (0..3)
            .map(|e| {
                sq.q.iter()
                    .map(|&x| {
                        x * (1.0 + 0.2 * e as f64) + 0.03 * rng.normal()
                    })
                    .collect()
            })
            .collect();
        let vs: Vec<Vec<f64>> =
            (0..3).map(|_| rng.normal_vec(n)).collect();
        let qr: Vec<&[f64]> = qs.iter().map(|q| q.as_slice()).collect();
        let vr: Vec<&[f64]> = vs.iter().map(|x| x.as_slice()).collect();
        let out = batched.solve_batch_vjp(
            Some(&qr),
            None,
            None,
            &vr,
            &rev(BackwardMode::Adjoint),
        );
        let fwd = batched.solve_batch(
            Some(&qr),
            None,
            None,
            &rev(BackwardMode::Forward(Param::Q)),
        );
        for e in 0..3 {
            let s = seq.solve_vjp(
                Some(&qs[e]),
                None,
                None,
                &vs[e],
                &rev(BackwardMode::Adjoint),
            );
            assert!(
                max_abs_diff(&out.vjp.grads_q[e], &s.vjp.grad_q) < 1e-8,
                "{label} element {e}: batched vs sequential grad_q"
            );
            assert!(
                max_abs_diff(&out.vjp.grads_b[e], &s.vjp.grad_b) < 1e-8,
                "{label} element {e}: batched vs sequential grad_b"
            );
            assert!(
                max_abs_diff(&out.vjp.grads_h[e], &s.vjp.grad_h) < 1e-8,
                "{label} element {e}: batched vs sequential grad_h"
            );
            let want = fwd.vjp(e, &vs[e]);
            assert!(
                max_abs_diff(&out.vjp.grads_q[e], &want) < 1e-8,
                "{label} element {e}: batched adjoint vs forward-mode"
            );
        }
    }
}

#[test]
fn fixed_k_adjoint_runs_exactly_k_and_stays_finite() {
    // serving contract: tol = 0 → forward AND adjoint run exactly k
    let dense = DenseAltDiff::new(dense_qp(10, 5, 2, 41), 1.0).unwrap();
    let batched = BatchedAltDiff::from_dense(&dense);
    let opts = Options {
        tol: 0.0,
        max_iter: 17,
        backward: BackwardMode::Adjoint,
        ..Default::default()
    };
    let v = vec![1.0; 10];
    let out = dense.solve_vjp(None, None, None, &v, &opts);
    assert_eq!(out.solution.iters, 17);
    assert_eq!(out.vjp.iters, 17);
    assert!(out.vjp.grad_q.iter().all(|g| g.is_finite()));
    let q2: Vec<f64> = dense.qp.q.iter().map(|&x| 0.5 * x).collect();
    let qr: Vec<&[f64]> = vec![&dense.qp.q, &q2];
    let vr: Vec<&[f64]> = vec![&v, &v];
    let ob = batched.solve_batch_vjp(Some(&qr), None, None, &vr, &opts);
    assert_eq!(ob.forward.iters, vec![17, 17]);
    assert_eq!(ob.vjp.iters, vec![17, 17]);
}

#[test]
fn adjoint_truncation_error_shrinks_with_tolerance() {
    // Thm 4.3 analogue for the transposed recursion: looser tolerance →
    // larger (but bounded) gradient error against the converged limit.
    let solver = DenseAltDiff::new(dense_qp(16, 8, 3, 51), 1.0).unwrap();
    let mut rng = Pcg64::new(12);
    let v = rng.normal_vec(16);
    let exact = solver
        .solve_vjp(None, None, None, &v, &rev(BackwardMode::Adjoint))
        .vjp;
    let mut errs = Vec::new();
    for tol in [1e-2, 1e-4, 1e-8] {
        let o = Options {
            tol,
            max_iter: 200_000,
            backward: BackwardMode::Adjoint,
            ..Default::default()
        };
        let g = solver.solve_vjp(None, None, None, &v, &o).vjp;
        errs.push(max_abs_diff(&g.grad_q, &exact.grad_q));
    }
    assert!(errs[0] >= errs[1] && errs[1] >= errs[2], "{errs:?}");
    assert!(errs[2] < 1e-6, "{errs:?}");
}
