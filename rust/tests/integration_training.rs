//! End-to-end training smoke tests (the §5.2 / §5.3 pipelines), small
//! enough for CI but exercising the full network + optimization layer +
//! optimizer loop.

use altdiff::nn::OptBackend;
use altdiff::train::{
    train_energy, train_mnist, EnergyBackend, EnergyConfig, MnistConfig,
};

#[test]
fn energy_pipeline_trains_and_truncation_is_cheap() {
    let tight = train_energy(&EnergyConfig {
        backend: EnergyBackend::AltDiff(1e-3),
        epochs: 4,
        days: 8,
        seed: 5,
        ..Default::default()
    });
    assert!(tight.losses.last().unwrap() < &tight.losses[0]);
    let loose = train_energy(&EnergyConfig {
        backend: EnergyBackend::AltDiff(1e-1),
        epochs: 4,
        days: 8,
        seed: 5,
        ..Default::default()
    });
    // truncation cuts layer iterations (the Fig. 2b mechanism)
    assert!(loose.mean_iters < tight.mean_iters);
    // and still trains
    assert!(loose.losses.last().unwrap() < &loose.losses[0]);
}

#[test]
fn energy_cvxpylayer_sim_backend_runs() {
    let rep = train_energy(&EnergyConfig {
        backend: EnergyBackend::CvxpyLayerSim,
        epochs: 2,
        days: 5,
        seed: 6,
        ..Default::default()
    });
    assert_eq!(rep.losses.len(), 2);
    assert!(rep.losses.iter().all(|l| l.is_finite()));
}

#[test]
fn mnist_pipeline_altdiff_vs_optnet_parity() {
    let base = MnistConfig {
        epochs: 2,
        train_size: 120,
        test_size: 60,
        layer_dim: 16,
        layer_eq: 4,
        layer_ineq: 4,
        noise: 0.3,
        seed: 2,
        ..Default::default()
    };
    let alt = train_mnist(&MnistConfig {
        backend: OptBackend::AltDiff,
        ..base.clone()
    });
    let opt = train_mnist(&MnistConfig {
        backend: OptBackend::OptNetKkt,
        ..base
    });
    let aa = *alt.test_accs.last().unwrap();
    let oa = *opt.test_accs.last().unwrap();
    assert!(aa > 0.3, "alt-diff acc {aa}");
    assert!(oa > 0.3, "optnet acc {oa}");
    // Table 6 parity claim: same network, comparable accuracy
    assert!((aa - oa).abs() < 0.25, "parity broken: {aa} vs {oa}");
}
