//! Cross-shard parity & stress suite for the sharded coordinator pool.
//!
//! The shard-pool scheduler (bounded per-shard queues, deadline-aware
//! batching, work stealing) must be invisible to the serving contract:
//! the same request trace answered by 1, 2, or 4 shards produces the
//! same numbers (to 1e-8), no reply is ever lost — not under shedding,
//! not under graceful drain — and the new per-shard counters reconcile
//! exactly with the global execution counters. Each test here pins one
//! of those claims; `ragged_load_*` additionally forces the scheduler
//! into its interesting regime (one hot shard, idle siblings) and
//! demands observable steals and partial flushes.

use altdiff::coordinator::{
    shard_for, Config, Coordinator, FailureKind, Priority, Reply,
    Request,
};
use altdiff::prob::dense_qp;
use altdiff::util::Pcg64;
use std::collections::BTreeMap;
use std::sync::atomic::Ordering::Relaxed;
use std::time::{Duration, Instant};

const TOLS: [f64; 3] = [1e-1, 1e-2, 1e-3];

/// Receive exactly `n` replies, panicking on duplicates or on a lost
/// reply (timeout) — the zero-lost-replies contract every stress
/// scenario asserts.
fn collect_replies(c: &Coordinator, n: usize) -> BTreeMap<u64, Reply> {
    let mut got = BTreeMap::new();
    while got.len() < n {
        let reply = c
            .recv_timeout(Duration::from_secs(120))
            .unwrap_or_else(|| {
                panic!("lost replies: {}/{} received", got.len(), n)
            });
        assert!(
            got.insert(reply.id(), reply).is_none(),
            "duplicate reply id"
        );
    }
    got
}

/// Identical two-layer registration (one Alt-Diff dense layer, one
/// ADMM-family layer) over `shards` coordinator shards.
fn two_family_pool(shards: usize) -> Coordinator {
    Coordinator::builder(Config {
        workers: 4,
        max_batch: 4,
        batch_timeout_us: 1_000,
        shards,
        artifacts: None,
        ..Default::default()
    })
    .register("d12", dense_qp(12, 6, 3, 9), 1.0)
    .unwrap()
    .register_admm("a10", dense_qp(10, 5, 2, 3), 1.0)
    .unwrap()
    .start()
}

/// Deterministic mixed trace: both layers, both request kinds, sessioned
/// and session-less, three tolerances. Returns submitted ids in order
/// (coordinators assign ids sequentially, so the same trace yields the
/// same id→request mapping on every pool).
fn submit_mixed_trace(c: &mut Coordinator, n: usize) -> Vec<u64> {
    let d12 = dense_qp(12, 6, 3, 9);
    let a10 = dense_qp(10, 5, 2, 3);
    let mut rng = Pcg64::new(42);
    let mut ids = Vec::with_capacity(n);
    for i in 0..n {
        let tol = TOLS[i % TOLS.len()];
        let admm = i % 3 == 2;
        let (layer, qp, dim) =
            if admm { ("a10", &a10, 10) } else { ("d12", &d12, 12) };
        let s = 1.0 + 0.05 * rng.normal();
        let q: Vec<f64> = qp.q.iter().map(|&v| v * s).collect();
        let grad = i % 4 == 1;
        let session = (i % 3 == 0).then_some((i % 5) as u64);
        let id = match (grad, session) {
            (false, None) => {
                c.submit(layer, q, qp.b.clone(), qp.h.clone(), tol)
            }
            (false, Some(sk)) => c.submit_session(
                layer,
                q,
                qp.b.clone(),
                qp.h.clone(),
                tol,
                sk,
            ),
            (true, None) => c.submit_grad(
                layer,
                q,
                qp.b.clone(),
                qp.h.clone(),
                vec![1.0; dim],
                tol,
            ),
            (true, Some(sk)) => c.submit_grad_session(
                layer,
                q,
                qp.b.clone(),
                qp.h.clone(),
                vec![1.0; dim],
                tol,
                sk,
            ),
        };
        ids.push(id);
    }
    ids
}

fn assert_vec_close(a: &[f64], b: &[f64], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(
            (x - y).abs() < 1e-8,
            "{what}[{i}]: {x} vs {y} (diff {:.2e})",
            (x - y).abs()
        );
    }
}

#[test]
fn shard_routing_is_deterministic_and_covers_all_shards() {
    // same (layer, session) → same shard, every time
    for s in [1usize, 2, 4, 7] {
        for session in 0..64u64 {
            let a = shard_for("qp16", session, s);
            assert_eq!(a, shard_for("qp16", session, s));
            assert!(a < s);
        }
    }
    // varying sessions reach every shard (no dead shard under FNV-1a)
    for s in [2usize, 4] {
        let hit: std::collections::BTreeSet<usize> =
            (0..256u64).map(|k| shard_for("qp16", k, s)).collect();
        assert_eq!(hit.len(), s, "{s}-shard routing left a shard cold");
    }
    // layer name participates in the hash
    assert!(
        (0..64u64)
            .any(|k| shard_for("a", k, 4) != shard_for("b", k, 4)),
        "layer name ignored by the routing hash"
    );
}

/// The tentpole acceptance criterion: the same mixed trace served by
/// 1, 2, and 4 shards is numerically identical per request (x, ∂x/∂b,
/// gradients, and the routed k) to 1e-8 — batch composition may differ
/// (and does), results may not. Every reply arrives exactly once.
#[test]
fn cross_shard_parity_zero_lost_replies() {
    const N: usize = 36;
    let run = |shards: usize| -> BTreeMap<u64, Reply> {
        let mut c = two_family_pool(shards);
        assert_eq!(c.shard_count(), shards);
        let ids = submit_mixed_trace(&mut c, N);
        let replies = collect_replies(&c, N);
        for id in &ids {
            assert!(replies.contains_key(id), "id {id} unanswered");
        }
        replies
    };
    let base = run(1);
    for shards in [2usize, 4] {
        let pool = run(shards);
        for (id, want) in &base {
            match (want, &pool[id]) {
                (Reply::Ok(a), Reply::Ok(b)) => {
                    assert_eq!(
                        a.k_used, b.k_used,
                        "id {id}: routed k diverged at {shards} shards"
                    );
                    assert_vec_close(&a.x, &b.x, "x");
                    assert_vec_close(&a.jx, &b.jx, "jx");
                }
                (Reply::Grad(a), Reply::Grad(b)) => {
                    assert_eq!(a.k_used, b.k_used, "id {id}: k diverged");
                    assert_vec_close(&a.x, &b.x, "grad x");
                    assert_vec_close(&a.grad_q, &b.grad_q, "grad_q");
                    assert_vec_close(&a.grad_b, &b.grad_b, "grad_b");
                    assert_vec_close(&a.grad_h, &b.grad_h, "grad_h");
                }
                (a, b) => panic!(
                    "id {id}: reply kind diverged across shard counts \
                     ({a:?} vs {b:?})"
                ),
            }
        }
    }
}

/// Deadline-aware batching property: a timeout-flushed *partial* batch
/// runs the same routed k and produces the same numbers as the same
/// requests served in full batches — the exact-k contract cannot see
/// the flush reason. The partial-flush counter proves the timeout path
/// actually fired.
#[test]
fn deadline_flush_preserves_exact_k_and_results() {
    let qp = dense_qp(12, 6, 3, 9);
    let thetas: Vec<Vec<f64>> = (0..3)
        .map(|i| {
            qp.q.iter().map(|&v| v * (1.0 + 0.02 * i as f64)).collect()
        })
        .collect();
    let run = |max_batch: usize, timeout_us: u64| {
        let mut c = Coordinator::builder(Config {
            workers: 1,
            max_batch,
            batch_timeout_us: timeout_us,
            artifacts: None,
            ..Default::default()
        })
        .register("d12", qp.clone(), 1.0)
        .unwrap()
        .start();
        for q in &thetas {
            c.submit("d12", q.clone(), qp.b.clone(), qp.h.clone(), 1e-3);
        }
        let replies = collect_replies(&c, thetas.len());
        let pflush: u64 = c
            .metrics
            .shards
            .iter()
            .map(|s| s.partial_flushes.load(Relaxed))
            .sum();
        (replies, pflush)
    };
    // 3 requests can never fill max_batch=8: only the 500µs deadline
    // can flush them. max_batch=3 with a generous deadline serves the
    // same θ in full (push-flushed) batches.
    let (partial, pflush) = run(8, 500);
    let (full, _) = run(3, 200_000);
    assert!(pflush >= 1, "no partial flush recorded at max_batch=8");
    for (id, reply) in &partial {
        let (Reply::Ok(p), Reply::Ok(f)) = (reply, &full[id]) else {
            panic!("expected solve replies");
        };
        assert!(
            p.batch_size < 8,
            "a 3-request trace cannot fill an 8-slot batch"
        );
        assert_eq!(
            p.k_used, f.k_used,
            "timeout flush changed the routed iteration count"
        );
        assert_vec_close(&p.x, &f.x, "x (partial vs full batch)");
        assert_vec_close(&p.jx, &f.jx, "jx (partial vs full batch)");
    }
}

/// Ragged load: every request hashes to shard 0 (hot), shard 1 idle.
/// Shard 1's workers must steal formed batches from shard 0, the lone
/// odd-tolerance straggler must flush by deadline, and the per-shard
/// elems counters must reconcile exactly with the native execution
/// counters — stealing moves work, never double-counts it.
#[test]
fn ragged_load_steals_partial_flushes_and_sum_consistency() {
    const SHARDS: usize = 2;
    let qp = dense_qp(64, 32, 12, 2);
    let mut c = Coordinator::builder(Config {
        workers: 4,
        max_batch: 4,
        batch_timeout_us: 1_000,
        shards: SHARDS,
        artifacts: None,
        ..Default::default()
    })
    .register("d64", qp.clone(), 1.0)
    .unwrap()
    .start();
    // session keys that all route to shard 0
    let hot: Vec<u64> = (0..1024u64)
        .filter(|&s| shard_for("d64", s, SHARDS) == 0)
        .take(8)
        .collect();
    assert!(!hot.is_empty());
    let steals = |c: &Coordinator| -> u64 {
        c.metrics.shards.iter().map(|s| s.steals.load(Relaxed)).sum()
    };
    // waves until a steal is observed (virtually always the first wave:
    // shard 1's workers poll for steal targets every 200µs while shard
    // 0's queue holds several n=64 batches)
    for wave in 0..6 {
        if wave > 0 && steals(&c) >= 1 {
            break;
        }
        for i in 0..32usize {
            let s = 1.0 + 0.01 * i as f64;
            let q: Vec<f64> = qp.q.iter().map(|&v| v * s).collect();
            let session = hot[i % hot.len()];
            if i % 8 == 7 {
                c.submit_grad_session(
                    "d64",
                    q,
                    qp.b.clone(),
                    qp.h.clone(),
                    vec![1.0; 64],
                    1e-3,
                    session,
                );
            } else {
                c.submit_session(
                    "d64",
                    q,
                    qp.b.clone(),
                    qp.h.clone(),
                    1e-3,
                    session,
                );
            }
        }
        // lone straggler at a different tolerance: its (layer, k) group
        // can never reach max_batch, so only the deadline can flush it
        c.submit_session(
            "d64",
            qp.q.clone(),
            qp.b.clone(),
            qp.h.clone(),
            1e-1,
            hot[0],
        );
        let replies = collect_replies(&c, 33);
        assert!(replies.values().all(|r| r.failure_kind().is_none()));
        for r in replies.values() {
            if let Reply::Ok(ok) = r {
                assert!(ok.x.iter().all(|v| v.is_finite()));
            }
        }
    }
    let m = &c.metrics;
    assert!(
        steals(&c) >= 1,
        "no work steal observed under a 100% hot-shard load"
    );
    let pflush: u64 = m
        .shards
        .iter()
        .map(|s| s.partial_flushes.load(Relaxed))
        .sum();
    assert!(pflush >= 1, "straggler never flushed by deadline");
    // the idle shard formed nothing; everything it served was stolen
    assert_eq!(m.shards[1].batches.load(Relaxed), 0);
    assert_eq!(m.shards[1].elems.load(Relaxed), 0);
    // sum consistency: every request flowed through exactly one formed
    // batch on exactly one shard, and every formed batch was executed
    // natively (no artifacts loaded) — stolen batches count for the
    // shard that formed them
    let shard_elems: u64 =
        m.shards.iter().map(|s| s.elems.load(Relaxed)).sum();
    let executed =
        m.native_elems.load(Relaxed) + m.adjoint_elems.load(Relaxed);
    assert_eq!(shard_elems, executed, "stolen work double-counted");
    let shard_batches: u64 =
        m.shards.iter().map(|s| s.batches.load(Relaxed)).sum();
    assert_eq!(shard_batches, m.batches.load(Relaxed));
    for s in &m.shards {
        assert!(s.steals.load(Relaxed) <= s.batches.load(Relaxed));
        assert!(s.stolen_elems.load(Relaxed) <= s.elems.load(Relaxed));
        assert!(
            s.partial_flushes.load(Relaxed) <= s.batches.load(Relaxed)
        );
    }
}

/// Shedding reconciliation: a tiny shard queue plus slow heavy batches
/// forces coordinator-level shedding. Every submitted request is
/// answered exactly once — `Overloaded` for the shed ones — and the
/// client-side tally matches the server's `shed` counter exactly.
/// After `shutdown`, late submits are counted by `drained` and produce
/// no reply (the reply channel is already disconnected).
#[test]
fn shed_replies_reconcile_with_metrics_and_drain_accounting() {
    const SHARDS: usize = 2;
    let qp = dense_qp(64, 32, 12, 2);
    let mut c = Coordinator::builder(Config {
        workers: 2,
        max_batch: 1,
        batch_timeout_us: 1_000,
        shards: SHARDS,
        shard_queue: 2,
        artifacts: None,
        ..Default::default()
    })
    .register("d64", qp.clone(), 1.0)
    .unwrap()
    .start();
    c.wait_ready(Duration::from_secs(60));
    let hot = (0..1024u64)
        .find(|&s| shard_for("d64", s, SHARDS) == 0)
        .unwrap();
    const N: usize = 64;
    for i in 0..N {
        let s = 1.0 + 0.01 * i as f64;
        c.submit_session(
            "d64",
            qp.q.iter().map(|&v| v * s).collect(),
            qp.b.clone(),
            qp.h.clone(),
            1e-3,
            hot,
        );
    }
    let replies = collect_replies(&c, N);
    let mut served = 0u64;
    let mut shed = 0u64;
    for r in replies.values() {
        match r.failure_kind() {
            None => served += 1,
            Some(FailureKind::Overloaded) => shed += 1,
            Some(k) => panic!("unexpected failure kind {k:?}"),
        }
    }
    assert_eq!(served + shed, N as u64, "request lost under shedding");
    assert!(
        shed >= 1,
        "64 rapid heavy submits against a 2-deep shard queue must shed"
    );
    assert_eq!(
        c.metrics.shed.load(Relaxed),
        shed,
        "server shed counter disagrees with client Overloaded tally"
    );
    assert_eq!(c.metrics.responses.load(Relaxed), served);
    // graceful drain: late submits are refused, counted, and get no
    // reply — the channel disconnected when the last buffered reply
    // (already consumed above) was taken
    c.shutdown();
    for _ in 0..3 {
        c.submit(
            "d64",
            qp.q.clone(),
            qp.b.clone(),
            qp.h.clone(),
            1e-3,
        );
    }
    assert_eq!(c.metrics.drained.load(Relaxed), 3);
    assert!(c.try_recv().is_none());
    assert!(c.recv_timeout(Duration::from_millis(50)).is_none());
}

/// Warm-start sessions survive sharding: a session's repeated gradient
/// solves hash to one shard, hit the shared cache after the first
/// solve, and the hit/miss tally covers every adjoint element exactly
/// once.
#[test]
fn warm_sessions_survive_sharding() {
    let qp = dense_qp(12, 6, 3, 9);
    let mut c = Coordinator::builder(Config {
        workers: 4,
        max_batch: 4,
        batch_timeout_us: 500,
        shards: 2,
        warm_capacity: 32,
        artifacts: None,
        ..Default::default()
    })
    .register("d12", qp.clone(), 1.0)
    .unwrap()
    .start();
    const ROUNDS: usize = 5;
    for _ in 0..ROUNDS {
        // sequential (wait for each reply): every solve after the first
        // finds the session's written-back iterate
        c.submit_grad_session(
            "d12",
            qp.q.clone(),
            qp.b.clone(),
            qp.h.clone(),
            vec![1.0; 12],
            1e-3,
            7,
        );
        match c.recv_timeout(Duration::from_secs(60)).expect("reply") {
            Reply::Grad(g) => {
                assert!(g.grad_q.iter().all(|v| v.is_finite()))
            }
            other => panic!("expected grad reply, got {other:?}"),
        }
    }
    let hits = c.metrics.warm_hits.load(Relaxed);
    let misses = c.metrics.warm_misses.load(Relaxed);
    assert!(hits >= 1, "repeat session solves never hit the warm cache");
    assert_eq!(
        hits + misses,
        c.metrics.adjoint_elems.load(Relaxed),
        "every adjoint element does exactly one cache lookup"
    );
}

/// Randomized mixed trace over 4 shards: the per-shard counters are
/// monotone while the pool runs, and at quiescence they reconcile with
/// the global execution counters (elems, batches, occupancy
/// histogram).
#[test]
fn randomized_trace_counters_monotone_and_reconciled() {
    const N: usize = 60;
    let mut c = two_family_pool(4);
    let mut rng = Pcg64::new(7);
    // interleave submission with a mid-flight snapshot
    let d12 = dense_qp(12, 6, 3, 9);
    for i in 0..N {
        let s = 1.0 + 0.05 * rng.normal();
        let q: Vec<f64> = d12.q.iter().map(|&v| v * s).collect();
        let tol = TOLS[rng.below(TOLS.len())];
        if rng.uniform() < 0.3 {
            c.submit_grad(
                "d12",
                q,
                d12.b.clone(),
                d12.h.clone(),
                vec![1.0; 12],
                tol,
            );
        } else if rng.uniform() < 0.5 {
            c.submit_session(
                "d12",
                q,
                d12.b.clone(),
                d12.h.clone(),
                tol,
                (i % 9) as u64,
            );
        } else {
            c.submit("d12", q, d12.b.clone(), d12.h.clone(), tol);
        }
    }
    let snapshot = |c: &Coordinator| -> Vec<u64> {
        let m = &c.metrics;
        let mut v = vec![
            m.requests.load(Relaxed),
            m.responses.load(Relaxed),
            m.batches.load(Relaxed),
            m.native_elems.load(Relaxed),
            m.adjoint_elems.load(Relaxed),
        ];
        for s in &m.shards {
            v.push(s.batches.load(Relaxed));
            v.push(s.elems.load(Relaxed));
            v.push(s.partial_flushes.load(Relaxed));
            v.push(s.steals.load(Relaxed));
            v.push(s.stolen_elems.load(Relaxed));
        }
        v
    };
    // take a snapshot after roughly half the replies, then drain
    let mut got = 0usize;
    let mut mid: Option<Vec<u64>> = None;
    while got < N {
        let r = c
            .recv_timeout(Duration::from_secs(120))
            .expect("lost reply in randomized trace");
        assert!(r.failure_kind().is_none());
        got += 1;
        if got == N / 2 {
            mid = Some(snapshot(&c));
        }
    }
    let fin = snapshot(&c);
    for (i, (a, b)) in mid.unwrap().iter().zip(&fin).enumerate() {
        assert!(a <= b, "counter {i} went backwards ({a} → {b})");
    }
    let m = &c.metrics;
    let shard_elems: u64 =
        m.shards.iter().map(|s| s.elems.load(Relaxed)).sum();
    assert_eq!(
        shard_elems,
        m.native_elems.load(Relaxed) + m.adjoint_elems.load(Relaxed)
    );
    let shard_batches: u64 =
        m.shards.iter().map(|s| s.batches.load(Relaxed)).sum();
    assert_eq!(shard_batches, m.batches.load(Relaxed));
    for s in &m.shards {
        let hist: u64 =
            s.occ_hist.iter().map(|b| b.load(Relaxed)).sum();
        assert_eq!(
            hist,
            s.batches.load(Relaxed),
            "occupancy histogram must count every formed batch once"
        );
        assert!(s.stolen_elems.load(Relaxed) <= s.elems.load(Relaxed));
    }
    assert_eq!(m.responses.load(Relaxed), N as u64);
}

/// Build a solve request at an explicit priority class (the typed
/// submit helpers all send Normal; the traffic-plane tests need the
/// full spread).
fn prio_request(
    qp: &altdiff::prob::Qp,
    scale: f64,
    tol: f64,
    priority: Priority,
    deadline_us: Option<u32>,
) -> Request {
    Request {
        id: 0,
        layer: "d64".to_string(),
        q: qp.q.iter().map(|&v| v * scale).collect(),
        b: qp.b.clone(),
        h: qp.h.clone(),
        tol,
        grad_v: None,
        session: None,
        priority,
        deadline_us,
        submitted: Instant::now(),
        stamps: altdiff::obs::StageStamps::off(),
        sampled: false,
        echo_stages: false,
    }
}

/// Mixed-priority ragged wave against a saturated `ShardQueue`:
/// equal arrival pressure per class (strict High/Normal/Low cycling)
/// must shed in priority order — Low forfeits its queue budget first,
/// High last — while the per-priority shed counters reconcile exactly
/// with both the client-side tally and the global shed/served totals.
/// Zero lost, zero duplicated replies throughout.
#[test]
fn mixed_priority_wave_sheds_low_before_high_and_reconciles() {
    let qp = dense_qp(64, 32, 12, 2);
    let mut c = Coordinator::builder(Config {
        workers: 1,
        max_batch: 1,
        batch_timeout_us: 1_000,
        shards: 1,
        // class budgets at cap 16: High 16, Normal 14, Low 12 — wide
        // enough that the bands between budgets are actually visited
        // while the wave piles in
        shard_queue: 16,
        artifacts: None,
        ..Default::default()
    })
    .register("d64", qp.clone(), 1.0)
    .unwrap()
    .start();
    c.wait_ready(Duration::from_secs(60));
    const N: usize = 90;
    // id i (1-based) carries class ALL[(i-1) % 3]: the trace is the
    // class oracle, so every reply can be attributed exactly
    let mut ids = Vec::with_capacity(N);
    for i in 0..N {
        let prio = Priority::ALL[i % 3];
        let req =
            prio_request(&qp, 1.0 + 0.01 * i as f64, 1e-3, prio, None);
        ids.push(c.submit_request(req));
    }
    let replies = collect_replies(&c, N);
    let mut served = [0u64; 3];
    let mut shed = [0u64; 3];
    for (pos, id) in ids.iter().enumerate() {
        let class = Priority::ALL[pos % 3].idx();
        match replies[id].failure_kind() {
            None => served[class] += 1,
            Some(FailureKind::Overloaded) => shed[class] += 1,
            Some(k) => panic!("unexpected failure kind {k:?}"),
        }
    }
    let (sh, sn, sl) = (shed[Priority::High.idx()],
        shed[Priority::Normal.idx()], shed[Priority::Low.idx()]);
    assert!(
        sl >= sn && sn >= sh,
        "shed order violated: low {sl} normal {sn} high {sh}"
    );
    assert!(
        sl > sh,
        "equal pressure must shed strictly more Low than High \
         (low {sl} vs high {sh})"
    );
    let m = &c.metrics;
    for p in Priority::ALL {
        assert_eq!(
            m.shed_by_class[p.idx()].load(Relaxed),
            shed[p.idx()],
            "server {} shed counter disagrees with client tally",
            p.label()
        );
        assert_eq!(
            m.served_by_class[p.idx()].load(Relaxed),
            served[p.idx()],
            "server {} served counter disagrees with client tally",
            p.label()
        );
    }
    // Σ per-class == the global totals, and nothing was lost
    let class_shed: u64 = shed.iter().sum();
    let class_served: u64 = served.iter().sum();
    assert_eq!(m.shed.load(Relaxed), class_shed);
    assert_eq!(m.responses.load(Relaxed), class_served);
    assert_eq!(class_shed + class_served, N as u64);
    // SLO accounting covers exactly the served requests
    let slo: u64 = (0..3)
        .map(|i| {
            m.slo_ok_by_class[i].load(Relaxed)
                + m.slo_miss_by_class[i].load(Relaxed)
        })
        .sum();
    assert_eq!(slo, class_served, "every served reply gets an SLO verdict");
}

/// Deadline shedding at the coordinator: requests whose budget died in
/// the shard queue (or behind a busy worker) are answered
/// `DeadlineExceeded` and **never consume a solve** — the execution
/// counters move only for the live request. This is the truncation
/// theorem read as scheduling policy: work whose answer can no longer
/// be useful is dropped before it costs anything.
#[test]
fn expired_requests_never_reach_an_engine() {
    let qp = dense_qp(64, 32, 12, 2);
    let mut c = Coordinator::builder(Config {
        workers: 1,
        max_batch: 1,
        batch_timeout_us: 500,
        shards: 1,
        shard_queue: 64, // roomy: only deadlines shed here
        artifacts: None,
        ..Default::default()
    })
    .register("d64", qp.clone(), 1.0)
    .unwrap()
    .start();
    c.wait_ready(Duration::from_secs(60));
    // one live request occupies the single worker for milliseconds…
    let live = c.submit_request(prio_request(&qp, 1.0, 1e-3, Priority::Normal, None));
    // …so these 1µs budgets are long dead by the time the router or
    // the worker looks at them, whichever checkpoint fires first
    const DOOMED: usize = 10;
    let mut doomed_ids = Vec::new();
    for i in 0..DOOMED {
        doomed_ids.push(c.submit_request(prio_request(
            &qp,
            1.0 + 0.01 * i as f64,
            1e-3,
            Priority::ALL[i % 3],
            Some(1),
        )));
    }
    let replies = collect_replies(&c, DOOMED + 1);
    match &replies[&live] {
        Reply::Ok(ok) => assert!(ok.x.iter().all(|v| v.is_finite())),
        other => panic!("live request failed: {other:?}"),
    }
    for id in &doomed_ids {
        assert_eq!(
            replies[id].failure_kind(),
            Some(FailureKind::DeadlineExceeded),
            "id {id} outlived a 1µs budget"
        );
    }
    let m = &c.metrics;
    assert_eq!(m.deadline_shed.load(Relaxed), DOOMED as u64);
    let by_class: u64 = (0..3)
        .map(|i| m.deadline_by_class[i].load(Relaxed))
        .sum();
    assert_eq!(by_class, DOOMED as u64);
    // the only executed work is the live solve: n=64 elements once.
    // (Do NOT assert shard elems reconciliation here — a batch shed at
    // the pre-execution checkpoint was formed but never run.)
    assert_eq!(
        m.native_elems.load(Relaxed) + m.adjoint_elems.load(Relaxed),
        1,
        "an expired request consumed a solve"
    );
    assert_eq!(m.responses.load(Relaxed), 1);
}
