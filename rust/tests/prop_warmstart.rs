//! Warm-start subsystem properties: warm-started solves converge to the
//! cold fixed point (1e-8 parity on all four engines, forward and
//! adjoint), mixed warm/cold batches match sequential solves, the cache
//! honors hit/miss/staleness/LRU semantics end to end through
//! `nn::OptLayer`, and a wire round trip with a session key observes
//! server-side warm hits.

#[path = "common/conformance.rs"]
mod conformance;

use altdiff::altdiff::{
    BackwardMode, DenseAltDiff, Options, Param, SparseAltDiff,
};
use altdiff::batch::{BatchedAltDiff, BatchedSparseAltDiff};
use altdiff::coordinator::{Config, Coordinator, FailureKind, Reply};
use altdiff::net::{Client, NetConfig, NetServer};
use altdiff::nn::{OptBackend, OptLayer};
use altdiff::prob::{dense_qp, sparse_qp, sparsemax_qp};
use altdiff::warm::WarmStart;
use conformance::{assert_close, tight};
use std::time::Duration;

#[test]
fn warm_equals_cold_dense_sequential() {
    let solver = DenseAltDiff::new(dense_qp(16, 8, 3, 41), 1.0).unwrap();
    let opts = tight();
    let cold = solver.solve(&opts);
    // warm from a *nearby* θ's solution: same fixed point, fewer iters
    let q2: Vec<f64> =
        solver.qp.q.iter().map(|&v| 1.05 * v).collect();
    let near = solver.solve_with(Some(&q2), None, None, &opts);
    let warm = solver.solve_from(
        None,
        None,
        None,
        Some(&WarmStart::of(&near)),
        &opts,
    );
    assert_close(&warm.x, &cold.x, 1e-8, "x");
    assert_close(&warm.lam, &cold.lam, 1e-8, "lam");
    assert!(
        warm.iters < cold.iters,
        "warm {} vs cold {} iterations",
        warm.iters,
        cold.iters
    );
    // warm from the converged solution itself: near-instant
    let rewarm = solver.solve_from(
        None,
        None,
        None,
        Some(&WarmStart::of(&cold)),
        &opts,
    );
    assert_close(&rewarm.x, &cold.x, 1e-8, "rewarm x");
    assert!(rewarm.iters <= 2, "rewarm took {} iters", rewarm.iters);
}

#[test]
fn warm_equals_cold_sparse_sequential_both_engines() {
    for (sq, label) in [
        (sparsemax_qp(30, 5), "sherman-morrison"),
        (sparse_qp(20, 9, 4, 0.3, 6), "cg"),
    ] {
        let solver = SparseAltDiff::new(sq, 1.0).unwrap();
        let opts = tight();
        let cold = solver.solve(&opts);
        let q2: Vec<f64> =
            solver.qp.q.iter().map(|&v| 0.95 * v).collect();
        let near = solver.solve_with(Some(&q2), None, None, &opts);
        let warm = solver.solve_from(
            None,
            None,
            None,
            Some(&WarmStart::of(&near)),
            &opts,
        );
        assert_close(&warm.x, &cold.x, 1e-8, label);
        assert!(
            warm.iters < cold.iters,
            "{label}: warm {} vs cold {}",
            warm.iters,
            cold.iters
        );
    }
}

#[test]
fn warm_vjp_parity_dense_and_sparse() {
    let opts = Options { backward: BackwardMode::Adjoint, ..tight() };
    // dense
    let d = DenseAltDiff::new(dense_qp(12, 6, 3, 42), 1.0).unwrap();
    let sol = d.solve_with(None, None, None, &tight());
    let v: Vec<f64> = (0..12).map(|i| 1.0 - 0.15 * i as f64).collect();
    let cold = d.vjp(&sol.s, &v, &opts);
    // seed from a backward at a perturbed v
    let v2: Vec<f64> = v.iter().map(|&x| 1.1 * x + 0.05).collect();
    let (_, seed) = d.vjp_from(&sol.s, &v2, None, &opts);
    let (warm, _) = d.vjp_from(&sol.s, &v, Some(&seed), &opts);
    assert_close(&warm.grad_q, &cold.grad_q, 1e-8, "dense grad_q");
    assert_close(&warm.grad_b, &cold.grad_b, 1e-8, "dense grad_b");
    assert_close(&warm.grad_h, &cold.grad_h, 1e-8, "dense grad_h");
    // resuming from the converged state is near-instant
    let (_, conv) = d.vjp_from(&sol.s, &v, None, &opts);
    let (re, _) = d.vjp_from(&sol.s, &v, Some(&conv), &opts);
    assert!(re.iters < cold.iters, "{} vs {}", re.iters, cold.iters);
    // sparse (both x-update engines)
    for sq in [sparsemax_qp(24, 7), sparse_qp(14, 6, 3, 0.3, 8)] {
        let s = SparseAltDiff::new(sq, 1.0).unwrap();
        let sol = s.solve_with(None, None, None, &tight());
        let n = sol.x.len();
        let v: Vec<f64> =
            (0..n).map(|i| 0.5 - 0.07 * i as f64).collect();
        let cold = s.vjp(&sol.s, &v, &opts);
        let v2: Vec<f64> = v.iter().map(|&x| 0.9 * x - 0.02).collect();
        let (_, seed) = s.vjp_from(&sol.s, &v2, None, &opts);
        let (warm, _) = s.vjp_from(&sol.s, &v, Some(&seed), &opts);
        assert_close(&warm.grad_q, &cold.grad_q, 1e-8, "sparse grad_q");
        assert_close(&warm.grad_h, &cold.grad_h, 1e-8, "sparse grad_h");
    }
}

/// Ragged mixed warm/cold batches: per-element parity against cold
/// sequential solves at 1e-8, with warm elements finishing first.
#[test]
fn mixed_warm_cold_batches_dense() {
    let dense = DenseAltDiff::new(dense_qp(14, 7, 3, 43), 1.0).unwrap();
    let batched = BatchedAltDiff::from_dense(&dense);
    let opts = tight();
    for bsz in [2usize, 5] {
        let qs: Vec<Vec<f64>> = (0..bsz)
            .map(|e| {
                dense
                    .qp
                    .q
                    .iter()
                    .map(|&v| v * (1.0 + 0.07 * e as f64))
                    .collect()
            })
            .collect();
        let qrefs: Vec<&[f64]> =
            qs.iter().map(|q| q.as_slice()).collect();
        // warm every even element from its own converged solution
        let warms: Vec<Option<WarmStart>> = (0..bsz)
            .map(|e| {
                (e % 2 == 0).then(|| {
                    WarmStart::of(&dense.solve_with(
                        Some(&qs[e]),
                        None,
                        None,
                        &opts,
                    ))
                })
            })
            .collect();
        let sol = batched.solve_batch_from(
            Some(&qrefs),
            None,
            None,
            Some(&warms),
            &opts,
        );
        for e in 0..bsz {
            let seq =
                dense.solve_with(Some(&qs[e]), None, None, &opts);
            assert_close(&sol.xs[e], &seq.x, 1e-8, "x");
            assert_close(&sol.nus[e], &seq.nu, 1e-8, "nu");
            if e % 2 == 0 {
                assert!(
                    sol.iters[e] < seq.iters,
                    "warm element {e}: {} vs cold {}",
                    sol.iters[e],
                    seq.iters
                );
            }
        }
    }
}

#[test]
fn mixed_warm_cold_batches_sparse_both_engines() {
    for (sq, label) in [
        (sparsemax_qp(20, 9), "sherman-morrison"),
        (sparse_qp(16, 7, 3, 0.3, 10), "cg"),
    ] {
        let seq = SparseAltDiff::new(sq, 1.0).unwrap();
        let batched = BatchedSparseAltDiff::from_sparse(&seq);
        let opts = tight();
        let bsz = 3usize;
        let qs: Vec<Vec<f64>> = (0..bsz)
            .map(|e| {
                seq.qp
                    .q
                    .iter()
                    .map(|&v| v * (1.0 + 0.1 * e as f64))
                    .collect()
            })
            .collect();
        let qrefs: Vec<&[f64]> =
            qs.iter().map(|q| q.as_slice()).collect();
        let warms: Vec<Option<WarmStart>> = (0..bsz)
            .map(|e| {
                (e != 1).then(|| {
                    WarmStart::of(&seq.solve_with(
                        Some(&qs[e]),
                        None,
                        None,
                        &opts,
                    ))
                })
            })
            .collect();
        let sol = batched
            .try_solve_batch_from(
                Some(&qrefs),
                None,
                None,
                Some(&warms),
                &opts,
            )
            .unwrap();
        for e in 0..bsz {
            let direct =
                seq.solve_with(Some(&qs[e]), None, None, &opts);
            assert_close(&sol.xs[e], &direct.x, 1e-8, label);
            if e != 1 {
                assert!(
                    sol.iters[e] < direct.iters,
                    "{label} warm element {e}: {} vs {}",
                    sol.iters[e],
                    direct.iters
                );
            }
        }
    }
}

#[test]
fn batched_adjoint_seeds_round_trip_both_engines() {
    let opts = Options { backward: BackwardMode::Adjoint, ..tight() };
    // dense batched
    let dense = DenseAltDiff::new(dense_qp(10, 5, 2, 44), 1.0).unwrap();
    let batched = BatchedAltDiff::from_dense(&dense);
    let fwd = batched.solve_batch(None, None, None, &tight());
    let slacks = fwd.slack_refs();
    let slacks2: Vec<&[f64]> = vec![slacks[0], slacks[0]];
    let v0: Vec<f64> = (0..10).map(|i| 1.0 - 0.2 * i as f64).collect();
    let v1: Vec<f64> = v0.iter().map(|&x| -0.5 * x).collect();
    let vs: Vec<&[f64]> = vec![&v0, &v1];
    let cold = batched.batch_vjp(&slacks2, &vs, &opts);
    let (_, seeds) = batched.batch_vjp_from(&slacks2, &vs, None, &opts);
    // warm only element 0; element 1 cold — parity for both
    let warms: Vec<_> =
        vec![Some(seeds[0].clone()), None];
    let (warm, _) =
        batched.batch_vjp_from(&slacks2, &vs, Some(&warms), &opts);
    for e in 0..2 {
        assert_close(
            &warm.grads_q[e],
            &cold.grads_q[e],
            1e-8,
            "dense grads_q",
        );
        assert_close(
            &warm.grads_h[e],
            &cold.grads_h[e],
            1e-8,
            "dense grads_h",
        );
    }
    assert!(warm.iters[0] < cold.iters[0], "seeded element is faster");
    // sparse batched (Sherman–Morrison structure)
    let ssolver = SparseAltDiff::new(sparsemax_qp(18, 11), 1.0).unwrap();
    let sbatched = BatchedSparseAltDiff::from_sparse(&ssolver);
    let sfwd = sbatched.solve_batch(None, None, None, &tight());
    let sslacks = sfwd.slack_refs();
    let sv: Vec<f64> = (0..18).map(|i| 0.3 * (i as f64).cos()).collect();
    let svs: Vec<&[f64]> = vec![&sv];
    let scold = sbatched.batch_vjp(&sslacks, &svs, &opts);
    let (_, sseeds) = sbatched
        .try_batch_vjp_from(&sslacks, &svs, None, &opts)
        .unwrap();
    let swarms: Vec<_> = vec![Some(sseeds[0].clone())];
    let (swarm, _) = sbatched
        .try_batch_vjp_from(&sslacks, &svs, Some(&swarms), &opts)
        .unwrap();
    assert_close(
        &swarm.grads_q[0],
        &scold.grads_q[0],
        1e-8,
        "sparse grads_q",
    );
    assert!(swarm.iters[0] <= scold.iters[0]);
}

#[test]
#[should_panic(expected = "forward-mode Jacobians require tol = 0")]
fn warm_forward_mode_with_truncation_is_rejected() {
    let solver = DenseAltDiff::new(dense_qp(8, 4, 2, 45), 1.0).unwrap();
    let sol = solver.solve(&Options::forward_only());
    let opts = Options {
        tol: 1e-3,
        backward: BackwardMode::Forward(Param::B),
        ..Default::default()
    };
    let _ = solver.solve_from(
        None,
        None,
        None,
        Some(&WarmStart::of(&sol)),
        &opts,
    );
}

/// Warm + forward-mode at tol = 0 (the serving contract) is legal and
/// at least as accurate as the cold fixed-k Jacobian.
#[test]
fn warm_fixed_k_forward_mode_jacobian_stays_valid() {
    let solver = DenseAltDiff::new(dense_qp(10, 5, 2, 46), 1.0).unwrap();
    let exact = solver.solve(&Options {
        tol: 1e-12,
        max_iter: 60_000,
        backward: BackwardMode::Forward(Param::B),
        ..Default::default()
    });
    let k_opts = Options {
        tol: 0.0,
        max_iter: 15,
        backward: BackwardMode::Forward(Param::B),
        ..Default::default()
    };
    let cold = solver.solve(&k_opts);
    let near = solver.solve(&Options::forward_only());
    let warm = solver.solve_from(
        None,
        None,
        None,
        Some(&WarmStart::of(&near)),
        &k_opts,
    );
    let je = exact.jacobian.as_ref().unwrap();
    let jc = cold.jacobian.as_ref().unwrap();
    let jw = warm.jacobian.as_ref().unwrap();
    let cold_err = jc.sub(je).fro();
    let warm_err = jw.sub(je).fro();
    // the warm run's slack gates are correct from iteration 1, so its
    // fixed-k Jacobian is comparable-or-better — never garbage (the
    // failure mode the tol=0 restriction exists to prevent)
    assert!(
        warm_err <= 2.0 * cold_err + 1e-10,
        "warm fixed-k Jacobian degraded: {warm_err} vs cold {cold_err}"
    );
}

/// `nn::OptLayer` keyed warm starts: parity with the cold layer and
/// observable hits on revisits (epoch-over-epoch reuse).
#[test]
fn optlayer_keyed_warm_starts_hit_and_agree() {
    let mk = || {
        OptLayer::new(dense_qp(10, 5, 2, 47), 1.0, OptBackend::AltDiff, 1e-9)
            .unwrap()
    };
    let mut cold = mk();
    let mut warm = mk();
    warm.enable_warm_start(16, 1.0);
    let qs: Vec<Vec<f64>> = (0..3)
        .map(|s| {
            (0..10).map(|i| 0.1 * i as f64 - 0.2 + 0.15 * s as f64).collect()
        })
        .collect();
    let keys: Vec<u64> = vec![7, 8, 9];
    let gxs: Vec<Vec<f64>> =
        (0..3).map(|_| vec![1.0; 10]).collect();
    // epoch 1: all cold (misses), epoch 2: all warm (hits)
    let x1 = warm.forward_batch_keyed(&qs, &keys);
    let g1 = warm.backward_batch(&gxs);
    assert_eq!(warm.warm_stats(), Some((0, 3)));
    let e1_iters: usize = warm.last_batch_iters.iter().sum();
    let x2 = warm.forward_batch_keyed(&qs, &keys);
    let g2 = warm.backward_batch(&gxs);
    assert_eq!(warm.warm_stats(), Some((3, 3)));
    let e2_iters: usize = warm.last_batch_iters.iter().sum();
    assert!(
        e2_iters < e1_iters,
        "revisit did not save iterations: {e2_iters} vs {e1_iters}"
    );
    // parity against the cold layer
    let xc = cold.forward_batch(&qs);
    let gc = cold.backward_batch(&gxs);
    for e in 0..3 {
        assert_close(&x1[e], &xc[e], 1e-6, "epoch-1 x");
        assert_close(&x2[e], &xc[e], 1e-6, "epoch-2 x");
        assert_close(&g1[e], &gc[e], 1e-6, "epoch-1 grad");
        assert_close(&g2[e], &gc[e], 1e-6, "epoch-2 grad");
    }
}

/// Coordinator warm cache: a repeated in-process solve under one
/// session key hits; the warm grad path saves iterations under the
/// routed k.
#[test]
fn coordinator_session_requests_hit_the_warm_cache() {
    let qp = dense_qp(12, 6, 3, 9);
    let mut c = Coordinator::builder(Config {
        workers: 1,
        max_batch: 1,
        batch_timeout_us: 1_000,
        artifacts: None,
        warm_capacity: 64,
        warm_radius: 0.5,
        ..Default::default()
    })
    .register("layer0", qp.clone(), 1.0)
    .unwrap()
    .start();
    let v = vec![1.0; 12];
    for round in 0..2 {
        c.submit_session(
            "layer0",
            qp.q.clone(),
            qp.b.clone(),
            qp.h.clone(),
            1e-3,
            500,
        );
        match c.recv_timeout(Duration::from_secs(30)).expect("reply") {
            Reply::Ok(r) => assert_eq!(r.x.len(), 12),
            other => panic!("round {round}: unexpected {other:?}"),
        }
        c.submit_grad_session(
            "layer0",
            qp.q.clone(),
            qp.b.clone(),
            qp.h.clone(),
            v.clone(),
            1e-3,
            501,
        );
        match c.recv_timeout(Duration::from_secs(30)).expect("reply") {
            Reply::Grad(g) => assert_eq!(g.grad_q.len(), 12),
            other => panic!("round {round}: unexpected {other:?}"),
        }
    }
    let ord = std::sync::atomic::Ordering::Relaxed;
    assert!(
        c.metrics.warm_hits.load(ord) >= 2,
        "second round should hit both sessions (hits={})",
        c.metrics.warm_hits.load(ord)
    );
    assert!(c.metrics.warm_misses.load(ord) >= 2, "first round misses");
    assert!(
        c.metrics.warm_iters_saved.load(ord) > 0,
        "warm grad batch should truncate under the routed k"
    );
}

/// Wire round trip: a second request with the same session key
/// observes `warm_hits > 0` in the server's metrics.
#[test]
fn wire_session_key_warms_across_requests() {
    let qp = dense_qp(12, 6, 3, 9);
    let coord = Coordinator::builder(Config {
        workers: 1,
        max_batch: 1,
        batch_timeout_us: 1_000,
        artifacts: None,
        warm_capacity: 64,
        warm_radius: 0.5,
        ..Default::default()
    })
    .register("dense12", qp.clone(), 1.0)
    .unwrap()
    .start();
    let server =
        NetServer::bind("127.0.0.1:0", coord, NetConfig::default())
            .expect("bind");
    let addr = server.local_addr().expect("addr");
    let stop = server.stop_handle();
    let handle = std::thread::spawn(move || server.run());

    let mut cl = Client::connect(addr).expect("connect");
    cl.set_session(1234);
    for round in 0..2 {
        // slight per-round drift: the session key (not θ equality) is
        // what routes round 2 onto round 1's iterate
        let s = 1.0 + 0.02 * round as f64;
        let q: Vec<f64> = qp.q.iter().map(|&v| v * s).collect();
        match cl
            .solve("dense12", q, qp.b.clone(), qp.h.clone(), 1e-3)
            .expect("solve")
        {
            Reply::Ok(r) => assert_eq!(r.x.len(), 12),
            other => panic!("round {round}: unexpected {other:?}"),
        }
    }
    // the second request's warm hit is visible over the wire
    let stats = cl.stats().expect("stats");
    let hits: u64 = stats
        .lines()
        .find(|l| l.starts_with("altdiff_warm_hits_total"))
        .and_then(|l| l.split_whitespace().last())
        .and_then(|v| v.parse().ok())
        .expect("warm_hits_total in stats text");
    assert!(hits >= 1, "no warm hit observed over the wire:\n{stats}");
    drop(cl);
    stop.store(true, std::sync::atomic::Ordering::SeqCst);
    let coord = handle.join().expect("server thread");
    let ord = std::sync::atomic::Ordering::Relaxed;
    assert!(coord.metrics.warm_hits.load(ord) >= 1);
}

/// The routing bugfix: a tolerance tighter than everything the layer's
/// truncation table was calibrated for is rejected with
/// `FailureKind::Invalid` (documented message), never silently clamped
/// to the top rung.
#[test]
fn over_tight_tolerance_is_rejected_not_clamped() {
    let qp = dense_qp(10, 5, 2, 9);
    let mut c = Coordinator::builder(Config::default())
        .register("layer0", qp.clone(), 1.0)
        .unwrap()
        .start();
    c.submit("layer0", qp.q.clone(), qp.b.clone(), qp.h.clone(), 1e-12);
    match c.recv_timeout(Duration::from_secs(10)).expect("reply") {
        Reply::Err(f) => {
            assert_eq!(f.kind, FailureKind::Invalid);
            assert!(
                f.error.contains("truncation table"),
                "unexpected message: {}",
                f.error
            );
        }
        other => panic!("expected Invalid failure, got {other:?}"),
    }
    // calibrated-range requests still serve
    c.submit("layer0", qp.q.clone(), qp.b.clone(), qp.h.clone(), 1e-3);
    match c.recv_timeout(Duration::from_secs(30)).expect("reply") {
        Reply::Ok(r) => assert_eq!(r.x.len(), 10),
        other => panic!("healthy request failed: {other:?}"),
    }
}
