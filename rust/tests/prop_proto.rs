//! Property tests for the wire codec: encode∘decode == identity over
//! randomized requests/replies (solve, grad, and failure variants), and
//! hostile-input tests — truncated frames, oversized length prefixes,
//! wrong version, garbage bytes — all return `Err`, never panic or
//! over-allocate.

use altdiff::coordinator::{
    Failure, FailureKind, GradientResponse, Priority, Reply, Request,
    Response,
};
use altdiff::net::frame::{
    header, parse_header, FrameReader, HEADER_LEN, MAX_PAYLOAD,
};
use altdiff::net::proto::{self, op};
use altdiff::obs::{StageStamps, N_SPANS};
use altdiff::util::Pcg64;
use std::time::Instant;

fn rand_vec(rng: &mut Pcg64, max_len: usize) -> Vec<f64> {
    let n = rng.below(max_len + 1);
    rng.normal_vec(n)
}

fn rand_name(rng: &mut Pcg64) -> String {
    let n = 1 + rng.below(12);
    (0..n)
        .map(|_| (b'a' + rng.below(26) as u8) as char)
        .collect()
}

fn rand_request(rng: &mut Pcg64, grad: bool) -> Request {
    Request {
        id: rng.next_u64(),
        layer: rand_name(rng),
        q: rand_vec(rng, 40),
        b: rand_vec(rng, 10),
        h: rand_vec(rng, 20),
        tol: 10f64.powi(-(rng.below(9) as i32)),
        grad_v: grad.then(|| rand_vec(rng, 40)),
        session: (rng.below(2) == 1).then(|| rng.next_u64()),
        priority: Priority::from_code(rng.below(3) as u8).unwrap(),
        deadline_us: (rng.below(2) == 1)
            .then(|| 1 + rng.next_u64() as u32 % 1_000_000),
        submitted: Instant::now(),
        stamps: StageStamps::off(),
        sampled: false,
        echo_stages: rng.below(2) == 1,
    }
}

fn rand_stages(rng: &mut Pcg64) -> Option<[u32; N_SPANS]> {
    (rng.below(2) == 1).then(|| {
        let mut s = [0u32; N_SPANS];
        for v in s.iter_mut() {
            *v = rng.next_u64() as u32 % 1_000_000;
        }
        s
    })
}

fn strip(frame: &[u8]) -> (u8, Vec<u8>) {
    let (op_, len) = parse_header(frame).expect("header");
    assert_eq!(frame.len(), HEADER_LEN + len, "frame length consistent");
    (op_, frame[HEADER_LEN..].to_vec())
}

#[test]
fn request_encode_decode_is_identity() {
    let mut rng = Pcg64::new(11);
    for trial in 0..200 {
        let grad = trial % 2 == 1;
        let req = rand_request(&mut rng, grad);
        let (op_, payload) = strip(&proto::encode_request(&req));
        assert_eq!(op_, if grad { op::GRAD } else { op::SOLVE });
        let back = proto::decode_request(op_, &payload).unwrap();
        assert_eq!(back.id, req.id);
        assert_eq!(back.layer, req.layer);
        assert_eq!(back.q, req.q);
        assert_eq!(back.b, req.b);
        assert_eq!(back.h, req.h);
        assert_eq!(back.tol, req.tol);
        assert_eq!(back.grad_v, req.grad_v);
        assert_eq!(back.session, req.session);
        assert_eq!(back.priority, req.priority);
        assert_eq!(back.deadline_us, req.deadline_us);
        assert_eq!(back.echo_stages, req.echo_stages);
    }
}

#[test]
fn reply_encode_decode_is_identity_all_variants() {
    let mut rng = Pcg64::new(12);
    let backends = ["native", "native-sparse", "pjrt"];
    for trial in 0..200 {
        let reply = match trial % 3 {
            0 => Reply::Ok(Response {
                id: rng.next_u64(),
                x: rand_vec(&mut rng, 50),
                jx: rand_vec(&mut rng, 100),
                prim_residual: rng.normal().abs(),
                k_used: rng.below(100),
                batch_size: 1 + rng.below(32),
                latency: rng.uniform(),
                backend: backends[rng.below(3)],
                stamps: StageStamps::off(),
                stages: rand_stages(&mut rng),
            }),
            1 => Reply::Grad(GradientResponse {
                id: rng.next_u64(),
                x: rand_vec(&mut rng, 50),
                grad_q: rand_vec(&mut rng, 50),
                grad_b: rand_vec(&mut rng, 10),
                grad_h: rand_vec(&mut rng, 25),
                prim_residual: rng.normal().abs(),
                k_used: rng.below(100),
                batch_size: 1 + rng.below(32),
                latency: rng.uniform(),
                backend: backends[rng.below(2)],
                stamps: StageStamps::off(),
                stages: rand_stages(&mut rng),
            }),
            _ => Reply::Err(Failure::new(
                rng.next_u64(),
                // all five kinds, DeadlineExceeded (code 4) included
                FailureKind::from_code(rng.below(5) as u8).unwrap(),
                rand_name(&mut rng),
            )),
        };
        let (op_, payload) = strip(&proto::encode_reply(&reply));
        let back = proto::decode_reply(op_, &payload).unwrap();
        match (&reply, &back) {
            (Reply::Ok(a), Reply::Ok(b)) => {
                assert_eq!(a.id, b.id);
                assert_eq!(a.x, b.x);
                assert_eq!(a.jx, b.jx);
                assert_eq!(a.prim_residual, b.prim_residual);
                assert_eq!(a.k_used, b.k_used);
                assert_eq!(a.batch_size, b.batch_size);
                assert_eq!(a.latency, b.latency);
                assert_eq!(a.backend, b.backend);
                assert_eq!(a.stages, b.stages);
            }
            (Reply::Grad(a), Reply::Grad(b)) => {
                assert_eq!(a.id, b.id);
                assert_eq!(a.x, b.x);
                assert_eq!(a.grad_q, b.grad_q);
                assert_eq!(a.grad_b, b.grad_b);
                assert_eq!(a.grad_h, b.grad_h);
                assert_eq!(a.backend, b.backend);
                assert_eq!(a.stages, b.stages);
            }
            (Reply::Err(a), Reply::Err(b)) => {
                assert_eq!(a.id, b.id);
                assert_eq!(a.kind, b.kind);
                assert_eq!(a.error, b.error);
            }
            _ => panic!("arm changed across the wire"),
        }
    }
}

#[test]
fn every_truncation_of_a_valid_frame_errs_or_waits_never_panics() {
    let mut rng = Pcg64::new(13);
    let req = rand_request(&mut rng, true);
    let frame = proto::encode_request(&req);
    // frame-level: a FrameReader holding any prefix either says "need
    // more bytes" or (for a complete frame) yields it — never Err on a
    // prefix of valid bytes, never a panic
    for cut in 0..frame.len() {
        let mut r = FrameReader::new();
        r.extend(&frame[..cut]);
        match r.next_frame() {
            Ok(None) => {}
            Ok(Some(_)) => panic!("complete frame from {cut} bytes"),
            Err(e) => panic!("prefix of valid frame errored: {e}"),
        }
    }
    // payload-level: every strict prefix of the payload must decode to
    // Err (truncated field), never panic
    let (op_, payload) = strip(&frame);
    for cut in 0..payload.len() {
        assert!(
            proto::decode_request(op_, &payload[..cut]).is_err(),
            "payload prefix {cut}/{} decoded",
            payload.len()
        );
    }
}

#[test]
fn oversized_length_prefix_is_rejected_before_allocation() {
    // header claiming a payload over MAX_PAYLOAD
    let mut h = header(op::SOLVE, 0).to_vec();
    h[4..8].copy_from_slice(&(MAX_PAYLOAD + 1).to_le_bytes());
    assert!(parse_header(&h).is_err());
    let mut r = FrameReader::new();
    r.extend(&h);
    assert!(r.next_frame().is_err());
    // in-payload: a vector count far beyond the payload fails before
    // the decoder allocates (would be 32 GiB if it trusted the count)
    let mut w_payload = Vec::new();
    w_payload.extend_from_slice(&7u64.to_le_bytes()); // id
    w_payload.extend_from_slice(&1e-3f64.to_le_bytes()); // tol
    w_payload.push(0); // no session key
    w_payload.extend_from_slice(&1u16.to_le_bytes()); // layer len
    w_payload.push(b'l');
    w_payload.extend_from_slice(&u32::MAX.to_le_bytes()); // q count
    assert!(proto::decode_request(op::SOLVE, &w_payload).is_err());
}

#[test]
fn wrong_version_and_magic_are_rejected() {
    let good = proto::encode_request(&Request {
        id: 1,
        layer: "l".into(),
        q: vec![1.0],
        b: vec![],
        h: vec![],
        tol: 0.1,
        grad_v: None,
        session: None,
        priority: Priority::Normal,
        deadline_us: None,
        submitted: Instant::now(),
        stamps: StageStamps::off(),
        sampled: false,
        echo_stages: false,
    });
    let mut bad_ver = good.clone();
    bad_ver[1] = 2; // future version
    let mut r = FrameReader::new();
    r.extend(&bad_ver);
    assert!(r.next_frame().is_err());
    let mut bad_magic = good.clone();
    bad_magic[0] = 0x00;
    let mut r = FrameReader::new();
    r.extend(&bad_magic);
    assert!(r.next_frame().is_err());
}

#[test]
fn garbage_bytes_never_panic_the_decoder() {
    let mut rng = Pcg64::new(14);
    for _ in 0..300 {
        let n = rng.below(256);
        let bytes: Vec<u8> =
            (0..n).map(|_| rng.next_u64() as u8).collect();
        // frame layer
        let mut r = FrameReader::new();
        r.extend(&bytes);
        let _ = r.next_frame(); // Ok(None), Ok(Some), or Err — no panic
        // payload layer, every opcode
        for op_ in
            [op::SOLVE, op::GRAD, op::R_SOLVE, op::R_GRAD, op::R_ERR]
        {
            match op_ {
                op::SOLVE | op::GRAD => {
                    let _ = proto::decode_request(op_, &bytes);
                }
                _ => {
                    let _ = proto::decode_reply(op_, &bytes);
                }
            }
        }
        let _ = proto::decode_stats_reply(&bytes);
        let _ = proto::decode_layers_reply(&bytes);
        let _ = proto::decode_goodbye(&bytes);
    }
}

#[test]
fn bad_session_tag_is_rejected() {
    let mut rng = Pcg64::new(18);
    for grad in [false, true] {
        let req = rand_request(&mut rng, grad);
        let (op_, mut payload) = strip(&proto::encode_request(&req));
        // the session presence tag sits after id (u64) + tol (f64) and
        // must be 0 or 1 — anything else is a protocol violation
        payload[16] = 2;
        assert!(proto::decode_request(op_, &payload).is_err());
    }
}

#[test]
fn malformed_priority_and_deadline_extensions_are_rejected() {
    let mut rng = Pcg64::new(21);
    for _ in 0..50 {
        let mut req = rand_request(&mut rng, false);
        // force the extension block onto the wire
        req.priority = Priority::Low;
        req.deadline_us = Some(1 + rng.below(1_000_000) as u32);
        let (op_, payload) = strip(&proto::encode_request(&req));
        // priority class byte is third-from... locate from the tail:
        // [prio tag, class, ddl tag, 4×budget] = last 7 bytes
        let base = payload.len() - 7;
        let mut bad_class = payload.clone();
        bad_class[base + 1] = 3 + (rng.below(250) as u8); // only 0..=2 valid
        assert!(proto::decode_request(op_, &bad_class).is_err());
        let mut bad_prio_tag = payload.clone();
        bad_prio_tag[base] = 2 + (rng.below(250) as u8); // tag is 0/1
        assert!(proto::decode_request(op_, &bad_prio_tag).is_err());
        let mut bad_ddl_tag = payload.clone();
        bad_ddl_tag[base + 2] = 2 + (rng.below(250) as u8);
        assert!(proto::decode_request(op_, &bad_ddl_tag).is_err());
        // truncations *inside* the extension must error too (cutting
        // the whole block off is legal — that's a pre-extension frame)
        for cut in base + 1..payload.len() {
            assert!(
                proto::decode_request(op_, &payload[..cut]).is_err(),
                "extension truncated at {cut} decoded"
            );
        }
    }
}

#[test]
fn garbage_tail_after_valid_fields_is_rejected() {
    let mut rng = Pcg64::new(15);
    let req = rand_request(&mut rng, false);
    let (op_, payload) = strip(&proto::encode_request(&req));
    let mut padded = payload.clone();
    padded.extend_from_slice(&[1, 2, 3]);
    assert!(proto::decode_request(op_, &padded).is_err());
}

#[test]
fn request_reply_opcode_confusion_is_an_error() {
    let mut rng = Pcg64::new(16);
    let req = rand_request(&mut rng, false);
    let (_, payload) = strip(&proto::encode_request(&req));
    assert!(proto::decode_reply(op::SOLVE, &payload).is_err());
    assert!(proto::decode_request(op::R_SOLVE, &payload).is_err());
    assert!(proto::decode_request(op::STATS, &[]).is_err());
}

#[test]
fn frame_reader_survives_interleaved_valid_frames_split_arbitrarily() {
    let mut rng = Pcg64::new(17);
    // a stream of 20 frames chopped at random points must reassemble
    // to exactly those 20 frames
    let mut stream = Vec::new();
    let mut expect = Vec::new();
    for i in 0..20 {
        let req = rand_request(&mut rng, i % 3 == 0);
        expect.push(req.id);
        stream.extend_from_slice(&proto::encode_request(&req));
    }
    let mut r = FrameReader::new();
    let mut got = Vec::new();
    let mut pos = 0;
    while pos < stream.len() {
        let step = 1 + rng.below(97);
        let end = (pos + step).min(stream.len());
        r.extend(&stream[pos..end]);
        pos = end;
        while let Some(f) = r.next_frame().unwrap() {
            let req = proto::decode_request(f.op, &f.payload).unwrap();
            got.push(req.id);
        }
    }
    assert_eq!(got, expect);
}
