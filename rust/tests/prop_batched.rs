//! Alt-Diff-family instantiation of the shared cross-engine conformance
//! battery (`tests/common/conformance.rs`), plus the randomized
//! property tests that are specific to the batched native engine:
//! `BatchedAltDiff` must reproduce `DenseAltDiff` run element-by-element
//! — solutions, duals, and Jacobians to 1e-8 — across random ragged
//! batch sizes, every Jacobian parameter, fixed-iteration (server)
//! semantics, and mixed per-element convergence speeds.

#[path = "common/conformance.rs"]
mod conformance;

use altdiff::altdiff::{BackwardMode, DenseAltDiff, Options, Param};
use altdiff::batch::BatchedAltDiff;
use altdiff::prob::dense_qp;
use altdiff::util::Pcg64;
use conformance::{max_abs_diff, Cell};

// ------------------------------------------------------------- battery

/// The identical battery every engine family runs (see
/// `common/conformance.rs`); this file instantiates the founding
/// Alt-Diff pair, so the oracle family is held to its own contracts.
#[test]
fn altdiff_passes_the_shared_conformance_battery() {
    let cells = [
        Cell {
            name: "dense(10,5,2)",
            qp: dense_qp(10, 5, 2, 31),
            rho: 1.0,
            check_duals: true,
            perturb_b: true,
            perturb_h: true,
        },
        Cell {
            name: "dense(14,7,3)",
            qp: dense_qp(14, 7, 3, 43),
            rho: 1.0,
            check_duals: true,
            perturb_b: true,
            perturb_h: true,
        },
    ];
    conformance::run_battery(&cells, |cell| {
        let single = DenseAltDiff::new(cell.qp.clone(), cell.rho)
            .expect("dense registration");
        let batched = BatchedAltDiff::from_dense(&single);
        (single, batched)
    });
}

// ---------------------------------------------------- randomized extras

struct Thetas {
    qs: Vec<Vec<f64>>,
    bs: Vec<Vec<f64>>,
    hs: Vec<Vec<f64>>,
}

impl Thetas {
    /// Random feasible perturbations of the registered θ: q rescaled,
    /// b shifted, h only *relaxed* (so the generator's strictly feasible
    /// point stays feasible for every element).
    fn random(qp: &altdiff::prob::Qp, bsz: usize, rng: &mut Pcg64) -> Self {
        let qs = (0..bsz)
            .map(|_| {
                qp.q.iter()
                    .map(|&v| v * (1.0 + 0.2 * rng.normal()))
                    .collect()
            })
            .collect();
        let bs = (0..bsz)
            .map(|_| {
                qp.b.iter().map(|&v| v + 0.1 * rng.normal()).collect()
            })
            .collect();
        let hs = (0..bsz)
            .map(|_| {
                qp.h.iter()
                    .map(|&v| v + (0.2 * rng.normal()).abs())
                    .collect()
            })
            .collect();
        Thetas { qs, bs, hs }
    }

    fn refs(&self) -> (Vec<&[f64]>, Vec<&[f64]>, Vec<&[f64]>) {
        (
            self.qs.iter().map(|v| v.as_slice()).collect(),
            self.bs.iter().map(|v| v.as_slice()).collect(),
            self.hs.iter().map(|v| v.as_slice()).collect(),
        )
    }
}

/// ∀ random QPs, ragged batch sizes, and Jacobian parameters: converged
/// batched results match per-element dense results to 1e-8.
#[test]
fn prop_batched_matches_dense_elementwise() {
    let mut rng = Pcg64::new(301);
    let params = [Param::Q, Param::B, Param::H];
    for case in 0..8u64 {
        let n = 6 + rng.below(18);
        let m = 2 + rng.below(8);
        let p = 1 + rng.below(4);
        let bsz = 1 + rng.below(17); // ragged: 1..=17, any remainder
        let qp = dense_qp(n, m, p, 4000 + case);
        let dense = DenseAltDiff::new(qp.clone(), 1.0).unwrap();
        let batched = BatchedAltDiff::from_dense(&dense);
        let param = params[case as usize % 3];
        let opts = Options {
            tol: 1e-11,
            max_iter: 100_000,
            backward: BackwardMode::Forward(param),
            ..Default::default()
        };
        let th = Thetas::random(&qp, bsz, &mut rng);
        let (qr, br, hr) = th.refs();
        let sb =
            batched.solve_batch(Some(&qr), Some(&br), Some(&hr), &opts);
        assert_eq!(sb.len(), bsz);
        for e in 0..bsz {
            let sd = dense.solve_with(
                Some(&th.qs[e]),
                Some(&th.bs[e]),
                Some(&th.hs[e]),
                &opts,
            );
            let ctx = format!("case {case} elem {e}/{bsz} n={n}");
            assert!(
                max_abs_diff(&sb.xs[e], &sd.x) < 1e-8,
                "{ctx}: x diff {}",
                max_abs_diff(&sb.xs[e], &sd.x)
            );
            assert!(max_abs_diff(&sb.lams[e], &sd.lam) < 1e-8, "{ctx}: λ");
            assert!(max_abs_diff(&sb.nus[e], &sd.nu) < 1e-8, "{ctx}: ν");
            assert!(max_abs_diff(&sb.ss[e], &sd.s) < 1e-8, "{ctx}: s");
            let jb = &sb.jacobians.as_ref().unwrap()[e];
            let jd = sd.jacobian.as_ref().unwrap();
            assert!(
                jb.max_abs_diff(jd) < 1e-8,
                "{ctx}: jacobian diff {} (param {param:?})",
                jb.max_abs_diff(jd)
            );
        }
    }
}

/// Server semantics (tol = 0, fixed k): every element runs exactly k
/// iterations and matches the dense engine's fixed-k run to 1e-8.
#[test]
fn prop_batched_fixed_k_matches_dense() {
    let mut rng = Pcg64::new(302);
    for &k in &[5usize, 20, 60] {
        let qp = dense_qp(16, 8, 4, 310 + k as u64);
        let dense = DenseAltDiff::new(qp.clone(), 1.0).unwrap();
        let batched = BatchedAltDiff::from_dense(&dense);
        let bsz = 7;
        let th = Thetas::random(&qp, bsz, &mut rng);
        let (qr, br, hr) = th.refs();
        let opts = Options {
            tol: 0.0,
            max_iter: k,
            backward: BackwardMode::Forward(Param::B),
            ..Default::default()
        };
        let sb =
            batched.solve_batch(Some(&qr), Some(&br), Some(&hr), &opts);
        assert!(sb.iters.iter().all(|&it| it == k), "{:?}", sb.iters);
        for e in 0..bsz {
            let sd = dense.solve_with(
                Some(&th.qs[e]),
                Some(&th.bs[e]),
                Some(&th.hs[e]),
                &opts,
            );
            assert_eq!(sd.iters, k);
            assert!(
                max_abs_diff(&sb.xs[e], &sd.x) < 1e-8,
                "k={k} elem {e}"
            );
            let jb = &sb.jacobians.as_ref().unwrap()[e];
            assert!(jb.max_abs_diff(sd.jacobian.as_ref().unwrap()) < 1e-8);
        }
    }
}

/// Mixed convergence speeds: elements whose objectives live on very
/// different scales cross the (relative-step) truncation threshold at
/// very different iterations; the active mask must freeze fast elements
/// without perturbing slow ones.
#[test]
fn prop_batched_mixed_convergence_speeds() {
    let qp = dense_qp(16, 8, 3, 777);
    let dense = DenseAltDiff::new(qp.clone(), 1.0).unwrap();
    let batched = BatchedAltDiff::from_dense(&dense);
    let scales = [1e-2, 1.0, 50.0, 0.1, 10.0];
    let qs: Vec<Vec<f64>> = scales
        .iter()
        .map(|&s| qp.q.iter().map(|&v| v * s).collect())
        .collect();
    let qr: Vec<&[f64]> = qs.iter().map(|v| v.as_slice()).collect();
    let opts = Options {
        tol: 1e-6,
        max_iter: 50_000,
        backward: BackwardMode::Forward(Param::Q),
        ..Default::default()
    };
    let sb = batched.solve_batch(Some(&qr), None, None, &opts);
    // the mask actually fired at different times
    let min_it = *sb.iters.iter().min().unwrap();
    let max_it = *sb.iters.iter().max().unwrap();
    assert!(
        min_it < max_it,
        "expected heterogeneous convergence, got {:?}",
        sb.iters
    );
    for (e, q) in qs.iter().enumerate() {
        let sd = dense.solve_with(Some(q), None, None, &opts);
        // identical stopping rule; allow a ±2 iteration slack for the
        // H⁻¹-gemm vs Cholesky-solve rounding at the threshold
        assert!(
            (sb.iters[e] as i64 - sd.iters as i64).abs() <= 2,
            "elem {e}: batched {} vs dense {} iters",
            sb.iters[e],
            sd.iters
        );
        for i in 0..16 {
            let tol_here = 1e-4 * (1.0 + sd.x[i].abs());
            assert!(
                (sb.xs[e][i] - sd.x[i]).abs() < tol_here,
                "elem {e} x[{i}]: {} vs {}",
                sb.xs[e][i],
                sd.x[i]
            );
        }
        assert!(sb.step_rel[e] < 1e-6);
    }
}
