//! Compiled-path parity: the PJRT-executed artifact must reproduce the
//! native Rust engine bit-for-bit up to f32 round-off. This is the test
//! that proves L1 (Pallas) → L2 (JAX scan) → AOT HLO → L3 (rust PJRT)
//! compose into the same algorithm as the native implementation.

use altdiff::altdiff::{BackwardMode, DenseAltDiff, Options, Param};
use altdiff::prob::dense_qp;
use altdiff::runtime::{Engine, Manifest};
use std::path::{Path, PathBuf};

fn artifacts_dir() -> Option<PathBuf> {
    if cfg!(not(feature = "pjrt")) {
        // default build substitutes the stub Engine (constructor always
        // fails) — skip even when artifacts are present on disk
        return None;
    }
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.tsv").exists().then_some(dir)
}

/// Run variant (n,m,p,k,b1) on PJRT and natively; compare x and ∂x/∂b.
fn parity_case(n: usize, m: usize, p: usize, k: usize) {
    let Some(dir) = artifacts_dir() else {
        eprintln!("artifacts missing; run `make artifacts`");
        return;
    };
    let mut eng = Engine::new(&dir).expect("engine");
    let name = format!("qp_n{n}_m{m}_p{p}_k{k}_b1");
    if eng.manifest.get(&name).is_none() {
        eprintln!("variant {name} not in manifest; skipping");
        return;
    }
    let qp = dense_qp(n, m, p, 42 + n as u64);
    let native = DenseAltDiff::new(qp.clone(), 1.0).unwrap();
    let hinv = native.hinv();

    let out = eng
        .execute_dense(&name, &hinv, &qp.a, &qp.g, &qp.q, &qp.b, &qp.h)
        .expect("pjrt execute");

    // native, exactly k iterations (tol=0 disables truncation)
    let sol = native.solve(&Options {
        tol: 0.0,
        max_iter: k,
        backward: BackwardMode::Forward(Param::B),
        ..Default::default()
    });
    assert_eq!(sol.iters, k);

    let xerr: f64 = out
        .x
        .iter()
        .zip(&sol.x)
        .map(|(&a, &b)| (a as f64 - b).abs())
        .fold(0.0, f64::max);
    assert!(xerr < 5e-4, "{name}: max |x_pjrt - x_native| = {xerr}");

    let j = sol.jacobian.unwrap();
    let jerr: f64 = out
        .jx
        .iter()
        .zip(&j.data)
        .map(|(&a, &b)| (a as f64 - b).abs())
        .fold(0.0, f64::max);
    assert!(jerr < 5e-3, "{name}: max |J_pjrt - J_native| = {jerr}");

    // residual outputs are finite and sane
    assert!(out.prim[0].is_finite() && out.prim[0] >= 0.0);
    assert!(out.dual[0].is_finite() && out.dual[0] >= 0.0);
}

#[test]
fn pjrt_matches_native_n16_k40() {
    parity_case(16, 8, 4, 40);
}

#[test]
fn pjrt_matches_native_n32_k20() {
    parity_case(32, 16, 8, 20);
}

#[test]
fn pjrt_matches_native_n64_k80() {
    parity_case(64, 32, 12, 80);
}

#[test]
fn pjrt_batched_variant_matches_per_request() {
    let Some(dir) = artifacts_dir() else { return };
    let mut eng = Engine::new(&dir).unwrap();
    let (n, m, p, k, bsz) = (16usize, 8usize, 4usize, 20usize, 8usize);
    let name = format!("qp_n{n}_m{m}_p{p}_k{k}_b{bsz}");
    if eng.manifest.get(&name).is_none() {
        return;
    }
    let qp = dense_qp(n, m, p, 7);
    let native = DenseAltDiff::new(qp.clone(), 1.0).unwrap();
    let hinv = native.hinv();
    // batch of 8 perturbed θ
    let mut qs = Vec::new();
    let mut bs = Vec::new();
    let mut hs = Vec::new();
    for i in 0..bsz {
        let scale = 1.0 + 0.05 * i as f64;
        qs.extend(qp.q.iter().map(|&v| (v * scale) as f32));
        bs.extend(qp.b.iter().map(|&v| (v * scale) as f32));
        hs.extend(qp.h.iter().map(|&v| (v + 0.01 * i as f64) as f32));
    }
    let out = eng
        .execute(
            &name,
            &hinv.to_f32(),
            &qp.a.to_f32(),
            &qp.g.to_f32(),
            &qs,
            &bs,
            &hs,
        )
        .unwrap();
    assert_eq!(out.x.len(), bsz * n);
    assert_eq!(out.jx.len(), bsz * n * p);
    // element 3 must match a single native run with the same θ
    let i = 3;
    let scale = 1.0 + 0.05 * i as f64;
    let q3: Vec<f64> = qp.q.iter().map(|&v| v * scale).collect();
    let b3: Vec<f64> = qp.b.iter().map(|&v| v * scale).collect();
    let h3: Vec<f64> = qp.h.iter().map(|&v| v + 0.01 * i as f64).collect();
    let sol = native.solve_with(
        Some(&q3),
        Some(&b3),
        Some(&h3),
        &Options {
            tol: 0.0,
            max_iter: k,
            backward: BackwardMode::Forward(Param::B),
            ..Default::default()
        },
    );
    for j in 0..n {
        let got = out.x[i * n + j] as f64;
        assert!(
            (got - sol.x[j]).abs() < 1e-3,
            "batched x[{j}]: {got} vs {}",
            sol.x[j]
        );
    }
}

#[test]
fn engine_rejects_wrong_arity() {
    let Some(dir) = artifacts_dir() else { return };
    let mut eng = Engine::new(&dir).unwrap();
    let name = eng.manifest.variants[0].name.clone();
    let err = eng.execute(&name, &[0.0f32; 3], &[], &[], &[], &[], &[]);
    assert!(err.is_err());
}

#[test]
fn engine_unknown_variant_is_registry_error() {
    let Some(dir) = artifacts_dir() else { return };
    let mut eng = Engine::new(&dir).unwrap();
    assert!(eng.compile("qp_nope").is_err());
}

#[test]
fn manifest_families_cover_ladder() {
    let Some(dir) = artifacts_dir() else { return };
    let man = Manifest::load(&dir).unwrap();
    for (n, m, p) in man.sizes() {
        let fam = man.family(n, m, p, 1);
        assert!(
            fam.len() >= 2,
            "size ({n},{m},{p}) needs a k-ladder for truncation routing"
        );
        for w in fam.windows(2) {
            assert!(w[0].k < w[1].k);
        }
    }
}
