//! Cross-module validation of the paper's two theorems and the complexity
//! story, at sizes larger than the unit tests use.

use altdiff::altdiff::{BackwardMode, DenseAltDiff, Options, Param, SparseAltDiff};
use altdiff::baselines::{self, conic};
use altdiff::linalg::{cosine, norm2, sub_vec};
use altdiff::prob::{dense_qp, sparse_qp, sparsemax_qp};

/// Thm 4.2 at n=80 for all three parameterizations.
#[test]
fn thm42_altdiff_converges_to_kkt_gradient() {
    let qp = dense_qp(80, 40, 16, 1);
    let solver = DenseAltDiff::new(qp.clone(), 1.0).unwrap();
    for param in [Param::B, Param::Q, Param::H] {
        let (_, jkkt, _) =
            baselines::optnet_layer(&qp, param, 1e-11).unwrap();
        let sol = solver.solve(&Options {
            tol: 1e-11,
            max_iter: 200_000,
            backward: BackwardMode::Forward(param),
            ..Default::default()
        });
        let cos = cosine(&sol.jacobian.unwrap().data, &jkkt.data);
        assert!(cos > 0.999, "{param:?}: cosine {cos}");
    }
}

/// Thm 4.3: the Jacobian error is bounded by C₁‖x_k − x*‖ with a single
/// constant across tolerances.
#[test]
fn thm43_truncation_error_is_same_order() {
    let qp = dense_qp(60, 30, 12, 2);
    let solver = DenseAltDiff::new(qp, 1.0).unwrap();
    let exact = solver.solve(&Options {
        tol: 1e-12,
        max_iter: 200_000,
        backward: BackwardMode::Forward(Param::B),
        ..Default::default()
    });
    let jstar = exact.jacobian.as_ref().unwrap();
    let mut ratios = Vec::new();
    for tol in [1e-1, 1e-2, 1e-3, 1e-4, 1e-5] {
        let sol = solver.solve(&Options {
            tol,
            max_iter: 200_000,
            backward: BackwardMode::Forward(Param::B),
            ..Default::default()
        });
        let xerr = norm2(&sub_vec(&sol.x, &exact.x)).max(1e-14);
        let jerr = sol.jacobian.unwrap().sub(jstar).fro();
        ratios.push(jerr / xerr);
    }
    let mx = ratios.iter().cloned().fold(f64::MIN, f64::max);
    let mn = ratios.iter().cloned().fold(f64::MAX, f64::min).max(1e-9);
    assert!(
        mx / mn < 200.0,
        "C1 ratio not same-order across tolerances: {ratios:?}"
    );
}

/// All differentiation engines agree on the same problem.
#[test]
fn multi_engine_gradient_agreement() {
    let qp = dense_qp(40, 20, 8, 3);
    let dense = DenseAltDiff::new(qp.clone(), 1.0).unwrap();
    let j_alt = dense
        .solve(&Options {
            tol: 1e-11,
            max_iter: 100_000,
            backward: BackwardMode::Forward(Param::B),
            ..Default::default()
        })
        .jacobian
        .unwrap();
    let (_, j_kkt, _) =
        baselines::optnet_layer(&qp, Param::B, 1e-11).unwrap();
    let j_cvx = conic::cvxpylayer_sim(&qp, Param::B, 1e-10)
        .unwrap()
        .jacobian;
    assert!(cosine(&j_alt.data, &j_kkt.data) > 0.999);
    assert!(cosine(&j_alt.data, &j_cvx.data) > 0.995);

    // sparse engine (CG path) vs dense engine on a diagonal-P problem
    let sq = sparse_qp(40, 20, 8, 0.2, 3);
    let j_sp = SparseAltDiff::new(sq.clone(), 1.0)
        .unwrap()
        .solve(&Options {
            tol: 1e-11,
            max_iter: 100_000,
            backward: BackwardMode::Forward(Param::B),
            ..Default::default()
        })
        .jacobian
        .unwrap();
    let j_dd = DenseAltDiff::new(sq.to_dense(), 1.0)
        .unwrap()
        .solve(&Options {
            tol: 1e-11,
            max_iter: 100_000,
            backward: BackwardMode::Forward(Param::B),
            ..Default::default()
        })
        .jacobian
        .unwrap();
    assert!(cosine(&j_sp.data, &j_dd.data) > 0.9999);
}

/// The sparse engine's two paths (Sherman–Morrison vs CG) agree with the
/// dense engine on their respective problem classes at n=200.
#[test]
fn sparse_engines_match_dense_at_scale() {
    let opts = Options {
        tol: 1e-10,
        max_iter: 100_000,
        backward: BackwardMode::Forward(Param::B),
        ..Default::default()
    };
    // SM path
    let sm = sparsemax_qp(200, 4);
    let s_sm = SparseAltDiff::new(sm.clone(), 1.0).unwrap();
    assert!(s_sm.uses_sherman_morrison());
    let d_sm = DenseAltDiff::new(sm.to_dense(), 1.0).unwrap();
    let a = s_sm.solve(&opts);
    let b = d_sm.solve(&opts);
    assert!(norm2(&sub_vec(&a.x, &b.x)) < 1e-6);
    assert!(a.jacobian.unwrap().sub(&b.jacobian.unwrap()).fro() < 1e-5);

    // CG path
    let sq = sparse_qp(150, 70, 25, 0.05, 5);
    let s_cg = SparseAltDiff::new(sq.clone(), 1.0).unwrap();
    assert!(!s_cg.uses_sherman_morrison());
    let d_cg = DenseAltDiff::new(sq.to_dense(), 1.0).unwrap();
    let a = s_cg.solve(&opts);
    let b = d_cg.solve(&opts);
    assert!(norm2(&sub_vec(&a.x, &b.x)) < 1e-5);
}

/// Failure injection: infeasible equality constraints must not panic —
/// ADMM fails to converge but stays finite.
#[test]
fn infeasible_problem_does_not_panic() {
    let mut qp = dense_qp(10, 5, 2, 6);
    for j in 0..10 {
        let v = qp.a[(0, j)];
        qp.a[(1, j)] = v;
    }
    qp.b[1] = qp.b[0] + 10.0;
    let solver = DenseAltDiff::new(qp.clone(), 1.0).unwrap();
    let sol = solver.solve(&Options {
        tol: 1e-8,
        max_iter: 500,
        backward: BackwardMode::Forward(Param::B),
        ..Default::default()
    });
    // ADMM on an infeasible program: x may stabilize (the least-squares
    // compromise) but primal feasibility is impossible — detectable.
    assert!(sol.x.iter().all(|v| v.is_finite()));
    let (eq, _) = qp.feasibility(&sol.x);
    assert!(eq > 1e-2, "infeasibility must show up in the residual: {eq}");
}

/// Failure injection: a PSD-but-singular P still registers (ρAᵀA + ρGᵀG
/// regularize H) and solves the resulting LP.
#[test]
fn singular_p_is_handled_by_penalty_terms() {
    // H = ρAᵀA + ρGᵀG alone can be singular (rank m+p < n) — the ridge
    // fallback in registration must absorb it.
    let mut qp = dense_qp(12, 6, 3, 7);
    qp.p = altdiff::linalg::Mat::zeros(12, 12);
    let solver = DenseAltDiff::new(qp.clone(), 1.0).unwrap();
    let sol = solver.solve(&Options {
        tol: 1e-8,
        max_iter: 50_000,
        backward: BackwardMode::None,
        ..Default::default()
    });
    let (eq, viol) = qp.feasibility(&sol.x);
    assert!(eq < 1e-4 && viol < 1e-4, "LP solve infeasible: {eq} {viol}");
}
