//! Property-based tests (in-repo generator loops — proptest is not
//! available offline; seeds are explicit so failures reproduce).

use altdiff::altdiff::{BackwardMode, DenseAltDiff, Options, Param};
use altdiff::coordinator::{Batcher, Priority, Request, TruncationTable};
use altdiff::warm::EngineFamily;
use altdiff::linalg::{gemv, Chol, Lu, Mat};
use altdiff::prob::dense_qp;
use altdiff::sparse::Csr;
use altdiff::util::Pcg64;
use std::time::{Duration, Instant};

const CASES: usize = 40;

fn rand_mat(r: usize, c: usize, rng: &mut Pcg64) -> Mat {
    Mat::from_vec(r, c, rng.normal_vec(r * c))
}

/// ∀ random SPD A, b: Chol solve residual ≈ 0 and A = LLᵀ.
#[test]
fn prop_cholesky_solve_residual() {
    let mut rng = Pcg64::new(101);
    for case in 0..CASES {
        let n = 2 + rng.below(30);
        let raw = rand_mat(n, n, &mut rng);
        let mut spd = altdiff::linalg::ata(&raw);
        for i in 0..n {
            spd[(i, i)] += n as f64;
        }
        let ch = Chol::factor(&spd).unwrap();
        let b = rng.normal_vec(n);
        let x = ch.solve(&b);
        let ax = gemv(&spd, &x);
        for i in 0..n {
            assert!(
                (ax[i] - b[i]).abs() < 1e-7,
                "case {case} n={n}: residual {}",
                (ax[i] - b[i]).abs()
            );
        }
    }
}

/// ∀ random square A (well-conditioned by diagonal boost): LU solves.
#[test]
fn prop_lu_solve_residual() {
    let mut rng = Pcg64::new(102);
    for _ in 0..CASES {
        let n = 2 + rng.below(25);
        let mut a = rand_mat(n, n, &mut rng);
        for i in 0..n {
            a[(i, i)] += 3.0;
        }
        let xtrue = rng.normal_vec(n);
        let b = gemv(&a, &xtrue);
        let x = Lu::factor(&a).unwrap().solve(&b);
        for i in 0..n {
            assert!((x[i] - xtrue[i]).abs() < 1e-6);
        }
    }
}

/// ∀ random sparse matrices: spmv agrees with dense, transpose twice is id.
#[test]
fn prop_csr_spmv_matches_dense() {
    let mut rng = Pcg64::new(103);
    for _ in 0..CASES {
        let r = 1 + rng.below(20);
        let c = 1 + rng.below(20);
        let mut t = Vec::new();
        for i in 0..r {
            for j in 0..c {
                if rng.uniform() < 0.3 {
                    t.push((i, j, rng.normal()));
                }
            }
        }
        let s = Csr::from_triplets(r, c, &t);
        let d = s.to_dense();
        let x = rng.normal_vec(c);
        let ys = s.spmv(&x);
        let yd = gemv(&d, &x);
        for i in 0..r {
            assert!((ys[i] - yd[i]).abs() < 1e-10);
        }
        let tt = s.transpose().transpose();
        assert!(tt.to_dense().max_abs_diff(&d) < 1e-12);
    }
}

/// ∀ random QPs: ADMM invariants hold at every iteration — s ≥ 0 always,
/// and the solution is primal-feasible at convergence.
#[test]
fn prop_admm_slack_nonnegative_and_feasible() {
    let mut rng = Pcg64::new(104);
    for case in 0..15 {
        let n = 5 + rng.below(20);
        let m = 1 + rng.below(n);
        let p = 1 + rng.below(n / 2 + 1);
        let qp = dense_qp(n, m, p, 1000 + case as u64);
        let solver = DenseAltDiff::new(qp.clone(), 1.0).unwrap();
        let sol = solver.solve(&Options {
            tol: 1e-9,
            max_iter: 100_000,
            backward: BackwardMode::None,
            ..Default::default()
        });
        assert!(sol.s.iter().all(|&v| v >= 0.0), "case {case}");
        let (eq, viol) = qp.feasibility(&sol.x);
        assert!(eq < 1e-4, "case {case}: eq {eq}");
        assert!(viol < 1e-4, "case {case}: viol {viol}");
        assert!(sol.nu.iter().all(|&v| v >= -1e-6), "dual feasibility");
    }
}

/// ∀ random QPs: the Jacobian is the derivative — directional FD check
/// in a random direction (cheaper than the full FD in unit tests).
#[test]
fn prop_jacobian_directional_derivative() {
    let mut rng = Pcg64::new(105);
    for case in 0..10 {
        let n = 6 + rng.below(10);
        let m = 2 + rng.below(4);
        let p = 1 + rng.below(3);
        let qp = dense_qp(n, m, p, 2000 + case as u64);
        let solver = DenseAltDiff::new(qp.clone(), 1.0).unwrap();
        let opts = Options {
            tol: 1e-11,
            max_iter: 100_000,
            backward: BackwardMode::Forward(Param::B),
            ..Default::default()
        };
        let sol = solver.solve(&opts);
        let j = sol.jacobian.unwrap();
        let dir: Vec<f64> = rng.normal_vec(p);
        let eps = 1e-5;
        let bp: Vec<f64> =
            qp.b.iter().zip(&dir).map(|(b, d)| b + eps * d).collect();
        let bm: Vec<f64> =
            qp.b.iter().zip(&dir).map(|(b, d)| b - eps * d).collect();
        let fopts = Options { backward: BackwardMode::None, ..opts };
        let xp = solver.solve_with(None, Some(&bp), None, &fopts).x;
        let xm = solver.solve_with(None, Some(&bm), None, &fopts).x;
        for i in 0..n {
            let fd = (xp[i] - xm[i]) / (2.0 * eps);
            let jd: f64 = (0..p).map(|c| j[(i, c)] * dir[c]).sum();
            assert!(
                (jd - fd).abs() < 5e-3 * (1.0 + fd.abs()),
                "case {case} x[{i}]: J·d={jd} fd={fd}"
            );
        }
    }
}

/// Batcher properties under random traffic: never mixes keys, never drops
/// or duplicates a request, preserves arrival order within a key.
#[test]
fn prop_batcher_conservation() {
    let mut rng = Pcg64::new(106);
    for _ in 0..30 {
        let max_batch = 1 + rng.below(6);
        let mut b = Batcher::new(max_batch, Duration::from_secs(3600));
        let layers = ["a", "b", "c"];
        let ks = [10usize, 20];
        let total = 30 + rng.below(50);
        let mut sent: Vec<(String, usize, u64)> = Vec::new();
        let mut got: Vec<(String, usize, u64)> = Vec::new();
        for id in 0..total as u64 {
            let layer = layers[rng.below(3)];
            let k = ks[rng.below(2)];
            sent.push((layer.to_string(), k, id));
            let req = Request {
                id,
                layer: layer.to_string(),
                q: vec![],
                b: vec![],
                h: vec![],
                tol: 1e-3,
                grad_v: None,
                session: None,
                priority: Priority::Normal,
                deadline_us: None,
                submitted: Instant::now(),
                stamps: altdiff::obs::StageStamps::off(),
                sampled: false,
                echo_stages: false,
            };
            if let Some(batch) = b.push(EngineFamily::AltDiff, k, req) {
                assert!(batch.requests.len() <= max_batch);
                for r in &batch.requests {
                    assert_eq!(
                        r.layer.as_str(),
                        &*batch.layer,
                        "mixed layers"
                    );
                    got.push((batch.layer.to_string(), batch.k, r.id));
                }
            }
        }
        for batch in b.flush_all() {
            for r in &batch.requests {
                got.push((batch.layer.to_string(), batch.k, r.id));
            }
        }
        assert_eq!(got.len(), sent.len(), "lost or duplicated requests");
        let mut gs: Vec<u64> = got.iter().map(|(_, _, id)| *id).collect();
        gs.sort_unstable();
        gs.dedup();
        assert_eq!(gs.len(), sent.len());
        // order within key preserved
        for layer in layers {
            for k in ks {
                let s: Vec<u64> = sent
                    .iter()
                    .filter(|(l, kk, _)| l == layer && *kk == k)
                    .map(|(_, _, id)| *id)
                    .collect();
                let g: Vec<u64> = got
                    .iter()
                    .filter(|(l, kk, _)| l == layer && *kk == k)
                    .map(|(_, _, id)| *id)
                    .collect();
                assert_eq!(s, g, "order broken for ({layer},{k})");
            }
        }
    }
}

/// Truncation table properties: k_for is monotone (tighter tol → ≥ k) and
/// always lands on a ladder rung.
#[test]
fn prop_truncation_table_monotone_on_ladder() {
    let mut rng = Pcg64::new(107);
    for _ in 0..30 {
        let rate = 0.5 + 0.45 * rng.uniform();
        let trace: Vec<f64> =
            (0..200).map(|i| rate.powi(i as i32)).collect();
        let ladder = [10usize, 20, 40, 80];
        let tols = [1e-1, 1e-2, 1e-3, 1e-4, 1e-5];
        let t = TruncationTable::calibrate(&ladder, &trace, &tols);
        let mut prev = 0usize;
        for &tol in tols.iter() {
            let k = t.k_for(tol);
            assert!(ladder.contains(&k), "k={k} off ladder");
            assert!(k >= prev, "not monotone");
            prev = k;
        }
    }
}
