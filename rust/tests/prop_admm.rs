//! ADMM-family instantiation of the shared cross-engine conformance
//! battery (`tests/common/conformance.rs`), plus the cross-method
//! router properties that are specific to this family: each layer is
//! dispatched to its calibrated winning engine, observable end-to-end
//! through the coordinator metrics and a `net/` stats round trip.

#[path = "common/conformance.rs"]
mod conformance;

use altdiff::admm::{AdmmQp, AdmmSettings, BatchedAdmm};
use altdiff::coordinator::{Config, Coordinator, Reply};
use altdiff::net::{Client, NetConfig, NetServer};
use altdiff::prob::{dense_qp, ill_conditioned_qp};
use conformance::{counter, max_abs_diff, pseudo, tight, Cell};
use std::sync::atomic::Ordering;
use std::time::Duration;

// ------------------------------------------------------------- battery

/// The identical battery every engine family runs: solve parity vs the
/// dense Alt-Diff oracle, ragged batch == singles, fixed-k, warm ==
/// cold + mixed isolation, VJP vs oracle and finite differences, and
/// batched adjoints with seed round trips. The contracts live in
/// `common/conformance.rs`; this file only instantiates the ADMM pair.
#[test]
fn admm_passes_the_shared_conformance_battery() {
    let cells = [
        Cell {
            name: "dense(10,5,2)",
            qp: dense_qp(10, 5, 2, 31),
            rho: 1.0,
            check_duals: true,
            perturb_b: true,
            perturb_h: true,
        },
        Cell {
            name: "dense(12,6,3)",
            qp: dense_qp(12, 6, 3, 2),
            rho: 1.0,
            check_duals: true,
            perturb_b: true,
            perturb_h: true,
        },
    ];
    conformance::run_battery(&cells, |cell| {
        let single = AdmmQp::new(cell.qp.clone(), cell.rho)
            .expect("admm registration");
        let batched = BatchedAdmm::from_single(&single);
        (single, batched)
    });
}

// ---------------------------------------------------------------- router

/// Coordinator whose router has a real choice to make: a well-behaved
/// layer (both families converge → tie → Alt-Diff) and an
/// ill-conditioned one (fixed-ρ Alt-Diff stalls, ρ-balanced ADMM
/// converges → ADMM), plus an ADMM-only layer. The ladder starts high
/// enough that both families clear the first rung on the easy layer.
fn routed_coordinator() -> Coordinator {
    Coordinator::builder(Config {
        workers: 2,
        max_batch: 4,
        batch_timeout_us: 1_000,
        artifacts: None,
        ..Default::default()
    })
    .ladder(vec![150, 600, 2400])
    .register_routed("well", dense_qp(12, 6, 3, 9), 1.0)
    .unwrap()
    .register_routed("ill", ill_conditioned_qp(10, 5, 2, 1e4, 7), 1.0)
    .unwrap()
    .register_admm("admm8", dense_qp(8, 4, 2, 5), 1.0)
    .unwrap()
    .start()
}

/// The cross-method router sends each layer to its calibrated winning
/// family — solve and gradient paths both — and the per-engine metrics
/// record the split.
#[test]
fn router_dispatches_each_layer_to_its_winning_family() {
    let mut c = routed_coordinator();
    let well = dense_qp(12, 6, 3, 9);
    let ill = ill_conditioned_qp(10, 5, 2, 1e4, 7);

    // oracle for the ill layer: a tight ρ-balanced ADMM solve
    let oracle =
        AdmmQp::new_adapted(ill.clone(), 1.0, AdmmSettings::default())
            .unwrap()
            .solve(&tight());

    c.submit("well", well.q.clone(), well.b.clone(), well.h.clone(), 1e-1);
    c.submit("ill", ill.q.clone(), ill.b.clone(), ill.h.clone(), 1e-1);
    c.submit("admm8", vec![0.1; 8], vec![0.0; 2], vec![1.0; 4], 1e-2);
    let mut well_seen = false;
    let mut ill_seen = false;
    let mut admm8_seen = false;
    for _ in 0..3 {
        match c.recv_timeout(Duration::from_secs(60)).expect("reply") {
            Reply::Ok(r) if r.x.len() == 12 => {
                assert_eq!(r.backend, "native", "well layer → Alt-Diff");
                well_seen = true;
            }
            Reply::Ok(r) if r.x.len() == 10 => {
                assert_eq!(r.backend, "native-admm", "ill layer → ADMM");
                assert!(
                    [150, 600, 2400].contains(&r.k_used),
                    "k_used is a ladder rung"
                );
                assert!(
                    max_abs_diff(&r.x, &oracle.x) < 1e-2,
                    "routed ill solve tracks the tight oracle"
                );
                ill_seen = true;
            }
            Reply::Ok(r) => {
                assert_eq!(r.x.len(), 8);
                assert_eq!(r.backend, "native-admm", "ADMM-only layer");
                admm8_seen = true;
            }
            other => panic!("unexpected reply {other:?}"),
        }
    }
    assert!(well_seen && ill_seen && admm8_seen);

    // gradient path routes through the same winner table
    let v10 = pseudo(10, 5);
    let v12 = pseudo(12, 6);
    c.submit_grad(
        "ill",
        ill.q.clone(),
        ill.b.clone(),
        ill.h.clone(),
        v10,
        1e-1,
    );
    c.submit_grad(
        "well",
        well.q.clone(),
        well.b.clone(),
        well.h.clone(),
        v12,
        1e-1,
    );
    for _ in 0..2 {
        match c.recv_timeout(Duration::from_secs(60)).expect("reply") {
            Reply::Grad(g) if g.x.len() == 10 => {
                assert_eq!(g.backend, "native-admm");
                assert_eq!(g.grad_q.len(), 10);
                assert_eq!(g.grad_b.len(), 2);
                assert_eq!(g.grad_h.len(), 5);
            }
            Reply::Grad(g) => {
                assert_eq!(g.x.len(), 12);
                assert_eq!(g.backend, "native");
            }
            other => panic!("unexpected reply {other:?}"),
        }
    }

    let ord = Ordering::Relaxed;
    assert!(c.metrics.router_admm_picks.load(ord) >= 2, "ill picks");
    assert!(
        c.metrics.router_altdiff_picks.load(ord) >= 2,
        "well picks"
    );
    assert!(c.metrics.admm_execs.load(ord) >= 3, "admm launches");
    assert!(c.metrics.admm_iters.load(ord) > 0);
    assert!(c.metrics.altdiff_iters.load(ord) > 0);
}

// -------------------------------------------------------------- net stats

/// The per-engine counters are observable over the wire protocol with
/// no protocol change: solve both families through a loopback server,
/// then read the split back out of the stats op.
#[test]
fn per_engine_counters_round_trip_through_net_stats() {
    let coord = routed_coordinator();
    let server = NetServer::bind("127.0.0.1:0", coord, NetConfig::default())
        .expect("bind");
    let addr = server.local_addr().expect("addr");
    let stop = server.stop_handle();
    let handle = std::thread::spawn(move || server.run());

    let well = dense_qp(12, 6, 3, 9);
    let ill = ill_conditioned_qp(10, 5, 2, 1e4, 7);
    let mut cl = Client::connect(addr).expect("connect");
    cl.set_timeout(Some(Duration::from_secs(60))).unwrap();
    match cl
        .solve("well", well.q.clone(), well.b.clone(), well.h.clone(), 1e-1)
        .expect("well solve")
    {
        Reply::Ok(r) => assert_eq!(r.backend, "native"),
        other => panic!("unexpected reply {other:?}"),
    }
    match cl
        .solve("ill", ill.q.clone(), ill.b.clone(), ill.h.clone(), 1e-1)
        .expect("ill solve")
    {
        Reply::Ok(r) => assert_eq!(r.backend, "native-admm"),
        other => panic!("unexpected reply {other:?}"),
    }

    let stats = cl.stats().expect("stats");
    assert!(counter(&stats, "altdiff_admm_execs_total") >= 1);
    assert!(counter(&stats, "altdiff_admm_elems_total") >= 1);
    assert!(counter(&stats, "altdiff_router_admm_picks_total") >= 1);
    assert!(counter(&stats, "altdiff_router_altdiff_picks_total") >= 1);
    assert!(counter(&stats, "altdiff_admm_iters_total") > 0);
    assert!(counter(&stats, "altdiff_altdiff_iters_total") > 0);
    assert_eq!(counter(&stats, "altdiff_pjrt_execs_total"), 0);

    stop.store(true, Ordering::SeqCst);
    handle.join().expect("server thread");
}
