//! Cross-family properties of the ADMM engine family: parity with the
//! Alt-Diff engines to 1e-8 on solves and adjoints, the fixed-k and
//! warm-start contracts, and the cross-method router dispatching each
//! layer to its calibrated winning family — observable end-to-end
//! through the coordinator metrics and a `net/` stats round trip.

use altdiff::admm::{AdmmQp, AdmmSettings, BatchedAdmm};
use altdiff::altdiff::{BackwardMode, DenseAltDiff, Options, Param};
use altdiff::coordinator::{Config, Coordinator, Reply};
use altdiff::net::{Client, NetConfig, NetServer};
use altdiff::prob::{dense_qp, ill_conditioned_qp, Qp};
use altdiff::warm::WarmStart;
use std::sync::atomic::Ordering;
use std::time::Duration;

fn max_abs_diff(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len(), "length mismatch");
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max)
}

/// Deterministic pseudo-random vector in [-0.5, 0.5) (splitmix-style).
fn pseudo(len: usize, seed: u64) -> Vec<f64> {
    let mut s = seed.wrapping_mul(0x9e3779b97f4a7c15).wrapping_add(1);
    (0..len)
        .map(|_| {
            s = s
                .wrapping_mul(6364136223846793005)
                .wrapping_add(1442695040888963407);
            ((s >> 11) as f64 / (1u64 << 53) as f64) - 0.5
        })
        .collect()
}

fn tight() -> Options {
    Options {
        rho: 1.0,
        tol: 1e-12,
        max_iter: 50_000,
        backward: BackwardMode::None,
        trace: false,
    }
}

// ---------------------------------------------------------------- parity

/// Both families minimize the same strictly convex QP, so the primal,
/// slack, and dual iterates must agree to 1e-8 at tight tolerance.
#[test]
fn admm_matches_dense_altdiff_to_1e8() {
    for (n, m, p, seed) in
        [(8, 4, 2, 1), (12, 6, 3, 2), (15, 7, 4, 3), (10, 5, 2, 31)]
    {
        let qp = dense_qp(n, m, p, seed);
        let alt =
            DenseAltDiff::new(qp.clone(), 1.0).unwrap().solve(&tight());
        let adm = AdmmQp::new(qp, 1.0).unwrap().solve(&tight());
        assert!(
            max_abs_diff(&alt.x, &adm.x) < 1e-8,
            "x parity ({n},{m},{p},{seed}): {}",
            max_abs_diff(&alt.x, &adm.x)
        );
        assert!(max_abs_diff(&alt.s, &adm.s) < 1e-8, "slack parity");
        assert!(max_abs_diff(&alt.lam, &adm.lam) < 1e-7, "λ parity");
        assert!(max_abs_diff(&alt.nu, &adm.nu) < 1e-7, "ν parity");
    }
}

/// A ragged batch (every element a different θ) must reproduce the
/// single-solve answers element-wise, Jacobians included.
#[test]
fn ragged_batch_matches_singles() {
    let qp = dense_qp(10, 5, 2, 31);
    let single = AdmmQp::new(qp.clone(), 1.0).unwrap();
    let batched = BatchedAdmm::from_single(&single);
    let opts = Options {
        rho: 1.0,
        tol: 1e-11,
        max_iter: 50_000,
        backward: BackwardMode::Forward(Param::B),
        trace: false,
    };

    let mut qs = Vec::new();
    let mut bs = Vec::new();
    let mut hs = Vec::new();
    for e in 0..5u64 {
        let dq = pseudo(10, 100 + e);
        let db = pseudo(2, 200 + e);
        let dh = pseudo(5, 300 + e);
        qs.push(
            qp.q.iter().zip(&dq).map(|(v, d)| v + 0.3 * d).collect::<Vec<_>>(),
        );
        bs.push(
            qp.b.iter().zip(&db).map(|(v, d)| v + 0.3 * d).collect::<Vec<_>>(),
        );
        hs.push(
            qp.h.iter().zip(&dh).map(|(v, d)| v + 0.3 * d).collect::<Vec<_>>(),
        );
    }
    let qr: Vec<&[f64]> = qs.iter().map(|v| v.as_slice()).collect();
    let br: Vec<&[f64]> = bs.iter().map(|v| v.as_slice()).collect();
    let hr: Vec<&[f64]> = hs.iter().map(|v| v.as_slice()).collect();

    let sol =
        batched.solve_batch(Some(&qr), Some(&br), Some(&hr), &opts);
    let jacs = sol.jacobians.as_ref().expect("forward mode tracked");
    for e in 0..5 {
        let one = single.solve_with(
            Some(&qs[e]),
            Some(&bs[e]),
            Some(&hs[e]),
            &opts,
        );
        assert!(
            max_abs_diff(&sol.xs[e], &one.x) < 1e-8,
            "element {e} x parity"
        );
        assert!(max_abs_diff(&sol.ss[e], &one.s) < 1e-8);
        let ja = one.jacobian.as_ref().unwrap();
        assert_eq!((jacs[e].rows, jacs[e].cols), (ja.rows, ja.cols));
        assert!(
            max_abs_diff(&jacs[e].data, &ja.data) < 1e-7,
            "element {e} Jacobian parity"
        );
        // batched and single truncation may differ by the one iteration
        // the GEMM-vs-triangular-solve rounding moves
        assert!(sol.iters[e].abs_diff(one.iters) <= 1);
    }
}

// ------------------------------------------------------------- contracts

/// tol = 0 + max_iter = k is the compiled-artifact contract: exactly k
/// iterations, no early exit, single and batched in lockstep.
#[test]
fn fixed_k_runs_exactly_k_iterations() {
    let qp = dense_qp(9, 4, 2, 11);
    let single = AdmmQp::new(qp.clone(), 1.0).unwrap();
    let batched = BatchedAdmm::from_single(&single);
    for k in [1, 7, 23] {
        let opts = Options {
            rho: 1.0,
            tol: 0.0,
            max_iter: k,
            backward: BackwardMode::None,
            trace: false,
        };
        let one = single.solve(&opts);
        assert_eq!(one.iters, k, "single ran exactly k");
        let sol = batched.solve_batch(None, None, None, &opts);
        assert_eq!(sol.iters, vec![k], "batched ran exactly k");
        assert!(
            max_abs_diff(&sol.xs[0], &one.x) < 1e-10,
            "fixed-k lockstep at k={k}"
        );
    }
}

/// Warm contract: `warm = None` is bit-identical to the cold solve, a
/// converged triple reproduces itself almost immediately, and a batch
/// may mix warm and cold members without cross-talk.
#[test]
fn warm_equals_cold_and_mixed_batches_are_isolated() {
    let qp = dense_qp(10, 5, 2, 13);
    let single = AdmmQp::new(qp.clone(), 1.0).unwrap();
    let batched = BatchedAdmm::from_single(&single);
    let opts = Options {
        rho: 1.0,
        tol: 1e-10,
        max_iter: 50_000,
        backward: BackwardMode::None,
        trace: false,
    };

    let cold = single.solve_with(None, None, None, &opts);
    let resumed = single.solve_from(None, None, None, None, &opts);
    assert_eq!(cold.x, resumed.x, "warm=None is bit-identical");
    assert_eq!(cold.iters, resumed.iters);

    let ws = WarmStart::of(&cold);
    let warm =
        single.solve_from(None, None, None, Some(&ws), &opts);
    assert!(
        warm.iters < cold.iters,
        "fixed-point resume must truncate early ({} vs {})",
        warm.iters,
        cold.iters
    );
    assert!(warm.iters <= 2, "fixed point reproduces itself");
    assert!(max_abs_diff(&warm.x, &cold.x) < 1e-9);

    // mixed batch: element 0 resumes the fixed point, element 1 is cold
    let warms = vec![Some(ws), None];
    let sol =
        batched.solve_batch_from(None, None, None, Some(&warms), &opts);
    assert!(sol.iters[0] <= 2, "warm element truncates early");
    assert!(
        sol.iters[1] > sol.iters[0],
        "cold element is undisturbed by its warm neighbour"
    );
    assert!(max_abs_diff(&sol.xs[0], &cold.x) < 1e-8);
    assert!(max_abs_diff(&sol.xs[1], &cold.x) < 1e-8);
}

// -------------------------------------------------------------- adjoints

/// The ADMM adjoint VJP must agree with (a) the Alt-Diff adjoint on the
/// same problem to 1e-8 and (b) central finite differences of
/// L(θ) = vᵀx*(θ) for every parameter.
#[test]
fn vjp_matches_altdiff_adjoint_and_finite_differences() {
    let qp = dense_qp(9, 4, 2, 17);
    let v = pseudo(9, 999);
    let opts = Options {
        rho: 1.0,
        tol: 1e-12,
        max_iter: 50_000,
        backward: BackwardMode::Adjoint,
        trace: false,
    };

    let alt = DenseAltDiff::new(qp.clone(), 1.0).unwrap();
    let adm = AdmmQp::new(qp.clone(), 1.0).unwrap();
    let av = alt.solve_vjp(None, None, None, &v, &opts);
    let dv = adm.solve_vjp(None, None, None, &v, &opts);
    assert!(
        max_abs_diff(&av.vjp.grad_q, &dv.vjp.grad_q) < 1e-8,
        "grad_q family parity"
    );
    assert!(max_abs_diff(&av.vjp.grad_b, &dv.vjp.grad_b) < 1e-8);
    assert!(max_abs_diff(&av.vjp.grad_h, &dv.vjp.grad_h) < 1e-8);

    // central differences along one random direction per parameter
    let eps = 1e-6;
    let loss = |qp: &Qp, q: &[f64], b: &[f64], h: &[f64]| -> f64 {
        let s = AdmmQp::new(qp.clone(), 1.0).unwrap().solve_with(
            Some(q),
            Some(b),
            Some(h),
            &tight(),
        );
        s.x.iter().zip(&v).map(|(x, vv)| x * vv).sum()
    };
    let dirs = [
        (pseudo(9, 41), Param::Q),
        (pseudo(2, 42), Param::B),
        (pseudo(5, 43), Param::H),
    ];
    for (dir, param) in &dirs {
        let perturb = |sign: f64| {
            let mut q = qp.q.clone();
            let mut b = qp.b.clone();
            let mut h = qp.h.clone();
            let target: &mut Vec<f64> = match param {
                Param::Q => &mut q,
                Param::B => &mut b,
                Param::H => &mut h,
            };
            for (t, d) in target.iter_mut().zip(dir) {
                *t += sign * eps * d;
            }
            loss(&qp, &q, &b, &h)
        };
        let fd = (perturb(1.0) - perturb(-1.0)) / (2.0 * eps);
        let analytic: f64 = dv
            .vjp
            .grad(*param)
            .iter()
            .zip(dir)
            .map(|(g, d)| g * d)
            .sum();
        assert!(
            (fd - analytic).abs() < 1e-4 * analytic.abs().max(1.0),
            "{param:?}: fd {fd} vs analytic {analytic}"
        );
    }
}

/// Batched adjoints reproduce the single VJPs, and a harvested adjoint
/// seed resumes the transposed recursion with fewer iterations.
#[test]
fn batch_vjp_matches_singles_and_seeds_truncate_early() {
    let qp = dense_qp(10, 5, 2, 23);
    let single = AdmmQp::new(qp.clone(), 1.0).unwrap();
    let batched = BatchedAdmm::from_single(&single);
    let bopts = Options {
        rho: 1.0,
        tol: 1e-11,
        max_iter: 50_000,
        backward: BackwardMode::Adjoint,
        trace: false,
    };

    let fwd = single.solve_with(None, None, None, &tight());
    let vs: Vec<Vec<f64>> =
        (0..3).map(|e| pseudo(10, 700 + e)).collect();
    let vrefs: Vec<&[f64]> = vs.iter().map(|v| v.as_slice()).collect();
    let slacks: Vec<&[f64]> = (0..3).map(|_| fwd.s.as_slice()).collect();

    let bv = batched.batch_vjp(&slacks, &vrefs, &bopts);
    for e in 0..3 {
        let one = single.vjp(&fwd.s, &vs[e], &bopts);
        assert!(max_abs_diff(&bv.grads_q[e], &one.grad_q) < 1e-8);
        assert!(max_abs_diff(&bv.grads_b[e], &one.grad_b) < 1e-8);
        assert!(max_abs_diff(&bv.grads_h[e], &one.grad_h) < 1e-8);
    }

    // seed round trip: the converged adjoint state reproduces itself
    let (cold, seed) = single.vjp_from(&fwd.s, &vs[0], None, &bopts);
    let (warm, _) =
        single.vjp_from(&fwd.s, &vs[0], Some(&seed), &bopts);
    assert!(
        warm.iters < cold.iters,
        "seeded adjoint truncates early ({} vs {})",
        warm.iters,
        cold.iters
    );
    assert!(max_abs_diff(&warm.grad_q, &cold.grad_q) < 1e-8);
    assert!(max_abs_diff(&warm.grad_h, &cold.grad_h) < 1e-8);
}

// ---------------------------------------------------------------- router

/// Coordinator whose router has a real choice to make: a well-behaved
/// layer (both families converge → tie → Alt-Diff) and an
/// ill-conditioned one (fixed-ρ Alt-Diff stalls, ρ-balanced ADMM
/// converges → ADMM), plus an ADMM-only layer. The ladder starts high
/// enough that both families clear the first rung on the easy layer.
fn routed_coordinator() -> Coordinator {
    Coordinator::builder(Config {
        workers: 2,
        max_batch: 4,
        batch_timeout_us: 1_000,
        artifacts: None,
        ..Default::default()
    })
    .ladder(vec![150, 600, 2400])
    .register_routed("well", dense_qp(12, 6, 3, 9), 1.0)
    .unwrap()
    .register_routed("ill", ill_conditioned_qp(10, 5, 2, 1e4, 7), 1.0)
    .unwrap()
    .register_admm("admm8", dense_qp(8, 4, 2, 5), 1.0)
    .unwrap()
    .start()
}

/// The cross-method router sends each layer to its calibrated winning
/// family — solve and gradient paths both — and the per-engine metrics
/// record the split.
#[test]
fn router_dispatches_each_layer_to_its_winning_family() {
    let mut c = routed_coordinator();
    let well = dense_qp(12, 6, 3, 9);
    let ill = ill_conditioned_qp(10, 5, 2, 1e4, 7);

    // oracle for the ill layer: a tight ρ-balanced ADMM solve
    let oracle =
        AdmmQp::new_adapted(ill.clone(), 1.0, AdmmSettings::default())
            .unwrap()
            .solve(&tight());

    c.submit("well", well.q.clone(), well.b.clone(), well.h.clone(), 1e-1);
    c.submit("ill", ill.q.clone(), ill.b.clone(), ill.h.clone(), 1e-1);
    c.submit("admm8", vec![0.1; 8], vec![0.0; 2], vec![1.0; 4], 1e-2);
    let mut well_seen = false;
    let mut ill_seen = false;
    let mut admm8_seen = false;
    for _ in 0..3 {
        match c.recv_timeout(Duration::from_secs(60)).expect("reply") {
            Reply::Ok(r) if r.x.len() == 12 => {
                assert_eq!(r.backend, "native", "well layer → Alt-Diff");
                well_seen = true;
            }
            Reply::Ok(r) if r.x.len() == 10 => {
                assert_eq!(r.backend, "native-admm", "ill layer → ADMM");
                assert!(
                    [150, 600, 2400].contains(&r.k_used),
                    "k_used is a ladder rung"
                );
                assert!(
                    max_abs_diff(&r.x, &oracle.x) < 1e-2,
                    "routed ill solve tracks the tight oracle"
                );
                ill_seen = true;
            }
            Reply::Ok(r) => {
                assert_eq!(r.x.len(), 8);
                assert_eq!(r.backend, "native-admm", "ADMM-only layer");
                admm8_seen = true;
            }
            other => panic!("unexpected reply {other:?}"),
        }
    }
    assert!(well_seen && ill_seen && admm8_seen);

    // gradient path routes through the same winner table
    let v10 = pseudo(10, 5);
    let v12 = pseudo(12, 6);
    c.submit_grad(
        "ill",
        ill.q.clone(),
        ill.b.clone(),
        ill.h.clone(),
        v10,
        1e-1,
    );
    c.submit_grad(
        "well",
        well.q.clone(),
        well.b.clone(),
        well.h.clone(),
        v12,
        1e-1,
    );
    for _ in 0..2 {
        match c.recv_timeout(Duration::from_secs(60)).expect("reply") {
            Reply::Grad(g) if g.x.len() == 10 => {
                assert_eq!(g.backend, "native-admm");
                assert_eq!(g.grad_q.len(), 10);
                assert_eq!(g.grad_b.len(), 2);
                assert_eq!(g.grad_h.len(), 5);
            }
            Reply::Grad(g) => {
                assert_eq!(g.x.len(), 12);
                assert_eq!(g.backend, "native");
            }
            other => panic!("unexpected reply {other:?}"),
        }
    }

    let ord = Ordering::Relaxed;
    assert!(c.metrics.router_admm_picks.load(ord) >= 2, "ill picks");
    assert!(
        c.metrics.router_altdiff_picks.load(ord) >= 2,
        "well picks"
    );
    assert!(c.metrics.admm_execs.load(ord) >= 3, "admm launches");
    assert!(c.metrics.admm_iters.load(ord) > 0);
    assert!(c.metrics.altdiff_iters.load(ord) > 0);
}

// -------------------------------------------------------------- net stats

/// Extract a Prometheus counter value from the stats text.
fn counter(stats: &str, name: &str) -> u64 {
    let prefix = format!("{name} ");
    stats
        .lines()
        .find_map(|l| l.strip_prefix(prefix.as_str()))
        .unwrap_or_else(|| panic!("counter {name} missing"))
        .trim()
        .parse()
        .expect("counter value")
}

/// The per-engine counters are observable over the wire protocol with
/// no protocol change: solve both families through a loopback server,
/// then read the split back out of the stats op.
#[test]
fn per_engine_counters_round_trip_through_net_stats() {
    let coord = routed_coordinator();
    let server = NetServer::bind("127.0.0.1:0", coord, NetConfig::default())
        .expect("bind");
    let addr = server.local_addr().expect("addr");
    let stop = server.stop_handle();
    let handle = std::thread::spawn(move || server.run());

    let well = dense_qp(12, 6, 3, 9);
    let ill = ill_conditioned_qp(10, 5, 2, 1e4, 7);
    let mut cl = Client::connect(addr).expect("connect");
    cl.set_timeout(Some(Duration::from_secs(60))).unwrap();
    match cl
        .solve("well", well.q.clone(), well.b.clone(), well.h.clone(), 1e-1)
        .expect("well solve")
    {
        Reply::Ok(r) => assert_eq!(r.backend, "native"),
        other => panic!("unexpected reply {other:?}"),
    }
    match cl
        .solve("ill", ill.q.clone(), ill.b.clone(), ill.h.clone(), 1e-1)
        .expect("ill solve")
    {
        Reply::Ok(r) => assert_eq!(r.backend, "native-admm"),
        other => panic!("unexpected reply {other:?}"),
    }

    let stats = cl.stats().expect("stats");
    assert!(counter(&stats, "altdiff_admm_execs_total") >= 1);
    assert!(counter(&stats, "altdiff_admm_elems_total") >= 1);
    assert!(counter(&stats, "altdiff_router_admm_picks_total") >= 1);
    assert!(counter(&stats, "altdiff_router_altdiff_picks_total") >= 1);
    assert!(counter(&stats, "altdiff_admm_iters_total") > 0);
    assert!(counter(&stats, "altdiff_altdiff_iters_total") > 0);
    assert_eq!(counter(&stats, "altdiff_pjrt_execs_total"), 0);

    stop.store(true, Ordering::SeqCst);
    handle.join().expect("server thread");
}
