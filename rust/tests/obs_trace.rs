//! Observability-plane integration suite: stage-latency decomposition
//! and sampled solver convergence traces, end to end.
//!
//! Contracts under test:
//!
//! - **monotone stamps + reconciliation**: every traced request's
//!   stamps are monotone, the per-stage spans sum exactly to the
//!   stamped end-to-end width, and the client-observed latency is
//!   never smaller than the server-side stage sum (1 ms slack);
//! - **convergence traces**: a sampled solve at fixed k records one
//!   residual pair per iteration, with decreasing primal/dual
//!   residuals — the raw material for Thm 4.3 truncation tuning;
//! - **observer transparency**: observing a solve never changes its
//!   iterates (bit-identical solutions with and without a collector);
//! - **`GET /trace`**: the ring drains as well-formed JSON-lines over
//!   the sniffed HTTP path while solve traffic is in flight;
//! - **off means off**: with the tracing plane disabled (the default)
//!   stamps stay zeroed, replies carry no stage echo even when the
//!   client asks, the stage histograms never move, and `/trace` is
//!   empty.

use altdiff::altdiff::{BackwardMode, DenseAltDiff, Options};
use altdiff::coordinator::{Config, Coordinator, Reply};
use altdiff::net::{
    run_loadgen, LoadgenOpts, NetConfig, NetServer, PipelinedClient,
};
use altdiff::obs::{
    sum_spans_us, IterObserver, IterSample, Stage, TraceCollector,
    N_SPANS,
};
use altdiff::prob::dense_qp;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// In-process coordinator with the tracing plane fully on.
fn traced_coordinator(trace_every: u64) -> Coordinator {
    Coordinator::builder(Config {
        workers: 2,
        max_batch: 4,
        stamps: true,
        trace_every,
        trace_ring: 256,
        trace_seed: 7,
        ..Default::default()
    })
    .register("qp16", dense_qp(16, 8, 4, 1), 1.0)
    .unwrap()
    .start()
}

struct Loopback {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: std::thread::JoinHandle<Coordinator>,
}

fn start_server(config: Config) -> Loopback {
    let coord = Coordinator::builder(config)
        .register("qp16", dense_qp(16, 8, 4, 1), 1.0)
        .unwrap()
        .start();
    let server =
        NetServer::bind("127.0.0.1:0", coord, NetConfig::default())
            .expect("bind");
    let addr = server.local_addr().expect("addr");
    let stop = server.stop_handle();
    let handle = std::thread::spawn(move || server.run());
    Loopback { addr, stop, handle }
}

impl Loopback {
    fn finish(self) -> Coordinator {
        self.stop.store(true, Ordering::SeqCst);
        self.handle.join().expect("server thread")
    }
}

/// Minimal HTTP/1.0 GET against the serving port; returns
/// (status line, body).
fn http_get(addr: SocketAddr, path: &str) -> (String, String) {
    let mut s = TcpStream::connect(addr).expect("http connect");
    s.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    s.write_all(format!("GET {path} HTTP/1.0\r\n\r\n").as_bytes())
        .unwrap();
    let mut raw = String::new();
    s.read_to_string(&mut raw).expect("http response");
    let (head, body) =
        raw.split_once("\r\n\r\n").expect("header terminator");
    (head.lines().next().unwrap_or("").to_string(), body.to_string())
}

/// Structural JSON-lines check (the CI smoke runs a real JSON parser;
/// this guards the invariants the renderer owns).
fn assert_trace_line_shape(line: &str) {
    assert!(line.starts_with('{') && line.ends_with('}'), "{line}");
    for key in ["\"id\":", "\"layer\":", "\"class\":", "\"iters\":"] {
        assert!(line.contains(key), "missing {key} in {line}");
    }
    assert_eq!(
        line.bytes().filter(|&b| b == b'"').count() % 2,
        0,
        "unbalanced quotes: {line}"
    );
}

// ------------------------------------------------- stage decomposition

#[test]
fn stamps_are_monotone_and_spans_reconcile_in_process() {
    let mut coord = traced_coordinator(0);
    coord.wait_ready(Duration::from_secs(60));
    let qp = dense_qp(16, 8, 4, 1);
    let t0 = Instant::now();
    let n = 24;
    for _ in 0..n {
        coord.submit(
            "qp16",
            qp.q.clone(),
            qp.b.clone(),
            qp.h.clone(),
            1e-3,
        );
    }
    for _ in 0..n {
        let reply = coord
            .recv_timeout(Duration::from_secs(60))
            .expect("reply");
        let stamps = match &reply {
            Reply::Ok(r) => r.stamps,
            other => panic!("expected Ok, got {other:?}"),
        };
        assert!(stamps.is_on(), "tracing plane is on");
        assert!(stamps.monotone(), "stamps out of order: {stamps:?}");
        // in-process requests stamp enqueued → batch-formed →
        // exec-start → exec-end; the adjacent spans must sum exactly
        // to the stamped end-to-end width
        for st in [Stage::Enqueued, Stage::ExecEnd] {
            assert!(stamps.get(st).is_some(), "{st:?} missing");
        }
        let spans = stamps.spans_us();
        assert_eq!(
            sum_spans_us(&spans),
            stamps.total_us(),
            "span sum ≠ stamped total: {spans:?}"
        );
        // the stamped server-side total can never exceed the
        // client-observed wall clock for the whole run (1 ms slack
        // for the µs-quantization at each stamp site)
        let wall_us = t0.elapsed().as_micros() as u64;
        assert!(
            stamps.total_us() <= wall_us + 1_000,
            "server stages {}µs exceed wall {}µs",
            stamps.total_us(),
            wall_us
        );
    }
    coord.shutdown();
}

// ------------------------------------------------- convergence traces

#[test]
fn observed_fixed_k_solve_records_decreasing_residuals() {
    let k = 60;
    let eng = DenseAltDiff::new(dense_qp(16, 8, 4, 1), 1.0).unwrap();
    let opts = Options {
        rho: 1.0,
        tol: 0.0, // fixed-k: run exactly max_iter iterations
        max_iter: k,
        backward: BackwardMode::None,
        trace: false,
    };
    let mut coll = TraceCollector::new(1);
    coll.watch(0);
    let sol = eng.solve_observed(
        None,
        None,
        None,
        None,
        &opts,
        Some(&mut coll as &mut dyn IterObserver),
    );
    assert_eq!(sol.iters, k);
    let iters: Vec<IterSample> = coll.take(0).expect("watched");
    assert_eq!(iters.len(), k, "one sample per iteration");
    for (i, s) in iters.iter().enumerate() {
        assert_eq!(s.iter as usize, i, "iteration indices in order");
        assert!(s.primal.is_finite() && s.primal >= 0.0);
        assert!(s.dual.is_finite() && s.dual >= 0.0);
    }
    // Alt-Diff converges linearly on a strongly convex QP (Thm 4.2):
    // the residual trace must fall, both endpoint-to-endpoint and in
    // window averages (jitter-tolerant monotonicity)
    let head = |v: &[IterSample], f: fn(&IterSample) -> f64| {
        v[..10].iter().map(f).sum::<f64>() / 10.0
    };
    let tail = |v: &[IterSample], f: fn(&IterSample) -> f64| {
        v[k - 10..].iter().map(f).sum::<f64>() / 10.0
    };
    let (p0, pk) =
        (head(&iters, |s| s.primal), tail(&iters, |s| s.primal));
    let (d0, dk) = (head(&iters, |s| s.dual), tail(&iters, |s| s.dual));
    assert!(pk < p0 * 0.5, "primal did not fall: {p0:.3e} → {pk:.3e}");
    assert!(dk < d0 * 0.5, "dual did not fall: {d0:.3e} → {dk:.3e}");
    assert!(
        iters[k - 1].dual <= iters[0].dual,
        "dual endpoint rose over the trace"
    );
}

#[test]
fn observer_never_perturbs_the_solve() {
    let eng = DenseAltDiff::new(dense_qp(16, 8, 4, 3), 1.0).unwrap();
    let opts = Options {
        backward: BackwardMode::None,
        ..Options::with_tol(1e-6)
    };
    let plain = eng.solve_from(None, None, None, None, &opts);
    let mut coll = TraceCollector::new(1);
    coll.watch(0);
    let observed = eng.solve_observed(
        None,
        None,
        None,
        None,
        &opts,
        Some(&mut coll as &mut dyn IterObserver),
    );
    // bit-identical, not approximately equal: the observer reads the
    // iterate, it never feeds back into it
    assert_eq!(plain.x, observed.x);
    assert_eq!(plain.iters, observed.iters);
    assert!(!coll.take(0).expect("watched").is_empty());
}

#[test]
fn sampled_requests_reach_the_ring_with_iteration_traces() {
    let mut coord = traced_coordinator(1); // sample every request
    coord.wait_ready(Duration::from_secs(60));
    let qp = dense_qp(16, 8, 4, 1);
    let n = 12;
    for _ in 0..n {
        coord.submit(
            "qp16",
            qp.q.clone(),
            qp.b.clone(),
            qp.h.clone(),
            1e-3,
        );
    }
    for _ in 0..n {
        coord.recv_timeout(Duration::from_secs(60)).expect("reply");
    }
    let events = coord.trace_ring().drain();
    assert_eq!(events.len(), n, "1-in-1 sampling traces every request");
    for ev in &events {
        assert_eq!(ev.layer, "qp16");
        assert_eq!(ev.class, "normal");
        assert!(!ev.grad);
        assert!(ev.stamps.is_on() && ev.stamps.monotone());
        assert!(!ev.iters.is_empty(), "native path records iterations");
        assert!(ev.iters.len() <= ev.k.max(1));
        for w in ev.iters.windows(2) {
            assert!(w[1].iter > w[0].iter, "iteration order");
        }
        let line = ev.render_jsonl();
        assert_trace_line_shape(&line);
    }
    // drained means drained
    assert!(coord.trace_ring().drain().is_empty());
    coord.shutdown();
}

// ----------------------------------------------------- /trace endpoint

#[test]
fn trace_endpoint_streams_jsonl_under_concurrent_load() {
    let lb = start_server(Config {
        workers: 2,
        max_batch: 4,
        stamps: true,
        trace_every: 1,
        trace_ring: 512,
        ..Default::default()
    });
    let addr = lb.addr;
    let done = Arc::new(AtomicBool::new(false));
    // concurrent scraper: drains /trace while the loadgen hammers the
    // same port with solve traffic
    let scraper = {
        let done = done.clone();
        std::thread::spawn(move || {
            let mut lines = 0usize;
            while !done.load(Ordering::SeqCst) {
                let (status, body) = http_get(addr, "/trace");
                assert!(status.contains("200"), "{status}");
                for line in body.lines().filter(|l| !l.is_empty()) {
                    assert_trace_line_shape(line);
                    lines += 1;
                }
                std::thread::sleep(Duration::from_millis(5));
            }
            lines
        })
    };
    let report = run_loadgen(
        addr,
        &LoadgenOpts {
            requests: 90,
            clients: 3,
            window: 8,
            grad_share: 0.2,
            stages: true,
            ..Default::default()
        },
    )
    .expect("loadgen");
    done.store(true, Ordering::SeqCst);
    let mid_run_lines = scraper.join().expect("scraper");
    assert_eq!(report.ok + report.grads, 90, "all requests served");
    // every served reply echoed its stage breakdown...
    assert_eq!(report.stage_count, 90);
    // ...and the reconciliation holds in aggregate: the client-side
    // round trips can only exceed the server-side stage sums (1 ms
    // slack per reply for stamp quantization)
    let server_us: f64 = report.stage_sum_us.iter().sum();
    assert!(
        report.stage_rtt_sum_us + 1_000.0 * report.stage_count as f64
            >= server_us,
        "client rtt sum {:.0}µs < server stage sum {server_us:.0}µs",
        report.stage_rtt_sum_us
    );
    let table = report.render_stages();
    assert!(table.contains("stage attribution"), "{table}");
    assert!(table.contains("Σ server"), "{table}");
    // the final scrape picks up whatever the mid-run scrapes missed
    let (_, body) = http_get(addr, "/trace");
    let final_lines =
        body.lines().filter(|l| !l.is_empty()).count();
    for line in body.lines().filter(|l| !l.is_empty()) {
        assert_trace_line_shape(line);
    }
    assert!(
        mid_run_lines + final_lines > 0,
        "no trace events surfaced over /trace"
    );
    lb.finish();
}

// ------------------------------------------------- FW duality-gap traces

/// A Frank–Wolfe-registered layer rides the same tracing plane: sampled
/// requests reach the ring tagged `native-fw`, their iteration samples
/// carry the duality gap in the primal slot (FW's convergence
/// certificate — see the `fw` module docs) and it falls over the routed
/// fixed-k run, and the same events stream over `GET /trace`.
#[test]
fn fw_layer_traces_carry_decreasing_gap_over_trace_endpoint() {
    use altdiff::prob::simplex_qp;
    let qp = simplex_qp(16, 1.0, 3);
    let coord = Coordinator::builder(Config {
        workers: 2,
        max_batch: 4,
        stamps: true,
        trace_every: 1,
        trace_ring: 256,
        trace_seed: 7,
        ..Default::default()
    })
    .register_fw("simplex16", qp.clone(), 1.0)
    .unwrap()
    .start();
    let server =
        NetServer::bind("127.0.0.1:0", coord, NetConfig::default())
            .expect("bind");
    let addr = server.local_addr().expect("addr");
    let stop = server.stop_handle();
    let handle = std::thread::spawn(move || server.run());

    let mut cl =
        altdiff::net::Client::connect(addr).expect("connect");
    cl.set_timeout(Some(Duration::from_secs(60))).unwrap();
    for _ in 0..6 {
        match cl
            .solve(
                "simplex16",
                qp.q.clone(),
                qp.b.clone(),
                qp.h.clone(),
                1e-3,
            )
            .expect("solve")
        {
            Reply::Ok(r) => assert_eq!(r.backend, "native-fw"),
            other => panic!("unexpected reply {other:?}"),
        }
    }
    // the first six drain over the HTTP path as tagged JSON-lines
    let (status, body) = http_get(addr, "/trace");
    assert!(status.contains("200"), "{status}");
    let lines: Vec<&str> =
        body.lines().filter(|l| !l.is_empty()).collect();
    assert!(!lines.is_empty(), "no FW trace events over /trace");
    for line in &lines {
        assert_trace_line_shape(line);
        assert!(line.contains("\"simplex16\""), "{line}");
        assert!(line.contains("\"native-fw\""), "{line}");
    }
    // the next six stay in the ring for the typed-event checks
    for _ in 0..6 {
        cl.solve(
            "simplex16",
            qp.q.clone(),
            qp.b.clone(),
            qp.h.clone(),
            1e-3,
        )
        .expect("solve");
    }
    drop(cl);
    stop.store(true, Ordering::SeqCst);
    let coord = handle.join().expect("server thread");
    let events = coord.trace_ring().drain();
    assert!(!events.is_empty(), "second batch left no typed events");
    for ev in &events {
        assert_eq!(ev.layer, "simplex16");
        assert_eq!(ev.backend, "native-fw");
        assert!(!ev.iters.is_empty(), "FW path records iterations");
        // primal slot = duality gap gₖ = ∇f(xₖ)ᵀ(xₖ − vₖ): nonnegative
        // (float slack only) and falling endpoint to endpoint
        for s in &ev.iters {
            assert!(s.primal.is_finite() && s.primal >= -1e-10);
            assert!(s.dual.is_finite() && s.dual >= 0.0);
        }
        let first = ev.iters.first().unwrap().primal;
        let last = ev.iters.last().unwrap().primal;
        assert!(
            last < first,
            "duality gap did not fall: {first:.3e} → {last:.3e}"
        );
    }
}

// ----------------------------------------------------------- off = off

#[test]
fn disabled_tracing_is_inert_end_to_end() {
    // default config: stamps off, sampler off, ring empty
    let lb = start_server(Config {
        workers: 2,
        max_batch: 4,
        ..Default::default()
    });
    let mut cl = PipelinedClient::connect(lb.addr, 4).expect("connect");
    cl.set_timeout(Some(Duration::from_secs(60))).unwrap();
    // the client may *ask* for the echo; a stamps-off server answers
    // without the block, exactly like a pre-echo server would
    cl.set_echo_stages(true);
    let qp = dense_qp(16, 8, 4, 1);
    let mut replies = Vec::new();
    for _ in 0..8 {
        replies.extend(
            cl.submit(
                "qp16",
                qp.q.clone(),
                qp.b.clone(),
                qp.h.clone(),
                None,
                1e-3,
            )
            .expect("submit"),
        );
    }
    replies.extend(cl.drain().expect("drain"));
    assert_eq!(replies.len(), 8);
    for t in &replies {
        assert!(
            matches!(t.reply, Reply::Ok(_)),
            "expected Ok, got {:?}",
            t.reply
        );
        assert!(t.reply.stages().is_none(), "echo on a stamps-off server");
        let stamps = t.reply.stamps().expect("served reply");
        assert!(!stamps.is_on(), "stamps moved while disabled");
        assert_eq!(stamps.total_us(), 0);
    }
    // the stage histograms never moved...
    let (_, metrics) = http_get(lb.addr, "/metrics");
    assert!(metrics.contains("altdiff_stage_latency_us"));
    for class in ["high", "normal", "low"] {
        for stage in ["decode", "queue", "exec", "write"] {
            let needle = format!(
                "altdiff_stage_latency_us_count{{class=\"{class}\",\
                 stage=\"{stage}\"}} 0"
            );
            assert!(metrics.contains(&needle), "missing `{needle}`");
        }
    }
    // ...and the trace ring has nothing to say
    let (status, body) = http_get(lb.addr, "/trace");
    assert!(status.contains("200"), "{status}");
    assert!(body.is_empty(), "events on a tracing-off server: {body}");
    let coord = lb.finish();
    assert_eq!(coord.trace_ring().len(), 0);
    assert_eq!(coord.trace_ring().dropped(), 0);
    // the spans type stayed fixed-width (wire contract: 6 × u32)
    assert_eq!(N_SPANS, 6);
}
