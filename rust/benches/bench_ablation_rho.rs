//! Ablation (ours): sensitivity of Alt-Diff to the ADMM penalty ρ.
//!
//! DESIGN.md calls out ρ as the one free hyperparameter the paper fixes at
//! 1.0. We sweep it and report iterations-to-tolerance and gradient
//! fidelity — the practical answer to "does serving need per-layer ρ
//! tuning?" (moderate ρ ∈ [0.5, 2] is flat; extreme ρ slows convergence).

use altdiff::altdiff::{BackwardMode, DenseAltDiff, Options, Param};
use altdiff::baselines;
use altdiff::linalg::cosine;
use altdiff::prob::dense_qp;
use altdiff::util::{Args, Table};
use std::time::Instant;

fn main() {
    let args = Args::parse();
    let n = args.get_usize("n", 150);
    let qp = dense_qp(n, n / 2, n / 5, 2);
    let (_, jkkt, _) =
        baselines::optnet_layer(&qp, Param::B, 1e-12).unwrap();

    let mut t = Table::new(
        &format!("Ablation — ADMM penalty ρ (n={n}, tol=1e-4)"),
        &["rho", "iters", "time(s)", "cosine vs KKT"],
    );
    for rho in [0.05, 0.2, 0.5, 1.0, 2.0, 5.0, 20.0] {
        let solver = DenseAltDiff::new(qp.clone(), rho).unwrap();
        let t0 = Instant::now();
        let sol = solver.solve(&Options {
            tol: 1e-4,
            max_iter: 50_000,
            backward: BackwardMode::Forward(Param::B),
            rho,
            trace: false,
        });
        let dt = t0.elapsed().as_secs_f64();
        let cos = cosine(&sol.jacobian.unwrap().data, &jkkt.data);
        t.row(&[
            format!("{rho}"),
            sol.iters.to_string(),
            format!("{dt:.4}"),
            format!("{cos:.6}"),
        ]);
    }
    t.print();
    t.write_csv("ablation_rho").unwrap();
    println!("\ntakeaway: gradients stay KKT-consistent for every ρ (Thm 4.2 \
              is ρ-independent); iteration count is the only tuning axis.");
}
