//! Batched vs sequential native Alt-Diff throughput (ours): the tentpole
//! claim of the `batch` subsystem — solving B instances of one registered
//! layer as a single batch-major launch beats B sequential
//! `DenseAltDiff::solve_with` calls, because every per-instance gemv and
//! d-column gemm becomes one GEMM with B-fold more columns (plus the
//! parallel row-split kernels engage).
//!
//! Grid: B ∈ {1, 8, 32, 128} × n ∈ {50, 200, 500} (m = n/2, p = n/5),
//! fixed-k forward+Jacobian (∂x/∂b) runs, the serving configuration.
//! Every cell also cross-checks max |x_batched − x_sequential|.
//!
//! Run: cargo bench --bench bench_batched_native [-- --quick|--smoke]
//!      [--sizes 50,200] [--batches 1,8,32] [--k 10]
//!
//! `--smoke` runs a tiny CI-sized grid (seconds) and skips the
//! repo-root baseline write; full runs refresh `BENCH_batched_native.json`
//! at the repository root (the committed perf trajectory).

use altdiff::altdiff::{BackwardMode, DenseAltDiff, Options, Param};
use altdiff::batch::BatchedAltDiff;
use altdiff::prob::dense_qp;
use altdiff::util::{Args, JsonReport, Pcg64, Stats, Table};
use std::time::Instant;

fn main() {
    let args = Args::parse();
    let smoke = args.has("smoke");
    let quick = args.has("quick");
    let default_sizes: &[usize] = if smoke {
        &[24]
    } else if quick {
        &[50, 200]
    } else {
        &[50, 200, 500]
    };
    let default_batches: &[usize] = if smoke {
        &[1, 4]
    } else if quick {
        &[1, 8, 32]
    } else {
        &[1, 8, 32, 128]
    };
    let sizes = args.get_usize_list("sizes", default_sizes);
    let batches = args.get_usize_list("batches", default_batches);
    let k = args.get_usize("k", 10);

    let mut t = Table::new(
        &format!(
            "Batched native engine — one launch vs B sequential solves \
             (k={k}, ∂x/∂b)"
        ),
        &[
            "n",
            "B",
            "seq (s)",
            "batched (s)",
            "seq inst/s",
            "batched inst/s",
            "speedup",
            "max|Δx|",
        ],
    );

    let mut json = JsonReport::new("batched_native");
    let mut b32_n200_speedup = None;
    for &n in &sizes {
        let (m, p) = (n / 2, n / 5);
        let qp = dense_qp(n, m, p, 42 + n as u64);
        let dense = DenseAltDiff::new(qp.clone(), 1.0).unwrap();
        let batched = BatchedAltDiff::from_dense(&dense);
        let opts = Options {
            tol: 0.0, // serving semantics: exactly k iterations
            max_iter: k,
            backward: BackwardMode::Forward(Param::B),
            ..Default::default()
        };
        for &bsz in &batches {
            // perturbed θ per instance (same structure, different rhs)
            let mut rng = Pcg64::new(7 + bsz as u64);
            let qs: Vec<Vec<f64>> = (0..bsz)
                .map(|_| {
                    qp.q.iter()
                        .map(|&v| v * (1.0 + 0.1 * rng.normal()))
                        .collect()
                })
                .collect();
            let bs: Vec<Vec<f64>> = (0..bsz)
                .map(|_| {
                    qp.b.iter().map(|&v| v + 0.05 * rng.normal()).collect()
                })
                .collect();
            let hs: Vec<Vec<f64>> = (0..bsz)
                .map(|_| {
                    qp.h.iter()
                        .map(|&v| v + (0.1 * rng.normal()).abs())
                        .collect()
                })
                .collect();

            // sequential arm: B independent dense solves
            let t0 = Instant::now();
            let seq: Vec<Vec<f64>> = (0..bsz)
                .map(|e| {
                    dense
                        .solve_with(
                            Some(&qs[e]),
                            Some(&bs[e]),
                            Some(&hs[e]),
                            &opts,
                        )
                        .x
                })
                .collect();
            let t_seq = t0.elapsed().as_secs_f64();

            // batched arm: one launch
            let qr: Vec<&[f64]> = qs.iter().map(|v| v.as_slice()).collect();
            let br: Vec<&[f64]> = bs.iter().map(|v| v.as_slice()).collect();
            let hr: Vec<&[f64]> = hs.iter().map(|v| v.as_slice()).collect();
            let t0 = Instant::now();
            let sol = batched.solve_batch(
                Some(&qr),
                Some(&br),
                Some(&hr),
                &opts,
            );
            let t_bat = t0.elapsed().as_secs_f64();

            let mut dx = 0.0f64;
            for e in 0..bsz {
                for i in 0..n {
                    dx = dx.max((sol.xs[e][i] - seq[e][i]).abs());
                }
            }
            let speedup = t_seq / t_bat.max(1e-12);
            if n == 200 && bsz == 32 {
                b32_n200_speedup = Some(speedup);
            }
            t.row(&[
                n.to_string(),
                bsz.to_string(),
                format!("{t_seq:.4}"),
                format!("{t_bat:.4}"),
                format!("{:.0}", bsz as f64 / t_seq),
                format!("{:.0}", bsz as f64 / t_bat),
                format!("{speedup:.2}x"),
                format!("{dx:.1e}"),
            ]);
            json.entry(
                &[("n", &n.to_string()), ("B", &bsz.to_string())],
                &Stats::from_samples(&[t_bat]),
                &[
                    ("seq_median", t_seq),
                    ("speedup", speedup),
                    ("max_dx", dx),
                    ("batched_inst_per_s", bsz as f64 / t_bat),
                ],
            );
        }
    }
    t.print();
    t.write_csv("batched_native").unwrap();
    match json.write() {
        Ok(path) => println!("machine-readable results: {path}"),
        Err(e) => eprintln!("json write failed: {e}"),
    }
    if !smoke {
        match json.write_repo_root() {
            Ok(path) => println!("perf baseline: {path}"),
            Err(e) => eprintln!("baseline write failed: {e}"),
        }
    }
    if let Some(s) = b32_n200_speedup {
        println!(
            "\nheadline cell (n=200, B=32): {s:.2}x batched over \
             sequential (target ≥ 3x)"
        );
    }
    println!(
        "claims: batch-major GEMM + masked kernels turn the native \
         fallback and minibatch training into one launch per batch; \
         max|Δx| confirms per-element parity."
    );
}
