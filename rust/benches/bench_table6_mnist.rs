//! Table 6 / Fig. 4 reproduction: image classification with a dense QP
//! layer — test accuracy and time per epoch, OptNet vs Alt-Diff, plus the
//! Alt-Diff truncation sweep (paper §5.3, on the synthetic-digit MNIST
//! substitute).

use altdiff::nn::OptBackend;
use altdiff::train::{train_mnist, MnistConfig};
use altdiff::util::{Args, Table};

fn main() {
    let args = Args::parse();
    let quick = args.has("quick");
    let base = MnistConfig {
        epochs: args.get_usize("epochs", if quick { 2 } else { 4 }),
        train_size: args.get_usize("train", if quick { 200 } else { 500 }),
        test_size: args.get_usize("test", 150),
        layer_dim: args.get_usize("layer-dim", 32),
        layer_eq: 8,
        layer_ineq: 8,
        noise: 0.6,
        seed: 1,
        ..Default::default()
    };

    let alt = train_mnist(&MnistConfig {
        backend: OptBackend::AltDiff,
        tol: 1e-3,
        ..base.clone()
    });
    let opt = train_mnist(&MnistConfig {
        backend: OptBackend::OptNetKkt,
        ..base.clone()
    });

    let mut t = Table::new(
        "Table 6 — QP-layer classifier",
        &["model", "test acc (%)", "time/epoch (s)", "layer iters"],
    );
    for r in [&opt, &alt] {
        t.row(&[
            r.backend_label.clone(),
            format!("{:.2}", 100.0 * r.test_accs.last().unwrap()),
            format!(
                "{:.3}",
                r.epoch_times.iter().sum::<f64>()
                    / r.epoch_times.len() as f64
            ),
            format!("{:.1}", r.mean_layer_iters),
        ]);
    }
    t.print();
    t.write_csv("table6_mnist").unwrap();

    // Fig. 4: per-epoch curves at three tolerances
    let mut rows = Vec::new();
    for tol in [1e-1, 1e-2, 1e-3] {
        let r = train_mnist(&MnistConfig {
            backend: OptBackend::AltDiff,
            tol,
            ..base.clone()
        });
        rows.push((tol, r));
    }
    let mut t2 = Table::new(
        "Fig 4 — alt-diff tolerance sweep (per-epoch test acc %)",
        &["epoch", "tol 1e-1", "tol 1e-2", "tol 1e-3"],
    );
    for e in 0..base.epochs {
        t2.row(&[
            e.to_string(),
            format!("{:.1}", 100.0 * rows[0].1.test_accs[e]),
            format!("{:.1}", 100.0 * rows[1].1.test_accs[e]),
            format!("{:.1}", 100.0 * rows[2].1.test_accs[e]),
        ]);
    }
    t2.print();
    t2.write_csv("fig4_mnist_tolerance").unwrap();

    println!(
        "\npaper claims: accuracy parity ({:.1}% vs {:.1}%), alt-diff \
         faster per epoch ({:.2}x here), truncation does not hurt accuracy",
        100.0 * opt.test_accs.last().unwrap(),
        100.0 * alt.test_accs.last().unwrap(),
        opt.epoch_times.iter().sum::<f64>()
            / alt.epoch_times.iter().sum::<f64>().max(1e-12)
    );
}
