//! Reverse-mode (adjoint) vs forward-mode backward — the tentpole claim
//! of the adjoint subsystem: training only consumes vᵀ∂x*/∂θ, and the
//! transposed recursion computes it in O(k·n²) per element instead of
//! the full-Jacobian O(k·n²·d), so at d = n (the ∂x/∂q training case)
//! the backward stops paying the factor-of-d cost entirely — and the
//! O(B·n·d) Jacobian state (the batched-serving memory cliff) never
//! exists.
//!
//! Grids (per-element gradients agree between both modes; every cell
//! cross-checks max |Δgrad|):
//! - dense batched: n ∈ {100, 200} × B ∈ {8, 32}, d = n
//! - sparse Sherman–Morrison (sparsemax): n ∈ {500, 1000}, B = 4
//! - sparse blocked-CG: n = 300, B = 8
//!
//! Run: cargo bench --bench bench_vjp [-- --smoke] [--sizes 100,200]
//!      [--batches 8,32] [--tol 1e-8]

use altdiff::altdiff::{BackwardMode, Options, Param};
use altdiff::batch::{BatchedAltDiff, BatchedSparseAltDiff};
use altdiff::prob::{dense_qp, sparse_qp, sparsemax_qp};
use altdiff::util::{fmt_secs, Args, JsonReport, Pcg64, Stats, Table};
use std::time::Instant;

/// Per-element q perturbations + incoming gradients for one cell.
fn make_inputs(
    q0: &[f64],
    n: usize,
    bsz: usize,
    seed: u64,
) -> (Vec<Vec<f64>>, Vec<Vec<f64>>) {
    let mut rng = Pcg64::new(seed);
    let qs: Vec<Vec<f64>> = (0..bsz)
        .map(|_| {
            q0.iter().map(|&v| v * (1.0 + 0.1 * rng.normal())).collect()
        })
        .collect();
    let vs: Vec<Vec<f64>> =
        (0..bsz).map(|_| rng.normal_vec(n)).collect();
    (qs, vs)
}

struct Cell {
    t_fwd: f64,
    t_adj: f64,
    max_dg: f64,
}

/// Time forward-mode (full ∂x/∂q + per-element gemv_t) against adjoint
/// on one engine; generic over the two batched engines via closures.
fn run_cell<FF, FA>(reps: usize, fwd: FF, adj: FA) -> Cell
where
    FF: Fn() -> Vec<Vec<f64>>,
    FA: Fn() -> Vec<Vec<f64>>,
{
    // warmup + correctness cross-check
    let gf = fwd();
    let ga = adj();
    let mut max_dg = 0.0f64;
    for (a, b) in gf.iter().zip(&ga) {
        for (x, y) in a.iter().zip(b) {
            max_dg = max_dg.max((x - y).abs());
        }
    }
    let mut tf = Vec::with_capacity(reps);
    let mut ta = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        std::hint::black_box(fwd());
        tf.push(t0.elapsed().as_secs_f64());
        let t0 = Instant::now();
        std::hint::black_box(adj());
        ta.push(t0.elapsed().as_secs_f64());
    }
    Cell {
        t_fwd: Stats::from_samples(&tf).median,
        t_adj: Stats::from_samples(&ta).median,
        max_dg,
    }
}

fn main() {
    let args = Args::parse();
    let smoke = args.has("smoke");
    let default_sizes: &[usize] = if smoke { &[24] } else { &[100, 200] };
    let default_batches: &[usize] = if smoke { &[2] } else { &[8, 32] };
    let sizes = args.get_usize_list("sizes", default_sizes);
    let batches = args.get_usize_list("batches", default_batches);
    let tol = args.get_f64("tol", 1e-8);
    let reps = if smoke { 1 } else { 3 };
    let opts_fwd = Options {
        tol,
        max_iter: 20_000,
        backward: BackwardMode::Forward(Param::Q),
        ..Default::default()
    };
    let opts_adj = Options {
        tol,
        max_iter: 20_000,
        backward: BackwardMode::Adjoint,
        ..Default::default()
    };

    let mut t = Table::new(
        &format!(
            "Adjoint vs full-Jacobian backward, d = n (∂x/∂q, tol={tol:.0e})"
        ),
        &[
            "engine",
            "n",
            "B",
            "fwd-mode",
            "adjoint",
            "speedup",
            "max|Δgrad|",
        ],
    );
    let mut json = JsonReport::new("vjp");
    let mut headline = None;

    // ---- dense batched grid
    for &n in &sizes {
        let (m, p) = (n / 2, n / 5);
        let engine =
            BatchedAltDiff::new(dense_qp(n, m, p, 42 + n as u64), 1.0)
                .unwrap();
        for &bsz in &batches {
            let (qs, vs) =
                make_inputs(&engine.qp.q, n, bsz, 7 + bsz as u64);
            let qr: Vec<&[f64]> = qs.iter().map(|v| v.as_slice()).collect();
            let vr: Vec<&[f64]> = vs.iter().map(|v| v.as_slice()).collect();
            let cell = run_cell(
                reps,
                || {
                    let sol = engine
                        .solve_batch(Some(&qr), None, None, &opts_fwd);
                    (0..bsz).map(|e| sol.vjp(e, &vs[e])).collect()
                },
                || {
                    engine
                        .solve_batch_vjp(
                            Some(&qr), None, None, &vr, &opts_adj,
                        )
                        .vjp
                        .grads_q
                },
            );
            let speedup = cell.t_fwd / cell.t_adj.max(1e-12);
            if n == 200 && bsz == 32 {
                headline = Some(speedup);
            }
            t.row(&[
                "dense".into(),
                n.to_string(),
                bsz.to_string(),
                fmt_secs(cell.t_fwd),
                fmt_secs(cell.t_adj),
                format!("{speedup:.1}x"),
                format!("{:.1e}", cell.max_dg),
            ]);
            json.entry(
                &[
                    ("engine", "dense"),
                    ("n", &n.to_string()),
                    ("B", &bsz.to_string()),
                ],
                &Stats::from_samples(&[cell.t_adj]),
                &[
                    ("fwd_median", cell.t_fwd),
                    ("speedup", speedup),
                    ("max_dgrad", cell.max_dg),
                ],
            );
        }
    }

    // ---- sparse grids: Sherman–Morrison (sparsemax) and blocked CG
    let sm_sizes: Vec<usize> =
        if smoke { vec![40] } else { vec![500, 1000] };
    let sm_b = if smoke { 2 } else { 4 };
    for &n in &sm_sizes {
        let engine =
            BatchedSparseAltDiff::new(sparsemax_qp(n, 3), 1.0).unwrap();
        assert!(engine.uses_sherman_morrison());
        let (qs, vs) = make_inputs(&engine.qp.q, n, sm_b, 11);
        let qr: Vec<&[f64]> = qs.iter().map(|v| v.as_slice()).collect();
        let vr: Vec<&[f64]> = vs.iter().map(|v| v.as_slice()).collect();
        let cell = run_cell(
            reps,
            || {
                let sol =
                    engine.solve_batch(Some(&qr), None, None, &opts_fwd);
                (0..sm_b).map(|e| sol.vjp(e, &vs[e])).collect()
            },
            || {
                engine
                    .solve_batch_vjp(Some(&qr), None, None, &vr, &opts_adj)
                    .vjp
                    .grads_q
            },
        );
        let speedup = cell.t_fwd / cell.t_adj.max(1e-12);
        t.row(&[
            "sparse-sm".into(),
            n.to_string(),
            sm_b.to_string(),
            fmt_secs(cell.t_fwd),
            fmt_secs(cell.t_adj),
            format!("{speedup:.1}x"),
            format!("{:.1e}", cell.max_dg),
        ]);
        json.entry(
            &[
                ("engine", "sparse-sm"),
                ("n", &n.to_string()),
                ("B", &sm_b.to_string()),
            ],
            &Stats::from_samples(&[cell.t_adj]),
            &[
                ("fwd_median", cell.t_fwd),
                ("speedup", speedup),
                ("max_dgrad", cell.max_dg),
            ],
        );
    }
    {
        let (n, m, p, cg_b) =
            if smoke { (30, 12, 6, 2) } else { (300, 120, 60, 8) };
        let engine = BatchedSparseAltDiff::new(
            sparse_qp(n, m, p, 0.05, 5),
            1.0,
        )
        .unwrap();
        assert!(!engine.uses_sherman_morrison());
        let (qs, vs) = make_inputs(&engine.qp.q, n, cg_b, 13);
        let qr: Vec<&[f64]> = qs.iter().map(|v| v.as_slice()).collect();
        let vr: Vec<&[f64]> = vs.iter().map(|v| v.as_slice()).collect();
        let cell = run_cell(
            reps,
            || {
                let sol =
                    engine.solve_batch(Some(&qr), None, None, &opts_fwd);
                (0..cg_b).map(|e| sol.vjp(e, &vs[e])).collect()
            },
            || {
                engine
                    .solve_batch_vjp(Some(&qr), None, None, &vr, &opts_adj)
                    .vjp
                    .grads_q
            },
        );
        let speedup = cell.t_fwd / cell.t_adj.max(1e-12);
        t.row(&[
            "sparse-cg".into(),
            n.to_string(),
            cg_b.to_string(),
            fmt_secs(cell.t_fwd),
            fmt_secs(cell.t_adj),
            format!("{speedup:.1}x"),
            format!("{:.1e}", cell.max_dg),
        ]);
        json.entry(
            &[
                ("engine", "sparse-cg"),
                ("n", &n.to_string()),
                ("B", &cg_b.to_string()),
            ],
            &Stats::from_samples(&[cell.t_adj]),
            &[
                ("fwd_median", cell.t_fwd),
                ("speedup", speedup),
                ("max_dgrad", cell.max_dg),
            ],
        );
    }

    t.print();
    t.write_csv("vjp").unwrap();
    match json.write() {
        Ok(path) => println!("machine-readable results: {path}"),
        Err(e) => eprintln!("json write failed: {e}"),
    }
    if !smoke {
        match json.write_repo_root() {
            Ok(path) => println!("perf baseline: {path}"),
            Err(e) => eprintln!("baseline write failed: {e}"),
        }
    }
    if let Some(s) = headline {
        println!(
            "\nheadline cell (dense n=200, B=32, d=n): {s:.1}x adjoint \
             over full-Jacobian backward (target ≥ 5x)"
        );
    }
    println!(
        "claims: the adjoint backward is d-free — one H⁻¹ apply per \
         iteration per element instead of d Jacobian columns — and \
         max|Δgrad| confirms both modes agree at the solve tolerance."
    );
}
