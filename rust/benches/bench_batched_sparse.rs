//! Batched vs sequential sparse Alt-Diff throughput (ours): the
//! tentpole claim of `batch::sparse` — solving B sparse instances of
//! one registered layer as a single multi-RHS launch beats B sequential
//! `SparseAltDiff::solve_with` calls, because every CSR traversal
//! decodes each nonzero once for the whole batch (and the batched
//! Sherman–Morrison path amortizes its dinv/u reads the same way).
//!
//! Grid: B ∈ {1, 8, 32, 128} × n ∈ {1e3, 1e4, 1e5} on the sparsemax
//! structure (Sherman–Morrison engine, the paper's Table 4 regime),
//! plus a smaller blocked-CG grid on random sparse QPs. Fixed-k
//! forward+Jacobian (∂x/∂b) runs, the serving configuration. Every
//! cell cross-checks max |x_batched − x_sequential|, and the whole
//! table is also written to `target/bench_json/BENCH_batched_sparse.json`
//! (median/p10/p90 per cell) for perf-trajectory tracking.
//!
//! Run: cargo bench --bench bench_batched_sparse [-- --quick|--smoke]
//!      [--sizes 1000,10000] [--batches 1,8,32] [--k 10]
//!      [--max-elems 4000000]
//!
//! `--smoke` runs a tiny CI-sized grid (seconds) and skips the
//! repo-root baseline write; full runs refresh `BENCH_batched_sparse.json`
//! at the repository root (the committed perf trajectory).

use altdiff::altdiff::{BackwardMode, Options, Param, SparseAltDiff};
use altdiff::batch::BatchedSparseAltDiff;
use altdiff::prob::{sparse_qp, sparsemax_qp};
use altdiff::util::{Args, JsonReport, Pcg64, Stats, Table};
use std::time::Instant;

struct Cell {
    seq: Stats,
    bat: Stats,
    max_dx: f64,
}

/// One (layer, B) cell: time B sequential solves vs one batched launch,
/// `reps` times each, and cross-check the solutions of the last rep.
fn bench_cell(
    seq: &SparseAltDiff,
    batched: &BatchedSparseAltDiff,
    opts: &Options,
    bsz: usize,
    reps: usize,
    seed: u64,
) -> Cell {
    let n = seq.qp.n();
    let mut rng = Pcg64::new(seed);
    let qs: Vec<Vec<f64>> = (0..bsz)
        .map(|_| {
            seq.qp
                .q
                .iter()
                .map(|&v| v * (1.0 + 0.1 * rng.normal()))
                .collect()
        })
        .collect();
    let qr: Vec<&[f64]> = qs.iter().map(|v| v.as_slice()).collect();

    let mut seq_times = Vec::with_capacity(reps);
    let mut bat_times = Vec::with_capacity(reps);
    let mut seq_xs: Vec<Vec<f64>> = Vec::new();
    let mut bat_xs: Vec<Vec<f64>> = Vec::new();
    for _ in 0..reps {
        let t0 = Instant::now();
        seq_xs = qs
            .iter()
            .map(|q| seq.solve_with(Some(q), None, None, opts).x)
            .collect();
        seq_times.push(t0.elapsed().as_secs_f64());

        let t0 = Instant::now();
        let sol = batched.solve_batch(Some(&qr), None, None, opts);
        bat_times.push(t0.elapsed().as_secs_f64());
        bat_xs = sol.xs;
    }
    let mut max_dx = 0.0f64;
    for e in 0..bsz {
        for i in 0..n {
            max_dx = max_dx.max((bat_xs[e][i] - seq_xs[e][i]).abs());
        }
    }
    Cell {
        seq: Stats::from_samples(&seq_times),
        bat: Stats::from_samples(&bat_times),
        max_dx,
    }
}

fn main() {
    let args = Args::parse();
    let smoke = args.has("smoke");
    let quick = args.has("quick");
    let default_sizes: &[usize] = if smoke {
        &[200]
    } else if quick {
        &[1_000, 10_000]
    } else {
        &[1_000, 10_000, 100_000]
    };
    let default_batches: &[usize] = if smoke {
        &[1, 4]
    } else if quick {
        &[1, 8, 32]
    } else {
        &[1, 8, 32, 128]
    };
    let default_cg_sizes: &[usize] = if smoke {
        &[100]
    } else if quick {
        &[1_000]
    } else {
        &[1_000, 4_000]
    };
    let sizes = args.get_usize_list("sizes", default_sizes);
    let batches = args.get_usize_list("batches", default_batches);
    let cg_sizes = args.get_usize_list("cg-sizes", default_cg_sizes);
    let k = args.get_usize("k", 10);
    // n·B cap: the batched engine holds ~24 (n, B) f64 blocks for the
    // sparsemax shape (m = 2n), so 4e6 elements ≈ 0.8 GB peak
    let max_elems = args.get_usize("max-elems", 4_000_000);

    let mut t = Table::new(
        &format!(
            "Batched sparse engine — one multi-RHS launch vs B \
             sequential solves (k={k}, ∂x/∂b)"
        ),
        &[
            "engine",
            "n",
            "B",
            "seq (s)",
            "batched (s)",
            "seq inst/s",
            "batched inst/s",
            "speedup",
            "max|Δx|",
        ],
    );
    let mut json = JsonReport::new("batched_sparse");
    // acceptance cells: B=32, n ≥ 1e4 on the Table 4 structure
    let mut acceptance: Vec<(usize, f64)> = Vec::new();

    let opts = Options {
        tol: 0.0, // serving semantics: exactly k iterations
        max_iter: k,
        backward: BackwardMode::Forward(Param::B),
        ..Default::default()
    };

    let record = |engine: &str,
                  n: usize,
                  bsz: usize,
                  cell: &Cell,
                  t: &mut Table,
                  json: &mut JsonReport| {
        let speedup = cell.seq.median / cell.bat.median.max(1e-12);
        t.row(&[
            engine.to_string(),
            n.to_string(),
            bsz.to_string(),
            format!("{:.4}", cell.seq.median),
            format!("{:.4}", cell.bat.median),
            format!("{:.0}", bsz as f64 / cell.seq.median),
            format!("{:.0}", bsz as f64 / cell.bat.median),
            format!("{speedup:.2}x"),
            format!("{:.1e}", cell.max_dx),
        ]);
        json.entry(
            &[
                ("engine", engine),
                ("n", &n.to_string()),
                ("B", &bsz.to_string()),
            ],
            &cell.bat,
            &[
                ("seq_median", cell.seq.median),
                ("seq_p10", cell.seq.p10),
                ("seq_p90", cell.seq.p90),
                ("speedup", speedup),
                ("max_dx", cell.max_dx),
                ("batched_inst_per_s", bsz as f64 / cell.bat.median),
            ],
        );
        speedup
    };

    // ---- Sherman–Morrison grid: constrained sparsemax (Table 4)
    for &n in &sizes {
        let sq = sparsemax_qp(n, 42);
        let seq = SparseAltDiff::new(sq, 1.0).unwrap();
        let batched = BatchedSparseAltDiff::from_sparse(&seq);
        assert!(batched.uses_sherman_morrison());
        for &bsz in &batches {
            if n * bsz > max_elems {
                println!(
                    "skip sparsemax n={n} B={bsz}: n·B > {max_elems} \
                     (--max-elems)"
                );
                continue;
            }
            let reps = if n * bsz <= 100_000 { 5 } else { 1 };
            let cell = bench_cell(
                &seq,
                &batched,
                &opts,
                bsz,
                reps,
                7 + bsz as u64,
            );
            let speedup =
                record("sparsemax/SM", n, bsz, &cell, &mut t, &mut json);
            if bsz == 32 && n >= 10_000 {
                acceptance.push((n, speedup));
            }
        }
    }

    // ---- blocked-CG grid: random sparse QPs (general structure)
    for &n in &cg_sizes {
        let density = 4.0 / n as f64; // ~5 nnz per constraint row
        let sq = sparse_qp(n, n / 2, 4, density, 21);
        let seq = SparseAltDiff::new(sq, 1.0).unwrap();
        let batched = BatchedSparseAltDiff::from_sparse(&seq);
        assert!(!batched.uses_sherman_morrison());
        for &bsz in &batches {
            if n * bsz > max_elems {
                println!("skip cg n={n} B={bsz}: n·B > {max_elems}");
                continue;
            }
            let reps = if n * bsz <= 50_000 { 3 } else { 1 };
            let cell = bench_cell(
                &seq,
                &batched,
                &opts,
                bsz,
                reps,
                11 + bsz as u64,
            );
            record("random/CG", n, bsz, &cell, &mut t, &mut json);
        }
    }

    t.print();
    t.write_csv("batched_sparse").unwrap();
    match json.write() {
        Ok(path) => println!("\nmachine-readable results: {path}"),
        Err(e) => eprintln!("json write failed: {e}"),
    }
    if !smoke {
        match json.write_repo_root() {
            Ok(path) => println!("perf baseline: {path}"),
            Err(e) => eprintln!("baseline write failed: {e}"),
        }
    }
    for (n, s) in &acceptance {
        println!(
            "acceptance cell (sparsemax, n={n}, B=32): {s:.2}x batched \
             over sequential (target ≥ 2x)"
        );
    }
    println!(
        "claims: multi-RHS SpMM + batched Sherman–Morrison/blocked CG \
         turn the sparse serving fallback and sparse minibatch training \
         into one launch per batch; max|Δx| confirms per-element parity."
    );
}
