//! Warm-start leverage: iterations-to-converge and wall clock, cold vs
//! warm, across the serving grid — the tentpole claim of the `warm`
//! subsystem. The workload models serving/training reality: solve a
//! batch of B instances, let θ drift by ~1%, solve again — cold from
//! zero vs warm from the pre-drift solutions (`solve_batch_from`).
//! Thm 4.3 makes the comparison fair: both runs stop at the same
//! relative-step tolerance, so "fewer iterations" is the whole win.
//!
//! Grid: n ∈ {200 (dense), 1e3, 1e4 (sparse/Sherman–Morrison)} ×
//! B ∈ {1, 8, 32}. Every cell asserts warm iterations are *strictly*
//! fewer than cold (the acceptance bar; a violation aborts the bench).
//!
//! Run: cargo bench --bench bench_warmstart [-- --quick|--smoke]
//!      [--batches 1,8] [--tol 1e-6] [--drift 0.01]
//!
//! `--smoke` runs a tiny CI-sized grid (seconds) and skips the
//! repo-root baseline write; full runs refresh `BENCH_warmstart.json`
//! at the repository root (the committed perf trajectory).

use altdiff::altdiff::{DenseAltDiff, Options, SparseAltDiff};
use altdiff::batch::{
    BatchSolution, BatchedAltDiff, BatchedSparseAltDiff,
};
use altdiff::prob::{dense_qp, sparsemax_qp};
use altdiff::util::{Args, JsonReport, Pcg64, Stats, Table};
use altdiff::warm::WarmStart;
use std::time::Instant;

/// One measured arm: per-element iteration counts + wall seconds.
struct Arm {
    iters: f64,
    secs: Vec<f64>,
}

fn mean(v: &[usize]) -> f64 {
    v.iter().sum::<usize>() as f64 / v.len().max(1) as f64
}

/// Solve `qs` via the cell's engine, cold or from `warms`.
fn launch(
    engine: &Engine,
    qs: &[Vec<f64>],
    warms: Option<&[Option<WarmStart>]>,
    opts: &Options,
) -> BatchSolution {
    let qrefs: Vec<&[f64]> = qs.iter().map(|q| q.as_slice()).collect();
    match engine {
        Engine::Dense(b) => {
            b.solve_batch_from(Some(&qrefs), None, None, warms, opts)
        }
        Engine::Sparse(b) => b
            .try_solve_batch_from(Some(&qrefs), None, None, warms, opts)
            .expect("sparse warm-start bench solve failed"),
    }
}

enum Engine {
    Dense(BatchedAltDiff),
    Sparse(BatchedSparseAltDiff),
}

fn main() {
    let args = Args::parse();
    let smoke = args.has("smoke");
    let quick = args.has("quick");
    // (n, dense?) grid: the 1e3/1e4 cells use the sparsemax structure
    // (Sherman–Morrison x-updates), where those sizes are practical
    let default_cells: &[(usize, bool)] = if smoke {
        &[(24, true), (200, false)]
    } else if quick {
        &[(200, true), (1_000, false)]
    } else {
        &[(200, true), (1_000, false), (10_000, false)]
    };
    let default_batches: &[usize] =
        if smoke { &[1, 4] } else { &[1, 8, 32] };
    let batches = args.get_usize_list("batches", default_batches);
    let tol = args.get_f64("tol", 1e-6);
    let drift = args.get_f64("drift", 0.01);
    let reps = if smoke { 1 } else { 3 };

    let mut t = Table::new(
        &format!(
            "Warm starts — cold vs warm (solve_batch_from) after {:.0}% \
             θ drift, tol {tol:.0e}",
            drift * 100.0
        ),
        &[
            "engine",
            "n",
            "B",
            "cold iters",
            "warm iters",
            "cold (s)",
            "warm (s)",
            "speedup",
            "iters saved",
        ],
    );
    let mut json = JsonReport::new("warmstart");

    for &(n, dense) in default_cells {
        let (label, engine, base_q): (&str, Engine, Vec<f64>) = if dense
        {
            let qp = dense_qp(n, n / 2, n / 5, 42 + n as u64);
            let q = qp.q.clone();
            let solver = DenseAltDiff::new(qp, 1.0).unwrap();
            ("dense", Engine::Dense(BatchedAltDiff::from_dense(&solver)), q)
        } else {
            let sq = sparsemax_qp(n, 42 + n as u64);
            let q = sq.q.clone();
            let solver = SparseAltDiff::new(sq, 1.0).unwrap();
            (
                "sparse-sm",
                Engine::Sparse(BatchedSparseAltDiff::from_sparse(
                    &solver,
                )),
                q,
            )
        };
        let opts = Options {
            tol,
            max_iter: 50_000,
            ..Options::forward_only()
        };
        for &bsz in &batches {
            let mut rng = Pcg64::new(7 + (n * 31 + bsz) as u64);
            // per-element base θ, then a small drift — the serving /
            // epoch-over-epoch pattern
            let qs0: Vec<Vec<f64>> = (0..bsz)
                .map(|_| {
                    base_q
                        .iter()
                        .map(|&v| v * (1.0 + 0.1 * rng.normal()))
                        .collect()
                })
                .collect();
            let qs1: Vec<Vec<f64>> = qs0
                .iter()
                .map(|q| {
                    q.iter()
                        .map(|&v| v * (1.0 + drift * rng.normal()))
                        .collect()
                })
                .collect();
            // pre-drift solve supplies the warm iterates
            let prior = launch(&engine, &qs0, None, &opts);
            let warms: Vec<Option<WarmStart>> =
                (0..bsz).map(|e| Some(prior.warm_start(e))).collect();

            let mut run = |warms: Option<&[Option<WarmStart>]>| -> Arm {
                let mut secs = Vec::with_capacity(reps);
                let mut iters = 0.0;
                for _ in 0..reps {
                    let t0 = Instant::now();
                    let sol = launch(&engine, &qs1, warms, &opts);
                    secs.push(t0.elapsed().as_secs_f64());
                    iters = mean(&sol.iters);
                }
                Arm { iters, secs }
            };
            let cold = run(None);
            let warm = run(Some(&warms));

            // the acceptance bar: warm strictly beats cold everywhere
            assert!(
                warm.iters < cold.iters,
                "warm start did not save iterations at {label} \
                 n={n} B={bsz}: warm {} vs cold {}",
                warm.iters,
                cold.iters
            );

            let cold_stats = Stats::from_samples(&cold.secs);
            let warm_stats = Stats::from_samples(&warm.secs);
            let speedup = cold_stats.median / warm_stats.median.max(1e-12);
            let saved_frac = 1.0 - warm.iters / cold.iters;
            t.row(&[
                label.to_string(),
                n.to_string(),
                bsz.to_string(),
                format!("{:.1}", cold.iters),
                format!("{:.1}", warm.iters),
                format!("{:.4}", cold_stats.median),
                format!("{:.4}", warm_stats.median),
                format!("{speedup:.2}x"),
                format!("{:.0}%", 100.0 * saved_frac),
            ]);
            json.entry(
                &[
                    ("engine", label),
                    ("n", &n.to_string()),
                    ("B", &bsz.to_string()),
                ],
                &warm_stats,
                &[
                    ("cold_median", cold_stats.median),
                    ("cold_iters", cold.iters),
                    ("warm_iters", warm.iters),
                    ("iters_saved_frac", saved_frac),
                    ("speedup", speedup),
                ],
            );
        }
    }
    t.print();
    t.write_csv("warmstart").unwrap();
    match json.write() {
        Ok(path) => println!("machine-readable results: {path}"),
        Err(e) => eprintln!("json write failed: {e}"),
    }
    if !smoke {
        match json.write_repo_root() {
            Ok(path) => println!("perf baseline: {path}"),
            Err(e) => eprintln!("baseline write failed: {e}"),
        }
    }
    println!(
        "claims: resuming the alternation from the pre-drift iterate \
         converges in strictly fewer iterations at every grid point \
         (asserted above) — the Thm 4.3 regime serving and training \
         live in; the wire analogue is `loadgen --sessions` against \
         `serve --warm-cache`."
    );
}
