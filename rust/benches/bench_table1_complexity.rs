//! Table 1 reproduction: empirical scaling exponents of forward/backward.
//!
//! Paper claims (QP case): Alt-Diff backward is O(k n²) and its one-time
//! setup O(n³); KKT differentiation backward is O((n+n_c)³). We time each
//! phase across a size sweep and fit log-log slopes — the printed
//! exponents should straddle ~2 for the Alt-Diff backward and ~3 for the
//! baselines' backward.

use altdiff::altdiff::{BackwardMode, DenseAltDiff, Options, Param};
use altdiff::baselines;
use altdiff::prob::dense_qp;
use altdiff::util::bench::loglog_slope;
use altdiff::util::{Args, Table};
use std::time::Instant;

fn main() {
    let args = Args::parse();
    let sizes: Vec<usize> = if args.has("quick") {
        vec![50, 100, 200]
    } else {
        vec![100, 200, 400, 800]
    };
    let fixed_k = args.get_usize("k", 30);

    let mut ns = Vec::new();
    let mut t_setup = Vec::new();
    let mut t_bwd_alt = Vec::new();
    let mut t_bwd_kkt = Vec::new();

    let mut t = Table::new(
        "Table 1 — measured phase times (fixed k backward iterations)",
        &["n", "altdiff setup(s)", "altdiff bwd k-iters(s)", "kkt bwd(s)"],
    );
    for &n in &sizes {
        // p (the Jacobian width d) is held FIXED across the sweep: the
        // paper's O(kn²) backward is per fixed parameter dimension; letting
        // d grow with n would measure O(kn²d) instead.
        let (m, p) = (n / 2, 20);
        let qp = dense_qp(n, m, p, 5);

        let t0 = Instant::now();
        let solver = DenseAltDiff::new(qp.clone(), 1.0).unwrap();
        let setup = t0.elapsed().as_secs_f64();

        // k iterations with Jacobian — the O(kn²) claim
        let t0 = Instant::now();
        let _ = solver.solve(&Options {
            tol: 0.0,
            max_iter: fixed_k,
            backward: BackwardMode::Forward(Param::B),
            ..Default::default()
        });
        let bwd_alt = t0.elapsed().as_secs_f64();

        // KKT backward alone (solution precomputed)
        let ipm = baselines::ipm_solve(&qp, 1e-9, 100).unwrap();
        let t0 = Instant::now();
        let _ = baselines::kkt_jacobian(
            &qp, &ipm.x, &ipm.lam, &ipm.nu, Param::B,
        )
        .unwrap();
        let bwd_kkt = t0.elapsed().as_secs_f64();

        ns.push(n as f64);
        t_setup.push(setup);
        t_bwd_alt.push(bwd_alt);
        t_bwd_kkt.push(bwd_kkt);
        t.row(&[
            n.to_string(),
            format!("{setup:.4}"),
            format!("{bwd_alt:.4}"),
            format!("{bwd_kkt:.4}"),
        ]);
    }
    t.print();
    let csv = t.write_csv("table1_complexity").unwrap();
    println!("\ncsv: {csv}");

    let s_setup = loglog_slope(&ns, &t_setup);
    let s_alt = loglog_slope(&ns, &t_bwd_alt);
    let s_kkt = loglog_slope(&ns, &t_bwd_kkt);
    println!("\nfitted scaling exponents (log-log slope):");
    println!("  altdiff setup      : n^{s_setup:.2}   (theory: 3 — one factorization)");
    println!("  altdiff backward   : n^{s_alt:.2}   (theory: 2 — Table 1 O(kn²); note J has O(n) cols → measured can exceed 2)");
    println!("  kkt backward       : n^{s_kkt:.2}   (theory: 3 — O((n+n_c)³))");
    println!(
        "\nclaim check: altdiff backward exponent < kkt backward exponent: {}",
        s_alt < s_kkt
    );
}
