//! Ablation: truncation error vs the Thm 4.3 bound.
//!
//! Measures ‖∂x_k/∂θ − ∂x*/∂θ‖ against ‖x_k − x*‖ across truncation
//! levels and reports the empirical ratio — the constant C₁ of Thm 4.3.
//! The claim under test: the ratio is bounded (same order), so loosening
//! the tolerance degrades the gradient *linearly*, not catastrophically.

use altdiff::altdiff::{BackwardMode, DenseAltDiff, Options, Param};
use altdiff::linalg::{norm2, sub_vec};
use altdiff::prob::dense_qp;
use altdiff::util::{Args, Table};

fn main() {
    let args = Args::parse();
    let n = args.get_usize("n", 120);
    let qp = dense_qp(n, n / 2, n / 5, 9);
    let solver = DenseAltDiff::new(qp, 1.0).unwrap();

    // "exact" reference at tol 1e-12
    let exact = solver.solve(&Options {
        tol: 1e-12,
        max_iter: 100_000,
        backward: BackwardMode::Forward(Param::B),
        ..Default::default()
    });
    let jstar = exact.jacobian.as_ref().unwrap();

    let mut t = Table::new(
        &format!("Ablation — truncation error vs Thm 4.3 bound (n={n})"),
        &["tol", "iters", "‖x_k−x*‖", "‖J_k−J*‖", "ratio (≈C₁)"],
    );
    let mut ratios = Vec::new();
    for tol in [1e-1, 3e-2, 1e-2, 3e-3, 1e-3, 1e-4, 1e-5] {
        let sol = solver.solve(&Options {
            tol,
            max_iter: 100_000,
            backward: BackwardMode::Forward(Param::B),
            ..Default::default()
        });
        let xerr = norm2(&sub_vec(&sol.x, &exact.x));
        let jerr = sol.jacobian.unwrap().sub(jstar).fro();
        let ratio = jerr / xerr.max(1e-15);
        ratios.push(ratio);
        t.row(&[
            format!("{tol:.0e}"),
            sol.iters.to_string(),
            format!("{xerr:.3e}"),
            format!("{jerr:.3e}"),
            format!("{ratio:.2}"),
        ]);
    }
    t.print();
    t.write_csv("ablation_truncation").unwrap();

    let mx = ratios.iter().cloned().fold(f64::MIN, f64::max);
    let mn = ratios.iter().cloned().fold(f64::MAX, f64::min);
    println!(
        "\nC₁ ratio range: [{mn:.2}, {mx:.2}] — bounded across 4 decades \
         of tolerance ⇒ Thm 4.3's same-order claim holds empirically."
    );
}
