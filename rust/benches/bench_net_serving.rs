//! Network serving bench (ours): the wire path vs in-process submission.
//!
//! For each in-flight window B ∈ {1, 8, 32} the same request trace runs
//! twice against an identical coordinator: once through the TCP front
//! end (loopback, pipelined loadgen clients) and once via direct
//! `Coordinator::submit` calls with the same concurrency — isolating
//! what the codec + event loop + admission control cost on top of the
//! in-process serving stack. A second grid drives the same bursty
//! open-loop trace (`LoadgenOpts::burst`) against 1 vs N coordinator
//! shards to watch the scaling path. Reports p50/p99 round trips and
//! throughput; JSON via `util::bench::JsonReport` (`--smoke` runs a
//! tiny grid — including the 1-vs-2-shard cell and the trace-overhead
//! pair — and never writes the committed repo-root baselines).
//!
//! The trace-overhead pair reruns one closed-loop cell with the
//! tracing plane off vs fully on (stamps + 1-in-1 sampling + stage
//! echo) and asserts the enabled plane stays within a generous noise
//! bound of the disabled one — the "near-free" contract from
//! DESIGN.md §Observability as a measured number.

use altdiff::coordinator::{Config, Coordinator, Reply};
use altdiff::net::{
    run_loadgen, LoadgenOpts, NetConfig, NetServer,
};
use altdiff::prob::dense_qp;
use altdiff::util::{Args, JsonReport, Pcg64, Stats, Table};
use std::time::{Duration, Instant};

const LAYER: &str = "qp16";

fn coordinator(workers: usize, shards: usize, traced: bool) -> Coordinator {
    Coordinator::builder(Config {
        workers,
        max_batch: 8,
        batch_timeout_us: 2_000,
        shards,
        artifacts: None,
        stamps: traced,
        trace_every: if traced { 1 } else { 0 },
        trace_ring: 512,
        ..Default::default()
    })
    .register(LAYER, dense_qp(16, 8, 4, 1), 1.0)
    .expect("register")
    .start()
}

struct Cell {
    throughput: f64,
    p50_us: f64,
    p99_us: f64,
    shed: usize,
    failed: usize,
    rtts: Vec<f64>,
}

/// Serve over loopback TCP, drive with the pipelined load generator.
/// `shards` sizes the coordinator pool; `burst > 0` switches the
/// loadgen to open-loop bursts of that size (the shard-scaling cells
/// use it so arrivals are ragged rather than self-paced).
fn run_net(
    nreq: usize,
    window: usize,
    clients: usize,
    shards: usize,
    burst: usize,
    traced: bool,
) -> Cell {
    let coord = coordinator(2, shards, traced);
    let server =
        NetServer::bind("127.0.0.1:0", coord, NetConfig::default())
            .expect("bind");
    let addr = server.local_addr().expect("addr");
    let stop = server.stop_handle();
    let handle = std::thread::spawn(move || server.run());
    let report = run_loadgen(
        addr,
        &LoadgenOpts {
            requests: nreq,
            clients,
            window,
            grad_share: 0.25,
            layer: LAYER.to_string(),
            tol: 1e-3,
            seed: 1,
            sessions: burst > 0,
            burst,
            stages: traced,
            ..Default::default()
        },
    )
    .expect("loadgen");
    stop.store(true, std::sync::atomic::Ordering::SeqCst);
    let _ = handle.join();
    Cell {
        throughput: report.throughput(),
        p50_us: report.p50_us,
        p99_us: report.p99_us,
        shed: report.shed,
        failed: report.failed,
        rtts: report.rtts,
    }
}

/// Same trace via in-process `submit`, same client concurrency: each
/// "client" thread keeps `window` requests outstanding against a
/// shared coordinator handle. The coordinator API is single-consumer,
/// so threads funnel through one submit/recv owner — mirroring what
/// the event loop does, minus the wire.
fn run_inproc(nreq: usize, window: usize, clients: usize) -> Cell {
    let mut coord = coordinator(2, 1, false);
    // same request count as run_net (the loadgen distributes the
    // remainder across clients; here the trace is one stream anyway)
    let total = nreq;
    let qp = dense_qp(16, 8, 4, 1);
    let mut rng = Pcg64::new(1);
    let t0 = Instant::now();
    let mut sent_at = std::collections::BTreeMap::new();
    let mut rtts = Vec::with_capacity(total);
    let mut failed = 0usize;
    let budget = window * clients;
    // returns false on timeout — callers then write off everything
    // still outstanding instead of looping on 60s waits forever
    let recv_one =
        |coord: &mut Coordinator,
         sent_at: &mut std::collections::BTreeMap<u64, Instant>,
         rtts: &mut Vec<f64>,
         failed: &mut usize|
         -> bool {
            match coord.recv_timeout(Duration::from_secs(60)) {
                Some(reply) => {
                    if let Some(t) = sent_at.remove(&reply.id()) {
                        rtts.push(t.elapsed().as_secs_f64());
                    }
                    if matches!(reply, Reply::Err(_)) {
                        *failed += 1;
                    }
                    true
                }
                None => false,
            }
        };
    let mut timed_out = false;
    for _ in 0..total {
        if timed_out {
            break;
        }
        while sent_at.len() >= budget {
            if !recv_one(&mut coord, &mut sent_at, &mut rtts, &mut failed)
            {
                timed_out = true;
                break;
            }
        }
        if timed_out {
            break;
        }
        let s = 1.0 + 0.1 * rng.normal();
        let q: Vec<f64> = qp.q.iter().map(|&v| v * s).collect();
        let id = if rng.uniform() < 0.25 {
            coord.submit_grad(
                LAYER,
                q,
                qp.b.clone(),
                qp.h.clone(),
                rng.normal_vec(16),
                1e-3,
            )
        } else {
            coord.submit(LAYER, q, qp.b.clone(), qp.h.clone(), 1e-3)
        };
        sent_at.insert(id, Instant::now());
    }
    while !timed_out && !sent_at.is_empty() {
        if !recv_one(&mut coord, &mut sent_at, &mut rtts, &mut failed) {
            timed_out = true;
        }
    }
    if timed_out {
        // lost replies: count every outstanding request as failed so
        // the bench's failed==0 assert fires instead of hanging CI
        failed += sent_at.len();
        sent_at.clear();
    }
    let wall = t0.elapsed().as_secs_f64();
    let mut sorted = rtts.clone();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |q: f64| -> f64 {
        if sorted.is_empty() {
            return 0.0;
        }
        altdiff::util::bench::percentile(&sorted, q) * 1e6
    };
    Cell {
        throughput: (total - failed) as f64 / wall,
        p50_us: pct(0.50),
        p99_us: pct(0.99),
        shed: 0,
        failed,
        rtts,
    }
}

fn main() {
    let args = Args::parse();
    let smoke = args.get_bool("smoke", false);
    let nreq = args.get_usize(
        "requests",
        if smoke || args.has("quick") { 80 } else { 400 },
    );
    let clients = args.get_usize("clients", 4);
    let windows: Vec<usize> = if smoke {
        vec![1, 8]
    } else {
        args.get_usize_list("windows", &[1, 8, 32])
    };

    let mut table = Table::new(
        &format!(
            "Network serving — wire vs in-process ({nreq} requests, \
             {clients} clients)"
        ),
        &[
            "mode",
            "B (window)",
            "throughput (req/s)",
            "p50 (µs)",
            "p99 (µs)",
            "shed",
            "failed",
        ],
    );
    let mut report = JsonReport::new("net_serving");
    for &b in &windows {
        for mode in ["net", "inproc"] {
            let cell = if mode == "net" {
                run_net(nreq, b, clients, 1, 0, false)
            } else {
                run_inproc(nreq, b, clients)
            };
            table.row(&[
                mode.to_string(),
                b.to_string(),
                format!("{:.0}", cell.throughput),
                format!("{:.0}", cell.p50_us),
                format!("{:.0}", cell.p99_us),
                cell.shed.to_string(),
                cell.failed.to_string(),
            ]);
            assert_eq!(
                cell.failed, 0,
                "{mode} B={b}: no request may fail under the default \
                 in-flight budget"
            );
            let stats = Stats::from_samples(&cell.rtts);
            report.entry(
                &[("mode", mode), ("B", &b.to_string())],
                &stats,
                &[
                    ("throughput_rps", cell.throughput),
                    ("p50_us", cell.p50_us),
                    ("p99_us", cell.p99_us),
                    ("shed", cell.shed as f64),
                ],
            );
        }
    }
    // shard-scaling cells: same bursty open-loop trace against 1 vs N
    // coordinator shards (smoke keeps the 1-vs-2 cell so CI watches
    // the scaling path on every push)
    let shard_grid: Vec<usize> =
        if smoke { vec![1, 2] } else { vec![1, 2, 4] };
    let burst_b = 8;
    for &s in &shard_grid {
        let cell = run_net(nreq, burst_b, clients, s, burst_b, false);
        table.row(&[
            format!("net ×{s} shard{}", if s == 1 { "" } else { "s" }),
            format!("{burst_b} (burst)"),
            format!("{:.0}", cell.throughput),
            format!("{:.0}", cell.p50_us),
            format!("{:.0}", cell.p99_us),
            cell.shed.to_string(),
            cell.failed.to_string(),
        ]);
        assert_eq!(
            cell.failed, 0,
            "shards={s}: no request may fail under bursty load within \
             the default in-flight budget"
        );
        let stats = Stats::from_samples(&cell.rtts);
        report.entry(
            &[
                ("mode", "net-burst"),
                ("shards", &s.to_string()),
                ("B", &burst_b.to_string()),
            ],
            &stats,
            &[
                ("throughput_rps", cell.throughput),
                ("p50_us", cell.p50_us),
                ("p99_us", cell.p99_us),
                ("shed", cell.shed as f64),
            ],
        );
    }

    // trace-overhead cells: the identical closed-loop trace with the
    // tracing plane fully off (the default) and fully on (stage
    // stamps + 1-in-1 solver sampling + per-reply stage echo). The
    // observability contract — disabled tracing is near-free, enabled
    // tracing costs a bounded slice — is measured here, not claimed;
    // the cells run in --smoke so CI watches the delta on every push.
    let trace_b = 8;
    let mut trace_cells = Vec::new();
    for (label, traced) in [("trace-off", false), ("trace-on", true)] {
        let cell = run_net(nreq, trace_b, clients, 1, 0, traced);
        table.row(&[
            label.to_string(),
            trace_b.to_string(),
            format!("{:.0}", cell.throughput),
            format!("{:.0}", cell.p50_us),
            format!("{:.0}", cell.p99_us),
            cell.shed.to_string(),
            cell.failed.to_string(),
        ]);
        assert_eq!(
            cell.failed, 0,
            "{label}: no request may fail under the default budget"
        );
        let stats = Stats::from_samples(&cell.rtts);
        report.entry(
            &[("mode", label), ("B", &trace_b.to_string())],
            &stats,
            &[
                ("throughput_rps", cell.throughput),
                ("p50_us", cell.p50_us),
                ("p99_us", cell.p99_us),
            ],
        );
        trace_cells.push(cell);
    }
    // generous noise bound (loopback RTTs are jittery at this scale):
    // even with every request sampled and echoing, the plane may not
    // cost half the throughput — a real regression (a lock on the hot
    // path, an allocation per iteration) lands far below this
    let (off, on) = (&trace_cells[0], &trace_cells[1]);
    assert!(
        on.throughput >= off.throughput * 0.5,
        "tracing overhead out of bounds: {:.0} req/s on vs {:.0} off",
        on.throughput,
        off.throughput
    );

    table.print();
    table.write_csv("net_serving").unwrap();
    println!("json: {}", report.write().unwrap());
    if !smoke {
        // committed perf baseline — full runs only, never smoke
        println!("baseline: {}", report.write_repo_root().unwrap());
    }
    println!(
        "\nclaims: the zero-dep wire path preserves the batcher's \
         throughput at realistic windows; its overhead is codec + \
         loopback, visible at B=1 and amortized by pipelining."
    );
}
