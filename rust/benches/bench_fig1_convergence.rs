//! Fig. 1 reproduction: convergence of the Alt-Diff Jacobian to the
//! KKT-implicit gradient (Thm 4.2).
//!
//! Panel (a): ‖∂x_k/∂b‖_F per iteration, with the KKT value as the
//! asymptote. Panel (b): cosine similarity between the Alt-Diff Jacobian
//! at iteration k and the KKT Jacobian.

use altdiff::altdiff::{BackwardMode, DenseAltDiff, Options, Param};
use altdiff::baselines;
use altdiff::linalg::cosine;
use altdiff::prob::dense_qp;
use altdiff::util::{Args, Table};

fn main() {
    let args = Args::parse();
    let n = args.get_usize("n", 100);
    let m = args.get_usize("m", 50);
    let p = args.get_usize("p", 20);
    let qp = dense_qp(n, m, p, 1);

    // KKT reference gradient (the blue dotted asymptote of Fig. 1a)
    let (_, jkkt, _) =
        baselines::optnet_layer(&qp, Param::B, 1e-12).unwrap();
    let kkt_norm = jkkt.fro();

    // Alt-Diff with trace; re-run to each k to extract J_k exactly
    let solver = DenseAltDiff::new(qp, 1.0).unwrap();
    let checkpoints: Vec<usize> =
        vec![1, 2, 3, 5, 8, 12, 18, 25, 35, 50, 70, 100];

    let mut t = Table::new(
        &format!("Fig 1 — Jacobian convergence (n={n}, m={m}, p={p})"),
        &["iter k", "‖J_k‖_F", "‖J_kkt‖_F", "cosine(J_k, J_kkt)", "step"],
    );
    for &k in &checkpoints {
        let sol = solver.solve(&Options {
            tol: 0.0,
            max_iter: k,
            backward: BackwardMode::Forward(Param::B),
            trace: true,
            ..Default::default()
        });
        let j = sol.jacobian.unwrap();
        t.row(&[
            k.to_string(),
            format!("{:.5}", j.fro()),
            format!("{kkt_norm:.5}"),
            format!("{:.6}", cosine(&j.data, &jkkt.data)),
            format!("{:.2e}", sol.step_rel),
        ]);
    }
    t.print();
    let csv = t.write_csv("fig1_convergence").unwrap();
    println!("\ncsv: {csv}");

    // assert the theorem numerically
    let sol = solver.solve(&Options {
        tol: 1e-12,
        max_iter: 100_000,
        backward: BackwardMode::Forward(Param::B),
        ..Default::default()
    });
    let final_cos = cosine(&sol.jacobian.unwrap().data, &jkkt.data);
    println!(
        "Thm 4.2 check: cosine at convergence = {final_cos:.8} (want → 1)"
    );
    assert!(final_cos > 0.999);
}
