//! Table 2 reproduction: dense Quadratic layers — running time and cosine
//! distance of gradients, Alt-Diff vs OptNet vs CvxpyLayer(sim).
//!
//! Paper sizes (n, m, p) = (1500,500,200) … (10000,5000,2000); we run the
//! same 10:5:2-ish ratios at ÷10 scale (no BLAS here — see DESIGN.md §8).
//! The claims under test: OptNet ≫ CvxpyLayer on dense QPs, Alt-Diff beats
//! both, and the gap widens with problem size; gradients agree to
//! cosine ≈ 0.999.

use altdiff::altdiff::{BackwardMode, DenseAltDiff, Options, Param};
use altdiff::baselines::{self, conic};
use altdiff::linalg::cosine;
use altdiff::prob::dense_qp;
use altdiff::util::{Args, Table};
use std::time::Instant;

fn main() {
    let args = Args::parse();
    let sizes: Vec<(usize, usize, usize)> = if args.has("quick") {
        vec![(50, 25, 10), (100, 50, 20)]
    } else {
        vec![(150, 50, 20), (300, 100, 50), (500, 200, 100), (1000, 500, 200)]
    };
    let tol = args.get_f64("tol", 1e-3);
    let labels = ["tiny", "small", "medium", "large"];

    let mut t = Table::new(
        &format!("Table 2 — dense quadratic layers (tol={tol:.0e}, sizes ÷10 vs paper)"),
        &[
            "size", "n", "m", "p", "optnet(s)", "cvxpy(s)", "cvx-init",
            "cvx-fwd", "cvx-bwd", "altdiff(s)", "inv(s)", "fwd+bwd(s)",
            "cos-dist",
        ],
    );

    for (i, &(n, m, p)) in sizes.iter().enumerate() {
        let qp = dense_qp(n, m, p, 7 + i as u64);

        // --- Alt-Diff: split registration (inversion) from iteration
        let t0 = Instant::now();
        let solver = DenseAltDiff::new(qp.clone(), 1.0).unwrap();
        let t_inv = t0.elapsed().as_secs_f64();
        let t0 = Instant::now();
        let sol = solver.solve(&Options {
            tol,
            backward: BackwardMode::Forward(Param::B),
            ..Default::default()
        });
        let t_iter = t0.elapsed().as_secs_f64();
        let t_alt = t_inv + t_iter;

        // --- OptNet: IPM forward + KKT backward
        let t0 = Instant::now();
        let (_, j_kkt, _) =
            baselines::optnet_layer(&qp, Param::B, tol * 1e-3).unwrap();
        let t_optnet = t0.elapsed().as_secs_f64();

        // --- CvxpyLayer(sim): skip at the largest size (the paper's "-"
        //     row: their machine also gave up on large dense problems)
        let (t_cvx, ph) = if n <= 500 {
            let res = conic::cvxpylayer_sim(&qp, Param::B, tol).unwrap();
            (res.phases.total(), res.phases)
        } else {
            (f64::NAN, conic::Phases { canon: f64::NAN, init: f64::NAN, forward: f64::NAN, backward: f64::NAN })
        };

        let cos = cosine(&sol.jacobian.as_ref().unwrap().data, &j_kkt.data);
        let fmt = |v: f64| {
            if v.is_nan() {
                "-".to_string()
            } else {
                format!("{v:.3}")
            }
        };
        t.row(&[
            labels[i.min(3)].to_string(),
            n.to_string(),
            m.to_string(),
            p.to_string(),
            fmt(t_optnet),
            fmt(t_cvx),
            fmt(ph.init + ph.canon),
            fmt(ph.forward),
            fmt(ph.backward),
            format!("{t_alt:.3}"),
            format!("{t_inv:.3}"),
            format!("{t_iter:.3}"),
            format!("{cos:.4}"),
        ]);
    }
    t.print();
    let csv = t.write_csv("table2_dense_qp").unwrap();
    println!("\ncsv: {csv}");
    println!(
        "paper claims: alt-diff fastest everywhere; optnet < cvxpylayer on \
         dense; gap grows with n; cosine ≈ 0.999"
    );
}
