//! ADMM vs Alt-Diff iterations-to-KKT-target across conditioning — the
//! offline analogue of the coordinator's cross-method router. Each cell
//! probes both batched families with fixed-k launches up an iteration
//! ladder (exactly the router's calibration procedure) and records the
//! smallest rung whose batch-max KKT residual clears the target: on
//! well-conditioned problems fixed-ρ Alt-Diff is competitive, on
//! ill-conditioned ones the ρ-balanced ADMM family converges while
//! Alt-Diff stalls — the gap the router monetizes per tolerance.
//!
//! Grid: conditioning ∈ {well, ill (P, q × 1e4)} × n ∈ {100, 500, 2000}
//! × B ∈ {1, 8, 32}. Every ill cell asserts the ADMM rung is strictly
//! better than Alt-Diff's (the acceptance bar; a violation aborts).
//!
//! Run: cargo bench --bench bench_admm [-- --quick|--smoke]
//!      [--batches 1,8] [--scale 1e4]
//!
//! `--smoke` runs a tiny CI-sized grid (seconds) and skips the
//! repo-root baseline write; full runs refresh `BENCH_admm.json` at
//! the repository root (the committed perf trajectory).

use altdiff::admm::{AdmmQp, AdmmSettings, BatchedAdmm};
use altdiff::altdiff::{BackwardMode, DenseAltDiff, Options};
use altdiff::batch::{BatchSolution, BatchedAltDiff};
use altdiff::prob::{dense_qp, ill_conditioned_qp, Qp};
use altdiff::util::{Args, JsonReport, Stats, Table};
use std::time::Instant;

/// The compiled-artifact contract: exactly k iterations, no early exit.
fn fixed_k(k: usize) -> Options {
    Options {
        rho: 1.0,
        tol: 0.0,
        max_iter: k,
        backward: BackwardMode::None,
        trace: false,
    }
}

enum Fam {
    Alt(BatchedAltDiff),
    Admm(BatchedAdmm),
}

impl Fam {
    /// One fixed-k launch of B replicas of the registered θ.
    fn launch(&self, bsz: usize, opts: &Options) -> BatchSolution {
        // replicate the registered q so every element does full work
        // while the KKT residual stays evaluable against the cell's Qp
        let q = match self {
            Fam::Alt(b) => b.qp.q.clone(),
            Fam::Admm(b) => b.qp.q.clone(),
        };
        let qs: Vec<&[f64]> = (0..bsz).map(|_| q.as_slice()).collect();
        match self {
            Fam::Alt(b) => b.solve_batch(Some(&qs), None, None, opts),
            Fam::Admm(b) => b.solve_batch(Some(&qs), None, None, opts),
        }
    }
}

/// Batch-max KKT residual against the cell's problem.
fn batch_residual(qp: &Qp, sol: &BatchSolution) -> f64 {
    (0..sol.len())
        .map(|e| qp.kkt_residual(&sol.xs[e], &sol.lams[e], &sol.nus[e]))
        .fold(0.0, f64::max)
}

/// Probe up the ladder; return (winning rung, converged?, residual
/// there). A family that never clears the target reports the top rung.
fn calibrate(
    fam: &Fam,
    qp: &Qp,
    bsz: usize,
    ladder: &[usize],
    target: f64,
) -> (usize, bool, f64) {
    let mut last = (ladder[0], false, f64::INFINITY);
    for &k in ladder {
        let sol = fam.launch(bsz, &fixed_k(k));
        let res = batch_residual(qp, &sol);
        last = (k, res <= target, res);
        if res <= target {
            return last;
        }
    }
    last
}

/// Median wall seconds of `reps` launches at the winning rung.
fn time_at(fam: &Fam, bsz: usize, k: usize, reps: usize) -> Stats {
    let opts = fixed_k(k);
    let secs: Vec<f64> = (0..reps)
        .map(|_| {
            let t0 = Instant::now();
            let _ = fam.launch(bsz, &opts);
            t0.elapsed().as_secs_f64()
        })
        .collect();
    Stats::from_samples(&secs)
}

fn main() {
    let args = Args::parse();
    let smoke = args.has("smoke");
    let quick = args.has("quick");
    let scale = args.get_f64("scale", 1e4);
    let default_sizes: &[usize] = if smoke {
        &[24, 60]
    } else if quick {
        &[100, 500]
    } else {
        &[100, 500, 2000]
    };
    let default_batches: &[usize] =
        if smoke { &[1, 4] } else { &[1, 8, 32] };
    let sizes = args.get_usize_list("sizes", default_sizes);
    let batches = args.get_usize_list("batches", default_batches);
    let ladder: &[usize] =
        if smoke { &[8, 64, 256] } else { &[16, 64, 256, 1024] };
    let reps = if smoke { 1 } else { 3 };

    let mut t = Table::new(
        &format!(
            "ADMM vs Alt-Diff — iterations to KKT target (fixed-k \
             ladder {ladder:?}, ill scale {scale:.0e})"
        ),
        &[
            "cond",
            "n",
            "B",
            "alt k",
            "admm k",
            "alt (s)",
            "admm (s)",
            "speedup",
        ],
    );
    let mut json = JsonReport::new("admm");

    for &n in &sizes {
        for ill in [false, true] {
            let (cond, qp) = if ill {
                (
                    "ill",
                    ill_conditioned_qp(
                        n,
                        n / 2,
                        n / 5,
                        scale,
                        42 + n as u64,
                    ),
                )
            } else {
                ("well", dense_qp(n, n / 2, n / 5, 42 + n as u64))
            };
            // accuracy target scales with the objective data so well
            // and ill cells demand the same *relative* accuracy
            let qmax =
                qp.q.iter().fold(0.0f64, |a, &v| a.max(v.abs()));
            let target = 1e-5 * (1.0 + qmax);
            let alt = Fam::Alt(BatchedAltDiff::from_dense(
                &DenseAltDiff::new(qp.clone(), 1.0).unwrap(),
            ));
            let adm = Fam::Admm(BatchedAdmm::from_single(
                &AdmmQp::new_adapted(
                    qp.clone(),
                    1.0,
                    AdmmSettings::default(),
                )
                .unwrap(),
            ));
            for &bsz in &batches {
                let (ak, aconv, ares) =
                    calibrate(&alt, &qp, bsz, ladder, target);
                let (mk, mconv, mres) =
                    calibrate(&adm, &qp, bsz, ladder, target);
                if ill {
                    // the acceptance bar: ρ-balanced ADMM must beat
                    // fixed-ρ Alt-Diff on every ill-conditioned cell
                    assert!(
                        mconv && (mk < ak || !aconv),
                        "ADMM did not win the ill cell n={n} B={bsz}: \
                         admm k={mk} (res {mres:.2e}) vs alt k={ak} \
                         (res {ares:.2e}, target {target:.2e})"
                    );
                }
                let ast = time_at(&alt, bsz, ak, reps);
                let mst = time_at(&adm, bsz, mk, reps);
                let speedup = ast.median / mst.median.max(1e-12);
                let mark = |k: usize, conv: bool| {
                    if conv {
                        k.to_string()
                    } else {
                        format!(">{k}")
                    }
                };
                t.row(&[
                    cond.to_string(),
                    n.to_string(),
                    bsz.to_string(),
                    mark(ak, aconv),
                    mark(mk, mconv),
                    format!("{:.4}", ast.median),
                    format!("{:.4}", mst.median),
                    format!("{speedup:.2}x"),
                ]);
                json.entry(
                    &[
                        ("cond", cond),
                        ("n", &n.to_string()),
                        ("B", &bsz.to_string()),
                    ],
                    &mst,
                    &[
                        ("alt_k", ak as f64),
                        ("admm_k", mk as f64),
                        ("alt_converged", f64::from(u8::from(aconv))),
                        ("admm_converged", f64::from(u8::from(mconv))),
                        ("alt_median", ast.median),
                        ("admm_median", mst.median),
                        ("speedup", speedup),
                        ("kkt_target", target),
                    ],
                );
            }
        }
    }
    t.print();
    t.write_csv("admm").unwrap();
    match json.write() {
        Ok(path) => println!("machine-readable results: {path}"),
        Err(e) => eprintln!("json write failed: {e}"),
    }
    if !smoke {
        match json.write_repo_root() {
            Ok(path) => println!("perf baseline: {path}"),
            Err(e) => eprintln!("baseline write failed: {e}"),
        }
    }
    println!(
        "claims: on every ill-conditioned cell the residual-balanced \
         ADMM family clears the KKT target at a strictly better ladder \
         rung than fixed-ρ Alt-Diff (asserted above) — the per-tolerance \
         gap the coordinator's cross-method router exploits when \
         `register_routed` calibrates both families; the serving \
         analogue is the `router_admm_picks` counter in `serve` stats."
    );
}
