//! Table 4 reproduction: constrained Sparsemax layers (sparse QPs).
//!
//! Paper sizes n = 5000…20000 with A = 1ᵀ, G = [−I; I]; we run n up to
//! 4000 (÷5). Alt-Diff uses the Sherman–Morrison closed form of paper
//! Table 3 — H = (2+2ρ)I + ρ11ᵀ — so its per-iteration cost is O(n);
//! OptNet pays dense (n+2n+1)³; the unrolling baseline shows the §2
//! memory/projection costs.

use altdiff::altdiff::{BackwardMode, Options, Param, SparseAltDiff};
use altdiff::baselines::{self, unrolled};
use altdiff::linalg::cosine;
use altdiff::prob::sparsemax_qp;
use altdiff::util::{Args, Table};
use std::time::Instant;

fn main() {
    let args = Args::parse();
    let sizes: Vec<usize> = if args.has("quick") {
        vec![100, 400]
    } else {
        vec![200, 500, 1000, 2000, 4000]
    };
    let tol = args.get_f64("tol", 1e-3);
    // dense baselines become cubic in 3n+1; cap them
    let optnet_cap = args.get_usize("optnet-cap", 200);

    let mut t = Table::new(
        &format!("Table 4 — constrained sparsemax layers (tol={tol:.0e})"),
        &[
            "n", "m(=2n)", "optnet(s)", "unrolled(s)", "unroll-mem",
            "altdiff(s)", "SM-path", "iters", "cos-dist",
        ],
    );

    for &n in &sizes {
        let sq = sparsemax_qp(n, 3);

        // --- Alt-Diff (Sherman–Morrison sparse path)
        let t0 = Instant::now();
        let solver = SparseAltDiff::new(sq.clone(), 1.0).unwrap();
        let sol = solver.solve(&Options {
            tol,
            backward: BackwardMode::Forward(Param::B),
            ..Default::default()
        });
        let t_alt = t0.elapsed().as_secs_f64();

        // --- OptNet (dense KKT at 3n+1) — capped
        let (t_opt, cos) = if n <= optnet_cap {
            let qp = sq.to_dense();
            let t0 = Instant::now();
            let (_, jk, _) =
                baselines::optnet_layer(&qp, Param::B, tol * 1e-3)
                    .unwrap();
            let dt = t0.elapsed().as_secs_f64();
            let c =
                cosine(&sol.jacobian.as_ref().unwrap().data, &jk.data);
            (dt, c)
        } else {
            (f64::NAN, f64::NAN)
        };

        // --- Unrolled PGD (simplex projection; dx/dy Jacobian) — capped
        // at moderate n (it builds an n×n Jacobian by n reverse sweeps).
        let (t_unr, mem) = if n <= 1000 {
            let y: Vec<f64> = sq.q.iter().map(|&v| -v / 2.0).collect();
            let t0 = Instant::now();
            let r = unrolled::unrolled_sparsemax(&y, 0.25, 500, tol);
            (t0.elapsed().as_secs_f64(), r.peak_stored_floats)
        } else {
            (f64::NAN, 0)
        };

        let fmt = |v: f64| {
            if v.is_nan() {
                "-".to_string()
            } else {
                format!("{v:.3}")
            }
        };
        t.row(&[
            n.to_string(),
            (2 * n).to_string(),
            fmt(t_opt),
            fmt(t_unr),
            if mem > 0 { format!("{mem}") } else { "-".into() },
            format!("{t_alt:.4}"),
            format!("{}", solver.uses_sherman_morrison()),
            sol.iters.to_string(),
            if cos.is_nan() {
                "-".into()
            } else {
                format!("{cos:.4}")
            },
        ]);
    }
    t.print();
    let csv = t.write_csv("table4_sparsemax").unwrap();
    println!("\ncsv: {csv}");
    println!(
        "paper claims: optnet blows up on sparse problems; alt-diff scales \
         ~linearly via the Table-3 closed form; cosine ≈ 0.998"
    );
}
