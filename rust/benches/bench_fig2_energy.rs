//! Fig. 2 reproduction: energy generation scheduling — loss curves and
//! average running time for Alt-Diff at tolerances 1e-1/1e-2/1e-3 vs the
//! simulated CvxpyLayer pipeline (paper §5.2).

use altdiff::train::{train_energy, EnergyBackend, EnergyConfig};
use altdiff::util::{Args, Table};

fn main() {
    let args = Args::parse();
    let epochs = args.get_usize("epochs", if args.has("quick") { 4 } else { 12 });
    let days = args.get_usize("days", if args.has("quick") { 10 } else { 30 });

    let backends = [
        EnergyBackend::AltDiff(1e-1),
        EnergyBackend::AltDiff(1e-2),
        EnergyBackend::AltDiff(1e-3),
        EnergyBackend::CvxpyLayerSim,
    ];
    let reports: Vec<_> = backends
        .iter()
        .map(|&b| {
            train_energy(&EnergyConfig {
                backend: b,
                epochs,
                days,
                seed: 3,
                ..Default::default()
            })
        })
        .collect();

    let mut t = Table::new(
        "Fig 2a — decision loss per epoch",
        &["epoch", "alt 1e-1", "alt 1e-2", "alt 1e-3", "cvxpy-sim"],
    );
    for e in 0..epochs {
        t.row(&[
            e.to_string(),
            format!("{:.3}", reports[0].losses[e]),
            format!("{:.3}", reports[1].losses[e]),
            format!("{:.3}", reports[2].losses[e]),
            format!("{:.3}", reports[3].losses[e]),
        ]);
    }
    t.print();
    t.write_csv("fig2a_energy_loss").unwrap();

    let mut t2 = Table::new(
        "Fig 2b — average epoch time (s) & layer iterations",
        &["backend", "time/epoch", "mean layer iters"],
    );
    for r in &reports {
        t2.row(&[
            r.config_label.clone(),
            format!(
                "{:.4}",
                r.epoch_times.iter().sum::<f64>()
                    / r.epoch_times.len() as f64
            ),
            format!("{:.1}", r.mean_iters),
        ]);
    }
    t2.print();
    t2.write_csv("fig2b_energy_time").unwrap();

    let l3 = *reports[2].losses.last().unwrap();
    let lc = *reports[3].losses.last().unwrap();
    let talt: f64 = reports[0].epoch_times.iter().sum();
    let tcvx: f64 = reports[3].epoch_times.iter().sum();
    println!("\npaper claims: losses nearly coincide across tolerances;");
    println!("  final loss alt(1e-3) {l3:.3} vs cvxpy-sim {lc:.3}");
    println!(
        "  alt-diff(1e-1) speedup over cvxpylayer-sim: {:.1}x",
        tcvx / talt.max(1e-12)
    );
}
