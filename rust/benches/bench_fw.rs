//! Frank–Wolfe vs Alt-Diff vs ADMM iterations-to-KKT-target on the
//! vertex-enumerable structures FW serves — the offline analogue of the
//! three-family cross-method router. Each cell probes all three batched
//! families with fixed-k launches up an iteration ladder (exactly the
//! router's calibration procedure) and records the smallest rung whose
//! batch-max KKT residual clears the target: FW pays no factorization
//! and no projection per iteration, so on LMO-friendly geometry its
//! rung-for-rung wall cost is a different trade than the splitting
//! families'.
//!
//! Grid: structure ∈ {box, simplex, ℓ1 (n = 10, 2ⁿ facets)} ×
//! n ∈ {32, 128} × B ∈ {1, 8}. Every cell asserts FW *converges* at
//! some rung (the serving bar for `register_fw`); which family wins the
//! rung race is reported, not asserted — that is the router's call.
//!
//! Run: cargo bench --bench bench_fw [-- --quick|--smoke]
//!      [--sizes 32,128] [--batches 1,8]
//!
//! `--smoke` runs a tiny CI-sized grid (seconds) and skips the
//! repo-root baseline write; full runs refresh `BENCH_fw.json` at the
//! repository root (the committed perf trajectory).

use altdiff::admm::{AdmmQp, AdmmSettings, BatchedAdmm};
use altdiff::altdiff::{BackwardMode, DenseAltDiff, Options};
use altdiff::batch::{BatchSolution, BatchedAltDiff};
use altdiff::fw::{BatchedFw, FwQp};
use altdiff::prob::{box_qp, l1_ball_qp, simplex_qp, Qp};
use altdiff::util::{Args, JsonReport, Stats, Table};
use std::time::Instant;

/// The compiled-artifact contract: exactly k iterations, no early exit.
fn fixed_k(k: usize) -> Options {
    Options {
        rho: 1.0,
        tol: 0.0,
        max_iter: k,
        backward: BackwardMode::None,
        trace: false,
    }
}

enum Fam {
    Alt(BatchedAltDiff),
    Admm(BatchedAdmm),
    Fw(BatchedFw),
}

impl Fam {
    /// One fixed-k launch of B replicas of the registered θ.
    fn launch(&self, bsz: usize, opts: &Options) -> BatchSolution {
        let q = match self {
            Fam::Alt(b) => b.qp.q.clone(),
            Fam::Admm(b) => b.qp.q.clone(),
            Fam::Fw(b) => b.qp.q.clone(),
        };
        let qs: Vec<&[f64]> = (0..bsz).map(|_| q.as_slice()).collect();
        match self {
            Fam::Alt(b) => b.solve_batch(Some(&qs), None, None, opts),
            Fam::Admm(b) => b.solve_batch(Some(&qs), None, None, opts),
            Fam::Fw(b) => b.solve_batch(Some(&qs), None, None, opts),
        }
    }
}

/// Batch-max KKT residual against the cell's problem.
fn batch_residual(qp: &Qp, sol: &BatchSolution) -> f64 {
    (0..sol.len())
        .map(|e| qp.kkt_residual(&sol.xs[e], &sol.lams[e], &sol.nus[e]))
        .fold(0.0, f64::max)
}

/// Probe up the ladder; return (winning rung, converged?, residual
/// there). A family that never clears the target reports the top rung.
fn calibrate(
    fam: &Fam,
    qp: &Qp,
    bsz: usize,
    ladder: &[usize],
    target: f64,
) -> (usize, bool, f64) {
    let mut last = (ladder[0], false, f64::INFINITY);
    for &k in ladder {
        let sol = fam.launch(bsz, &fixed_k(k));
        let res = batch_residual(qp, &sol);
        last = (k, res <= target, res);
        if res <= target {
            return last;
        }
    }
    last
}

/// Median wall seconds of `reps` launches at the winning rung.
fn time_at(fam: &Fam, bsz: usize, k: usize, reps: usize) -> Stats {
    let opts = fixed_k(k);
    let secs: Vec<f64> = (0..reps)
        .map(|_| {
            let t0 = Instant::now();
            let _ = fam.launch(bsz, &opts);
            t0.elapsed().as_secs_f64()
        })
        .collect();
    Stats::from_samples(&secs)
}

fn main() {
    let args = Args::parse();
    let smoke = args.has("smoke");
    let quick = args.has("quick");
    let default_sizes: &[usize] = if smoke {
        &[16]
    } else if quick {
        &[32]
    } else {
        &[32, 128]
    };
    let default_batches: &[usize] = if smoke { &[1, 4] } else { &[1, 8] };
    let sizes = args.get_usize_list("sizes", default_sizes);
    let batches = args.get_usize_list("batches", default_batches);
    let ladder: &[usize] =
        if smoke { &[16, 128, 1024] } else { &[16, 64, 256, 2048] };
    let reps = if smoke { 1 } else { 3 };

    // (structure label, problem); the ℓ1 ball enumerates all 2ⁿ sign
    // facets, so its dimension is pinned small independent of --sizes
    let mut cells: Vec<(&str, Qp)> = Vec::new();
    for &n in &sizes {
        cells.push(("box", box_qp(n, 42 + n as u64)));
        cells.push(("simplex", simplex_qp(n, 1.0, 42 + n as u64)));
    }
    cells.push(("l1", l1_ball_qp(10, 1.5, 42)));

    let mut t = Table::new(
        &format!(
            "FW vs Alt-Diff vs ADMM — iterations to KKT target \
             (fixed-k ladder {ladder:?}, LMO structures)"
        ),
        &[
            "set", "n", "B", "fw k", "alt k", "admm k", "fw (s)",
            "alt (s)", "admm (s)",
        ],
    );
    let mut json = JsonReport::new("fw");

    for (set, qp) in &cells {
        let n = qp.n();
        let qmax = qp.q.iter().fold(0.0f64, |a, &v| a.max(v.abs()));
        let target = 1e-5 * (1.0 + qmax);
        let fw = Fam::Fw(BatchedFw::from_single(
            &FwQp::new(qp.clone(), 1.0).unwrap(),
        ));
        let alt = Fam::Alt(BatchedAltDiff::from_dense(
            &DenseAltDiff::new(qp.clone(), 1.0).unwrap(),
        ));
        let adm = Fam::Admm(BatchedAdmm::from_single(
            &AdmmQp::new_adapted(qp.clone(), 1.0, AdmmSettings::default())
                .unwrap(),
        ));
        for &bsz in &batches {
            let (fk, fconv, fres) =
                calibrate(&fw, qp, bsz, ladder, target);
            // the serving bar: a structure register_fw accepts must be
            // servable — FW has to clear the target at some rung
            assert!(
                fconv,
                "FW did not converge on {set} n={n} B={bsz}: \
                 k={fk} res {fres:.2e} (target {target:.2e})"
            );
            let (ak, aconv, _) = calibrate(&alt, qp, bsz, ladder, target);
            let (mk, mconv, _) = calibrate(&adm, qp, bsz, ladder, target);
            let fst = time_at(&fw, bsz, fk, reps);
            let ast = time_at(&alt, bsz, ak, reps);
            let mst = time_at(&adm, bsz, mk, reps);
            let mark = |k: usize, conv: bool| {
                if conv {
                    k.to_string()
                } else {
                    format!(">{k}")
                }
            };
            t.row(&[
                set.to_string(),
                n.to_string(),
                bsz.to_string(),
                mark(fk, fconv),
                mark(ak, aconv),
                mark(mk, mconv),
                format!("{:.4}", fst.median),
                format!("{:.4}", ast.median),
                format!("{:.4}", mst.median),
            ]);
            json.entry(
                &[
                    ("set", *set),
                    ("n", &n.to_string()),
                    ("B", &bsz.to_string()),
                ],
                &fst,
                &[
                    ("fw_k", fk as f64),
                    ("alt_k", ak as f64),
                    ("admm_k", mk as f64),
                    ("fw_converged", f64::from(u8::from(fconv))),
                    ("alt_converged", f64::from(u8::from(aconv))),
                    ("admm_converged", f64::from(u8::from(mconv))),
                    ("fw_median", fst.median),
                    ("alt_median", ast.median),
                    ("admm_median", mst.median),
                    ("kkt_target", target),
                ],
            );
        }
    }
    t.print();
    t.write_csv("fw").unwrap();
    match json.write() {
        Ok(path) => println!("machine-readable results: {path}"),
        Err(e) => eprintln!("json write failed: {e}"),
    }
    if !smoke {
        match json.write_repo_root() {
            Ok(path) => println!("perf baseline: {path}"),
            Err(e) => eprintln!("baseline write failed: {e}"),
        }
    }
    println!(
        "claims: on every vertex-enumerable cell the FW family clears \
         the KKT target at some ladder rung (asserted above — the bar \
         `register_fw` relies on), paying no factorization and no \
         projection per iteration; which family wins each (structure, \
         tolerance) cell is the three-way decision `register_routed` \
         calibrates and the `router_fw_picks` counter exposes in \
         `serve` stats."
    );
}
