//! Serving bench (ours): coordinator latency/throughput across batch
//! policies and backends — the systems contribution of this repo.
//!
//! Sweeps max_batch and measures steady-state throughput on a mixed
//! request trace (two layer sizes, three tolerances), PJRT-compiled vs
//! native backends.

use altdiff::coordinator::{Config, Coordinator, Reply};
use altdiff::prob::dense_qp;
use altdiff::util::{Args, Pcg64, Table};
use std::path::Path;
use std::time::{Duration, Instant};

fn run_trace(
    artifacts: Option<std::path::PathBuf>,
    max_batch: usize,
    nreq: usize,
) -> (f64, f64, u64, u64) {
    let qp16 = dense_qp(16, 8, 4, 1);
    let qp64 = dense_qp(64, 32, 12, 2);
    let mut coord = Coordinator::builder(Config {
        workers: 2,
        max_batch,
        batch_timeout_us: 2_000,
        artifacts,
        ..Default::default()
    })
    .register("qp16", qp16.clone(), 1.0)
    .unwrap()
    .register("qp64", qp64.clone(), 1.0)
    .unwrap()
    .start();
    coord.wait_ready(Duration::from_secs(180));

    let mut rng = Pcg64::new(7);
    let tols = [1e-1, 1e-2, 1e-3];
    let t0 = Instant::now();
    for i in 0..nreq {
        let tol = tols[rng.below(3)];
        let s = 1.0 + 0.1 * rng.normal();
        if i % 3 == 0 {
            coord.submit(
                "qp64",
                qp64.q.iter().map(|&v| v * s).collect(),
                qp64.b.clone(),
                qp64.h.clone(),
                tol,
            );
        } else {
            coord.submit(
                "qp16",
                qp16.q.iter().map(|&v| v * s).collect(),
                qp16.b.clone(),
                qp16.h.clone(),
                tol,
            );
        }
    }
    let mut lat_sum = 0.0;
    let mut got = 0;
    while got < nreq {
        match coord.recv_timeout(Duration::from_secs(120)) {
            Some(Reply::Ok(r)) => {
                lat_sum += r.latency;
                got += 1;
            }
            Some(Reply::Err(_)) => got += 1,
            Some(Reply::Grad(_)) => got += 1,
            None => break,
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let pjrt = coord
        .metrics
        .pjrt_execs
        .load(std::sync::atomic::Ordering::Relaxed);
    let batches = coord
        .metrics
        .batches
        .load(std::sync::atomic::Ordering::Relaxed);
    (
        got as f64 / wall,
        lat_sum / got.max(1) as f64 * 1e3,
        pjrt,
        batches,
    )
}

fn main() {
    let args = Args::parse();
    let nreq = args.get_usize("requests", if args.has("quick") { 100 } else { 400 });
    let artifacts = {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.tsv").exists().then_some(dir)
    };

    let mut t = Table::new(
        &format!("Serving — batching policy sweep ({nreq} requests, 2 workers)"),
        &[
            "backend", "max_batch", "throughput (req/s)", "mean lat (ms)",
            "pjrt execs", "batches",
        ],
    );
    for &mb in &[1usize, 4, 8] {
        if let Some(dir) = artifacts.clone() {
            let (thr, lat, pjrt, batches) =
                run_trace(Some(dir), mb, nreq);
            t.row(&[
                "pjrt".into(),
                mb.to_string(),
                format!("{thr:.0}"),
                format!("{lat:.1}"),
                pjrt.to_string(),
                batches.to_string(),
            ]);
        }
        let (thr, lat, _, batches) = run_trace(None, mb, nreq);
        t.row(&[
            "native".into(),
            mb.to_string(),
            format!("{thr:.0}"),
            format!("{lat:.1}"),
            "0".into(),
            batches.to_string(),
        ]);
    }
    t.print();
    t.write_csv("serving").unwrap();
    println!(
        "\nclaims: batching raises compiled-path throughput; the truncation \
         router keeps loose-tolerance requests on small-k executables."
    );
}
