//! Table 5 reproduction: constrained Softmax layers (general convex
//! objective −yᵀx + Σ x log x, simplex + box constraints).
//!
//! OptNet cannot express this layer (quadratic-only) — the paper compares
//! only against CvxpyLayer. Alt-Diff runs the inner-Newton path with the
//! Sherman–Morrison Hessian solve (diag(1/x) + 2ρI + ρ11ᵀ, paper Table 3).
//! The CvxpyLayer comparator here is the embedded-QP conic pipeline on a
//! local quadratic model of the entropy objective at the solution — it
//! prices the *pipeline* (embedded sizes, full-dimension backward), which
//! is what the paper's timing rows measure.

use altdiff::altdiff::{BackwardMode, NewtonAltDiff, Options, Param};
use altdiff::baselines::conic;
use altdiff::linalg::{cosine, Mat};
use altdiff::prob::{softmax_layer, EntropyObjective, Qp};
use altdiff::sparse::Csr;
use altdiff::util::{Args, Table};
use std::time::Instant;

fn build_layer(n: usize, seed: u64) -> NewtonAltDiff<EntropyObjective> {
    let (y, u) = softmax_layer(n, seed);
    let ones: Vec<(usize, usize, f64)> =
        (0..n).map(|j| (0, j, 1.0)).collect();
    let a = Csr::from_triplets(1, n, &ones);
    let mut gt = Vec::new();
    for i in 0..n {
        gt.push((i, i, -1.0));
        gt.push((n + i, i, 1.0));
    }
    let g = Csr::from_triplets(2 * n, n, &gt);
    let mut h = vec![0.0; 2 * n];
    for i in 0..n {
        h[n + i] = u[i];
    }
    NewtonAltDiff::new(EntropyObjective { y }, a, vec![1.0], g, h, 1.0)
        .unwrap()
}

fn main() {
    let args = Args::parse();
    let sizes: Vec<usize> = if args.has("quick") {
        vec![50, 100]
    } else {
        vec![100, 300, 500, 1000]
    };
    let tol = args.get_f64("tol", 1e-3);
    let cvx_cap = args.get_usize("cvx-cap", 500);

    let mut t = Table::new(
        &format!("Table 5 — constrained softmax layers (tol={tol:.0e})"),
        &[
            "n", "cvxpy(s)", "cvx-init", "cvx-fwd", "cvx-bwd",
            "altdiff(s)", "iters", "cos-dist(local-QP)",
        ],
    );

    for &n in &sizes {
        let layer = build_layer(n, 11);

        let t0 = Instant::now();
        let sol = layer.solve(&Options {
            tol,
            backward: BackwardMode::Forward(Param::Q),
            max_iter: 10_000,
            ..Default::default()
        });
        let t_alt = t0.elapsed().as_secs_f64();

        // CvxpyLayer comparator: conic pipeline on the local quadratic
        // model at x*: P = diag(1/x*), q chosen so the optimum matches.
        let (t_cvx, ph, cos) = if n <= cvx_cap {
            let pdiag: Vec<f64> =
                sol.x.iter().map(|&v| 1.0 / v.max(1e-9)).collect();
            let qp = Qp {
                p: Mat::diag(&pdiag),
                q: layer.obj.y.iter().map(|&v| -v).collect(),
                a: layer.a.to_dense(),
                b: layer.b.clone(),
                g: layer.g.to_dense(),
                h: layer.h.clone(),
            };
            let res = conic::cvxpylayer_sim(&qp, Param::Q, tol).unwrap();
            let c = cosine(
                &sol.jacobian.as_ref().unwrap().data,
                &res.jacobian.data,
            );
            (res.phases.total(), res.phases, c)
        } else {
            (f64::NAN, conic::Phases { canon: f64::NAN, init: f64::NAN, forward: f64::NAN, backward: f64::NAN }, f64::NAN)
        };

        let fmt = |v: f64| {
            if v.is_nan() {
                "-".to_string()
            } else {
                format!("{v:.3}")
            }
        };
        t.row(&[
            n.to_string(),
            fmt(t_cvx),
            fmt(ph.init + ph.canon),
            fmt(ph.forward),
            fmt(ph.backward),
            format!("{t_alt:.4}"),
            sol.iters.to_string(),
            if cos.is_nan() {
                "-".into()
            } else {
                format!("{cos:.3}")
            },
        ]);
    }
    t.print();
    let csv = t.write_csv("table5_softmax").unwrap();
    println!("\ncsv: {csv}");
    println!(
        "paper claims: alt-diff beats cvxpylayer on general convex \
         objectives, increasingly with n; optnet not applicable"
    );
}
