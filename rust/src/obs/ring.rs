//! The trace ring: a fixed-capacity, lock-striped buffer of finished
//! [`TraceEvent`]s, drained as JSON-lines by `GET /trace`.
//!
//! Writers (execution workers) hash a request id to one of a small
//! power-of-two set of stripes and take only that stripe's mutex, so
//! concurrent workers almost never contend; each stripe is a bounded
//! `VecDeque` that drops its oldest event when full (newest-wins, with
//! a dropped counter). The reader (`/trace`) drains every stripe and
//! merges by id. Total memory is bounded by construction: capacity
//! events, each holding at most the solver's iteration count of
//! 24-byte samples.

use std::collections::VecDeque;
use std::sync::Mutex;

use super::stamps::{StageStamps, SPAN_LABELS};

/// One recorded solver iteration of one traced request.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct IterSample {
    /// Iteration index (0-based).
    pub iter: u32,
    /// Constraint-violation norm ‖(Ax−b, Gx+s−h)‖₂ at the new iterate.
    pub primal: f64,
    /// Scaled iterate step ρ‖x_{k+1}−x_k‖₂ (dual-residual surrogate).
    pub dual: f64,
}

/// One traced request: identity, routing outcome, stage spans, and the
/// per-iteration residual series (empty on the compiled/PJRT path,
/// which exposes no per-iteration state).
#[derive(Clone, Debug)]
pub struct TraceEvent {
    /// Coordinator-assigned request id.
    pub id: u64,
    /// Layer the request solved against.
    pub layer: String,
    /// Executing backend label (`"native"`, `"native-admm"`, …).
    pub backend: &'static str,
    /// Priority-class label (`"high"` / `"normal"` / `"low"`).
    pub class: &'static str,
    /// Truncation rung the router chose.
    pub k: usize,
    /// Size of the batch this request executed in.
    pub batch: usize,
    /// Whether this was a gradient (VJP) request.
    pub grad: bool,
    /// The request's stage stamps as of trace capture (exec-end; the
    /// reply-written stamp happens after capture by construction).
    pub stamps: StageStamps,
    /// Per-iteration residuals recorded by the engine observer.
    pub iters: Vec<IterSample>,
}

/// JSON-escape + format an f64 (non-finite → `null`, which keeps every
/// emitted line machine-parseable).
fn json_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v:e}")
    } else {
        "null".to_string()
    }
}

fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

impl TraceEvent {
    /// Render one JSON-lines record (no trailing newline).
    pub fn render_jsonl(&self) -> String {
        let spans = self.stamps.spans_us();
        let mut out = String::with_capacity(128 + 48 * self.iters.len());
        out.push_str(&format!(
            "{{\"id\":{},\"layer\":{},\"backend\":{},\"class\":{},\
             \"k\":{},\"batch\":{},\"grad\":{}",
            self.id,
            json_str(&self.layer),
            json_str(self.backend),
            json_str(self.class),
            self.k,
            self.batch,
            self.grad,
        ));
        out.push_str(",\"stages_us\":{");
        for (i, name) in SPAN_LABELS.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!("{}:{}", json_str(name), spans[i]));
        }
        out.push_str("},\"iters\":[");
        for (i, s) in self.iters.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "{{\"iter\":{},\"primal\":{},\"dual\":{}}}",
                s.iter,
                json_f64(s.primal),
                json_f64(s.dual)
            ));
        }
        out.push_str("]}");
        out
    }
}

const STRIPES: usize = 8;

struct Stripe {
    buf: VecDeque<TraceEvent>,
    dropped: u64,
}

/// Fixed-capacity lock-striped ring of [`TraceEvent`]s.
pub struct TraceRing {
    stripes: Vec<Mutex<Stripe>>,
    per_stripe: usize,
}

impl std::fmt::Debug for TraceRing {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TraceRing")
            .field("capacity", &self.capacity())
            .field("len", &self.len())
            .finish()
    }
}

impl TraceRing {
    /// A ring holding at most `capacity` events (rounded up to a
    /// multiple of the stripe count; minimum one event per stripe).
    pub fn new(capacity: usize) -> Self {
        let per_stripe = capacity.div_ceil(STRIPES).max(1);
        let stripes = (0..STRIPES)
            .map(|_| {
                Mutex::new(Stripe {
                    buf: VecDeque::with_capacity(per_stripe),
                    dropped: 0,
                })
            })
            .collect();
        TraceRing { stripes, per_stripe }
    }

    /// Total event capacity.
    pub fn capacity(&self) -> usize {
        self.per_stripe * STRIPES
    }

    /// Events currently buffered.
    pub fn len(&self) -> usize {
        self.stripes
            .iter()
            .map(|s| s.lock().unwrap_or_else(|e| e.into_inner()).buf.len())
            .sum()
    }

    /// True when no events are buffered.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Events evicted unread because their stripe was full.
    pub fn dropped(&self) -> u64 {
        self.stripes
            .iter()
            .map(|s| s.lock().unwrap_or_else(|e| e.into_inner()).dropped)
            .sum()
    }

    /// Record a finished trace. Takes one stripe mutex; evicts that
    /// stripe's oldest event when full.
    pub fn push(&self, ev: TraceEvent) {
        let idx = (ev.id as usize) % STRIPES;
        let mut s =
            self.stripes[idx].lock().unwrap_or_else(|e| e.into_inner());
        if s.buf.len() >= self.per_stripe {
            s.buf.pop_front();
            s.dropped += 1;
        }
        s.buf.push_back(ev);
    }

    /// Drain every buffered event, merged in id order (oldest first).
    pub fn drain(&self) -> Vec<TraceEvent> {
        let mut all: Vec<TraceEvent> = Vec::new();
        for s in &self.stripes {
            let mut s = s.lock().unwrap_or_else(|e| e.into_inner());
            all.extend(s.buf.drain(..));
        }
        all.sort_by_key(|e| e.id);
        all
    }

    /// Drain and render as JSON-lines (one event per `\n`-terminated
    /// line; empty string when no events are buffered).
    pub fn drain_jsonl(&self) -> String {
        let mut out = String::new();
        for ev in self.drain() {
            out.push_str(&ev.render_jsonl());
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::stamps::StageStamps;

    fn ev(id: u64) -> TraceEvent {
        TraceEvent {
            id,
            layer: "qp".to_string(),
            backend: "native",
            class: "normal",
            k: 30,
            batch: 4,
            grad: false,
            stamps: StageStamps::enabled(),
            iters: vec![
                IterSample { iter: 0, primal: 1.5e-2, dual: 3.0e-2 },
                IterSample { iter: 1, primal: 4.0e-3, dual: 8.0e-3 },
            ],
        }
    }

    #[test]
    fn push_drain_roundtrip_in_id_order() {
        let r = TraceRing::new(16);
        for id in [3u64, 1, 2] {
            r.push(ev(id));
        }
        assert_eq!(r.len(), 3);
        let out = r.drain();
        assert_eq!(
            out.iter().map(|e| e.id).collect::<Vec<_>>(),
            vec![1, 2, 3]
        );
        assert!(r.is_empty());
    }

    #[test]
    fn capacity_evicts_oldest_and_counts_drops() {
        let r = TraceRing::new(8); // 1 per stripe
        r.push(ev(0));
        r.push(ev(8)); // same stripe as 0 → evicts it
        assert_eq!(r.dropped(), 1);
        let out = r.drain();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].id, 8);
    }

    #[test]
    fn jsonl_lines_are_well_formed() {
        let r = TraceRing::new(16);
        r.push(ev(7));
        let text = r.drain_jsonl();
        let line = text.trim_end();
        assert!(line.starts_with('{') && line.ends_with('}'));
        assert!(line.contains("\"id\":7"));
        assert!(line.contains("\"stages_us\""));
        assert!(line.contains("\"primal\":1.5e-2"));
        assert!(!line.contains('\n'));
        // balanced braces/brackets (cheap well-formedness proxy)
        let opens = line.matches('{').count();
        let closes = line.matches('}').count();
        assert_eq!(opens, closes);
    }

    #[test]
    fn non_finite_residuals_render_null() {
        let mut e = ev(1);
        e.iters[0].primal = f64::INFINITY;
        let line = e.render_jsonl();
        assert!(line.contains("\"primal\":null"));
    }

    #[test]
    fn layer_names_are_escaped() {
        let mut e = ev(1);
        e.layer = "we\"ird\\name".to_string();
        let line = e.render_jsonl();
        assert!(line.contains("we\\\"ird\\\\name"));
    }
}
