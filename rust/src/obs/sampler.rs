//! The 1-in-N trace sampler: decides at admission which requests are
//! promoted to full solver traces.
//!
//! Deterministic by design — a counter with a seeded phase, not a PRNG
//! draw per request — so (a) the decision costs one `fetch_add`, (b) a
//! fixed workload samples a fixed set of requests (tests and incident
//! replays are reproducible), and (c) the sample rate is exactly 1/N
//! rather than 1/N in expectation.

use std::sync::atomic::{AtomicU64, Ordering};

/// Seeded 1-in-N sampler. `every = 0` disables sampling entirely.
#[derive(Debug)]
pub struct TraceSampler {
    every: u64,
    count: AtomicU64,
}

impl TraceSampler {
    /// Sample every `every`-th request; `seed` shifts the phase so
    /// co-located servers don't all sample the same ordinal positions.
    pub fn new(every: u64, seed: u64) -> Self {
        let phase = if every > 1 { seed % every } else { 0 };
        TraceSampler { every, count: AtomicU64::new(phase) }
    }

    /// A sampler that never samples.
    pub fn off() -> Self {
        TraceSampler::new(0, 0)
    }

    /// The configured period (0 = off).
    pub fn every(&self) -> u64 {
        self.every
    }

    /// Admission-time decision: is this request sampled? Thread-safe,
    /// one relaxed `fetch_add` when enabled, one branch when disabled.
    #[inline]
    pub fn sample(&self) -> bool {
        if self.every == 0 {
            return false;
        }
        self.count.fetch_add(1, Ordering::Relaxed) % self.every == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_period_never_samples() {
        let s = TraceSampler::off();
        assert!((0..1000).all(|_| !s.sample()));
    }

    #[test]
    fn one_in_n_is_exact() {
        let s = TraceSampler::new(4, 0);
        let hits = (0..1000).filter(|_| s.sample()).count();
        assert_eq!(hits, 250);
    }

    #[test]
    fn seed_shifts_the_phase() {
        let a = TraceSampler::new(4, 0);
        let b = TraceSampler::new(4, 1);
        let pa: Vec<bool> = (0..8).map(|_| a.sample()).collect();
        let pb: Vec<bool> = (0..8).map(|_| b.sample()).collect();
        assert_eq!(pa.iter().filter(|&&x| x).count(), 2);
        assert_eq!(pb.iter().filter(|&&x| x).count(), 2);
        assert_ne!(pa, pb);
    }

    #[test]
    fn every_one_samples_everything() {
        let s = TraceSampler::new(1, 7);
        assert!((0..100).all(|_| s.sample()));
    }
}
