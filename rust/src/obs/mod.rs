//! L4.5: the per-request tracing plane.
//!
//! Cumulative counters ([`crate::coordinator::Metrics`]) say *that* p99
//! blew past an SLO; this module says *where the time went* and *what
//! the solver was doing*. Two cooperating mechanisms, both zero-dep and
//! both provably near-free when disabled:
//!
//! - **Stage spans** ([`StageStamps`]): every request carries seven
//!   monotonic-µs stamps (accepted → decoded → enqueued → batch-formed
//!   → exec-start → exec-end → reply-written), stamped at each handoff
//!   by the net front end, the shard router, the batcher, and the
//!   execution workers. Each `stamp()` is a single branch on a per-record
//!   flag fixed at admission from [`Config::stamps`]
//!   (crate::coordinator::Config); with the flag off the record never
//!   mutates and replies stay byte-identical to the pre-tracing wire.
//!   Stage durations feed per-(stage × priority-class) Prometheus
//!   histograms and an opt-in reply echo the load generator reconciles
//!   against client-observed latency.
//!
//! - **Sampled deep traces** ([`TraceSampler`] + [`IterObserver`] +
//!   [`TraceRing`]): a seeded 1-in-N sampler promotes requests to full
//!   traces. The engines call a per-iteration observer hook that records
//!   primal/dual residuals for watched batch elements only — the
//!   unsampled path pays one `Option` branch per iteration and allocates
//!   nothing. Finished traces land in a fixed-capacity lock-striped ring
//!   and drain as JSON-lines from `GET /trace` on the serving port.
//!
//! The paper's Thm 4.3 bounds the Jacobian error by the iterate error,
//! so the residual trajectory in a trace is exactly the evidence needed
//! to pick the truncation rung k — see DESIGN.md §"Observability".

pub mod ring;
pub mod sampler;
pub mod stamps;

pub use ring::{IterSample, TraceEvent, TraceRing};
pub use sampler::TraceSampler;
pub use stamps::{
    now_us, sum_spans_us, Stage, StageSpans, StageStamps, N_SPANS,
    SPAN_LABELS,
};

/// Per-iteration solver hook. Engines call [`IterObserver::wants`] once
/// per live batch element per iteration and compute the (relatively
/// expensive) KKT residuals only for elements the observer claims —
/// passing `None` for the observer costs a single branch per iteration
/// and zero allocation.
pub trait IterObserver {
    /// Whether batch element `elem` should be traced this launch.
    fn wants(&self, elem: usize) -> bool;
    /// Record iteration `iter` of element `elem`: `primal` is the
    /// constraint-violation norm ‖(Ax−b, Gx+s−h)‖₂ at the new iterate,
    /// `dual` the scaled iterate step ρ‖x_{k+1}−x_k‖₂ (the standard
    /// ADMM dual-residual surrogate for this splitting).
    fn on_iter(&mut self, elem: usize, iter: usize, primal: f64, dual: f64);
}

/// The coordinator-side [`IterObserver`]: collects residual series for
/// the sampled elements of one batch launch, to be packaged into
/// [`TraceEvent`]s after the launch returns.
#[derive(Debug)]
pub struct TraceCollector {
    slots: Vec<Option<Vec<IterSample>>>,
}

impl TraceCollector {
    /// A collector for a batch of `batch` elements, watching none.
    pub fn new(batch: usize) -> Self {
        TraceCollector { slots: vec![None; batch] }
    }

    /// Mark element `elem` as sampled (its residuals will be recorded).
    pub fn watch(&mut self, elem: usize) {
        self.slots[elem] = Some(Vec::new());
    }

    /// Whether any element is being watched (skip the observer pass
    /// entirely — and the collector itself — when false).
    pub fn any(&self) -> bool {
        self.slots.iter().any(|s| s.is_some())
    }

    /// Take element `elem`'s recorded series (None if unwatched).
    pub fn take(&mut self, elem: usize) -> Option<Vec<IterSample>> {
        self.slots[elem].take()
    }
}

impl IterObserver for TraceCollector {
    fn wants(&self, elem: usize) -> bool {
        self.slots.get(elem).is_some_and(|s| s.is_some())
    }

    fn on_iter(&mut self, elem: usize, iter: usize, primal: f64, dual: f64) {
        if let Some(Some(buf)) = self.slots.get_mut(elem) {
            buf.push(IterSample { iter: iter as u32, primal, dual });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collector_watches_only_marked_elements() {
        let mut c = TraceCollector::new(3);
        assert!(!c.any());
        c.watch(1);
        assert!(c.any());
        assert!(!c.wants(0) && c.wants(1) && !c.wants(2));
        c.on_iter(1, 0, 1.0, 2.0);
        c.on_iter(1, 1, 0.5, 1.0);
        c.on_iter(0, 0, 9.0, 9.0); // unwatched: dropped
        let s = c.take(1).unwrap();
        assert_eq!(s.len(), 2);
        assert_eq!(s[0].iter, 0);
        assert_eq!(s[1].primal, 0.5);
        assert!(c.take(0).is_none());
        assert!(c.take(1).is_none()); // taken
    }

    #[test]
    fn out_of_range_elem_is_ignored() {
        let mut c = TraceCollector::new(1);
        assert!(!c.wants(5));
        c.on_iter(5, 0, 1.0, 1.0); // no panic
    }
}
