//! Stage stamps: a fixed-size record of monotonic-µs handoff times
//! carried by every [`Request`](crate::coordinator::Request).
//!
//! All stamps are µs offsets from one process-wide monotonic anchor
//! (first use of [`now_us`]), so stamps taken on different threads are
//! directly comparable and differences are wall-clock stage durations.
//! The record is `Copy` (64 bytes + flag) and every mutation is gated
//! on a flag fixed at construction — the disabled record is inert, which
//! is the whole overhead contract: tracing off costs one predictable
//! branch per stamp site.

use std::sync::OnceLock;
use std::time::Instant;

static ANCHOR: OnceLock<Instant> = OnceLock::new();

/// Microseconds since the process-wide monotonic anchor. The anchor is
/// fixed on first call; all threads share it.
pub fn now_us() -> u64 {
    ANCHOR.get_or_init(Instant::now).elapsed().as_micros() as u64
}

/// The seven handoff points of a request's life, in path order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stage {
    /// First byte of the frame read off the socket.
    Accepted = 0,
    /// Wire payload decoded into a [`Request`](crate::coordinator::Request).
    Decoded = 1,
    /// Admitted into a shard queue by the coordinator.
    Enqueued = 2,
    /// Emitted from the batcher as part of a formed batch.
    BatchFormed = 3,
    /// Execution worker picked the batch up (pre-solve).
    ExecStart = 4,
    /// Solve finished, reply constructed.
    ExecEnd = 5,
    /// Reply encoded into the connection's write buffer.
    ReplyWritten = 6,
}

/// Number of stages (and stamp slots).
pub const N_STAGES: usize = 7;

/// Number of inter-stage durations (`N_STAGES − 1`).
pub const N_SPANS: usize = 6;

/// Short label for the span *ending* at stage `i + 1` — the Prometheus
/// `stage` label and the loadgen table row name.
pub const SPAN_LABELS: [&str; N_SPANS] =
    ["decode", "admit", "queue", "sched", "exec", "write"];

/// The six inter-stage durations in µs, as echoed on the wire and fed
/// to the per-(stage × class) histograms.
pub type StageSpans = [u32; N_SPANS];

/// The per-request stamp record. Inert (never mutates) unless built
/// with [`StageStamps::enabled`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StageStamps {
    on: bool,
    t: [u64; N_STAGES],
}

impl Default for StageStamps {
    fn default() -> Self {
        StageStamps::off()
    }
}

impl StageStamps {
    /// A disabled record: `stamp` is a no-op, all slots stay unset.
    pub fn off() -> Self {
        StageStamps { on: false, t: [0; N_STAGES] }
    }

    /// An enabled record with no stamps taken yet.
    pub fn enabled() -> Self {
        StageStamps { on: true, t: [0; N_STAGES] }
    }

    /// Whether this record stamps at all.
    pub fn is_on(&self) -> bool {
        self.on
    }

    /// Record `stage` at the current monotonic time. Single branch when
    /// disabled; later stamps of the same stage overwrite.
    #[inline]
    pub fn stamp(&mut self, stage: Stage) {
        if self.on {
            self.t[stage as usize] = now_us().max(1);
        }
    }

    /// The stamp for `stage`, if taken (µs since the anchor).
    pub fn get(&self, stage: Stage) -> Option<u64> {
        match self.t[stage as usize] {
            0 => None,
            v => Some(v),
        }
    }

    /// True when every *taken* stamp is non-decreasing in stage order.
    /// Unset slots (e.g. no net front end → no `Accepted`) are skipped.
    pub fn monotone(&self) -> bool {
        let mut prev = 0u64;
        for &v in &self.t {
            if v == 0 {
                continue;
            }
            if v < prev {
                return false;
            }
            prev = v;
        }
        true
    }

    /// Span durations in µs: slot `i` is `t[i+1] − t[i]`, or 0 when
    /// either endpoint is unset (the span never happened on this path)
    /// or the pair is out of order. Saturates at `u32::MAX` (~71 min).
    pub fn spans_us(&self) -> [u32; N_SPANS] {
        let mut d = [0u32; N_SPANS];
        for i in 0..N_SPANS {
            let (a, b) = (self.t[i], self.t[i + 1]);
            if a != 0 && b >= a {
                d[i] = (b - a).min(u32::MAX as u64) as u32;
            }
        }
        d
    }

    /// First-to-last taken stamp, µs (0 if fewer than two stamps).
    pub fn total_us(&self) -> u64 {
        let taken: Vec<u64> =
            self.t.iter().copied().filter(|&v| v != 0).collect();
        match (taken.first(), taken.last()) {
            (Some(&a), Some(&b)) if b >= a => b - a,
            _ => 0,
        }
    }
}

/// Sum of span durations — the server-side attributed latency a client
/// reconciles its observed RTT against.
pub fn sum_spans_us(spans: &[u32; N_SPANS]) -> u64 {
    spans.iter().map(|&d| d as u64).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_record_is_inert() {
        let mut s = StageStamps::off();
        s.stamp(Stage::Accepted);
        s.stamp(Stage::ReplyWritten);
        assert_eq!(s, StageStamps::off());
        assert_eq!(s.spans_us(), [0; N_SPANS]);
        assert_eq!(s.total_us(), 0);
        assert!(s.monotone());
    }

    #[test]
    fn stamps_are_monotone_and_spans_reconcile() {
        let mut s = StageStamps::enabled();
        s.stamp(Stage::Accepted);
        s.stamp(Stage::Decoded);
        s.stamp(Stage::Enqueued);
        s.stamp(Stage::BatchFormed);
        s.stamp(Stage::ExecStart);
        s.stamp(Stage::ExecEnd);
        s.stamp(Stage::ReplyWritten);
        assert!(s.monotone());
        let spans = s.spans_us();
        assert_eq!(sum_spans_us(&spans), s.total_us());
    }

    #[test]
    fn unset_interior_stamp_zeroes_adjacent_spans() {
        // In-process submission: no net front end, Accepted/Decoded unset.
        let mut s = StageStamps::enabled();
        s.stamp(Stage::Enqueued);
        s.stamp(Stage::BatchFormed);
        let spans = s.spans_us();
        assert_eq!(spans[0], 0); // accepted→decoded: both unset
        assert_eq!(spans[1], 0); // decoded→enqueued: start unset
        assert!(s.monotone());
        assert_eq!(sum_spans_us(&spans), s.total_us());
    }

    #[test]
    fn now_us_is_monotone() {
        let a = now_us();
        let b = now_us();
        assert!(b >= a);
    }
}
