//! Registration-time state shared by the ADMM engines: the stacked
//! constraint operator C = [A; G] and the factorization of
//! K(ρ) = P + ρCᵀC — exactly the H(ρ) the Alt-Diff registration
//! factors, so the two families share conditioning behavior at equal ρ.

use crate::error::Result;
use crate::linalg::{ata, Chol, Mat};
use crate::prob::Qp;

/// Cached stacked-constraint products, built once per registration.
#[derive(Clone)]
pub(crate) struct Stacked {
    /// C = [A; G], ((p+m), n).
    pub c: Mat,
    /// Cᵀ, (n, (p+m)).
    pub ct: Mat,
    /// CᵀC, (n, n) — lets a ρ change reassemble K without re-touching C.
    pub ctc: Mat,
    /// Symmetrized P.
    pub psym: Mat,
}

impl Stacked {
    pub fn new(qp: &Qp) -> Stacked {
        let c = qp.a.vstack(&qp.g);
        let ct = c.transpose();
        let ctc = ata(&c);
        let mut psym = qp.p.clone();
        psym.symmetrize();
        Stacked { c, ct, ctc, psym }
    }

    /// Factor K(ρ) = P + ρCᵀC, with the same PSD ridge retry the
    /// Alt-Diff registration applies to H.
    pub fn factor(&self, rho: f64) -> Result<Chol> {
        let mut k = self.psym.clone();
        k.axpy(rho, &self.ctc);
        match Chol::factor(&k) {
            Ok(ch) => Ok(ch),
            Err(_) => {
                let ridge = 1e-8 * (1.0 + k.fro() / k.rows as f64);
                for i in 0..k.rows {
                    k[(i, i)] += ridge;
                }
                Chol::factor(&k)
            }
        }
    }
}
