//! Single-problem ADMM QP engine — the family sibling of
//! [`DenseAltDiff`](crate::altdiff::DenseAltDiff), same contracts.

use super::stacked::Stacked;
use super::AdmmSettings;
use crate::altdiff::{
    BackwardMode, Options, Param, Solution, TraceEntry, Vjp, VjpSolution,
};
use crate::error::Result;
use crate::linalg::{gemm_acc, gemv_acc, gemv_t_acc, norm2, Chol, Mat};
use crate::prob::Qp;
use crate::warm::{AdmmSeed, WarmStart};

/// A registered ADMM QP layer: one Cholesky of K = P + ρCᵀC at
/// registration (C = [A; G] stacked), reused by every subsequent solve,
/// Jacobian recursion, and adjoint backward.
pub struct AdmmQp {
    /// The registered problem.
    pub qp: Qp,
    /// Penalty ρ the cached factorization was built at. A
    /// registration-time property, like the Alt-Diff engines: per-solve
    /// `opts.rho` is ignored (it would desynchronize the factor).
    pub rho: f64,
    /// Family knobs (over-relaxation α, residual-balancing adaptation).
    pub settings: AdmmSettings,
    pub(crate) stacked: Stacked,
    pub(crate) chol: Chol,
    /// Explicit K⁻¹ — the batched engine consumes it as GEMM panels,
    /// mirroring the dense Alt-Diff `hinv_cache`.
    pub(crate) kinv_cache: Mat,
}

impl AdmmQp {
    /// Register with default [`AdmmSettings`] (α = 1.6, no adaptation).
    pub fn new(qp: Qp, rho: f64) -> Result<AdmmQp> {
        AdmmQp::with_settings(qp, rho, AdmmSettings::default())
    }

    /// Register with explicit family knobs.
    pub fn with_settings(
        qp: Qp,
        rho: f64,
        settings: AdmmSettings,
    ) -> Result<AdmmQp> {
        assert!(
            settings.alpha > 0.0 && settings.alpha < 2.0,
            "over-relaxation alpha must lie in (0, 2)"
        );
        let stacked = Stacked::new(&qp);
        let chol = stacked.factor(rho)?;
        let kinv_cache = chol.inverse();
        Ok(AdmmQp { qp, rho, settings, stacked, chol, kinv_cache })
    }

    /// Register with residual balancing folded into registration: run
    /// one adaptive probe solve on the registered θ, adopt the balanced
    /// ρ it ends at, and refactor once. The returned solver is frozen
    /// (no in-solve adaptation), so serving, the batched engine, and
    /// both differentiation modes all run the same balanced ρ — this is
    /// what the coordinator registers for routed layers.
    pub fn new_adapted(
        qp: Qp,
        rho: f64,
        settings: AdmmSettings,
    ) -> Result<AdmmQp> {
        let probe = AdmmQp::with_settings(
            qp,
            rho,
            AdmmSettings { adaptive_rho: true, ..settings },
        )?;
        let popts = Options {
            rho,
            tol: 1e-10,
            max_iter: 500,
            backward: BackwardMode::None,
            trace: false,
        };
        let rho_star = probe.adapted_rho(&popts);
        if rho_star == probe.rho {
            return Ok(AdmmQp { settings, ..probe });
        }
        let chol = probe.stacked.factor(rho_star)?;
        let kinv_cache = chol.inverse();
        Ok(AdmmQp { rho: rho_star, settings, chol, kinv_cache, ..probe })
    }

    /// The penalty a residual-balancing probe solve of the registered θ
    /// ends at. Returns the registered ρ unchanged unless
    /// `settings.adaptive_rho` is set and `opts` carries no forward-mode
    /// Jacobian (the recursion differentiates a fixed-ρ map).
    pub fn adapted_rho(&self, opts: &Options) -> f64 {
        self.solve_inner(None, None, None, None, opts).1
    }

    /// Solve + differentiate with per-request parameters; `None` means
    /// the registered value. Same contract as
    /// [`DenseAltDiff::solve_with`](crate::altdiff::DenseAltDiff::solve_with).
    pub fn solve_with(
        &self,
        q: Option<&[f64]>,
        b: Option<&[f64]>,
        h: Option<&[f64]>,
        opts: &Options,
    ) -> Solution {
        self.solve_from(q, b, h, None, opts)
    }

    /// [`Self::solve_with`] resuming from a prior iterate triple. The
    /// shared warm format maps onto ADMM state as u = (λ/ρ, ν/ρ) (the
    /// scaled duals), z = (b, min(Gx, h)) against the *requested*
    /// right-hand sides, so a fixed-point triple reproduces itself and
    /// stops in one iteration; `warm = None` is bit-identical to the
    /// cold [`Self::solve_with`]. The forward-mode/tol composition rule
    /// is the same as the Alt-Diff engines' (asserted).
    pub fn solve_from(
        &self,
        q: Option<&[f64]>,
        b: Option<&[f64]>,
        h: Option<&[f64]>,
        warm: Option<&WarmStart>,
        opts: &Options,
    ) -> Solution {
        self.solve_inner(q, b, h, warm, opts).0
    }

    /// Convenience: registered parameters, default θ.
    ///
    /// ```
    /// use altdiff::admm::AdmmQp;
    /// use altdiff::altdiff::Options;
    /// use altdiff::prob::dense_qp;
    ///
    /// let qp = dense_qp(8, 4, 2, 3);
    /// let layer = AdmmQp::new(qp.clone(), 1.0).unwrap();
    /// let sol = layer.solve(&Options::with_tol(1e-9));
    /// let (eq, viol) = qp.feasibility(&sol.x);
    /// assert!(eq < 1e-6 && viol < 1e-6);
    /// assert!(qp.kkt_residual(&sol.x, &sol.lam, &sol.nu) < 1e-5);
    /// // ∂x/∂b rides the same loop (default forward mode), d = p
    /// assert_eq!(sol.jacobian.as_ref().unwrap().cols, 2);
    /// ```
    pub fn solve(&self, opts: &Options) -> Solution {
        self.solve_with(None, None, None, opts)
    }

    /// The full iteration; returns the solution plus the final local ρ
    /// (differs from `self.rho` only when in-solve adaptation adopted a
    /// rebalanced penalty).
    fn solve_inner(
        &self,
        q: Option<&[f64]>,
        b: Option<&[f64]>,
        h: Option<&[f64]>,
        warm: Option<&WarmStart>,
        opts: &Options,
    ) -> (Solution, f64) {
        let n = self.qp.n();
        let m = self.qp.m_ineq();
        let p = self.qp.p_eq();
        let pm = p + m;
        let alpha = self.settings.alpha;
        let q = q.unwrap_or(&self.qp.q);
        let b = b.unwrap_or(&self.qp.b);
        let h = h.unwrap_or(&self.qp.h);

        // ρ and the factor may be rebalanced mid-solve; the registered
        // pair is the starting point
        let mut rho = self.rho;
        let mut chol_local: Option<Chol> = None;

        let mut x = vec![0.0; n];
        let mut z = vec![0.0; pm];
        let mut u = vec![0.0; pm];
        let mut v = vec![0.0; pm];
        if let Some(w) = warm {
            assert!(
                opts.backward.forward_param().is_none() || opts.tol == 0.0,
                "warm starts with forward-mode Jacobians require tol = 0 \
                 (fixed-k); use BackwardMode::None/Adjoint for truncated \
                 warm solves"
            );
            assert_eq!(w.dims(), (n, p, m), "warm-start dimensions");
            x.copy_from_slice(&w.x);
            let mut gx0 = vec![0.0; m];
            gemv_acc(&mut gx0, 1.0, &self.qp.g, &w.x);
            for i in 0..p {
                z[i] = b[i];
                u[i] = w.lam[i] / rho;
            }
            for i in 0..m {
                z[p + i] = gx0[i].min(h[i]);
                u[p + i] = w.nu[i] / rho;
            }
            for i in 0..pm {
                v[i] = z[i] + u[i];
            }
        }

        // Jacobian state, present only in forward mode
        let param = opts.backward.forward_param();
        let d = param.map(|pp| pp.dim(n, m, p));
        let mut jx = d.map(|d| Mat::zeros(n, d));
        let mut jz = d.map(|d| Mat::zeros(pm, d));
        let mut ju = d.map(|d| Mat::zeros(pm, d));
        let mut work = d.map(|d| FwdWork::new(n, pm, d));

        // adaptation only when nothing differentiates the loop: the
        // Jacobian recursion is the derivative of a FIXED-ρ map
        let adapt = self.settings.adaptive_rho && param.is_none();

        let mut trace = Vec::new();
        let mut rhs = vec![0.0; n];
        let mut xprev = vec![0.0; n];
        let mut cx = vec![0.0; pm];
        let mut zu = vec![0.0; pm];
        let mut zprev = vec![0.0; pm];
        let mut ctbuf = vec![0.0; n];
        let mut iters = 0;
        let mut step_rel = f64::INFINITY;

        for k in 0..opts.max_iter {
            iters = k + 1;
            xprev.copy_from_slice(&x);
            if adapt {
                zprev.copy_from_slice(&z);
            }

            // ---- x-update: K x = −q + ρCᵀ(z − u)
            for i in 0..pm {
                zu[i] = z[i] - u[i];
            }
            for i in 0..n {
                rhs[i] = -q[i];
            }
            gemv_t_acc(&mut rhs, rho, &self.stacked.c, &zu);
            x.copy_from_slice(&rhs);
            chol_local
                .as_ref()
                .unwrap_or(&self.chol)
                .solve_in_place(&mut x);

            // ---- relaxation + projection input: v = αCx + (1−α)z + u
            cx.iter_mut().for_each(|ci| *ci = 0.0);
            gemv_acc(&mut cx, 1.0, &self.stacked.c, &x);
            for i in 0..pm {
                v[i] = alpha * cx[i] + (1.0 - alpha) * z[i] + u[i];
            }
            // ---- projection z⁺ = (b, min(v, h)); scaled dual u⁺ = v − z⁺
            for i in 0..p {
                z[i] = b[i];
                u[i] = v[i] - b[i];
            }
            for i in 0..m {
                let zi = v[p + i].min(h[i]);
                z[p + i] = zi;
                u[p + i] = v[p + i] - zi;
            }

            // ---- forward-mode recursion rides the same loop
            if let (Some(jx), Some(jz), Some(ju), Some(w)) =
                (jx.as_mut(), jz.as_mut(), ju.as_mut(), work.as_mut())
            {
                self.jacobian_step(param.unwrap(), alpha, &v, h, jx, jz, ju, w);
            }

            // ---- truncation check (same criterion as Algorithm 1)
            let dx: f64 = x
                .iter()
                .zip(&xprev)
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f64>()
                .sqrt();
            step_rel = dx / norm2(&xprev).max(1.0);
            if opts.trace {
                trace.push(TraceEntry {
                    iter: k,
                    step_rel,
                    jac_norm: jx.as_ref().map(|j| j.fro()).unwrap_or(0.0),
                });
            }
            if step_rel < opts.tol {
                break;
            }

            // ---- residual balancing: ρ ← ρ·√(r_p/r_d) when the primal
            // and dual residuals have drifted apart (checked every
            // adapt_every iterations; adoption refactors locally and
            // rescales u so the unscaled dual y = ρu is invariant)
            if adapt && (k + 1) % self.settings.adapt_every == 0 {
                let mut rp = 0.0;
                for i in 0..pm {
                    let di = cx[i] - z[i];
                    rp += di * di;
                }
                let rp = rp.sqrt() / norm2(&cx).max(norm2(&z)).max(1.0);
                for i in 0..pm {
                    zu[i] = z[i] - zprev[i];
                }
                ctbuf.iter_mut().for_each(|c| *c = 0.0);
                gemv_t_acc(&mut ctbuf, 1.0, &self.stacked.c, &zu);
                let rd_abs = rho * norm2(&ctbuf);
                ctbuf.iter_mut().for_each(|c| *c = 0.0);
                gemv_t_acc(&mut ctbuf, 1.0, &self.stacked.c, &u);
                let rd = rd_abs / (rho * norm2(&ctbuf)).max(1.0);
                if rp > 0.0 && rd > 0.0 {
                    let target = (rho * (rp / rd).sqrt())
                        .clamp(self.settings.rho_min, self.settings.rho_max);
                    let ratio = target / rho;
                    if ratio > self.settings.adapt_threshold
                        || ratio < 1.0 / self.settings.adapt_threshold
                    {
                        // a failed refactorization just skips adoption
                        if let Ok(ch) = self.stacked.factor(target) {
                            let f = rho / target;
                            u.iter_mut().for_each(|ui| *ui *= f);
                            rho = target;
                            chol_local = Some(ch);
                        }
                    }
                }
            }
        }

        // solution mapping: unscaled duals y = ρu, slack from the final
        // projection input (exact zeros on active rows — the same gate
        // convention the Alt-Diff adjoint reads)
        let mut s = vec![0.0; m];
        for i in 0..m {
            s[i] = (h[i] - v[p + i]).max(0.0);
        }
        let lam: Vec<f64> = (0..p).map(|i| rho * u[i]).collect();
        let nu: Vec<f64> = (0..m).map(|i| rho * u[p + i]).collect();
        (
            Solution { x, s, lam, nu, jacobian: jx, iters, step_rel, trace },
            rho,
        )
    }

    /// One forward-mode Jacobian update: the derivative of the fixed-ρ
    /// iteration map at the current projection pattern. `v` is the fresh
    /// projection input (its comparison against `h` is the gate).
    #[allow(clippy::too_many_arguments)]
    fn jacobian_step(
        &self,
        param: Param,
        alpha: f64,
        v: &[f64],
        h: &[f64],
        jx: &mut Mat,
        jz: &mut Mat,
        ju: &mut Mat,
        w: &mut FwdWork,
    ) {
        let n = self.qp.n();
        let m = self.qp.m_ineq();
        let p = self.qp.p_eq();
        let rho = self.rho;
        let d = jx.cols;

        // Jx = K⁻¹(∂(−q)/∂θ + ρCᵀ(Jz − Ju))
        w.jzu.data.fill(0.0);
        w.jzu.axpy(1.0, jz);
        w.jzu.axpy(-1.0, ju);
        w.lrhs.data.fill(0.0);
        gemm_acc(&mut w.lrhs, rho, &self.stacked.ct, &w.jzu);
        if param == Param::Q {
            for i in 0..n.min(d) {
                w.lrhs[(i, i)] -= 1.0;
            }
        }
        w.newjx.data.fill(0.0);
        gemm_acc(&mut w.newjx, 1.0, &self.kinv_cache, &w.lrhs);
        std::mem::swap(jx, &mut w.newjx);

        // Jv = αC Jx + (1−α)Jz + Ju
        w.jv.data.fill(0.0);
        gemm_acc(&mut w.jv, alpha, &self.stacked.c, jx);
        w.jv.axpy(1.0 - alpha, jz);
        w.jv.axpy(1.0, ju);

        // projection rows: Jz⁺ = ∂(projection)/∂θ, Ju⁺ = Jv − Jz⁺
        for r in 0..p {
            jz.row_mut(r).fill(0.0);
            if param == Param::B {
                jz[(r, r)] = 1.0;
            }
            for c in 0..d {
                ju[(r, c)] = w.jv[(r, c)] - jz[(r, c)];
            }
        }
        for i in 0..m {
            let r = p + i;
            if v[r] < h[i] {
                // inactive: the projection passes Jv straight through
                for c in 0..d {
                    jz[(r, c)] = w.jv[(r, c)];
                    ju[(r, c)] = 0.0;
                }
            } else {
                jz.row_mut(r).fill(0.0);
                if param == Param::H {
                    jz[(r, i)] = 1.0;
                }
                for c in 0..d {
                    ju[(r, c)] = w.jv[(r, c)] - jz[(r, c)];
                }
            }
        }
    }

    /// Reverse-mode backward against an already-solved forward pass:
    /// iterate the transposed derivative of the projection/relaxation
    /// map to its fixed point, then project out vᵀ∂x*/∂θ for all three
    /// parameters at once. With t = K⁻¹v, gₛ = ρCt and gate e = 1 on
    /// inactive rows:
    ///
    ///   a  = e ⊙ w_z + (1−e) ⊙ w_u
    ///   Sa = αρ C K⁻¹ Cᵀ a
    ///   w_z ← Sa + (1−α)a + gₛ,    w_u ← a − Sa − gₛ
    ///
    /// Cost per iteration: one Cholesky solve + two gemvs — independent
    /// of the parameter dimension d, O(p+m) state, mirroring the
    /// Alt-Diff adjoint (DESIGN.md §3c). Truncation on w_z (`opts.tol`;
    /// `tol = 0` runs exactly `opts.max_iter` iterations).
    pub fn vjp(&self, slack: &[f64], v: &[f64], opts: &Options) -> Vjp {
        self.vjp_from(slack, v, None, opts).0
    }

    /// [`Self::vjp`] resuming the transposed recursion from a harvested
    /// [`AdmmSeed`] and returning the final state for the next caller —
    /// the family sibling of
    /// [`DenseAltDiff::vjp_from`](crate::altdiff::DenseAltDiff::vjp_from).
    /// `warm = None` is bit-identical to the cold [`Self::vjp`].
    pub fn vjp_from(
        &self,
        slack: &[f64],
        v: &[f64],
        warm: Option<&AdmmSeed>,
        opts: &Options,
    ) -> (Vjp, AdmmSeed) {
        let n = self.qp.n();
        let m = self.qp.m_ineq();
        let p = self.qp.p_eq();
        let pm = p + m;
        let rho = self.rho;
        let alpha = self.settings.alpha;
        assert_eq!(slack.len(), m, "slack dimension");
        assert_eq!(v.len(), n, "v dimension");
        // gate e = 1 on INACTIVE inequality rows (the projection is the
        // identity there); equality and active rows pin z to a constant
        let gate: Vec<f64> = slack
            .iter()
            .map(|&s| if s > 0.0 { 1.0 } else { 0.0 })
            .collect();

        // t = K⁻¹v and the parameter-independent seed g = ρCt (= −g on
        // the u leg)
        let mut t = v.to_vec();
        self.chol.solve_in_place(&mut t);
        let mut seedz = vec![0.0; pm];
        gemv_acc(&mut seedz, rho, &self.stacked.c, &t);

        // first series term, unless a harvested state resumes it
        let mut wz = seedz.clone();
        let mut wu: Vec<f64> = seedz.iter().map(|&g| -g).collect();
        let seeded = warm.is_some();
        if let Some(seed) = warm {
            assert_eq!(seed.dim(), pm, "adjoint-seed dimensions");
            wz.copy_from_slice(&seed.wz);
            wu.copy_from_slice(&seed.wu);
        }

        let mut a = vec![0.0; pm];
        let mut cta = vec![0.0; n];
        let mut sa = vec![0.0; pm];
        let mut wzprev = vec![0.0; pm];
        let mut iters = 1;
        let mut step_rel = f64::INFINITY;

        let astep = |a: &mut Vec<f64>, wz: &[f64], wu: &[f64]| {
            for i in 0..p {
                a[i] = wu[i];
            }
            for i in 0..m {
                a[p + i] =
                    gate[i] * wz[p + i] + (1.0 - gate[i]) * wu[p + i];
            }
        };

        for k in 1..opts.max_iter {
            wzprev.copy_from_slice(&wz);
            astep(&mut a, &wz, &wu);
            // Sa = αρ C K⁻¹ Cᵀ a — one Cholesky solve + two gemvs
            cta.iter_mut().for_each(|c| *c = 0.0);
            gemv_t_acc(&mut cta, 1.0, &self.stacked.c, &a);
            self.chol.solve_in_place(&mut cta);
            sa.iter_mut().for_each(|si| *si = 0.0);
            gemv_acc(&mut sa, alpha * rho, &self.stacked.c, &cta);
            // W ← FᵀW + g
            for i in 0..pm {
                wz[i] = sa[i] + (1.0 - alpha) * a[i] + seedz[i];
                wu[i] = a[i] - sa[i] - seedz[i];
            }
            iters = k + 1;
            let dz: f64 = wz
                .iter()
                .zip(&wzprev)
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f64>()
                .sqrt();
            step_rel = dz / norm2(&wzprev).max(1.0);
            // a seeded first iteration reproduces the harvested state
            // exactly — require one genuine step before trusting it
            if step_rel < opts.tol && (k > 1 || !seeded) {
                break;
            }
        }

        // the reusable adjoint state, harvested before the projection
        // consumes the w's
        let seed_out = AdmmSeed { wz: wz.clone(), wu: wu.clone() };

        // project: the converged a feeds every gradient at once
        astep(&mut a, &wz, &wu);
        cta.iter_mut().for_each(|c| *c = 0.0);
        gemv_t_acc(&mut cta, 1.0, &self.stacked.c, &a);
        self.chol.solve_in_place(&mut cta);
        let grad_q: Vec<f64> =
            (0..n).map(|i| -t[i] - alpha * cta[i]).collect();
        let grad_b: Vec<f64> = (0..p).map(|i| wz[i] - wu[i]).collect();
        let grad_h: Vec<f64> = (0..m)
            .map(|i| (1.0 - gate[i]) * (wz[p + i] - wu[p + i]))
            .collect();
        (Vjp { grad_q, grad_b, grad_h, iters, step_rel }, seed_out)
    }

    /// Forward solve + reverse-mode backward in one call — the training
    /// entry point, d-free like
    /// [`DenseAltDiff::solve_vjp`](crate::altdiff::DenseAltDiff::solve_vjp).
    pub fn solve_vjp(
        &self,
        q: Option<&[f64]>,
        b: Option<&[f64]>,
        h: Option<&[f64]>,
        v: &[f64],
        opts: &Options,
    ) -> VjpSolution {
        let fopts =
            Options { backward: BackwardMode::None, ..opts.clone() };
        let solution = self.solve_with(q, b, h, &fopts);
        let vjp = self.vjp(&solution.s, v, opts);
        VjpSolution { solution, vjp }
    }
}

/// Forward-mode work buffers, allocated once per solve and reused
/// across iterations (hoisted out of the hot loop).
struct FwdWork {
    jzu: Mat,
    lrhs: Mat,
    newjx: Mat,
    jv: Mat,
}

impl FwdWork {
    fn new(n: usize, pm: usize, d: usize) -> Self {
        FwdWork {
            jzu: Mat::zeros(pm, d),
            lrhs: Mat::zeros(n, d),
            newjx: Mat::zeros(n, d),
            jv: Mat::zeros(pm, d),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::altdiff::DenseAltDiff;
    use crate::prob::{dense_qp, ill_conditioned_qp};

    fn solver(n: usize, m: usize, p: usize, seed: u64) -> AdmmQp {
        AdmmQp::new(dense_qp(n, m, p, seed), 1.0).unwrap()
    }

    fn tight() -> Options {
        Options {
            tol: 1e-12,
            max_iter: 200_000,
            backward: BackwardMode::None,
            ..Default::default()
        }
    }

    #[test]
    fn forward_reaches_kkt_point() {
        let s = solver(20, 10, 4, 1);
        let sol = s.solve(&tight());
        let r = s.qp.kkt_residual(&sol.x, &sol.lam, &sol.nu);
        assert!(r < 1e-6, "kkt residual {r}");
        assert!(sol.iters < 200_000, "did not converge");
    }

    #[test]
    fn matches_dense_altdiff() {
        for seed in [2, 5, 11] {
            let qp = dense_qp(16, 8, 3, seed);
            let admm = AdmmQp::new(qp.clone(), 1.0).unwrap();
            let alt = DenseAltDiff::new(qp, 1.0).unwrap();
            let sa = admm.solve(&tight());
            let sd = alt.solve(&tight());
            for i in 0..16 {
                assert!((sa.x[i] - sd.x[i]).abs() < 1e-8, "x[{i}]");
            }
            for i in 0..3 {
                assert!((sa.lam[i] - sd.lam[i]).abs() < 1e-8, "lam[{i}]");
            }
            for i in 0..8 {
                assert!((sa.nu[i] - sd.nu[i]).abs() < 1e-8, "nu[{i}]");
                assert!((sa.s[i] - sd.s[i]).abs() < 1e-8, "s[{i}]");
            }
        }
    }

    #[test]
    fn jacobian_b_matches_finite_difference() {
        let s = solver(10, 5, 2, 7);
        let opts = Options {
            backward: BackwardMode::Forward(Param::B),
            ..tight()
        };
        let sol = s.solve(&opts);
        let jac = sol.jacobian.unwrap();
        let eps = 1e-5;
        for j in 0..2 {
            let mut bp = s.qp.b.clone();
            bp[j] += eps;
            let mut bm = s.qp.b.clone();
            bm[j] -= eps;
            let fopts = Options { backward: BackwardMode::None, ..tight() };
            let xp = s.solve_with(None, Some(&bp), None, &fopts).x;
            let xm = s.solve_with(None, Some(&bm), None, &fopts).x;
            for i in 0..10 {
                let fd = (xp[i] - xm[i]) / (2.0 * eps);
                assert!(
                    (jac[(i, j)] - fd).abs() < 1e-5,
                    "jac[({i},{j})]={} fd={fd}",
                    jac[(i, j)]
                );
            }
        }
    }

    #[test]
    fn vjp_matches_finite_difference() {
        let s = solver(8, 4, 2, 13);
        let v: Vec<f64> = (0..8).map(|i| 0.3 * (i as f64) - 1.0).collect();
        let out = s.solve_vjp(None, None, None, &v, &tight());
        let eps = 1e-5;
        let loss = |q: &[f64], b: &[f64], h: &[f64]| -> f64 {
            let fopts = Options { backward: BackwardMode::None, ..tight() };
            let x = s.solve_with(Some(q), Some(b), Some(h), &fopts).x;
            x.iter().zip(&v).map(|(xi, vi)| xi * vi).sum()
        };
        let check = |got: f64, fd: f64, tag: &str| {
            assert!(
                (got - fd).abs() < 1e-3 * (1.0 + fd.abs()),
                "{tag}: got {got} fd {fd}"
            );
        };
        for j in 0..8 {
            let mut qp_ = s.qp.q.clone();
            qp_[j] += eps;
            let mut qm_ = s.qp.q.clone();
            qm_[j] -= eps;
            let fd = (loss(&qp_, &s.qp.b, &s.qp.h)
                - loss(&qm_, &s.qp.b, &s.qp.h))
                / (2.0 * eps);
            check(out.vjp.grad_q[j], fd, "grad_q");
        }
        for j in 0..2 {
            let mut bp = s.qp.b.clone();
            bp[j] += eps;
            let mut bm = s.qp.b.clone();
            bm[j] -= eps;
            let fd = (loss(&s.qp.q, &bp, &s.qp.h)
                - loss(&s.qp.q, &bm, &s.qp.h))
                / (2.0 * eps);
            check(out.vjp.grad_b[j], fd, "grad_b");
        }
        for j in 0..4 {
            let mut hp = s.qp.h.clone();
            hp[j] += eps;
            let mut hm = s.qp.h.clone();
            hm[j] -= eps;
            let fd = (loss(&s.qp.q, &s.qp.b, &hp)
                - loss(&s.qp.q, &s.qp.b, &hm))
                / (2.0 * eps);
            check(out.vjp.grad_h[j], fd, "grad_h");
        }
    }

    #[test]
    fn warm_fixed_point_stops_immediately() {
        let s = solver(12, 6, 2, 17);
        let cold = s.solve(&tight());
        let warm = crate::warm::WarmStart::new(
            cold.x.clone(),
            cold.lam.clone(),
            cold.nu.clone(),
        );
        let opts = Options { tol: 1e-8, ..tight() };
        let resumed = s.solve_from(None, None, None, Some(&warm), &opts);
        assert_eq!(resumed.iters, 1, "fixed point should stop in one");
        for i in 0..12 {
            assert!((resumed.x[i] - cold.x[i]).abs() < 1e-9);
        }
    }

    #[test]
    fn adaptation_balances_ill_conditioned() {
        let qp = ill_conditioned_qp(10, 5, 2, 1e4, 3);
        let adapted =
            AdmmQp::new_adapted(qp.clone(), 1.0, AdmmSettings::default())
                .unwrap();
        assert!(
            adapted.rho > 30.0,
            "balancing should push rho up, got {}",
            adapted.rho
        );
        let fixed = AdmmQp::new(qp, 1.0).unwrap();
        let opts = Options {
            tol: 1e-8,
            max_iter: 3000,
            backward: BackwardMode::None,
            ..Default::default()
        };
        let sa = adapted.solve(&opts);
        let sf = fixed.solve(&opts);
        assert!(sa.iters < 3000, "adapted should converge, {}", sa.iters);
        assert!(
            sf.iters == 3000 && sa.iters < sf.iters,
            "fixed unit rho should crawl: adapted {} fixed {}",
            sa.iters,
            sf.iters
        );
    }

    #[test]
    #[should_panic(expected = "alpha")]
    fn alpha_out_of_range_panics() {
        let _ = AdmmQp::with_settings(
            dense_qp(4, 2, 1, 1),
            1.0,
            AdmmSettings { alpha: 2.5, ..Default::default() },
        );
    }
}
