//! Batched ADMM: B problems of one registered structure per launch —
//! the family sibling of [`BatchedAltDiff`](crate::batch::BatchedAltDiff).
//!
//! Iterates are batch-major (B, ·) panels advanced with one GEMM per
//! term against the shared K⁻¹/C caches; per-element Jacobians are
//! column-stacked (·, B·d) blocks; per-element truncation reuses the
//! [`ActiveSet`] masks so converged elements freeze and stop consuming
//! flops. The batched engine never adapts ρ — all B elements share one
//! factorization — so register through [`AdmmQp::new_adapted`] when the
//! layer needs a balanced penalty.

use super::{AdmmQp, AdmmSettings};
use crate::altdiff::{BackwardMode, Options, Param};
use crate::batch::engine::{gather, zero_cols};
use crate::batch::{
    ActiveSet, BatchSolution, BatchVjp, BatchVjpSolution,
};
use crate::error::Result;
use crate::linalg::{
    axpy_cols, gemm_acc_cols, gemm_acc_rows, gemv, norm2, par_gemm_acc,
    Mat,
};
use crate::obs::IterObserver;
use crate::prob::Qp;
use crate::warm::{AdmmSeed, WarmStart};

/// A registered ADMM QP structure ready to solve B right-hand sides per
/// launch.
///
/// ```
/// use altdiff::admm::BatchedAdmm;
/// use altdiff::altdiff::Options;
/// use altdiff::prob::dense_qp;
///
/// let engine = BatchedAdmm::new(dense_qp(6, 3, 1, 7), 1.0).unwrap();
/// let q2: Vec<f64> = engine.qp.q.iter().map(|v| 0.5 * v).collect();
/// let qs: Vec<&[f64]> = vec![&engine.qp.q, &q2];
/// let sol = engine.solve_batch(Some(&qs), None, None, &Options::default());
/// assert_eq!(sol.len(), 2);
/// assert!(sol.xs.iter().flatten().all(|v| v.is_finite()));
/// ```
pub struct BatchedAdmm {
    /// The registered problem (broadcast defaults for absent θ).
    pub qp: Qp,
    /// Penalty ρ of the shared factorization (never adapted per batch).
    pub rho: f64,
    /// Family knobs; `adaptive_rho` is ignored here (see module docs).
    pub settings: AdmmSettings,
    c: Mat,   // C = [A; G], (p+m, n)
    ct: Mat,  // Cᵀ, (n, p+m)
    kinv: Mat, // explicit K⁻¹ shared with the single-problem engine
}

impl BatchedAdmm {
    /// Register from scratch (factors K once, like [`AdmmQp::new`]).
    pub fn new(qp: Qp, rho: f64) -> Result<BatchedAdmm> {
        Ok(BatchedAdmm::from_single(&AdmmQp::new(qp, rho)?))
    }

    /// Share an already-registered layer's factorization caches — the
    /// cheap path for the server, which keeps both shapes per layer.
    pub fn from_single(solver: &AdmmQp) -> BatchedAdmm {
        BatchedAdmm {
            qp: solver.qp.clone(),
            rho: solver.rho,
            settings: solver.settings,
            c: solver.stacked.c.clone(),
            ct: solver.stacked.ct.clone(),
            kinv: solver.kinv_cache.clone(),
        }
    }

    /// Solve + differentiate B instances in one launch; same θ
    /// broadcast/arity contract as
    /// [`BatchedAltDiff::solve_batch`](crate::batch::BatchedAltDiff::solve_batch).
    pub fn solve_batch(
        &self,
        qs: Option<&[&[f64]]>,
        bs: Option<&[&[f64]]>,
        hs: Option<&[&[f64]]>,
        opts: &Options,
    ) -> BatchSolution {
        self.solve_batch_from(qs, bs, hs, None, opts)
    }

    /// [`Self::solve_batch`] with per-element warm starts: a batch may
    /// freely mix warm and cold members; warm state is loaded exactly
    /// as in [`AdmmQp::solve_from`], and `warms = None` (or all-`None`)
    /// is bit-identical to the cold [`Self::solve_batch`]. Warm
    /// elements with forward-mode Jacobians require `tol = 0`
    /// (asserted — see DESIGN.md §5).
    pub fn solve_batch_from(
        &self,
        qs: Option<&[&[f64]]>,
        bs: Option<&[&[f64]]>,
        hs: Option<&[&[f64]]>,
        warms: Option<&[Option<WarmStart>]>,
        opts: &Options,
    ) -> BatchSolution {
        self.solve_batch_observed(qs, bs, hs, warms, opts, None)
    }

    /// [`Self::solve_batch_from`] with a per-iteration
    /// [`IterObserver`] hook (see
    /// [`BatchedAltDiff::solve_batch_observed`](crate::batch::BatchedAltDiff::solve_batch_observed)
    /// for the contract): residuals only for claimed elements,
    /// `observer = None` is the unsampled fast path, identical solution
    /// either way.
    pub fn solve_batch_observed(
        &self,
        qs: Option<&[&[f64]]>,
        bs: Option<&[&[f64]]>,
        hs: Option<&[&[f64]]>,
        warms: Option<&[Option<WarmStart>]>,
        opts: &Options,
        mut observer: Option<&mut dyn IterObserver>,
    ) -> BatchSolution {
        let n = self.qp.n();
        let m = self.qp.m_ineq();
        let p = self.qp.p_eq();
        let pm = p + m;
        let rho = self.rho;
        let alpha = self.settings.alpha;
        let bsz = qs
            .map(|v| v.len())
            .or_else(|| bs.map(|v| v.len()))
            .or_else(|| hs.map(|v| v.len()))
            .or_else(|| warms.map(|v| v.len()))
            .unwrap_or(1);
        assert!(bsz > 0, "empty batch");

        let qm = gather(qs, &self.qp.q, bsz, n);
        let bm = gather(bs, &self.qp.b, bsz, p);
        let hm = gather(hs, &self.qp.h, bsz, m);

        // iterates, batch-major
        let mut x = Mat::zeros(bsz, n);
        let mut z = Mat::zeros(bsz, pm);
        let mut um = Mat::zeros(bsz, pm);
        let mut vm = Mat::zeros(bsz, pm);
        let mut xprev = Mat::zeros(bsz, n);
        let mut rhs = Mat::zeros(bsz, n);
        let mut cx = Mat::zeros(bsz, pm);
        let mut zu = Mat::zeros(bsz, pm);

        if let Some(ws_) = warms {
            assert_eq!(ws_.len(), bsz, "warm-start arity");
            if ws_.iter().any(|w| w.is_some()) {
                assert!(
                    opts.backward.forward_param().is_none()
                        || opts.tol == 0.0,
                    "warm starts with forward-mode Jacobians require \
                     tol = 0 (fixed-k); use BackwardMode::None/Adjoint \
                     for truncated warm solves"
                );
            }
            for (e, w) in ws_.iter().enumerate() {
                let Some(w) = w else { continue };
                assert_eq!(w.dims(), (n, p, m), "warm-start dimensions");
                x.row_mut(e).copy_from_slice(&w.x);
                let gx0 = gemv(&self.qp.g, &w.x);
                {
                    let zr = z.row_mut(e);
                    for i in 0..p {
                        zr[i] = bm[(e, i)];
                    }
                    for i in 0..m {
                        zr[p + i] = gx0[i].min(hm[(e, i)]);
                    }
                }
                {
                    let ur = um.row_mut(e);
                    for i in 0..p {
                        ur[i] = w.lam[i] / rho;
                    }
                    for i in 0..m {
                        ur[p + i] = w.nu[i] / rho;
                    }
                }
                let zr = z.row(e);
                let ur = um.row(e);
                let vr = vm.row_mut(e);
                for i in 0..pm {
                    vr[i] = zr[i] + ur[i];
                }
            }
        }

        // Jacobian state: per-element (·, d) blocks stacked along columns
        let param = opts.backward.forward_param();
        let d = param.map(|pp| pp.dim(n, m, p));
        let mut jac = d.map(|d| JacFwdState::new(n, pm, bsz, d));

        let mut act = ActiveSet::new(bsz);
        let mut iters = vec![0usize; bsz];
        let mut step_rel = vec![f64::INFINITY; bsz];
        let mut live: Vec<usize> = Vec::with_capacity(bsz);

        for k in 0..opts.max_iter {
            if act.all_done() {
                break;
            }
            live.clear();
            live.extend(act.iter());
            for &e in &live {
                iters[e] = k + 1;
                xprev.row_mut(e).copy_from_slice(x.row(e));
            }

            // ---- x-update: K x = −q + ρCᵀ(z − u), batch-major
            for &e in &live {
                let zr = z.row(e);
                let ur = um.row(e);
                let zur = zu.row_mut(e);
                for i in 0..pm {
                    zur[i] = zr[i] - ur[i];
                }
                let rr = rhs.row_mut(e);
                let qr = qm.row(e);
                for i in 0..n {
                    rr[i] = -qr[i];
                }
            }
            gemm_acc_rows(&mut rhs, rho, &zu, &self.c, act.flags());
            for &e in &live {
                x.row_mut(e).fill(0.0);
            }
            gemm_acc_rows(&mut x, 1.0, &rhs, &self.kinv, act.flags());

            // ---- relaxation + projection input v = αCx + (1−α)z + u
            for &e in &live {
                cx.row_mut(e).fill(0.0);
            }
            gemm_acc_rows(&mut cx, 1.0, &x, &self.ct, act.flags());
            for &e in &live {
                let cr = cx.row(e);
                let zr = z.row(e);
                let ur = um.row(e);
                let vr = vm.row_mut(e);
                for i in 0..pm {
                    vr[i] =
                        alpha * cr[i] + (1.0 - alpha) * zr[i] + ur[i];
                }
            }
            // ---- projection z⁺ = (b, min(v, h)); dual u⁺ = v − z⁺
            for &e in &live {
                let vr = vm.row(e);
                let br = bm.row(e);
                let hr = hm.row(e);
                let zr = z.row_mut(e);
                for i in 0..p {
                    zr[i] = br[i];
                }
                for i in 0..m {
                    zr[p + i] = vr[p + i].min(hr[i]);
                }
                let zr = z.row(e);
                let ur = um.row_mut(e);
                for i in 0..pm {
                    ur[i] = vr[i] - zr[i];
                }
            }

            // ---- forward-mode panels, only live column blocks
            if let Some(jac) = jac.as_mut() {
                jac.step(self, param.unwrap(), &vm, &hm, &act, &live);
            }

            // ---- per-element truncation (Algorithm 1 condition)
            for &e in &live {
                let xr = x.row(e);
                let xp = xprev.row(e);
                let dx: f64 = xr
                    .iter()
                    .zip(xp)
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum::<f64>()
                    .sqrt();
                // sampled-trace hook: cx = Cx = [Ax; Gx] at the k+1
                // iterate, slack re-derived as the unpack step does
                if let Some(obs) = observer.as_deref_mut() {
                    if obs.wants(e) {
                        let cr = cx.row(e);
                        let br = bm.row(e);
                        let hr = hm.row(e);
                        let vr = vm.row(e);
                        let mut pr = 0.0;
                        for i in 0..p {
                            let v = cr[i] - br[i];
                            pr += v * v;
                        }
                        for i in 0..m {
                            let si = (hr[i] - vr[p + i]).max(0.0);
                            let v = cr[p + i] + si - hr[i];
                            pr += v * v;
                        }
                        obs.on_iter(e, k, pr.sqrt(), rho * dx);
                    }
                }
                let step = dx / norm2(xp).max(1.0);
                step_rel[e] = step;
                if step < opts.tol {
                    act.deactivate(e);
                }
            }
        }

        // unpack: unscaled duals y = ρu, slack from the projection input
        let xs: Vec<Vec<f64>> =
            (0..bsz).map(|e| x.row(e).to_vec()).collect();
        let mut ss = Vec::with_capacity(bsz);
        let mut lams = Vec::with_capacity(bsz);
        let mut nus = Vec::with_capacity(bsz);
        for e in 0..bsz {
            let vr = vm.row(e);
            let hr = hm.row(e);
            let ur = um.row(e);
            ss.push(
                (0..m)
                    .map(|i| (hr[i] - vr[p + i]).max(0.0))
                    .collect::<Vec<f64>>(),
            );
            lams.push((0..p).map(|i| rho * ur[i]).collect::<Vec<f64>>());
            nus.push(
                (0..m).map(|i| rho * ur[p + i]).collect::<Vec<f64>>(),
            );
        }
        let jacobians = jac.map(|j| j.unstack(n, bsz));
        BatchSolution { xs, ss, lams, nus, jacobians, iters, step_rel }
    }

    /// Batched reverse-mode backward: B adjoint states advance as (B,
    /// p+m) panels, one GEMM per term against the shared K⁻¹/C — cost
    /// per iteration O(B·(n² + n(p+m))), independent of d. Same
    /// slack-gate and truncation contract as
    /// [`BatchedAltDiff::batch_vjp`](crate::batch::BatchedAltDiff::batch_vjp).
    pub fn batch_vjp(
        &self,
        slacks: &[&[f64]],
        vs: &[&[f64]],
        opts: &Options,
    ) -> BatchVjp {
        self.batch_vjp_from(slacks, vs, None, opts).0
    }

    /// [`Self::batch_vjp`] with per-element warm adjoint seeds, also
    /// returning every element's final adjoint state for the next
    /// backward to resume from. A batch may mix seeded and cold
    /// elements; `warms = None` is bit-identical to the cold
    /// [`Self::batch_vjp`].
    pub fn batch_vjp_from(
        &self,
        slacks: &[&[f64]],
        vs: &[&[f64]],
        warms: Option<&[Option<AdmmSeed>]>,
        opts: &Options,
    ) -> (BatchVjp, Vec<AdmmSeed>) {
        let n = self.qp.n();
        let m = self.qp.m_ineq();
        let p = self.qp.p_eq();
        let pm = p + m;
        let rho = self.rho;
        let alpha = self.settings.alpha;
        let bsz = vs.len();
        assert!(bsz > 0, "empty batch");
        assert_eq!(slacks.len(), bsz, "slack arity");

        // gates e (B, m): 1 on inactive rows, from the forward slacks
        let mut gates = Mat::zeros(bsz, m);
        for (e, s) in slacks.iter().enumerate() {
            assert_eq!(s.len(), m, "slack dimension");
            let gr = gates.row_mut(e);
            for i in 0..m {
                gr[i] = if s[i] > 0.0 { 1.0 } else { 0.0 };
            }
        }

        // T = V K⁻¹ (row-major stacked t's) and the seed G_z = ρ T Cᵀ
        let vmat = gather(Some(vs), &[], bsz, n);
        let mut t = Mat::zeros(bsz, n);
        par_gemm_acc(&mut t, 1.0, &vmat, &self.kinv);
        let mut seedz = Mat::zeros(bsz, pm);
        par_gemm_acc(&mut seedz, rho, &t, &self.ct);

        // first series term (or resume from harvested states)
        let mut wz = seedz.clone();
        let mut wu = seedz.clone();
        wu.scale(-1.0);
        let mut seeded = vec![false; bsz];
        if let Some(seeds) = warms {
            assert_eq!(seeds.len(), bsz, "adjoint-seed arity");
            for (e, seed) in seeds.iter().enumerate() {
                let Some(seed) = seed else { continue };
                assert_eq!(seed.dim(), pm, "adjoint-seed dimensions");
                wz.row_mut(e).copy_from_slice(&seed.wz);
                wu.row_mut(e).copy_from_slice(&seed.wu);
                seeded[e] = true;
            }
        }

        let mut amat = Mat::zeros(bsz, pm);
        let mut cta = Mat::zeros(bsz, n);
        let mut sa = Mat::zeros(bsz, pm);
        let mut wzprev = Mat::zeros(bsz, pm);

        let mut act = ActiveSet::new(bsz);
        let mut iters = vec![1usize; bsz];
        let mut step_rel = vec![f64::INFINITY; bsz];
        let mut live: Vec<usize> = Vec::with_capacity(bsz);

        for k in 1..opts.max_iter {
            if act.all_done() {
                break;
            }
            live.clear();
            live.extend(act.iter());
            // a = e ⊙ w_z + (1−e) ⊙ w_u (a = w_u on equality rows)
            for &e in &live {
                wzprev.row_mut(e).copy_from_slice(wz.row(e));
                let gr = gates.row(e);
                let wzr = wz.row(e);
                let wur = wu.row(e);
                let ar = amat.row_mut(e);
                for i in 0..p {
                    ar[i] = wur[i];
                }
                for i in 0..m {
                    ar[p + i] = gr[i] * wzr[p + i]
                        + (1.0 - gr[i]) * wur[p + i];
                }
                cta.row_mut(e).fill(0.0);
            }
            // Sa = αρ (a C) K⁻¹ Cᵀ, three masked GEMMs
            gemm_acc_rows(&mut cta, 1.0, &amat, &self.c, act.flags());
            for &e in &live {
                sa.row_mut(e).fill(0.0);
            }
            {
                let mut yk = Mat::zeros(bsz, n);
                gemm_acc_rows(&mut yk, 1.0, &cta, &self.kinv, act.flags());
                gemm_acc_rows(&mut sa, alpha * rho, &yk, &self.ct, act.flags());
            }
            // W ← FᵀW + g per live row
            for &e in &live {
                iters[e] = k + 1;
                let ar = amat.row(e);
                let sr = sa.row(e);
                let gzr = seedz.row(e);
                {
                    let wzr = wz.row_mut(e);
                    for i in 0..pm {
                        wzr[i] =
                            sr[i] + (1.0 - alpha) * ar[i] + gzr[i];
                    }
                }
                let wur = wu.row_mut(e);
                for i in 0..pm {
                    wur[i] = ar[i] - sr[i] - gzr[i];
                }
                // per-element truncation on w_z; a seeded element must
                // take one genuine step before the criterion is trusted
                let wzr = wz.row(e);
                let wp = wzprev.row(e);
                let dz: f64 = wzr
                    .iter()
                    .zip(wp)
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum::<f64>()
                    .sqrt();
                let step = dz / norm2(wp).max(1.0);
                step_rel[e] = step;
                if step < opts.tol && (k > 1 || !seeded[e]) {
                    act.deactivate(e);
                }
            }
        }

        // reusable adjoint states, harvested before the projection
        let seeds_out: Vec<AdmmSeed> = (0..bsz)
            .map(|e| AdmmSeed {
                wz: wz.row(e).to_vec(),
                wu: wu.row(e).to_vec(),
            })
            .collect();

        // final a at every element's converged state, then project
        let all = vec![true; bsz];
        for e in 0..bsz {
            let gr = gates.row(e);
            let wzr = wz.row(e);
            let wur = wu.row(e);
            let ar = amat.row_mut(e);
            for i in 0..p {
                ar[i] = wur[i];
            }
            for i in 0..m {
                ar[p + i] =
                    gr[i] * wzr[p + i] + (1.0 - gr[i]) * wur[p + i];
            }
        }
        cta.data.fill(0.0);
        gemm_acc_rows(&mut cta, 1.0, &amat, &self.c, &all);
        let mut yk = Mat::zeros(bsz, n);
        par_gemm_acc(&mut yk, 1.0, &cta, &self.kinv);
        // grad_q = −t − α K⁻¹Cᵀa; grad_b = w_z − w_u on equality rows;
        // grad_h = (1−e) ⊙ (w_z − w_u) on inequality rows
        let mut gq = t;
        gq.scale(-1.0);
        gq.axpy(-alpha, &yk);
        let mut gb = Mat::zeros(bsz, p);
        let mut gh = Mat::zeros(bsz, m);
        for e in 0..bsz {
            let wzr = wz.row(e);
            let wur = wu.row(e);
            let gbr = gb.row_mut(e);
            for i in 0..p {
                gbr[i] = wzr[i] - wur[i];
            }
            let gr = gates.row(e);
            let ghr = gh.row_mut(e);
            for i in 0..m {
                ghr[i] =
                    (1.0 - gr[i]) * (wzr[p + i] - wur[p + i]);
            }
        }

        let rows = |mat: &Mat| -> Vec<Vec<f64>> {
            (0..bsz).map(|e| mat.row(e).to_vec()).collect()
        };
        (
            BatchVjp {
                grads_q: rows(&gq),
                grads_b: rows(&gb),
                grads_h: rows(&gh),
                iters,
                step_rel,
            },
            seeds_out,
        )
    }

    /// Forward batch solve + batched reverse-mode backward in one call —
    /// the minibatch training entry point, no Jacobian ever materialized.
    pub fn solve_batch_vjp(
        &self,
        qs: Option<&[&[f64]]>,
        bs: Option<&[&[f64]]>,
        hs: Option<&[&[f64]]>,
        vs: &[&[f64]],
        opts: &Options,
    ) -> BatchVjpSolution {
        let fopts =
            Options { backward: BackwardMode::None, ..opts.clone() };
        let forward = self.solve_batch(qs, bs, hs, &fopts);
        let vjp = self.batch_vjp(&forward.slack_refs(), vs, opts);
        BatchVjpSolution { forward, vjp }
    }
}

/// Column-stacked forward-mode state: J_x (n, B·d), J_z and J_u
/// ((p+m), B·d), plus the work panels the step reuses.
struct JacFwdState {
    d: usize,
    jx: Mat,
    jz: Mat,
    ju: Mat,
    jzu: Mat,
    lrhs: Mat,
    newjx: Mat,
    jv: Mat,
}

impl JacFwdState {
    fn new(n: usize, pm: usize, bsz: usize, d: usize) -> Self {
        let bd = bsz * d;
        JacFwdState {
            d,
            jx: Mat::zeros(n, bd),
            jz: Mat::zeros(pm, bd),
            ju: Mat::zeros(pm, bd),
            jzu: Mat::zeros(pm, bd),
            lrhs: Mat::zeros(n, bd),
            newjx: Mat::zeros(n, bd),
            jv: Mat::zeros(pm, bd),
        }
    }

    /// One batched Jacobian update; mirrors `AdmmQp::jacobian_step` per
    /// column block, frozen blocks untouched.
    fn step(
        &mut self,
        eng: &BatchedAdmm,
        param: Param,
        vm: &Mat,
        hm: &Mat,
        act: &ActiveSet,
        live: &[usize],
    ) {
        let d = self.d;
        let n = eng.qp.n();
        let m = eng.qp.m_ineq();
        let p = eng.qp.p_eq();
        let rho = eng.rho;
        let alpha = eng.settings.alpha;
        let ranges = act.col_ranges(d);

        // Jx = K⁻¹(∂(−q)/∂θ + ρCᵀ(Jz − Ju)), live blocks only
        zero_cols(&mut self.jzu, &ranges);
        axpy_cols(&mut self.jzu, 1.0, &self.jz, &ranges);
        axpy_cols(&mut self.jzu, -1.0, &self.ju, &ranges);
        zero_cols(&mut self.lrhs, &ranges);
        gemm_acc_cols(&mut self.lrhs, rho, &eng.ct, &self.jzu, &ranges);
        if param == Param::Q {
            for &e in live {
                let base = e * d;
                for i in 0..n.min(d) {
                    self.lrhs[(i, base + i)] -= 1.0;
                }
            }
        }
        zero_cols(&mut self.newjx, &ranges);
        gemm_acc_cols(&mut self.newjx, 1.0, &eng.kinv, &self.lrhs, &ranges);
        zero_cols(&mut self.jx, &ranges);
        axpy_cols(&mut self.jx, 1.0, &self.newjx, &ranges);

        // Jv = αC Jx + (1−α)Jz + Ju
        zero_cols(&mut self.jv, &ranges);
        gemm_acc_cols(&mut self.jv, alpha, &eng.c, &self.jx, &ranges);
        axpy_cols(&mut self.jv, 1.0 - alpha, &self.jz, &ranges);
        axpy_cols(&mut self.jv, 1.0, &self.ju, &ranges);

        // projection rows per live block: Jz⁺ = ∂proj/∂θ, Ju⁺ = Jv − Jz⁺
        for &e in live {
            let base = e * d;
            for r in 0..p {
                for c in 0..d {
                    self.jz[(r, base + c)] = 0.0;
                }
                if param == Param::B {
                    self.jz[(r, base + r)] = 1.0;
                }
                for c in 0..d {
                    self.ju[(r, base + c)] =
                        self.jv[(r, base + c)] - self.jz[(r, base + c)];
                }
            }
            for i in 0..m {
                let r = p + i;
                if vm[(e, r)] < hm[(e, i)] {
                    for c in 0..d {
                        self.jz[(r, base + c)] = self.jv[(r, base + c)];
                        self.ju[(r, base + c)] = 0.0;
                    }
                } else {
                    for c in 0..d {
                        self.jz[(r, base + c)] = 0.0;
                    }
                    if param == Param::H {
                        self.jz[(r, base + i)] = 1.0;
                    }
                    for c in 0..d {
                        self.ju[(r, base + c)] = self.jv[(r, base + c)]
                            - self.jz[(r, base + c)];
                    }
                }
            }
        }
    }

    /// Split the stacked (n, B·d) Jacobian back into per-element mats.
    fn unstack(&self, n: usize, bsz: usize) -> Vec<Mat> {
        let d = self.d;
        let bd = bsz * d;
        (0..bsz)
            .map(|e| {
                let mut jm = Mat::zeros(n, d);
                for i in 0..n {
                    jm.row_mut(i).copy_from_slice(
                        &self.jx.data[i * bd + e * d..i * bd + (e + 1) * d],
                    );
                }
                jm
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prob::dense_qp;

    fn engines(
        n: usize,
        m: usize,
        p: usize,
        seed: u64,
    ) -> (AdmmQp, BatchedAdmm) {
        let single = AdmmQp::new(dense_qp(n, m, p, seed), 1.0).unwrap();
        let batched = BatchedAdmm::from_single(&single);
        (single, batched)
    }

    #[test]
    fn broadcast_batch_matches_single_solve() {
        let (single, batched) = engines(14, 7, 3, 21);
        let opts = Options {
            tol: 1e-10,
            max_iter: 50_000,
            backward: BackwardMode::Forward(Param::B),
            ..Default::default()
        };
        let sd = single.solve(&opts);
        let sb = batched.solve_batch(None, None, None, &opts);
        assert_eq!(sb.len(), 1);
        for i in 0..14 {
            assert!((sb.xs[0][i] - sd.x[i]).abs() < 1e-8, "x[{i}]");
        }
        for i in 0..3 {
            assert!((sb.lams[0][i] - sd.lam[i]).abs() < 1e-8, "lam[{i}]");
        }
        let jb = &sb.jacobians.as_ref().unwrap()[0];
        let jd = sd.jacobian.as_ref().unwrap();
        assert!(jb.max_abs_diff(jd) < 1e-8);
        // the single engine back-substitutes while the batched engine
        // multiplies by the explicit K⁻¹; allow one rounding iteration
        assert!(sb.iters[0].abs_diff(sd.iters) <= 1);
    }

    #[test]
    fn fixed_k_runs_every_element_exactly_k() {
        let (_, batched) = engines(10, 5, 2, 22);
        let q2: Vec<f64> =
            batched.qp.q.iter().map(|&v| 2.0 * v).collect();
        let qs: Vec<&[f64]> = vec![&batched.qp.q, &q2];
        let opts = Options {
            tol: 0.0,
            max_iter: 17,
            backward: BackwardMode::Forward(Param::Q),
            ..Default::default()
        };
        let sb = batched.solve_batch(Some(&qs), None, None, &opts);
        assert_eq!(sb.iters, vec![17, 17]);
        assert!(sb.xs.iter().all(|x| x.iter().all(|v| v.is_finite())));
    }

    #[test]
    fn batch_vjp_matches_single_vjp() {
        let (single, batched) = engines(8, 4, 2, 23);
        let opts = Options {
            tol: 1e-11,
            max_iter: 100_000,
            backward: BackwardMode::None,
            ..Default::default()
        };
        let q2: Vec<f64> =
            batched.qp.q.iter().map(|&v| 0.7 * v).collect();
        let qs: Vec<&[f64]> = vec![&batched.qp.q, &q2];
        let fwd = batched.solve_batch(Some(&qs), None, None, &opts);
        let v: Vec<f64> = (0..8).map(|i| (i as f64) - 3.5).collect();
        let vs: Vec<&[f64]> = vec![&v, &v];
        let bv = batched.batch_vjp(&fwd.slack_refs(), &vs, &opts);
        for e in 0..2 {
            let sf = single.solve_with(
                Some(qs[e]),
                None,
                None,
                &opts,
            );
            let sv = single.vjp(&sf.s, &v, &opts);
            for i in 0..8 {
                assert!(
                    (bv.grads_q[e][i] - sv.grad_q[i]).abs() < 1e-8,
                    "grad_q[{e}][{i}]"
                );
            }
            for i in 0..2 {
                assert!(
                    (bv.grads_b[e][i] - sv.grad_b[i]).abs() < 1e-8,
                    "grad_b[{e}][{i}]"
                );
            }
            for i in 0..4 {
                assert!(
                    (bv.grads_h[e][i] - sv.grad_h[i]).abs() < 1e-8,
                    "grad_h[{e}][{i}]"
                );
            }
        }
    }
}
