//! The second differentiable engine family: consensus-form ADMM.
//!
//! Alt-Diff (the paper's Algorithm 1) is one point in the family of
//! operator-splitting differentiable solvers; this module provides a
//! sibling in the style of Butler & Kwon 2021 ("Efficient differentiable
//! quadratic programming layers: an ADMM approach"), honoring every
//! contract the Alt-Diff engines satisfy so the coordinator can route
//! between the two families per layer (see DESIGN.md §6).
//!
//! The splitting: stack the constraints as C = [A; G] and solve
//!
//!   min ½xᵀPx + qᵀx + I_S(z)   s.t.  Cx = z,
//!   S = {b} × {v : v ≤ h},
//!
//! by scaled, over-relaxed ADMM:
//!
//!   x  = K⁻¹(−q + ρCᵀ(z − u)),      K = P + ρCᵀC
//!   v  = α·Cx + (1−α)z + u          (over-relaxation, α ∈ (0, 2))
//!   z⁺ = (b, min(v_in, h)),   u⁺ = v − z⁺
//!
//! K is exactly the H(ρ) matrix the Alt-Diff registration factors, so
//! one Cholesky at registration serves every subsequent solve of either
//! shape. The solution mapping back to the shared [`Solution`] contract
//! is λ = ρu_eq, ν = ρu_in (the scaled duals), s = max(h − v_in, 0)
//! (exact zeros on active rows, the same sign-gate convention the
//! Alt-Diff adjoint uses).
//!
//! Differentiation mirrors the Alt-Diff engines mode-for-mode: a
//! forward-mode Jacobian recursion rides the iteration when
//! [`BackwardMode::Forward`](crate::altdiff::BackwardMode) is selected,
//! and a dimension-free adjoint fixed-point iteration serves reverse
//! mode — O(p+m) state, never an (n, d) Jacobian (DESIGN.md §6).
//!
//! What this family adds over Alt-Diff: the over-relaxation knob α and
//! residual-balancing ρ adaptation ([`AdmmSettings`]), which make ADMM
//! markedly faster on ill-conditioned layers where a fixed unit penalty
//! crawls — the regime the coordinator's cross-method router detects at
//! calibration time.

pub mod batch;
pub mod qp;
mod stacked;

pub use batch::BatchedAdmm;
pub use qp::AdmmQp;

/// Family-specific knobs shared by [`AdmmQp`] and [`BatchedAdmm`].
///
/// The default is over-relaxation α = 1.6 (the classical sweet spot)
/// with ρ adaptation off, which keeps warm == cold and batched ==
/// single parity exact.
#[derive(Clone, Copy, Debug)]
pub struct AdmmSettings {
    /// Over-relaxation coefficient α ∈ (0, 2); 1.0 disables relaxation.
    pub alpha: f64,
    /// Residual-balancing ρ adaptation (OSQP-style ρ ← ρ·√(r_p/r_d),
    /// checked every [`Self::adapt_every`] iterations, with a local
    /// refactorization on adoption). Applied only when no forward-mode
    /// Jacobian rides the loop — the recursion differentiates a
    /// fixed-ρ map — and never by the batched engine, whose elements
    /// share one factorization. Use [`AdmmQp::new_adapted`] to balance
    /// ρ once at registration instead; that frozen ρ then serves every
    /// engine and mode.
    pub adaptive_rho: bool,
    /// Residual-balance check period, in iterations.
    pub adapt_every: usize,
    /// Only adopt (and refactor for) a rebalanced ρ when it differs
    /// from the current one by more than this multiplicative factor.
    pub adapt_threshold: f64,
    /// Lower clamp for an adapted ρ.
    pub rho_min: f64,
    /// Upper clamp for an adapted ρ.
    pub rho_max: f64,
}

impl Default for AdmmSettings {
    fn default() -> Self {
        AdmmSettings {
            alpha: 1.6,
            adaptive_rho: false,
            adapt_every: 10,
            adapt_threshold: 5.0,
            rho_min: 1e-6,
            rho_max: 1e6,
        }
    }
}

impl AdmmSettings {
    /// Default knobs with residual-balancing adaptation switched on.
    pub fn adaptive() -> Self {
        AdmmSettings { adaptive_rho: true, ..AdmmSettings::default() }
    }
}
