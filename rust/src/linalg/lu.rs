//! LU factorization with partial pivoting — for the *indefinite* systems
//! the baselines need: the KKT matrix of OptNet-style implicit
//! differentiation (eq. 25) and the IPM Newton systems are symmetric but
//! indefinite, so Cholesky does not apply.

use super::dense::Mat;
use crate::error::AltDiffError;

/// P A = L U with row-pivot permutation `perm` (perm[i] = original row).
#[derive(Clone, Debug)]
pub struct Lu {
    /// Packed factors: L below the unit diagonal, U on and above it.
    pub lu: Mat,
    /// Row permutation (perm[i] = original row index).
    pub perm: Vec<usize>,
    /// Permutation parity (±1; the determinant's sign factor).
    pub sign: f64,
}

impl Lu {
    /// Factor with partial pivoting; fails on an (effectively) zero
    /// pivot.
    pub fn factor(a: &Mat) -> Result<Lu, AltDiffError> {
        assert_eq!(a.rows, a.cols);
        let n = a.rows;
        let mut lu = a.clone();
        let mut perm: Vec<usize> = (0..n).collect();
        let mut sign = 1.0;
        for k in 0..n {
            // pivot: max |a_ik| over i >= k
            let mut piv = k;
            let mut pmax = lu[(k, k)].abs();
            for i in (k + 1)..n {
                let v = lu[(i, k)].abs();
                if v > pmax {
                    pmax = v;
                    piv = i;
                }
            }
            if pmax < 1e-300 || !pmax.is_finite() {
                return Err(AltDiffError::Singular { pivot: k });
            }
            if piv != k {
                perm.swap(k, piv);
                sign = -sign;
                // swap rows k, piv
                for j in 0..n {
                    lu.data.swap(k * n + j, piv * n + j);
                }
            }
            let pivval = lu[(k, k)];
            let inv = 1.0 / pivval;
            // split borrows: row k immutable, rows > k mutable
            let (upper, lower) = lu.data.split_at_mut((k + 1) * n);
            let rowk = &upper[k * n..k * n + n];
            for i in (k + 1)..n {
                let ri = &mut lower[(i - k - 1) * n..(i - k) * n];
                let f = ri[k] * inv;
                ri[k] = f;
                if f != 0.0 {
                    for j in (k + 1)..n {
                        ri[j] -= f * rowk[j];
                    }
                }
            }
        }
        Ok(Lu { lu, perm, sign })
    }

    /// Solve A x = b via the cached factors.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let n = self.lu.rows;
        debug_assert_eq!(b.len(), n);
        // apply permutation
        let mut x: Vec<f64> = self.perm.iter().map(|&p| b[p]).collect();
        // L y = Pb (unit diagonal)
        for i in 1..n {
            let row = &self.lu.data[i * n..i * n + i];
            let mut s = x[i];
            for (lij, xj) in row.iter().zip(x.iter()) {
                s -= lij * xj;
            }
            x[i] = s;
        }
        // U x = y
        for i in (0..n).rev() {
            let mut s = x[i];
            for j in (i + 1)..n {
                s -= self.lu.data[i * n + j] * x[j];
            }
            x[i] = s / self.lu.data[i * n + i];
        }
        x
    }

    /// Solve A X = B for matrix B.
    pub fn solve_mat(&self, b: &Mat) -> Mat {
        let bt = b.transpose();
        let mut out_t = Mat::zeros(b.cols, b.rows);
        for c in 0..b.cols {
            let x = self.solve(bt.row(c));
            out_t.row_mut(c).copy_from_slice(&x);
        }
        out_t.transpose()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::blas::{gemm, gemv};
    use crate::util::rng::Pcg64;

    #[test]
    fn solve_random_system() {
        let mut rng = Pcg64::new(1);
        let n = 25;
        let a = Mat::from_vec(n, n, rng.normal_vec(n * n));
        let xtrue = rng.normal_vec(n);
        let b = gemv(&a, &xtrue);
        let lu = Lu::factor(&a).unwrap();
        let x = lu.solve(&b);
        for i in 0..n {
            assert!((x[i] - xtrue[i]).abs() < 1e-8, "i={i}");
        }
    }

    #[test]
    fn solves_indefinite_kkt_like() {
        // [[I, Aᵀ],[A, 0]] — indefinite, well-posed when A full row rank.
        let mut rng = Pcg64::new(2);
        let (n, p) = (10, 4);
        let a = Mat::from_vec(p, n, rng.normal_vec(p * n));
        let top = Mat::eye(n).hstack(&a.transpose());
        let bot = a.hstack(&Mat::zeros(p, p));
        let kkt = top.vstack(&bot);
        let lu = Lu::factor(&kkt).unwrap();
        let b = rng.normal_vec(n + p);
        let x = lu.solve(&b);
        let r = gemv(&kkt, &x);
        for i in 0..(n + p) {
            assert!((r[i] - b[i]).abs() < 1e-8);
        }
    }

    #[test]
    fn rejects_singular() {
        let a = Mat::from_rows(&[&[1., 2.], &[2., 4.]]);
        assert!(Lu::factor(&a).is_err());
    }

    #[test]
    fn solve_mat_consistency() {
        let mut rng = Pcg64::new(3);
        let a = Mat::from_vec(6, 6, rng.normal_vec(36));
        let b = Mat::from_vec(6, 2, rng.normal_vec(12));
        let lu = Lu::factor(&a).unwrap();
        let x = lu.solve_mat(&b);
        let rec = gemm(&a, &x);
        assert!(rec.max_abs_diff(&b) < 1e-8);
    }
}
