//! Dense linear algebra substrate (no external BLAS/LAPACK).
//!
//! - [`dense`]: row-major `Mat`, vector ops.
//! - [`blas`]: blocked gemm/gemv kernels (the native hot path).
//! - [`chol`]: Cholesky for the SPD Alt-Diff Hessian.
//! - [`lu`]: pivoted LU for the baselines' indefinite KKT systems.

pub mod blas;
pub mod chol;
pub mod dense;
pub mod lu;

pub use blas::{
    ata, axpy_cols, gemm, gemm_acc, gemm_acc_cols, gemm_acc_rows, gemv,
    gemv_acc, gemv_t, gemv_t_acc, par_gemm_acc,
};
pub use chol::Chol;
pub use dense::{add_vec, axpy, cosine, dot, norm2, relu, sub_vec, Mat};
pub use lu::Lu;
