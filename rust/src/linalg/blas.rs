//! Blocked matrix kernels (the in-repo BLAS).
//!
//! `gemm` uses an i-k-j loop order with row-panel blocking: the inner loop
//! is a contiguous axpy over a row of B, which the compiler auto-vectorizes
//! well. This is the single hottest routine in the native engine (Hessian
//! assembly AᵀA/GᵀG, Jacobian propagation, KKT factorizations) — see
//! EXPERIMENTS.md §Perf for the before/after of the blocking.

use super::dense::Mat;

/// Tile edge for the k/j blocking. 64 keeps an A-panel (64x64 f64 = 32 KB)
/// inside L1/L2 comfortably; measured best among {32, 64, 128} here.
const KB: usize = 64;
const JB: usize = 256;

/// C = A @ B.
pub fn gemm(a: &Mat, b: &Mat) -> Mat {
    let mut c = Mat::zeros(a.rows, b.cols);
    gemm_acc(&mut c, 1.0, a, b);
    c
}

/// C += alpha * A @ B (blocked i-k-j).
pub fn gemm_acc(c: &mut Mat, alpha: f64, a: &Mat, b: &Mat) {
    assert_eq!(a.cols, b.rows, "gemm dims");
    assert_eq!(c.rows, a.rows);
    assert_eq!(c.cols, b.cols);
    let (n, k, m) = (a.rows, a.cols, b.cols);
    for kb in (0..k).step_by(KB) {
        let kend = (kb + KB).min(k);
        for jb in (0..m).step_by(JB) {
            let jend = (jb + JB).min(m);
            for i in 0..n {
                let arow = &a.data[i * k..(i + 1) * k];
                let crow = &mut c.data[i * m + jb..i * m + jend];
                for kk in kb..kend {
                    let aik = alpha * arow[kk];
                    if aik == 0.0 {
                        continue;
                    }
                    let brow = &b.data[kk * m + jb..kk * m + jend];
                    for (cv, bv) in crow.iter_mut().zip(brow) {
                        *cv += aik * bv;
                    }
                }
            }
        }
    }
}

/// C = Aᵀ @ A (symmetric rank-k style; exploits symmetry: computes the
/// upper triangle then mirrors). Used for the ρAᵀA/ρGᵀG Hessian terms.
pub fn ata(a: &Mat) -> Mat {
    let (r, n) = (a.rows, a.cols);
    let mut c = Mat::zeros(n, n);
    for kk in 0..r {
        let row = &a.data[kk * n..(kk + 1) * n];
        for i in 0..n {
            let aik = row[i];
            if aik == 0.0 {
                continue;
            }
            let crow = &mut c.data[i * n + i..(i + 1) * n];
            let brow = &row[i..];
            for (cv, bv) in crow.iter_mut().zip(brow) {
                *cv += aik * bv;
            }
        }
    }
    // mirror upper to lower
    for i in 0..n {
        for j in (i + 1)..n {
            c.data[j * n + i] = c.data[i * n + j];
        }
    }
    c
}

/// y = A @ x.
pub fn gemv(a: &Mat, x: &[f64]) -> Vec<f64> {
    assert_eq!(a.cols, x.len(), "gemv dims");
    let mut y = vec![0.0; a.rows];
    gemv_acc(&mut y, 1.0, a, x);
    y
}

/// y += alpha * A @ x (row-wise dot: contiguous per row).
pub fn gemv_acc(y: &mut [f64], alpha: f64, a: &Mat, x: &[f64]) {
    assert_eq!(a.cols, x.len());
    assert_eq!(a.rows, y.len());
    for i in 0..a.rows {
        y[i] += alpha * super::dense::dot(a.row(i), x);
    }
}

/// y = Aᵀ @ x without materializing the transpose (column axpys).
pub fn gemv_t(a: &Mat, x: &[f64]) -> Vec<f64> {
    assert_eq!(a.rows, x.len(), "gemv_t dims");
    let mut y = vec![0.0; a.cols];
    gemv_t_acc(&mut y, 1.0, a, x);
    y
}

/// y += alpha * Aᵀ @ x.
pub fn gemv_t_acc(y: &mut [f64], alpha: f64, a: &Mat, x: &[f64]) {
    assert_eq!(a.rows, x.len());
    assert_eq!(a.cols, y.len());
    for i in 0..a.rows {
        let s = alpha * x[i];
        if s == 0.0 {
            continue;
        }
        super::dense::axpy(y, s, a.row(i));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn randmat(r: usize, c: usize, rng: &mut Pcg64) -> Mat {
        Mat::from_vec(r, c, rng.normal_vec(r * c))
    }

    fn gemm_naive(a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0.0;
                for k in 0..a.cols {
                    s += a[(i, k)] * b[(k, j)];
                }
                c[(i, j)] = s;
            }
        }
        c
    }

    #[test]
    fn gemm_matches_naive_odd_sizes() {
        let mut rng = Pcg64::new(1);
        for &(n, k, m) in &[(3, 5, 7), (65, 64, 63), (130, 70, 129)] {
            let a = randmat(n, k, &mut rng);
            let b = randmat(k, m, &mut rng);
            let c = gemm(&a, &b);
            let cn = gemm_naive(&a, &b);
            assert!(c.max_abs_diff(&cn) < 1e-10, "n={n} k={k} m={m}");
        }
    }

    #[test]
    fn gemm_identity() {
        let mut rng = Pcg64::new(2);
        let a = randmat(20, 20, &mut rng);
        let c = gemm(&a, &Mat::eye(20));
        assert!(c.max_abs_diff(&a) < 1e-14);
    }

    #[test]
    fn ata_matches_gemm() {
        let mut rng = Pcg64::new(3);
        let a = randmat(17, 23, &mut rng);
        let direct = ata(&a);
        let viag = gemm(&a.transpose(), &a);
        assert!(direct.max_abs_diff(&viag) < 1e-10);
    }

    #[test]
    fn gemv_and_t_match_gemm() {
        let mut rng = Pcg64::new(4);
        let a = randmat(9, 13, &mut rng);
        let x = rng.normal_vec(13);
        let z = rng.normal_vec(9);
        let xm = Mat::from_vec(13, 1, x.clone());
        let want = gemm(&a, &xm);
        let got = gemv(&a, &x);
        for i in 0..9 {
            assert!((got[i] - want[(i, 0)]).abs() < 1e-12);
        }
        let wt = gemm(&a.transpose(), &Mat::from_vec(9, 1, z.clone()));
        let gt = gemv_t(&a, &z);
        for i in 0..13 {
            assert!((gt[i] - wt[(i, 0)]).abs() < 1e-12);
        }
    }

    #[test]
    fn gemm_acc_alpha() {
        let mut rng = Pcg64::new(5);
        let a = randmat(8, 8, &mut rng);
        let b = randmat(8, 8, &mut rng);
        let mut c = Mat::eye(8);
        gemm_acc(&mut c, -2.0, &a, &b);
        let mut want = gemm(&a, &b);
        want.scale(-2.0);
        want.axpy(1.0, &Mat::eye(8));
        assert!(c.max_abs_diff(&want) < 1e-10);
    }
}
