//! Blocked matrix kernels (the in-repo BLAS).
//!
//! `gemm` uses an i-k-j loop order with row-panel blocking: the inner loop
//! is a contiguous axpy over a row of B, which the compiler auto-vectorizes
//! well. This is the single hottest routine in the native engine (Hessian
//! assembly AᵀA/GᵀG, Jacobian propagation, KKT factorizations) — see
//! EXPERIMENTS.md §Perf for the before/after of the blocking.

use super::dense::Mat;
use std::thread;

/// Tile edge for the k/j blocking. 64 keeps an A-panel (64x64 f64 = 32 KB)
/// inside L1/L2 comfortably; measured best among {32, 64, 128} here.
const KB: usize = 64;
const JB: usize = 256;

/// Multiply-add count below which the parallel dispatcher stays serial
/// (thread-spawn overhead would dominate the kernel).
const PAR_MIN_FLOPS: usize = 1 << 20;
/// Worker-thread cap for one kernel launch.
const PAR_MAX_THREADS: usize = 8;

/// C = A @ B.
pub fn gemm(a: &Mat, b: &Mat) -> Mat {
    let mut c = Mat::zeros(a.rows, b.cols);
    gemm_acc(&mut c, 1.0, a, b);
    c
}

/// C += alpha * A @ B (blocked i-k-j).
pub fn gemm_acc(c: &mut Mat, alpha: f64, a: &Mat, b: &Mat) {
    assert_eq!(a.cols, b.rows, "gemm dims");
    assert_eq!(c.rows, a.rows);
    assert_eq!(c.cols, b.cols);
    let (n, k, m) = (a.rows, a.cols, b.cols);
    for kb in (0..k).step_by(KB) {
        let kend = (kb + KB).min(k);
        for jb in (0..m).step_by(JB) {
            let jend = (jb + JB).min(m);
            for i in 0..n {
                let arow = &a.data[i * k..(i + 1) * k];
                let crow = &mut c.data[i * m + jb..i * m + jend];
                for kk in kb..kend {
                    let aik = alpha * arow[kk];
                    if aik == 0.0 {
                        continue;
                    }
                    let brow = &b.data[kk * m + jb..kk * m + jend];
                    for (cv, bv) in crow.iter_mut().zip(brow) {
                        *cv += aik * bv;
                    }
                }
            }
        }
    }
}

/// Blocked kernel over one horizontal slab of C (rows `r0..r1`, stored in
/// `cdata`), with an optional per-row activity mask (absolute indices into
/// A's rows) and optional column ranges. Accumulation order over k for any
/// (i, j) matches [`gemm_acc`] exactly (ascending k blocks, ascending k),
/// so masked/parallel results are bitwise identical to the serial kernel.
fn gemm_span(
    cdata: &mut [f64],
    r0: usize,
    r1: usize,
    alpha: f64,
    a: &Mat,
    b: &Mat,
    rows_active: Option<&[bool]>,
    col_ranges: Option<&[(usize, usize)]>,
) {
    let k = a.cols;
    let m = b.cols;
    let full = [(0usize, m)];
    let ranges: &[(usize, usize)] = match col_ranges {
        Some(r) => r,
        None => &full,
    };
    for &(j0, j1) in ranges {
        for kb in (0..k).step_by(KB) {
            let kend = (kb + KB).min(k);
            for jb in (j0..j1).step_by(JB) {
                let jend = (jb + JB).min(j1);
                for i in r0..r1 {
                    if let Some(act) = rows_active {
                        if !act[i] {
                            continue;
                        }
                    }
                    let arow = &a.data[i * k..(i + 1) * k];
                    let crow = &mut cdata
                        [(i - r0) * m + jb..(i - r0) * m + jend];
                    for kk in kb..kend {
                        let aik = alpha * arow[kk];
                        if aik == 0.0 {
                            continue;
                        }
                        let brow = &b.data[kk * m + jb..kk * m + jend];
                        for (cv, bv) in crow.iter_mut().zip(brow) {
                            *cv += aik * bv;
                        }
                    }
                }
            }
        }
    }
}

/// Shared entry point for the plain / row-masked / column-ranged gemm
/// variants: validates shapes, estimates the live flop count, and splits
/// C's rows across up to [`PAR_MAX_THREADS`] scoped threads when the
/// kernel is large enough to amortize the spawns.
fn gemm_dispatch(
    c: &mut Mat,
    alpha: f64,
    a: &Mat,
    b: &Mat,
    rows_active: Option<&[bool]>,
    col_ranges: Option<&[(usize, usize)]>,
) {
    assert_eq!(a.cols, b.rows, "gemm dims");
    assert_eq!(c.rows, a.rows);
    assert_eq!(c.cols, b.cols);
    if let Some(act) = rows_active {
        assert_eq!(act.len(), a.rows, "row mask length");
    }
    if let Some(rs) = col_ranges {
        let mut prev = 0usize;
        for &(j0, j1) in rs {
            assert!(j0 >= prev && j1 >= j0 && j1 <= c.cols, "col ranges");
            prev = j1;
        }
    }
    let rows_live = rows_active
        .map(|act| act.iter().filter(|&&f| f).count())
        .unwrap_or(a.rows);
    let cols_live = col_ranges
        .map(|rs| rs.iter().map(|&(j0, j1)| j1 - j0).sum())
        .unwrap_or(b.cols);
    if rows_live == 0 || cols_live == 0 || a.cols == 0 || c.cols == 0 {
        return;
    }
    let flops = rows_live * a.cols * cols_live;
    let threads = if flops < PAR_MIN_FLOPS || c.rows < 2 {
        1
    } else {
        thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
            .min(PAR_MAX_THREADS)
            .min(c.rows)
    };
    row_split_dispatch(c, threads, |cdata, r0, r1| {
        gemm_span(cdata, r0, r1, alpha, a, b, rows_active, col_ranges)
    });
}

/// Split C's rows into up to `threads` contiguous spans and run `f` on
/// each span from a scoped worker thread (`f(span_data, r0, r1)` with
/// `span_data` = rows `r0..r1` of C). `threads <= 1` runs inline. The
/// shared row-split behind [`par_gemm_acc`], the masked gemm variants,
/// and [`ata`].
fn row_split_dispatch(
    c: &mut Mat,
    threads: usize,
    f: impl Fn(&mut [f64], usize, usize) + Sync,
) {
    let m = c.cols;
    let n = c.rows;
    if threads <= 1 {
        f(&mut c.data, 0, n);
        return;
    }
    let rows_per = n.div_ceil(threads);
    thread::scope(|s| {
        let mut rest: &mut [f64] = &mut c.data;
        let mut r0 = 0usize;
        let f = &f;
        while r0 < n {
            let r1 = (r0 + rows_per).min(n);
            let (head, tail) = rest.split_at_mut((r1 - r0) * m);
            rest = tail;
            s.spawn(move || f(head, r0, r1));
            r0 = r1;
        }
    });
}

/// C += alpha * A @ B, row-split across up to 8 worker threads when the
/// kernel is large enough to pay for them. Bitwise identical to
/// [`gemm_acc`] (per-row accumulation order is unchanged).
pub fn par_gemm_acc(c: &mut Mat, alpha: f64, a: &Mat, b: &Mat) {
    gemm_dispatch(c, alpha, a, b, None, None);
}

/// Row-masked C += alpha * A @ B: rows with `active[i] == false` are left
/// untouched and consume no flops. This is the batch engine's iterate
/// update — converged batch elements stop costing work (§4.3 truncation,
/// per element).
pub fn gemm_acc_rows(
    c: &mut Mat,
    alpha: f64,
    a: &Mat,
    b: &Mat,
    active: &[bool],
) {
    gemm_dispatch(c, alpha, a, b, Some(active), None);
}

/// Column-range-masked C += alpha * A @ B: only columns inside the given
/// disjoint ascending `[j0, j1)` ranges are updated. The batch engine
/// stacks per-element Jacobians as column blocks; deactivated elements'
/// blocks are simply absent from the ranges.
pub fn gemm_acc_cols(
    c: &mut Mat,
    alpha: f64,
    a: &Mat,
    b: &Mat,
    ranges: &[(usize, usize)],
) {
    gemm_dispatch(c, alpha, a, b, None, Some(ranges));
}

/// Y += alpha * X restricted to the given column ranges (the cheap
/// element-wise companion of [`gemm_acc_cols`]).
pub fn axpy_cols(
    y: &mut Mat,
    alpha: f64,
    x: &Mat,
    ranges: &[(usize, usize)],
) {
    assert_eq!((y.rows, y.cols), (x.rows, x.cols), "axpy_cols dims");
    for i in 0..y.rows {
        let yr = y.row_mut(i);
        let xr = x.row(i);
        for &(j0, j1) in ranges {
            for j in j0..j1 {
                yr[j] += alpha * xr[j];
            }
        }
    }
}

/// One horizontal slab of the Aᵀ A upper triangle: rows `r0..r1` of C
/// (stored in `cdata`). Per-entry accumulation is ascending `kk`, so any
/// row split produces bitwise-identical results to the serial kernel.
fn ata_span(cdata: &mut [f64], r0: usize, r1: usize, a: &Mat) {
    let n = a.cols;
    for kk in 0..a.rows {
        let row = &a.data[kk * n..(kk + 1) * n];
        for i in r0..r1 {
            let aik = row[i];
            if aik == 0.0 {
                continue;
            }
            let crow = &mut cdata[(i - r0) * n + i..(i - r0) * n + n];
            let brow = &row[i..];
            for (cv, bv) in crow.iter_mut().zip(brow) {
                *cv += aik * bv;
            }
        }
    }
}

/// C = Aᵀ @ A (symmetric rank-k style; exploits symmetry: computes the
/// upper triangle then mirrors). Used for the ρAᵀA/ρGᵀG Hessian terms —
/// the registration hot spot at large n — so the upper-triangle build is
/// row-split across worker threads through the same dispatcher as
/// [`par_gemm_acc`] once the kernel is big enough to pay for spawns.
pub fn ata(a: &Mat) -> Mat {
    let (r, n) = (a.rows, a.cols);
    let mut c = Mat::zeros(n, n);
    // ~half the gemm flop count (upper triangle only)
    let flops = r * n * n / 2;
    let threads = if flops < PAR_MIN_FLOPS || n < 2 {
        1
    } else {
        thread::available_parallelism()
            .map(|t| t.get())
            .unwrap_or(1)
            .min(PAR_MAX_THREADS)
            .min(n)
    };
    row_split_dispatch(&mut c, threads, |cdata, r0, r1| {
        ata_span(cdata, r0, r1, a)
    });
    // mirror upper to lower
    for i in 0..n {
        for j in (i + 1)..n {
            c.data[j * n + i] = c.data[i * n + j];
        }
    }
    c
}

/// y = A @ x.
pub fn gemv(a: &Mat, x: &[f64]) -> Vec<f64> {
    assert_eq!(a.cols, x.len(), "gemv dims");
    let mut y = vec![0.0; a.rows];
    gemv_acc(&mut y, 1.0, a, x);
    y
}

/// y += alpha * A @ x (row-wise dot: contiguous per row).
pub fn gemv_acc(y: &mut [f64], alpha: f64, a: &Mat, x: &[f64]) {
    assert_eq!(a.cols, x.len());
    assert_eq!(a.rows, y.len());
    for i in 0..a.rows {
        y[i] += alpha * super::dense::dot(a.row(i), x);
    }
}

/// y = Aᵀ @ x without materializing the transpose (column axpys).
pub fn gemv_t(a: &Mat, x: &[f64]) -> Vec<f64> {
    assert_eq!(a.rows, x.len(), "gemv_t dims");
    let mut y = vec![0.0; a.cols];
    gemv_t_acc(&mut y, 1.0, a, x);
    y
}

/// y += alpha * Aᵀ @ x.
pub fn gemv_t_acc(y: &mut [f64], alpha: f64, a: &Mat, x: &[f64]) {
    assert_eq!(a.rows, x.len());
    assert_eq!(a.cols, y.len());
    for i in 0..a.rows {
        let s = alpha * x[i];
        if s == 0.0 {
            continue;
        }
        super::dense::axpy(y, s, a.row(i));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn randmat(r: usize, c: usize, rng: &mut Pcg64) -> Mat {
        Mat::from_vec(r, c, rng.normal_vec(r * c))
    }

    fn gemm_naive(a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0.0;
                for k in 0..a.cols {
                    s += a[(i, k)] * b[(k, j)];
                }
                c[(i, j)] = s;
            }
        }
        c
    }

    #[test]
    fn gemm_matches_naive_odd_sizes() {
        let mut rng = Pcg64::new(1);
        for &(n, k, m) in &[(3, 5, 7), (65, 64, 63), (130, 70, 129)] {
            let a = randmat(n, k, &mut rng);
            let b = randmat(k, m, &mut rng);
            let c = gemm(&a, &b);
            let cn = gemm_naive(&a, &b);
            assert!(c.max_abs_diff(&cn) < 1e-10, "n={n} k={k} m={m}");
        }
    }

    #[test]
    fn gemm_identity() {
        let mut rng = Pcg64::new(2);
        let a = randmat(20, 20, &mut rng);
        let c = gemm(&a, &Mat::eye(20));
        assert!(c.max_abs_diff(&a) < 1e-14);
    }

    #[test]
    fn ata_matches_gemm() {
        let mut rng = Pcg64::new(3);
        let a = randmat(17, 23, &mut rng);
        let direct = ata(&a);
        let viag = gemm(&a.transpose(), &a);
        assert!(direct.max_abs_diff(&viag) < 1e-10);
    }

    #[test]
    fn parallel_ata_matches_serial_bitwise() {
        let mut rng = Pcg64::new(17);
        // large enough to cross the parallel threshold (r·n²/2 ≥ 2^20)
        let a = randmat(300, 120, &mut rng);
        let par = ata(&a);
        // serial reference: the span kernel over the full row range
        let mut ser = Mat::zeros(120, 120);
        ata_span(&mut ser.data, 0, 120, &a);
        for i in 0..120 {
            for j in (i + 1)..120 {
                ser.data[j * 120 + i] = ser.data[i * 120 + j];
            }
        }
        assert_eq!(par.data, ser.data, "row split changed results");
        let viag = gemm(&a.transpose(), &a);
        assert!(par.max_abs_diff(&viag) < 1e-9);
    }

    #[test]
    fn gemv_and_t_match_gemm() {
        let mut rng = Pcg64::new(4);
        let a = randmat(9, 13, &mut rng);
        let x = rng.normal_vec(13);
        let z = rng.normal_vec(9);
        let xm = Mat::from_vec(13, 1, x.clone());
        let want = gemm(&a, &xm);
        let got = gemv(&a, &x);
        for i in 0..9 {
            assert!((got[i] - want[(i, 0)]).abs() < 1e-12);
        }
        let wt = gemm(&a.transpose(), &Mat::from_vec(9, 1, z.clone()));
        let gt = gemv_t(&a, &z);
        for i in 0..13 {
            assert!((gt[i] - wt[(i, 0)]).abs() < 1e-12);
        }
    }

    #[test]
    fn par_gemm_matches_serial_bitwise() {
        let mut rng = Pcg64::new(11);
        // large enough to cross the parallel threshold (>= 2^20 flops)
        let a = randmat(128, 96, &mut rng);
        let b = randmat(96, 120, &mut rng);
        let mut serial = Mat::zeros(128, 120);
        gemm_acc(&mut serial, 0.7, &a, &b);
        let mut par = Mat::zeros(128, 120);
        par_gemm_acc(&mut par, 0.7, &a, &b);
        assert_eq!(serial.data, par.data, "parallel split changed results");
    }

    #[test]
    fn row_masked_gemm_skips_inactive_rows() {
        let mut rng = Pcg64::new(12);
        let a = randmat(9, 7, &mut rng);
        let b = randmat(7, 5, &mut rng);
        let active: Vec<bool> =
            (0..9).map(|i| i % 3 != 1).collect();
        let mut c = Mat::zeros(9, 5);
        // poison inactive rows to prove they are untouched
        for i in 0..9 {
            if !active[i] {
                c.row_mut(i).iter_mut().for_each(|v| *v = 42.0);
            }
        }
        gemm_acc_rows(&mut c, 1.0, &a, &b, &active);
        let full = gemm(&a, &b);
        for i in 0..9 {
            for j in 0..5 {
                let want = if active[i] { full[(i, j)] } else { 42.0 };
                assert!((c[(i, j)] - want).abs() < 1e-12, "({i},{j})");
            }
        }
    }

    #[test]
    fn col_ranged_gemm_matches_full_inside_ranges() {
        let mut rng = Pcg64::new(13);
        let a = randmat(8, 6, &mut rng);
        let b = randmat(6, 12, &mut rng);
        let ranges = [(0usize, 3usize), (6, 9)];
        let mut c = Mat::zeros(8, 12);
        gemm_acc_cols(&mut c, 2.0, &a, &b, &ranges);
        let mut full = Mat::zeros(8, 12);
        gemm_acc(&mut full, 2.0, &a, &b);
        for i in 0..8 {
            for j in 0..12 {
                let inside = (j < 3) || (6..9).contains(&j);
                let want = if inside { full[(i, j)] } else { 0.0 };
                assert!((c[(i, j)] - want).abs() < 1e-12, "({i},{j})");
            }
        }
    }

    #[test]
    fn axpy_cols_restricted() {
        let mut y = Mat::zeros(2, 4);
        let x = Mat::from_rows(&[&[1., 2., 3., 4.], &[5., 6., 7., 8.]]);
        axpy_cols(&mut y, 2.0, &x, &[(1, 3)]);
        assert_eq!(y.row(0), &[0.0, 4.0, 6.0, 0.0]);
        assert_eq!(y.row(1), &[0.0, 12.0, 14.0, 0.0]);
    }

    #[test]
    fn empty_masks_are_noops() {
        let mut rng = Pcg64::new(14);
        let a = randmat(4, 4, &mut rng);
        let b = randmat(4, 4, &mut rng);
        let mut c = Mat::zeros(4, 4);
        gemm_acc_rows(&mut c, 1.0, &a, &b, &[false; 4]);
        assert_eq!(c.data, vec![0.0; 16]);
        gemm_acc_cols(&mut c, 1.0, &a, &b, &[]);
        assert_eq!(c.data, vec![0.0; 16]);
    }

    #[test]
    fn gemm_acc_alpha() {
        let mut rng = Pcg64::new(5);
        let a = randmat(8, 8, &mut rng);
        let b = randmat(8, 8, &mut rng);
        let mut c = Mat::eye(8);
        gemm_acc(&mut c, -2.0, &a, &b);
        let mut want = gemm(&a, &b);
        want.scale(-2.0);
        want.axpy(1.0, &Mat::eye(8));
        assert!(c.max_abs_diff(&want) < 1e-10);
    }
}
