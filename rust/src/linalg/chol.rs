//! Cholesky factorization and SPD solves.
//!
//! The Alt-Diff Hessian H = P + ρAᵀA + ρGᵀG is SPD by construction
//! (P ⪰ 0, ρ > 0, and the penalty terms are Gram matrices), so Cholesky is
//! the right factorization: one O(n³/3) factor at variant-registration
//! time, O(n²) triangular solves per ADMM iteration thereafter — this is
//! the "inheritance of the Hessian" of paper Appendix B.1 made concrete.

use super::dense::Mat;
use crate::error::AltDiffError;

/// Lower-triangular Cholesky factor L with A = L Lᵀ.
#[derive(Clone, Debug)]
pub struct Chol {
    /// The factor L (lower triangle; upper entries are zero).
    pub l: Mat,
}

impl Chol {
    /// Factor an SPD matrix. Fails (NotSpd) on a non-positive pivot.
    pub fn factor(a: &Mat) -> Result<Chol, AltDiffError> {
        assert_eq!(a.rows, a.cols, "cholesky needs square");
        let n = a.rows;
        let mut l = Mat::zeros(n, n);
        for j in 0..n {
            // d = a_jj - sum_k l_jk^2
            let lrow_j = &l.data[j * n..j * n + j];
            let mut d = a[(j, j)];
            for v in lrow_j {
                d -= v * v;
            }
            if d <= 0.0 || !d.is_finite() {
                return Err(AltDiffError::NotSpd { pivot: j, value: d });
            }
            let djj = d.sqrt();
            l[(j, j)] = djj;
            let inv = 1.0 / djj;
            for i in (j + 1)..n {
                // l_ij = (a_ij - sum_k l_ik l_jk) / l_jj
                let (head, tail) = l.data.split_at(i * n);
                let lrow_j = &head[j * n..j * n + j];
                let lrow_i = &tail[..j];
                let mut s = a[(i, j)];
                for (x, y) in lrow_i.iter().zip(lrow_j) {
                    s -= x * y;
                }
                l.data[i * n + j] = s * inv;
            }
        }
        Ok(Chol { l })
    }

    /// Solve A x = b via forward + backward substitution.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let mut x = b.to_vec();
        self.solve_in_place(&mut x);
        x
    }

    /// In-place solve (no allocation — hot-path variant).
    pub fn solve_in_place(&self, x: &mut [f64]) {
        let n = self.l.rows;
        debug_assert_eq!(x.len(), n);
        // L y = b
        for i in 0..n {
            let row = &self.l.data[i * n..i * n + i];
            let mut s = x[i];
            for (lij, xj) in row.iter().zip(x.iter()) {
                s -= lij * xj;
            }
            x[i] = s / self.l.data[i * n + i];
        }
        // Lᵀ x = y
        for i in (0..n).rev() {
            let mut s = x[i];
            for j in (i + 1)..n {
                s -= self.l.data[j * n + i] * x[j];
            }
            x[i] = s / self.l.data[i * n + i];
        }
    }

    /// Solve A X = B column-block (B rows x cols). Used for Jacobian
    /// right-hand sides: one factorization, p simultaneous solves.
    pub fn solve_mat(&self, b: &Mat) -> Mat {
        let n = self.l.rows;
        assert_eq!(b.rows, n);
        // work column-major for cache: transpose, solve rows, transpose.
        let bt = b.transpose();
        let mut out_t = Mat::zeros(b.cols, n);
        let mut buf = vec![0.0; n];
        for c in 0..b.cols {
            buf.copy_from_slice(bt.row(c));
            self.solve_in_place(&mut buf);
            out_t.row_mut(c).copy_from_slice(&buf);
        }
        out_t.transpose()
    }

    /// Explicit inverse (only when the inverse itself ships to an artifact
    /// as the `hinv` input; native paths prefer `solve`).
    pub fn inverse(&self) -> Mat {
        self.solve_mat(&Mat::eye(self.l.rows))
    }

    /// log det A = 2 sum log l_ii.
    pub fn logdet(&self) -> f64 {
        let n = self.l.rows;
        (0..n).map(|i| self.l.data[i * n + i].ln()).sum::<f64>() * 2.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::blas::{ata, gemm};
    use crate::util::rng::Pcg64;

    fn spd(n: usize, seed: u64) -> Mat {
        let mut rng = Pcg64::new(seed);
        let m = Mat::from_vec(n, n, rng.normal_vec(n * n));
        let mut a = ata(&m);
        for i in 0..n {
            a[(i, i)] += 0.5 * n as f64;
        }
        a
    }

    #[test]
    fn factor_reconstructs() {
        let a = spd(12, 1);
        let ch = Chol::factor(&a).unwrap();
        let rec = gemm(&ch.l, &ch.l.transpose());
        assert!(rec.max_abs_diff(&a) < 1e-8);
    }

    #[test]
    fn solve_residual_small() {
        let a = spd(20, 2);
        let ch = Chol::factor(&a).unwrap();
        let mut rng = Pcg64::new(3);
        let b = rng.normal_vec(20);
        let x = ch.solve(&b);
        let ax = crate::linalg::blas::gemv(&a, &x);
        for i in 0..20 {
            assert!((ax[i] - b[i]).abs() < 1e-8);
        }
    }

    #[test]
    fn inverse_is_inverse() {
        let a = spd(10, 4);
        let inv = Chol::factor(&a).unwrap().inverse();
        let prod = gemm(&a, &inv);
        assert!(prod.max_abs_diff(&Mat::eye(10)) < 1e-8);
    }

    #[test]
    fn solve_mat_matches_columns() {
        let a = spd(8, 5);
        let ch = Chol::factor(&a).unwrap();
        let mut rng = Pcg64::new(6);
        let b = Mat::from_vec(8, 3, rng.normal_vec(24));
        let x = ch.solve_mat(&b);
        for c in 0..3 {
            let bc = b.col(c);
            let xc = ch.solve(&bc);
            for i in 0..8 {
                assert!((x[(i, c)] - xc[i]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn rejects_indefinite() {
        let mut a = Mat::eye(3);
        a[(2, 2)] = -1.0;
        assert!(Chol::factor(&a).is_err());
    }

    #[test]
    fn logdet_of_diag() {
        let a = Mat::diag(&[2.0, 3.0, 4.0]);
        let ch = Chol::factor(&a).unwrap();
        assert!((ch.logdet() - (24f64).ln()).abs() < 1e-12);
    }
}
