//! Dense row-major matrix type and core operations.
//!
//! No external BLAS in this environment; `gemm`/`gemv` live in
//! [`super::blas`] with blocked kernels. This module owns the storage
//! type, constructors, and the small structural ops everything builds on.

use std::fmt;

/// Dense row-major `rows x cols` matrix of f64.
#[derive(Clone, PartialEq)]
pub struct Mat {
    /// Number of rows.
    pub rows: usize,
    /// Number of columns.
    pub cols: usize,
    /// Row-major storage: entry (i, j) lives at `data[i * cols + j]`.
    pub data: Vec<f64>,
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Mat {}x{}", self.rows, self.cols)?;
        for i in 0..self.rows.min(6) {
            let cols = self.cols.min(8);
            let row: Vec<String> = (0..cols)
                .map(|j| format!("{:9.4}", self[(i, j)]))
                .collect();
            writeln!(f, "  [{}{}]", row.join(", "),
                if self.cols > 8 { ", ..." } else { "" })?;
        }
        if self.rows > 6 {
            writeln!(f, "  ...")?;
        }
        Ok(())
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline(always)]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline(always)]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

impl Mat {
    /// All-zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Build from row slices (all the same length).
    pub fn from_rows(rows: &[&[f64]]) -> Self {
        let r = rows.len();
        let c = rows.first().map(|x| x.len()).unwrap_or(0);
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Mat { rows: r, cols: c, data }
    }

    /// Wrap a row-major buffer (length must be rows·cols).
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Mat { rows, cols, data }
    }

    /// Scaled diagonal matrix diag(d).
    pub fn diag(d: &[f64]) -> Self {
        let mut m = Mat::zeros(d.len(), d.len());
        for (i, &v) in d.iter().enumerate() {
            m[(i, i)] = v;
        }
        m
    }

    /// Row i as a contiguous slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Row i as a mutable contiguous slice.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Blocked out-of-place transpose.
    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        // blocked transpose for cache friendliness
        const B: usize = 32;
        for ib in (0..self.rows).step_by(B) {
            for jb in (0..self.cols).step_by(B) {
                for i in ib..(ib + B).min(self.rows) {
                    for j in jb..(jb + B).min(self.cols) {
                        t.data[j * self.rows + i] =
                            self.data[i * self.cols + j];
                    }
                }
            }
        }
        t
    }

    /// In-place scalar multiply.
    pub fn scale(&mut self, s: f64) {
        for v in &mut self.data {
            *v *= s;
        }
    }

    /// self += s * other (axpy on matrices).
    pub fn axpy(&mut self, s: f64, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += s * b;
        }
    }

    /// self + other (allocating).
    pub fn add(&self, other: &Mat) -> Mat {
        let mut out = self.clone();
        out.axpy(1.0, other);
        out
    }

    /// self − other (allocating).
    pub fn sub(&self, other: &Mat) -> Mat {
        let mut out = self.clone();
        out.axpy(-1.0, other);
        out
    }

    /// Frobenius norm.
    pub fn fro(&self) -> f64 {
        self.data.iter().map(|v| v * v).sum::<f64>().sqrt()
    }

    /// Max |a_ij - b_ij|.
    pub fn max_abs_diff(&self, other: &Mat) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    /// Symmetrize in place: A <- (A + A^T)/2 (numerical hygiene for SPD).
    pub fn symmetrize(&mut self) {
        assert_eq!(self.rows, self.cols);
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                let v = 0.5 * (self[(i, j)] + self[(j, i)]);
                self[(i, j)] = v;
                self[(j, i)] = v;
            }
        }
    }

    /// Horizontal stack [self | other].
    pub fn hstack(&self, other: &Mat) -> Mat {
        assert_eq!(self.rows, other.rows);
        let mut out = Mat::zeros(self.rows, self.cols + other.cols);
        for i in 0..self.rows {
            out.row_mut(i)[..self.cols].copy_from_slice(self.row(i));
            out.row_mut(i)[self.cols..].copy_from_slice(other.row(i));
        }
        out
    }

    /// Vertical stack [self; other].
    pub fn vstack(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.cols);
        let mut data = self.data.clone();
        data.extend_from_slice(&other.data);
        Mat::from_vec(self.rows + other.rows, self.cols, data)
    }

    /// Extract column j.
    pub fn col(&self, j: usize) -> Vec<f64> {
        (0..self.rows).map(|i| self[(i, j)]).collect()
    }

    /// Set column j.
    pub fn set_col(&mut self, j: usize, v: &[f64]) {
        assert_eq!(v.len(), self.rows);
        for i in 0..self.rows {
            self[(i, j)] = v[i];
        }
    }

    /// f32 export (PJRT literals are f32 in the compiled family).
    pub fn to_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&v| v as f32).collect()
    }
}

// ------------------------------------------------------------- vector ops

/// Euclidean norm.
pub fn norm2(v: &[f64]) -> f64 {
    v.iter().map(|x| x * x).sum::<f64>().sqrt()
}

/// Dot product.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    // 4-way unrolled accumulation: measurably faster than the naive zip
    // and deterministic (fixed association order).
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for c in 0..chunks {
        let i = 4 * c;
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
    }
    let mut s = (s0 + s1) + (s2 + s3);
    for i in 4 * chunks..n {
        s += a[i] * b[i];
    }
    s
}

/// y += s * x.
#[inline]
pub fn axpy(y: &mut [f64], s: f64, x: &[f64]) {
    debug_assert_eq!(y.len(), x.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += s * xi;
    }
}

/// Elementwise max(v, 0) — the ReLU slack projection (paper eq. 6).
pub fn relu(v: &[f64]) -> Vec<f64> {
    v.iter().map(|&x| x.max(0.0)).collect()
}

/// Elementwise a − b.
pub fn sub_vec(a: &[f64], b: &[f64]) -> Vec<f64> {
    a.iter().zip(b).map(|(x, y)| x - y).collect()
}

/// Elementwise a + b.
pub fn add_vec(a: &[f64], b: &[f64]) -> Vec<f64> {
    a.iter().zip(b).map(|(x, y)| x + y).collect()
}

/// Cosine similarity of two flattened arrays (paper's "cosine distance"
/// metric reports this value; 1.0 = identical direction).
pub fn cosine(a: &[f64], b: &[f64]) -> f64 {
    let na = norm2(a);
    let nb = norm2(b);
    if na == 0.0 || nb == 0.0 {
        return 0.0;
    }
    dot(a, b) / (na * nb)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_and_eye() {
        let e = Mat::eye(3);
        assert_eq!(e[(0, 0)], 1.0);
        assert_eq!(e[(0, 1)], 0.0);
        assert_eq!(e.fro(), 3f64.sqrt());
    }

    #[test]
    fn transpose_roundtrip() {
        let m = Mat::from_rows(&[&[1., 2., 3.], &[4., 5., 6.]]);
        let t = m.transpose();
        assert_eq!(t.rows, 3);
        assert_eq!(t[(2, 1)], 6.0);
        assert_eq!(t.transpose(), m);
    }

    #[test]
    fn stack_ops() {
        let a = Mat::eye(2);
        let b = Mat::zeros(2, 2);
        let h = a.hstack(&b);
        assert_eq!((h.rows, h.cols), (2, 4));
        let v = a.vstack(&b);
        assert_eq!((v.rows, v.cols), (4, 2));
        assert_eq!(v[(0, 0)], 1.0);
        assert_eq!(v[(2, 0)], 0.0);
    }

    #[test]
    fn vector_ops() {
        let a = [1.0, 2.0, 3.0, 4.0, 5.0];
        let b = [5.0, 4.0, 3.0, 2.0, 1.0];
        assert_eq!(dot(&a, &b), 35.0);
        assert!((norm2(&a) - 55f64.sqrt()).abs() < 1e-12);
        assert_eq!(relu(&[-1.0, 2.0]), vec![0.0, 2.0]);
        assert!((cosine(&a, &a) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn axpy_matrix() {
        let mut a = Mat::eye(2);
        let b = Mat::eye(2);
        a.axpy(2.0, &b);
        assert_eq!(a[(0, 0)], 3.0);
    }

    #[test]
    fn symmetrize() {
        let mut m = Mat::from_rows(&[&[1., 2.], &[4., 1.]]);
        m.symmetrize();
        assert_eq!(m[(0, 1)], 3.0);
        assert_eq!(m[(1, 0)], 3.0);
    }

    #[test]
    fn col_ops() {
        let mut m = Mat::zeros(3, 2);
        m.set_col(1, &[1.0, 2.0, 3.0]);
        assert_eq!(m.col(1), vec![1.0, 2.0, 3.0]);
        assert_eq!(m.col(0), vec![0.0, 0.0, 0.0]);
    }
}
