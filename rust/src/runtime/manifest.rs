//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! rust runtime. TSV, one row per compiled variant:
//!     name  n  m  p  k  batch  rho  in_shapes  out_shapes

use crate::error::{AltDiffError, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One compiled variant of the QP layer family.
#[derive(Clone, Debug, PartialEq)]
pub struct Variant {
    /// Variant name (`qp_n{n}_m{m}_p{p}_k{k}_b{batch}`).
    pub name: String,
    /// Variables n.
    pub n: usize,
    /// Inequality constraints m.
    pub m: usize,
    /// Equality constraints p.
    pub p: usize,
    /// Unrolled iteration count k.
    pub k: usize,
    /// Compiled batch size B.
    pub batch: usize,
    /// ADMM penalty ρ baked into the artifact.
    pub rho: f64,
    /// Input literal shapes, in argument order.
    pub in_shapes: Vec<Vec<usize>>,
    /// Output literal shapes, in result order.
    pub out_shapes: Vec<Vec<usize>>,
    /// HLO protobuf path (resolved relative to the manifest dir).
    pub hlo_path: PathBuf,
}

/// Parsed manifest + lookup indices.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    /// Every variant, in manifest order.
    pub variants: Vec<Variant>,
    by_name: BTreeMap<String, usize>,
}

fn parse_shape(s: &str) -> Vec<usize> {
    if s.is_empty() {
        return vec![]; // scalar
    }
    s.split('x').map(|t| t.parse().unwrap_or(0)).collect()
}

impl Manifest {
    /// Load `<dir>/manifest.tsv`; HLO paths resolve relative to `dir`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            AltDiffError::Registry(format!("read {}: {e}", path.display()))
        })?;
        Self::parse(&text, dir)
    }

    /// Parse manifest text (exposed for tests).
    pub fn parse(text: &str, dir: &Path) -> Result<Manifest> {
        let mut variants = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let f: Vec<&str> = line.split('\t').collect();
            if f.len() != 9 {
                return Err(AltDiffError::Registry(format!(
                    "manifest line {} has {} fields, want 9",
                    lineno + 1,
                    f.len()
                )));
            }
            let parse_usize = |s: &str, what: &str| -> Result<usize> {
                s.parse().map_err(|_| {
                    AltDiffError::Registry(format!(
                        "bad {what} '{s}' at line {}",
                        lineno + 1
                    ))
                })
            };
            let v = Variant {
                name: f[0].to_string(),
                n: parse_usize(f[1], "n")?,
                m: parse_usize(f[2], "m")?,
                p: parse_usize(f[3], "p")?,
                k: parse_usize(f[4], "k")?,
                batch: parse_usize(f[5], "batch")?,
                rho: f[6].parse().map_err(|_| {
                    AltDiffError::Registry(format!("bad rho '{}'", f[6]))
                })?,
                in_shapes: f[7].split(';').map(parse_shape).collect(),
                out_shapes: f[8].split(';').map(parse_shape).collect(),
                hlo_path: dir.join(format!("{}.hlo.txt", f[0])),
            };
            variants.push(v);
        }
        if variants.is_empty() {
            return Err(AltDiffError::Registry(
                "manifest has no variants".into(),
            ));
        }
        let by_name = variants
            .iter()
            .enumerate()
            .map(|(i, v)| (v.name.clone(), i))
            .collect();
        Ok(Manifest { variants, by_name })
    }

    /// Look up a variant by name.
    pub fn get(&self, name: &str) -> Option<&Variant> {
        self.by_name.get(name).map(|&i| &self.variants[i])
    }

    /// All variants with a given problem size, sorted by k ascending —
    /// the truncation router's selection domain.
    pub fn family(&self, n: usize, m: usize, p: usize, batch: usize)
        -> Vec<&Variant>
    {
        let mut out: Vec<&Variant> = self
            .variants
            .iter()
            .filter(|v| {
                v.n == n && v.m == m && v.p == p && v.batch == batch
            })
            .collect();
        out.sort_by_key(|v| v.k);
        out
    }

    /// Distinct (n, m, p) sizes present.
    pub fn sizes(&self) -> Vec<(usize, usize, usize)> {
        let mut s: Vec<(usize, usize, usize)> =
            self.variants.iter().map(|v| (v.n, v.m, v.p)).collect();
        s.sort();
        s.dedup();
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# name\tn\tm\tp\tk\tbatch\trho\tin_shapes\tout_shapes
qp_n8_m4_p2_k5_b1\t8\t4\t2\t5\t1\t1.0\t8x8;2x8;4x8;8;2;4\t8;8x2;;
qp_n8_m4_p2_k20_b1\t8\t4\t2\t20\t1\t1.0\t8x8;2x8;4x8;8;2;4\t8;8x2;;
qp_n16_m8_p4_k5_b8\t16\t8\t4\t5\t8\t1.0\t16x16;4x16;8x16;8x16;8x4;8x8\t8x16;8x16x4;8;8
";

    #[test]
    fn parse_and_lookup() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.variants.len(), 3);
        let v = m.get("qp_n8_m4_p2_k5_b1").unwrap();
        assert_eq!((v.n, v.m, v.p, v.k, v.batch), (8, 4, 2, 5, 1));
        assert_eq!(v.in_shapes[0], vec![8, 8]);
        assert_eq!(v.in_shapes[3], vec![8]);
        assert_eq!(v.out_shapes[2], Vec::<usize>::new()); // scalar
        assert!(v.hlo_path.ends_with("qp_n8_m4_p2_k5_b1.hlo.txt"));
    }

    #[test]
    fn family_sorted_by_k() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp")).unwrap();
        let fam = m.family(8, 4, 2, 1);
        assert_eq!(fam.len(), 2);
        assert!(fam[0].k < fam[1].k);
        assert!(m.family(99, 1, 1, 1).is_empty());
    }

    #[test]
    fn sizes_deduped() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp")).unwrap();
        assert_eq!(m.sizes(), vec![(8, 4, 2), (16, 8, 4)]);
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("bad\tline", Path::new("/tmp")).is_err());
        assert!(Manifest::parse("", Path::new("/tmp")).is_err());
        assert!(Manifest::parse("# only comments\n", Path::new("/tmp"))
            .is_err());
    }

    #[test]
    fn real_artifacts_manifest_if_present() {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.tsv").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(!m.variants.is_empty());
            for v in &m.variants {
                assert!(v.hlo_path.exists(), "{} missing", v.name);
                assert_eq!(v.in_shapes.len(), 6);
                assert_eq!(v.out_shapes.len(), 4);
            }
        }
    }
}
