//! Native-only stand-in for the PJRT engine, compiled when the `pjrt`
//! feature is disabled (the default — the offline build has no `xla`
//! crate). The API surface is identical to [`super::engine`]'s real
//! implementation so the coordinator, CLI, and tests compile unchanged;
//! construction fails with a `Runtime` error and every caller falls back
//! to the native batched engine.

use crate::error::{AltDiffError, Result};
use crate::linalg::Mat;
use crate::runtime::manifest::Manifest;
use std::path::Path;

/// Output of one compiled QP-layer execution (shape contract shared with
/// the real engine).
#[derive(Clone, Debug)]
pub struct LayerOutput {
    /// x iterate(s): batch-major, (B, n) flattened.
    pub x: Vec<f32>,
    /// ∂x/∂b Jacobian(s): (B, n, p) flattened.
    pub jx: Vec<f32>,
    /// primal residual per batch element.
    pub prim: Vec<f32>,
    /// dual residual (ρ‖x_k − x_{k−1}‖) per batch element.
    pub dual: Vec<f32>,
}

/// Disabled engine: exists only so the `Engine` name resolves.
pub struct Engine {
    /// Manifest of compiled variants (always empty here).
    pub manifest: Manifest,
    /// executions served (always 0 here)
    pub exec_count: u64,
}

fn disabled<T>() -> Result<T> {
    Err(AltDiffError::Runtime(
        "PJRT runtime unavailable: built without the `pjrt` feature \
         (native batched backend only)"
            .into(),
    ))
}

impl Engine {
    /// Always fails: the compiled path needs `--features pjrt`.
    pub fn new(_dir: &Path) -> Result<Engine> {
        disabled()
    }

    /// Placeholder platform string.
    pub fn platform(&self) -> String {
        "pjrt-disabled".to_string()
    }

    /// Always fails (see [`Engine::new`]).
    pub fn compile(&mut self, _name: &str) -> Result<()> {
        disabled()
    }

    /// Always fails (see [`Engine::new`]).
    pub fn warmup(&mut self) -> Result<usize> {
        disabled()
    }

    /// Always fails (see [`Engine::new`]).
    #[allow(clippy::too_many_arguments)]
    pub fn execute(
        &mut self,
        _name: &str,
        _hinv: &[f32],
        _a: &[f32],
        _g: &[f32],
        _q: &[f32],
        _b: &[f32],
        _h: &[f32],
    ) -> Result<LayerOutput> {
        disabled()
    }

    /// Always fails (see [`Engine::new`]).
    #[allow(clippy::too_many_arguments)]
    pub fn execute_dense(
        &mut self,
        _name: &str,
        _hinv: &Mat,
        _a: &Mat,
        _g: &Mat,
        _q: &[f64],
        _b: &[f64],
        _h: &[f64],
    ) -> Result<LayerOutput> {
        disabled()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_runtime_unavailable() {
        let err = Engine::new(Path::new("/nonexistent")).err().unwrap();
        assert!(err.to_string().contains("pjrt"));
    }
}
