//! PJRT execution engine: compile-once, execute-many.
//!
//! Wraps the `xla` crate (PJRT C API, CPU plugin): HLO text →
//! `HloModuleProto` → `XlaComputation` → `PjRtLoadedExecutable`, memoized
//! per variant. Executables are compiled lazily on first use (startup
//! stays fast) or eagerly via [`Engine::warmup`] (serving avoids
//! first-request latency spikes).
//!
//! Threading: `PjRtClient` and executables are not `Sync`; the coordinator
//! gives each worker thread its own `Engine` (cheap: compilation is
//! per-thread but the artifact files are shared).
//!
//! Compiled only with `--features pjrt`, which requires the vendored
//! `xla` crate (see Cargo.toml); the default build uses
//! [`super::engine` = `engine_stub`] instead.

use crate::error::{AltDiffError, Result};
use crate::linalg::Mat;
use crate::runtime::manifest::{Manifest, Variant};
use std::collections::BTreeMap;
use std::path::Path;

/// Output of one compiled QP-layer execution.
#[derive(Clone, Debug)]
pub struct LayerOutput {
    /// x iterate(s): batch-major, (B, n) flattened.
    pub x: Vec<f32>,
    /// ∂x/∂b Jacobian(s): (B, n, p) flattened.
    pub jx: Vec<f32>,
    /// primal residual per batch element.
    pub prim: Vec<f32>,
    /// dual residual (ρ‖x_k − x_{k−1}‖) per batch element.
    pub dual: Vec<f32>,
}

/// Compile-once, execute-many PJRT engine over one artifact directory.
pub struct Engine {
    client: xla::PjRtClient,
    /// Manifest of available compiled variants.
    pub manifest: Manifest,
    executables: BTreeMap<String, xla::PjRtLoadedExecutable>,
    /// executions served (metrics)
    pub exec_count: u64,
}

impl Engine {
    /// Create a CPU PJRT client and load the manifest from `dir`.
    pub fn new(dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| {
            AltDiffError::Runtime(format!("PjRtClient::cpu: {e:?}"))
        })?;
        Ok(Engine {
            client,
            manifest,
            executables: BTreeMap::new(),
            exec_count: 0,
        })
    }

    /// PJRT platform name (e.g. "cpu").
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (and memoize) the executable for `name`.
    pub fn compile(&mut self, name: &str) -> Result<()> {
        if self.executables.contains_key(name) {
            return Ok(());
        }
        let v = self.manifest.get(name).ok_or_else(|| {
            AltDiffError::Registry(format!("unknown variant '{name}'"))
        })?;
        let path = v.hlo_path.clone();
        let proto = xla::HloModuleProto::from_text_file(&path).map_err(
            |e| {
                AltDiffError::Runtime(format!(
                    "parse {}: {e:?}",
                    path.display()
                ))
            },
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(|e| {
            AltDiffError::Runtime(format!("compile {name}: {e:?}"))
        })?;
        self.executables.insert(name.to_string(), exe);
        Ok(())
    }

    /// Eagerly compile every variant (serving startup).
    pub fn warmup(&mut self) -> Result<usize> {
        let names: Vec<String> = self
            .manifest
            .variants
            .iter()
            .map(|v| v.name.clone())
            .collect();
        for n in &names {
            self.compile(n)?;
        }
        Ok(names.len())
    }

    /// Execute one variant.
    ///
    /// `hinv` is the registration-time H⁻¹ (n,n); `a` (p,n), `g` (m,n);
    /// `q`, `b`, `h` are batch-major flattened per the variant's batch.
    #[allow(clippy::too_many_arguments)]
    pub fn execute(
        &mut self,
        name: &str,
        hinv: &[f32],
        a: &[f32],
        g: &[f32],
        q: &[f32],
        b: &[f32],
        h: &[f32],
    ) -> Result<LayerOutput> {
        self.compile(name)?;
        let v = self.manifest.get(name).unwrap().clone();
        self.check_arity(&v, hinv, a, g, q, b, h)?;
        let lit = |data: &[f32], dims: &[usize]| -> Result<xla::Literal> {
            let l = xla::Literal::vec1(data);
            let dims_i64: Vec<i64> =
                dims.iter().map(|&d| d as i64).collect();
            l.reshape(&dims_i64).map_err(|e| {
                AltDiffError::Runtime(format!("reshape {dims:?}: {e:?}"))
            })
        };
        let args = [
            lit(hinv, &v.in_shapes[0])?,
            lit(a, &v.in_shapes[1])?,
            lit(g, &v.in_shapes[2])?,
            lit(q, &v.in_shapes[3])?,
            lit(b, &v.in_shapes[4])?,
            lit(h, &v.in_shapes[5])?,
        ];
        let exe = self.executables.get(name).unwrap();
        let result = exe.execute::<xla::Literal>(&args).map_err(|e| {
            AltDiffError::Runtime(format!("execute {name}: {e:?}"))
        })?;
        self.exec_count += 1;
        let lit_out = result[0][0].to_literal_sync().map_err(|e| {
            AltDiffError::Runtime(format!("to_literal: {e:?}"))
        })?;
        let parts = lit_out.to_tuple().map_err(|e| {
            AltDiffError::Runtime(format!("to_tuple: {e:?}"))
        })?;
        if parts.len() != 4 {
            return Err(AltDiffError::Runtime(format!(
                "variant {name}: expected 4 outputs, got {}",
                parts.len()
            )));
        }
        let take = |l: &xla::Literal| -> Result<Vec<f32>> {
            l.to_vec::<f32>().map_err(|e| {
                AltDiffError::Runtime(format!("to_vec: {e:?}"))
            })
        };
        Ok(LayerOutput {
            x: take(&parts[0])?,
            jx: take(&parts[1])?,
            prim: take(&parts[2])?,
            dual: take(&parts[3])?,
        })
    }

    fn check_arity(
        &self,
        v: &Variant,
        hinv: &[f32],
        a: &[f32],
        g: &[f32],
        q: &[f32],
        b: &[f32],
        h: &[f32],
    ) -> Result<()> {
        let want = |dims: &[usize]| dims.iter().product::<usize>();
        let checks = [
            ("hinv", hinv.len(), want(&v.in_shapes[0])),
            ("a", a.len(), want(&v.in_shapes[1])),
            ("g", g.len(), want(&v.in_shapes[2])),
            ("q", q.len(), want(&v.in_shapes[3])),
            ("b", b.len(), want(&v.in_shapes[4])),
            ("h", h.len(), want(&v.in_shapes[5])),
        ];
        for (what, got, want) in checks {
            if got != want {
                return Err(AltDiffError::DimMismatch(format!(
                    "{}: input '{what}' has {got} elements, want {want}",
                    v.name
                )));
            }
        }
        Ok(())
    }

    /// Convenience: run a *registered dense layer* through the compiled
    /// path (converts f64 problem data to the f32 artifact contract).
    pub fn execute_dense(
        &mut self,
        name: &str,
        hinv: &Mat,
        a: &Mat,
        g: &Mat,
        q: &[f64],
        b: &[f64],
        h: &[f64],
    ) -> Result<LayerOutput> {
        let f = |v: &[f64]| -> Vec<f32> {
            v.iter().map(|&x| x as f32).collect()
        };
        self.execute(
            name,
            &hinv.to_f32(),
            &a.to_f32(),
            &g.to_f32(),
            &f(q),
            &f(b),
            &f(h),
        )
    }
}
