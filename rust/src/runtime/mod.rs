//! PJRT runtime: loads the AOT artifacts produced by `python/compile/aot.py`
//! and executes them from the serving hot path.
//!
//! - [`manifest`]: parses `artifacts/manifest.tsv` into variant metadata.
//! - [`engine`]: PJRT CPU client + lazily compiled executables, keyed by
//!   variant name; typed f32 I/O matched to the artifact contract. Built
//!   only with the `pjrt` feature (needs the vendored `xla` crate); the
//!   default offline build substitutes a same-API stub whose constructor
//!   fails, so serving falls back to the native batched engine.

#[cfg(feature = "pjrt")]
pub mod engine;
#[cfg(not(feature = "pjrt"))]
#[path = "engine_stub.rs"]
pub mod engine;
pub mod manifest;

pub use engine::{Engine, LayerOutput};
pub use manifest::{Manifest, Variant};
