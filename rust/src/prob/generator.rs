//! Workload generators: random feasible QPs matching the paper's setups.
//!
//! Table 2 (dense): P ⪰ 0 random dense, A/G random dense, sizes with
//! n : m : p = 10 : 5 : 2. Feasibility by construction: pick x0, set
//! b = A x0 and h = G x0 + |u| + margin, so x0 is strictly feasible.

use super::qp::{Qp, SparseQp};
use crate::linalg::{ata, gemv, Mat};
use crate::sparse::Csr;
use crate::util::rng::Pcg64;

/// Dense QP in the paper's Table 2 style.
pub fn dense_qp(n: usize, m: usize, p: usize, seed: u64) -> Qp {
    let mut rng = Pcg64::new(seed);
    // P = 0.1 I + M Mᵀ / n : SPD, spectrum O(1)
    let mraw = Mat::from_vec(n, n, rng.normal_vec(n * n));
    let mut pm = ata(&mraw);
    pm.scale(1.0 / n as f64);
    for i in 0..n {
        pm[(i, i)] += 0.1;
    }
    let q = rng.normal_vec(n);
    let scale = 1.0 / (n as f64).sqrt();
    let mut a = Mat::from_vec(p, n, rng.normal_vec(p * n));
    a.scale(scale);
    let mut g = Mat::from_vec(m, n, rng.normal_vec(m * n));
    g.scale(scale);
    let x0 = rng.normal_vec(n);
    let b = gemv(&a, &x0);
    let h: Vec<f64> = gemv(&g, &x0)
        .into_iter()
        .map(|gx| gx + rng.uniform().abs() + 0.1)
        .collect();
    Qp { p: pm, q, a, b, g, h }
}

/// [`dense_qp`] with the objective blown up by `scale`: P and q are
/// both multiplied by it, so the minimizer x* is *unchanged* while the
/// duals scale by `scale` — the stationarity residual of any fixed
/// iterate scales with it too. At `scale ≫ 1` a fixed unit penalty ρ
/// crawls (the splitting step P + ρCᵀC is dominated by P), which is
/// exactly the regime residual-balancing ρ adaptation — and hence the
/// coordinator's cross-method router — is built for.
pub fn ill_conditioned_qp(
    n: usize,
    m: usize,
    p: usize,
    scale: f64,
    seed: u64,
) -> Qp {
    let mut qp = dense_qp(n, m, p, seed);
    qp.p.scale(scale);
    for v in qp.q.iter_mut() {
        *v *= scale;
    }
    qp
}

/// Well-conditioned SPD objective shared by the Frank–Wolfe workload
/// generators: P = I + M Mᵀ/n (spectrum O(1), κ small enough that the
/// away-step engine converges fast), q ~ N(0, 1).
fn fw_objective(n: usize, rng: &mut Pcg64) -> (Mat, Vec<f64>) {
    let mraw = Mat::from_vec(n, n, rng.normal_vec(n * n));
    let mut pm = ata(&mraw);
    pm.scale(1.0 / n as f64);
    for i in 0..n {
        pm[(i, i)] += 1.0;
    }
    let q = rng.normal_vec(n);
    (pm, q)
}

/// Box-constrained QP — the projection-free (Frank–Wolfe) engine's home
/// turf:
///     min ½xᵀPx + qᵀx   s.t.   l ≤ x ≤ u
/// encoded with no equalities (p = 0) and the canonical stacking
/// G = [I; −I], h = [u; −l] that [`crate::fw::FeasibleSet::detect`]
/// recognizes. Bounds straddle 0 with per-coordinate widths in
/// (1, 3), so generic instances have a mix of active and free
/// coordinates at the optimum.
pub fn box_qp(n: usize, seed: u64) -> Qp {
    let mut rng = Pcg64::new(seed);
    let (pm, q) = fw_objective(n, &mut rng);
    let mut g = Mat::zeros(2 * n, n);
    let mut h = vec![0.0; 2 * n];
    for i in 0..n {
        g[(i, i)] = 1.0;
        g[(n + i, i)] = -1.0;
        let u = 0.5 + rng.uniform();
        let l = -(0.5 + rng.uniform());
        h[i] = u;
        h[n + i] = -l;
    }
    Qp { p: pm, q, a: Mat::zeros(0, n), b: vec![], g, h }
}

/// Scaled-simplex QP:
///     min ½xᵀPx + qᵀx   s.t.   1ᵀx = r,  x ≥ 0
/// encoded as A = 1ᵀ (p = 1), b = [r], G = −I, h = 0 — the simplex
/// shape [`crate::fw::FeasibleSet::detect`] recognizes. Strictly
/// feasible at x = (r/n)·1.
pub fn simplex_qp(n: usize, r: f64, seed: u64) -> Qp {
    assert!(r > 0.0, "simplex radius must be positive");
    let mut rng = Pcg64::new(seed);
    let (pm, q) = fw_objective(n, &mut rng);
    let a = Mat::from_vec(1, n, vec![1.0; n]);
    let mut g = Mat::zeros(n, n);
    for i in 0..n {
        g[(i, i)] = -1.0;
    }
    Qp { p: pm, q, a, b: vec![r], g, h: vec![0.0; n] }
}

/// ℓ1-ball QP:
///     min ½xᵀPx + qᵀx   s.t.   ‖x‖₁ ≤ r
/// encoded explicitly as the 2ⁿ facet inequalities σᵀx ≤ r over every
/// sign pattern σ ∈ {±1}ⁿ (p = 0) — exactly the polytope description
/// the dense Alt-Diff/ADMM oracles consume, and the shape
/// [`crate::fw::FeasibleSet::detect`] maps back to a vertex oracle
/// over ±r·eⱼ. Exponential in n by construction, so n is capped; the
/// linear term is scaled up so generic instances are *constrained*
/// (the unconstrained minimizer falls outside the ball).
pub fn l1_ball_qp(n: usize, r: f64, seed: u64) -> Qp {
    assert!(r > 0.0, "l1 radius must be positive");
    assert!(n <= 12, "l1_ball_qp materializes 2^n facets; keep n <= 12");
    let mut rng = Pcg64::new(seed);
    let (pm, mut q) = fw_objective(n, &mut rng);
    for v in q.iter_mut() {
        *v *= 2.0 * r.max(1.0);
    }
    let m = 1usize << n;
    let mut g = Mat::zeros(m, n);
    for row in 0..m {
        for j in 0..n {
            g[(row, j)] =
                if (row >> j) & 1 == 1 { -1.0 } else { 1.0 };
        }
    }
    Qp { p: pm, q, a: Mat::zeros(0, n), b: vec![], g, h: vec![r; m] }
}

/// Constrained-sparsemax layer (paper Table 3/4):
///     min ‖x − y‖²  s.t.  1ᵀx = 1,  0 ≤ x ≤ u
/// i.e. P = 2I, q = −2y, A = 1ᵀ (p=1), G = [−I; I], h = [0; u].
pub fn sparsemax_qp(n: usize, seed: u64) -> SparseQp {
    let mut rng = Pcg64::new(seed);
    let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let q: Vec<f64> = y.iter().map(|v| -2.0 * v).collect();
    let ones: Vec<(usize, usize, f64)> =
        (0..n).map(|j| (0, j, 1.0)).collect();
    let a = Csr::from_triplets(1, n, &ones);
    // G = [-I; I]
    let mut gt = Vec::with_capacity(2 * n);
    for i in 0..n {
        gt.push((i, i, -1.0));
        gt.push((n + i, i, 1.0));
    }
    let g = Csr::from_triplets(2 * n, n, &gt);
    // upper bounds u in (0.5, 1.5): simplex cap, strictly feasible at 1/n.
    let mut h = vec![0.0; 2 * n];
    for i in 0..n {
        h[n + i] = 0.5 + rng.uniform();
    }
    SparseQp { pdiag: vec![2.0; n], q, a, b: vec![1.0], g, h }
}

/// Random sparse QP with controllable density (general sparse workloads).
pub fn sparse_qp(
    n: usize,
    m: usize,
    p: usize,
    density: f64,
    seed: u64,
) -> SparseQp {
    let mut rng = Pcg64::new(seed);
    let pdiag: Vec<f64> = (0..n).map(|_| 0.5 + rng.uniform()).collect();
    let q = rng.normal_vec(n);
    let gen_mat = |rows: usize, rng: &mut Pcg64| {
        let mut t = Vec::new();
        for i in 0..rows {
            // ensure at least one entry per row: full row rank-ish
            let j0 = rng.below(n);
            t.push((i, j0, rng.normal()));
            for j in 0..n {
                if j != j0 && rng.uniform() < density {
                    t.push((i, j, rng.normal()));
                }
            }
        }
        Csr::from_triplets(rows, n, &t)
    };
    let a = gen_mat(p, &mut rng);
    let g = gen_mat(m, &mut rng);
    let x0 = rng.normal_vec(n);
    let b = a.spmv(&x0);
    let h: Vec<f64> = g
        .spmv(&x0)
        .into_iter()
        .map(|gx| gx + rng.uniform().abs() + 0.1)
        .collect();
    SparseQp { pdiag, q, a, b, g, h }
}

/// Constrained-softmax layer data (paper Table 5):
///     min −yᵀx + Σ x log x   s.t. 1ᵀx = 1,  0 ≤ x ≤ u
/// Returns (y, u). The solver couples it with `EntropyObjective`.
pub fn softmax_layer(n: usize, seed: u64) -> (Vec<f64>, Vec<f64>) {
    let mut rng = Pcg64::new(seed);
    let y = rng.normal_vec(n);
    let u: Vec<f64> = (0..n).map(|_| 0.3 + rng.uniform()).collect();
    (y, u)
}

/// Energy-generation-scheduling QP (paper §5.2, eq. 14):
///     min Σ_k ‖x_k − P_dk‖²  s.t. |x_{k+1} − x_k| ≤ r
/// Horizon T=24, ramp limit r. As a QP: P = 2I, q = −2 P_d,
/// G = [D; −D] with D the (T−1, T) difference matrix, h = r·1. No
/// equalities (paper has none) — we add a vacuous one (0ᵀx = 0) so the
/// uniform (A,b) interface holds; it does not alter the solution.
pub fn energy_qp(demand: &[f64], ramp: f64) -> SparseQp {
    let t = demand.len();
    assert!(t >= 2);
    let q: Vec<f64> = demand.iter().map(|d| -2.0 * d).collect();
    let mut gt = Vec::with_capacity(4 * (t - 1));
    for k in 0..(t - 1) {
        // (x_{k+1} - x_k) <= r
        gt.push((k, k + 1, 1.0));
        gt.push((k, k, -1.0));
        // -(x_{k+1} - x_k) <= r
        gt.push((t - 1 + k, k + 1, -1.0));
        gt.push((t - 1 + k, k, 1.0));
    }
    let g = Csr::from_triplets(2 * (t - 1), t, &gt);
    let h = vec![ramp; 2 * (t - 1)];
    let a = Csr::from_triplets(1, t, &[(0, 0, 0.0)]);
    SparseQp { pdiag: vec![2.0; t], q, a, b: vec![0.0], g, h }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_qp_is_strictly_feasible_and_spd() {
        let qp = dense_qp(30, 15, 6, 7);
        assert_eq!(qp.n(), 30);
        assert_eq!(qp.m_ineq(), 15);
        assert_eq!(qp.p_eq(), 6);
        // SPD check via Cholesky
        assert!(crate::linalg::Chol::factor(&qp.p).is_ok());
        // the generator's x0 satisfied Ax=b; verify a feasible point exists
        // by solving the least-squares x = A⁺b and checking Gx < h is
        // not required — directly test with the generator's construction:
        // regenerate with same seed and confirm h - G x0 > 0 by margin.
        // (structural: h was built as G x0 + pos)
        let (eq, _) = qp.feasibility(&crate::linalg::gemv(
            &qp.a.transpose(),
            &crate::linalg::Lu::factor(&crate::linalg::gemm(
                &qp.a,
                &qp.a.transpose(),
            ))
            .unwrap()
            .solve(&qp.b),
        ));
        assert!(eq < 1e-8, "min-norm equality solution exists, eq={eq}");
    }

    #[test]
    fn ill_conditioned_scales_objective_only() {
        let base = dense_qp(12, 6, 3, 5);
        let ill = ill_conditioned_qp(12, 6, 3, 1e4, 5);
        // constraints untouched → same feasible set, same minimizer
        assert_eq!(base.b, ill.b);
        assert_eq!(base.h, ill.h);
        assert_eq!(base.a.data, ill.a.data);
        assert_eq!(base.g.data, ill.g.data);
        for i in 0..12 {
            assert!((ill.q[i] - 1e4 * base.q[i]).abs() < 1e-9);
            for j in 0..12 {
                assert!(
                    (ill.p[(i, j)] - 1e4 * base.p[(i, j)]).abs()
                        < 1e-6 * base.p[(i, j)].abs().max(1.0)
                );
            }
        }
        assert!(crate::linalg::Chol::factor(&ill.p).is_ok());
    }

    #[test]
    fn dense_qp_deterministic_per_seed() {
        let a = dense_qp(10, 5, 2, 3);
        let b = dense_qp(10, 5, 2, 3);
        assert_eq!(a.q, b.q);
        assert_eq!(a.p.data, b.p.data);
        let c = dense_qp(10, 5, 2, 4);
        assert_ne!(a.q, c.q);
    }

    #[test]
    fn box_qp_stacking_and_feasibility() {
        let qp = box_qp(6, 11);
        assert_eq!(qp.p_eq(), 0);
        assert_eq!(qp.m_ineq(), 12);
        assert!(crate::linalg::Chol::factor(&qp.p).is_ok());
        // bounds straddle 0: x = 0 strictly feasible
        for i in 0..12 {
            assert!(qp.h[i] > 0.0);
        }
    }

    #[test]
    fn simplex_qp_center_is_strictly_feasible() {
        let qp = simplex_qp(9, 2.0, 4);
        assert_eq!(qp.p_eq(), 1);
        assert_eq!(qp.m_ineq(), 9);
        let c = vec![2.0 / 9.0; 9];
        let ax = crate::linalg::gemv(&qp.a, &c);
        assert!((ax[0] - 2.0).abs() < 1e-12);
        for i in 0..9 {
            assert!(qp.h[i] == 0.0 && c[i] > 0.0);
        }
    }

    #[test]
    fn l1_ball_qp_enumerates_all_facets() {
        let qp = l1_ball_qp(5, 1.5, 2);
        assert_eq!(qp.p_eq(), 0);
        assert_eq!(qp.m_ineq(), 32);
        let mut seen = std::collections::BTreeSet::new();
        for row in 0..32 {
            let mut mask = 0usize;
            for j in 0..5 {
                let v = qp.g[(row, j)];
                assert!(v == 1.0 || v == -1.0);
                if v < 0.0 {
                    mask |= 1 << j;
                }
            }
            seen.insert(mask);
            assert_eq!(qp.h[row], 1.5);
        }
        assert_eq!(seen.len(), 32, "every sign pattern appears once");
    }

    #[test]
    fn sparsemax_structure() {
        let sq = sparsemax_qp(8, 1);
        assert_eq!(sq.n(), 8);
        assert_eq!(sq.a.rows, 1);
        assert_eq!(sq.a.nnz(), 8);
        assert_eq!(sq.g.rows, 16);
        assert_eq!(sq.g.nnz(), 16);
        // uniform x = 1/n is strictly feasible
        let x = vec![1.0 / 8.0; 8];
        let ax = sq.a.spmv(&x);
        assert!((ax[0] - 1.0).abs() < 1e-12);
        let gx = sq.g.spmv(&x);
        for i in 0..16 {
            assert!(gx[i] < sq.h[i]);
        }
    }

    #[test]
    fn sparse_qp_density_scales_nnz() {
        let lo = sparse_qp(100, 50, 20, 0.01, 5);
        let hi = sparse_qp(100, 50, 20, 0.2, 5);
        assert!(hi.g.nnz() > 2 * lo.g.nnz());
        assert!(lo.a.nnz() >= 20); // at least one entry per row
    }

    #[test]
    fn energy_qp_ramp_encoding() {
        let demand = vec![10.0, 12.0, 9.0, 11.0];
        let qp = energy_qp(&demand, 1.5);
        assert_eq!(qp.n(), 4);
        assert_eq!(qp.g.rows, 6);
        // x = demand violates ramps where |Δd| > 1.5
        let gx = qp.g.spmv(&demand);
        let viol = gx
            .iter()
            .zip(&qp.h)
            .filter(|(g, h)| *g > *h)
            .count();
        assert_eq!(viol, 3); // Δ = +2, -3, +2 all exceed 1.5
        // constant schedule is feasible
        let flat = vec![10.0; 4];
        let gx2 = qp.g.spmv(&flat);
        for (g, h) in gx2.iter().zip(&qp.h) {
            assert!(g <= h);
        }
    }

    #[test]
    fn softmax_layer_bounds_positive() {
        let (y, u) = softmax_layer(12, 9);
        assert_eq!(y.len(), 12);
        assert!(u.iter().all(|&v| v > 0.29));
    }
}
