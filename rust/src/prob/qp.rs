//! Parameterized convex problems with polyhedral constraints (paper eq. 1).
//!
//! The canonical object is the QP layer
//!     min_x 0.5 xᵀPx + qᵀx   s.t.  Ax = b,  Gx ≤ h
//! plus the general-objective variant (entropy etc.) via [`Objective`].

use crate::linalg::{gemv, norm2, sub_vec, Mat};
use crate::sparse::Csr;

/// Dense QP instance.
#[derive(Clone, Debug)]
pub struct Qp {
    /// Quadratic term P, (n,n) SPD (or PSD + regularized).
    pub p: Mat,
    /// Linear term q, (n).
    pub q: Vec<f64>,
    /// Equality constraint matrix A, (p,n).
    pub a: Mat,
    /// Equality right-hand side b, (p).
    pub b: Vec<f64>,
    /// Inequality constraint matrix G, (m,n).
    pub g: Mat,
    /// Inequality right-hand side h, (m).
    pub h: Vec<f64>,
}

impl Qp {
    /// Number of variables n.
    pub fn n(&self) -> usize {
        self.q.len()
    }
    /// Number of equality constraints p.
    pub fn p_eq(&self) -> usize {
        self.b.len()
    }
    /// Number of inequality constraints m.
    pub fn m_ineq(&self) -> usize {
        self.h.len()
    }

    /// Objective value at x.
    pub fn objective(&self, x: &[f64]) -> f64 {
        let px = gemv(&self.p, x);
        0.5 * crate::linalg::dot(x, &px) + crate::linalg::dot(&self.q, x)
    }

    /// (‖Ax−b‖, max(Gx−h)_+) — primal feasibility metrics.
    pub fn feasibility(&self, x: &[f64]) -> (f64, f64) {
        self.feasibility_with(x, &self.b, &self.h)
    }

    /// [`Self::feasibility`] against caller-supplied right-hand sides —
    /// the per-request variant the server uses (requests may override
    /// the registered b/h, and the residual must be judged against the
    /// θ the solve actually ran with).
    pub fn feasibility_with(
        &self,
        x: &[f64],
        b: &[f64],
        h: &[f64],
    ) -> (f64, f64) {
        let eq = norm2(&sub_vec(&gemv(&self.a, x), b));
        let viol = gemv(&self.g, x)
            .iter()
            .zip(h)
            .map(|(gx, hi)| (gx - hi).max(0.0))
            .fold(0.0, f64::max);
        (eq, viol)
    }

    /// KKT residual norm at (x, λ, ν): stationarity, primal, complementarity.
    pub fn kkt_residual(&self, x: &[f64], lam: &[f64], nu: &[f64]) -> f64 {
        let mut st = gemv(&self.p, x);
        crate::linalg::axpy(&mut st, 1.0, &self.q);
        let at_lam = crate::linalg::gemv_t(&self.a, lam);
        let gt_nu = crate::linalg::gemv_t(&self.g, nu);
        crate::linalg::axpy(&mut st, 1.0, &at_lam);
        crate::linalg::axpy(&mut st, 1.0, &gt_nu);
        let (eq, viol) = self.feasibility(x);
        let comp: f64 = gemv(&self.g, x)
            .iter()
            .zip(&self.h)
            .zip(nu)
            .map(|((gx, h), nui)| (nui * (gx - h)).abs())
            .sum();
        norm2(&st) + eq + viol + comp
    }
}

/// Sparse QP instance (diagonal P — the regime of Table 4).
#[derive(Clone, Debug)]
pub struct SparseQp {
    /// Diagonal of the quadratic term P, (n).
    pub pdiag: Vec<f64>,
    /// Linear term q, (n).
    pub q: Vec<f64>,
    /// Equality constraint matrix A, (p,n) CSR.
    pub a: Csr,
    /// Equality right-hand side b, (p).
    pub b: Vec<f64>,
    /// Inequality constraint matrix G, (m,n) CSR.
    pub g: Csr,
    /// Inequality right-hand side h, (m).
    pub h: Vec<f64>,
}

impl SparseQp {
    /// Number of variables n.
    pub fn n(&self) -> usize {
        self.q.len()
    }

    /// Number of equality constraints p.
    pub fn p_eq(&self) -> usize {
        self.b.len()
    }

    /// Number of inequality constraints m.
    pub fn m_ineq(&self) -> usize {
        self.h.len()
    }

    /// (‖Ax−b‖, max(Gx−h)_+) — primal feasibility metrics, the sparse
    /// sibling of [`Qp::feasibility`].
    pub fn feasibility(&self, x: &[f64]) -> (f64, f64) {
        self.feasibility_with(x, &self.b, &self.h)
    }

    /// [`Self::feasibility`] against caller-supplied right-hand sides
    /// (the per-request variant, like [`Qp::feasibility_with`]).
    pub fn feasibility_with(
        &self,
        x: &[f64],
        b: &[f64],
        h: &[f64],
    ) -> (f64, f64) {
        let eq = norm2(&sub_vec(&self.a.spmv(x), b));
        let viol = self
            .g
            .spmv(x)
            .iter()
            .zip(h)
            .map(|(gx, hi)| (gx - hi).max(0.0))
            .fold(0.0, f64::max);
        (eq, viol)
    }

    /// Densify (diagnostics and small-n cross-checks).
    pub fn to_dense(&self) -> Qp {
        Qp {
            p: Mat::diag(&self.pdiag),
            q: self.q.clone(),
            a: self.a.to_dense(),
            b: self.b.clone(),
            g: self.g.to_dense(),
            h: self.h.clone(),
        }
    }
}

/// General convex objective for the non-QP layers (paper Table 5).
pub trait Objective: Send + Sync {
    /// f(x)
    fn value(&self, x: &[f64]) -> f64;
    /// ∇f(x)
    fn grad(&self, x: &[f64]) -> Vec<f64>;
    /// ∇²f(x) — dense; diagonal objectives may override `hess_diag`.
    fn hess(&self, x: &[f64]) -> Mat;
    /// Diagonal of the Hessian if the Hessian is diagonal (fast path).
    fn hess_diag(&self, _x: &[f64]) -> Option<Vec<f64>> {
        None
    }
    /// A strictly feasible starting point for the domain (e.g. entropy
    /// needs x > 0).
    fn domain_start(&self, n: usize) -> Vec<f64> {
        vec![0.0; n]
    }
}

/// Quadratic objective wrapper (makes the QP a special case).
pub struct QuadObjective {
    /// Quadratic term P.
    pub p: Mat,
    /// Linear term q.
    pub q: Vec<f64>,
}

impl Objective for QuadObjective {
    fn value(&self, x: &[f64]) -> f64 {
        let px = gemv(&self.p, x);
        0.5 * crate::linalg::dot(x, &px) + crate::linalg::dot(&self.q, x)
    }
    fn grad(&self, x: &[f64]) -> Vec<f64> {
        let mut g = gemv(&self.p, x);
        crate::linalg::axpy(&mut g, 1.0, &self.q);
        g
    }
    fn hess(&self, _x: &[f64]) -> Mat {
        self.p.clone()
    }
}

/// Negative-entropy objective  f(x) = -yᵀx + Σ x_i log x_i  (paper §F.1,
/// constrained Softmax layer). Domain x > 0.
pub struct EntropyObjective {
    /// The layer input y (logits).
    pub y: Vec<f64>,
}

impl Objective for EntropyObjective {
    fn value(&self, x: &[f64]) -> f64 {
        x.iter()
            .zip(&self.y)
            .map(|(&xi, &yi)| {
                let xl = xi.max(1e-12);
                -yi * xi + xl * xl.ln()
            })
            .sum()
    }
    fn grad(&self, x: &[f64]) -> Vec<f64> {
        x.iter()
            .zip(&self.y)
            .map(|(&xi, &yi)| -yi + xi.max(1e-12).ln() + 1.0)
            .collect()
    }
    fn hess(&self, x: &[f64]) -> Mat {
        Mat::diag(&self.hess_diag(x).unwrap())
    }
    fn hess_diag(&self, x: &[f64]) -> Option<Vec<f64>> {
        Some(x.iter().map(|&xi| 1.0 / xi.max(1e-12)).collect())
    }
    fn domain_start(&self, n: usize) -> Vec<f64> {
        vec![1.0 / n as f64; n]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_qp() -> Qp {
        // min x1^2 + x2^2  s.t. x1 + x2 = 1, x <= 2  → x* = (0.5, 0.5)
        Qp {
            p: Mat::diag(&[2.0, 2.0]),
            q: vec![0.0, 0.0],
            a: Mat::from_rows(&[&[1.0, 1.0]]),
            b: vec![1.0],
            g: Mat::eye(2),
            h: vec![2.0, 2.0],
        }
    }

    #[test]
    fn objective_and_feasibility() {
        let qp = tiny_qp();
        let x = [0.5, 0.5];
        assert!((qp.objective(&x) - 0.5).abs() < 1e-12);
        let (eq, viol) = qp.feasibility(&x);
        assert!(eq < 1e-12 && viol == 0.0);
        let (eq2, viol2) = qp.feasibility(&[3.0, 3.0]);
        assert!(eq2 > 0.0 && viol2 == 1.0);
    }

    #[test]
    fn kkt_residual_zero_at_optimum() {
        let qp = tiny_qp();
        // x* = (.5,.5): 2x + λ·1 = 0 → λ = -1; inactive ineq → ν = 0.
        let r = qp.kkt_residual(&[0.5, 0.5], &[-1.0], &[0.0, 0.0]);
        assert!(r < 1e-12, "r={r}");
        let r_bad = qp.kkt_residual(&[0.9, 0.1], &[-1.0], &[0.0, 0.0]);
        assert!(r_bad > 0.1);
    }

    #[test]
    fn entropy_gradient_matches_fd() {
        let obj = EntropyObjective { y: vec![0.3, -0.2, 0.5] };
        let x = [0.2, 0.5, 0.3];
        let g = obj.grad(&x);
        let eps = 1e-6;
        for i in 0..3 {
            let mut xp = x;
            xp[i] += eps;
            let mut xm = x;
            xm[i] -= eps;
            let fd = (obj.value(&xp) - obj.value(&xm)) / (2.0 * eps);
            assert!((g[i] - fd).abs() < 1e-5, "i={i} g={} fd={fd}", g[i]);
        }
    }

    #[test]
    fn entropy_hess_diag_consistent() {
        let obj = EntropyObjective { y: vec![0.0, 0.0] };
        let x = [0.25, 0.5];
        let d = obj.hess_diag(&x).unwrap();
        assert!((d[0] - 4.0).abs() < 1e-9);
        assert!((d[1] - 2.0).abs() < 1e-9);
        let h = obj.hess(&x);
        assert!((h[(0, 0)] - 4.0).abs() < 1e-9);
        assert_eq!(h[(0, 1)], 0.0);
    }

    #[test]
    fn sparse_to_dense_roundtrip() {
        let sq = SparseQp {
            pdiag: vec![2.0, 2.0],
            q: vec![-1.0, 0.5],
            a: Csr::from_triplets(1, 2, &[(0, 0, 1.0), (0, 1, 1.0)]),
            b: vec![1.0],
            g: Csr::eye(2),
            h: vec![1.0, 1.0],
        };
        let d = sq.to_dense();
        assert_eq!(d.p[(0, 0)], 2.0);
        assert_eq!(d.a[(0, 1)], 1.0);
        assert_eq!(d.n(), 2);
    }
}
