//! Problem models + workload generators for every experiment.
pub mod generator;
pub mod qp;

pub use generator::{box_qp, dense_qp, energy_qp, ill_conditioned_qp,
                    l1_ball_qp, simplex_qp, softmax_layer, sparse_qp,
                    sparsemax_qp};
pub use qp::{EntropyObjective, Objective, Qp, QuadObjective, SparseQp};
