//! Batched Frank–Wolfe: B problems of one registered structure per
//! launch — the family sibling of
//! [`BatchedAltDiff`](crate::batch::BatchedAltDiff) and
//! [`BatchedAdmm`](crate::admm::BatchedAdmm), same contracts.
//!
//! Honesty note on the execution model: FW has no shared factorization
//! to amortize across a batch — the per-element state is an LMO vertex
//! walk, not a panel against a cached K⁻¹ — so one launch advances all
//! live elements in interleaved round-robin sweeps of the *identical*
//! [`FwQp`] step (shared code, bit-identical per-element results). What
//! the batch shape still buys is the serving contract: one call per
//! coalesced batch, ragged truncation through the shared
//! [`ActiveSet`] (converged elements deactivate and stop consuming
//! budget mid-sweep), per-element warm/cold mixing, and true `(elem,
//! iter)` indices into the observability plane.

use super::qp::{FwQp, FwState, Geom};
use crate::altdiff::Options;
use crate::batch::{
    ActiveSet, BatchSolution, BatchVjp, BatchVjpSolution,
};
use crate::error::Result;
use crate::obs::IterObserver;
use crate::prob::Qp;
use crate::warm::{FwSeed, WarmStart};

/// A registered Frank–Wolfe QP structure ready to solve B right-hand
/// sides per launch.
///
/// ```
/// use altdiff::altdiff::Options;
/// use altdiff::fw::BatchedFw;
/// use altdiff::prob::simplex_qp;
///
/// let engine = BatchedFw::new(simplex_qp(6, 1.0, 7), 1.0).unwrap();
/// let q2: Vec<f64> = engine.qp.q.iter().map(|v| 0.5 * v).collect();
/// let qs: Vec<&[f64]> = vec![&engine.qp.q, &q2];
/// let sol = engine.solve_batch(Some(&qs), None, None, &Options::default());
/// assert_eq!(sol.len(), 2);
/// assert!(sol.xs.iter().flatten().all(|v| v.is_finite()));
/// ```
pub struct BatchedFw {
    /// The registered problem (broadcast defaults for absent θ).
    pub qp: Qp,
    /// Interface parity with the factorizing families (never read).
    pub rho: f64,
    solver: FwQp,
}

impl BatchedFw {
    /// Register from scratch (structural detection only, like
    /// [`FwQp::new`]; there is no factorization to build).
    pub fn new(qp: Qp, rho: f64) -> Result<BatchedFw> {
        Ok(BatchedFw::from_single(&FwQp::new(qp, rho)?))
    }

    /// Share an already-registered layer — the cheap path for the
    /// server, which keeps both shapes per layer.
    pub fn from_single(solver: &FwQp) -> BatchedFw {
        BatchedFw {
            qp: solver.qp.clone(),
            rho: solver.rho,
            solver: solver.clone(),
        }
    }

    /// Solve B problems sharing the registered structure; `None` slots
    /// broadcast the registered θ. Same broadcast/arity contract as
    /// [`BatchedAltDiff::solve_batch`](crate::batch::BatchedAltDiff::solve_batch).
    pub fn solve_batch(
        &self,
        qs: Option<&[&[f64]]>,
        bs: Option<&[&[f64]]>,
        hs: Option<&[&[f64]]>,
        opts: &Options,
    ) -> BatchSolution {
        self.solve_batch_from(qs, bs, hs, None, opts)
    }

    /// [`Self::solve_batch`] with per-element warm starts: a batch may
    /// freely mix warm and cold members; warm state is expanded exactly
    /// as in [`FwQp::solve_from`], and `warms = None` (or all-`None`)
    /// is bit-identical to the cold [`Self::solve_batch`]. Warm
    /// elements with forward-mode Jacobians require `tol = 0`
    /// (asserted — see DESIGN.md §5).
    pub fn solve_batch_from(
        &self,
        qs: Option<&[&[f64]]>,
        bs: Option<&[&[f64]]>,
        hs: Option<&[&[f64]]>,
        warms: Option<&[Option<WarmStart>]>,
        opts: &Options,
    ) -> BatchSolution {
        self.solve_batch_observed(qs, bs, hs, warms, opts, None)
    }

    /// [`Self::solve_batch_from`] with a per-iteration
    /// [`IterObserver`] hook. FW reports (duality gap, iterate step)
    /// per element — see the [module docs](crate::fw) — and only for
    /// claimed elements; `observer = None` is the unsampled fast path,
    /// identical solution either way.
    pub fn solve_batch_observed(
        &self,
        qs: Option<&[&[f64]]>,
        bs: Option<&[&[f64]]>,
        hs: Option<&[&[f64]]>,
        warms: Option<&[Option<WarmStart>]>,
        opts: &Options,
        mut observer: Option<&mut dyn IterObserver>,
    ) -> BatchSolution {
        let n = self.qp.n();
        let m = self.qp.m_ineq();
        let p = self.qp.p_eq();
        let bsz = qs
            .map(|v| v.len())
            .or_else(|| bs.map(|v| v.len()))
            .or_else(|| hs.map(|v| v.len()))
            .or_else(|| warms.map(|v| v.len()))
            .unwrap_or(1);
        assert!(bsz > 0, "empty batch");

        let qe = |e: usize| qs.map_or(self.qp.q.as_slice(), |v| v[e]);
        let be = |e: usize| bs.map_or(self.qp.b.as_slice(), |v| v[e]);
        let he = |e: usize| hs.map_or(self.qp.h.as_slice(), |v| v[e]);

        if let Some(ws_) = warms {
            assert_eq!(ws_.len(), bsz, "warm-start arity");
            if ws_.iter().any(|w| w.is_some()) {
                assert!(
                    opts.backward.forward_param().is_none()
                        || opts.tol == 0.0,
                    "warm starts with forward-mode Jacobians require \
                     tol = 0 (fixed-k); use BackwardMode::None/Adjoint \
                     for truncated warm solves"
                );
            }
        }

        let mut geoms: Vec<Geom> = Vec::with_capacity(bsz);
        let mut states: Vec<FwState> = Vec::with_capacity(bsz);
        for e in 0..bsz {
            assert_eq!(qe(e).len(), n, "q dimension (element {e})");
            assert_eq!(be(e).len(), p, "b dimension (element {e})");
            assert_eq!(he(e).len(), m, "h dimension (element {e})");
            let warm = warms.and_then(|w| w[e].as_ref());
            if let Some(w) = warm {
                assert_eq!(
                    w.dims(),
                    (n, p, m),
                    "warm-start dimensions (element {e})"
                );
            }
            let geom = self.solver.geom(be(e), he(e));
            states.push(self.solver.init_state(&geom, qe(e), warm));
            geoms.push(geom);
        }

        let mut act = ActiveSet::new(bsz);
        let mut iters = vec![0usize; bsz];
        let mut step_rel = vec![f64::INFINITY; bsz];
        let mut live: Vec<usize> = Vec::with_capacity(bsz);
        for k in 0..opts.max_iter {
            if act.all_done() {
                break;
            }
            live.clear();
            live.extend(act.iter());
            for &e in &live {
                let info =
                    self.solver.fw_step(&mut states[e], qe(e), &geoms[e]);
                iters[e] = k + 1;
                step_rel[e] = info.step_rel;
                if let Some(obs) = observer.as_mut() {
                    if obs.wants(e) {
                        obs.on_iter(e, k, info.gap, info.dx_norm);
                    }
                }
                if info.step_rel < opts.tol {
                    act.deactivate(e);
                }
            }
        }

        let param = opts.backward.forward_param();
        let mut xs = Vec::with_capacity(bsz);
        let mut ss = Vec::with_capacity(bsz);
        let mut lams = Vec::with_capacity(bsz);
        let mut nus = Vec::with_capacity(bsz);
        let mut jacobians = param.map(|_| Vec::with_capacity(bsz));
        for (e, st) in states.into_iter().enumerate() {
            let (s, lam, nu) =
                self.solver.recover(&st.x, qe(e), he(e), &geoms[e]);
            if let (Some(jl), Some(prm)) = (jacobians.as_mut(), param) {
                jl.push(self.solver.forward_jacobian(&s, prm));
            }
            xs.push(st.x);
            ss.push(s);
            lams.push(lam);
            nus.push(nu);
        }
        BatchSolution { xs, ss, lams, nus, jacobians, iters, step_rel }
    }

    /// Batched dimension-free adjoint: per-element ∂L/∂θ from each
    /// element's ∂L/∂x, same gate convention as [`FwQp::vjp`].
    pub fn batch_vjp(
        &self,
        slacks: &[&[f64]],
        vs: &[&[f64]],
        opts: &Options,
    ) -> BatchVjp {
        self.batch_vjp_from(slacks, vs, None, opts).0
    }

    /// [`Self::batch_vjp`] resuming per-element projected-CG states
    /// from harvested [`FwSeed`]s (cold where `None`), returning the
    /// final per-element states for the next caller — the family
    /// sibling of
    /// [`BatchedAltDiff::batch_vjp_from`](crate::batch::BatchedAltDiff::batch_vjp_from).
    pub fn batch_vjp_from(
        &self,
        slacks: &[&[f64]],
        vs: &[&[f64]],
        warms: Option<&[Option<FwSeed>]>,
        opts: &Options,
    ) -> (BatchVjp, Vec<FwSeed>) {
        let bsz = slacks.len();
        assert_eq!(vs.len(), bsz, "v arity");
        if let Some(w) = warms {
            assert_eq!(w.len(), bsz, "adjoint-seed arity");
        }
        let mut grads_q = Vec::with_capacity(bsz);
        let mut grads_b = Vec::with_capacity(bsz);
        let mut grads_h = Vec::with_capacity(bsz);
        let mut iters = Vec::with_capacity(bsz);
        let mut step_rel = Vec::with_capacity(bsz);
        let mut seeds = Vec::with_capacity(bsz);
        for e in 0..bsz {
            let warm = warms.and_then(|w| w[e].as_ref());
            let (vjp, seed) =
                self.solver.vjp_from(slacks[e], vs[e], warm, opts);
            grads_q.push(vjp.grad_q);
            grads_b.push(vjp.grad_b);
            grads_h.push(vjp.grad_h);
            iters.push(vjp.iters);
            step_rel.push(vjp.step_rel);
            seeds.push(seed);
        }
        (
            BatchVjp { grads_q, grads_b, grads_h, iters, step_rel },
            seeds,
        )
    }

    /// Forward batch + reverse-mode backward in one call — the batched
    /// training entry point.
    pub fn solve_batch_vjp(
        &self,
        qs: Option<&[&[f64]]>,
        bs: Option<&[&[f64]]>,
        hs: Option<&[&[f64]]>,
        vs: &[&[f64]],
        opts: &Options,
    ) -> BatchVjpSolution {
        let fopts = Options {
            backward: crate::altdiff::BackwardMode::None,
            ..opts.clone()
        };
        let forward = self.solve_batch(qs, bs, hs, &fopts);
        let slacks = forward.slack_refs();
        let vjp = self.batch_vjp(&slacks, vs, opts);
        BatchVjpSolution { forward, vjp }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::altdiff::{BackwardMode, Options, Param};
    use crate::prob::{box_qp, l1_ball_qp, simplex_qp};

    fn tight() -> Options {
        Options {
            tol: 1e-12,
            max_iter: 200_000,
            backward: BackwardMode::None,
            ..Default::default()
        }
    }

    #[test]
    fn broadcast_matches_single_bitwise() {
        let qp = simplex_qp(10, 1.0, 3);
        let single = FwQp::new(qp.clone(), 1.0).unwrap();
        let batched = BatchedFw::from_single(&single);
        let q2: Vec<f64> =
            qp.q.iter().map(|v| 1.3 * v + 0.1).collect();
        let qs: Vec<&[f64]> = vec![&qp.q, &q2];
        let sol = batched.solve_batch(Some(&qs), None, None, &tight());
        for (e, qe) in qs.iter().enumerate() {
            let se =
                single.solve_with(Some(qe), None, None, &tight());
            assert_eq!(sol.xs[e], se.x, "element {e} diverged");
            assert_eq!(sol.iters[e], se.iters);
        }
    }

    #[test]
    fn ragged_truncation_freezes_converged_elements() {
        let qp = box_qp(8, 9);
        let batched = BatchedFw::new(qp.clone(), 1.0).unwrap();
        // one near-trivial element (tiny q → lands on a vertex fast)
        // and one hard element
        let easy: Vec<f64> = qp.q.iter().map(|v| 1e-3 * v).collect();
        let hard: Vec<f64> = qp.q.iter().map(|v| -2.0 * v).collect();
        let qs: Vec<&[f64]> = vec![&easy, &hard];
        let opts = Options { tol: 1e-10, ..tight() };
        let sol = batched.solve_batch(Some(&qs), None, None, &opts);
        assert!(sol.iters[0] <= sol.iters[1]);
        assert!(sol.step_rel.iter().all(|&s| s < 1e-10));
    }

    #[test]
    fn fixed_k_runs_lockstep() {
        let qp = l1_ball_qp(5, 1.0, 4);
        let single = FwQp::new(qp.clone(), 1.0).unwrap();
        let batched = BatchedFw::from_single(&single);
        let opts = Options {
            tol: 0.0,
            max_iter: 13,
            backward: BackwardMode::None,
            ..Default::default()
        };
        let qs: Vec<&[f64]> = vec![&qp.q, &qp.q];
        let sol = batched.solve_batch(Some(&qs), None, None, &opts);
        let se = single.solve(&opts);
        assert!(sol.iters.iter().all(|&i| i == 13));
        for e in 0..2 {
            assert_eq!(sol.xs[e], se.x);
        }
    }

    #[test]
    fn mixed_warm_cold_isolation() {
        let qp = simplex_qp(8, 1.0, 12);
        let batched = BatchedFw::new(qp.clone(), 1.0).unwrap();
        let cold = batched.solve_batch(None, None, None, &tight());
        let ws = cold.warm_start(0);
        let warms = vec![Some(ws), None];
        let qs: Vec<&[f64]> = vec![&qp.q, &qp.q];
        let mixed = batched
            .solve_batch_from(Some(&qs), None, None, Some(&warms), &tight());
        // warm element converges immediately; cold element is
        // bit-identical to an all-cold solve
        assert!(mixed.iters[0] <= 2);
        assert_eq!(mixed.xs[1], cold.xs[0]);
    }

    #[test]
    fn batch_vjp_matches_single_and_reseeds() {
        let qp = box_qp(6, 21);
        let single = FwQp::new(qp.clone(), 1.0).unwrap();
        let batched = BatchedFw::from_single(&single);
        let sol = batched.solve_batch(None, None, None, &tight());
        let slacks = sol.slack_refs();
        let v: Vec<f64> = (0..6).map(|i| 0.2 * i as f64 - 0.5).collect();
        let vs: Vec<&[f64]> = vec![&v];
        let (bv, seeds) =
            batched.batch_vjp_from(&slacks, &vs, None, &tight());
        let sv = single.vjp(&sol.ss[0], &v, &tight());
        assert_eq!(bv.grads_q[0], sv.grad_q);
        assert_eq!(bv.grads_h[0], sv.grad_h);
        let warms = vec![Some(seeds[0].clone())];
        let (re, _) =
            batched.batch_vjp_from(&slacks, &vs, Some(&warms), &tight());
        assert!(re.iters[0] <= 4, "seeded iters {}", re.iters[0]);
    }

    #[test]
    fn batched_jacobians_match_single() {
        let qp = simplex_qp(7, 1.0, 8);
        let single = FwQp::new(qp.clone(), 1.0).unwrap();
        let batched = BatchedFw::from_single(&single);
        let opts = Options {
            backward: BackwardMode::Forward(Param::B),
            ..tight()
        };
        let sol = batched.solve_batch(None, None, None, &opts);
        let se = single.solve(&opts);
        let jb = &sol.jacobians.as_ref().unwrap()[0];
        let js = se.jacobian.as_ref().unwrap();
        for i in 0..7 {
            assert_eq!(jb[(i, 0)], js[(i, 0)]);
        }
    }
}
