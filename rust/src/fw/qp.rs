//! Single-problem Frank–Wolfe engine — the projection-free family
//! sibling of [`DenseAltDiff`](crate::altdiff::DenseAltDiff) and
//! [`AdmmQp`](crate::admm::AdmmQp), same contracts.
//!
//! Forward pass: away-step conditional gradient with exact line search.
//! The iterate is carried as an explicit convex combination
//! x = Σ αᵥ·v over an active vertex set S, so an away step can move
//! mass *off* a bad vertex (the ingredient that upgrades plain FW's
//! O(1/k) to linear convergence on polytopes). Each iteration costs one
//! gradient (n² flops), one LMO (O(n)), one away scan (O(|S|·n)), and
//! one exact line search — no Cholesky at registration, no projection
//! in the loop.
//!
//! Backward pass: the active-set KKT system is solved directly. The
//! supported feasible sets make its null space trivial to parameterize
//! (pinned coordinates + at most one dense row), so the adjoint is a
//! projected conjugate-gradient solve of ΠPΠ y = Πv — O(n) state
//! ([`FwSeed`]), d-free like the other families' adjoints, truncated by
//! the same step_rel/tol criterion so `tol = 0` runs exactly
//! `max_iter` iterations (Thm 4.3 fixed-k semantics). Forward-mode
//! Jacobians are produced from the same gated system, one run-to-
//! convergence CG per parameter column, *after* the primal loop —
//! unrolling FW itself would differentiate through a piecewise-constant
//! LMO and return zero almost everywhere.

use super::FeasibleSet;
use crate::altdiff::{
    BackwardMode, Options, Param, Solution, TraceEntry, Vjp, VjpSolution,
};
use crate::error::{AltDiffError, Result};
use crate::linalg::{axpy, dot, gemv, norm2, Mat};
use crate::obs::IterObserver;
use crate::prob::Qp;
use crate::warm::{FwSeed, WarmStart};

/// Vertex weights below this are dropped from the active set; the mass
/// they carried is O(ε)·r and an away "drop step" lands on exactly this
/// threshold after float cancellation.
const WEIGHT_EPS: f64 = 1e-12;

/// Per-request geometry, re-derived from the requested (b, h) so θ
/// overrides move the bounds/scale without re-detection. The *class*
/// is fixed at registration; a request must stay inside it (asserted).
#[derive(Clone, Debug)]
pub(crate) enum Geom {
    /// l ≤ x ≤ u with l = −h[n..2n], u = h[0..n].
    Box { l: Vec<f64>, u: Vec<f64> },
    /// 1ᵀx = r, x ≥ 0 with r = b[0].
    Simplex { r: f64 },
    /// ‖x‖₁ ≤ r with r = h[0] (h must stay uniform).
    L1 { r: f64 },
}

/// The conditional-gradient iterate: x plus the explicit convex
/// combination it decomposes into (the away step needs the vertex
/// weights). Shared verbatim with [`BatchedFw`](super::BatchedFw) —
/// the batch engine drives one `FwState` per element through the same
/// [`FwQp::fw_step`], which is what makes batch == single bit-exact.
#[derive(Clone, Debug)]
pub(crate) struct FwState {
    pub(crate) x: Vec<f64>,
    verts: Vec<Vec<f64>>,
    alphas: Vec<f64>,
}

/// What one FW iteration reports upward: the duality gap (the
/// convergence certificate, surfaced in the observer's primal slot),
/// the relative step (truncation criterion), and the absolute step
/// (observer dual slot).
pub(crate) struct StepInfo {
    pub(crate) gap: f64,
    pub(crate) step_rel: f64,
    pub(crate) dx_norm: f64,
}

/// Slack-gated tangent space of the active-set KKT system: which
/// coordinates are pinned, plus the (at most one) dense constraint row
/// the supported sets can contribute — 1ᵀ for the simplex equality,
/// the shared support signs σ_S for a face of the ℓ1 ball.
struct Tangent {
    pins: Vec<bool>,
    /// Dense row restricted to free coordinates (the projector uses it).
    dense_masked: Option<Vec<f64>>,
    /// The same row with pinned coordinates included (particular
    /// solutions must honor the full constraint).
    dense_full: Option<Vec<f64>>,
    kind: TangentKind,
}

enum TangentKind {
    /// Per coordinate: the active bound row and its ±1 coefficient.
    Box { coeff_rows: Vec<Option<(usize, f64)>> },
    Simplex,
    /// Active facet rows, shared support signs (0 on pins), |S|.
    L1 { active_rows: Vec<usize>, sigma: Vec<f64>, n_support: usize },
}

/// A registered Frank–Wolfe QP layer. Registration is O(1) — the only
/// work is structural detection of the feasible set; there is no
/// factorization to build or cache.
#[derive(Clone)]
pub struct FwQp {
    /// The registered problem.
    pub qp: Qp,
    /// Interface parity with the factorizing families; the FW iteration
    /// is penalty-free and never reads it.
    pub rho: f64,
    set: FeasibleSet,
}

impl FwQp {
    /// Register a layer; fails unless the constraint structure matches
    /// one of the supported vertex-enumerable sets
    /// ([`FeasibleSet::detect`]).
    pub fn new(qp: Qp, rho: f64) -> Result<FwQp> {
        match FeasibleSet::detect(&qp) {
            Some(set) => Ok(FwQp { qp, rho, set }),
            None => Err(AltDiffError::DimMismatch(
                "FW engine requires a box ([I; -I]), simplex (1ᵀx = r, \
                 x ≥ 0), or ℓ1-ball (all 2ⁿ sign facets) constraint \
                 encoding; structure not recognized"
                    .into(),
            )),
        }
    }

    /// The detected feasible-set class this layer serves.
    pub fn feasible_set(&self) -> &FeasibleSet {
        &self.set
    }

    /// Solve + differentiate with per-request parameters; `None` means
    /// the registered value. Same contract as
    /// [`DenseAltDiff::solve_with`](crate::altdiff::DenseAltDiff::solve_with).
    pub fn solve_with(
        &self,
        q: Option<&[f64]>,
        b: Option<&[f64]>,
        h: Option<&[f64]>,
        opts: &Options,
    ) -> Solution {
        self.solve_from(q, b, h, None, opts)
    }

    /// [`Self::solve_with`] resuming from a prior iterate triple. The
    /// shared warm format carries x; FW re-expands it into a vertex
    /// combination (box: the nested-interval staircase, simplex/ℓ1:
    /// coordinate vertices plus leftover mass), so a fixed-point x
    /// reproduces itself and stops in one iteration. `warm.lam`/`nu`
    /// are ignored — FW carries no dual state between solves. `warm =
    /// None` is bit-identical to the cold [`Self::solve_with`]; the
    /// forward-mode/tol composition rule is the same as the other
    /// families' (asserted).
    pub fn solve_from(
        &self,
        q: Option<&[f64]>,
        b: Option<&[f64]>,
        h: Option<&[f64]>,
        warm: Option<&WarmStart>,
        opts: &Options,
    ) -> Solution {
        self.solve_observed(q, b, h, warm, opts, None)
    }

    /// Convenience: registered parameters, default θ.
    ///
    /// ```
    /// use altdiff::altdiff::Options;
    /// use altdiff::fw::FwQp;
    /// use altdiff::prob::simplex_qp;
    ///
    /// let qp = simplex_qp(12, 1.0, 7);
    /// let layer = FwQp::new(qp.clone(), 1.0).unwrap();
    /// let sol = layer.solve(&Options::with_tol(1e-10));
    /// // iterates are convex combinations of simplex vertices —
    /// // feasible by construction, no projection ever ran
    /// let mass: f64 = sol.x.iter().sum();
    /// assert!((mass - 1.0).abs() < 1e-9);
    /// assert!(sol.x.iter().all(|&v| v >= -1e-12));
    /// assert!(qp.kkt_residual(&sol.x, &sol.lam, &sol.nu) < 1e-5);
    /// // ∂x/∂b rides along (default forward mode), d = p = 1
    /// assert_eq!(sol.jacobian.as_ref().unwrap().cols, 1);
    /// ```
    pub fn solve(&self, opts: &Options) -> Solution {
        self.solve_with(None, None, None, opts)
    }

    /// [`Self::solve_from`] streaming per-iteration progress into an
    /// [`IterObserver`] (element index 0). FW reports the duality gap
    /// gₖ = ∇f(xₖ)ᵀ(xₖ − vₖ) in the primal slot — its convergence
    /// certificate, f(xₖ) − f* ≤ gₖ — and ‖xₖ₊₁ − xₖ‖ in the dual slot
    /// (see the [module docs](crate::fw) for why this diverges from the
    /// factorizing families' constraint-violation convention).
    pub fn solve_observed(
        &self,
        q: Option<&[f64]>,
        b: Option<&[f64]>,
        h: Option<&[f64]>,
        warm: Option<&WarmStart>,
        opts: &Options,
        mut observer: Option<&mut dyn IterObserver>,
    ) -> Solution {
        let n = self.qp.n();
        let m = self.qp.m_ineq();
        let p = self.qp.p_eq();
        let q = q.unwrap_or(&self.qp.q);
        let b = b.unwrap_or(&self.qp.b);
        let h = h.unwrap_or(&self.qp.h);
        assert_eq!(q.len(), n, "q dimension");
        assert_eq!(b.len(), p, "b dimension");
        assert_eq!(h.len(), m, "h dimension");
        if let Some(w) = warm {
            assert!(
                opts.backward.forward_param().is_none() || opts.tol == 0.0,
                "warm starts with forward-mode Jacobians require tol = 0 \
                 (fixed-k); use BackwardMode::None/Adjoint for truncated \
                 warm solves"
            );
            assert_eq!(w.dims(), (n, p, m), "warm-start dimensions");
        }

        let geom = self.geom(b, h);
        let mut st = self.init_state(&geom, q, warm);

        let mut trace = Vec::new();
        let mut iters = 0;
        let mut step_rel = f64::INFINITY;
        for k in 0..opts.max_iter {
            iters = k + 1;
            let info = self.fw_step(&mut st, q, &geom);
            step_rel = info.step_rel;
            if let Some(obs) = observer.as_mut() {
                if obs.wants(0) {
                    obs.on_iter(0, k, info.gap, info.dx_norm);
                }
            }
            if opts.trace {
                trace.push(TraceEntry { iter: k, step_rel, jac_norm: 0.0 });
            }
            if step_rel < opts.tol {
                break;
            }
        }

        let (s, lam, nu) = self.recover(&st.x, q, h, &geom);
        let jacobian = opts
            .backward
            .forward_param()
            .map(|prm| self.forward_jacobian(&s, prm));
        Solution { x: st.x, s, lam, nu, jacobian, iters, step_rel, trace }
    }

    /// Dimension-free adjoint: ∂L/∂θ from v = ∂L/∂x via the slack-gated
    /// KKT system, without ever forming a Jacobian. Truncation on the
    /// CG step (`opts.tol`; `tol = 0` runs exactly `opts.max_iter`
    /// iterations).
    pub fn vjp(&self, slack: &[f64], v: &[f64], opts: &Options) -> Vjp {
        self.vjp_from(slack, v, None, opts).0
    }

    /// [`Self::vjp`] resuming the projected-CG solve from a harvested
    /// [`FwSeed`] and returning the final state for the next caller —
    /// the family sibling of
    /// [`DenseAltDiff::vjp_from`](crate::altdiff::DenseAltDiff::vjp_from).
    /// `warm = None` is bit-identical to the cold [`Self::vjp`].
    pub fn vjp_from(
        &self,
        slack: &[f64],
        v: &[f64],
        warm: Option<&FwSeed>,
        opts: &Options,
    ) -> (Vjp, FwSeed) {
        let n = self.qp.n();
        let m = self.qp.m_ineq();
        let p = self.qp.p_eq();
        assert_eq!(slack.len(), m, "slack dimension");
        assert_eq!(v.len(), n, "v dimension");
        let tan = self.tangent(slack);
        let seeded = warm.is_some();
        let y0 = warm.map(|seed| {
            assert_eq!(seed.dim(), n, "adjoint-seed dimensions");
            seed.y.clone()
        });
        let (y, iters, step_rel) =
            self.gated_cg(&tan, v, y0, opts, seeded);
        let seed_out = FwSeed { y: y.clone() };

        // residual v − Py lies (at convergence) in the span of the
        // active constraint normals; reading the multipliers off it is
        // geometry-specific
        let mut res = gemv(&self.qp.p, &y);
        for i in 0..n {
            res[i] = v[i] - res[i];
        }
        let grad_q: Vec<f64> = y.iter().map(|&yi| -yi).collect();
        let mut grad_b = vec![0.0; p];
        let mut grad_h = vec![0.0; m];
        match &tan.kind {
            TangentKind::Box { coeff_rows } => {
                for (i, cr) in coeff_rows.iter().enumerate() {
                    if let Some((row, coeff)) = cr {
                        grad_h[*row] = coeff * res[i];
                    }
                }
            }
            TangentKind::Simplex => {
                let free = tan.pins.iter().filter(|&&pin| !pin).count();
                let beta: f64 = res
                    .iter()
                    .zip(&tan.pins)
                    .filter(|(_, &pin)| !pin)
                    .map(|(&r, _)| r)
                    .sum::<f64>()
                    / free.max(1) as f64;
                grad_b[0] = beta;
                for i in 0..n {
                    if tan.pins[i] {
                        grad_h[i] = beta - res[i];
                    }
                }
            }
            TangentKind::L1 { active_rows, sigma, n_support } => {
                if !active_rows.is_empty() && *n_support > 0 {
                    let gamma_total: f64 = (0..n)
                        .map(|j| sigma[j] * res[j])
                        .sum::<f64>()
                        / *n_support as f64;
                    if gamma_total.abs() > 1e-300 {
                        // distribute Γ over the active sub-cube so the
                        // pinned coordinates of res are reproduced:
                        // per-row weight Γ·Π (1 + σ'ⱼ·resⱼ/Γ)/2
                        for &row in active_rows {
                            let mut w = gamma_total;
                            for j in 0..n {
                                if tan.pins[j] {
                                    let d = res[j] / gamma_total;
                                    w *= (1.0 + self.qp.g[(row, j)] * d)
                                        / 2.0;
                                }
                            }
                            grad_h[row] = w;
                        }
                    }
                }
            }
        }
        (Vjp { grad_q, grad_b, grad_h, iters, step_rel }, seed_out)
    }

    /// Forward solve + reverse-mode backward in one call — the training
    /// entry point, d-free like
    /// [`DenseAltDiff::solve_vjp`](crate::altdiff::DenseAltDiff::solve_vjp).
    pub fn solve_vjp(
        &self,
        q: Option<&[f64]>,
        b: Option<&[f64]>,
        h: Option<&[f64]>,
        v: &[f64],
        opts: &Options,
    ) -> VjpSolution {
        let fopts =
            Options { backward: BackwardMode::None, ..opts.clone() };
        let solution = self.solve_with(q, b, h, &fopts);
        let vjp = self.vjp(&solution.s, v, opts);
        VjpSolution { solution, vjp }
    }

    // ---- shared internals (the batch engine drives these directly) ----

    /// Re-derive the request geometry from the requested right-hand
    /// sides; the class is registration-fixed, the numbers are not.
    pub(crate) fn geom(&self, b: &[f64], h: &[f64]) -> Geom {
        let n = self.qp.n();
        match &self.set {
            FeasibleSet::Box { .. } => {
                let u: Vec<f64> = h[..n].to_vec();
                let l: Vec<f64> = h[n..].iter().map(|&v| -v).collect();
                assert!(
                    l.iter().zip(&u).all(|(lo, hi)| lo < hi),
                    "per-request h left the box class (l < u violated)"
                );
                Geom::Box { l, u }
            }
            FeasibleSet::Simplex { .. } => {
                assert!(
                    b[0] > 0.0,
                    "per-request b left the simplex class (r ≤ 0)"
                );
                Geom::Simplex { r: b[0] }
            }
            FeasibleSet::L1Ball { .. } => {
                assert!(
                    h[0] > 0.0 && h.iter().all(|&v| v == h[0]),
                    "per-request h left the ℓ1-ball class (non-uniform \
                     or non-positive radius)"
                );
                Geom::L1 { r: h[0] }
            }
        }
    }

    /// Linear minimization oracle: argmin over the feasible set of
    /// ⟨grad, v⟩. Deterministic tie rules (module docs) keep batch and
    /// single solves in lockstep.
    fn lmo(geom: &Geom, grad: &[f64]) -> Vec<f64> {
        match geom {
            Geom::Box { l, u } => grad
                .iter()
                .zip(l.iter().zip(u))
                .map(|(&g, (&lo, &hi))| if g > 0.0 { lo } else { hi })
                .collect(),
            Geom::Simplex { r } => {
                let mut best = 0;
                for (i, &g) in grad.iter().enumerate() {
                    if g < grad[best] {
                        best = i;
                    }
                }
                let mut v = vec![0.0; grad.len()];
                v[best] = *r;
                v
            }
            Geom::L1 { r } => {
                let mut best = 0;
                for (i, &g) in grad.iter().enumerate() {
                    if g.abs() > grad[best].abs() {
                        best = i;
                    }
                }
                let mut v = vec![0.0; grad.len()];
                v[best] = if grad[best] > 0.0 { -*r } else { *r };
                v
            }
        }
    }

    /// Cold start: the LMO vertex of the linear term (the minimizer of
    /// the objective's gradient at 0). Warm start: re-expand the
    /// carried x into an explicit convex combination of vertices.
    pub(crate) fn init_state(
        &self,
        geom: &Geom,
        q: &[f64],
        warm: Option<&WarmStart>,
    ) -> FwState {
        match warm {
            None => {
                let v0 = Self::lmo(geom, q);
                FwState { x: v0.clone(), verts: vec![v0], alphas: vec![1.0] }
            }
            Some(w) => self.decompose(geom, &w.x),
        }
    }

    /// Vertex decomposition of an arbitrary (feasible) point. The
    /// rebuilt x = Σ αᵥ·v replaces the carried one so the invariant the
    /// away step relies on holds exactly; a fixed-point warm start then
    /// reproduces itself to float accuracy and stops in one iteration.
    fn decompose(&self, geom: &Geom, x: &[f64]) -> FwState {
        let n = x.len();
        let mut verts: Vec<Vec<f64>> = Vec::new();
        let mut alphas: Vec<f64> = Vec::new();
        match geom {
            Geom::Box { l, u } => {
                // nested-interval staircase: sort coordinates by their
                // relative position t, walk the prefix-set vertices
                let t: Vec<f64> = (0..n)
                    .map(|i| {
                        ((x[i] - l[i]) / (u[i] - l[i])).clamp(0.0, 1.0)
                    })
                    .collect();
                let mut idx: Vec<usize> = (0..n).collect();
                idx.sort_by(|&a, &b| {
                    t[b].partial_cmp(&t[a]).unwrap().then(a.cmp(&b))
                });
                let mut cur = l.clone();
                let w0 = 1.0 - t[idx[0]];
                if w0 > WEIGHT_EPS {
                    verts.push(cur.clone());
                    alphas.push(w0);
                }
                for j in 0..n {
                    cur[idx[j]] = u[idx[j]];
                    let w = if j + 1 < n {
                        t[idx[j]] - t[idx[j + 1]]
                    } else {
                        t[idx[j]]
                    };
                    if w > WEIGHT_EPS {
                        verts.push(cur.clone());
                        alphas.push(w);
                    }
                }
            }
            Geom::Simplex { r } => {
                for i in 0..n {
                    let w = x[i].max(0.0) / r;
                    if w > WEIGHT_EPS {
                        let mut v = vec![0.0; n];
                        v[i] = *r;
                        verts.push(v);
                        alphas.push(w);
                    }
                }
            }
            Geom::L1 { r } => {
                let mut sum = 0.0;
                for i in 0..n {
                    let w = x[i].abs() / r;
                    if w > WEIGHT_EPS {
                        let mut v = vec![0.0; n];
                        v[i] = r * x[i].signum();
                        verts.push(v);
                        alphas.push(w);
                        sum += w;
                    }
                }
                if sum > 1.0 {
                    for a in &mut alphas {
                        *a /= sum;
                    }
                    sum = 1.0;
                }
                // leftover mass sits on a ± vertex pair so it cancels
                let beta = 1.0 - sum;
                if beta > WEIGHT_EPS {
                    for sign in [1.0, -1.0] {
                        let mut v = vec![0.0; n];
                        v[0] = sign * r;
                        match verts.iter().position(|w| *w == v) {
                            Some(j) => alphas[j] += beta / 2.0,
                            None => {
                                verts.push(v);
                                alphas.push(beta / 2.0);
                            }
                        }
                    }
                }
            }
        }
        if verts.is_empty() {
            // degenerate carry (e.g. an all-clamped point); fall back to
            // a single deterministic vertex
            let v0 = Self::lmo(geom, &self.qp.q);
            verts.push(v0);
            alphas.push(1.0);
        }
        let total: f64 = alphas.iter().sum();
        for a in &mut alphas {
            *a /= total;
        }
        let mut x = vec![0.0; n];
        for (v, &a) in verts.iter().zip(&alphas) {
            axpy(&mut x, a, v);
        }
        FwState { x, verts, alphas }
    }

    /// One away-step FW iteration with exact line search. Zero-length
    /// steps are genuine no-ops (state untouched up to exact float
    /// identity), which is what keeps `tol = 0` fixed-k runs
    /// deterministic past convergence.
    pub(crate) fn fw_step(
        &self,
        st: &mut FwState,
        q: &[f64],
        geom: &Geom,
    ) -> StepInfo {
        let n = q.len();
        let mut grad = gemv(&self.qp.p, &st.x);
        for i in 0..n {
            grad[i] += q[i];
        }
        let v_fw = Self::lmo(geom, &grad);
        let gx = dot(&grad, &st.x);
        let g_fw = gx - dot(&grad, &v_fw);
        // away vertex: the active-set vertex the gradient most opposes
        let mut aw = 0;
        let mut aw_score = f64::NEG_INFINITY;
        for (j, v) in st.verts.iter().enumerate() {
            let sc = dot(&grad, v);
            if sc > aw_score {
                aw_score = sc;
                aw = j;
            }
        }
        let g_aw = aw_score - gx;

        let away = g_aw > g_fw;
        let (d, gamma_max): (Vec<f64>, f64) = if away {
            let a = st.alphas[aw];
            let d: Vec<f64> = st
                .x
                .iter()
                .zip(&st.verts[aw])
                .map(|(&xi, &vi)| xi - vi)
                .collect();
            let gmax = if a < 1.0 { a / (1.0 - a) } else { f64::MAX };
            (d, gmax)
        } else {
            let d: Vec<f64> = v_fw
                .iter()
                .zip(&st.x)
                .map(|(&vi, &xi)| vi - xi)
                .collect();
            (d, 1.0)
        };

        // exact line search on the quadratic: γ* = ⟨−grad, d⟩ / ⟨d, Pd⟩
        let pd = gemv(&self.qp.p, &d);
        let denom = dot(&d, &pd);
        let descent = -dot(&grad, &d);
        let mut gamma = if denom > 0.0 {
            (descent / denom).clamp(0.0, gamma_max)
        } else {
            gamma_max
        };
        if !gamma.is_finite() || descent <= 0.0 {
            gamma = 0.0;
        }

        let xprev_norm = norm2(&st.x);
        let dx_norm = gamma * norm2(&d);
        if gamma > 0.0 {
            axpy(&mut st.x, gamma, &d);
            if away {
                for a in &mut st.alphas {
                    *a *= 1.0 + gamma;
                }
                st.alphas[aw] -= gamma;
            } else {
                for a in &mut st.alphas {
                    *a *= 1.0 - gamma;
                }
                match st.verts.iter().position(|v| *v == v_fw) {
                    Some(j) => st.alphas[j] += gamma,
                    None => {
                        st.verts.push(v_fw);
                        st.alphas.push(gamma);
                    }
                }
            }
            // drop spent vertices (away drop steps land here exactly)
            let mut j = 0;
            while j < st.alphas.len() {
                if st.alphas[j] <= WEIGHT_EPS {
                    st.alphas.swap_remove(j);
                    st.verts.swap_remove(j);
                } else {
                    j += 1;
                }
            }
        }
        StepInfo {
            gap: g_fw,
            step_rel: dx_norm / xprev_norm.max(1.0),
            dx_norm,
        }
    }

    /// Post-loop slack/dual recovery: s = h − Gx with active rows
    /// snapped to exact 0.0 (the same gate convention every adjoint in
    /// the crate reads), duals read off the stationarity residual
    /// res = −(Px + q) per geometry.
    pub(crate) fn recover(
        &self,
        x: &[f64],
        q: &[f64],
        h: &[f64],
        geom: &Geom,
    ) -> (Vec<f64>, Vec<f64>, Vec<f64>) {
        let n = self.qp.n();
        let m = self.qp.m_ineq();
        let p = self.qp.p_eq();
        let mut s = gemv(&self.qp.g, x);
        for i in 0..m {
            s[i] = h[i] - s[i];
            if s[i] < 1e-9 * (1.0 + h[i].abs()) {
                s[i] = 0.0;
            }
        }
        let mut res = gemv(&self.qp.p, x);
        for i in 0..n {
            res[i] = -(res[i] + q[i]);
        }
        let mut lam = vec![0.0; p];
        let mut nu = vec![0.0; m];
        match geom {
            Geom::Box { .. } => {
                for i in 0..n {
                    if s[i] == 0.0 {
                        nu[i] = res[i];
                    } else if s[n + i] == 0.0 {
                        nu[n + i] = -res[i];
                    }
                }
            }
            Geom::Simplex { .. } => {
                // free coordinates: ν = 0 ⇒ λ = resᵢ there; average
                // for robustness at truncated iterates
                let mut acc = 0.0;
                let mut cnt = 0usize;
                for i in 0..n {
                    if s[i] > 0.0 {
                        acc += res[i];
                        cnt += 1;
                    }
                }
                let l0 = acc / cnt.max(1) as f64;
                lam[0] = l0;
                for i in 0..n {
                    if s[i] == 0.0 {
                        nu[i] = l0 - res[i];
                    }
                }
            }
            Geom::L1 { .. } => {
                let tan = self.tangent(&s);
                if let TangentKind::L1 { active_rows, sigma, n_support } =
                    &tan.kind
                {
                    if !active_rows.is_empty() && *n_support > 0 {
                        let g_tot: f64 = (0..n)
                            .map(|j| sigma[j] * res[j])
                            .sum::<f64>()
                            / *n_support as f64;
                        if g_tot.abs() > 1e-300 {
                            for &row in active_rows {
                                let mut w = g_tot;
                                for j in 0..n {
                                    if tan.pins[j] {
                                        let dj = res[j] / g_tot;
                                        w *= (1.0
                                            + self.qp.g[(row, j)] * dj)
                                            / 2.0;
                                    }
                                }
                                nu[row] = w;
                            }
                        }
                    }
                }
            }
        }
        (s, lam, nu)
    }

    /// Derive the slack-gated tangent space from a recovered slack.
    fn tangent(&self, s: &[f64]) -> Tangent {
        let n = self.qp.n();
        match &self.set {
            FeasibleSet::Box { .. } => {
                let mut pins = vec![false; n];
                let mut coeff_rows: Vec<Option<(usize, f64)>> =
                    vec![None; n];
                for i in 0..n {
                    if s[i] == 0.0 {
                        pins[i] = true;
                        coeff_rows[i] = Some((i, 1.0));
                    } else if s[n + i] == 0.0 {
                        pins[i] = true;
                        coeff_rows[i] = Some((n + i, -1.0));
                    }
                }
                Tangent {
                    pins,
                    dense_masked: None,
                    dense_full: None,
                    kind: TangentKind::Box { coeff_rows },
                }
            }
            FeasibleSet::Simplex { .. } => {
                let pins: Vec<bool> =
                    (0..n).map(|i| s[i] == 0.0).collect();
                let full = vec![1.0; n];
                let masked: Vec<f64> = pins
                    .iter()
                    .map(|&pin| if pin { 0.0 } else { 1.0 })
                    .collect();
                Tangent {
                    pins,
                    dense_masked: Some(masked),
                    dense_full: Some(full),
                    kind: TangentKind::Simplex,
                }
            }
            FeasibleSet::L1Ball { .. } => {
                let m = self.qp.m_ineq();
                let active_rows: Vec<usize> =
                    (0..m).filter(|&row| s[row] == 0.0).collect();
                let mut pins = vec![false; n];
                let mut sigma = vec![0.0; n];
                let mut n_support = 0usize;
                if !active_rows.is_empty() {
                    for j in 0..n {
                        let first = self.qp.g[(active_rows[0], j)];
                        if active_rows
                            .iter()
                            .all(|&row| self.qp.g[(row, j)] == first)
                        {
                            sigma[j] = first;
                            n_support += 1;
                        } else {
                            pins[j] = true;
                        }
                    }
                }
                let dense = if active_rows.is_empty() {
                    None
                } else {
                    Some(sigma.clone())
                };
                Tangent {
                    pins,
                    dense_masked: dense.clone(),
                    dense_full: dense,
                    kind: TangentKind::L1 { active_rows, sigma, n_support },
                }
            }
        }
    }

    /// Projected CG on ΠPΠ y = Πv, where Π zeroes the pinned
    /// coordinates and removes the dense-row component. Iteration
    /// conventions mirror the other adjoints: a converged (or
    /// degenerate) state takes zero-length steps that still count, so
    /// `tol = 0` runs exactly `max_iter` iterations, and a seeded first
    /// iteration must take one genuine step before the truncation test
    /// is trusted.
    fn gated_cg(
        &self,
        tan: &Tangent,
        rhs: &[f64],
        y0: Option<Vec<f64>>,
        opts: &Options,
        seeded: bool,
    ) -> (Vec<f64>, usize, f64) {
        let n = self.qp.n();
        let project = |w: &mut [f64]| {
            for i in 0..n {
                if tan.pins[i] {
                    w[i] = 0.0;
                }
            }
            if let Some(c) = &tan.dense_masked {
                let cc = dot(c, c);
                if cc > 0.0 {
                    let t = dot(c, w) / cc;
                    for i in 0..n {
                        w[i] -= t * c[i];
                    }
                }
            }
        };

        let mut y = y0.unwrap_or_else(|| vec![0.0; n]);
        project(&mut y);
        let mut r = gemv(&self.qp.p, &y);
        for i in 0..n {
            r[i] = rhs[i] - r[i];
        }
        project(&mut r);
        let mut pv = r.clone();
        let mut rs = dot(&r, &r);

        let mut iters = 1;
        let mut step_rel = f64::INFINITY;
        for k in 1..opts.max_iter {
            let mut dy_norm = 0.0;
            let yprev_norm = norm2(&y);
            if rs > 1e-300 {
                let mut ap = gemv(&self.qp.p, &pv);
                project(&mut ap);
                let pap = dot(&pv, &ap);
                if pap > 0.0 {
                    let alpha = rs / pap;
                    dy_norm = alpha * norm2(&pv);
                    axpy(&mut y, alpha, &pv);
                    axpy(&mut r, -alpha, &ap);
                    let rs_new = dot(&r, &r);
                    let beta = rs_new / rs;
                    for i in 0..n {
                        pv[i] = r[i] + beta * pv[i];
                    }
                    rs = rs_new;
                }
            }
            iters = k + 1;
            step_rel = dy_norm / yprev_norm.max(1.0);
            if step_rel < opts.tol && (k > 1 || !seeded) {
                break;
            }
        }
        (y, iters, step_rel)
    }

    /// Run-to-convergence CG options for Jacobian columns: the columns
    /// are the *exact* implicit derivative at the final active set, so
    /// batch and single solves agree bit-for-bit.
    fn exact_opts(&self) -> Options {
        Options {
            tol: 1e-14,
            max_iter: 6 * self.qp.n() + 20,
            backward: BackwardMode::None,
            rho: self.rho,
            trace: false,
        }
    }

    /// One column of the implicit derivative: a particular solution
    /// honoring the perturbed affine constraints (pinned values + the
    /// full dense row), plus a gated-CG correction in the tangent
    /// space.
    fn constrained_column(
        &self,
        tan: &Tangent,
        rhs_x: &[f64],
        pin_vals: &[f64],
        c_rhs: f64,
    ) -> Vec<f64> {
        let n = self.qp.n();
        let mut xp = pin_vals.to_vec();
        if let (Some(cm), Some(cf)) = (&tan.dense_masked, &tan.dense_full)
        {
            let cc = dot(cm, cm);
            if cc > 0.0 {
                let defect = c_rhs - dot(cf, &xp);
                for i in 0..n {
                    xp[i] += defect / cc * cm[i];
                }
            }
        }
        let mut rhs = gemv(&self.qp.p, &xp);
        for i in 0..n {
            rhs[i] = rhs_x[i] - rhs[i];
        }
        let (z, _, _) =
            self.gated_cg(tan, &rhs, None, &self.exact_opts(), false);
        let mut col = xp;
        axpy(&mut col, 1.0, &z);
        col
    }

    /// Forward-mode Jacobian ∂x/∂θ at the recovered active set,
    /// computed by implicit differentiation after the primal loop (the
    /// LMO is piecewise constant — unrolling would return zero).
    ///
    /// ℓ1 convention: an active sub-cube has non-unique per-facet
    /// sensitivities; ∂x/∂hᵣₒᵥ is reported as the uniform-radius-bump
    /// column split equally across the active rows, whose *sum* (the
    /// ∂x/∂r direction) is the canonical well-defined object.
    pub(crate) fn forward_jacobian(&self, s: &[f64], param: Param) -> Mat {
        let n = self.qp.n();
        let m = self.qp.m_ineq();
        let p = self.qp.p_eq();
        let d = param.dim(n, m, p);
        let tan = self.tangent(s);
        let mut jac = Mat::zeros(n, d);
        let zero = vec![0.0; n];
        match param {
            Param::Q => {
                for j in 0..d {
                    let mut rhs = vec![0.0; n];
                    rhs[j] = -1.0;
                    let col =
                        self.constrained_column(&tan, &rhs, &zero, 0.0);
                    for i in 0..n {
                        jac[(i, j)] = col[i];
                    }
                }
            }
            Param::B => {
                // only the simplex class has a live equality; vacuous
                // rows have zero sensitivity
                if matches!(tan.kind, TangentKind::Simplex) && d > 0 {
                    let col =
                        self.constrained_column(&tan, &zero, &zero, 1.0);
                    for i in 0..n {
                        jac[(i, 0)] = col[i];
                    }
                }
            }
            Param::H => match &tan.kind {
                TangentKind::Box { coeff_rows } => {
                    for (i, cr) in coeff_rows.iter().enumerate() {
                        if let Some((row, coeff)) = cr {
                            let mut pv = vec![0.0; n];
                            pv[i] = *coeff;
                            let col = self
                                .constrained_column(&tan, &zero, &pv, 0.0);
                            for ii in 0..n {
                                jac[(ii, *row)] = col[ii];
                            }
                        }
                    }
                }
                TangentKind::Simplex => {
                    for t in 0..n {
                        if s[t] == 0.0 {
                            let mut pv = vec![0.0; n];
                            pv[t] = -1.0;
                            let col = self
                                .constrained_column(&tan, &zero, &pv, 0.0);
                            for ii in 0..n {
                                jac[(ii, t)] = col[ii];
                            }
                        }
                    }
                }
                TangentKind::L1 { active_rows, .. } => {
                    if !active_rows.is_empty() {
                        let col = self
                            .constrained_column(&tan, &zero, &zero, 1.0);
                        let split = active_rows.len() as f64;
                        for &row in active_rows {
                            for ii in 0..n {
                                jac[(ii, row)] = col[ii] / split;
                            }
                        }
                    }
                }
            },
        }
        jac
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::altdiff::DenseAltDiff;
    use crate::prob::{box_qp, dense_qp, l1_ball_qp, simplex_qp};

    fn tight() -> Options {
        Options {
            tol: 1e-12,
            max_iter: 200_000,
            backward: BackwardMode::None,
            ..Default::default()
        }
    }

    #[test]
    fn rejects_unservable_structure() {
        assert!(FwQp::new(dense_qp(8, 4, 2, 3), 1.0).is_err());
    }

    #[test]
    fn box_solution_matches_dense_altdiff() {
        for seed in [1, 4, 9] {
            let qp = box_qp(10, seed);
            let fw = FwQp::new(qp.clone(), 1.0).unwrap();
            let alt = DenseAltDiff::new(qp, 1.0).unwrap();
            let sf = fw.solve(&tight());
            let sa = alt.solve(&tight());
            for i in 0..10 {
                assert!(
                    (sf.x[i] - sa.x[i]).abs() < 1e-8,
                    "x[{i}]: fw {} alt {}",
                    sf.x[i],
                    sa.x[i]
                );
            }
        }
    }

    #[test]
    fn simplex_reaches_kkt_point_with_duals() {
        let qp = simplex_qp(14, 1.0, 2);
        let fw = FwQp::new(qp.clone(), 1.0).unwrap();
        let sol = fw.solve(&tight());
        let r = qp.kkt_residual(&sol.x, &sol.lam, &sol.nu);
        assert!(r < 1e-6, "kkt residual {r}");
        assert!(sol.iters < 200_000, "did not converge");
        assert!(sol.nu.iter().all(|&v| v > -1e-7), "dual feasibility");
    }

    #[test]
    fn l1_solution_matches_dense_altdiff_primal() {
        let qp = l1_ball_qp(6, 1.0, 3);
        let fw = FwQp::new(qp.clone(), 1.0).unwrap();
        let alt = DenseAltDiff::new(qp.clone(), 1.0).unwrap();
        let sf = fw.solve(&tight());
        let sa = alt.solve(&tight());
        for i in 0..6 {
            assert!((sf.x[i] - sa.x[i]).abs() < 1e-7, "x[{i}]");
        }
        // FW's product-form duals still certify the KKT point
        let r = qp.kkt_residual(&sf.x, &sf.lam, &sf.nu);
        assert!(r < 1e-5, "kkt residual {r}");
    }

    #[test]
    fn fixed_k_runs_exactly_k_iterations() {
        let fw = FwQp::new(box_qp(8, 11), 1.0).unwrap();
        for k in [1, 5, 40] {
            let sol = fw.solve(&Options {
                tol: 0.0,
                max_iter: k,
                backward: BackwardMode::None,
                ..Default::default()
            });
            assert_eq!(sol.iters, k);
        }
    }

    #[test]
    fn warm_fixed_point_stops_immediately() {
        let fw = FwQp::new(simplex_qp(10, 1.0, 5), 1.0).unwrap();
        let cold = fw.solve(&tight());
        let ws = WarmStart::new(
            cold.x.clone(),
            cold.lam.clone(),
            cold.nu.clone(),
        );
        let warm = fw.solve_from(None, None, None, Some(&ws), &tight());
        assert!(warm.iters <= 2, "warm iters {}", warm.iters);
        for i in 0..10 {
            assert!((warm.x[i] - cold.x[i]).abs() < 1e-9);
        }
    }

    #[test]
    fn jacobian_b_matches_finite_difference_on_simplex() {
        let qp = simplex_qp(9, 1.0, 6);
        let fw = FwQp::new(qp.clone(), 1.0).unwrap();
        let opts = Options {
            backward: BackwardMode::Forward(Param::B),
            ..tight()
        };
        let jac = fw.solve(&opts).jacobian.unwrap();
        let eps = 1e-6;
        let fopts = Options { backward: BackwardMode::None, ..tight() };
        let bp = [qp.b[0] + eps];
        let bm = [qp.b[0] - eps];
        let xp = fw.solve_with(None, Some(&bp), None, &fopts).x;
        let xm = fw.solve_with(None, Some(&bm), None, &fopts).x;
        for i in 0..9 {
            let fd = (xp[i] - xm[i]) / (2.0 * eps);
            assert!(
                (jac[(i, 0)] - fd).abs() < 1e-4,
                "jac[({i},0)]={} fd={fd}",
                jac[(i, 0)]
            );
        }
    }

    #[test]
    fn vjp_matches_finite_difference_on_box() {
        let qp = box_qp(7, 13);
        let fw = FwQp::new(qp.clone(), 1.0).unwrap();
        let v: Vec<f64> = (0..7).map(|i| 0.4 * i as f64 - 1.0).collect();
        let out = fw.solve_vjp(None, None, None, &v, &tight());
        let eps = 1e-6;
        let loss = |q: &[f64], h: &[f64]| -> f64 {
            let fopts =
                Options { backward: BackwardMode::None, ..tight() };
            let x = fw.solve_with(Some(q), None, Some(h), &fopts).x;
            dot(&x, &v)
        };
        for j in 0..7 {
            let mut qp_ = qp.q.clone();
            qp_[j] += eps;
            let mut qm_ = qp.q.clone();
            qm_[j] -= eps;
            let fd =
                (loss(&qp_, &qp.h) - loss(&qm_, &qp.h)) / (2.0 * eps);
            assert!(
                (out.vjp.grad_q[j] - fd).abs() < 1e-4 * (1.0 + fd.abs()),
                "grad_q[{j}] got {} fd {fd}",
                out.vjp.grad_q[j]
            );
        }
        for j in 0..14 {
            let mut hp = qp.h.clone();
            hp[j] += eps;
            let mut hm = qp.h.clone();
            hm[j] -= eps;
            let fd =
                (loss(&qp.q, &hp) - loss(&qp.q, &hm)) / (2.0 * eps);
            assert!(
                (out.vjp.grad_h[j] - fd).abs() < 1e-4 * (1.0 + fd.abs()),
                "grad_h[{j}] got {} fd {fd}",
                out.vjp.grad_h[j]
            );
        }
    }

    #[test]
    fn vjp_seed_resumes_in_a_bounded_restart() {
        let qp = simplex_qp(8, 1.0, 4);
        let fw = FwQp::new(qp, 1.0).unwrap();
        let sol = fw.solve(&tight());
        let v = vec![0.5; 8];
        let (cold, seed) = fw.vjp_from(&sol.s, &v, None, &tight());
        let (warm, _) = fw.vjp_from(&sol.s, &v, Some(&seed), &tight());
        assert!(warm.iters <= 4, "seeded iters {}", warm.iters);
        for j in 0..8 {
            assert!((warm.grad_q[j] - cold.grad_q[j]).abs() < 1e-9);
        }
    }
}
