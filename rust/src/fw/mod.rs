//! Third engine family: projection-free Frank–Wolfe (conditional
//! gradient) for QP layers over vertex-friendly feasible sets.
//!
//! The paper's framework (§3) only needs an inner solver that exposes
//! truncated iterates; Alt-Diff's alternating updates and the ADMM
//! family both pay for a factorization of P + ρCᵀC and a projection per
//! iteration. On polytopes whose *vertices* are cheap to enumerate —
//! boxes, scaled simplices, ℓ1 balls (the DFWLayer regime from
//! PAPERS.md) — a linear minimization oracle (LMO) replaces both: each
//! iteration is one gradient, one LMO call, and an exact line search.
//! No Cholesky, no projection, iterates feasible by construction.
//!
//! | set | constraint | LMO(g) | tie rule |
//! |-----|------------|--------|----------|
//! | box | l ≤ x ≤ u (G = [I; −I], h = [u; −l]) | vᵢ = lᵢ if gᵢ > 0 else uᵢ | gᵢ = 0 → uᵢ |
//! | simplex | 1ᵀx = r, x ≥ 0 | r·eᵢ, i = argminᵢ gᵢ | smallest index |
//! | ℓ1 ball | ‖x‖₁ ≤ r (all 2ⁿ facets σᵀx ≤ r) | −r·sign(gⱼ)·eⱼ, j = argmaxⱼ \|gⱼ\| | smallest index, sign(0) → +1 → +r·eⱼ |
//!
//! - [`FwQp`]: single-problem engine. Forward = **away-step** FW
//!   (linear convergence on polytopes for strongly convex objectives —
//!   plain FW's O(1/k) could never hit the 1e-8 parity bar), truncated
//!   by the same ‖x_{k+1}−x_k‖/max(‖x_k‖,1) < tol criterion as every
//!   other family, so Thm 4.3's fixed-k semantics apply unchanged.
//!   Backward = dimension-free adjoint via a projected-CG solve of the
//!   slack-gated KKT system (`vjp`/`vjp_from`, O(n)
//!   [`FwSeed`](crate::warm::FwSeed) resume state).
//! - [`BatchedFw`]: the batch-major sibling. There is no cross-element
//!   factorization to amortize (the LMO walk is per-element state), so
//!   one launch advances all live elements in interleaved round-robin
//!   sweeps under a shared [`ActiveSet`](crate::batch::mask::ActiveSet)
//!   — converged elements deactivate and stop consuming budget (ragged
//!   truncation), and each element reproduces the single-engine
//!   iteration exactly (shared step code, bit-identical results).
//!
//! **Observer convention.** FW iterates are feasible by construction,
//! so the constraint-violation norm the other families report in the
//! `primal` slot of [`IterObserver`](crate::obs::IterObserver) is
//! identically ~0 and carries no information. The FW engines instead
//! report the **duality gap** g_k = ∇f(x_k)ᵀ(x_k − v_k) — the
//! conditional-gradient convergence certificate (f(x_k) − f* ≤ g_k) —
//! in the primal slot, and the iterate step ‖x_{k+1}−x_k‖ in the dual
//! slot. Sampled `/trace` series from FW solves therefore show the gap
//! decaying, which is exactly the evidence an operator needs to pick
//! the truncation rung k.

pub mod batch;
pub mod qp;

pub use batch::BatchedFw;
pub use qp::FwQp;

use crate::prob::Qp;

/// The vertex-enumerable feasible sets the FW engines serve, detected
/// structurally from a standard `(A, b, G, h)` QP description so the
/// same problem object feeds every engine family (parity oracles,
/// uniform registration).
#[derive(Clone, Debug, PartialEq)]
pub enum FeasibleSet {
    /// l ≤ x ≤ u, encoded G = [I; −I], h = [u; −l], no (or vacuous)
    /// equalities.
    Box {
        /// Lower bounds (length n), from −h[n..2n].
        l: Vec<f64>,
        /// Upper bounds (length n), from h[0..n].
        u: Vec<f64>,
    },
    /// 1ᵀx = r, x ≥ 0, encoded A = 1ᵀ, b = [r], G = −I, h = 0.
    Simplex {
        /// Simplex scale r > 0, from b[0].
        r: f64,
    },
    /// ‖x‖₁ ≤ r, encoded as all 2ⁿ facets σᵀx ≤ r, σ ∈ {±1}ⁿ, no (or
    /// vacuous) equalities.
    L1Ball {
        /// Ball radius r > 0, from h[0].
        r: f64,
    },
}

/// Rows-of-A-and-b-are-all-zero check: the vacuous-equality precedent
/// set by [`crate::prob::energy_qp`] (a 0ᵀx = 0 row added purely so the
/// uniform (A, b) interface holds).
fn vacuous_eq(qp: &Qp) -> bool {
    let p = qp.p_eq();
    if p == 0 {
        return true;
    }
    qp.b.iter().all(|&v| v == 0.0)
        && (0..p).all(|i| qp.a.row(i).iter().all(|&v| v == 0.0))
}

impl FeasibleSet {
    /// Structurally detect one of the supported vertex-enumerable sets
    /// from a standard QP description; `None` means the problem is not
    /// FW-servable (the router then simply never probes this family).
    ///
    /// Detection is exact-match on the canonical encodings produced by
    /// [`crate::prob::box_qp`], [`crate::prob::simplex_qp`], and
    /// [`crate::prob::l1_ball_qp`] (ℓ1 additionally caps n at 16: the
    /// facet description is 2ⁿ rows). The box shape is tried first, so
    /// the n = 1 encoding — where a box and an ℓ1 ball are the same
    /// interval — resolves deterministically.
    pub fn detect(qp: &Qp) -> Option<FeasibleSet> {
        let n = qp.n();
        let m = qp.m_ineq();
        if n == 0 {
            return None;
        }
        // box: G = [I; −I] with vacuous equalities
        if m == 2 * n && vacuous_eq(qp) {
            let mut is_box = true;
            'rows: for i in 0..n {
                for j in 0..n {
                    let up = if i == j { 1.0 } else { 0.0 };
                    if qp.g[(i, j)] != up || qp.g[(n + i, j)] != -up {
                        is_box = false;
                        break 'rows;
                    }
                }
            }
            if is_box {
                let u: Vec<f64> = qp.h[..n].to_vec();
                let l: Vec<f64> =
                    qp.h[n..].iter().map(|&v| -v).collect();
                if l.iter().zip(&u).all(|(&lo, &hi)| lo < hi) {
                    return Some(FeasibleSet::Box { l, u });
                }
            }
        }
        // simplex: A = 1ᵀ, b = [r > 0], G = −I, h = 0
        if qp.p_eq() == 1
            && m == n
            && qp.b[0] > 0.0
            && (0..n).all(|j| qp.a[(0, j)] == 1.0)
            && qp.h.iter().all(|&v| v == 0.0)
        {
            let diag = (0..n).all(|i| {
                (0..n).all(|j| {
                    qp.g[(i, j)] == if i == j { -1.0 } else { 0.0 }
                })
            });
            if diag {
                return Some(FeasibleSet::Simplex { r: qp.b[0] });
            }
        }
        // ℓ1 ball: every sign pattern σᵀx ≤ r exactly once
        if n <= 16
            && m == (1usize << n)
            && vacuous_eq(qp)
            && qp.h[0] > 0.0
            && qp.h.iter().all(|&v| v == qp.h[0])
        {
            let mut seen = vec![false; m];
            for row in 0..m {
                let mut mask = 0usize;
                for j in 0..n {
                    match qp.g[(row, j)] {
                        v if v == 1.0 => {}
                        v if v == -1.0 => mask |= 1 << j,
                        _ => return None,
                    }
                }
                if seen[mask] {
                    return None;
                }
                seen[mask] = true;
            }
            return Some(FeasibleSet::L1Ball { r: qp.h[0] });
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prob::{box_qp, dense_qp, l1_ball_qp, simplex_qp};

    #[test]
    fn detects_the_three_canonical_shapes() {
        match FeasibleSet::detect(&box_qp(7, 3)).unwrap() {
            FeasibleSet::Box { l, u } => {
                assert_eq!(l.len(), 7);
                assert!(l.iter().zip(&u).all(|(a, b)| a < b));
            }
            other => panic!("expected box, got {other:?}"),
        }
        assert_eq!(
            FeasibleSet::detect(&simplex_qp(9, 2.5, 1)),
            Some(FeasibleSet::Simplex { r: 2.5 })
        );
        assert_eq!(
            FeasibleSet::detect(&l1_ball_qp(6, 1.25, 2)),
            Some(FeasibleSet::L1Ball { r: 1.25 })
        );
    }

    #[test]
    fn rejects_general_polytopes() {
        assert_eq!(FeasibleSet::detect(&dense_qp(8, 4, 2, 3)), None);
        // a box with one bound flipped (l ≥ u) is not servable
        let mut qp = box_qp(4, 5);
        qp.h[0] = -qp.h[4] - 1.0;
        assert_eq!(FeasibleSet::detect(&qp), None);
        // an ℓ1 encoding with a duplicated facet row is rejected
        let mut qp = l1_ball_qp(4, 1.0, 6);
        for j in 0..4 {
            let v = qp.g[(0, j)];
            qp.g[(1, j)] = v;
        }
        assert_eq!(FeasibleSet::detect(&qp), None);
    }
}
