//! `altdiff` — CLI entrypoint for the optimization-layer server and tools.
//!
//! Subcommands:
//!   serve     run the coordinator; `--listen <addr>` serves it over TCP
//!             (otherwise runs a synthetic in-process trace); prints the
//!             Prometheus metrics text on exit
//!   loadgen   drive a running `serve --listen` server over loopback/TCP
//!             with pipelined clients, report p50/p99 round trips
//!   solve     solve + differentiate one random dense QP layer
//!   check     validate the artifact directory (manifest + compile)
//!   info      print build/layer-family information

use altdiff::altdiff::{BackwardMode, DenseAltDiff, Options, Param};
use altdiff::coordinator::{Config, Coordinator, Reply};
use altdiff::net::{
    ChaosConfig, ChaosProxy, Client, LoadgenOpts, NetConfig, NetServer,
};
use altdiff::prob::{dense_qp, simplex_qp, sparsemax_qp};
use altdiff::runtime::{Engine, Manifest};
use altdiff::util::{Args, Pcg64};
use std::path::PathBuf;
use std::time::{Duration, Instant};

fn artifacts_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.get_str("artifacts", "artifacts"))
}

fn cmd_info(args: &Args) {
    println!(
        "altdiff {} — Alt-Diff optimization-layer engine",
        env!("CARGO_PKG_VERSION")
    );
    let dir = artifacts_dir(args);
    match Manifest::load(&dir) {
        Ok(m) => {
            println!(
                "artifacts: {} variants in {}",
                m.variants.len(),
                dir.display()
            );
            for (n, mm, p) in m.sizes() {
                let ks: Vec<String> = m
                    .family(n, mm, p, 1)
                    .iter()
                    .map(|v| v.k.to_string())
                    .collect();
                println!(
                    "  size (n={n}, m={mm}, p={p}): k ladder [{}]",
                    ks.join(", ")
                );
            }
        }
        Err(e) => {
            println!("artifacts: unavailable ({e}) — native backend only")
        }
    }
}

fn cmd_check(args: &Args) -> altdiff::Result<()> {
    let dir = artifacts_dir(args);
    let mut eng = Engine::new(&dir)?;
    println!("platform: {}", eng.platform());
    let t0 = Instant::now();
    let n = eng.warmup()?;
    println!(
        "compiled {n} variants in {:.2}s — artifact directory OK",
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}

fn cmd_solve(args: &Args) {
    let n = args.get_usize("n", 100);
    let m = args.get_usize("m", n / 2);
    let p = args.get_usize("p", n / 5);
    let tol = args.get_f64("tol", 1e-3);
    let qp = dense_qp(n, m, p, args.get_usize("seed", 0) as u64);
    let t0 = Instant::now();
    let solver = DenseAltDiff::new(qp.clone(), args.get_f64("rho", 1.0))
        .expect("register");
    let t_reg = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let sol = solver.solve(&Options {
        tol,
        backward: BackwardMode::Forward(Param::B),
        ..Default::default()
    });
    let t_solve = t0.elapsed().as_secs_f64();
    let (eq, viol) = qp.feasibility(&sol.x);
    println!("n={n} m={m} p={p} tol={tol:.0e}");
    println!("register (factor H): {t_reg:.4}s");
    println!("solve+diff: {t_solve:.4}s, {} iterations", sol.iters);
    println!(
        "objective {:.6}, ‖Ax−b‖ {eq:.2e}, viol {viol:.2e}",
        qp.objective(&sol.x)
    );
    println!("jacobian ∂x/∂b: {}x{}", n, p);
}

/// Build the default serve-mode coordinator: two dense layer sizes
/// (matching the compiled-artifact family), a sparse sparsemax layer,
/// and a Frank–Wolfe simplex layer, so the wire exposes every native
/// backend.
fn serve_coordinator(args: &Args) -> Coordinator {
    let workers = args.get_usize("workers", 2);
    let dir = artifacts_dir(args);
    let artifacts = dir.join("manifest.tsv").exists().then_some(dir);
    println!(
        "serving with {} backend",
        if artifacts.is_some() { "pjrt+native" } else { "native" }
    );
    Coordinator::builder(Config {
        workers,
        max_batch: args.get_usize("max-batch", 8),
        // --batch-timeout-us is the primary knob; legacy --deadline-ms
        // still works when the new flag is absent
        batch_timeout_us: args.get_usize(
            "batch-timeout-us",
            args.get_usize("deadline-ms", 2) * 1000,
        ) as u64,
        shards: args.get_usize("shards", 1),
        shard_queue: args.get_usize("shard-queue", 1024),
        pin_cores: args.get_bool("pin-cores", false),
        artifacts,
        // --warm-cache N enables cross-request warm starts (0 = the
        // cold default); pair with a loadgen running --sessions
        warm_capacity: args.get_usize("warm-cache", 0),
        warm_radius: args.get_f64("warm-radius", 0.5),
        // --stamps turns on the per-request tracing plane (stage
        // stamps + histograms + reply echo); --trace-sample N promotes
        // 1-in-N requests to full convergence traces served at /trace
        stamps: args.get_bool("stamps", false),
        trace_every: args.get_usize("trace-sample", 0) as u64,
        trace_ring: args.get_usize("trace-ring", 256),
        trace_seed: args.get_usize("trace-seed", 0) as u64,
        ..Default::default()
    })
    // both dense layers use generator seed 1 so a default `loadgen`
    // (--seed 1) synthesizes θ feasible for either (dense_qp's b/h are
    // only feasible w.r.t. the same seed's A/G matrices)
    .register("qp16", dense_qp(16, 8, 4, 1), 1.0)
    .expect("register qp16")
    .register("qp64", dense_qp(64, 32, 12, 1), 1.0)
    .expect("register qp64")
    .register_sparse("smax40", sparsemax_qp(40, 7), 1.0)
    .expect("register smax40")
    // a simplex layer on the projection-free Frank–Wolfe family, so
    // the wire also exposes the "native-fw" backend
    .register_fw("simplex24", simplex_qp(24, 1.0, 1), 1.0)
    .expect("register simplex24")
    .start()
}

/// `serve --listen <addr>`: expose the coordinator over TCP until a
/// wire stop op arrives (or `--duration-secs` expires), then drain and
/// print the Prometheus metrics text. `--selftest` additionally runs
/// the load generator in-process against the bound port (works with
/// `--listen 127.0.0.1:0`) and stops the server when it finishes — a
/// one-invocation loopback round trip over solve + grad ops.
fn cmd_serve_net(args: &Args, listen: &str) {
    let coord = serve_coordinator(args);
    coord.wait_ready(Duration::from_secs(180));
    let cfg = NetConfig {
        max_inflight: args.get_usize("max-inflight", 256),
        max_conns: args.get_usize("max-conns", 128),
        ..Default::default()
    };
    let server = NetServer::bind(listen, coord, cfg)
        .expect("bind listen address");
    let addr = server.local_addr().expect("local addr");
    println!("listening on {addr} (stop via the wire stop op)");
    let duration = args.get_usize("duration-secs", 0);
    if duration > 0 {
        let stop = server.stop_handle();
        std::thread::spawn(move || {
            std::thread::sleep(Duration::from_secs(duration as u64));
            stop.store(true, std::sync::atomic::Ordering::SeqCst);
        });
    }
    let selftest_failed =
        std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
    if args.get_bool("selftest", false) {
        let opts = LoadgenOpts {
            requests: args.get_usize("requests", 200),
            ..Default::default()
        };
        let failed = selftest_failed.clone();
        std::thread::spawn(move || {
            match altdiff::net::run_loadgen(addr, &opts) {
                Ok(report) => println!("selftest: {}", report.render()),
                Err(e) => {
                    eprintln!("selftest loadgen failed: {e}");
                    failed
                        .store(true, std::sync::atomic::Ordering::SeqCst);
                }
            }
            if let Ok(mut c) = Client::connect(addr) {
                let _ = c.stop_server();
            }
        });
    }
    let coord = server.run();
    println!("{}", coord.metrics.render_text());
    if selftest_failed.load(std::sync::atomic::Ordering::SeqCst) {
        std::process::exit(1);
    }
}

fn cmd_serve(args: &Args) {
    let listen = args.get_str("listen", "");
    if !listen.is_empty() {
        return cmd_serve_net(args, &listen);
    }
    let nreq = args.get_usize("requests", 500);
    let mut coord = serve_coordinator(args);
    coord.wait_ready(Duration::from_secs(180));
    let qp = dense_qp(16, 8, 4, 1);
    let mut rng = Pcg64::new(0);
    let t0 = Instant::now();
    for _ in 0..nreq {
        let s = 1.0 + 0.1 * rng.normal();
        coord.submit(
            "qp16",
            qp.q.iter().map(|&v| v * s).collect(),
            qp.b.clone(),
            qp.h.clone(),
            [1e-1, 1e-2, 1e-3][rng.below(3)],
        );
    }
    let mut ok = 0;
    for _ in 0..nreq {
        match coord.recv_timeout(Duration::from_secs(60)) {
            Some(Reply::Ok(_)) => ok += 1,
            Some(Reply::Grad(_)) => ok += 1,
            Some(Reply::Err(f)) => eprintln!("fail: {}", f.error),
            None => break,
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    println!("{ok}/{nreq} in {wall:.3}s → {:.0} req/s", ok as f64 / wall);
    println!("{}", coord.metrics.render_text());
}

/// `loadgen <addr>`: drive a running `serve --listen` server.
/// `--chaos` interposes a seeded fault-injection proxy on the path
/// (pair it with `--retry` unless an aborted run is the point).
fn cmd_loadgen(args: &Args) {
    let Some(addr) = args.positional().get(1).cloned() else {
        eprintln!(
            "usage: altdiff loadgen <addr> [--requests N] [--clients C] \
             [--window W] [--grad-share F] [--layer NAME] [--tol T] \
             [--sessions] [--burst B] [--burst-gap-us G] \
             [--priorities] [--deadline-us D] [--stages] [--retry] \
             [--chaos] [--chaos-seed S] [--chaos-reset-prob P] \
             [--stop-server]"
        );
        std::process::exit(2);
    };
    let deadline_us = args.get_usize("deadline-us", 0);
    let opts = LoadgenOpts {
        requests: args.get_usize("requests", 200),
        clients: args.get_usize("clients", 4),
        window: args.get_usize("window", 8),
        grad_share: args.get_f64("grad-share", 0.25),
        layer: args.get_str("layer", ""),
        tol: args.get_f64("tol", 1e-3),
        seed: args.get_usize("seed", 1) as u64,
        sessions: args.get_bool("sessions", false),
        burst: args.get_usize("burst", 0),
        burst_gap_us: args.get_usize("burst-gap-us", 2_000) as u64,
        priorities: args.get_bool("priorities", false),
        deadline_us: (deadline_us > 0).then_some(deadline_us as u32),
        stages: args.get_bool("stages", false),
        retry: args.get_bool("retry", false),
    };
    // with --chaos, clients talk to the fault proxy; the real server
    // address stays in `addr` for --stop-server's direct connection
    let proxy = args.get_bool("chaos", false).then(|| {
        let cfg = ChaosConfig {
            seed: args.get_usize("chaos-seed", 5) as u64,
            reset_prob: args.get_f64("chaos-reset-prob", 0.0),
            ..ChaosConfig::default()
        };
        ChaosProxy::spawn(addr.as_str(), cfg).unwrap_or_else(|e| {
            eprintln!("chaos proxy failed to start: {e}");
            std::process::exit(1);
        })
    });
    let target = proxy
        .as_ref()
        .map(|p| p.addr().to_string())
        .unwrap_or_else(|| addr.clone());
    match altdiff::net::run_loadgen(target.as_str(), &opts) {
        Ok(report) => {
            println!("{}", report.render());
            if let Some(p) = &proxy {
                println!("{}", p.stats().render());
            }
            if args.get_bool("stop-server", false) {
                match Client::connect(addr.as_str())
                    .and_then(|mut c| c.stop_server())
                {
                    Ok(stats) => {
                        println!("\nserver final metrics:\n{stats}")
                    }
                    Err(e) => eprintln!("stop-server failed: {e}"),
                }
            }
        }
        Err(e) => {
            eprintln!("loadgen failed: {e}");
            std::process::exit(1);
        }
    }
}

fn main() {
    let args = Args::parse();
    let cmd = args
        .positional()
        .first()
        .map(String::as_str)
        .unwrap_or("info");
    match cmd {
        "info" => cmd_info(&args),
        "check" => {
            if let Err(e) = cmd_check(&args) {
                eprintln!("check failed: {e}");
                std::process::exit(1);
            }
        }
        "solve" => cmd_solve(&args),
        "serve" => cmd_serve(&args),
        "loadgen" => cmd_loadgen(&args),
        other => {
            eprintln!("unknown command '{other}'");
            eprintln!(
                "usage: altdiff [info|check|solve|serve|loadgen] \
                 [--key value]"
            );
            std::process::exit(2);
        }
    }
}
