//! `altdiff` — CLI entrypoint for the optimization-layer server and tools.
//!
//! Subcommands:
//!   serve     run the coordinator on a synthetic trace and print metrics
//!   solve     solve + differentiate one random dense QP layer
//!   check     validate the artifact directory (manifest + compile)
//!   info      print build/layer-family information

use altdiff::altdiff::{BackwardMode, DenseAltDiff, Options, Param};
use altdiff::coordinator::{Config, Coordinator, Reply};
use altdiff::prob::dense_qp;
use altdiff::runtime::{Engine, Manifest};
use altdiff::util::{Args, Pcg64};
use std::path::PathBuf;
use std::time::{Duration, Instant};

fn artifacts_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.get_str("artifacts", "artifacts"))
}

fn cmd_info(args: &Args) {
    println!(
        "altdiff {} — Alt-Diff optimization-layer engine",
        env!("CARGO_PKG_VERSION")
    );
    let dir = artifacts_dir(args);
    match Manifest::load(&dir) {
        Ok(m) => {
            println!(
                "artifacts: {} variants in {}",
                m.variants.len(),
                dir.display()
            );
            for (n, mm, p) in m.sizes() {
                let ks: Vec<String> = m
                    .family(n, mm, p, 1)
                    .iter()
                    .map(|v| v.k.to_string())
                    .collect();
                println!(
                    "  size (n={n}, m={mm}, p={p}): k ladder [{}]",
                    ks.join(", ")
                );
            }
        }
        Err(e) => {
            println!("artifacts: unavailable ({e}) — native backend only")
        }
    }
}

fn cmd_check(args: &Args) -> altdiff::Result<()> {
    let dir = artifacts_dir(args);
    let mut eng = Engine::new(&dir)?;
    println!("platform: {}", eng.platform());
    let t0 = Instant::now();
    let n = eng.warmup()?;
    println!(
        "compiled {n} variants in {:.2}s — artifact directory OK",
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}

fn cmd_solve(args: &Args) {
    let n = args.get_usize("n", 100);
    let m = args.get_usize("m", n / 2);
    let p = args.get_usize("p", n / 5);
    let tol = args.get_f64("tol", 1e-3);
    let qp = dense_qp(n, m, p, args.get_usize("seed", 0) as u64);
    let t0 = Instant::now();
    let solver = DenseAltDiff::new(qp.clone(), args.get_f64("rho", 1.0))
        .expect("register");
    let t_reg = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let sol = solver.solve(&Options {
        tol,
        backward: BackwardMode::Forward(Param::B),
        ..Default::default()
    });
    let t_solve = t0.elapsed().as_secs_f64();
    let (eq, viol) = qp.feasibility(&sol.x);
    println!("n={n} m={m} p={p} tol={tol:.0e}");
    println!("register (factor H): {t_reg:.4}s");
    println!("solve+diff: {t_solve:.4}s, {} iterations", sol.iters);
    println!(
        "objective {:.6}, ‖Ax−b‖ {eq:.2e}, viol {viol:.2e}",
        qp.objective(&sol.x)
    );
    println!("jacobian ∂x/∂b: {}x{}", n, p);
}

fn cmd_serve(args: &Args) {
    let nreq = args.get_usize("requests", 500);
    let workers = args.get_usize("workers", 2);
    let dir = artifacts_dir(args);
    let artifacts = dir.join("manifest.tsv").exists().then_some(dir);
    println!(
        "serving with {} backend",
        if artifacts.is_some() { "pjrt+native" } else { "native" }
    );
    let qp = dense_qp(16, 8, 4, 1);
    let mut coord = Coordinator::builder(Config {
        workers,
        max_batch: args.get_usize("max-batch", 8),
        batch_deadline: Duration::from_millis(
            args.get_usize("deadline-ms", 2) as u64,
        ),
        artifacts,
        ..Default::default()
    })
    .register("qp16", qp.clone(), 1.0)
    .expect("register")
    .start();
    coord.wait_ready(Duration::from_secs(180));
    let mut rng = Pcg64::new(0);
    let t0 = Instant::now();
    for _ in 0..nreq {
        let s = 1.0 + 0.1 * rng.normal();
        coord.submit(
            "qp16",
            qp.q.iter().map(|&v| v * s).collect(),
            qp.b.clone(),
            qp.h.clone(),
            [1e-1, 1e-2, 1e-3][rng.below(3)],
        );
    }
    let mut ok = 0;
    for _ in 0..nreq {
        match coord.recv_timeout(Duration::from_secs(60)) {
            Some(Reply::Ok(_)) => ok += 1,
            Some(Reply::Grad(_)) => ok += 1,
            Some(Reply::Err(f)) => eprintln!("fail: {}", f.error),
            None => break,
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    println!("{ok}/{nreq} in {wall:.3}s → {:.0} req/s", ok as f64 / wall);
    println!("{}", coord.metrics.summary());
}

fn main() {
    let args = Args::parse();
    let cmd = args
        .positional()
        .first()
        .map(String::as_str)
        .unwrap_or("info");
    match cmd {
        "info" => cmd_info(&args),
        "check" => {
            if let Err(e) = cmd_check(&args) {
                eprintln!("check failed: {e}");
                std::process::exit(1);
            }
        }
        "solve" => cmd_solve(&args),
        "serve" => cmd_serve(&args),
        other => {
            eprintln!("unknown command '{other}'");
            eprintln!(
                "usage: altdiff [info|check|solve|serve] [--key value]"
            );
            std::process::exit(2);
        }
    }
}
