//! Adam optimizer (Kingma & Ba, 2014) — the optimizer both end-to-end
//! experiments in the paper use.

/// Adam state for a flat list of parameter tensors.
pub struct Adam {
    /// Learning rate.
    pub lr: f64,
    /// First-moment decay β₁.
    pub beta1: f64,
    /// Second-moment decay β₂.
    pub beta2: f64,
    /// Denominator fuzz ε.
    pub eps: f64,
    m: Vec<Vec<f64>>,
    v: Vec<Vec<f64>>,
    t: u64,
}

impl Adam {
    /// Fresh state with the standard (0.9, 0.999, 1e-8) moments.
    pub fn new(lr: f64) -> Self {
        Adam {
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            m: Vec::new(),
            v: Vec::new(),
            t: 0,
        }
    }

    /// Apply one update to a list of (param, grad) pairs. The list's
    /// length and per-tensor sizes must be stable across calls.
    pub fn step(&mut self, params_grads: &mut [(&mut [f64], &[f64])]) {
        if self.m.is_empty() {
            for (p, _) in params_grads.iter() {
                self.m.push(vec![0.0; p.len()]);
                self.v.push(vec![0.0; p.len()]);
            }
        }
        assert_eq!(self.m.len(), params_grads.len(), "param group changed");
        self.t += 1;
        let b1t = 1.0 - self.beta1.powi(self.t as i32);
        let b2t = 1.0 - self.beta2.powi(self.t as i32);
        for (idx, (p, g)) in params_grads.iter_mut().enumerate() {
            let m = &mut self.m[idx];
            let v = &mut self.v[idx];
            for i in 0..p.len() {
                m[i] = self.beta1 * m[i] + (1.0 - self.beta1) * g[i];
                v[i] = self.beta2 * v[i] + (1.0 - self.beta2) * g[i] * g[i];
                let mhat = m[i] / b1t;
                let vhat = v[i] / b2t;
                p[i] -= self.lr * mhat / (vhat.sqrt() + self.eps);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adam_minimizes_quadratic() {
        // min (x-3)² — should converge to 3
        let mut x = vec![0.0f64];
        let mut opt = Adam::new(0.1);
        for _ in 0..500 {
            let g = vec![2.0 * (x[0] - 3.0)];
            let mut pg: Vec<(&mut [f64], &[f64])> =
                vec![(x.as_mut_slice(), g.as_slice())];
            opt.step(&mut pg);
        }
        assert!((x[0] - 3.0).abs() < 1e-3, "x={}", x[0]);
    }

    #[test]
    fn first_step_magnitude_is_lr() {
        // Adam's first step is ~lr in the gradient direction.
        let mut x = vec![10.0f64];
        let mut opt = Adam::new(0.05);
        let g = vec![123.0];
        let mut pg: Vec<(&mut [f64], &[f64])> =
            vec![(x.as_mut_slice(), g.as_slice())];
        opt.step(&mut pg);
        assert!((x[0] - (10.0 - 0.05)).abs() < 1e-6);
    }
}
