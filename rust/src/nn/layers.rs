//! Dense layers with manual reverse-mode gradients.

use crate::linalg::{gemv, gemv_t, Mat};
use crate::util::rng::Pcg64;

/// Fully-connected layer y = W x + b with cached input for backward.
pub struct Linear {
    /// Weights (out, in).
    pub w: Mat,
    /// Bias (out).
    pub b: Vec<f64>,
    /// Accumulated weight gradient.
    pub gw: Mat,
    /// Accumulated bias gradient.
    pub gb: Vec<f64>,
    last_x: Vec<f64>,
}

impl Linear {
    /// He initialization.
    pub fn new(inp: usize, out: usize, rng: &mut Pcg64) -> Self {
        let scale = (2.0 / inp as f64).sqrt();
        let data: Vec<f64> =
            (0..out * inp).map(|_| rng.normal() * scale).collect();
        Linear {
            w: Mat::from_vec(out, inp, data),
            b: vec![0.0; out],
            gw: Mat::zeros(out, inp),
            gb: vec![0.0; out],
            last_x: vec![0.0; inp],
        }
    }

    /// y = W x + b, caching x for backward.
    pub fn forward(&mut self, x: &[f64]) -> Vec<f64> {
        self.last_x = x.to_vec();
        let mut y = gemv(&self.w, x);
        for (yi, bi) in y.iter_mut().zip(&self.b) {
            *yi += bi;
        }
        y
    }

    /// Accumulate parameter grads; return dL/dx.
    pub fn backward(&mut self, gy: &[f64]) -> Vec<f64> {
        for i in 0..self.w.rows {
            self.gb[i] += gy[i];
            let row = self.gw.row_mut(i);
            for j in 0..row.len() {
                row[j] += gy[i] * self.last_x[j];
            }
        }
        gemv_t(&self.w, gy)
    }

    /// Reset accumulated gradients to zero.
    pub fn zero_grad(&mut self) {
        self.gw.data.iter_mut().for_each(|v| *v = 0.0);
        self.gb.iter_mut().for_each(|v| *v = 0.0);
    }

    /// (param, grad) pairs in optimizer order.
    pub fn params_grads(&mut self) -> Vec<(&mut [f64], &[f64])> {
        vec![
            (self.w.data.as_mut_slice(), self.gw.data.as_slice()),
            (self.b.as_mut_slice(), self.gb.as_slice()),
        ]
    }
}

/// ReLU with cached mask.
#[derive(Default)]
pub struct Relu {
    mask: Vec<bool>,
}

impl Relu {
    /// max(x, 0), caching the activation mask.
    pub fn forward(&mut self, x: &[f64]) -> Vec<f64> {
        self.mask = x.iter().map(|&v| v > 0.0).collect();
        x.iter().map(|&v| v.max(0.0)).collect()
    }

    /// Gate the upstream gradient by the cached mask.
    pub fn backward(&self, gy: &[f64]) -> Vec<f64> {
        gy.iter()
            .zip(&self.mask)
            .map(|(&g, &m)| if m { g } else { 0.0 })
            .collect()
    }
}

/// MLP: Linear→ReLU stack with a final Linear.
pub struct Mlp {
    /// The linear layers, first to last.
    pub layers: Vec<Linear>,
    relus: Vec<Relu>,
}

impl Mlp {
    /// dims = [in, h1, ..., out]
    pub fn new(dims: &[usize], rng: &mut Pcg64) -> Self {
        assert!(dims.len() >= 2);
        let mut layers = Vec::new();
        let mut relus = Vec::new();
        for w in dims.windows(2) {
            layers.push(Linear::new(w[0], w[1], rng));
            relus.push(Relu::default());
        }
        relus.pop(); // no activation after the last layer
        Mlp { layers, relus }
    }

    /// Forward through every Linear(+ReLU) stage.
    pub fn forward(&mut self, x: &[f64]) -> Vec<f64> {
        let mut h = x.to_vec();
        let nl = self.layers.len();
        for i in 0..nl {
            h = self.layers[i].forward(&h);
            if i < self.relus.len() {
                h = self.relus[i].forward(&h);
            }
        }
        h
    }

    /// Reverse pass; accumulates layer grads, returns dL/dx.
    pub fn backward(&mut self, gy: &[f64]) -> Vec<f64> {
        let mut g = gy.to_vec();
        let nl = self.layers.len();
        for i in (0..nl).rev() {
            if i < self.relus.len() {
                g = self.relus[i].backward(&g);
            }
            g = self.layers[i].backward(&g);
        }
        g
    }

    /// Reset every layer's accumulated gradients.
    pub fn zero_grad(&mut self) {
        for l in &mut self.layers {
            l.zero_grad();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_forward_known_values() {
        let mut rng = Pcg64::new(0);
        let mut l = Linear::new(2, 2, &mut rng);
        l.w = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        l.b = vec![0.5, -0.5];
        let y = l.forward(&[1.0, 1.0]);
        assert_eq!(y, vec![3.5, 6.5]);
    }

    #[test]
    fn linear_gradcheck() {
        let mut rng = Pcg64::new(1);
        let mut l = Linear::new(3, 2, &mut rng);
        let x = [0.3, -0.8, 0.5];
        // L = sum(y); dL/dW_ij = x_j, dL/db = 1, dL/dx_j = sum_i W_ij
        let _ = l.forward(&x);
        let gx = l.backward(&[1.0, 1.0]);
        for j in 0..3 {
            assert!((l.gw[(0, j)] - x[j]).abs() < 1e-12);
            let want = l.w[(0, j)] + l.w[(1, j)];
            assert!((gx[j] - want).abs() < 1e-12);
        }
        assert_eq!(l.gb, vec![1.0, 1.0]);
    }

    #[test]
    fn mlp_gradcheck_fd() {
        let mut rng = Pcg64::new(2);
        let mut net = Mlp::new(&[4, 6, 3], &mut rng);
        let x: Vec<f64> = rng.normal_vec(4);
        // L = 0.5 sum y²
        let y = net.forward(&x);
        let gy: Vec<f64> = y.clone();
        net.zero_grad();
        let _ = net.backward(&gy);
        // FD check on first layer's first weight
        let eps = 1e-6;
        let lossf = |net: &mut Mlp, x: &[f64]| -> f64 {
            let y = net.forward(x);
            0.5 * y.iter().map(|v| v * v).sum::<f64>()
        };
        for (i, j) in [(0usize, 0usize), (2, 3), (5, 1)] {
            let saved = net.layers[0].w[(i, j)];
            net.layers[0].w[(i, j)] = saved + eps;
            let lp = lossf(&mut net, &x);
            net.layers[0].w[(i, j)] = saved - eps;
            let lm = lossf(&mut net, &x);
            net.layers[0].w[(i, j)] = saved;
            let fd = (lp - lm) / (2.0 * eps);
            let got = net.layers[0].gw[(i, j)];
            assert!(
                (got - fd).abs() < 1e-5 * (1.0 + fd.abs()),
                "gw[{i},{j}]={got} fd={fd}"
            );
        }
    }

    #[test]
    fn relu_masks() {
        let mut r = Relu::default();
        let y = r.forward(&[-1.0, 2.0, 0.0]);
        assert_eq!(y, vec![0.0, 2.0, 0.0]);
        let g = r.backward(&[1.0, 1.0, 1.0]);
        assert_eq!(g, vec![0.0, 1.0, 0.0]);
    }
}
