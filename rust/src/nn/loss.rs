//! Loss functions with analytic gradients.

/// Mean-squared-error ½Σ(pred−target)² (paper eq. 13 uses this form).
/// Returns (loss, dL/dpred).
pub fn mse_loss(pred: &[f64], target: &[f64]) -> (f64, Vec<f64>) {
    assert_eq!(pred.len(), target.len());
    let mut loss = 0.0;
    let grad: Vec<f64> = pred
        .iter()
        .zip(target)
        .map(|(&p, &t)| {
            let d = p - t;
            loss += 0.5 * d * d;
            d
        })
        .collect();
    (loss, grad)
}

/// Softmax + negative log likelihood for one example.
/// Returns (loss, dL/dlogits).
pub fn softmax_nll(logits: &[f64], label: usize) -> (f64, Vec<f64>) {
    let mx = logits.iter().cloned().fold(f64::MIN, f64::max);
    let exps: Vec<f64> = logits.iter().map(|&v| (v - mx).exp()).collect();
    let z: f64 = exps.iter().sum();
    let probs: Vec<f64> = exps.iter().map(|&e| e / z).collect();
    let loss = -probs[label].max(1e-300).ln();
    let grad: Vec<f64> = probs
        .iter()
        .enumerate()
        .map(|(i, &p)| if i == label { p - 1.0 } else { p })
        .collect();
    (loss, grad)
}

/// argmax helper for accuracy computation.
pub fn argmax(v: &[f64]) -> usize {
    v.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mse_known() {
        let (l, g) = mse_loss(&[1.0, 2.0], &[0.0, 0.0]);
        assert!((l - 2.5).abs() < 1e-12);
        assert_eq!(g, vec![1.0, 2.0]);
    }

    #[test]
    fn nll_gradient_sums_to_zero_and_fd() {
        let logits = vec![0.2, -0.5, 1.3];
        let (_, g) = softmax_nll(&logits, 2);
        assert!(g.iter().sum::<f64>().abs() < 1e-12);
        let eps = 1e-6;
        for i in 0..3 {
            let mut lp = logits.clone();
            lp[i] += eps;
            let mut lm = logits.clone();
            lm[i] -= eps;
            let fd = (softmax_nll(&lp, 2).0 - softmax_nll(&lm, 2).0)
                / (2.0 * eps);
            assert!((g[i] - fd).abs() < 1e-6, "i={i}");
        }
    }

    #[test]
    fn nll_confident_correct_is_small() {
        let (l, _) = softmax_nll(&[10.0, 0.0, 0.0], 0);
        assert!(l < 1e-3);
        let (l2, _) = softmax_nll(&[10.0, 0.0, 0.0], 1);
        assert!(l2 > 5.0);
    }

    #[test]
    fn argmax_works() {
        assert_eq!(argmax(&[0.1, 0.9, 0.5]), 1);
    }
}
