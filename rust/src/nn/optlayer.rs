//! The optimization layer as a network module (paper Definition 3.1).
//!
//! Forward: x* = argmin ½xᵀPx + qᵀx s.t. Ax=b, Gx≤h with q supplied by the
//! previous layer. Backward: dL/dq = (∂x*/∂q)ᵀ dL/dx*, computed either by
//! Alt-Diff (the paper) or by IPM + implicit KKT differentiation (the
//! OptNet baseline) — switchable so Table 6 can compare both inside the
//! identical network.

use crate::altdiff::{DenseAltDiff, Options, Param};
use crate::baselines;
use crate::error::Result;
use crate::linalg::{gemv_t, Mat};
use crate::prob::Qp;

/// Which differentiation engine backs the layer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OptBackend {
    /// Alt-Diff with the given truncation tolerance.
    AltDiff,
    /// OptNet semantics: interior point + KKT implicit differentiation.
    OptNetKkt,
}

/// Optimization layer with fixed structure (P, A, b, G, h); input is q.
pub struct OptLayer {
    solver: DenseAltDiff,
    pub backend: OptBackend,
    pub tol: f64,
    /// cached ∂x/∂q from the last forward (n×n)
    last_jac: Option<Mat>,
    /// iterations used by the last forward (metrics)
    pub last_iters: usize,
}

impl OptLayer {
    pub fn new(qp: Qp, rho: f64, backend: OptBackend, tol: f64)
        -> Result<Self>
    {
        Ok(OptLayer {
            solver: DenseAltDiff::new(qp, rho)?,
            backend,
            tol,
            last_jac: None,
            last_iters: 0,
        })
    }

    pub fn n(&self) -> usize {
        self.solver.qp.n()
    }

    /// Forward: solve with the supplied q, cache ∂x/∂q for backward.
    pub fn forward(&mut self, q: &[f64]) -> Vec<f64> {
        match self.backend {
            OptBackend::AltDiff => {
                let sol = self.solver.solve_with(
                    Some(q),
                    None,
                    None,
                    &Options {
                        tol: self.tol,
                        max_iter: 20_000,
                        jacobian: Some(Param::Q),
                        ..Default::default()
                    },
                );
                self.last_iters = sol.iters;
                self.last_jac = sol.jacobian;
                sol.x
            }
            OptBackend::OptNetKkt => {
                let mut qp = self.solver.qp.clone();
                qp.q = q.to_vec();
                let (x, j, iters) =
                    baselines::optnet_layer(&qp, Param::Q, self.tol * 1e-3)
                        .expect("optnet layer");
                self.last_iters = iters;
                self.last_jac = Some(j);
                x
            }
        }
    }

    /// Backward: dL/dq = Jᵀ · dL/dx.
    pub fn backward(&self, gx: &[f64]) -> Vec<f64> {
        let j = self
            .last_jac
            .as_ref()
            .expect("backward before forward");
        gemv_t(j, gx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prob::dense_qp;

    fn layer(backend: OptBackend) -> OptLayer {
        OptLayer::new(dense_qp(10, 5, 2, 31), 1.0, backend, 1e-8).unwrap()
    }

    #[test]
    fn forward_matches_between_backends() {
        let mut a = layer(OptBackend::AltDiff);
        let mut b = layer(OptBackend::OptNetKkt);
        let q: Vec<f64> = (0..10).map(|i| 0.1 * i as f64 - 0.4).collect();
        let xa = a.forward(&q);
        let xb = b.forward(&q);
        for i in 0..10 {
            assert!(
                (xa[i] - xb[i]).abs() < 1e-4,
                "x[{i}]: altdiff {} optnet {}",
                xa[i],
                xb[i]
            );
        }
    }

    #[test]
    fn backward_matches_between_backends() {
        let mut a = layer(OptBackend::AltDiff);
        let mut b = layer(OptBackend::OptNetKkt);
        let q: Vec<f64> = (0..10).map(|i| 0.05 * i as f64).collect();
        let _ = a.forward(&q);
        let _ = b.forward(&q);
        let gx: Vec<f64> = (0..10).map(|i| 1.0 - 0.1 * i as f64).collect();
        let ga = a.backward(&gx);
        let gb = b.backward(&gx);
        let cos = crate::linalg::cosine(&ga, &gb);
        assert!(cos > 0.999, "cosine {cos}");
    }

    #[test]
    fn backward_matches_loss_finite_difference() {
        // L(q) = sum x*(q); check dL/dq by FD through the solver.
        let mut l = layer(OptBackend::AltDiff);
        let q: Vec<f64> = (0..10).map(|i| -0.2 + 0.07 * i as f64).collect();
        let _x = l.forward(&q);
        let g = l.backward(&vec![1.0; 10]);
        let eps = 1e-5;
        for c in [0usize, 3, 9] {
            let mut qp = q.clone();
            qp[c] += eps;
            let mut qm = q.clone();
            qm[c] -= eps;
            let lp: f64 = l.forward(&qp).iter().sum();
            let lm: f64 = l.forward(&qm).iter().sum();
            let fd = (lp - lm) / (2.0 * eps);
            assert!(
                (g[c] - fd).abs() < 1e-3 * (1.0 + fd.abs()),
                "g[{c}]={} fd={fd}",
                g[c]
            );
        }
    }
}
