//! The optimization layer as a network module (paper Definition 3.1).
//!
//! Forward: x* = argmin ½xᵀPx + qᵀx s.t. Ax=b, Gx≤h with q supplied by the
//! previous layer. Backward: dL/dq = (∂x*/∂q)ᵀ dL/dx*, computed either by
//! Alt-Diff (the paper) or by IPM + implicit KKT differentiation (the
//! OptNet baseline) — switchable so Table 6 can compare both inside the
//! identical network.
//!
//! The Alt-Diff backend runs **reverse mode**: forward solves carry no
//! Jacobian state (only the final slack, whose sign pattern gates the
//! adjoint recursion), and `backward*` iterates the transposed
//! recursion for the incoming dL/dx* — per-sample state is O(n) instead
//! of the O(n·d) cached Jacobian, and a minibatch backward is ONE
//! batched adjoint launch. The OptNet baseline keeps its cached
//! Jacobians (KKT differentiation produces them as a byproduct).
//!
//! Layers come in two structural flavours sharing one interface: dense
//! ([`OptLayer::new`], Table 2 structure) and sparse
//! ([`OptLayer::new_sparse`], Table 4 structure — diagonal P, CSR
//! constraints, e.g. a constrained-sparsemax output layer). Minibatch
//! forwards route through the matching batched engine
//! ([`BatchedAltDiff`] / [`BatchedSparseAltDiff`]): B samples per launch.
//!
//! A third backend, [`OptBackend::Admm`], swaps in the second engine
//! family ([`AdmmQp`] / [`BatchedAdmm`]) behind the identical module
//! interface — same reverse-mode contract (slack-gated adjoint, no
//! materialized Jacobians), with registration-time ρ balancing for
//! ill-conditioned layer structures (see DESIGN.md §6).
//!
//! A fourth backend, [`OptBackend::Fw`], swaps in the projection-free
//! Frank–Wolfe family ([`FwQp`] / [`BatchedFw`]) for layers whose
//! constraint block encodes a servable LMO structure (box / simplex /
//! ℓ1 ball) — e.g. a simplex-constrained attention or portfolio layer.
//! Same reverse-mode contract; registration fails fast when the
//! structure is not recognized.

use crate::admm::{AdmmQp, AdmmSettings, BatchedAdmm};
use crate::altdiff::{DenseAltDiff, Options, Param, SparseAltDiff};
use crate::baselines;
use crate::batch::{BatchedAltDiff, BatchedSparseAltDiff};
use crate::error::Result;
use crate::fw::{BatchedFw, FwQp};
use crate::linalg::{gemv_t, Mat};
use crate::prob::{Qp, SparseQp};
use crate::warm::{
    fingerprint, EngineFamily, EngineSeed, WarmStart, WarmStartCache,
};

/// Cache-layer name the optimization layer files its warm entries
/// under (it owns its cache, so the name only has to be stable).
const WARM_LAYER: &str = "opt";

/// Which differentiation engine backs the layer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OptBackend {
    /// Alt-Diff with the given truncation tolerance.
    AltDiff,
    /// OptNet semantics: interior point + KKT implicit differentiation.
    OptNetKkt,
    /// Consensus-form ADMM (the second engine family): same truncation
    /// and reverse-mode contracts as Alt-Diff, with ρ residual-balanced
    /// once at registration.
    Admm,
    /// Projection-free away-step Frank–Wolfe (the third engine family):
    /// same truncation and reverse-mode contracts, restricted to layers
    /// whose constraint block encodes a box / simplex / ℓ1-ball LMO.
    Fw,
}

/// Structure-specific solver pair: the sequential engine plus the
/// batched engine sharing its registration.
enum LayerSolver {
    Dense {
        solver: DenseAltDiff,
        /// minibatches; only built for the Alt-Diff backend — OptNet
        /// has no batched path
        batched: Option<BatchedAltDiff>,
    },
    Sparse {
        solver: SparseAltDiff,
        batched: BatchedSparseAltDiff,
    },
    Admm {
        solver: AdmmQp,
        batched: BatchedAdmm,
    },
    Fw {
        solver: FwQp,
        batched: BatchedFw,
    },
}

/// Optimization layer with fixed structure (P, A, b, G, h); input is q.
pub struct OptLayer {
    solver: LayerSolver,
    /// Differentiation engine behind [`Self::forward`].
    pub backend: OptBackend,
    /// Truncation tolerance (paper §4.3).
    pub tol: f64,
    /// cached ∂x/∂q from the last forward — OptNet backend only
    last_jac: Option<Mat>,
    /// cached per-element ∂x/∂q from the last `forward_batch` — OptNet
    /// backend only (the Alt-Diff backend never materializes Jacobians)
    last_jacs: Vec<Mat>,
    /// final slack of the last Alt-Diff forward (adjoint gate pattern)
    last_slack: Option<Vec<f64>>,
    /// per-element final slacks from the last Alt-Diff `forward_batch`
    last_slacks: Vec<Vec<f64>>,
    /// iterations used by the last forward (metrics; mean over the batch
    /// after `forward_batch`)
    pub last_iters: usize,
    /// per-element iterations from the last `forward_batch`
    pub last_batch_iters: Vec<usize>,
    /// warm-start cache for [`Self::forward_batch_keyed`] (None until
    /// [`Self::enable_warm_start`]; Alt-Diff backend only)
    warm: Option<WarmStartCache>,
    /// sample keys of the last keyed forward (pairs its backward)
    last_keys: Vec<u64>,
    /// θ of the last keyed forward (cache write-backs record it)
    last_qs: Vec<Vec<f64>>,
    /// adjoint seeds recalled alongside the last keyed forward's warm
    /// iterates — the backward resumes from them (engine-tagged; a seed
    /// of the other family is never consumed)
    last_seeds: Vec<Option<EngineSeed>>,
    /// converged iterates of the last keyed forward (the backward's
    /// cache write-back pairs them with fresh adjoint seeds)
    last_warm_out: Vec<WarmStart>,
}

impl OptLayer {
    /// Register a dense QP layer. [`OptBackend::Admm`] builds the
    /// second engine family instead of the Alt-Diff pair, with ρ
    /// residual-balanced once here ([`AdmmQp::new_adapted`]).
    pub fn new(qp: Qp, rho: f64, backend: OptBackend, tol: f64)
        -> Result<Self>
    {
        let solver = if backend == OptBackend::Admm {
            let solver =
                AdmmQp::new_adapted(qp, rho, AdmmSettings::default())?;
            let batched = BatchedAdmm::from_single(&solver);
            LayerSolver::Admm { solver, batched }
        } else if backend == OptBackend::Fw {
            let solver = FwQp::new(qp, rho)?;
            let batched = BatchedFw::from_single(&solver);
            LayerSolver::Fw { solver, batched }
        } else {
            let solver = DenseAltDiff::new(qp, rho)?;
            let batched = (backend == OptBackend::AltDiff)
                .then(|| BatchedAltDiff::from_dense(&solver));
            LayerSolver::Dense { solver, batched }
        };
        Ok(OptLayer {
            solver,
            backend,
            tol,
            last_jac: None,
            last_jacs: Vec::new(),
            last_slack: None,
            last_slacks: Vec::new(),
            last_iters: 0,
            last_batch_iters: Vec::new(),
            warm: None,
            last_keys: Vec::new(),
            last_qs: Vec::new(),
            last_seeds: Vec::new(),
            last_warm_out: Vec::new(),
        })
    }

    /// Register a sparse QP layer (diagonal P, CSR constraints — the
    /// Table 4 structure). Always Alt-Diff: the OptNet baseline has no
    /// sparse KKT path.
    pub fn new_sparse(qp: SparseQp, rho: f64, tol: f64) -> Result<Self> {
        let solver = SparseAltDiff::new(qp, rho)?;
        let batched = BatchedSparseAltDiff::from_sparse(&solver);
        Ok(OptLayer {
            solver: LayerSolver::Sparse { solver, batched },
            backend: OptBackend::AltDiff,
            tol,
            last_jac: None,
            last_jacs: Vec::new(),
            last_slack: None,
            last_slacks: Vec::new(),
            last_iters: 0,
            last_batch_iters: Vec::new(),
            warm: None,
            last_keys: Vec::new(),
            last_qs: Vec::new(),
            last_seeds: Vec::new(),
            last_warm_out: Vec::new(),
        })
    }

    /// Number of layer variables n.
    pub fn n(&self) -> usize {
        match &self.solver {
            LayerSolver::Dense { solver, .. } => solver.qp.n(),
            LayerSolver::Sparse { solver, .. } => solver.qp.n(),
            LayerSolver::Admm { solver, .. } => solver.qp.n(),
            LayerSolver::Fw { solver, .. } => solver.qp.n(),
        }
    }

    /// The engine family serving this layer (tags warm-cache entries so
    /// cross-family iterates are never reused).
    fn family(&self) -> EngineFamily {
        match self.backend {
            OptBackend::Admm => EngineFamily::Admm,
            OptBackend::Fw => EngineFamily::Fw,
            _ => EngineFamily::AltDiff,
        }
    }

    /// Solver options for one layer evaluation (forward-only; gradients
    /// are served by the adjoint backward for the Alt-Diff backend).
    fn opts(&self) -> Options {
        Options { tol: self.tol, max_iter: 20_000, ..Options::adjoint() }
    }

    /// Enable cross-call warm starts for [`Self::forward_batch_keyed`]
    /// / [`Self::backward_batch`]: solves keyed by the same sample key
    /// resume from each other's iterates across epochs (Alt-Diff
    /// backend only; a no-op request on the OptNet baseline, whose KKT
    /// path has nothing to warm). `radius` is the staleness bound on
    /// the relative q-drift between epochs (see
    /// [`crate::warm::theta_distance`]) — training inputs drift slowly,
    /// so a generous radius (≈1.0) is the right default.
    pub fn enable_warm_start(&mut self, capacity: usize, radius: f64) {
        self.warm = (self.backend != OptBackend::OptNetKkt
            && capacity > 0)
            .then(|| WarmStartCache::new(capacity, radius));
    }

    /// Warm-cache `(hits, misses)` so far; `None` while warm starts are
    /// disabled.
    pub fn warm_stats(&self) -> Option<(u64, u64)> {
        self.warm.as_ref().map(|c| (c.hits(), c.misses()))
    }

    /// [`Self::forward_batch`] with per-sample warm-start keys (e.g.
    /// the dataset indices of the minibatch): when warm starts are
    /// enabled, sample `keys[e]`'s solve resumes from the iterate its
    /// previous epoch converged to, and the converged result is written
    /// back for the next epoch — ONE batched launch either way, mixing
    /// first-sight (cold) and revisited (warm) samples freely. Without
    /// [`Self::enable_warm_start`] (or on the OptNet baseline) this is
    /// exactly [`Self::forward_batch`].
    pub fn forward_batch_keyed(
        &mut self,
        qs: &[Vec<f64>],
        keys: &[u64],
    ) -> Vec<Vec<f64>> {
        assert_eq!(qs.len(), keys.len(), "one warm key per sample");
        if self.warm.is_none() || self.backend == OptBackend::OptNetKkt {
            self.last_keys.clear();
            return self.forward_batch(qs);
        }
        let opts = self.opts();
        let fam = self.family();
        // recall prior iterates (and the adjoint seeds their backwards
        // left behind) per sample key
        let mut warms: Vec<Option<WarmStart>> =
            Vec::with_capacity(qs.len());
        let mut seeds: Vec<Option<EngineSeed>> =
            Vec::with_capacity(qs.len());
        {
            let cache = self.warm.as_mut().expect("warm enabled");
            for (q, &key) in qs.iter().zip(keys) {
                let fp = fingerprint(Some(key), q, &[], &[]);
                match cache.get(WARM_LAYER, fam, 0, fp, q, &[], &[]) {
                    Some((w, a)) => {
                        warms.push(Some(w));
                        seeds.push(a);
                    }
                    None => {
                        warms.push(None);
                        seeds.push(None);
                    }
                }
            }
        }
        let qrefs: Vec<&[f64]> =
            qs.iter().map(|q| q.as_slice()).collect();
        let sol = match &self.solver {
            LayerSolver::Dense { batched, .. } => batched
                .as_ref()
                .expect("alt-diff backend has engine")
                .solve_batch_from(
                    Some(&qrefs),
                    None,
                    None,
                    Some(&warms),
                    &opts,
                ),
            LayerSolver::Sparse { batched, .. } => batched
                .try_solve_batch_from(
                    Some(&qrefs),
                    None,
                    None,
                    Some(&warms),
                    &opts,
                )
                .expect("batched sparse solve failed"),
            LayerSolver::Admm { batched, .. } => batched
                .solve_batch_from(
                    Some(&qrefs),
                    None,
                    None,
                    Some(&warms),
                    &opts,
                ),
            LayerSolver::Fw { batched, .. } => batched
                .solve_batch_from(
                    Some(&qrefs),
                    None,
                    None,
                    Some(&warms),
                    &opts,
                ),
        };
        // write the converged iterates back, preserving each entry's
        // previous adjoint seed (this epoch's backward resumes from it
        // and will overwrite it with a fresh one)
        let warm_out: Vec<WarmStart> =
            (0..qs.len()).map(|e| sol.warm_start(e)).collect();
        {
            let cache = self.warm.as_mut().expect("warm enabled");
            for (e, (q, &key)) in qs.iter().zip(keys).enumerate() {
                let fp = fingerprint(Some(key), q, &[], &[]);
                cache.put(
                    WARM_LAYER,
                    fam,
                    0,
                    fp,
                    q.clone(),
                    vec![],
                    vec![],
                    warm_out[e].clone(),
                    seeds[e].clone(),
                );
            }
        }
        self.last_keys = keys.to_vec();
        self.last_qs = qs.to_vec();
        self.last_seeds = seeds;
        self.last_warm_out = warm_out;
        self.last_batch_iters = sol.iters.clone();
        self.last_iters =
            sol.iters.iter().sum::<usize>() / sol.iters.len();
        self.last_slacks = sol.ss;
        self.last_jacs = Vec::new();
        self.last_jac = None;
        self.last_slack = None;
        sol.xs
    }

    /// Forward: solve with the supplied q. The Alt-Diff backend caches
    /// only the final slack (the adjoint gate pattern, O(m)); the OptNet
    /// baseline caches the full ∂x/∂q its KKT solve produces.
    pub fn forward(&mut self, q: &[f64]) -> Vec<f64> {
        let opts = self.opts();
        let (x, slack, jac, iters) = match (&self.solver, self.backend) {
            (LayerSolver::Dense { solver, .. }, OptBackend::AltDiff) => {
                let sol = solver.solve_with(Some(q), None, None, &opts);
                (sol.x, Some(sol.s), None, sol.iters)
            }
            (LayerSolver::Dense { solver, .. }, OptBackend::OptNetKkt) => {
                let mut qp = solver.qp.clone();
                qp.q = q.to_vec();
                let (x, j, iters) =
                    baselines::optnet_layer(&qp, Param::Q, self.tol * 1e-3)
                        .expect("optnet layer");
                (x, None, Some(j), iters)
            }
            (LayerSolver::Sparse { solver, .. }, _) => {
                let sol = solver.solve_with(Some(q), None, None, &opts);
                (sol.x, Some(sol.s), None, sol.iters)
            }
            (LayerSolver::Admm { solver, .. }, _) => {
                let sol = solver.solve_with(Some(q), None, None, &opts);
                (sol.x, Some(sol.s), None, sol.iters)
            }
            (LayerSolver::Fw { solver, .. }, _) => {
                let sol = solver.solve_with(Some(q), None, None, &opts);
                (sol.x, Some(sol.s), None, sol.iters)
            }
        };
        self.last_iters = iters;
        self.last_slack = slack;
        self.last_jac = jac;
        x
    }

    /// Backward: dL/dq = (∂x*/∂q)ᵀ · dL/dx. Alt-Diff backend: one
    /// adjoint iteration against the cached slack gates — the Jacobian
    /// is never formed. OptNet backend: gemv against its cached KKT
    /// Jacobian.
    pub fn backward(&self, gx: &[f64]) -> Vec<f64> {
        if let Some(j) = self.last_jac.as_ref() {
            return gemv_t(j, gx);
        }
        let slack = self
            .last_slack
            .as_ref()
            .expect("backward before forward");
        let opts = self.opts();
        match &self.solver {
            LayerSolver::Dense { solver, .. } => {
                solver.vjp(slack, gx, &opts).grad_q
            }
            LayerSolver::Sparse { solver, .. } => {
                solver.vjp(slack, gx, &opts).grad_q
            }
            LayerSolver::Admm { solver, .. } => {
                solver.vjp(slack, gx, &opts).grad_q
            }
            LayerSolver::Fw { solver, .. } => {
                solver.vjp(slack, gx, &opts).grad_q
            }
        }
    }

    /// Minibatch forward: solve B instances of the layer in one batched
    /// launch ([`BatchedAltDiff`] for dense layers,
    /// [`BatchedSparseAltDiff`] for sparse ones; the OptNet baseline has
    /// no batched KKT path and falls back to a per-sample loop).
    /// The Alt-Diff backend caches one slack vector per element (O(B·m)
    /// total — no per-element Jacobians) for the adjoint backward.
    pub fn forward_batch(&mut self, qs: &[Vec<f64>]) -> Vec<Vec<f64>> {
        assert!(!qs.is_empty(), "empty minibatch");
        // unkeyed forwards must not pair a later backward with stale
        // keyed state (the warm write-back path checks last_keys)
        self.last_keys.clear();
        if qs.len() == 1 || self.backend == OptBackend::OptNetKkt {
            // per-sample path (exact single-sample semantics)
            let mut xs = Vec::with_capacity(qs.len());
            self.last_jacs = Vec::with_capacity(qs.len());
            self.last_slacks = Vec::with_capacity(qs.len());
            self.last_batch_iters = Vec::with_capacity(qs.len());
            for q in qs {
                let x = self.forward(q);
                if let Some(j) = self.last_jac.clone() {
                    self.last_jacs.push(j);
                }
                if let Some(s) = self.last_slack.clone() {
                    self.last_slacks.push(s);
                }
                self.last_batch_iters.push(self.last_iters);
                xs.push(x);
            }
            return xs;
        }
        let qrefs: Vec<&[f64]> =
            qs.iter().map(|q| q.as_slice()).collect();
        let opts = self.opts();
        let sol = match &self.solver {
            LayerSolver::Dense { batched, .. } => batched
                .as_ref()
                .expect("alt-diff backend has engine")
                .solve_batch(Some(&qrefs), None, None, &opts),
            LayerSolver::Sparse { batched, .. } => {
                batched.solve_batch(Some(&qrefs), None, None, &opts)
            }
            LayerSolver::Admm { batched, .. } => {
                batched.solve_batch(Some(&qrefs), None, None, &opts)
            }
            LayerSolver::Fw { batched, .. } => {
                batched.solve_batch(Some(&qrefs), None, None, &opts)
            }
        };
        self.last_batch_iters = sol.iters.clone();
        self.last_iters = sol.iters.iter().sum::<usize>() / sol.iters.len();
        self.last_slacks = sol.ss;
        self.last_jacs = Vec::new();
        self.last_jac = None; // single-sample caches are now stale
        self.last_slack = None;
        sol.xs
    }

    /// Backward for minibatch element `e`: dL/dq_e = (∂x*/∂q_e)ᵀ dL/dx_e
    /// (one sequential adjoint run for the Alt-Diff backend; prefer
    /// [`Self::backward_batch`], which batches the whole minibatch's
    /// adjoints into one launch).
    pub fn backward_element(&self, e: usize, gx: &[f64]) -> Vec<f64> {
        if let Some(j) = self.last_jacs.get(e) {
            return gemv_t(j, gx);
        }
        let slack = self
            .last_slacks
            .get(e)
            .expect("backward_element before forward_batch");
        let opts = self.opts();
        match &self.solver {
            LayerSolver::Dense { solver, .. } => {
                solver.vjp(slack, gx, &opts).grad_q
            }
            LayerSolver::Sparse { solver, .. } => {
                solver.vjp(slack, gx, &opts).grad_q
            }
            LayerSolver::Admm { solver, .. } => {
                solver.vjp(slack, gx, &opts).grad_q
            }
            LayerSolver::Fw { solver, .. } => {
                solver.vjp(slack, gx, &opts).grad_q
            }
        }
    }

    /// Backward for a whole minibatch (pairs with
    /// [`Self::forward_batch`] / [`Self::forward_batch_keyed`]).
    /// Alt-Diff backend: ONE batched adjoint launch — B incoming
    /// gradients advance as a single panel through the transposed
    /// recursion; after a keyed forward with warm starts enabled, each
    /// sample's adjoint resumes from the seed its previous epoch's
    /// backward cached (and leaves a fresh one behind). OptNet backend:
    /// per-element gemvs against the cached KKT Jacobians.
    pub fn backward_batch(&mut self, gxs: &[Vec<f64>]) -> Vec<Vec<f64>> {
        if !self.last_jacs.is_empty() {
            return gxs
                .iter()
                .enumerate()
                .map(|(e, gx)| self.backward_element(e, gx))
                .collect();
        }
        assert_eq!(
            gxs.len(),
            self.last_slacks.len(),
            "backward_batch arity (did forward_batch run?)"
        );
        let slack_refs: Vec<&[f64]> =
            self.last_slacks.iter().map(|s| s.as_slice()).collect();
        let gx_refs: Vec<&[f64]> =
            gxs.iter().map(|g| g.as_slice()).collect();
        let opts = self.opts();
        let use_warm =
            self.warm.is_some() && self.last_keys.len() == gxs.len();
        // seeds are engine-tagged: unwrap this layer's family (the keyed
        // forward only ever recalled same-family entries, but the
        // conversion keeps the invariant explicit in the types)
        let fam = self.family();
        let (vjp, seeds_out): (_, Vec<EngineSeed>) = match &self.solver {
            LayerSolver::Dense { batched, .. } => {
                let alt = use_warm.then(|| {
                    self.last_seeds
                        .iter()
                        .map(|o| {
                            o.clone().and_then(EngineSeed::into_altdiff)
                        })
                        .collect::<Vec<_>>()
                });
                let (vjp, states) = batched
                    .as_ref()
                    .expect("alt-diff backend has engine")
                    .batch_vjp_from(
                        &slack_refs,
                        &gx_refs,
                        alt.as_deref(),
                        &opts,
                    );
                (
                    vjp,
                    states
                        .into_iter()
                        .map(EngineSeed::AltDiff)
                        .collect(),
                )
            }
            LayerSolver::Sparse { batched, .. } => {
                let alt = use_warm.then(|| {
                    self.last_seeds
                        .iter()
                        .map(|o| {
                            o.clone().and_then(EngineSeed::into_altdiff)
                        })
                        .collect::<Vec<_>>()
                });
                let (vjp, states) = batched
                    .try_batch_vjp_from(
                        &slack_refs,
                        &gx_refs,
                        alt.as_deref(),
                        &opts,
                    )
                    .expect("batched sparse adjoint failed");
                (
                    vjp,
                    states
                        .into_iter()
                        .map(EngineSeed::AltDiff)
                        .collect(),
                )
            }
            LayerSolver::Admm { batched, .. } => {
                let admm = use_warm.then(|| {
                    self.last_seeds
                        .iter()
                        .map(|o| o.clone().and_then(EngineSeed::into_admm))
                        .collect::<Vec<_>>()
                });
                let (vjp, states) = batched.batch_vjp_from(
                    &slack_refs,
                    &gx_refs,
                    admm.as_deref(),
                    &opts,
                );
                (
                    vjp,
                    states.into_iter().map(EngineSeed::Admm).collect(),
                )
            }
            LayerSolver::Fw { batched, .. } => {
                let fw = use_warm.then(|| {
                    self.last_seeds
                        .iter()
                        .map(|o| o.clone().and_then(EngineSeed::into_fw))
                        .collect::<Vec<_>>()
                });
                let (vjp, states) = batched.batch_vjp_from(
                    &slack_refs,
                    &gx_refs,
                    fw.as_deref(),
                    &opts,
                );
                (
                    vjp,
                    states.into_iter().map(EngineSeed::Fw).collect(),
                )
            }
        };
        if use_warm {
            let cache = self.warm.as_mut().expect("warm enabled");
            for (e, &key) in self.last_keys.iter().enumerate() {
                let q = &self.last_qs[e];
                let fp = fingerprint(Some(key), q, &[], &[]);
                cache.put(
                    WARM_LAYER,
                    fam,
                    0,
                    fp,
                    q.clone(),
                    vec![],
                    vec![],
                    self.last_warm_out[e].clone(),
                    Some(seeds_out[e].clone()),
                );
            }
        }
        vjp.grads_q
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prob::{dense_qp, sparsemax_qp};

    fn layer(backend: OptBackend) -> OptLayer {
        OptLayer::new(dense_qp(10, 5, 2, 31), 1.0, backend, 1e-8).unwrap()
    }

    #[test]
    fn forward_matches_between_backends() {
        let mut a = layer(OptBackend::AltDiff);
        let mut b = layer(OptBackend::OptNetKkt);
        let q: Vec<f64> = (0..10).map(|i| 0.1 * i as f64 - 0.4).collect();
        let xa = a.forward(&q);
        let xb = b.forward(&q);
        for i in 0..10 {
            assert!(
                (xa[i] - xb[i]).abs() < 1e-4,
                "x[{i}]: altdiff {} optnet {}",
                xa[i],
                xb[i]
            );
        }
    }

    #[test]
    fn backward_matches_between_backends() {
        let mut a = layer(OptBackend::AltDiff);
        let mut b = layer(OptBackend::OptNetKkt);
        let q: Vec<f64> = (0..10).map(|i| 0.05 * i as f64).collect();
        let _ = a.forward(&q);
        let _ = b.forward(&q);
        let gx: Vec<f64> = (0..10).map(|i| 1.0 - 0.1 * i as f64).collect();
        let ga = a.backward(&gx);
        let gb = b.backward(&gx);
        let cos = crate::linalg::cosine(&ga, &gb);
        assert!(cos > 0.999, "cosine {cos}");
    }

    #[test]
    fn forward_batch_matches_sequential_forward() {
        let mut seq = layer(OptBackend::AltDiff);
        let mut bat = layer(OptBackend::AltDiff);
        let qs: Vec<Vec<f64>> = (0..3)
            .map(|s| {
                (0..10)
                    .map(|i| 0.1 * i as f64 - 0.3 + 0.2 * s as f64)
                    .collect()
            })
            .collect();
        let xs = bat.forward_batch(&qs);
        assert_eq!(xs.len(), 3);
        assert_eq!(bat.last_batch_iters.len(), 3);
        let gx: Vec<f64> = (0..10).map(|i| 0.5 - 0.1 * i as f64).collect();
        for (e, q) in qs.iter().enumerate() {
            let x = seq.forward(q);
            for i in 0..10 {
                assert!(
                    (xs[e][i] - x[i]).abs() < 1e-6,
                    "x[{e}][{i}]: batched {} sequential {}",
                    xs[e][i],
                    x[i]
                );
            }
            let gb = bat.backward_element(e, &gx);
            let gs = seq.backward(&gx);
            for i in 0..10 {
                assert!((gb[i] - gs[i]).abs() < 1e-6, "g[{e}][{i}]");
            }
        }
    }

    #[test]
    fn forward_batch_optnet_fallback_works() {
        let mut l = layer(OptBackend::OptNetKkt);
        let qs: Vec<Vec<f64>> = (0..2)
            .map(|s| (0..10).map(|i| 0.05 * i as f64 + s as f64 * 0.1).collect())
            .collect();
        let xs = l.forward_batch(&qs);
        assert_eq!(xs.len(), 2);
        let gq = l.backward_batch(&[vec![1.0; 10], vec![1.0; 10]]);
        assert_eq!(gq.len(), 2);
        assert!(gq[0].iter().all(|v| v.is_finite()));
    }

    #[test]
    fn backward_matches_loss_finite_difference() {
        // L(q) = sum x*(q); check dL/dq by FD through the solver.
        let mut l = layer(OptBackend::AltDiff);
        let q: Vec<f64> = (0..10).map(|i| -0.2 + 0.07 * i as f64).collect();
        let _x = l.forward(&q);
        let g = l.backward(&[1.0; 10]);
        let eps = 1e-5;
        for c in [0usize, 3, 9] {
            let mut qp = q.clone();
            qp[c] += eps;
            let mut qm = q.clone();
            qm[c] -= eps;
            let lp: f64 = l.forward(&qp).iter().sum();
            let lm: f64 = l.forward(&qm).iter().sum();
            let fd = (lp - lm) / (2.0 * eps);
            assert!(
                (g[c] - fd).abs() < 1e-3 * (1.0 + fd.abs()),
                "g[{c}]={} fd={fd}",
                g[c]
            );
        }
    }

    #[test]
    fn admm_backend_matches_altdiff() {
        let mut a = layer(OptBackend::AltDiff);
        let mut m = layer(OptBackend::Admm);
        let q: Vec<f64> = (0..10).map(|i| 0.08 * i as f64 - 0.3).collect();
        let xa = a.forward(&q);
        let xm = m.forward(&q);
        for i in 0..10 {
            assert!(
                (xa[i] - xm[i]).abs() < 1e-6,
                "x[{i}]: altdiff {} admm {}",
                xa[i],
                xm[i]
            );
        }
        let gx: Vec<f64> = (0..10).map(|i| 0.9 - 0.15 * i as f64).collect();
        let ga = a.backward(&gx);
        let gm = m.backward(&gx);
        for i in 0..10 {
            assert!(
                (ga[i] - gm[i]).abs() < 1e-5,
                "g[{i}]: altdiff {} admm {}",
                ga[i],
                gm[i]
            );
        }
    }

    #[test]
    fn fw_backend_serves_simplex_layers() {
        use crate::prob::simplex_qp;
        // FW refuses general polytopes at registration...
        assert!(OptLayer::new(
            dense_qp(10, 5, 2, 31),
            1.0,
            OptBackend::Fw,
            1e-8
        )
        .is_err());
        // ...and matches the Alt-Diff layer on a servable simplex one.
        let qp = simplex_qp(12, 1.0, 7);
        let mut a =
            OptLayer::new(qp.clone(), 1.0, OptBackend::AltDiff, 1e-10)
                .unwrap();
        let mut f =
            OptLayer::new(qp, 1.0, OptBackend::Fw, 1e-10).unwrap();
        let q: Vec<f64> =
            (0..12).map(|i| 0.07 * i as f64 - 0.4).collect();
        let xa = a.forward(&q);
        let xf = f.forward(&q);
        for i in 0..12 {
            assert!(
                (xa[i] - xf[i]).abs() < 1e-6,
                "x[{i}]: altdiff {} fw {}",
                xa[i],
                xf[i]
            );
        }
        let gx: Vec<f64> =
            (0..12).map(|i| 1.0 - 0.1 * i as f64).collect();
        let ga = a.backward(&gx);
        let gf = f.backward(&gx);
        for i in 0..12 {
            assert!(
                (ga[i] - gf[i]).abs() < 1e-5,
                "g[{i}]: altdiff {} fw {}",
                ga[i],
                gf[i]
            );
        }
    }

    #[test]
    fn admm_batch_roundtrip_and_keyed_warm_starts() {
        let mut l = layer(OptBackend::Admm);
        l.enable_warm_start(64, 1.0);
        let qs: Vec<Vec<f64>> = (0..3)
            .map(|s| {
                (0..10)
                    .map(|i| 0.1 * i as f64 - 0.2 + 0.15 * s as f64)
                    .collect()
            })
            .collect();
        let keys = [11u64, 22, 33];
        let xs1 = l.forward_batch_keyed(&qs, &keys);
        let gxs: Vec<Vec<f64>> = vec![vec![1.0; 10]; 3];
        let g1 = l.backward_batch(&gxs);
        // second epoch, same keys: warm hits, identical answers
        let xs2 = l.forward_batch_keyed(&qs, &keys);
        let g2 = l.backward_batch(&gxs);
        let (hits, _) = l.warm_stats().unwrap();
        assert!(hits >= 3, "expected warm hits on revisit, got {hits}");
        for e in 0..3 {
            for i in 0..10 {
                assert!((xs1[e][i] - xs2[e][i]).abs() < 1e-7);
                assert!((g1[e][i] - g2[e][i]).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn sparse_layer_forward_is_simplex_projection() {
        // constrained sparsemax as an output layer: x lands on the
        // capped simplex for any input q
        let mut l = OptLayer::new_sparse(sparsemax_qp(20, 4), 1.0, 1e-9)
            .unwrap();
        assert_eq!(l.n(), 20);
        let q: Vec<f64> = (0..20).map(|i| 0.3 * (i as f64).sin()).collect();
        let x = l.forward(&q);
        let sum: f64 = x.iter().sum();
        assert!((sum - 1.0).abs() < 1e-5, "simplex sum {sum}");
        assert!(x.iter().all(|&v| v >= -1e-6));
        let g = l.backward(&[1.0; 20]);
        assert!(g.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn sparse_forward_batch_matches_sequential_forward() {
        let mut seq =
            OptLayer::new_sparse(sparsemax_qp(16, 5), 1.0, 1e-9).unwrap();
        let mut bat =
            OptLayer::new_sparse(sparsemax_qp(16, 5), 1.0, 1e-9).unwrap();
        let qs: Vec<Vec<f64>> = (0..4)
            .map(|s| {
                (0..16)
                    .map(|i| 0.2 * ((i + s) as f64).cos())
                    .collect()
            })
            .collect();
        let xs = bat.forward_batch(&qs);
        assert_eq!(xs.len(), 4);
        let gx: Vec<f64> = (0..16).map(|i| 0.1 * i as f64 - 0.8).collect();
        for (e, q) in qs.iter().enumerate() {
            let x = seq.forward(q);
            for i in 0..16 {
                assert!(
                    (xs[e][i] - x[i]).abs() < 1e-6,
                    "x[{e}][{i}]: batched {} sequential {}",
                    xs[e][i],
                    x[i]
                );
            }
            let gb = bat.backward_element(e, &gx);
            let gs = seq.backward(&gx);
            for i in 0..16 {
                assert!((gb[i] - gs[i]).abs() < 1e-6, "g[{e}][{i}]");
            }
        }
    }
}
