//! The optimization layer as a network module (paper Definition 3.1).
//!
//! Forward: x* = argmin ½xᵀPx + qᵀx s.t. Ax=b, Gx≤h with q supplied by the
//! previous layer. Backward: dL/dq = (∂x*/∂q)ᵀ dL/dx*, computed either by
//! Alt-Diff (the paper) or by IPM + implicit KKT differentiation (the
//! OptNet baseline) — switchable so Table 6 can compare both inside the
//! identical network.
//!
//! Layers come in two structural flavours sharing one interface: dense
//! ([`OptLayer::new`], Table 2 structure) and sparse
//! ([`OptLayer::new_sparse`], Table 4 structure — diagonal P, CSR
//! constraints, e.g. a constrained-sparsemax output layer). Minibatch
//! forwards route through the matching batched engine
//! ([`BatchedAltDiff`] / [`BatchedSparseAltDiff`]): B samples per launch.

use crate::altdiff::{DenseAltDiff, Options, Param, SparseAltDiff};
use crate::baselines;
use crate::batch::{BatchedAltDiff, BatchedSparseAltDiff};
use crate::error::Result;
use crate::linalg::{gemv_t, Mat};
use crate::prob::{Qp, SparseQp};

/// Which differentiation engine backs the layer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OptBackend {
    /// Alt-Diff with the given truncation tolerance.
    AltDiff,
    /// OptNet semantics: interior point + KKT implicit differentiation.
    OptNetKkt,
}

/// Structure-specific solver pair: the sequential engine plus the
/// batched engine sharing its registration.
enum LayerSolver {
    Dense {
        solver: DenseAltDiff,
        /// minibatches; only built for the Alt-Diff backend — OptNet
        /// has no batched path
        batched: Option<BatchedAltDiff>,
    },
    Sparse {
        solver: SparseAltDiff,
        batched: BatchedSparseAltDiff,
    },
}

/// Optimization layer with fixed structure (P, A, b, G, h); input is q.
pub struct OptLayer {
    solver: LayerSolver,
    /// Differentiation engine behind [`Self::forward`].
    pub backend: OptBackend,
    /// Truncation tolerance (paper §4.3).
    pub tol: f64,
    /// cached ∂x/∂q from the last forward (n×n)
    last_jac: Option<Mat>,
    /// cached per-element ∂x/∂q from the last `forward_batch`
    last_jacs: Vec<Mat>,
    /// iterations used by the last forward (metrics; mean over the batch
    /// after `forward_batch`)
    pub last_iters: usize,
    /// per-element iterations from the last `forward_batch`
    pub last_batch_iters: Vec<usize>,
}

impl OptLayer {
    /// Register a dense QP layer.
    pub fn new(qp: Qp, rho: f64, backend: OptBackend, tol: f64)
        -> Result<Self>
    {
        let solver = DenseAltDiff::new(qp, rho)?;
        let batched = (backend == OptBackend::AltDiff)
            .then(|| BatchedAltDiff::from_dense(&solver));
        Ok(OptLayer {
            solver: LayerSolver::Dense { solver, batched },
            backend,
            tol,
            last_jac: None,
            last_jacs: Vec::new(),
            last_iters: 0,
            last_batch_iters: Vec::new(),
        })
    }

    /// Register a sparse QP layer (diagonal P, CSR constraints — the
    /// Table 4 structure). Always Alt-Diff: the OptNet baseline has no
    /// sparse KKT path.
    pub fn new_sparse(qp: SparseQp, rho: f64, tol: f64) -> Result<Self> {
        let solver = SparseAltDiff::new(qp, rho)?;
        let batched = BatchedSparseAltDiff::from_sparse(&solver);
        Ok(OptLayer {
            solver: LayerSolver::Sparse { solver, batched },
            backend: OptBackend::AltDiff,
            tol,
            last_jac: None,
            last_jacs: Vec::new(),
            last_iters: 0,
            last_batch_iters: Vec::new(),
        })
    }

    /// Number of layer variables n.
    pub fn n(&self) -> usize {
        match &self.solver {
            LayerSolver::Dense { solver, .. } => solver.qp.n(),
            LayerSolver::Sparse { solver, .. } => solver.qp.n(),
        }
    }

    /// Forward: solve with the supplied q, cache ∂x/∂q for backward.
    pub fn forward(&mut self, q: &[f64]) -> Vec<f64> {
        let opts = Options {
            tol: self.tol,
            max_iter: 20_000,
            jacobian: Some(Param::Q),
            ..Default::default()
        };
        let (x, jac, iters) = match (&self.solver, self.backend) {
            (LayerSolver::Dense { solver, .. }, OptBackend::AltDiff) => {
                let sol = solver.solve_with(Some(q), None, None, &opts);
                (sol.x, sol.jacobian, sol.iters)
            }
            (LayerSolver::Dense { solver, .. }, OptBackend::OptNetKkt) => {
                let mut qp = solver.qp.clone();
                qp.q = q.to_vec();
                let (x, j, iters) =
                    baselines::optnet_layer(&qp, Param::Q, self.tol * 1e-3)
                        .expect("optnet layer");
                (x, Some(j), iters)
            }
            (LayerSolver::Sparse { solver, .. }, _) => {
                let sol = solver.solve_with(Some(q), None, None, &opts);
                (sol.x, sol.jacobian, sol.iters)
            }
        };
        self.last_iters = iters;
        self.last_jac = jac;
        x
    }

    /// Backward: dL/dq = Jᵀ · dL/dx.
    pub fn backward(&self, gx: &[f64]) -> Vec<f64> {
        let j = self
            .last_jac
            .as_ref()
            .expect("backward before forward");
        gemv_t(j, gx)
    }

    /// Minibatch forward: solve B instances of the layer in one batched
    /// launch ([`BatchedAltDiff`] for dense layers,
    /// [`BatchedSparseAltDiff`] for sparse ones; the OptNet baseline has
    /// no batched KKT path and falls back to a per-sample loop).
    /// Caches one Jacobian per element for [`Self::backward_element`].
    pub fn forward_batch(&mut self, qs: &[Vec<f64>]) -> Vec<Vec<f64>> {
        assert!(!qs.is_empty(), "empty minibatch");
        if qs.len() == 1 || self.backend == OptBackend::OptNetKkt {
            // per-sample path (exact single-sample semantics)
            let mut xs = Vec::with_capacity(qs.len());
            self.last_jacs = Vec::with_capacity(qs.len());
            self.last_batch_iters = Vec::with_capacity(qs.len());
            for q in qs {
                let x = self.forward(q);
                self.last_jacs.push(
                    self.last_jac.clone().expect("forward caches jac"),
                );
                self.last_batch_iters.push(self.last_iters);
                xs.push(x);
            }
            return xs;
        }
        let qrefs: Vec<&[f64]> =
            qs.iter().map(|q| q.as_slice()).collect();
        let opts = Options {
            tol: self.tol,
            max_iter: 20_000,
            jacobian: Some(Param::Q),
            ..Default::default()
        };
        let sol = match &self.solver {
            LayerSolver::Dense { batched, .. } => batched
                .as_ref()
                .expect("alt-diff backend has engine")
                .solve_batch(Some(&qrefs), None, None, &opts),
            LayerSolver::Sparse { batched, .. } => {
                batched.solve_batch(Some(&qrefs), None, None, &opts)
            }
        };
        self.last_batch_iters = sol.iters.clone();
        self.last_iters = sol.iters.iter().sum::<usize>() / sol.iters.len();
        self.last_jacs = sol.jacobians.expect("jacobian requested");
        self.last_jac = None; // single-sample cache is now stale
        sol.xs
    }

    /// Backward for minibatch element `e`: dL/dq_e = J_eᵀ · dL/dx_e.
    pub fn backward_element(&self, e: usize, gx: &[f64]) -> Vec<f64> {
        let j = self
            .last_jacs
            .get(e)
            .expect("backward_element before forward_batch");
        gemv_t(j, gx)
    }

    /// Backward for a whole minibatch (pairs with [`Self::forward_batch`]).
    pub fn backward_batch(&self, gxs: &[Vec<f64>]) -> Vec<Vec<f64>> {
        gxs.iter()
            .enumerate()
            .map(|(e, gx)| self.backward_element(e, gx))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prob::{dense_qp, sparsemax_qp};

    fn layer(backend: OptBackend) -> OptLayer {
        OptLayer::new(dense_qp(10, 5, 2, 31), 1.0, backend, 1e-8).unwrap()
    }

    #[test]
    fn forward_matches_between_backends() {
        let mut a = layer(OptBackend::AltDiff);
        let mut b = layer(OptBackend::OptNetKkt);
        let q: Vec<f64> = (0..10).map(|i| 0.1 * i as f64 - 0.4).collect();
        let xa = a.forward(&q);
        let xb = b.forward(&q);
        for i in 0..10 {
            assert!(
                (xa[i] - xb[i]).abs() < 1e-4,
                "x[{i}]: altdiff {} optnet {}",
                xa[i],
                xb[i]
            );
        }
    }

    #[test]
    fn backward_matches_between_backends() {
        let mut a = layer(OptBackend::AltDiff);
        let mut b = layer(OptBackend::OptNetKkt);
        let q: Vec<f64> = (0..10).map(|i| 0.05 * i as f64).collect();
        let _ = a.forward(&q);
        let _ = b.forward(&q);
        let gx: Vec<f64> = (0..10).map(|i| 1.0 - 0.1 * i as f64).collect();
        let ga = a.backward(&gx);
        let gb = b.backward(&gx);
        let cos = crate::linalg::cosine(&ga, &gb);
        assert!(cos > 0.999, "cosine {cos}");
    }

    #[test]
    fn forward_batch_matches_sequential_forward() {
        let mut seq = layer(OptBackend::AltDiff);
        let mut bat = layer(OptBackend::AltDiff);
        let qs: Vec<Vec<f64>> = (0..3)
            .map(|s| {
                (0..10)
                    .map(|i| 0.1 * i as f64 - 0.3 + 0.2 * s as f64)
                    .collect()
            })
            .collect();
        let xs = bat.forward_batch(&qs);
        assert_eq!(xs.len(), 3);
        assert_eq!(bat.last_batch_iters.len(), 3);
        let gx: Vec<f64> = (0..10).map(|i| 0.5 - 0.1 * i as f64).collect();
        for (e, q) in qs.iter().enumerate() {
            let x = seq.forward(q);
            for i in 0..10 {
                assert!(
                    (xs[e][i] - x[i]).abs() < 1e-6,
                    "x[{e}][{i}]: batched {} sequential {}",
                    xs[e][i],
                    x[i]
                );
            }
            let gb = bat.backward_element(e, &gx);
            let gs = seq.backward(&gx);
            for i in 0..10 {
                assert!((gb[i] - gs[i]).abs() < 1e-6, "g[{e}][{i}]");
            }
        }
    }

    #[test]
    fn forward_batch_optnet_fallback_works() {
        let mut l = layer(OptBackend::OptNetKkt);
        let qs: Vec<Vec<f64>> = (0..2)
            .map(|s| (0..10).map(|i| 0.05 * i as f64 + s as f64 * 0.1).collect())
            .collect();
        let xs = l.forward_batch(&qs);
        assert_eq!(xs.len(), 2);
        let gq = l.backward_batch(&[vec![1.0; 10], vec![1.0; 10]]);
        assert_eq!(gq.len(), 2);
        assert!(gq[0].iter().all(|v| v.is_finite()));
    }

    #[test]
    fn backward_matches_loss_finite_difference() {
        // L(q) = sum x*(q); check dL/dq by FD through the solver.
        let mut l = layer(OptBackend::AltDiff);
        let q: Vec<f64> = (0..10).map(|i| -0.2 + 0.07 * i as f64).collect();
        let _x = l.forward(&q);
        let g = l.backward(&[1.0; 10]);
        let eps = 1e-5;
        for c in [0usize, 3, 9] {
            let mut qp = q.clone();
            qp[c] += eps;
            let mut qm = q.clone();
            qm[c] -= eps;
            let lp: f64 = l.forward(&qp).iter().sum();
            let lm: f64 = l.forward(&qm).iter().sum();
            let fd = (lp - lm) / (2.0 * eps);
            assert!(
                (g[c] - fd).abs() < 1e-3 * (1.0 + fd.abs()),
                "g[{c}]={} fd={fd}",
                g[c]
            );
        }
    }

    #[test]
    fn sparse_layer_forward_is_simplex_projection() {
        // constrained sparsemax as an output layer: x lands on the
        // capped simplex for any input q
        let mut l = OptLayer::new_sparse(sparsemax_qp(20, 4), 1.0, 1e-9)
            .unwrap();
        assert_eq!(l.n(), 20);
        let q: Vec<f64> = (0..20).map(|i| 0.3 * (i as f64).sin()).collect();
        let x = l.forward(&q);
        let sum: f64 = x.iter().sum();
        assert!((sum - 1.0).abs() < 1e-5, "simplex sum {sum}");
        assert!(x.iter().all(|&v| v >= -1e-6));
        let g = l.backward(&[1.0; 20]);
        assert!(g.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn sparse_forward_batch_matches_sequential_forward() {
        let mut seq =
            OptLayer::new_sparse(sparsemax_qp(16, 5), 1.0, 1e-9).unwrap();
        let mut bat =
            OptLayer::new_sparse(sparsemax_qp(16, 5), 1.0, 1e-9).unwrap();
        let qs: Vec<Vec<f64>> = (0..4)
            .map(|s| {
                (0..16)
                    .map(|i| 0.2 * ((i + s) as f64).cos())
                    .collect()
            })
            .collect();
        let xs = bat.forward_batch(&qs);
        assert_eq!(xs.len(), 4);
        let gx: Vec<f64> = (0..16).map(|i| 0.1 * i as f64 - 0.8).collect();
        for (e, q) in qs.iter().enumerate() {
            let x = seq.forward(q);
            for i in 0..16 {
                assert!(
                    (xs[e][i] - x[i]).abs() < 1e-6,
                    "x[{e}][{i}]: batched {} sequential {}",
                    xs[e][i],
                    x[i]
                );
            }
            let gb = bat.backward_element(e, &gx);
            let gs = seq.backward(&gx);
            for i in 0..16 {
                assert!((gb[i] - gs[i]).abs() < 1e-6, "g[{e}][{i}]");
            }
        }
    }
}
