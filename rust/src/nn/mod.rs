//! Minimal neural-network substrate for the end-to-end experiments
//! (Fig. 2 predict-then-optimize; Table 6 image classification).
//!
//! Deliberately small: dense layers, ReLU, softmax/NLL and MSE losses,
//! Adam — plus [`optlayer::OptLayer`], the optimization layer whose
//! backward pass is Alt-Diff (or the OptNet-style KKT baseline, switchable
//! for the Table 6 comparison).

pub mod adam;
pub mod layers;
pub mod loss;
pub mod optlayer;

pub use adam::Adam;
pub use layers::{Linear, Mlp};
pub use loss::{mse_loss, softmax_nll};
pub use optlayer::{OptLayer, OptBackend};
