//! Best-effort thread→core pinning for the sharded coordinator pool.
//!
//! The crate is dependency-free, so there is no `libc` to call
//! `sched_setaffinity(2)` through. On Linux (x86_64 / aarch64) the
//! syscall is issued directly with inline assembly — the only `unsafe`
//! in the crate, contained to this module and exercised only when an
//! operator opts in (`Config::pin_cores` / `serve --pin-cores`). On
//! every other target pinning is a no-op that reports `false`, and the
//! coordinator runs unpinned exactly as before.
//!
//! Pinning is *best effort by contract*: a `false` return (unsupported
//! target, restricted cpuset, masked-out CPU) must never change
//! behavior, only placement. Callers ignore the result except for
//! logging.

/// Number of CPUs visible to this process (≥ 1). The coordinator uses
/// it to wrap worker→core assignment (`core = worker_index % cores`).
pub fn available_cores() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Pin the *calling* thread to the single CPU `cpu`. Returns `true` if
/// the kernel accepted the affinity mask, `false` on any failure or on
/// targets where pinning is unsupported (the thread then keeps its
/// inherited mask — correctness is unaffected either way).
pub fn pin_current_thread(cpu: usize) -> bool {
    // cpu_set_t is 1024 bits on Linux: 16 × u64 words.
    let mut mask = [0u64; 16];
    let word = cpu / 64;
    if word >= mask.len() {
        return false;
    }
    mask[word] = 1u64 << (cpu % 64);
    sched_setaffinity_self(&mask)
}

/// `sched_setaffinity(0, sizeof(mask), &mask)` for the calling thread
/// (pid 0 = self), issued as a raw syscall. Returns `true` on success.
#[cfg(all(target_os = "linux", target_arch = "x86_64"))]
fn sched_setaffinity_self(mask: &[u64; 16]) -> bool {
    let ret: isize;
    // SAFETY: syscall 203 (sched_setaffinity) reads `cpusetsize` bytes
    // from the pointer in rdx and touches no other user memory; the
    // mask outlives the call, and rcx/r11 (clobbered by `syscall`) are
    // declared as outputs.
    unsafe {
        core::arch::asm!(
            "syscall",
            inlateout("rax") 203isize => ret,
            in("rdi") 0usize,                       // pid 0 = this thread
            in("rsi") core::mem::size_of_val(mask), // cpusetsize
            in("rdx") mask.as_ptr(),
            lateout("rcx") _,
            lateout("r11") _,
            options(nostack),
        );
    }
    ret == 0
}

/// `sched_setaffinity` raw syscall, aarch64 flavor (syscall 122).
#[cfg(all(target_os = "linux", target_arch = "aarch64"))]
fn sched_setaffinity_self(mask: &[u64; 16]) -> bool {
    let ret: isize;
    // SAFETY: as the x86_64 variant — the kernel only reads
    // `cpusetsize` bytes from x2 for the duration of the call.
    unsafe {
        core::arch::asm!(
            "svc #0",
            in("x8") 122usize,
            inlateout("x0") 0usize => ret,
            in("x1") core::mem::size_of_val(mask),
            in("x2") mask.as_ptr(),
            options(nostack),
        );
    }
    ret == 0
}

/// Unsupported targets: report failure, pin nothing.
#[cfg(not(all(
    target_os = "linux",
    any(target_arch = "x86_64", target_arch = "aarch64")
)))]
fn sched_setaffinity_self(_mask: &[u64; 16]) -> bool {
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn available_cores_is_positive() {
        assert!(available_cores() >= 1);
    }

    #[test]
    fn pinning_never_panics_and_out_of_range_fails() {
        // cpu 0 exists on every machine; the call may still legally
        // fail (restricted cpuset), but it must not panic, and the
        // thread keeps working either way.
        let _ = pin_current_thread(0);
        assert!(!pin_current_thread(16 * 64)); // beyond cpu_set_t
    }

    #[test]
    fn pinned_thread_still_computes() {
        let h = std::thread::spawn(|| {
            let _ = pin_current_thread(0);
            (0..100u64).sum::<u64>()
        });
        assert_eq!(h.join().unwrap(), 4950);
    }
}
