//! Minimal benchmark harness (criterion is unavailable offline).
//!
//! Each `[[bench]]` target is a `harness = false` binary that uses
//! [`Bencher`] for warmup + repeated timing and [`Table`] to print the
//! paper-style rows, and writes machine-readable CSV next to the binary
//! output (`target/bench_csv/<name>.csv`). For longitudinal tracking,
//! [`JsonReport`] additionally emits `target/bench_json/BENCH_<name>.json`
//! with median/p10/p90 per measured configuration — stable keys a
//! perf-trajectory script can diff across commits.

use std::time::Instant;

/// Timing statistics over repeated runs (seconds).
#[derive(Clone, Copy, Debug)]
pub struct Stats {
    /// Arithmetic mean.
    pub mean: f64,
    /// Median (upper-middle sample for even counts).
    pub median: f64,
    /// 10th percentile (nearest-rank over the sorted samples).
    pub p10: f64,
    /// 90th percentile (nearest-rank over the sorted samples).
    pub p90: f64,
    /// Fastest sample.
    pub min: f64,
    /// Slowest sample.
    pub max: f64,
    /// Number of measured repetitions.
    pub reps: usize,
}

/// Repeated-measurement micro/macro benchmark runner.
pub struct Bencher {
    /// Untimed warmup runs before measuring.
    pub warmup: usize,
    /// Timed repetitions.
    pub reps: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher { warmup: 1, reps: 5 }
    }
}

impl Bencher {
    /// Runner with explicit warmup/repetition counts.
    pub fn new(warmup: usize, reps: usize) -> Self {
        Bencher { warmup, reps }
    }

    /// Time `f`, returning stats over `reps` runs after `warmup` runs.
    /// `f` should return something cheap to keep the compiler honest.
    pub fn run<T, F: FnMut() -> T>(&self, mut f: F) -> Stats {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut times = Vec::with_capacity(self.reps);
        for _ in 0..self.reps {
            let t0 = Instant::now();
            std::hint::black_box(f());
            times.push(t0.elapsed().as_secs_f64());
        }
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = times.iter().sum::<f64>() / times.len() as f64;
        Stats {
            mean,
            median: times[times.len() / 2],
            p10: percentile(&times, 0.10),
            p90: percentile(&times, 0.90),
            min: times[0],
            max: times[times.len() - 1],
            reps: self.reps,
        }
    }

    /// Time one run only (for expensive end-to-end cells).
    pub fn run_once<T, F: FnOnce() -> T>(&self, f: F) -> (f64, T) {
        let t0 = Instant::now();
        let out = f();
        (t0.elapsed().as_secs_f64(), out)
    }
}

/// Nearest-rank percentile of an ascending-sorted, non-empty sample
/// vector — the single definition every p50/p99 in the crate uses
/// ([`Stats`], the loadgen report, the serving benches), so reported
/// quantiles are comparable across surfaces.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    debug_assert!(!sorted.is_empty());
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

impl Stats {
    /// Build from raw timing samples (any order). One-sample inputs are
    /// legal: every statistic degenerates to that sample — the case for
    /// expensive cells measured via [`Bencher::run_once`].
    pub fn from_samples(samples: &[f64]) -> Stats {
        assert!(!samples.is_empty(), "no samples");
        let mut times = samples.to_vec();
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Stats {
            mean: times.iter().sum::<f64>() / times.len() as f64,
            median: times[times.len() / 2],
            p10: percentile(&times, 0.10),
            p90: percentile(&times, 0.90),
            min: times[0],
            max: times[times.len() - 1],
            reps: times.len(),
        }
    }
}

/// Fixed-width table printer mirroring the paper's layout.
pub struct Table {
    /// Table title (printed above the header).
    pub title: String,
    /// Column headers.
    pub header: Vec<String>,
    /// Row cells (each row matches the header arity).
    pub rows: Vec<Vec<String>>,
}

impl Table {
    /// Empty table with a title and column headers.
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append one row (must match the header arity).
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row arity");
        self.rows.push(cells.to_vec());
    }

    /// Render to stdout with aligned columns.
    pub fn print(&self) {
        let ncol = self.header.len();
        let mut widths: Vec<usize> =
            self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut out = String::new();
            for i in 0..ncol {
                out.push_str(&format!("{:>w$}  ", cells[i], w = widths[i]));
            }
            out
        };
        println!("\n== {} ==", self.title);
        println!("{}", line(&self.header));
        println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * ncol));
        for r in &self.rows {
            println!("{}", line(r));
        }
    }

    /// Write CSV to `target/bench_csv/<name>.csv`.
    pub fn write_csv(&self, name: &str) -> std::io::Result<String> {
        let dir = std::path::Path::new("target/bench_csv");
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{name}.csv"));
        let mut body = self.header.join(",") + "\n";
        for r in &self.rows {
            body.push_str(&r.join(","));
            body.push('\n');
        }
        std::fs::write(&path, body)?;
        Ok(path.display().to_string())
    }
}

/// Machine-readable benchmark report: one JSON object per measured
/// configuration, written to `target/bench_json/BENCH_<name>.json` so
/// the perf trajectory can be tracked across commits (the printed
/// [`Table`] stays the human-facing view).
///
/// Schema: `{"bench": <name>, "results": [{<config k/v as strings>,
/// "median": s, "p10": s, "p90": s, "mean": s, "min": s, "max": s,
/// "reps": n, <extra metric k/v as numbers>}, ...]}`.
pub struct JsonReport {
    name: String,
    entries: Vec<String>,
}

/// Minimal JSON string escaping (quotes, backslashes, control chars).
fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out
}

/// A finite f64 as a JSON number (non-finite → null, which JSON lacks a
/// number for).
fn json_num(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".to_string()
    }
}

impl JsonReport {
    /// Start a report for bench `name` (used in the output filename).
    pub fn new(name: &str) -> Self {
        JsonReport { name: name.to_string(), entries: Vec::new() }
    }

    /// Record one configuration: `config` are identifying key/values
    /// (e.g. `[("n", "1000"), ("B", "32")]`), `stats` the timing, and
    /// `extra` additional numeric metrics (e.g. speedup, max|Δx|).
    pub fn entry(
        &mut self,
        config: &[(&str, &str)],
        stats: &Stats,
        extra: &[(&str, f64)],
    ) {
        let mut fields: Vec<String> = config
            .iter()
            .map(|(k, v)| {
                format!("\"{}\": \"{}\"", json_escape(k), json_escape(v))
            })
            .collect();
        for (k, v) in [
            ("median", stats.median),
            ("p10", stats.p10),
            ("p90", stats.p90),
            ("mean", stats.mean),
            ("min", stats.min),
            ("max", stats.max),
        ] {
            fields.push(format!("\"{k}\": {}", json_num(v)));
        }
        fields.push(format!("\"reps\": {}", stats.reps));
        for (k, v) in extra {
            fields.push(format!(
                "\"{}\": {}",
                json_escape(k),
                json_num(*v)
            ));
        }
        self.entries.push(format!("    {{{}}}", fields.join(", ")));
    }

    /// Render the report body.
    pub fn render(&self) -> String {
        format!(
            "{{\n  \"bench\": \"{}\",\n  \"results\": [\n{}\n  ]\n}}\n",
            json_escape(&self.name),
            self.entries.join(",\n")
        )
    }

    /// Write `target/bench_json/BENCH_<name>.json`; returns the path.
    pub fn write(&self) -> std::io::Result<String> {
        let dir = std::path::Path::new("target/bench_json");
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("BENCH_{}.json", self.name));
        std::fs::write(&path, self.render())?;
        Ok(path.display().to_string())
    }

    /// Write `BENCH_<name>.json` at the repository root (found by
    /// walking up from the working directory to the first ancestor
    /// containing `.git`; falls back to the working directory). These
    /// are the *committed* perf baselines — benches write them on full
    /// (non-smoke) runs so the perf trajectory can be diffed across
    /// commits; smoke runs must not clobber them.
    pub fn write_repo_root(&self) -> std::io::Result<String> {
        let mut dir = std::env::current_dir()?;
        loop {
            if dir.join(".git").exists() {
                break;
            }
            if !dir.pop() {
                dir = std::env::current_dir()?;
                break;
            }
        }
        let path = dir.join(format!("BENCH_{}.json", self.name));
        std::fs::write(&path, self.render())?;
        Ok(path.display().to_string())
    }
}

/// Format seconds with sensible precision (paper prints seconds).
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-4 {
        format!("{:.1}us", s * 1e6)
    } else if s < 0.1 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{s:.2}s")
    }
}

/// Least-squares slope of log(y) on log(x) — scaling-exponent estimator
/// used by the Table 1 complexity bench.
pub fn loglog_slope(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let lx: Vec<f64> = xs.iter().map(|x| x.ln()).collect();
    let ly: Vec<f64> = ys.iter().map(|y| y.ln()).collect();
    let n = lx.len() as f64;
    let mx = lx.iter().sum::<f64>() / n;
    let my = ly.iter().sum::<f64>() / n;
    let cov: f64 =
        lx.iter().zip(&ly).map(|(x, y)| (x - mx) * (y - my)).sum();
    let var: f64 = lx.iter().map(|x| (x - mx) * (x - mx)).sum();
    cov / var
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bencher_runs_and_orders_stats() {
        let b = Bencher::new(1, 5);
        let s = b.run(|| {
            let mut acc = 0u64;
            for i in 0..1000 {
                acc = acc.wrapping_add(i);
            }
            acc
        });
        assert!(s.min <= s.median && s.median <= s.max);
        assert!(s.mean > 0.0);
        assert_eq!(s.reps, 5);
    }

    #[test]
    fn table_rejects_bad_arity() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        assert_eq!(t.rows.len(), 1);
    }

    #[test]
    fn stats_from_samples_percentiles() {
        let s = Stats::from_samples(&[5.0, 1.0, 3.0, 2.0, 4.0]);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.median, 3.0);
        assert_eq!(s.p10, 1.0); // nearest rank over 5 samples
        assert_eq!(s.p90, 5.0);
        assert_eq!(s.reps, 5);
        // one-sample degenerate case (run_once cells)
        let one = Stats::from_samples(&[0.25]);
        assert_eq!(one.median, 0.25);
        assert_eq!(one.p10, 0.25);
        assert_eq!(one.p90, 0.25);
        assert_eq!(one.reps, 1);
    }

    #[test]
    fn json_report_renders_valid_shape() {
        let mut r = JsonReport::new("unit_test");
        r.entry(
            &[("n", "100"), ("B", "8")],
            &Stats::from_samples(&[0.5]),
            &[("speedup", 2.0), ("bad", f64::NAN)],
        );
        let body = r.render();
        assert!(body.starts_with("{\n  \"bench\": \"unit_test\""));
        assert!(body.contains("\"n\": \"100\""));
        assert!(body.contains("\"median\": 0.5"));
        assert!(body.contains("\"p90\": 0.5"));
        assert!(body.contains("\"speedup\": 2"));
        assert!(body.contains("\"bad\": null"));
        assert!(body.contains("\"reps\": 1"));
        // braces balance (cheap well-formedness check)
        let open = body.matches('{').count();
        let close = body.matches('}').count();
        assert_eq!(open, close);
        // escaping
        assert_eq!(super::json_escape("a\"b\\c"), "a\\\"b\\\\c");
    }

    #[test]
    fn loglog_slope_recovers_cubic() {
        let xs = [100.0, 200.0, 400.0, 800.0];
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 * x * x * x).collect();
        let s = loglog_slope(&xs, &ys);
        assert!((s - 3.0).abs() < 1e-9);
    }

    #[test]
    fn fmt_secs_ranges() {
        assert!(fmt_secs(2.0).ends_with('s'));
        assert!(fmt_secs(0.005).ends_with("ms"));
        assert!(fmt_secs(5e-6).ends_with("us"));
    }
}
