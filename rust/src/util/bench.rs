//! Minimal benchmark harness (criterion is unavailable offline).
//!
//! Each `[[bench]]` target is a `harness = false` binary that uses
//! [`Bencher`] for warmup + repeated timing and [`Table`] to print the
//! paper-style rows, and writes machine-readable CSV next to the binary
//! output (`target/bench_csv/<name>.csv`).

use std::time::Instant;

/// Timing statistics over repeated runs (seconds).
#[derive(Clone, Copy, Debug)]
pub struct Stats {
    pub mean: f64,
    pub median: f64,
    pub min: f64,
    pub max: f64,
    pub reps: usize,
}

/// Repeated-measurement micro/macro benchmark runner.
pub struct Bencher {
    pub warmup: usize,
    pub reps: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher { warmup: 1, reps: 5 }
    }
}

impl Bencher {
    pub fn new(warmup: usize, reps: usize) -> Self {
        Bencher { warmup, reps }
    }

    /// Time `f`, returning stats over `reps` runs after `warmup` runs.
    /// `f` should return something cheap to keep the compiler honest.
    pub fn run<T, F: FnMut() -> T>(&self, mut f: F) -> Stats {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut times = Vec::with_capacity(self.reps);
        for _ in 0..self.reps {
            let t0 = Instant::now();
            std::hint::black_box(f());
            times.push(t0.elapsed().as_secs_f64());
        }
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = times.iter().sum::<f64>() / times.len() as f64;
        Stats {
            mean,
            median: times[times.len() / 2],
            min: times[0],
            max: times[times.len() - 1],
            reps: self.reps,
        }
    }

    /// Time one run only (for expensive end-to-end cells).
    pub fn run_once<T, F: FnOnce() -> T>(&self, f: F) -> (f64, T) {
        let t0 = Instant::now();
        let out = f();
        (t0.elapsed().as_secs_f64(), out)
    }
}

/// Fixed-width table printer mirroring the paper's layout.
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row arity");
        self.rows.push(cells.to_vec());
    }

    /// Render to stdout with aligned columns.
    pub fn print(&self) {
        let ncol = self.header.len();
        let mut widths: Vec<usize> =
            self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut out = String::new();
            for i in 0..ncol {
                out.push_str(&format!("{:>w$}  ", cells[i], w = widths[i]));
            }
            out
        };
        println!("\n== {} ==", self.title);
        println!("{}", line(&self.header));
        println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * ncol));
        for r in &self.rows {
            println!("{}", line(r));
        }
    }

    /// Write CSV to `target/bench_csv/<name>.csv`.
    pub fn write_csv(&self, name: &str) -> std::io::Result<String> {
        let dir = std::path::Path::new("target/bench_csv");
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{name}.csv"));
        let mut body = self.header.join(",") + "\n";
        for r in &self.rows {
            body.push_str(&r.join(","));
            body.push('\n');
        }
        std::fs::write(&path, body)?;
        Ok(path.display().to_string())
    }
}

/// Format seconds with sensible precision (paper prints seconds).
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-4 {
        format!("{:.1}us", s * 1e6)
    } else if s < 0.1 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{s:.2}s")
    }
}

/// Least-squares slope of log(y) on log(x) — scaling-exponent estimator
/// used by the Table 1 complexity bench.
pub fn loglog_slope(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let lx: Vec<f64> = xs.iter().map(|x| x.ln()).collect();
    let ly: Vec<f64> = ys.iter().map(|y| y.ln()).collect();
    let n = lx.len() as f64;
    let mx = lx.iter().sum::<f64>() / n;
    let my = ly.iter().sum::<f64>() / n;
    let cov: f64 =
        lx.iter().zip(&ly).map(|(x, y)| (x - mx) * (y - my)).sum();
    let var: f64 = lx.iter().map(|x| (x - mx) * (x - mx)).sum();
    cov / var
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bencher_runs_and_orders_stats() {
        let b = Bencher::new(1, 5);
        let s = b.run(|| {
            let mut acc = 0u64;
            for i in 0..1000 {
                acc = acc.wrapping_add(i);
            }
            acc
        });
        assert!(s.min <= s.median && s.median <= s.max);
        assert!(s.mean > 0.0);
        assert_eq!(s.reps, 5);
    }

    #[test]
    fn table_rejects_bad_arity() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        assert_eq!(t.rows.len(), 1);
    }

    #[test]
    fn loglog_slope_recovers_cubic() {
        let xs = [100.0, 200.0, 400.0, 800.0];
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 * x * x * x).collect();
        let s = loglog_slope(&xs, &ys);
        assert!((s - 3.0).abs() < 1e-9);
    }

    #[test]
    fn fmt_secs_ranges() {
        assert!(fmt_secs(2.0).ends_with('s'));
        assert!(fmt_secs(0.005).ends_with("ms"));
        assert!(fmt_secs(5e-6).ends_with("us"));
    }
}
