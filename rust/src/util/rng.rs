//! PCG64 (XSL-RR 128/64) pseudo-random generator + distributions.
//!
//! The offline environment has no `rand` crate; this is a small,
//! well-tested PCG implementation (O'Neill 2014) sufficient for workload
//! generation and property tests. Deterministic given a seed — every
//! benchmark and test seeds explicitly so runs are reproducible.

/// PCG64: 128-bit LCG state, XSL-RR output to 64 bits.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Seed with an arbitrary 64-bit value (stream fixed).
    pub fn new(seed: u64) -> Self {
        let mut rng = Pcg64 {
            state: 0,
            inc: (0xda3e_39cb_94b9_5bdb_u128 << 1) | 1,
        };
        rng.state = rng
            .state
            .wrapping_mul(PCG_MULT)
            .wrapping_add(rng.inc)
            .wrapping_add(seed as u128);
        rng.step();
        rng
    }

    #[inline]
    fn step(&mut self) {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
    }

    /// Next uniform u64.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = self.state;
        self.step();
        let xored = ((s >> 64) as u64) ^ (s as u64);
        let rot = (s >> 122) as u32;
        xored.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n) (Lemire-ish rejection; n > 0).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n) as usize;
            }
        }
    }

    /// Standard normal via Box–Muller (no cached spare: keeps state simple).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.uniform();
            if u1 > 1e-300 {
                let u2 = self.uniform();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Vector of standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample k distinct indices from 0..n (k <= n), sorted.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        debug_assert!(k <= n);
        // Floyd's algorithm
        let mut chosen = std::collections::BTreeSet::new();
        for j in (n - k)..n {
            let t = self.below(j + 1);
            if !chosen.insert(t) {
                chosen.insert(j);
            }
        }
        chosen.into_iter().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Pcg64::new(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_in_unit_interval_and_roughly_uniform() {
        let mut r = Pcg64::new(1);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::new(7);
        let n = 50_000;
        let xs = r.normal_vec(n);
        let mean: f64 = xs.iter().sum::<f64>() / n as f64;
        let var: f64 =
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Pcg64::new(3);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.below(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn sample_indices_distinct_sorted() {
        let mut r = Pcg64::new(9);
        let idx = r.sample_indices(50, 10);
        assert_eq!(idx.len(), 10);
        for w in idx.windows(2) {
            assert!(w[0] < w[1]);
        }
        assert!(idx.iter().all(|&i| i < 50));
    }
}
