//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and free
//! positional arguments. Typed getters with defaults; `usage()` renders a
//! help string from registered options.

use std::collections::BTreeMap;

/// Parsed command line.
pub struct Args {
    flags: BTreeMap<String, String>,
    positional: Vec<String>,
    registered: Vec<(String, String, String)>, // (name, default, help)
}

impl Args {
    /// Parse from an explicit iterator (tests) or `std::env::args()`.
    pub fn parse_from<I: IntoIterator<Item = String>>(it: I) -> Self {
        let mut flags = BTreeMap::new();
        let mut positional = Vec::new();
        let mut iter = it.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    flags.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    flags.insert(stripped.to_string(), v);
                } else {
                    flags.insert(stripped.to_string(), "true".to_string());
                }
            } else {
                positional.push(tok);
            }
        }
        Args { flags, positional, registered: Vec::new() }
    }

    /// Parse the process arguments (skipping argv[0]; also skips a bare
    /// `--bench` token that `cargo bench` appends to harness binaries).
    pub fn parse() -> Self {
        Args::parse_from(
            std::env::args().skip(1).filter(|a| a != "--bench"),
        )
    }

    /// Register an option for `usage()`.
    pub fn describe(&mut self, name: &str, default: &str, help: &str) {
        self.registered.push((
            name.to_string(),
            default.to_string(),
            help.to_string(),
        ));
    }

    /// Render a help string from the registered options.
    pub fn usage(&self, prog: &str) -> String {
        let mut s = format!("usage: {prog} [--key value]...\n");
        for (n, d, h) in &self.registered {
            s.push_str(&format!("  --{n:<18} {h} (default: {d})\n"));
        }
        s
    }

    /// Whether `--key` was passed at all.
    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    /// String value of `--key`, or `default`.
    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    /// usize value of `--key`, or `default` (also on parse failure).
    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.flags
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    /// f64 value of `--key`, or `default` (also on parse failure).
    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.flags
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    /// Boolean value of `--key` ("true"/"1"/"yes"), or `default`.
    pub fn get_bool(&self, key: &str, default: bool) -> bool {
        self.flags
            .get(key)
            .map(|v| v == "true" || v == "1" || v == "yes")
            .unwrap_or(default)
    }

    /// Comma-separated list of usize, e.g. `--sizes 100,200,400`.
    pub fn get_usize_list(&self, key: &str, default: &[usize]) -> Vec<usize> {
        match self.flags.get(key) {
            None => default.to_vec(),
            Some(v) => v
                .split(',')
                .filter_map(|t| t.trim().parse().ok())
                .collect(),
        }
    }

    /// Free (non-`--key`) arguments, in order.
    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::parse_from(toks.iter().map(|s| s.to_string()))
    }

    #[test]
    fn kv_and_eq_and_bool() {
        // note: a bare `--flag` followed by a non-flag token would consume
        // it as a value (greedy semantics) — flags go last or use `=`.
        let a = parse(&["--n", "100", "--tol=1e-3", "pos1", "--verbose"]);
        assert_eq!(a.get_usize("n", 0), 100);
        assert!((a.get_f64("tol", 0.0) - 1e-3).abs() < 1e-12);
        assert!(a.get_bool("verbose", false));
        assert_eq!(a.positional(), &["pos1".to_string()]);
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&[]);
        assert_eq!(a.get_usize("n", 7), 7);
        assert_eq!(a.get_str("mode", "fast"), "fast");
        assert!(!a.get_bool("verbose", false));
    }

    #[test]
    fn list_parsing() {
        let a = parse(&["--sizes", "10,20,30"]);
        assert_eq!(a.get_usize_list("sizes", &[1]), vec![10, 20, 30]);
        assert_eq!(a.get_usize_list("other", &[1, 2]), vec![1, 2]);
    }

    #[test]
    fn flag_before_flag_is_boolean() {
        let a = parse(&["--fast", "--n", "5"]);
        assert!(a.get_bool("fast", false));
        assert_eq!(a.get_usize("n", 0), 5);
    }
}
