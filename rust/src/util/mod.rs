//! Self-contained utilities. The offline environment lacks rand / clap /
//! criterion / serde; these modules replace exactly what this repo needs.
pub mod affinity;
pub mod args;
pub mod bench;
pub mod rng;

pub use affinity::{available_cores, pin_current_thread};
pub use args::Args;
pub use bench::{fmt_secs, Bencher, JsonReport, Stats, Table};
pub use rng::Pcg64;
