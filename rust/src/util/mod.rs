//! Self-contained utilities. The offline environment lacks rand / clap /
//! criterion / serde; these modules replace exactly what this repo needs.
pub mod args;
pub mod bench;
pub mod rng;

pub use args::Args;
pub use bench::{fmt_secs, Bencher, JsonReport, Stats, Table};
pub use rng::Pcg64;
