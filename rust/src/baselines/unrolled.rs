//! Unrolling baseline (paper §2 "Unrolling methods").
//!
//! Projected gradient descent on the simplex-constrained quadratic
//! (the sparsemax family: min ‖x − y‖² s.t. 1ᵀx = 1, x ≥ 0), with the
//! gradient of the layer obtained by *reverse-mode through the unrolled
//! iterations*. This exhibits exactly the two costs the paper attributes
//! to unrolling:
//!
//!  1. every iterate must be stored for the reverse sweep (memory grows
//!     linearly in iteration count — `peak_stored_floats` reports it);
//!  2. each forward step needs an exact projection onto the feasible set
//!     (here the O(n log n) sort-based simplex projection; for general
//!     polyhedra this is itself a QP — the reason unrolling does not
//!     scale to Alt-Diff's problem class).

use crate::linalg::Mat;

/// Exact Euclidean projection onto the simplex {x ≥ 0, 1ᵀx = 1}
/// (Held–Wolfe–Crowder / sort-based). Returns (projection, support mask).
pub fn project_simplex(v: &[f64]) -> (Vec<f64>, Vec<bool>) {
    let _n = v.len();
    let mut u: Vec<f64> = v.to_vec();
    u.sort_by(|a, b| b.partial_cmp(a).unwrap());
    let mut css = 0.0;
    let mut rho = 0;
    let mut theta = 0.0;
    for (i, &ui) in u.iter().enumerate() {
        css += ui;
        let t = (css - 1.0) / (i + 1) as f64;
        if ui - t > 0.0 {
            rho = i + 1;
            theta = t;
        }
    }
    let _ = rho;
    let x: Vec<f64> = v.iter().map(|&vi| (vi - theta).max(0.0)).collect();
    let mask: Vec<bool> = x.iter().map(|&xi| xi > 0.0).collect();
    (x, mask)
}

/// VJP of `project_simplex` at a point with support `mask`:
/// J = I_S − (1/|S|) 1_S 1_Sᵀ on the support, 0 off-support.
fn project_simplex_vjp(gbar: &[f64], mask: &[bool]) -> Vec<f64> {
    let k = mask.iter().filter(|&&b| b).count().max(1) as f64;
    let ssum: f64 = gbar
        .iter()
        .zip(mask)
        .filter(|(_, &b)| b)
        .map(|(g, _)| *g)
        .sum();
    gbar.iter()
        .zip(mask)
        .map(|(g, &b)| if b { g - ssum / k } else { 0.0 })
        .collect()
}

/// Result of the unrolled layer.
pub struct UnrolledResult {
    /// Final iterate x_T.
    pub x: Vec<f64>,
    /// dx/dy (n×n) for the sparsemax objective min ‖x − y‖².
    pub jacobian: Mat,
    /// Forward iterations unrolled.
    pub iters: usize,
    /// floats retained for the reverse sweep (the memory cost).
    pub peak_stored_floats: usize,
}

/// Unrolled PGD sparsemax: forward stores every support mask, backward
/// reverse-propagates an identity seed to build the full Jacobian dx/dy.
///
/// step x_{t+1} = Π(x_t − η(2x_t − 2y)):  linear map between projections,
/// so the reverse sweep composes (I − η·2I) with the projection VJPs.
pub fn unrolled_sparsemax(
    y: &[f64],
    eta: f64,
    iters: usize,
    tol: f64,
) -> UnrolledResult {
    let n = y.len();
    let mut x = vec![1.0 / n as f64; n];
    let mut masks: Vec<Vec<bool>> = Vec::with_capacity(iters);
    let mut used = 0;
    for _ in 0..iters {
        let pre: Vec<f64> = x
            .iter()
            .zip(y)
            .map(|(xi, yi)| xi - eta * (2.0 * xi - 2.0 * yi))
            .collect();
        let (xn, mask) = project_simplex(&pre);
        let dx: f64 = xn
            .iter()
            .zip(&x)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt();
        x = xn;
        masks.push(mask);
        used += 1;
        if dx < tol {
            break;
        }
    }
    // reverse sweep: for each output coordinate seed e_i, propagate
    // v ← (1 − 2η) Πᵀv  backwards; the y-gradient accumulates 2η Πᵀv at
    // every step. (All iterates' masks required → the memory cost.)
    let mut jac = Mat::zeros(n, n);
    for seed in 0..n {
        let mut v = vec![0.0; n];
        v[seed] = 1.0;
        let mut gy = vec![0.0; n];
        for mask in masks[..used].iter().rev() {
            let pv = project_simplex_vjp(&v, mask);
            for i in 0..n {
                gy[i] += 2.0 * eta * pv[i];
                v[i] = (1.0 - 2.0 * eta) * pv[i];
            }
        }
        for i in 0..n {
            jac[(seed, i)] = gy[i];
        }
    }
    // jac rows currently = d x_seed / d y_i — already (n,n) as desired.
    UnrolledResult {
        x,
        jacobian: jac,
        iters: used,
        peak_stored_floats: used * n, // one mask per iteration (as bytes ~ n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn projection_is_simplex_point_and_idempotent() {
        let v = vec![0.5, -1.0, 2.0, 0.1];
        let (x, _) = project_simplex(&v);
        let sum: f64 = x.iter().sum();
        assert!((sum - 1.0).abs() < 1e-12);
        assert!(x.iter().all(|&xi| xi >= 0.0));
        let (x2, _) = project_simplex(&x);
        for i in 0..4 {
            assert!((x[i] - x2[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn projection_of_simplex_interior_point_is_identity() {
        let v = vec![0.25, 0.25, 0.25, 0.25];
        let (x, mask) = project_simplex(&v);
        assert_eq!(x, v);
        assert!(mask.iter().all(|&b| b));
    }

    #[test]
    fn unrolled_matches_sparsemax_fixed_point() {
        // The unrolled PGD solves min ‖x−y‖² on the simplex = sparsemax(y).
        let y = vec![0.3, -0.1, 0.9, 0.05, -0.4];
        let r = unrolled_sparsemax(&y, 0.25, 2000, 1e-12);
        // compare with direct projection of y (sparsemax(y) = Π(y))
        let (want, _) = project_simplex(&y);
        for i in 0..5 {
            assert!(
                (r.x[i] - want[i]).abs() < 1e-6,
                "x[{i}]={} want {}",
                r.x[i],
                want[i]
            );
        }
    }

    #[test]
    fn unrolled_jacobian_matches_finite_difference() {
        let y = vec![0.3, -0.1, 0.9, 0.05, -0.4];
        let r = unrolled_sparsemax(&y, 0.25, 4000, 1e-13);
        let eps = 1e-6;
        for c in 0..5 {
            let mut yp = y.clone();
            yp[c] += eps;
            let mut ym = y.clone();
            ym[c] -= eps;
            let xp = unrolled_sparsemax(&yp, 0.25, 4000, 1e-13).x;
            let xm = unrolled_sparsemax(&ym, 0.25, 4000, 1e-13).x;
            for i in 0..5 {
                let fd = (xp[i] - xm[i]) / (2.0 * eps);
                assert!(
                    (r.jacobian[(i, c)] - fd).abs() < 1e-4,
                    "J[{i},{c}]={} fd={fd}",
                    r.jacobian[(i, c)]
                );
            }
        }
    }

    #[test]
    fn memory_grows_with_iterations() {
        let y = vec![0.5, 0.2, -0.3, 0.8];
        let short = unrolled_sparsemax(&y, 0.05, 10, 0.0);
        let long = unrolled_sparsemax(&y, 0.05, 100, 0.0);
        assert!(long.peak_stored_floats > 5 * short.peak_stored_floats);
    }
}
