//! Primal-dual interior-point QP solver — the forward pass of the
//! OptNet-style baseline (qpth solves QPs with a dense primal-dual IPM).
//!
//! Mehrotra-lite: Newton on the perturbed KKT system with a single
//! centering parameter, fraction-to-boundary step, dense LU of the full
//! (n+p+2m) system each iteration — i.e. exactly the O(T(n+n_c)³) forward
//! cost that Table 1 attributes to the KKT-differentiation school.

use crate::error::{AltDiffError, Result};
use crate::linalg::{gemv, gemv_t, norm2, Lu, Mat};
use crate::prob::Qp;

/// IPM outcome: primal + duals (ν ≥ 0 for Gx ≤ h) and iteration count.
#[derive(Clone, Debug)]
pub struct IpmSolution {
    /// Primal minimizer x*.
    pub x: Vec<f64>,
    /// Equality duals λ.
    pub lam: Vec<f64>,
    /// Inequality duals ν ≥ 0.
    pub nu: Vec<f64>,
    /// slack t = h − Gx > 0
    pub t: Vec<f64>,
    /// Newton iterations run.
    pub iters: usize,
}

/// Solve the QP to tolerance `tol` on the KKT residual.
pub fn solve(qp: &Qp, tol: f64, max_iter: usize) -> Result<IpmSolution> {
    let n = qp.n();
    let p = qp.p_eq();
    let m = qp.m_ineq();
    // strictly feasible-ish start: x = 0, t = max(h - Gx, 1), nu = 1
    let mut x = vec![0.0; n];
    let mut lam = vec![0.0; p];
    let gx = gemv(&qp.g, &x);
    let mut t: Vec<f64> =
        gx.iter().zip(&qp.h).map(|(g, h)| (h - g).max(1.0)).collect();
    let mut nu = vec![1.0; m];

    let dim = n + p + 2 * m;
    for it in 0..max_iter {
        // residuals
        // r_dual = Px + q + Aᵀλ + Gᵀν
        let mut r_dual = gemv(&qp.p, &x);
        crate::linalg::axpy(&mut r_dual, 1.0, &qp.q);
        let atl = gemv_t(&qp.a, &lam);
        let gtn = gemv_t(&qp.g, &nu);
        crate::linalg::axpy(&mut r_dual, 1.0, &atl);
        crate::linalg::axpy(&mut r_dual, 1.0, &gtn);
        // r_pri_eq = Ax - b ; r_pri_in = Gx + t - h
        let mut r_eq = gemv(&qp.a, &x);
        for i in 0..p {
            r_eq[i] -= qp.b[i];
        }
        let gx = gemv(&qp.g, &x);
        let mut r_in = vec![0.0; m];
        for i in 0..m {
            r_in[i] = gx[i] + t[i] - qp.h[i];
        }
        // complementarity μ and centering
        let mu: f64 =
            t.iter().zip(&nu).map(|(ti, ni)| ti * ni).sum::<f64>() / m as f64;
        let res = norm2(&r_dual) + norm2(&r_eq) + norm2(&r_in) + mu;
        if res < tol {
            return Ok(IpmSolution { x, lam, nu, t, iters: it });
        }
        let sigma = 0.1;
        // Newton system on [dx, dλ, dν, dt]:
        //   P dx + Aᵀ dλ + Gᵀ dν = -r_dual
        //   A dx                  = -r_eq
        //   G dx + dt             = -r_in
        //   T dν + N dt           = -(T N 1 - σμ 1)
        let mut kkt = Mat::zeros(dim, dim);
        let mut rhs = vec![0.0; dim];
        for i in 0..n {
            for j in 0..n {
                kkt[(i, j)] = qp.p[(i, j)];
            }
            for j in 0..p {
                kkt[(i, n + j)] = qp.a[(j, i)];
            }
            for j in 0..m {
                kkt[(i, n + p + j)] = qp.g[(j, i)];
            }
            rhs[i] = -r_dual[i];
        }
        for i in 0..p {
            for j in 0..n {
                kkt[(n + i, j)] = qp.a[(i, j)];
            }
            rhs[n + i] = -r_eq[i];
        }
        for i in 0..m {
            for j in 0..n {
                kkt[(n + p + i, j)] = qp.g[(i, j)];
            }
            kkt[(n + p + i, n + p + m + i)] = 1.0;
            rhs[n + p + i] = -r_in[i];
        }
        for i in 0..m {
            kkt[(n + p + m + i, n + p + i)] = t[i];
            kkt[(n + p + m + i, n + p + m + i)] = nu[i];
            rhs[n + p + m + i] = -(t[i] * nu[i] - sigma * mu);
        }
        let lu = Lu::factor(&kkt)?;
        let d = lu.solve(&rhs);
        // fraction to boundary
        let mut alpha: f64 = 1.0;
        for i in 0..m {
            let dnu = d[n + p + i];
            let dt = d[n + p + m + i];
            if dnu < 0.0 {
                alpha = alpha.min(-0.99 * nu[i] / dnu);
            }
            if dt < 0.0 {
                alpha = alpha.min(-0.99 * t[i] / dt);
            }
        }
        for i in 0..n {
            x[i] += alpha * d[i];
        }
        for i in 0..p {
            lam[i] += alpha * d[n + i];
        }
        for i in 0..m {
            nu[i] += alpha * d[n + p + i];
            t[i] += alpha * d[n + p + m + i];
        }
    }
    Err(AltDiffError::NoConvergence {
        iters: max_iter,
        residual: f64::NAN,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prob::dense_qp;

    #[test]
    fn ipm_reaches_kkt_point() {
        let qp = dense_qp(15, 8, 3, 1);
        let sol = solve(&qp, 1e-8, 100).unwrap();
        let r = qp.kkt_residual(&sol.x, &sol.lam, &sol.nu);
        assert!(r < 1e-5, "kkt residual {r}");
        assert!(sol.nu.iter().all(|&v| v > -1e-10));
        assert!(sol.t.iter().all(|&v| v > -1e-10));
    }

    #[test]
    fn ipm_matches_altdiff_solution() {
        let qp = dense_qp(12, 6, 2, 2);
        let ipm = solve(&qp, 1e-9, 100).unwrap();
        let ad = crate::altdiff::DenseAltDiff::new(qp, 1.0).unwrap();
        let sol = ad.solve(&crate::altdiff::Options {
            tol: 1e-10,
            max_iter: 50_000,
            backward: crate::altdiff::BackwardMode::None,
            ..Default::default()
        });
        for i in 0..12 {
            assert!(
                (ipm.x[i] - sol.x[i]).abs() < 1e-4,
                "x[{i}]: ipm {} altdiff {}",
                ipm.x[i],
                sol.x[i]
            );
        }
    }

    #[test]
    fn ipm_tiny_analytic() {
        // min x² s.t. x >= 1  →  x* = 1  (written as -x <= -1)
        let qp = Qp {
            p: Mat::diag(&[2.0]),
            q: vec![0.0],
            a: Mat::zeros(0, 1),
            b: vec![],
            g: Mat::from_rows(&[&[-1.0]]),
            h: vec![-1.0],
        };
        let sol = solve(&qp, 1e-10, 100).unwrap();
        assert!((sol.x[0] - 1.0).abs() < 1e-6);
        assert!((sol.nu[0] - 2.0).abs() < 1e-4); // ν* = 2 (stationarity)
    }
}
