//! Implicit differentiation of the KKT conditions (paper Appendix C.1,
//! eq. 25) — the OptNet/CvxpyLayer backward semantics that Alt-Diff is
//! benchmarked against.
//!
//! J_z dz/dθ = -J_θ with z = (x, λ, ν) and
//!     J_z = [ ∇²f        Aᵀ              Gᵀ            ]
//!           [ A          0               0             ]
//!           [ diag(ν)G   0               diag(Gx - h)  ]
//! One dense (n+p+m) LU factorization; O((n+n_c)³) — the cost Table 1
//! assigns to this school of methods.

use crate::altdiff::Param;
use crate::error::Result;
use crate::linalg::{gemv, Lu, Mat};
use crate::prob::Qp;

/// ∂x*/∂θ via KKT implicit differentiation at the solution (x, λ, ν).
pub fn kkt_jacobian(
    qp: &Qp,
    x: &[f64],
    _lam: &[f64],
    nu: &[f64],
    param: Param,
) -> Result<Mat> {
    let n = qp.n();
    let p = qp.p_eq();
    let m = qp.m_ineq();
    let dim = n + p + m;
    let d = param.dim(n, m, p);

    let gx = gemv(&qp.g, x);
    let mut jz = Mat::zeros(dim, dim);
    for i in 0..n {
        for j in 0..n {
            jz[(i, j)] = qp.p[(i, j)];
        }
        for j in 0..p {
            jz[(i, n + j)] = qp.a[(j, i)];
        }
        for j in 0..m {
            jz[(i, n + p + j)] = qp.g[(j, i)];
        }
    }
    for i in 0..p {
        for j in 0..n {
            jz[(n + i, j)] = qp.a[(i, j)];
        }
    }
    for i in 0..m {
        for j in 0..n {
            jz[(n + p + i, j)] = nu[i] * qp.g[(i, j)];
        }
        jz[(n + p + i, n + p + i)] = gx[i] - qp.h[i];
    }
    // strict-complementarity boundary regularization (qpth/diffcp do the
    // same in spirit): keeps the factorization well-posed when an
    // inequality is weakly active.
    for i in 0..dim {
        jz[(i, i)] += if i < n { 0.0 } else { -1e-10 };
    }

    // -J_θ columns
    let mut jt = Mat::zeros(dim, d);
    match param {
        Param::Q => {
            // ∂(∇f + q)/∂q = I in the stationarity block
            for i in 0..n {
                jt[(i, i)] = 1.0;
            }
        }
        Param::B => {
            // ∂(Ax - b)/∂b = -I in the equality block
            for i in 0..p {
                jt[(n + i, i)] = -1.0;
            }
        }
        Param::H => {
            // ∂[diag(ν)(Gx - h)]/∂h = -diag(ν)
            for i in 0..m {
                jt[(n + p + i, i)] = -nu[i];
            }
        }
    }
    let lu = Lu::factor(&jz)?;
    let mut dz = lu.solve_mat(&jt);
    dz.scale(-1.0);
    // top n rows = dx/dθ
    let mut out = Mat::zeros(n, d);
    for i in 0..n {
        out.row_mut(i).copy_from_slice(dz.row(i));
    }
    Ok(out)
}

/// Full OptNet-style layer evaluation: IPM forward + KKT backward.
/// Returns (x, jacobian, forward_iters).
pub fn optnet_layer(
    qp: &Qp,
    param: Param,
    tol: f64,
) -> Result<(Vec<f64>, Mat, usize)> {
    let sol = super::ipm::solve(qp, tol, 200)?;
    let j = kkt_jacobian(qp, &sol.x, &sol.lam, &sol.nu, param)?;
    Ok((sol.x, j, sol.iters))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::altdiff::{BackwardMode, DenseAltDiff, Options};
    use crate::linalg::cosine;
    use crate::prob::dense_qp;

    #[test]
    fn kkt_jacobian_matches_altdiff_thm42() {
        // Thm 4.2: Alt-Diff converges to the KKT-implicit gradient.
        let qp = dense_qp(14, 7, 3, 11);
        for param in [Param::B, Param::Q, Param::H] {
            let (_, jk, _) = optnet_layer(&qp, param, 1e-10).unwrap();
            let ad = DenseAltDiff::new(qp.clone(), 1.0).unwrap();
            let ja = ad
                .solve(&Options {
                    tol: 1e-12,
                    max_iter: 60_000,
                    backward: BackwardMode::Forward(param),
                    ..Default::default()
                })
                .jacobian
                .unwrap();
            let cos = cosine(&jk.data, &ja.data);
            assert!(cos > 0.999, "param {param:?}: cosine {cos}");
        }
    }

    #[test]
    fn kkt_jacobian_b_finite_difference() {
        let qp = dense_qp(10, 5, 2, 12);
        let (_, j, _) = optnet_layer(&qp, Param::B, 1e-10).unwrap();
        let eps = 1e-5;
        for c in 0..2 {
            let mut qpp = qp.clone();
            qpp.b[c] += eps;
            let mut qpm = qp.clone();
            qpm.b[c] -= eps;
            let xp = super::super::ipm::solve(&qpp, 1e-11, 200).unwrap().x;
            let xm = super::super::ipm::solve(&qpm, 1e-11, 200).unwrap().x;
            for i in 0..10 {
                let fd = (xp[i] - xm[i]) / (2.0 * eps);
                assert!(
                    (j[(i, c)] - fd).abs() < 2e-3 * (1.0 + fd.abs()),
                    "J[{i},{c}]={} fd={fd}",
                    j[(i, c)]
                );
            }
        }
    }
}
