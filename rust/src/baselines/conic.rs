//! CvxpyLayer-style comparator (simulated — see DESIGN.md §8).
//!
//! CvxpyLayer canonicalizes the program into cone form, solves it with an
//! operator-splitting conic solver (SCS), and differentiates the *cone
//! program* — all at the embedded dimension. We reproduce that pipeline
//! and its phase structure:
//!
//!   canonicalize : embed z = (x, s), Ã z = (b, h), cone s ≥ 0  — O(nnz)
//!   initialize   : factor the embedded (n+m)-dim operator       — O((n+m)³)
//!   forward      : ADMM on the embedded program                 — O(T(n+m)²)
//!   backward     : implicit diff of the embedded KKT system     — O((n+2m+p)³)
//!
//! The embedded sizes are what make CvxpyLayer the slowest column of the
//! paper's Tables 2/4/5: every phase pays for n + n_c, never just n.

use crate::altdiff::{BackwardMode, DenseAltDiff, Options, Param};
use crate::baselines::kkt_diff;
use crate::error::Result;
use crate::linalg::Mat;
use crate::prob::Qp;
use std::time::Instant;

/// Phase timing breakdown (the per-row structure of Tables 2/4/5).
#[derive(Clone, Copy, Debug, Default)]
pub struct Phases {
    /// Canonicalization seconds.
    pub canon: f64,
    /// Initialization seconds.
    pub init: f64,
    /// Forward-solve seconds.
    pub forward: f64,
    /// Backward (differentiation) seconds.
    pub backward: f64,
}

impl Phases {
    /// Sum of all phases.
    pub fn total(&self) -> f64 {
        self.canon + self.init + self.forward + self.backward
    }
}

/// Result of one layer evaluation through the conic pipeline.
pub struct ConicResult {
    /// Primal minimizer (original variables).
    pub x: Vec<f64>,
    /// ∂x/∂θ for the requested parameter.
    pub jacobian: Mat,
    /// Interior-point iterations of the embedded solve.
    pub iters: usize,
    /// Where the time went.
    pub phases: Phases,
}

/// Embed the QP into the slack cone form.
///
/// z = (x, s) ∈ R^{n+m};  min ½zᵀP̃z + q̃ᵀz
/// s.t. [A 0; G I] z = (b, h)   and   −s ≤ 0.
fn canonicalize(qp: &Qp, eps_reg: f64) -> Qp {
    let n = qp.n();
    let m = qp.m_ineq();
    let p = qp.p_eq();
    let nz = n + m;
    let mut pt = Mat::zeros(nz, nz);
    for i in 0..n {
        for j in 0..n {
            pt[(i, j)] = qp.p[(i, j)];
        }
    }
    for i in n..nz {
        pt[(i, i)] = eps_reg; // keep P̃ SPD on the slack block
    }
    let mut qt = vec![0.0; nz];
    qt[..n].copy_from_slice(&qp.q);
    let mut at = Mat::zeros(p + m, nz);
    for i in 0..p {
        for j in 0..n {
            at[(i, j)] = qp.a[(i, j)];
        }
    }
    for i in 0..m {
        for j in 0..n {
            at[(p + i, j)] = qp.g[(i, j)];
        }
        at[(p + i, n + i)] = 1.0;
    }
    let mut bt = vec![0.0; p + m];
    bt[..p].copy_from_slice(&qp.b);
    bt[p..].copy_from_slice(&qp.h);
    // cone: s >= 0  ⇔  -z_{n+i} <= 0
    let mut gt = Mat::zeros(m, nz);
    for i in 0..m {
        gt[(i, n + i)] = -1.0;
    }
    Qp { p: pt, q: qt, a: at, b: bt, g: gt, h: vec![0.0; m] }
}

/// Evaluate the layer through the simulated CvxpyLayer pipeline.
/// `param` refers to the ORIGINAL problem's parameters; only the x-block
/// of the embedded Jacobian is returned.
pub fn cvxpylayer_sim(
    qp: &Qp,
    param: Param,
    tol: f64,
) -> Result<ConicResult> {
    let n = qp.n();
    let m = qp.m_ineq();
    let p = qp.p_eq();
    let mut ph = Phases::default();

    let t0 = Instant::now();
    let emb = canonicalize(qp, 1e-6);
    ph.canon = t0.elapsed().as_secs_f64();

    let t0 = Instant::now();
    // "initialization": factor the embedded operator (SCS caches an LDL of
    // the full system; our splitting solver caches the (n+m) Hessian).
    let solver = DenseAltDiff::new(emb.clone(), 1.0)?;
    ph.init = t0.elapsed().as_secs_f64();

    let t0 = Instant::now();
    let sol = solver.solve(&Options {
        tol,
        max_iter: 20_000,
        backward: BackwardMode::None,
        ..Default::default()
    });
    ph.forward = t0.elapsed().as_secs_f64();

    // backward: implicit differentiation at the embedded size. The
    // embedded duals for the cone rows come from the splitting solver.
    let t0 = Instant::now();
    let emb_param = match param {
        Param::Q => Param::Q, // q̃ = (q, 0): first n columns
        Param::B => Param::B, // b̃ = (b, h): first p columns
        Param::H => Param::B, // h lives in b̃ columns p..p+m
    };
    let jfull = kkt_diff::kkt_jacobian(
        &emb, &sol.x, &sol.lam, &sol.nu, emb_param,
    )?;
    // slice x-rows and the columns of the original parameter
    let (col_off, d) = match param {
        Param::Q => (0usize, n),
        Param::B => (0usize, p),
        Param::H => (p, m),
    };
    let mut j = Mat::zeros(n, d);
    for i in 0..n {
        for c in 0..d {
            j[(i, c)] = jfull[(i, col_off + c)];
        }
    }
    ph.backward = t0.elapsed().as_secs_f64();

    Ok(ConicResult {
        x: sol.x[..n].to_vec(),
        jacobian: j,
        iters: sol.iters,
        phases: ph,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::cosine;
    use crate::prob::dense_qp;

    #[test]
    fn embedded_solution_matches_direct() {
        let qp = dense_qp(10, 5, 2, 21);
        let res = cvxpylayer_sim(&qp, Param::B, 1e-9).unwrap();
        let direct = crate::altdiff::DenseAltDiff::new(qp.clone(), 1.0)
            .unwrap()
            .solve(&Options {
                tol: 1e-10,
                max_iter: 50_000,
                backward: BackwardMode::None,
                ..Default::default()
            });
        for i in 0..10 {
            assert!(
                (res.x[i] - direct.x[i]).abs() < 1e-4,
                "x[{i}]: {} vs {}",
                res.x[i],
                direct.x[i]
            );
        }
    }

    #[test]
    fn embedded_jacobian_matches_altdiff() {
        let qp = dense_qp(10, 5, 2, 22);
        for param in [Param::B, Param::Q] {
            let res = cvxpylayer_sim(&qp, param, 1e-10).unwrap();
            let ja = crate::altdiff::DenseAltDiff::new(qp.clone(), 1.0)
                .unwrap()
                .solve(&Options {
                    tol: 1e-12,
                    max_iter: 60_000,
                    backward: BackwardMode::Forward(param),
                    ..Default::default()
                })
                .jacobian
                .unwrap();
            let cos = cosine(&res.jacobian.data, &ja.data);
            assert!(cos > 0.995, "{param:?}: cosine {cos}");
        }
    }

    #[test]
    fn phases_are_populated() {
        let qp = dense_qp(8, 4, 2, 23);
        let res = cvxpylayer_sim(&qp, Param::B, 1e-8).unwrap();
        assert!(res.phases.init > 0.0);
        assert!(res.phases.forward > 0.0);
        assert!(res.phases.backward > 0.0);
        assert!(res.phases.total() >= res.phases.forward);
        assert!(res.iters > 0);
    }
}
