//! The comparators the paper benchmarks Alt-Diff against.
//!
//! - [`ipm`] + [`kkt_diff`]: OptNet semantics (IPM forward, implicit KKT
//!   differentiation backward) — dense O((n+n_c)³).
//! - [`conic`]: CvxpyLayer semantics (canonicalize → embedded cone solve →
//!   embedded implicit differentiation), with the phase breakdown the
//!   paper's tables report.
//! - [`unrolled`]: reverse-mode through unrolled projected gradient
//!   descent (the §2 "unrolling methods" school).
pub mod conic;
pub mod ipm;
pub mod kkt_diff;
pub mod unrolled;

pub use conic::{cvxpylayer_sim, ConicResult, Phases};
pub use ipm::{solve as ipm_solve, IpmSolution};
pub use kkt_diff::{kkt_jacobian, optnet_layer};
pub use unrolled::{unrolled_sparsemax, UnrolledResult};
