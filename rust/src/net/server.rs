//! Nonblocking TCP front end multiplexing N connections onto one
//! [`Coordinator`].
//!
//! Single poll-style event loop (no tokio — the crate is
//! dependency-free, and the work per tick is bounded):
//!
//! ```text
//!   accept ──▶ read ready conns ──▶ decode frames ──▶ admission
//!                                                       │ admit: Coordinator::submit
//!                                                       │ shed:  Failure::Overloaded
//!   Coordinator::try_recv ──▶ re-encode with client id ──▶ per-conn WriteBuf
//!                                                       └ flush (partial writes kept)
//! ```
//!
//! **Admission control.** At most [`NetConfig::max_inflight`] admitted
//! requests may be outstanding inside the coordinator at once. A request
//! that arrives at budget is answered *immediately* with
//! [`FailureKind::Overloaded`] — the connection is never stalled and
//! never dropped, so clients can tell "back off" from "broken".
//!
//! **Write backpressure.** Replies queue per connection in a
//! [`WriteBuf`]; when a connection's buffer exceeds
//! [`NetConfig::write_backpressure`] the loop stops *reading* from that
//! connection, the kernel receive buffer fills, and TCP pushes back on
//! the client — a slow reader throttles only itself.
//!
//! **Graceful drain.** On stop (the wire `STOP` op or the shared stop
//! flag) the server stops accepting and admitting, waits for in-flight
//! replies (bounded by [`NetConfig::drain_timeout`]), answers anything
//! still unreplied with [`FailureKind::Shutdown`], sends every open
//! connection a goodbye frame, and only then shuts the coordinator down
//! — which itself flushes queued batches (see
//! [`Coordinator::shutdown`]).

use super::frame::{FrameReader, WriteBuf};
use super::proto::{self, op};
use crate::coordinator::{
    class_budget, Coordinator, Failure, FailureKind, Priority, Reply,
};
use crate::error::Result;
use crate::obs::Stage;
use std::collections::BTreeMap;
use std::io::Read;
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Front-end tuning knobs.
#[derive(Clone, Debug)]
pub struct NetConfig {
    /// Admission budget: max requests admitted to the coordinator and
    /// not yet answered. Arrivals beyond it are shed with
    /// [`FailureKind::Overloaded`].
    pub max_inflight: usize,
    /// Max simultaneous connections; extras get a goodbye frame and an
    /// immediate close.
    pub max_conns: usize,
    /// Per-connection write-buffer size (bytes) past which the server
    /// stops reading from that connection until it drains.
    pub write_backpressure: usize,
    /// How long a graceful drain may wait for in-flight replies before
    /// answering the stragglers with [`FailureKind::Shutdown`].
    pub drain_timeout: Duration,
    /// Base event-loop sleep when a tick made no progress. Consecutive
    /// idle ticks back off to 10× this value, so an idle server's
    /// per-connection read() scanning costs bounded CPU while the
    /// first request after a lull sees at most ~10× this latency.
    pub idle_sleep: Duration,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            max_inflight: 256,
            max_conns: 128,
            write_backpressure: 1 << 20,
            drain_timeout: Duration::from_secs(10),
            idle_sleep: Duration::from_micros(300),
        }
    }
}

/// What the first byte of a connection said it speaks.
#[derive(Clone, Copy, PartialEq)]
enum ConnMode {
    /// No bytes seen yet.
    Unknown,
    /// The framed binary protocol (including garbage that fails frame
    /// validation — malformed peers keep the framed error path).
    Framed,
    /// An HTTP/1.x scrape (`GET /metrics`, `GET /healthz`): first byte
    /// was an ASCII uppercase method letter, which no valid frame
    /// starts with (the magic is 0xAD).
    Http,
}

/// Routing record for one admitted request: which connection to answer
/// on, the client's correlation id, and the observability plane's
/// per-request choices (stage echo opt-in, priority class for the
/// stage-histogram labels).
struct Route {
    cid: u64,
    client_id: u64,
    echo: bool,
    class: Priority,
}

/// Per-connection state.
struct Conn {
    stream: TcpStream,
    reader: FrameReader,
    wbuf: WriteBuf,
    /// stop reading, close once the write buffer drains and no
    /// admitted request is still owed a reply
    closing: bool,
    /// peer closed its write side (or errored). Half-close is a legal
    /// client pattern (send → `shutdown(SHUT_WR)` → read the reply),
    /// so an eof connection is reaped only once `inflight` replies
    /// have been delivered.
    eof: bool,
    /// requests admitted from this connection and not yet answered
    inflight: usize,
    /// sent the STOP op and is owed the post-drain stats ack — kept
    /// alive through the drain even if half-closed
    awaiting_stop_ack: bool,
    /// protocol this connection speaks (sniffed from its first byte)
    mode: ConnMode,
    /// buffered HTTP request bytes (Http mode only)
    http_buf: Vec<u8>,
    /// when the write-backpressure gate first parked this connection
    /// with bytes already buffered — frames decoded after the gate
    /// lifts aged this long before decode, which is the pre-decode
    /// deadline checkpoint's clock
    parked_since: Option<Instant>,
}

impl Conn {
    fn push_reply(&mut self, reply: &Reply) {
        self.wbuf.push(&proto::encode_reply(reply));
    }
}

/// A bound, not-yet-running network server. [`NetServer::run`] consumes
/// it and gives the [`Coordinator`] back after the graceful drain so
/// callers can inspect final metrics.
pub struct NetServer {
    listener: TcpListener,
    coord: Coordinator,
    cfg: NetConfig,
    stop: Arc<AtomicBool>,
}

impl NetServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"`) in nonblocking mode.
    pub fn bind<A: ToSocketAddrs>(
        addr: A,
        coord: Coordinator,
        cfg: NetConfig,
    ) -> Result<Self> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        Ok(NetServer {
            listener,
            coord,
            cfg,
            stop: Arc::new(AtomicBool::new(false)),
        })
    }

    /// The actually-bound address (resolves `:0` to the chosen port).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Shared stop flag: set it from any thread (a timer, a test, a
    /// signal handler) to trigger the graceful drain.
    pub fn stop_handle(&self) -> Arc<AtomicBool> {
        self.stop.clone()
    }

    /// Run the event loop until a stop is requested, then drain
    /// gracefully and return the coordinator (already shut down) for
    /// final metrics inspection.
    pub fn run(self) -> Coordinator {
        let NetServer { listener, mut coord, cfg, stop } = self;
        let metrics = coord.metrics.clone();
        let mut conns: BTreeMap<u64, Conn> = BTreeMap::new();
        let mut next_conn: u64 = 0;
        // coordinator request id → reply routing record
        let mut routes: BTreeMap<u64, Route> = BTreeMap::new();
        let mut inflight: usize = 0;
        // connections owed the post-drain stats reply to a STOP op
        let mut stop_acks: Vec<u64> = Vec::new();
        let mut draining = false;
        let mut drain_start: Option<Instant> = None;
        let mut idle_ticks: u32 = 0;
        let mut scratch = vec![0u8; 64 * 1024];

        loop {
            let mut progress = false;
            if stop.load(Ordering::SeqCst) {
                draining = true;
            }

            // --- accept ------------------------------------------------
            if !draining {
                loop {
                    match listener.accept() {
                        Ok((stream, _peer)) => {
                            progress = true;
                            if stream.set_nonblocking(true).is_err() {
                                continue;
                            }
                            let _ = stream.set_nodelay(true);
                            let mut conn = Conn {
                                stream,
                                reader: FrameReader::new(),
                                wbuf: WriteBuf::new(),
                                closing: false,
                                eof: false,
                                inflight: 0,
                                awaiting_stop_ack: false,
                                mode: ConnMode::Unknown,
                                http_buf: Vec::new(),
                                parked_since: None,
                            };
                            if conns.len() >= cfg.max_conns {
                                conn.wbuf.push(&proto::encode_goodbye(
                                    "connection limit reached",
                                ));
                                conn.closing = true;
                            }
                            next_conn += 1;
                            conns.insert(next_conn, conn);
                        }
                        Err(e)
                            if e.kind()
                                == std::io::ErrorKind::WouldBlock =>
                        {
                            break
                        }
                        Err(e)
                            if e.kind()
                                == std::io::ErrorKind::Interrupted => {}
                        Err(_) => break,
                    }
                }
            }

            // --- read + decode + admit ---------------------------------
            for (&cid, conn) in conns.iter_mut() {
                if conn.closing || conn.eof {
                    continue;
                }
                // backpressure: a connection over its write budget is
                // not read until the peer drains what it already owes.
                // Frames already buffered in the reader park with it —
                // note when, so their deadline clock keeps running.
                if conn.wbuf.len() > cfg.write_backpressure {
                    if conn.reader.buffered() > 0
                        && conn.parked_since.is_none()
                    {
                        conn.parked_since = Some(Instant::now());
                    }
                    continue;
                }
                let parked_for = conn
                    .parked_since
                    .take()
                    .map(|t| t.elapsed())
                    .unwrap_or(Duration::ZERO);
                // bounded read burst so one firehose connection cannot
                // starve the tick
                for _ in 0..16 {
                    match conn.stream.read(&mut scratch) {
                        Ok(0) => {
                            conn.eof = true;
                            break;
                        }
                        Ok(n) => {
                            progress = true;
                            // first byte decides the protocol: frames
                            // start 0xAD, HTTP methods start with an
                            // ASCII uppercase letter; anything else
                            // keeps the framed (error) path
                            if conn.mode == ConnMode::Unknown {
                                conn.mode =
                                    if scratch[0].is_ascii_uppercase() {
                                        ConnMode::Http
                                    } else {
                                        ConnMode::Framed
                                    };
                            }
                            if conn.mode == ConnMode::Http {
                                conn.http_buf
                                    .extend_from_slice(&scratch[..n]);
                            } else {
                                conn.reader.extend(&scratch[..n]);
                            }
                            if n < scratch.len() {
                                break;
                            }
                        }
                        Err(e)
                            if e.kind()
                                == std::io::ErrorKind::WouldBlock =>
                        {
                            break
                        }
                        Err(e)
                            if e.kind()
                                == std::io::ErrorKind::Interrupted => {}
                        Err(_) => {
                            conn.eof = true;
                            break;
                        }
                    }
                }
                if conn.mode == ConnMode::Http {
                    handle_http(conn, &coord, draining);
                    continue;
                }
                loop {
                    match conn.reader.next_frame() {
                        Ok(None) => break,
                        Ok(Some(frame)) => {
                            handle_frame(
                                frame.op,
                                &frame.payload,
                                cid,
                                conn,
                                &mut coord,
                                &mut routes,
                                &mut inflight,
                                &mut stop_acks,
                                &cfg,
                                &mut draining,
                                parked_for,
                            );
                            if conn.closing {
                                break;
                            }
                        }
                        Err(e) => {
                            // framing is unrecoverable: answer with a
                            // protocol failure and close after flush —
                            // the coordinator never saw this request,
                            // so nothing is poisoned
                            metrics
                                .failures
                                .fetch_add(1, Ordering::Relaxed);
                            conn.push_reply(&Reply::Err(Failure::new(
                                0,
                                FailureKind::Invalid,
                                format!("{e}"),
                            )));
                            conn.closing = true;
                            break;
                        }
                    }
                }
            }

            // --- route coordinator replies -----------------------------
            while let Some(mut reply) = coord.try_recv() {
                progress = true;
                if let Some(route) = routes.remove(&reply.id()) {
                    inflight = inflight.saturating_sub(1);
                    set_reply_id(&mut reply, route.client_id);
                    // reply-written stamp + stage accounting happen at
                    // the last server-side touch point, right before
                    // the frame enters the write buffer; the stamp is
                    // a no-op when the tracing plane is off
                    let spans = match reply.stamps_mut() {
                        Some(stamps) => {
                            stamps.stamp(Stage::ReplyWritten);
                            stamps
                                .is_on()
                                .then(|| stamps.spans_us())
                        }
                        None => None,
                    };
                    if let Some(spans) = spans {
                        metrics.note_stages(route.class, &spans);
                        if route.echo {
                            reply.set_stages(spans);
                        }
                    }
                    if let Some(conn) = conns.get_mut(&route.cid) {
                        conn.inflight = conn.inflight.saturating_sub(1);
                        conn.push_reply(&reply);
                    }
                    // a vanished connection just drops its reply — the
                    // request already executed; nothing to unwind
                }
            }
            metrics
                .net_inflight
                .store(inflight as u64, Ordering::Relaxed);

            // --- flush + reap ------------------------------------------
            // a closing/eof connection survives until its write buffer
            // drains AND every admitted request has been answered —
            // half-closed clients still get their replies
            conns.retain(|_, conn| match conn.wbuf.flush(&mut conn.stream)
            {
                Ok(true) => {
                    !((conn.closing || conn.eof)
                        && conn.inflight == 0
                        && !conn.awaiting_stop_ack)
                }
                Ok(false) => true,
                Err(_) => false,
            });

            // --- drain / exit ------------------------------------------
            if draining {
                let started =
                    *drain_start.get_or_insert_with(Instant::now);
                let expired = started.elapsed() > cfg.drain_timeout;
                if inflight == 0 || expired {
                    for (_, route) in std::mem::take(&mut routes) {
                        if let Some(conn) = conns.get_mut(&route.cid) {
                            metrics
                                .failures
                                .fetch_add(1, Ordering::Relaxed);
                            metrics
                                .drained
                                .fetch_add(1, Ordering::Relaxed);
                            conn.push_reply(&Reply::Err(Failure::new(
                                route.client_id,
                                FailureKind::Shutdown,
                                "server stopped before this request \
                                 finished",
                            )));
                        }
                    }
                    // STOP requesters get the *final* stats — rendered
                    // after the drain, so in-flight work that finished
                    // during it is included
                    let final_stats = proto::encode_stats_reply(
                        &metrics.render_text(),
                    );
                    for cid in stop_acks.drain(..) {
                        if let Some(conn) = conns.get_mut(&cid) {
                            conn.wbuf.push(&final_stats);
                            conn.awaiting_stop_ack = false;
                        }
                    }
                    for conn in conns.values_mut() {
                        conn.wbuf.push(&proto::encode_goodbye(
                            "server draining; goodbye",
                        ));
                    }
                    // best-effort final flush, bounded
                    let deadline =
                        Instant::now() + Duration::from_millis(500);
                    loop {
                        let mut all_empty = true;
                        for conn in conns.values_mut() {
                            match conn.wbuf.flush(&mut conn.stream) {
                                Ok(true) => {}
                                Ok(false) => all_empty = false,
                                Err(_) => {}
                            }
                        }
                        if all_empty || Instant::now() > deadline {
                            break;
                        }
                        std::thread::sleep(Duration::from_millis(1));
                    }
                    break;
                }
            }

            if !progress {
                // linear backoff to 10× base: idle connections are
                // scanned, not epoll-waited (zero-dep contract), so
                // bound the idle syscall rate
                idle_ticks = (idle_ticks + 1).min(10);
                std::thread::sleep(cfg.idle_sleep * idle_ticks);
            } else {
                idle_ticks = 0;
            }
        }

        metrics.net_inflight.store(0, Ordering::Relaxed);
        coord.shutdown();
        coord
    }
}

/// Rewrite a reply's correlation id to the client-assigned one (the
/// coordinator numbers requests itself; the wire keeps client ids).
fn set_reply_id(reply: &mut Reply, id: u64) {
    match reply {
        Reply::Ok(r) => r.id = id,
        Reply::Grad(g) => g.id = id,
        Reply::Err(f) => f.id = id,
    }
}

/// Handle one decoded frame on `conn`. `parked_for` is how long the
/// frame's bytes sat in the connection's reader while the
/// write-backpressure gate held reads — the pre-decode deadline
/// checkpoint charges that wait against the request's budget.
#[allow(clippy::too_many_arguments)]
fn handle_frame(
    opcode: u8,
    payload: &[u8],
    cid: u64,
    conn: &mut Conn,
    coord: &mut Coordinator,
    routes: &mut BTreeMap<u64, Route>,
    inflight: &mut usize,
    stop_acks: &mut Vec<u64>,
    cfg: &NetConfig,
    draining: &mut bool,
    parked_for: Duration,
) {
    match opcode {
        op::SOLVE | op::GRAD => {
            // Admission control runs on the RAW frame: id, priority
            // class, and deadline budget come from an allocation-free
            // metadata peek, so rejecting (drain/deadline/shed) never
            // pays the full θ deserialization — keeping the reject
            // path cheap is the point of shedding. A malformed frame
            // falls through to decode_request for its Protocol error.
            let (peek_id, prio, deadline_us) =
                proto::peek_request_meta(opcode, payload).unwrap_or((
                    payload
                        .get(..8)
                        .map(|b| {
                            u64::from_le_bytes(b.try_into().unwrap())
                        })
                        .unwrap_or(0),
                    Priority::Normal,
                    None,
                ));
            if *draining {
                coord
                    .metrics
                    .failures
                    .fetch_add(1, Ordering::Relaxed);
                coord.metrics.drained.fetch_add(1, Ordering::Relaxed);
                conn.push_reply(&Reply::Err(Failure::new(
                    peek_id,
                    FailureKind::Shutdown,
                    "server is draining",
                )));
                return;
            }
            // pre-decode deadline checkpoint: a frame parked under
            // write backpressure longer than its whole budget is dead
            // on arrival — shed before decode
            if let Some(us) = deadline_us {
                if parked_for >= Duration::from_micros(us as u64) {
                    coord.metrics.note_deadline_shed(prio);
                    conn.push_reply(&Reply::Err(Failure::new(
                        peek_id,
                        FailureKind::DeadlineExceeded,
                        format!(
                            "deadline budget {us}µs elapsed before \
                             decode ({}µs parked under write \
                             backpressure)",
                            parked_for.as_micros()
                        ),
                    )));
                    return;
                }
            }
            let budget = class_budget(cfg.max_inflight, prio);
            if *inflight >= budget {
                // shed instead of queueing: the reply goes out on this
                // tick, the connection stays healthy. Budgets are
                // graduated by class, so Low sheds before Normal
                // before High as the pool fills.
                coord.metrics.note_shed(prio);
                conn.push_reply(&Reply::Err(Failure::new(
                    peek_id,
                    FailureKind::Overloaded,
                    format!(
                        "in-flight budget {budget} exhausted for \
                         class {}; retry later",
                        prio.label()
                    ),
                )));
                return;
            }
            // accepted-stamp before decode, decoded-stamp after: the
            // first span is exactly the deserialization cost. Both are
            // single no-op branches when the tracing plane is off.
            let mut stamps = coord.new_stamps();
            stamps.stamp(Stage::Accepted);
            let mut req = match proto::decode_request(opcode, payload) {
                Ok(r) => r,
                Err(e) => {
                    coord
                        .metrics
                        .failures
                        .fetch_add(1, Ordering::Relaxed);
                    conn.push_reply(&Reply::Err(Failure::new(
                        0,
                        FailureKind::Invalid,
                        format!("{e}"),
                    )));
                    conn.closing = true;
                    return;
                }
            };
            stamps.stamp(Stage::Decoded);
            req.stamps = stamps;
            // the frame aged `parked_for` before decode could stamp
            // `submitted`; backdate so the later checkpoints (and
            // latency accounting) see the request's true age
            if parked_for > Duration::ZERO {
                req.submitted = req
                    .submitted
                    .checked_sub(parked_for)
                    .unwrap_or(req.submitted);
            }
            // hand the decoded request straight to the coordinator —
            // its decode-time `submitted` stamp survives, so latency
            // accounting starts at server-side decode as documented.
            // The coordinator hashes (layer, session) to a shard (or
            // round-robins session-less requests); a full shard queue
            // answers Overloaded through the ordinary reply route, so
            // coordinator-level shedding still reaches the client.
            let client_id = req.id;
            let echo = req.echo_stages;
            let class = req.priority;
            let sid = coord.submit_request(req);
            routes.insert(
                sid,
                Route { cid, client_id, echo, class },
            );
            conn.inflight += 1;
            *inflight += 1;
        }
        op::STATS | op::LAYERS | op::STOP => {
            // admin requests carry no payload; trailing bytes are the
            // same framing violation the codec rejects elsewhere
            if !payload.is_empty() {
                coord
                    .metrics
                    .failures
                    .fetch_add(1, Ordering::Relaxed);
                conn.push_reply(&Reply::Err(Failure::new(
                    0,
                    FailureKind::Invalid,
                    format!(
                        "{} trailing bytes on admin opcode 0x{opcode:02x}",
                        payload.len()
                    ),
                )));
                conn.closing = true;
                return;
            }
            match opcode {
                op::STATS => {
                    let text = coord.metrics.render_text();
                    conn.wbuf.push(&proto::encode_stats_reply(&text));
                }
                op::LAYERS => {
                    conn.wbuf.push(&proto::encode_layers_reply(
                        coord.layer_dims(),
                    ));
                }
                _ => {
                    // STOP: the ack (a final stats frame) is deferred
                    // to the end of the drain so it reflects work that
                    // finishes during it
                    *draining = true;
                    stop_acks.push(cid);
                    conn.awaiting_stop_ack = true;
                }
            }
        }
        other => {
            coord
                .metrics
                .failures
                .fetch_add(1, Ordering::Relaxed);
            conn.push_reply(&Reply::Err(Failure::new(
                0,
                FailureKind::Invalid,
                format!("unknown opcode 0x{other:02x}"),
            )));
            conn.closing = true;
        }
    }
}

/// Render one HTTP/1.0 response (`Connection: close`; HEAD callers
/// pass an empty body and get a zero Content-Length).
fn http_response(status: &str, ctype: &str, body: &str) -> Vec<u8> {
    format!(
        "HTTP/1.0 {status}\r\n\
         Content-Type: {ctype}\r\n\
         Content-Length: {}\r\n\
         Connection: close\r\n\r\n{body}",
        body.len()
    )
    .into_bytes()
}

/// Serve one sniffed HTTP connection: a zero-dep `GET /metrics` +
/// `GET /healthz` + `GET /trace` responder multiplexed on the same
/// poll loop as the framed protocol, so a Prometheus scrape, a load
/// balancer's health probe, or a convergence-trace pull works *live*
/// against a serving front end — no separate port, no extra thread,
/// and the render cost is paid by the scraper's tick only. `/trace`
/// *drains* the sampled-trace ring (each event is delivered exactly
/// once across scrapers) as JSON-lines. One request per connection
/// (HTTP/1.0 semantics): the response queues on the ordinary write
/// buffer and the connection closes after the flush.
fn handle_http(conn: &mut Conn, coord: &Coordinator, draining: bool) {
    const MAX_HEADER: usize = 8 * 1024;
    let end = conn.http_buf.windows(4).position(|w| w == b"\r\n\r\n");
    let Some(end) = end else {
        if conn.http_buf.len() > MAX_HEADER {
            conn.wbuf.push(&http_response(
                "400 Bad Request",
                "text/plain",
                "request header too large\n",
            ));
            conn.closing = true;
        } else if conn.eof {
            // peer gave up mid-request: nothing to answer
            conn.closing = true;
        }
        return;
    };
    let head = String::from_utf8_lossy(&conn.http_buf[..end]);
    let request_line = head.lines().next().unwrap_or("");
    let mut parts = request_line.split_ascii_whitespace();
    let method = parts.next().unwrap_or("");
    let path = parts.next().unwrap_or("");
    let response = if method != "GET" && method != "HEAD" {
        http_response(
            "405 Method Not Allowed",
            "text/plain",
            "only GET and HEAD are served\n",
        )
    } else {
        match path {
            "/metrics" => {
                let text = coord.metrics.render_text();
                let body = if method == "HEAD" { "" } else { &text };
                // version=0.0.4 is the Prometheus text exposition format
                http_response(
                    "200 OK",
                    "text/plain; version=0.0.4",
                    body,
                )
            }
            "/healthz" => {
                // health reflects drain state and shard saturation: a
                // draining server answers 503 so balancers stop
                // routing to it; a shard queue at ≥ 90% of its bound
                // degrades the report without failing the probe
                let depths = coord.shard_queue_depths();
                let cap = coord.shard_queue_cap().max(1);
                let saturated =
                    depths.iter().any(|&d| d * 10 >= cap * 9);
                let status = if draining {
                    "draining"
                } else if saturated {
                    "degraded"
                } else {
                    "ok"
                };
                let code = if draining {
                    "503 Service Unavailable"
                } else {
                    "200 OK"
                };
                let body = format!(
                    "{{\"status\":\"{status}\",\"shards\":{},\
                     \"queue_cap\":{cap},\"queue_depth\":{:?},\
                     \"inflight\":{}}}\n",
                    depths.len(),
                    depths,
                    coord.metrics.net_inflight.load(Ordering::Relaxed)
                );
                let body =
                    if method == "HEAD" { String::new() } else { body };
                http_response(code, "application/json", &body)
            }
            "/trace" => {
                // destructive read: the ring is drained, so repeated
                // scrapes stream fresh events instead of re-sending —
                // HEAD still drains nothing observable body-wise but
                // would consume events, so it short-circuits first
                let body = if method == "HEAD" {
                    String::new()
                } else {
                    coord.trace_ring().drain_jsonl()
                };
                http_response("200 OK", "application/x-ndjson", &body)
            }
            _ => http_response(
                "404 Not Found",
                "text/plain",
                "known paths: /metrics /healthz /trace\n",
            ),
        }
    };
    conn.wbuf.push(&response);
    conn.closing = true;
}
