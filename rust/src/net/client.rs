//! Clients for the wire protocol: a simple blocking client (one request
//! outstanding), a pipelined client (configurable in-flight window —
//! the load-generator workhorse), and the multi-connection load
//! generator itself.

use super::frame::blocking::{read_frame_buffered, write_frame};
use super::frame::{Frame, FrameReader, MAX_PAYLOAD};
use super::proto::{self, op, LayerInfo};
use crate::coordinator::{FailureKind, Priority, Reply, Request};
use crate::error::{AltDiffError, Result};
use crate::obs::{StageStamps, N_SPANS, SPAN_LABELS};
use crate::prob::dense_qp;
use crate::util::Pcg64;
use std::collections::BTreeMap;
use std::net::{SocketAddr, TcpStream, ToSocketAddrs};
use std::time::{Duration, Instant};

/// Default end-to-end operation deadline for the blocking [`Client`]:
/// a silently dead peer fails the call with a timeout instead of
/// hanging the caller forever (mid-frame partial bytes stay buffered,
/// so a *slow* peer is still fine — only a stalled one times out).
pub const DEFAULT_OP_TIMEOUT: Duration = Duration::from_secs(30);

/// Bounded retry with exponential backoff + deterministic jitter.
///
/// Retry fires only on conditions where a repeat can plausibly
/// succeed: transport errors (refused/reset/torn connections, read
/// timeouts) and [`FailureKind::Overloaded`] sheds. It NEVER fires on
/// [`FailureKind::Invalid`] (a malformed request fails identically
/// forever), [`FailureKind::DeadlineExceeded`] (the caller's budget,
/// not the server, is the limit), or [`FailureKind::Shutdown`].
#[derive(Clone, Debug)]
pub struct RetryPolicy {
    /// Max retry attempts after the initial try.
    pub max_retries: u32,
    /// Backoff before retry 1; doubles per attempt.
    pub base_backoff: Duration,
    /// Backoff growth cap.
    pub max_backoff: Duration,
    /// Jitter RNG seed (deterministic for reproducible tests).
    pub seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_retries: 3,
            base_backoff: Duration::from_millis(5),
            max_backoff: Duration::from_millis(500),
            seed: 0x9e37_79b9_7f4a_7c15,
        }
    }
}

impl RetryPolicy {
    /// Backoff before retry `attempt` (1-based): the exponential
    /// schedule capped at `max_backoff`, jittered over its upper half
    /// so synchronized clients decorrelate.
    pub fn backoff(&self, attempt: u32, rng: &mut Pcg64) -> Duration {
        let doublings = attempt.max(1).min(16) - 1;
        let exp = self.base_backoff.saturating_mul(1u32 << doublings);
        let capped = exp.min(self.max_backoff);
        capped.mul_f64(0.5 + 0.5 * rng.uniform())
    }
}

/// Transient transport conditions a bounded retry may recover from.
fn io_retryable(k: std::io::ErrorKind) -> bool {
    use std::io::ErrorKind::*;
    matches!(
        k,
        TimedOut
            | WouldBlock
            | ConnectionReset
            | ConnectionAborted
            | ConnectionRefused
            | BrokenPipe
            | UnexpectedEof
            | Interrupted
    )
}

/// True when the error is a retryable transport failure (never a
/// protocol or server-classified failure).
fn transport_retryable(e: &AltDiffError) -> bool {
    matches!(e, AltDiffError::Io(io) if io_retryable(io.kind()))
}

fn op_timeout_err() -> AltDiffError {
    AltDiffError::Io(std::io::Error::new(
        std::io::ErrorKind::TimedOut,
        "op deadline elapsed with no reply from the server",
    ))
}

/// Encode a request, rejecting locally anything the server's frame
/// validation would kill the connection over. Mirrors the reply-side
/// degradation in `proto::encode_reply`: the size check runs on the
/// computed length, so an oversized request never allocates its frame.
fn checked_request_bytes(req: &Request) -> Result<Vec<u8>> {
    let payload_len = proto::request_payload_len(req);
    if payload_len > MAX_PAYLOAD as usize {
        return Err(AltDiffError::Protocol(format!(
            "request payload {payload_len} bytes exceeds the wire \
             limit {MAX_PAYLOAD}"
        )));
    }
    Ok(proto::encode_request(req))
}

/// Blocking request/reply client: one outstanding call at a time — a
/// window-1 [`PipelinedClient`] plus the admin ops (stats, layer
/// discovery, graceful stop).
///
/// A full loopback round trip (the server runs in-process here; any
/// reachable [`super::NetServer`] address works the same):
///
/// ```
/// use altdiff::coordinator::{Config, Coordinator, Reply};
/// use altdiff::net::{Client, NetConfig, NetServer};
/// use altdiff::prob::dense_qp;
///
/// let coord = Coordinator::builder(Config::default())
///     .register("qp6", dense_qp(6, 3, 1, 7), 1.0)?
///     .start();
/// let server =
///     NetServer::bind("127.0.0.1:0", coord, NetConfig::default())?;
/// let addr = server.local_addr()?;
/// let handle = std::thread::spawn(move || server.run());
///
/// let mut client = Client::connect(addr)?;
/// assert_eq!(client.layers()?[0].name, "qp6");
/// let qp = dense_qp(6, 3, 1, 7);
/// match client.solve("qp6", qp.q, qp.b, qp.h, 1e-2)? {
///     Reply::Ok(r) => assert_eq!(r.x.len(), 6),
///     other => panic!("expected a solve reply, got {other:?}"),
/// }
/// client.stop_server()?; // graceful drain; final stats text
/// handle.join().unwrap();
/// # Ok::<(), altdiff::AltDiffError>(())
/// ```
pub struct Client {
    inner: PipelinedClient,
    addr: SocketAddr,
    op_timeout: Option<Duration>,
    retry: Option<RetryPolicy>,
    rng: Pcg64,
    retries: u64,
    reconnects: u64,
}

impl Client {
    /// Connect to a running [`super::NetServer`]. Every operation is
    /// bounded by [`DEFAULT_OP_TIMEOUT`] end to end (see
    /// [`Client::set_timeout`]); retry is off until
    /// [`Client::set_retry`] arms it.
    pub fn connect<A: ToSocketAddrs>(addr: A) -> Result<Self> {
        let addr = addr.to_socket_addrs()?.next().ok_or_else(|| {
            AltDiffError::Coordinator("client: no address".into())
        })?;
        let mut c = Client {
            inner: PipelinedClient::connect(addr, 1)?,
            addr,
            op_timeout: Some(DEFAULT_OP_TIMEOUT),
            retry: None,
            rng: Pcg64::new(0xc11e_47),
            retries: 0,
            reconnects: 0,
        };
        c.inner.stream.set_write_timeout(c.op_timeout)?;
        Ok(c)
    }

    /// Attach a warm-start session key to every subsequent request
    /// (see [`PipelinedClient::set_session`]).
    pub fn set_session(&mut self, key: impl Into<Option<u64>>) {
        self.inner.set_session(key);
    }

    /// Priority class attached to every subsequent request (see
    /// [`PipelinedClient::set_priority`]).
    pub fn set_priority(&mut self, p: Priority) {
        self.inner.set_priority(p);
    }

    /// Per-request deadline budget in µs attached to every subsequent
    /// request (see [`PipelinedClient::set_deadline_us`]).
    pub fn set_deadline_us(&mut self, us: impl Into<Option<u32>>) {
        self.inner.set_deadline_us(us);
    }

    /// Bound every operation end to end (default:
    /// [`DEFAULT_OP_TIMEOUT`]): the remaining budget re-arms the
    /// socket's read timeout before each frame, so a silently dead
    /// server fails the call instead of hanging it forever. `None`
    /// opts out (unbounded, the pre-deadline behaviour). A timeout
    /// mid-frame is recoverable: partial bytes stay buffered.
    pub fn set_timeout(&mut self, d: Option<Duration>) -> Result<()> {
        self.op_timeout = d;
        self.inner.set_timeout(d)?;
        self.inner.stream.set_write_timeout(d)?;
        Ok(())
    }

    /// Arm bounded retry (see [`RetryPolicy`] for what is — and is
    /// never — retried). `None` disarms it.
    pub fn set_retry(&mut self, policy: impl Into<Option<RetryPolicy>>) {
        self.retry = policy.into();
        if let Some(p) = &self.retry {
            self.rng = Pcg64::new(p.seed);
        }
    }

    /// `(retries, reconnects)` performed by the retry policy so far.
    pub fn retry_counts(&self) -> (u64, u64) {
        (self.retries, self.reconnects)
    }

    /// Re-arm the socket's read timeout with the budget remaining
    /// since `t0`; errors with `TimedOut` once the budget is gone.
    /// No-op when the op deadline is disabled.
    fn arm_read_timeout(&mut self, t0: Instant) -> Result<()> {
        let Some(d) = self.op_timeout else { return Ok(()) };
        let rem =
            d.checked_sub(t0.elapsed()).ok_or_else(op_timeout_err)?;
        self.inner
            .set_timeout(Some(rem.max(Duration::from_millis(1))))?;
        Ok(())
    }

    /// Tear down and rebuild the connection after a transport failure,
    /// carrying over session/priority/deadline state. The old stream's
    /// in-flight bookkeeping is dropped: those replies are gone.
    fn reconnect(&mut self) -> Result<()> {
        let mut fresh = PipelinedClient::connect(self.addr, 1)?;
        fresh.session = self.inner.session;
        fresh.priority = self.inner.priority;
        fresh.deadline_us = self.inner.deadline_us;
        fresh.set_timeout(self.op_timeout)?;
        fresh.stream.set_write_timeout(self.op_timeout)?;
        self.inner = fresh;
        self.reconnects += 1;
        Ok(())
    }

    /// Read until a frame with opcode `want` arrives, skipping stale
    /// replies of *any* kind left over from previously timed-out calls
    /// (data and admin alike) so one timeout does not poison later
    /// ops. Bounded end to end by the op deadline.
    fn read_expected(&mut self, want: u8) -> Result<Frame> {
        let t0 = Instant::now();
        loop {
            self.arm_read_timeout(t0)?;
            let f = match read_frame_buffered(
                &mut self.inner.stream,
                &mut self.inner.rbuf,
            ) {
                Ok(f) => f,
                // a per-read timeout under an armed op deadline is not
                // final: loop back, where arm_read_timeout converts an
                // exhausted budget into the terminal error
                Err(AltDiffError::Io(e))
                    if self.op_timeout.is_some()
                        && matches!(
                            e.kind(),
                            std::io::ErrorKind::TimedOut
                                | std::io::ErrorKind::WouldBlock
                        ) =>
                {
                    continue
                }
                Err(e) => return Err(e),
            };
            if f.op == want {
                return Ok(f);
            }
            match f.op {
                op::R_GOODBYE => {
                    return Err(AltDiffError::Coordinator(
                        proto::decode_goodbye(&f.payload)
                            .unwrap_or_else(|_| "server closed".into()),
                    ))
                }
                op::R_SOLVE | op::R_GRAD | op::R_ERR => {
                    // stale data reply: also clear its bookkeeping so
                    // `inflight()` does not count it forever
                    if let Ok(r) = proto::decode_reply(f.op, &f.payload)
                    {
                        self.inner.sent_at.remove(&r.id());
                    }
                }
                op::R_STATS | op::R_LAYERS => {} // stale admin reply
                other => {
                    return Err(AltDiffError::Protocol(format!(
                        "expected opcode 0x{want:02x}, got 0x{other:02x}"
                    )))
                }
            }
        }
    }

    /// One blocking request/reply round trip through the inner
    /// window-1 pipeline. Reads reply-by-reply (not `drain`) so a
    /// connection-level id-0 failure — which the server sends right
    /// before closing — is returned as the classified failure it is
    /// instead of being masked by the EOF that follows it; stale
    /// replies from earlier timed-out calls are skipped by id.
    /// Bounded end to end by the op deadline.
    fn roundtrip_once(
        &mut self,
        layer: &str,
        q: Vec<f64>,
        b: Vec<f64>,
        h: Vec<f64>,
        grad_v: Option<Vec<f64>>,
        tol: f64,
    ) -> Result<Reply> {
        let t0 = Instant::now();
        // re-arm with the full budget up front: submit may itself read
        // (stale in-flight entries from a timed-out predecessor) and
        // must not inherit that predecessor's dregs of a timeout
        self.arm_read_timeout(t0)?;
        self.inner.submit(layer, q, b, h, grad_v, tol)?;
        let id = self.inner.next_id;
        loop {
            self.arm_read_timeout(t0)?;
            match self.inner.read_one() {
                Ok(t) => {
                    if t.reply.id() == id || t.reply.id() == 0 {
                        return Ok(t.reply);
                    }
                }
                Err(AltDiffError::Io(e))
                    if self.op_timeout.is_some()
                        && matches!(
                            e.kind(),
                            std::io::ErrorKind::TimedOut
                                | std::io::ErrorKind::WouldBlock
                        ) =>
                {
                    // deadline loop: arm_read_timeout terminates this
                }
                Err(e) => return Err(e),
            }
        }
    }

    /// [`Client::roundtrip_once`] under the armed [`RetryPolicy`] (a
    /// plain single attempt when retry is disarmed). Retryable
    /// outcomes are transport errors — the connection is rebuilt, its
    /// state being unknowable after a torn read — and `Overloaded`
    /// sheds; `Invalid`, `DeadlineExceeded`, and `Shutdown` replies
    /// return immediately.
    fn roundtrip(
        &mut self,
        layer: &str,
        q: Vec<f64>,
        b: Vec<f64>,
        h: Vec<f64>,
        grad_v: Option<Vec<f64>>,
        tol: f64,
    ) -> Result<Reply> {
        let Some(policy) = self.retry.clone() else {
            return self.roundtrip_once(layer, q, b, h, grad_v, tol);
        };
        let mut attempt = 0u32;
        loop {
            let res = self.roundtrip_once(
                layer,
                q.clone(),
                b.clone(),
                h.clone(),
                grad_v.clone(),
                tol,
            );
            let (retry, rebuild) = match &res {
                Ok(Reply::Err(f))
                    if f.kind == FailureKind::Overloaded =>
                {
                    (true, false)
                }
                Ok(_) => (false, false),
                Err(e) => (transport_retryable(e), true),
            };
            if !retry || attempt >= policy.max_retries {
                return res;
            }
            attempt += 1;
            self.retries += 1;
            std::thread::sleep(policy.backoff(attempt, &mut self.rng));
            if rebuild {
                // best effort: a refused reconnect burns the attempt
                // and the next roundtrip fails fast on the dead stream
                let _ = self.reconnect();
            }
        }
    }

    /// Solve `layer` at θ = (q, b, h); the reply carries x* and ∂x/∂b.
    pub fn solve(
        &mut self,
        layer: &str,
        q: Vec<f64>,
        b: Vec<f64>,
        h: Vec<f64>,
        tol: f64,
    ) -> Result<Reply> {
        self.roundtrip(layer, q, b, h, None, tol)
    }

    /// Gradient request: the reply carries x* and vᵀ∂x*/∂{q,b,h}.
    pub fn grad(
        &mut self,
        layer: &str,
        q: Vec<f64>,
        b: Vec<f64>,
        h: Vec<f64>,
        v: Vec<f64>,
        tol: f64,
    ) -> Result<Reply> {
        self.roundtrip(layer, q, b, h, Some(v), tol)
    }

    /// Fetch the server's Prometheus-style metrics text.
    pub fn stats(&mut self) -> Result<String> {
        write_frame(
            &mut self.inner.stream,
            &proto::encode_admin(op::STATS),
        )?;
        let f = self.read_expected(op::R_STATS)?;
        proto::decode_stats_reply(&f.payload)
    }

    /// List the layers registered on the server.
    pub fn layers(&mut self) -> Result<Vec<LayerInfo>> {
        write_frame(
            &mut self.inner.stream,
            &proto::encode_admin(op::LAYERS),
        )?;
        let f = self.read_expected(op::R_LAYERS)?;
        proto::decode_layers_reply(&f.payload)
    }

    /// Ask the server to drain and stop. Blocks until the drain
    /// completes: the ack is the server's *final* stats text, rendered
    /// after every in-flight request has been answered.
    pub fn stop_server(&mut self) -> Result<String> {
        write_frame(
            &mut self.inner.stream,
            &proto::encode_admin(op::STOP),
        )?;
        let f = self.read_expected(op::R_STATS)?;
        proto::decode_stats_reply(&f.payload)
    }
}

/// A reply paired with its measured round-trip time (seconds).
#[derive(Debug)]
pub struct TimedReply {
    /// The decoded reply.
    pub reply: Reply,
    /// Client-observed round trip: send → reply decoded.
    pub rtt: f64,
}

/// Pipelined client: keeps up to `window` requests on the wire before
/// insisting on a reply, so one connection can saturate the server's
/// dynamic batcher (a window of 1 degenerates to the blocking client).
///
/// ```no_run
/// use altdiff::net::PipelinedClient;
///
/// let mut cl = PipelinedClient::connect("127.0.0.1:7171", 8)?;
/// cl.set_session(42); // warm-start session: solves seed each other
/// let mut replies = Vec::new();
/// for step in 0..32 {
///     let scale = 1.0 + 0.01 * step as f64;
///     let q: Vec<f64> = (0..16).map(|i| scale * i as f64).collect();
///     // up to 8 requests ride the wire before a reply is insisted on
///     replies.extend(cl.submit(
///         "qp16", q, vec![0.0; 8], vec![1.0; 8], None, 1e-3)?);
/// }
/// replies.extend(cl.drain()?); // collect the stragglers
/// assert_eq!(replies.len(), 32);
/// # Ok::<(), altdiff::AltDiffError>(())
/// ```
pub struct PipelinedClient {
    stream: TcpStream,
    rbuf: FrameReader,
    window: usize,
    next_id: u64,
    session: Option<u64>,
    priority: Priority,
    deadline_us: Option<u32>,
    echo_stages: bool,
    sent_at: BTreeMap<u64, Instant>,
}

impl PipelinedClient {
    /// Connect with the given in-flight window (min 1).
    pub fn connect<A: ToSocketAddrs>(
        addr: A,
        window: usize,
    ) -> Result<Self> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        Ok(PipelinedClient {
            stream,
            rbuf: FrameReader::new(),
            window: window.max(1),
            next_id: 0,
            session: None,
            priority: Priority::Normal,
            deadline_us: None,
            echo_stages: false,
            sent_at: BTreeMap::new(),
        })
    }

    /// [`PipelinedClient::connect`] with bounded-backoff retries on
    /// transient connect failures (refused/reset/timed out — exactly
    /// the window a restarting or chaos-proxied server presents).
    /// Non-transport errors return immediately.
    pub fn connect_with_retry<A: ToSocketAddrs>(
        addr: A,
        window: usize,
        policy: &RetryPolicy,
    ) -> Result<Self> {
        let addr = addr.to_socket_addrs()?.next().ok_or_else(|| {
            AltDiffError::Coordinator("client: no address".into())
        })?;
        let mut rng = Pcg64::new(policy.seed ^ 0xc0_aa);
        let mut attempt = 0u32;
        loop {
            match PipelinedClient::connect(addr, window) {
                Ok(cl) => return Ok(cl),
                Err(e)
                    if transport_retryable(&e)
                        && attempt < policy.max_retries =>
                {
                    attempt += 1;
                    std::thread::sleep(
                        policy.backoff(attempt, &mut rng),
                    );
                }
                Err(e) => return Err(e),
            }
        }
    }

    /// Attach a warm-start session key to every subsequent request:
    /// the server's warm cache (when configured) will seed each of this
    /// session's solves from the previous one's converged iterate (see
    /// [`crate::warm`]). `None` reverts to anonymous requests.
    pub fn set_session(&mut self, key: impl Into<Option<u64>>) {
        self.session = key.into();
    }

    /// Priority class attached to every subsequent request (default
    /// [`Priority::Normal`]). Priority decides *shedding order* under
    /// pressure — Low forfeits queue/admission budget before Normal
    /// before High — never execution order of admitted work.
    pub fn set_priority(&mut self, p: Priority) {
        self.priority = p;
    }

    /// Per-request deadline budget in microseconds attached to every
    /// subsequent request (`None` = no deadline, the default). The
    /// server sheds a request whose budget has elapsed at its decode,
    /// batch-formation, and pre-execution checkpoints, replying
    /// [`FailureKind::DeadlineExceeded`] instead of burning a solve
    /// whose answer can no longer be useful.
    pub fn set_deadline_us(&mut self, us: impl Into<Option<u32>>) {
        self.deadline_us = us.into();
    }

    /// Opt every subsequent request into the server's stage echo: the
    /// reply then carries the per-stage server-side latency breakdown
    /// (decode/admit/queue/sched/exec/write, µs), provided the server
    /// runs with its tracing plane on (`serve --stamps`). Against a
    /// stamps-off or pre-echo server the replies simply come back
    /// without the block — the opt-in never breaks interop.
    pub fn set_echo_stages(&mut self, on: bool) {
        self.echo_stages = on;
    }

    /// Bound the wait for any single reply (default: unbounded). A
    /// timeout mid-frame is recoverable: partial bytes stay buffered.
    pub fn set_timeout(&mut self, d: Option<Duration>) -> Result<()> {
        self.stream.set_read_timeout(d)?;
        Ok(())
    }

    /// Requests currently on the wire.
    pub fn inflight(&self) -> usize {
        self.sent_at.len()
    }

    fn read_one(&mut self) -> Result<TimedReply> {
        let f = read_frame_buffered(&mut self.stream, &mut self.rbuf)?;
        if f.op == op::R_GOODBYE {
            return Err(AltDiffError::Coordinator(
                proto::decode_goodbye(&f.payload)
                    .unwrap_or_else(|_| "server closed".into()),
            ));
        }
        let reply = proto::decode_reply(f.op, &f.payload)?;
        let rtt = match self.sent_at.remove(&reply.id()) {
            Some(t0) => t0.elapsed().as_secs_f64(),
            // id 0 = connection-level protocol failure
            None => 0.0,
        };
        Ok(TimedReply { reply, rtt })
    }

    /// Send one request, collecting replies whenever the window is
    /// full. Returns the replies drained while making room (possibly
    /// empty).
    pub fn submit(
        &mut self,
        layer: &str,
        q: Vec<f64>,
        b: Vec<f64>,
        h: Vec<f64>,
        grad_v: Option<Vec<f64>>,
        tol: f64,
    ) -> Result<Vec<TimedReply>> {
        let mut drained = Vec::new();
        while self.sent_at.len() >= self.window {
            drained.push(self.read_one()?);
        }
        self.next_id += 1;
        let req = Request {
            id: self.next_id,
            layer: layer.to_string(),
            q,
            b,
            h,
            tol,
            grad_v,
            session: self.session,
            priority: self.priority,
            deadline_us: self.deadline_us,
            submitted: Instant::now(),
            stamps: StageStamps::off(),
            sampled: false,
            echo_stages: self.echo_stages,
        };
        let bytes = checked_request_bytes(&req)?;
        self.sent_at.insert(req.id, Instant::now());
        write_frame(&mut self.stream, &bytes)?;
        Ok(drained)
    }

    /// Block until every outstanding request has replied.
    pub fn drain(&mut self) -> Result<Vec<TimedReply>> {
        let mut out = Vec::new();
        while !self.sent_at.is_empty() {
            out.push(self.read_one()?);
        }
        Ok(out)
    }
}

/// Load-generator parameters (see [`run_loadgen`]).
#[derive(Clone, Debug)]
pub struct LoadgenOpts {
    /// Total requests across all client connections.
    pub requests: usize,
    /// Concurrent connections, each with its own pipelined window.
    pub clients: usize,
    /// Per-connection in-flight window.
    pub window: usize,
    /// Fraction of requests that take the gradient (adjoint) path.
    pub grad_share: f64,
    /// Target layer name; empty → first layer the server advertises.
    pub layer: String,
    /// Requested truncation tolerance.
    pub tol: f64,
    /// Seed for the synthetic θ stream. The loadgen rebuilds the
    /// target layer's QP with `dense_qp(n, m, p, seed)`, and a
    /// generated (b, h) is feasible only for the *same seed's* A/G
    /// matrices — so this must match the seed the server registered
    /// the layer with (the `serve` CLI registers its dense layers with
    /// seed 1, the default here). A mismatched seed still round-trips
    /// structurally but measures an infeasible workload.
    pub seed: u64,
    /// Attach a distinct warm-start session key to each client
    /// connection, so the connection's drifting θ stream repeatedly
    /// hits the server's warm cache (requires the server to run with a
    /// nonzero warm capacity, e.g. `serve --warm-cache 512`; without
    /// one the keys ride along harmlessly). The server's
    /// `warm_hits`/`warm_misses`/`warm_iters_saved` metrics quantify
    /// the effect — see the README's cold-vs-warm comparison.
    pub sessions: bool,
    /// Open-loop bursty arrivals: each client fires `burst` requests
    /// back-to-back (the pipelined window is widened to at least the
    /// burst size so the burst is not self-paced by replies), then
    /// sleeps [`LoadgenOpts::burst_gap_us`] before the next burst. 0
    /// (the default) keeps the classic closed-loop stream. Bursts are
    /// what actually exercise deadline flushes and cross-shard work
    /// stealing — steady closed-loop traffic keeps every queue shallow.
    pub burst: usize,
    /// Idle gap between bursts (microseconds; only with `burst > 0`).
    pub burst_gap_us: u64,
    /// Cycle each connection's requests through the three priority
    /// classes (High/Normal/Low round-robin per request), so equal
    /// arrival pressure per class makes priority-ordered shedding
    /// directly observable in the per-class server counters.
    pub priorities: bool,
    /// Attach this deadline budget (µs) to every request; `None` (the
    /// default) sends deadline-free traffic.
    pub deadline_us: Option<u32>,
    /// Opt every request into the server's per-stage latency echo and
    /// print the end-to-end stage-attribution table: client-observed
    /// round trips reconciled against the sum of server-side stages,
    /// so the network + client share of latency falls out as the
    /// difference. Needs a server running with `--stamps`; against a
    /// stamps-off server the table is simply absent.
    pub stages: bool,
    /// Survive transport faults: bounded-backoff connects, plus
    /// reconnect-and-resubmit when a connection tears mid-run (replies
    /// stranded on the dead connection are counted `failed`, never
    /// silently dropped). Off (the default), any transport error
    /// aborts the run — the right behaviour against a healthy server,
    /// useless against a chaos proxy.
    pub retry: bool,
}

impl Default for LoadgenOpts {
    fn default() -> Self {
        LoadgenOpts {
            requests: 200,
            clients: 4,
            window: 8,
            grad_share: 0.25,
            layer: String::new(),
            tol: 1e-3,
            seed: 1,
            sessions: false,
            burst: 0,
            burst_gap_us: 2_000,
            priorities: false,
            deadline_us: None,
            stages: false,
            retry: false,
        }
    }
}

/// Aggregate load-generator outcome.
#[derive(Clone, Debug, Default)]
pub struct LoadgenReport {
    /// Requests sent.
    pub sent: usize,
    /// Successful solve replies.
    pub ok: usize,
    /// Successful gradient replies.
    pub grads: usize,
    /// Replies shed by admission control (`Overloaded`).
    pub shed: usize,
    /// Replies shed because the request's own deadline budget elapsed
    /// (`DeadlineExceeded`) — never retried.
    pub deadline: usize,
    /// Other failure replies, plus replies stranded on connections the
    /// retry path had to rebuild.
    pub failed: usize,
    /// Requests re-sent by the retry path after a transport fault.
    pub retries: u64,
    /// Connections rebuilt by the retry path after a transport fault.
    pub reconnects: u64,
    /// Wall-clock seconds for the whole run.
    pub wall: f64,
    /// Median client-observed round trip (µs).
    pub p50_us: f64,
    /// 99th-percentile round trip (µs).
    pub p99_us: f64,
    /// Round trips of *served* (Ok/Grad) replies only, seconds,
    /// unsorted — shed/failed fast-replies are excluded so quantiles
    /// reflect service latency even under overload.
    pub rtts: Vec<f64>,
    /// Replies that carried the server's stage echo.
    pub stage_count: usize,
    /// Summed per-stage server-side spans (µs) over those replies,
    /// [`SPAN_LABELS`] order.
    pub stage_sum_us: [f64; N_SPANS],
    /// Summed client-observed round trips (µs) over those same
    /// replies — the reconciliation baseline for the attribution
    /// table (Σ server stages ≤ client rtt; the gap is wire + client).
    pub stage_rtt_sum_us: f64,
}

impl LoadgenReport {
    /// Throughput over the whole run (answered requests per second).
    pub fn throughput(&self) -> f64 {
        if self.wall <= 0.0 {
            return 0.0;
        }
        (self.ok + self.grads) as f64 / self.wall
    }

    /// Human-readable multi-line summary.
    pub fn render(&self) -> String {
        let mut s = format!(
            "sent {} → ok {} grad {} shed {} ddl {} failed {} in \
             {:.3}s ({:.0} req/s)\nrtt p50 {:.0}µs p99 {:.0}µs",
            self.sent,
            self.ok,
            self.grads,
            self.shed,
            self.deadline,
            self.failed,
            self.wall,
            self.throughput(),
            self.p50_us,
            self.p99_us,
        );
        if self.retries > 0 || self.reconnects > 0 {
            s.push_str(&format!(
                "\nretries {} reconnects {}",
                self.retries, self.reconnects
            ));
        }
        let stages = self.render_stages();
        if !stages.is_empty() {
            s.push('\n');
            s.push_str(&stages);
        }
        s
    }

    /// End-to-end stage-attribution table from the echoed server-side
    /// breakdowns: mean µs per stage, their sum, and the mean
    /// client-observed round trip of the same replies — the difference
    /// is the wire + client share the server cannot see. Empty when no
    /// reply carried an echo (stages off, or a stamps-off server).
    pub fn render_stages(&self) -> String {
        if self.stage_count == 0 {
            return String::new();
        }
        let n = self.stage_count as f64;
        let mut s = format!(
            "stage attribution ({} echoed replies, mean µs):\n ",
            self.stage_count
        );
        let mut server = 0.0;
        for (label, &sum) in
            SPAN_LABELS.iter().zip(self.stage_sum_us.iter())
        {
            let mean = sum / n;
            server += mean;
            s.push_str(&format!(" {label} {mean:.0}"));
        }
        let rtt = self.stage_rtt_sum_us / n;
        let gap = (rtt - server).max(0.0);
        s.push_str(&format!(
            "\n  Σ server {server:.0}µs · client rtt {rtt:.0}µs · \
             wire+client {gap:.0}µs"
        ));
        s
    }
}

fn percentile_us(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    crate::util::bench::percentile(sorted, q) * 1e6
}

fn tally(report: &mut LoadgenReport, t: &TimedReply) {
    // echoed stage breakdowns accumulate against the same replies'
    // client-observed rtts, so the attribution table reconciles like
    // with like
    if let Some(spans) = t.reply.stages() {
        if t.rtt > 0.0 {
            report.stage_count += 1;
            report.stage_rtt_sum_us += t.rtt * 1e6;
            for (acc, &v) in
                report.stage_sum_us.iter_mut().zip(spans.iter())
            {
                *acc += v as f64;
            }
        }
    }
    // only *served* replies contribute latency samples: shed replies
    // return in microseconds and would drag p50/p99 far below the real
    // service latency exactly when overload makes those numbers matter
    match &t.reply {
        Reply::Ok(_) => {
            report.ok += 1;
            if t.rtt > 0.0 {
                report.rtts.push(t.rtt);
            }
        }
        Reply::Grad(_) => {
            report.grads += 1;
            if t.rtt > 0.0 {
                report.rtts.push(t.rtt);
            }
        }
        Reply::Err(f) if f.kind == FailureKind::Overloaded => {
            report.shed += 1
        }
        Reply::Err(f)
            if f.kind == FailureKind::DeadlineExceeded =>
        {
            report.deadline += 1
        }
        Reply::Err(_) => report.failed += 1,
    }
}

/// Drive `opts.clients` pipelined connections against `addr`, each
/// replaying a deterministic synthetic θ stream (scaled copies of the
/// generator QP matching the layer's advertised dimensions, the same
/// trace the in-process serving bench uses). Every client counts its
/// replies; the merged report carries client-observed p50/p99 round
/// trips. Shed replies are counted, not retried — the point of the
/// load generator is to *observe* admission control, not to hide it.
///
/// θ is synthesized by the *dense* generator, so target a dense layer
/// registered from the same [`LoadgenOpts::seed`] for a feasible
/// workload (see the seed field's doc); sparse layers accept the
/// traffic but solve whatever infeasible θ they are handed.
pub fn run_loadgen<A: ToSocketAddrs>(
    addr: A,
    opts: &LoadgenOpts,
) -> Result<LoadgenReport> {
    let addr = addr
        .to_socket_addrs()?
        .next()
        .ok_or_else(|| {
            AltDiffError::Coordinator("loadgen: no address".into())
        })?;
    // discover the target layer's dimensions. Every loadgen socket
    // gets a generous read timeout so a wedged server fails the run
    // (and CI) instead of hanging it forever.
    let timeout = Some(Duration::from_secs(120));
    let mut probe = Client::connect(addr)?;
    probe.set_timeout(timeout)?;
    let layers = probe.layers()?;
    let info = if opts.layer.is_empty() {
        layers.first().cloned()
    } else {
        layers.iter().find(|l| l.name == opts.layer).cloned()
    }
    .ok_or_else(|| {
        AltDiffError::Coordinator(format!(
            "loadgen: layer '{}' not registered on the server \
             (advertised: {:?})",
            opts.layer,
            layers.iter().map(|l| &l.name).collect::<Vec<_>>()
        ))
    })?;
    drop(probe);

    let clients = opts.clients.max(1);
    // distribute the remainder so exactly opts.requests are sent even
    // when requests % clients != 0 (and small runs still send)
    let base = opts.requests / clients;
    let extra = opts.requests % clients;
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..clients {
        let per_client = base + usize::from(c < extra);
        let info = info.clone();
        let opts = opts.clone();
        handles.push(std::thread::spawn(move || -> Result<LoadgenReport> {
            // the generator QP gives a feasible θ for these dimensions;
            // scaling q keeps it feasible (b, h untouched)
            let qp = dense_qp(info.n, info.m, info.p, opts.seed);
            let mut rng = Pcg64::new(opts.seed ^ (c as u64 + 1));
            // open-loop bursts must not be self-paced by replies: the
            // window is widened to hold a whole burst in flight
            let window = opts.window.max(opts.burst);
            let policy = RetryPolicy {
                seed: opts.seed ^ (0xba_c0ff ^ c as u64),
                ..RetryPolicy::default()
            };
            let mut backoff_rng = Pcg64::new(policy.seed ^ 0xb0ff);
            let timeout = Some(Duration::from_secs(120));
            let fresh_client = |report: &mut LoadgenReport,
                                first: bool|
             -> Result<PipelinedClient> {
                let mut cl = if opts.retry {
                    PipelinedClient::connect_with_retry(
                        addr, window, &policy,
                    )?
                } else {
                    PipelinedClient::connect(addr, window)?
                };
                if !first {
                    report.reconnects += 1;
                }
                cl.set_timeout(timeout)?;
                if opts.sessions {
                    // one session per connection: its θ stream drifts
                    // slowly, exactly what the warm cache serves
                    cl.set_session(opts.seed ^ (0x5e55 + c as u64));
                }
                cl.set_deadline_us(opts.deadline_us);
                cl.set_echo_stages(opts.stages);
                Ok(cl)
            };
            let mut report = LoadgenReport::default();
            let mut cl = fresh_client(&mut report, true)?;
            let mut i = 0usize;
            let mut attempts = 0u32;
            while i < per_client {
                if opts.priorities {
                    cl.set_priority(Priority::ALL[i % 3]);
                }
                let s = 1.0 + 0.1 * rng.normal();
                let q: Vec<f64> =
                    qp.q.iter().map(|&v| v * s).collect();
                let grad_v = (rng.uniform() < opts.grad_share)
                    .then(|| rng.normal_vec(info.n));
                match cl.submit(
                    &info.name,
                    q,
                    qp.b.clone(),
                    qp.h.clone(),
                    grad_v,
                    opts.tol,
                ) {
                    Ok(ts) => {
                        report.sent += 1;
                        for t in &ts {
                            tally(&mut report, t);
                        }
                        i += 1;
                        attempts = 0;
                        if opts.burst > 0 && i % opts.burst == 0 {
                            std::thread::sleep(Duration::from_micros(
                                opts.burst_gap_us,
                            ));
                        }
                    }
                    Err(e) => {
                        if !opts.retry
                            || !transport_retryable(&e)
                            || attempts >= policy.max_retries
                        {
                            return Err(e);
                        }
                        attempts += 1;
                        report.retries += 1;
                        // the failed submit's own id may already be in
                        // the in-flight book; drop it so only genuinely
                        // stranded predecessors are counted failed
                        cl.sent_at.remove(&cl.next_id);
                        report.failed += cl.inflight();
                        std::thread::sleep(
                            policy.backoff(attempts, &mut backoff_rng),
                        );
                        cl = fresh_client(&mut report, false)?;
                    }
                }
            }
            match cl.drain() {
                Ok(ts) => {
                    for t in &ts {
                        tally(&mut report, t);
                    }
                }
                Err(e)
                    if opts.retry && transport_retryable(&e) =>
                {
                    // replies stranded on the torn connection are
                    // unrecoverable: account them, don't hide them
                    report.failed += cl.inflight();
                }
                Err(e) => return Err(e),
            }
            Ok(report)
        }));
    }
    let mut merged = LoadgenReport::default();
    for h in handles {
        let r = h
            .join()
            .map_err(|_| {
                AltDiffError::Coordinator(
                    "loadgen client thread panicked".into(),
                )
            })??;
        merged.sent += r.sent;
        merged.ok += r.ok;
        merged.grads += r.grads;
        merged.shed += r.shed;
        merged.deadline += r.deadline;
        merged.failed += r.failed;
        merged.retries += r.retries;
        merged.reconnects += r.reconnects;
        merged.rtts.extend(r.rtts);
        merged.stage_count += r.stage_count;
        merged.stage_rtt_sum_us += r.stage_rtt_sum_us;
        for (acc, v) in
            merged.stage_sum_us.iter_mut().zip(r.stage_sum_us)
        {
            *acc += v;
        }
    }
    merged.wall = t0.elapsed().as_secs_f64();
    let mut sorted = merged.rtts.clone();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    merged.p50_us = percentile_us(&sorted, 0.50);
    merged.p99_us = percentile_us(&sorted, 0.99);
    Ok(merged)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Failure;

    #[test]
    fn backoff_is_bounded_and_grows() {
        let p = RetryPolicy::default();
        let mut rng = Pcg64::new(7);
        let b1 = p.backoff(1, &mut rng);
        assert!(b1 >= p.base_backoff / 2, "jitter floor is half");
        assert!(b1 <= p.base_backoff);
        for attempt in 1..64 {
            let b = p.backoff(attempt, &mut rng);
            assert!(b <= p.max_backoff, "attempt {attempt}: {b:?}");
            assert!(b >= p.base_backoff / 2);
        }
        // deep attempts saturate at the cap's jitter band
        let deep = p.backoff(60, &mut rng);
        assert!(deep >= p.max_backoff / 2);
    }

    #[test]
    fn retry_classification_never_touches_terminal_failures() {
        // Overloaded is the only retryable *reply*; the terminal kinds
        // must stay terminal no matter what
        for kind in [
            FailureKind::Invalid,
            FailureKind::DeadlineExceeded,
            FailureKind::Shutdown,
            FailureKind::Exec,
        ] {
            assert_ne!(kind, FailureKind::Overloaded);
        }
        assert!(io_retryable(std::io::ErrorKind::ConnectionRefused));
        assert!(io_retryable(std::io::ErrorKind::TimedOut));
        assert!(!io_retryable(std::io::ErrorKind::PermissionDenied));
        assert!(!transport_retryable(&AltDiffError::Protocol(
            "bad".into()
        )));
        assert!(transport_retryable(&AltDiffError::Io(
            std::io::Error::new(
                std::io::ErrorKind::BrokenPipe,
                "torn"
            )
        )));
    }

    #[test]
    fn tally_routes_deadline_sheds_to_their_own_counter() {
        let mut r = LoadgenReport::default();
        let mk = |kind| TimedReply {
            reply: Reply::Err(Failure::new(1, kind, "")),
            rtt: 0.0,
        };
        tally(&mut r, &mk(FailureKind::Overloaded));
        tally(&mut r, &mk(FailureKind::DeadlineExceeded));
        tally(&mut r, &mk(FailureKind::Exec));
        assert_eq!((r.shed, r.deadline, r.failed), (1, 1, 1));
        let text = r.render();
        assert!(text.contains("ddl 1"), "{text}");
        // retry lines only appear when the retry path actually fired
        assert!(!text.contains("retries"), "{text}");
        r.retries = 2;
        r.reconnects = 1;
        assert!(r.render().contains("retries 2 reconnects 1"));
    }

    #[test]
    fn tally_builds_the_stage_attribution_table() {
        use crate::coordinator::Response;
        let mut r = LoadgenReport::default();
        assert!(r.render_stages().is_empty());
        let resp = |spans| Response {
            id: 1,
            x: vec![],
            jx: vec![],
            prim_residual: 0.0,
            k_used: 1,
            batch_size: 1,
            latency: 0.0,
            backend: "native",
            stamps: StageStamps::off(),
            stages: spans,
        };
        // no echo → no stage row, but still an ok tally
        tally(
            &mut r,
            &TimedReply { reply: Reply::Ok(resp(None)), rtt: 1e-3 },
        );
        assert_eq!((r.ok, r.stage_count), (1, 0));
        // echoed spans accumulate against the same reply's rtt
        let spans: [u32; N_SPANS] = [10, 0, 100, 20, 800, 5];
        tally(
            &mut r,
            &TimedReply {
                reply: Reply::Ok(resp(Some(spans))),
                rtt: 1.2e-3,
            },
        );
        assert_eq!((r.ok, r.stage_count), (2, 1));
        assert_eq!(r.stage_sum_us[4], 800.0);
        let table = r.render_stages();
        assert!(table.contains("exec 800"), "{table}");
        // Σ server = 935µs, rtt = 1200µs → 265µs wire+client gap
        assert!(table.contains("Σ server 935µs"), "{table}");
        assert!(table.contains("wire+client 265µs"), "{table}");
        assert!(r.render().contains("stage attribution"));
    }
}
