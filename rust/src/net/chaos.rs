//! Deterministic fault-injection TCP proxy (the chaos harness).
//!
//! [`ChaosProxy`] sits between a client and a [`super::NetServer`] and
//! mistreats the byte stream in seeded, reproducible ways:
//!
//! - **torn frames**: a forwarded chunk is split at a random offset and
//!   the halves are written separately, so the peer's frame reassembly
//!   sees arbitrary partial headers/payloads;
//! - **mid-frame stalls**: a pause *between* the torn halves, parking
//!   the peer mid-frame exactly where incremental readers are weakest;
//! - **delayed bytes**: whole chunks held back before forwarding,
//!   inflating round trips into any armed deadline budget;
//! - **slow-reader throttling**: forwarding in small slices with idle
//!   gaps, building genuine TCP backpressure toward the writer;
//! - **connection kills**: both directions shut down mid-stream, so a
//!   solve in flight loses its reply and the client must reconnect
//!   (`std::net` exposes no portable hard-RST knob, so the kill is an
//!   abrupt FIN — the client-visible symptom, an `UnexpectedEof`
//!   mid-frame, is the same transport-retryable failure).
//!
//! Every decision comes from a [`Pcg64`] stream seeded per connection
//! and direction from [`ChaosConfig::seed`], so a failing run replays
//! exactly. Zero dependencies beyond `std::net`, same as the rest of
//! the crate. The harness is deliberately protocol-blind: it never
//! parses frames, so it cannot accidentally "help" the implementation
//! under test.
//!
//! Used by `tests/chaos_net.rs` and `loadgen --chaos`; see DESIGN.md
//! §4c.

use crate::error::Result;
use crate::util::Pcg64;
use std::io::{Read, Write};
use std::net::{
    Shutdown, SocketAddr, TcpListener, TcpStream, ToSocketAddrs,
};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// Fault mix for a [`ChaosProxy`]. Probabilities are per forwarded
/// chunk and independent; `..Default::default()` gives a mild mix that
/// exercises every fault without starving throughput.
#[derive(Clone, Debug)]
pub struct ChaosConfig {
    /// Root seed; each (connection, direction) pump derives its own
    /// deterministic [`Pcg64`] stream from it.
    pub seed: u64,
    /// P(split a chunk and write the halves separately).
    pub tear_prob: f64,
    /// P(pause between the torn halves) — only meaningful on torn
    /// chunks, which is what makes the stall land mid-frame.
    pub stall_prob: f64,
    /// Mid-frame stall length (µs).
    pub stall_us: u64,
    /// P(hold a whole chunk back before forwarding).
    pub delay_prob: f64,
    /// Chunk delay length (µs).
    pub delay_us: u64,
    /// P(kill the connection outright, both directions).
    pub reset_prob: f64,
    /// Forwarding slice size in bytes (0 = unthrottled). Small values
    /// emulate a slow reader and push real TCP backpressure upstream.
    pub throttle: usize,
}

impl Default for ChaosConfig {
    fn default() -> Self {
        ChaosConfig {
            seed: 0xc4a0_5,
            tear_prob: 0.25,
            stall_prob: 0.5,
            stall_us: 2_000,
            delay_prob: 0.1,
            delay_us: 1_000,
            reset_prob: 0.0,
            throttle: 0,
        }
    }
}

/// Counters for every injected fault (all `Ordering::Relaxed`; exact
/// once the proxy is stopped or traffic has drained).
#[derive(Debug, Default)]
pub struct ChaosStats {
    /// Connections accepted and proxied.
    pub connections: AtomicU64,
    /// Chunks split into separately-written halves.
    pub torn: AtomicU64,
    /// Mid-frame stalls injected between torn halves.
    pub stalls: AtomicU64,
    /// Whole-chunk delays injected.
    pub delays: AtomicU64,
    /// Connections killed mid-stream.
    pub resets: AtomicU64,
    /// Total payload bytes forwarded (both directions).
    pub bytes: AtomicU64,
}

impl ChaosStats {
    /// One-line human-readable summary.
    pub fn render(&self) -> String {
        let o = Ordering::Relaxed;
        format!(
            "chaos: conns {} torn {} stalls {} delays {} resets {} \
             ({} bytes)",
            self.connections.load(o),
            self.torn.load(o),
            self.stalls.load(o),
            self.delays.load(o),
            self.resets.load(o),
            self.bytes.load(o),
        )
    }
}

/// A running fault-injection proxy: accepts on its own ephemeral port
/// and pipes every connection to the upstream address through the
/// configured fault mix. Point clients at [`ChaosProxy::addr`].
pub struct ChaosProxy {
    addr: SocketAddr,
    stats: Arc<ChaosStats>,
    stop: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
}

/// Pump loop read timeout — also bounds how long a stopped proxy's
/// worker threads linger.
const PUMP_TICK: Duration = Duration::from_millis(10);

impl ChaosProxy {
    /// Bind an ephemeral local port and start proxying to `upstream`.
    pub fn spawn<A: ToSocketAddrs>(
        upstream: A,
        cfg: ChaosConfig,
    ) -> Result<Self> {
        let upstream =
            upstream.to_socket_addrs()?.next().ok_or_else(|| {
                crate::error::AltDiffError::Coordinator(
                    "chaos: no upstream address".into(),
                )
            })?;
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stats = Arc::new(ChaosStats::default());
        let stop = Arc::new(AtomicBool::new(false));
        let (st, sp) = (stats.clone(), stop.clone());
        let accept_thread = std::thread::spawn(move || {
            let mut conn_id: u64 = 0;
            while !sp.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((down, _)) => {
                        conn_id += 1;
                        st.connections.fetch_add(1, Ordering::Relaxed);
                        if let Ok(up) = TcpStream::connect(upstream) {
                            spawn_pumps(
                                down,
                                up,
                                conn_id,
                                &cfg,
                                &st,
                                &sp,
                            );
                        }
                        // an unreachable upstream drops `down`: the
                        // client sees a clean close and may retry
                    }
                    Err(e)
                        if e.kind()
                            == std::io::ErrorKind::WouldBlock =>
                    {
                        std::thread::sleep(Duration::from_millis(2));
                    }
                    Err(_) => break,
                }
            }
        });
        Ok(ChaosProxy {
            addr,
            stats,
            stop,
            accept_thread: Some(accept_thread),
        })
    }

    /// Address clients should connect to instead of the upstream.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Live fault counters.
    pub fn stats(&self) -> &ChaosStats {
        &self.stats
    }

    /// Stop accepting and wind down the pump threads (they notice the
    /// flag within one [`PUMP_TICK`] and close their sockets).
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ChaosProxy {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Start the two directional pumps for one proxied connection.
fn spawn_pumps(
    down: TcpStream,
    up: TcpStream,
    conn_id: u64,
    cfg: &ChaosConfig,
    stats: &Arc<ChaosStats>,
    stop: &Arc<AtomicBool>,
) {
    let pairs = match (down.try_clone(), up.try_clone()) {
        Ok((d2, u2)) => [(down, u2, 0u64), (d2, up, 1u64)],
        Err(_) => return,
    };
    for (src, dst, dir) in pairs {
        let rng = Pcg64::new(
            cfg.seed ^ (conn_id.wrapping_mul(2).wrapping_add(dir)),
        );
        let (cfg, stats, stop) =
            (cfg.clone(), stats.clone(), stop.clone());
        std::thread::spawn(move || {
            pump(src, dst, cfg, rng, stats, stop);
        });
    }
}

/// Forward `src` → `dst` through the fault mix until EOF, transport
/// error, an injected kill, or proxy stop.
fn pump(
    mut src: TcpStream,
    mut dst: TcpStream,
    cfg: ChaosConfig,
    mut rng: Pcg64,
    stats: Arc<ChaosStats>,
    stop: Arc<AtomicBool>,
) {
    let _ = src.set_read_timeout(Some(PUMP_TICK));
    let mut buf = [0u8; 4096];
    while !stop.load(Ordering::Relaxed) {
        let n = match src.read(&mut buf) {
            Ok(0) => break, // EOF: propagate the close downstream
            Ok(n) => n,
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock
                        | std::io::ErrorKind::TimedOut
                        | std::io::ErrorKind::Interrupted
                ) =>
            {
                continue
            }
            Err(_) => break,
        };
        if cfg.reset_prob > 0.0 && rng.uniform() < cfg.reset_prob {
            stats.resets.fetch_add(1, Ordering::Relaxed);
            let _ = src.shutdown(Shutdown::Both);
            let _ = dst.shutdown(Shutdown::Both);
            return;
        }
        if cfg.delay_prob > 0.0 && rng.uniform() < cfg.delay_prob {
            stats.delays.fetch_add(1, Ordering::Relaxed);
            std::thread::sleep(Duration::from_micros(cfg.delay_us));
        }
        if forward(&mut dst, &buf[..n], &cfg, &mut rng, &stats)
            .is_err()
        {
            let _ = src.shutdown(Shutdown::Both);
            return;
        }
        stats.bytes.fetch_add(n as u64, Ordering::Relaxed);
    }
    let _ = dst.shutdown(Shutdown::Write);
}

/// Write one chunk through the tear/stall/throttle mix.
fn forward(
    dst: &mut TcpStream,
    chunk: &[u8],
    cfg: &ChaosConfig,
    rng: &mut Pcg64,
    stats: &ChaosStats,
) -> std::io::Result<()> {
    let mut parts: Vec<&[u8]> = Vec::with_capacity(2);
    if chunk.len() > 1
        && cfg.tear_prob > 0.0
        && rng.uniform() < cfg.tear_prob
    {
        // split at a seeded offset strictly inside the chunk, so both
        // halves are nonempty and the peer reassembles across them
        let cut = 1 + rng.below(chunk.len() as u64 - 1) as usize;
        stats.torn.fetch_add(1, Ordering::Relaxed);
        parts.push(&chunk[..cut]);
        parts.push(&chunk[cut..]);
    } else {
        parts.push(chunk);
    }
    let torn = parts.len() > 1;
    for (i, part) in parts.into_iter().enumerate() {
        if i > 0
            && torn
            && cfg.stall_prob > 0.0
            && rng.uniform() < cfg.stall_prob
        {
            stats.stalls.fetch_add(1, Ordering::Relaxed);
            std::thread::sleep(Duration::from_micros(cfg.stall_us));
        }
        if cfg.throttle > 0 {
            for slice in part.chunks(cfg.throttle) {
                dst.write_all(slice)?;
                dst.flush()?;
                // idle gap per slice: the upstream writer's send
                // buffer fills and it feels real backpressure
                std::thread::sleep(Duration::from_micros(100));
            }
        } else {
            dst.write_all(part)?;
            dst.flush()?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Plain TCP echo server for proxy tests (no frames: the proxy is
    /// protocol-blind, so bytes-in-order is the whole contract).
    fn echo_server() -> (SocketAddr, JoinHandle<()>) {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap();
        let h = std::thread::spawn(move || {
            // serve a bounded number of connections, then exit
            for _ in 0..4 {
                let Ok((mut s, _)) = l.accept() else { return };
                std::thread::spawn(move || {
                    let mut buf = [0u8; 1024];
                    loop {
                        match s.read(&mut buf) {
                            Ok(0) | Err(_) => return,
                            Ok(n) => {
                                if s.write_all(&buf[..n]).is_err() {
                                    return;
                                }
                            }
                        }
                    }
                });
            }
        });
        (addr, h)
    }

    #[test]
    fn proxied_bytes_survive_tearing_and_stalls_in_order() {
        let (upstream, _h) = echo_server();
        let mut proxy = ChaosProxy::spawn(
            upstream,
            ChaosConfig {
                seed: 42,
                tear_prob: 0.9,
                stall_prob: 0.9,
                stall_us: 200,
                delay_prob: 0.5,
                delay_us: 100,
                throttle: 7,
                ..ChaosConfig::default()
            },
        )
        .unwrap();
        let mut s = TcpStream::connect(proxy.addr()).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let msg: Vec<u8> = (0..=255u8).cycle().take(2048).collect();
        s.write_all(&msg).unwrap();
        let mut got = vec![0u8; msg.len()];
        s.read_exact(&mut got).unwrap();
        assert_eq!(got, msg, "chaos must reorder timing, not bytes");
        let stats = proxy.stats();
        assert!(
            stats.torn.load(Ordering::Relaxed) > 0,
            "tear_prob 0.9 over many chunks must tear at least once"
        );
        assert!(stats.bytes.load(Ordering::Relaxed) >= 2 * 2048);
        proxy.stop();
    }

    #[test]
    fn reset_prob_one_kills_the_connection() {
        let (upstream, _h) = echo_server();
        let proxy = ChaosProxy::spawn(
            upstream,
            ChaosConfig {
                seed: 7,
                tear_prob: 0.0,
                stall_prob: 0.0,
                delay_prob: 0.0,
                reset_prob: 1.0,
                ..ChaosConfig::default()
            },
        )
        .unwrap();
        let mut s = TcpStream::connect(proxy.addr()).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        s.write_all(b"doomed").unwrap();
        let mut buf = [0u8; 16];
        // the kill manifests as EOF (Ok(0)) or a reset error — either
        // way, never the echoed payload
        match s.read(&mut buf) {
            Ok(0) | Err(_) => {}
            Ok(n) => panic!("expected a dead conn, read {n} bytes"),
        }
        assert!(proxy.stats().resets.load(Ordering::Relaxed) >= 1);
    }

    #[test]
    fn seeded_reruns_inject_identically() {
        for _ in 0..2 {
            let (upstream, _h) = echo_server();
            let proxy = ChaosProxy::spawn(
                upstream,
                ChaosConfig {
                    seed: 99,
                    tear_prob: 0.5,
                    stall_prob: 0.0,
                    delay_prob: 0.0,
                    throttle: 0,
                    ..ChaosConfig::default()
                },
            )
            .unwrap();
            let mut s = TcpStream::connect(proxy.addr()).unwrap();
            s.set_read_timeout(Some(Duration::from_secs(10)))
                .unwrap();
            let msg = vec![0xA5u8; 512];
            s.write_all(&msg).unwrap();
            let mut got = vec![0u8; msg.len()];
            s.read_exact(&mut got).unwrap();
            assert_eq!(got, msg);
            // determinism caveat: chunk boundaries depend on kernel
            // read coalescing, so we assert the *stream* (seeded RNG
            // per conn/direction) not an exact tear count
            drop(s);
        }
    }
}
