//! Versioned binary codec for coordinator requests/replies.
//!
//! Every message is one frame (see [`super::frame`] for the header).
//! Payload encoding is little-endian throughout; variable-length fields
//! carry an explicit count and are validated against the remaining
//! payload *before* allocation, so truncated or hostile frames return
//! [`AltDiffError::Protocol`] — never a panic, never an over-allocation.
//!
//! Request payloads (`op::SOLVE` / `op::GRAD`):
//!
//! ```text
//!   id u64 · tol f64 · session u8 [· key u64] · layer str16
//!   · q f64vec · b f64vec · h f64vec
//!   [· v f64vec]                      -- GRAD only (adjoint seed)
//!   [· prio u8 [· class u8] · ddl u8 [· budget u32]    -- extension
//!    [· echo u8]]                     -- stage-echo opt-in (tag = 1)
//! ```
//!
//! `session` is the optional warm-start session key: a one-byte
//! presence tag (0 = absent, 1 = present, anything else →
//! [`AltDiffError::Protocol`]) followed by the u64 key when present.
//! Requests sharing a key share a slot in the server's warm-start
//! cache (see [`crate::warm`]), so a remote caller's repeated solves
//! resume from each other's iterates across requests.
//!
//! The trailing **extension block** carries the traffic-plane fields
//! (priority class and per-request deadline budget in µs) with the same
//! presence-tag style. It is *omitted entirely* when everything is at
//! its default (Normal priority, no deadline, no stage echo), so
//! pre-extension encoders and decoders stay byte-compatible: an old
//! client's payload simply ends after h/v and decodes to the defaults,
//! and a new client talking to an old server only breaks if it actually
//! sets the new fields. The final **stage-echo** byte opts the request
//! into the observability plane: when present (value 1) the server's
//! reply appends the per-stage latency breakdown (see
//! [`reply_payload_len`]); a payload that ends after the deadline field
//! decodes as echo-off, so pre-echo traffic-plane frames still parse.
//! Malformed values (tag ∉ {0,1}, class > 2, budget 0, echo ≠ 1) come
//! back as [`AltDiffError::Protocol`] — never a panic.
//!
//! Reply payloads mirror [`Reply`]'s three arms (`op::R_SOLVE`,
//! `op::R_GRAD`, `op::R_ERR`); admin ops (`op::STATS`, `op::LAYERS`,
//! `op::STOP`) have empty request payloads. `str16` is a u16 byte count
//! plus UTF-8 bytes; `f64vec` is a u32 element count plus raw LE f64s.

use crate::coordinator::{
    Failure, FailureKind, GradientResponse, Priority, Reply, Request,
    Response,
};
use crate::error::{AltDiffError, Result};
use crate::obs::{StageSpans, StageStamps, N_SPANS};
use super::frame::header;
use std::time::Instant;

/// Frame opcodes. Requests are < 0x80, replies have the top bit set.
pub mod op {
    /// Solve request (classic forward + ∂x/∂b Jacobian reply).
    pub const SOLVE: u8 = 0x01;
    /// Gradient request (adjoint path; carries the seed v).
    pub const GRAD: u8 = 0x02;
    /// Stats request: reply is the Prometheus text rendering.
    pub const STATS: u8 = 0x03;
    /// Layer-discovery request: reply lists `(name, n, m, p)`.
    pub const LAYERS: u8 = 0x04;
    /// Graceful-stop request (SIGTERM over the wire; std has no
    /// dependency-free signal handling). The reply is a final stats
    /// frame sent *after* the drain completes (right before the
    /// goodbye), so it includes work that finished during the drain.
    pub const STOP: u8 = 0x05;
    /// Solve reply ([`crate::coordinator::Response`]).
    pub const R_SOLVE: u8 = 0x81;
    /// Gradient reply ([`crate::coordinator::GradientResponse`]).
    pub const R_GRAD: u8 = 0x82;
    /// Failure reply ([`crate::coordinator::Failure`]).
    pub const R_ERR: u8 = 0x83;
    /// Stats reply (UTF-8 text).
    pub const R_STATS: u8 = 0x84;
    /// Layer-discovery reply.
    pub const R_LAYERS: u8 = 0x85;
    /// Server-initiated goodbye: sent to every open connection when the
    /// server drains on shutdown, right before close.
    pub const R_GOODBYE: u8 = 0x86;
}

/// Backend tags (`Response::backend` is `&'static str` in-process).
fn backend_code(b: &str) -> u8 {
    match b {
        "native" => 0,
        "native-sparse" => 1,
        "pjrt" => 2,
        "native-admm" => 3,
        _ => 255,
    }
}

fn backend_str(c: u8) -> &'static str {
    match c {
        0 => "native",
        1 => "native-sparse",
        2 => "pjrt",
        3 => "native-admm",
        _ => "unknown",
    }
}

// ---------------------------------------------------------------- write

struct Wr {
    buf: Vec<u8>,
}

impl Wr {
    fn new(op_: u8) -> Self {
        // header is patched with the real length in `finish`
        let mut buf = header(op_, 0).to_vec();
        buf.reserve(64);
        Wr { buf }
    }

    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn str16(&mut self, s: &str) {
        let n = s.len().min(u16::MAX as usize);
        self.buf
            .extend_from_slice(&(n as u16).to_le_bytes());
        self.buf.extend_from_slice(&s.as_bytes()[..n]);
    }

    fn str32(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }

    fn f64_vec(&mut self, v: &[f64]) {
        self.u32(v.len() as u32);
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    fn finish(mut self) -> Vec<u8> {
        let len = (self.buf.len() - super::frame::HEADER_LEN) as u32;
        self.buf[4..8].copy_from_slice(&len.to_le_bytes());
        self.buf
    }
}

// ----------------------------------------------------------------- read

struct Rd<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Rd<'a> {
    fn new(b: &'a [u8]) -> Self {
        Rd { b, pos: 0 }
    }

    fn need(&self, n: usize) -> Result<()> {
        if self.b.len() - self.pos < n {
            return Err(AltDiffError::Protocol(format!(
                "truncated payload: need {n} bytes at offset {}, have {}",
                self.pos,
                self.b.len() - self.pos
            )));
        }
        Ok(())
    }

    fn u8(&mut self) -> Result<u8> {
        self.need(1)?;
        let v = self.b[self.pos];
        self.pos += 1;
        Ok(v)
    }

    fn u16(&mut self) -> Result<u16> {
        self.need(2)?;
        let v = u16::from_le_bytes(
            self.b[self.pos..self.pos + 2].try_into().unwrap(),
        );
        self.pos += 2;
        Ok(v)
    }

    fn u32(&mut self) -> Result<u32> {
        self.need(4)?;
        let v = u32::from_le_bytes(
            self.b[self.pos..self.pos + 4].try_into().unwrap(),
        );
        self.pos += 4;
        Ok(v)
    }

    fn u64(&mut self) -> Result<u64> {
        self.need(8)?;
        let v = u64::from_le_bytes(
            self.b[self.pos..self.pos + 8].try_into().unwrap(),
        );
        self.pos += 8;
        Ok(v)
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        self.need(n)?;
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn str16(&mut self) -> Result<String> {
        let n = self.u16()? as usize;
        let raw = self.bytes(n)?;
        String::from_utf8(raw.to_vec()).map_err(|_| {
            AltDiffError::Protocol("string field is not UTF-8".into())
        })
    }

    fn str32(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        let raw = self.bytes(n)?;
        String::from_utf8(raw.to_vec()).map_err(|_| {
            AltDiffError::Protocol("string field is not UTF-8".into())
        })
    }

    /// Count-prefixed f64 vector. The count is validated against the
    /// *remaining payload* before the Vec is allocated — a hostile
    /// `u32::MAX` count fails here instead of reserving 32 GiB.
    fn f64_vec(&mut self) -> Result<Vec<f64>> {
        let n = self.u32()? as usize;
        self.need(n.checked_mul(8).ok_or_else(|| {
            AltDiffError::Protocol("vector count overflows".into())
        })?)?;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(self.f64()?);
        }
        Ok(v)
    }

    fn done(&self) -> Result<()> {
        if self.pos != self.b.len() {
            return Err(AltDiffError::Protocol(format!(
                "{} trailing bytes after payload",
                self.b.len() - self.pos
            )));
        }
        Ok(())
    }
}

// ------------------------------------------------------------- requests

/// Exact payload size of a request, computed without encoding it —
/// clients check it against the frame limit before allocating the
/// frame (mirror of [`reply_payload_len`]; kept in sync with
/// [`encode_request`], which debug-asserts the equality).
pub fn request_payload_len(req: &Request) -> usize {
    let vec_len = |v: &[f64]| 4 + 8 * v.len();
    // id u64 + tol f64 + session tag u8 [+ key u64]
    // + layer str16 (name truncated at u16::MAX)
    8 + 8
        + 1
        + if req.session.is_some() { 8 } else { 0 }
        + (2 + req.layer.len().min(u16::MAX as usize))
        + vec_len(&req.q)
        + vec_len(&req.b)
        + vec_len(&req.h)
        + req.grad_v.as_deref().map(vec_len).unwrap_or(0)
        + extension_len(req)
}

/// Size of the trailing traffic-plane extension block (0 when every
/// field is at its default and the block is omitted).
fn extension_len(req: &Request) -> usize {
    if req.priority == Priority::Normal
        && req.deadline_us.is_none()
        && !req.echo_stages
    {
        return 0;
    }
    // prio tag u8 [+ class u8] + ddl tag u8 [+ budget u32] [+ echo u8]
    1 + if req.priority != Priority::Normal { 1 } else { 0 }
        + 1
        + if req.deadline_us.is_some() { 4 } else { 0 }
        + usize::from(req.echo_stages)
}

/// Encode a request as one frame (opcode chosen by the adjoint seed:
/// `grad_v = Some` → `op::GRAD`). The `submitted` timestamp is *not*
/// encoded — the receiving server stamps arrival time, so served
/// latency covers queue + execution, not the client's network path.
pub fn encode_request(req: &Request) -> Vec<u8> {
    let opcode = if req.is_grad() { op::GRAD } else { op::SOLVE };
    let mut w = Wr::new(opcode);
    w.u64(req.id);
    w.f64(req.tol);
    match req.session {
        Some(key) => {
            w.u8(1);
            w.u64(key);
        }
        None => w.u8(0),
    }
    w.str16(&req.layer);
    w.f64_vec(&req.q);
    w.f64_vec(&req.b);
    w.f64_vec(&req.h);
    if let Some(v) = &req.grad_v {
        w.f64_vec(v);
    }
    // traffic-plane extension: omitted entirely at the defaults, so
    // default-request frames are byte-identical to pre-extension ones.
    // The stage-echo byte rides at the tail and is only written when
    // set, so echo-off frames match pre-echo encoders byte for byte.
    if req.priority != Priority::Normal
        || req.deadline_us.is_some()
        || req.echo_stages
    {
        match req.priority {
            Priority::Normal => w.u8(0),
            p => {
                w.u8(1);
                w.u8(p.code());
            }
        }
        match req.deadline_us {
            Some(us) => {
                w.u8(1);
                w.u32(us);
            }
            None => w.u8(0),
        }
        if req.echo_stages {
            w.u8(1);
        }
    }
    let frame = w.finish();
    debug_assert_eq!(
        frame.len() - super::frame::HEADER_LEN,
        request_payload_len(req),
        "request_payload_len out of sync with the encoder"
    );
    frame
}

/// Decode a request payload for `opcode` (`op::SOLVE` or `op::GRAD`).
pub fn decode_request(opcode: u8, payload: &[u8]) -> Result<Request> {
    if opcode != op::SOLVE && opcode != op::GRAD {
        return Err(AltDiffError::Protocol(format!(
            "opcode 0x{opcode:02x} is not a request"
        )));
    }
    let mut r = Rd::new(payload);
    let id = r.u64()?;
    let tol = r.f64()?;
    let session = match r.u8()? {
        0 => None,
        1 => Some(r.u64()?),
        tag => {
            return Err(AltDiffError::Protocol(format!(
                "session presence tag must be 0 or 1, got {tag}"
            )))
        }
    };
    let layer = r.str16()?;
    let q = r.f64_vec()?;
    let b = r.f64_vec()?;
    let h = r.f64_vec()?;
    let grad_v = if opcode == op::GRAD {
        Some(r.f64_vec()?)
    } else {
        None
    };
    let (priority, deadline_us, echo_stages) = decode_extension(&mut r)?;
    r.done()?;
    Ok(Request {
        id,
        layer,
        q,
        b,
        h,
        tol,
        grad_v,
        session,
        priority,
        deadline_us,
        submitted: Instant::now(),
        stamps: StageStamps::off(),
        sampled: false,
        echo_stages,
    })
}

/// Decode the trailing traffic-plane extension block. An exhausted
/// reader (a pre-extension client's payload) yields the defaults;
/// anything present must be well-formed or the whole request is a
/// `Protocol` error. The stage-echo byte is likewise optional *within*
/// the block: a payload that ends after the deadline field (a pre-echo
/// traffic-plane client) decodes as echo-off.
fn decode_extension(
    r: &mut Rd<'_>,
) -> Result<(Priority, Option<u32>, bool)> {
    if r.pos == r.b.len() {
        return Ok((Priority::Normal, None, false));
    }
    let priority = match r.u8()? {
        0 => Priority::Normal,
        1 => {
            let code = r.u8()?;
            Priority::from_code(code).ok_or_else(|| {
                AltDiffError::Protocol(format!(
                    "priority class must be 0..=2, got {code}"
                ))
            })?
        }
        tag => {
            return Err(AltDiffError::Protocol(format!(
                "priority presence tag must be 0 or 1, got {tag}"
            )))
        }
    };
    let deadline_us = match r.u8()? {
        0 => None,
        1 => {
            let us = r.u32()?;
            if us == 0 {
                return Err(AltDiffError::Protocol(
                    "deadline budget must be positive".into(),
                ));
            }
            Some(us)
        }
        tag => {
            return Err(AltDiffError::Protocol(format!(
                "deadline presence tag must be 0 or 1, got {tag}"
            )))
        }
    };
    let echo_stages = if r.pos == r.b.len() {
        false
    } else {
        match r.u8()? {
            1 => true,
            tag => {
                return Err(AltDiffError::Protocol(format!(
                    "stage-echo tag must be 1, got {tag}"
                )))
            }
        }
    };
    Ok((priority, deadline_us, echo_stages))
}

/// Allocation-free skip-parse of a request payload's traffic-plane
/// metadata: `(client id, priority, deadline budget)`. The admission
/// path uses this to shed expired or over-budget requests *before*
/// paying the full θ deserialization — no `Vec` is ever allocated, the
/// reader only skips over the count-prefixed fields. Returns the same
/// `Protocol` errors full decoding would, so a caller that sheds on
/// `Ok` and falls through to [`decode_request`] on `Err` reports the
/// identical failure.
pub fn peek_request_meta(
    opcode: u8,
    payload: &[u8],
) -> Result<(u64, Priority, Option<u32>)> {
    if opcode != op::SOLVE && opcode != op::GRAD {
        return Err(AltDiffError::Protocol(format!(
            "opcode 0x{opcode:02x} is not a request"
        )));
    }
    let mut r = Rd::new(payload);
    let id = r.u64()?;
    r.bytes(8)?; // tol
    match r.u8()? {
        0 => {}
        1 => {
            r.bytes(8)?; // session key
        }
        tag => {
            return Err(AltDiffError::Protocol(format!(
                "session presence tag must be 0 or 1, got {tag}"
            )))
        }
    }
    let name_len = r.u16()? as usize;
    r.bytes(name_len)?;
    let vecs = if opcode == op::GRAD { 4 } else { 3 };
    for _ in 0..vecs {
        let n = r.u32()? as usize;
        r.bytes(n.checked_mul(8).ok_or_else(|| {
            AltDiffError::Protocol("vector count overflows".into())
        })?)?;
    }
    let (priority, deadline_us, _echo) = decode_extension(&mut r)?;
    r.done()?;
    Ok((id, priority, deadline_us))
}

// -------------------------------------------------------------- replies

/// Exact payload size of a reply, computed without encoding it (8
/// bytes per f64, length prefixes per the field docs above). Keep in
/// sync with [`encode_reply`]'s writers — `encode_reply` debug-asserts
/// the equality.
fn reply_payload_len(reply: &Reply) -> usize {
    // fixed: id u64 + k u32 + bs u32 + prim f64 + lat f64 + backend u8
    const DATA_FIXED: usize = 8 + 4 + 4 + 8 + 8 + 1;
    let vec_len = |v: &[f64]| 4 + 8 * v.len();
    // optional trailing stage-echo block: tag u8 + N_SPANS × u32.
    // Present only when the request opted in (`stages = Some`), so a
    // non-echo reply is byte-identical to a pre-echo server's.
    let stage_len = |s: &Option<StageSpans>| {
        if s.is_some() {
            1 + 4 * N_SPANS
        } else {
            0
        }
    };
    match reply {
        Reply::Ok(r) => {
            DATA_FIXED
                + vec_len(&r.x)
                + vec_len(&r.jx)
                + stage_len(&r.stages)
        }
        Reply::Grad(g) => {
            DATA_FIXED
                + vec_len(&g.x)
                + vec_len(&g.grad_q)
                + vec_len(&g.grad_b)
                + vec_len(&g.grad_h)
                + stage_len(&g.stages)
        }
        Reply::Err(f) => 8 + 1 + 4 + f.error.len(),
    }
}

/// Encode a reply as one frame (opcode chosen by the arm). A reply
/// whose payload would exceed [`super::frame::MAX_PAYLOAD`] — e.g. the
/// (n × p) Jacobian of a very large dense layer — is replaced by an
/// explicit [`FailureKind::Exec`] failure frame carrying the same id:
/// the peer gets a parseable, classified answer instead of a frame its
/// own header validation must reject (which would desync the stream).
/// The size check runs on the computed length *before* any encoding,
/// so the oversized case never allocates the doomed frame.
pub fn encode_reply(reply: &Reply) -> Vec<u8> {
    let payload_len = reply_payload_len(reply);
    if payload_len > super::frame::MAX_PAYLOAD as usize {
        return encode_reply_unchecked(&Reply::Err(Failure {
            id: reply.id(),
            kind: FailureKind::Exec,
            error: format!(
                "reply payload {payload_len} bytes exceeds the wire \
                 limit {}; request the adjoint (grad) path instead of \
                 the Jacobian",
                super::frame::MAX_PAYLOAD
            ),
        }));
    }
    let frame = encode_reply_unchecked(reply);
    debug_assert_eq!(
        frame.len() - super::frame::HEADER_LEN,
        payload_len,
        "reply_payload_len out of sync with the encoder"
    );
    frame
}

fn encode_reply_unchecked(reply: &Reply) -> Vec<u8> {
    match reply {
        Reply::Ok(r) => {
            let mut w = Wr::new(op::R_SOLVE);
            w.u64(r.id);
            w.u32(r.k_used as u32);
            w.u32(r.batch_size as u32);
            w.f64(r.prim_residual);
            w.f64(r.latency);
            w.u8(backend_code(r.backend));
            w.f64_vec(&r.x);
            w.f64_vec(&r.jx);
            encode_stage_echo(&mut w, &r.stages);
            w.finish()
        }
        Reply::Grad(g) => {
            let mut w = Wr::new(op::R_GRAD);
            w.u64(g.id);
            w.u32(g.k_used as u32);
            w.u32(g.batch_size as u32);
            w.f64(g.prim_residual);
            w.f64(g.latency);
            w.u8(backend_code(g.backend));
            w.f64_vec(&g.x);
            w.f64_vec(&g.grad_q);
            w.f64_vec(&g.grad_b);
            w.f64_vec(&g.grad_h);
            encode_stage_echo(&mut w, &g.stages);
            w.finish()
        }
        Reply::Err(f) => {
            let mut w = Wr::new(op::R_ERR);
            w.u64(f.id);
            w.u8(f.kind.code());
            w.str32(&f.error);
            w.finish()
        }
    }
}

/// Write the optional stage-echo block: tag 1 + the six span widths
/// in µs (decode order matches [`crate::obs::SPAN_LABELS`]). Nothing
/// is written when the request did not opt in.
fn encode_stage_echo(w: &mut Wr, stages: &Option<StageSpans>) {
    if let Some(spans) = stages {
        w.u8(1);
        for &v in spans.iter() {
            w.u32(v);
        }
    }
}

/// Parse the optional trailing stage-echo block. An exhausted reader
/// (a pre-echo server, or a request that did not opt in) yields
/// `None`; a present block must be well-formed.
fn decode_stage_echo(r: &mut Rd<'_>) -> Result<Option<StageSpans>> {
    if r.pos == r.b.len() {
        return Ok(None);
    }
    match r.u8()? {
        1 => {
            let mut spans: StageSpans = [0; N_SPANS];
            for s in spans.iter_mut() {
                *s = r.u32()?;
            }
            Ok(Some(spans))
        }
        tag => Err(AltDiffError::Protocol(format!(
            "stage-echo tag must be 1, got {tag}"
        ))),
    }
}

/// Decode a reply payload for `opcode` (any of the three reply arms).
pub fn decode_reply(opcode: u8, payload: &[u8]) -> Result<Reply> {
    let mut r = Rd::new(payload);
    match opcode {
        op::R_SOLVE => {
            let id = r.u64()?;
            let k_used = r.u32()? as usize;
            let batch_size = r.u32()? as usize;
            let prim_residual = r.f64()?;
            let latency = r.f64()?;
            let backend = backend_str(r.u8()?);
            let x = r.f64_vec()?;
            let jx = r.f64_vec()?;
            let stages = decode_stage_echo(&mut r)?;
            r.done()?;
            Ok(Reply::Ok(Response {
                id,
                x,
                jx,
                prim_residual,
                k_used,
                batch_size,
                latency,
                backend,
                stamps: StageStamps::off(),
                stages,
            }))
        }
        op::R_GRAD => {
            let id = r.u64()?;
            let k_used = r.u32()? as usize;
            let batch_size = r.u32()? as usize;
            let prim_residual = r.f64()?;
            let latency = r.f64()?;
            let backend = backend_str(r.u8()?);
            let x = r.f64_vec()?;
            let grad_q = r.f64_vec()?;
            let grad_b = r.f64_vec()?;
            let grad_h = r.f64_vec()?;
            let stages = decode_stage_echo(&mut r)?;
            r.done()?;
            Ok(Reply::Grad(GradientResponse {
                id,
                x,
                grad_q,
                grad_b,
                grad_h,
                prim_residual,
                k_used,
                batch_size,
                latency,
                backend,
                stamps: StageStamps::off(),
                stages,
            }))
        }
        op::R_ERR => {
            let id = r.u64()?;
            let kind = FailureKind::from_code(r.u8()?).ok_or_else(|| {
                AltDiffError::Protocol("unknown failure kind".into())
            })?;
            let error = r.str32()?;
            r.done()?;
            Ok(Reply::Err(Failure { id, kind, error }))
        }
        other => Err(AltDiffError::Protocol(format!(
            "opcode 0x{other:02x} is not a reply"
        ))),
    }
}

// ------------------------------------------------------------ admin ops

/// One registered layer as advertised by the discovery op.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LayerInfo {
    /// Registration name (routing key).
    pub name: String,
    /// Variables n.
    pub n: usize,
    /// Inequality constraints m.
    pub m: usize,
    /// Equality constraints p.
    pub p: usize,
}

/// Encode an empty-payload admin request (`op::STATS`, `op::LAYERS`,
/// `op::STOP`).
pub fn encode_admin(opcode: u8) -> Vec<u8> {
    header(opcode, 0).to_vec()
}

/// Encode a stats reply (Prometheus text).
pub fn encode_stats_reply(text: &str) -> Vec<u8> {
    let mut w = Wr::new(op::R_STATS);
    w.str32(text);
    w.finish()
}

/// Decode a stats reply payload.
pub fn decode_stats_reply(payload: &[u8]) -> Result<String> {
    let mut r = Rd::new(payload);
    let s = r.str32()?;
    r.done()?;
    Ok(s)
}

/// Encode the layer-discovery reply.
pub fn encode_layers_reply(
    layers: &[(String, usize, usize, usize)],
) -> Vec<u8> {
    let mut w = Wr::new(op::R_LAYERS);
    w.u32(layers.len() as u32);
    for (name, n, m, p) in layers {
        w.str16(name);
        w.u32(*n as u32);
        w.u32(*m as u32);
        w.u32(*p as u32);
    }
    w.finish()
}

/// Decode the layer-discovery reply payload.
pub fn decode_layers_reply(payload: &[u8]) -> Result<Vec<LayerInfo>> {
    let mut r = Rd::new(payload);
    let count = r.u32()? as usize;
    // each entry is ≥ 14 bytes; bound count before allocating
    if count > payload.len() / 14 {
        return Err(AltDiffError::Protocol(format!(
            "layer count {count} exceeds payload"
        )));
    }
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        let name = r.str16()?;
        let n = r.u32()? as usize;
        let m = r.u32()? as usize;
        let p = r.u32()? as usize;
        out.push(LayerInfo { name, n, m, p });
    }
    r.done()?;
    Ok(out)
}

/// Encode the server's goodbye frame (drain notice before close).
pub fn encode_goodbye(msg: &str) -> Vec<u8> {
    let mut w = Wr::new(op::R_GOODBYE);
    w.str32(msg);
    w.finish()
}

/// Decode a goodbye payload.
pub fn decode_goodbye(payload: &[u8]) -> Result<String> {
    let mut r = Rd::new(payload);
    let s = r.str32()?;
    r.done()?;
    Ok(s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::frame::{parse_header, HEADER_LEN};

    fn strip(frame: &[u8]) -> (u8, &[u8]) {
        let (op_, len) = parse_header(frame).unwrap();
        assert_eq!(frame.len(), HEADER_LEN + len);
        (op_, &frame[HEADER_LEN..])
    }

    #[test]
    fn solve_request_round_trips() {
        let req = Request {
            id: 42,
            layer: "qp16".into(),
            q: vec![1.0, -2.5, 3.25],
            b: vec![0.5],
            h: vec![1.0, 2.0],
            tol: 1e-3,
            grad_v: None,
            session: None,
            priority: Priority::Normal,
            deadline_us: None,
            submitted: Instant::now(),
            stamps: StageStamps::off(),
            sampled: false,
            echo_stages: false,
        };
        let frame = encode_request(&req);
        let (op_, payload) = strip(&frame);
        assert_eq!(op_, op::SOLVE);
        let back = decode_request(op_, payload).unwrap();
        assert_eq!(back.id, req.id);
        assert_eq!(back.layer, req.layer);
        assert_eq!(back.q, req.q);
        assert_eq!(back.b, req.b);
        assert_eq!(back.h, req.h);
        assert_eq!(back.tol, req.tol);
        assert!(back.grad_v.is_none());
        assert_eq!(back.priority, Priority::Normal);
        assert_eq!(back.deadline_us, None);
    }

    #[test]
    fn grad_request_round_trips() {
        let req = Request {
            id: 7,
            layer: "l".into(),
            q: vec![0.0; 4],
            b: vec![],
            h: vec![9.0],
            tol: 1e-2,
            grad_v: Some(vec![1.0, 0.0, -1.0, 2.0]),
            session: Some(0xfeed_beef),
            priority: Priority::Normal,
            deadline_us: None,
            submitted: Instant::now(),
            stamps: StageStamps::off(),
            sampled: false,
            echo_stages: false,
        };
        let frame = encode_request(&req);
        let (op_, payload) = strip(&frame);
        assert_eq!(op_, op::GRAD);
        let back = decode_request(op_, payload).unwrap();
        assert_eq!(back.grad_v, req.grad_v);
    }

    #[test]
    fn err_reply_round_trips_kind() {
        let f = Failure::new(3, FailureKind::Overloaded, "busy");
        let frame = encode_reply(&Reply::Err(f));
        let (op_, payload) = strip(&frame);
        match decode_reply(op_, payload).unwrap() {
            Reply::Err(f) => {
                assert_eq!(f.id, 3);
                assert_eq!(f.kind, FailureKind::Overloaded);
                assert_eq!(f.error, "busy");
            }
            _ => panic!("wrong arm"),
        }
    }

    #[test]
    fn trailing_bytes_are_rejected() {
        let req = Request {
            id: 1,
            layer: "l".into(),
            q: vec![],
            b: vec![],
            h: vec![],
            tol: 0.1,
            grad_v: None,
            session: None,
            priority: Priority::Normal,
            deadline_us: None,
            submitted: Instant::now(),
            stamps: StageStamps::off(),
            sampled: false,
            echo_stages: false,
        };
        let frame = encode_request(&req);
        let (op_, payload) = strip(&frame);
        // a single appended byte now reads as a truncated extension
        // block (prio tag with nothing after) — still a Protocol error
        let mut longer = payload.to_vec();
        longer.push(0);
        assert!(decode_request(op_, &longer).is_err());
        // two appended zero bytes parse as an explicit all-default
        // extension, which is legal
        let mut explicit = payload.to_vec();
        explicit.extend_from_slice(&[0, 0]);
        let back = decode_request(op_, &explicit).unwrap();
        assert_eq!(back.priority, Priority::Normal);
        assert_eq!(back.deadline_us, None);
        assert!(!back.echo_stages);
        // a third byte is the stage-echo tag: 1 opts in, 0 is invalid
        let mut echoed = payload.to_vec();
        echoed.extend_from_slice(&[0, 0, 1]);
        assert!(decode_request(op_, &echoed).unwrap().echo_stages);
        let mut garbage = payload.to_vec();
        garbage.extend_from_slice(&[0, 0, 0]);
        assert!(decode_request(op_, &garbage).is_err());
        // anything after the echo byte is trailing garbage again
        let mut longer_still = payload.to_vec();
        longer_still.extend_from_slice(&[0, 0, 1, 1]);
        assert!(decode_request(op_, &longer_still).is_err());
    }

    #[test]
    fn priority_and_deadline_round_trip() {
        for (prio, ddl) in [
            (Priority::High, Some(1_500u32)),
            (Priority::Low, None),
            (Priority::Normal, Some(250_000)),
            (Priority::High, None),
        ] {
            let req = Request {
                id: 11,
                layer: "qp16".into(),
                q: vec![1.0, 2.0],
                b: vec![3.0],
                h: vec![4.0],
                tol: 1e-3,
                grad_v: None,
                session: Some(9),
                priority: prio,
                deadline_us: ddl,
                submitted: Instant::now(),
                stamps: StageStamps::off(),
                sampled: false,
                echo_stages: false,
            };
            let frame = encode_request(&req);
            let (op_, payload) = strip(&frame);
            let back = decode_request(op_, payload).unwrap();
            assert_eq!(back.priority, prio);
            assert_eq!(back.deadline_us, ddl);
            // the skip-parse peek agrees with the full decode
            let (id, p, d) = peek_request_meta(op_, payload).unwrap();
            assert_eq!((id, p, d), (11, prio, ddl));
        }
    }

    #[test]
    fn default_requests_omit_the_extension_block() {
        // old decoders must keep working: a default request's payload
        // ends exactly where the pre-extension format did
        let mut req = Request {
            id: 5,
            layer: "l".into(),
            q: vec![1.0],
            b: vec![],
            h: vec![],
            tol: 1e-2,
            grad_v: None,
            session: None,
            priority: Priority::Normal,
            deadline_us: None,
            submitted: Instant::now(),
            stamps: StageStamps::off(),
            sampled: false,
            echo_stages: false,
        };
        let default_len = encode_request(&req).len();
        req.priority = Priority::Low;
        // prio tag + class code + (empty) deadline tag
        assert_eq!(encode_request(&req).len(), default_len + 3);
        req.deadline_us = Some(1000);
        assert_eq!(encode_request(&req).len(), default_len + 3 + 4);
        // stage echo adds exactly one opt-in byte at the tail
        req.echo_stages = true;
        assert_eq!(encode_request(&req).len(), default_len + 3 + 4 + 1);
        // echo alone forces the block with explicit default tags
        req.priority = Priority::Normal;
        req.deadline_us = None;
        assert_eq!(encode_request(&req).len(), default_len + 3);
        let frame = encode_request(&req);
        let (op_, payload) = strip(&frame);
        let back = decode_request(op_, payload).unwrap();
        assert!(back.echo_stages);
        assert_eq!(back.priority, Priority::Normal);
        assert_eq!(back.deadline_us, None);
    }

    #[test]
    fn malformed_extension_fields_are_protocol_errors() {
        let req = Request {
            id: 1,
            layer: "l".into(),
            q: vec![],
            b: vec![],
            h: vec![],
            tol: 0.1,
            grad_v: None,
            session: None,
            priority: Priority::Normal,
            deadline_us: None,
            submitted: Instant::now(),
            stamps: StageStamps::off(),
            sampled: false,
            echo_stages: false,
        };
        let frame = encode_request(&req);
        let (op_, payload) = strip(&frame);
        let check = |ext: &[u8]| {
            let mut p = payload.to_vec();
            p.extend_from_slice(ext);
            let err = decode_request(op_, &p).unwrap_err();
            assert!(matches!(err, AltDiffError::Protocol(_)), "{ext:?}");
            let err = peek_request_meta(op_, &p).unwrap_err();
            assert!(matches!(err, AltDiffError::Protocol(_)), "{ext:?}");
        };
        check(&[2, 0]); // bad priority presence tag
        check(&[1, 3, 0]); // priority class out of range
        check(&[0, 2]); // bad deadline presence tag
        check(&[0, 1, 0, 0, 0, 0]); // zero deadline budget
        check(&[1, 1]); // truncated: deadline tag missing
        check(&[0, 0, 2]); // bad stage-echo tag
    }

    #[test]
    fn peek_meta_defaults_match_old_payloads() {
        let req = Request {
            id: 77,
            layer: "qp".into(),
            q: vec![0.5; 3],
            b: vec![1.0],
            h: vec![2.0; 2],
            tol: 1e-3,
            grad_v: Some(vec![1.0; 3]),
            session: None,
            priority: Priority::Normal,
            deadline_us: None,
            submitted: Instant::now(),
            stamps: StageStamps::off(),
            sampled: false,
            echo_stages: false,
        };
        let frame = encode_request(&req);
        let (op_, payload) = strip(&frame);
        let (id, p, d) = peek_request_meta(op_, payload).unwrap();
        assert_eq!(id, 77);
        assert_eq!(p, Priority::Normal);
        assert_eq!(d, None);
        // hostile: peek must reject what decode rejects, without panic
        assert!(peek_request_meta(op::R_SOLVE, payload).is_err());
        assert!(peek_request_meta(op_, &payload[..5]).is_err());
    }

    #[test]
    fn admm_backend_survives_the_wire() {
        let reply = Reply::Ok(Response {
            id: 1,
            x: vec![1.0],
            jx: vec![],
            prim_residual: 0.0,
            k_used: 10,
            batch_size: 1,
            latency: 0.0,
            backend: "native-admm",
            stamps: StageStamps::off(),
            stages: None,
        });
        let frame = encode_reply(&reply);
        let (op_, payload) = strip(&frame);
        match decode_reply(op_, payload).unwrap() {
            Reply::Ok(r) => assert_eq!(r.backend, "native-admm"),
            _ => panic!("wrong arm"),
        }
    }

    #[test]
    fn layers_round_trip() {
        let layers = vec![
            ("qp16".to_string(), 16usize, 8usize, 4usize),
            ("smax40".to_string(), 40, 40, 1),
        ];
        let frame = encode_layers_reply(&layers);
        let (op_, payload) = strip(&frame);
        assert_eq!(op_, op::R_LAYERS);
        let back = decode_layers_reply(payload).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back[0].name, "qp16");
        assert_eq!(back[1].m, 40);
    }

    #[test]
    fn stats_and_goodbye_round_trip() {
        let frame = encode_stats_reply("altdiff_requests_total 5\n");
        let (_, payload) = strip(&frame);
        assert!(decode_stats_reply(payload)
            .unwrap()
            .contains("requests_total"));
        let frame = encode_goodbye("drained");
        let (op_, payload) = strip(&frame);
        assert_eq!(op_, op::R_GOODBYE);
        assert_eq!(decode_goodbye(payload).unwrap(), "drained");
    }

    #[test]
    fn oversized_reply_degrades_to_an_exec_failure_frame() {
        // a reply that cannot fit MAX_PAYLOAD must come out as a
        // parseable failure frame with the same id, not an over-limit
        // frame the peer's header validation would reject
        let reply = Reply::Ok(Response {
            id: 99,
            x: vec![1.0; 2_200_000], // ~17.6 MB of payload
            jx: vec![],
            prim_residual: 0.0,
            k_used: 10,
            batch_size: 1,
            latency: 0.0,
            backend: "native",
            stamps: StageStamps::off(),
            stages: None,
        });
        let frame = encode_reply(&reply);
        let (op_, payload) = strip(&frame);
        assert_eq!(op_, op::R_ERR);
        match decode_reply(op_, payload).unwrap() {
            Reply::Err(f) => {
                assert_eq!(f.id, 99);
                assert_eq!(f.kind, FailureKind::Exec);
                assert!(f.error.contains("wire limit"));
            }
            _ => panic!("expected failure arm"),
        }
    }

    #[test]
    fn hostile_vector_count_fails_before_allocating() {
        // a request payload whose q count claims u32::MAX elements
        let mut w = Wr::new(op::SOLVE);
        w.u64(1);
        w.f64(0.1);
        w.u8(0); // no session key
        w.str16("l");
        w.u32(u32::MAX); // q count — no data follows
        let frame = w.finish();
        let (op_, payload) = strip(&frame);
        let err = decode_request(op_, payload).unwrap_err();
        assert!(matches!(err, AltDiffError::Protocol(_)));
    }

    #[test]
    fn stage_echo_reply_round_trips() {
        let spans: StageSpans = [3, 1, 250, 40, 900, 7];
        let mut resp = Response {
            id: 21,
            x: vec![1.0, 2.0],
            jx: vec![0.5],
            prim_residual: 1e-6,
            k_used: 16,
            batch_size: 4,
            latency: 0.002,
            backend: "native",
            stamps: StageStamps::off(),
            stages: Some(spans),
        };
        let frame = encode_reply(&Reply::Ok(resp.clone()));
        let (op_, payload) = strip(&frame);
        match decode_reply(op_, payload).unwrap() {
            Reply::Ok(r) => {
                assert_eq!(r.stages, Some(spans));
                assert_eq!(r.x, resp.x);
            }
            _ => panic!("wrong arm"),
        }
        // without the echo the frame is byte-identical to a pre-echo
        // encoder's, and decodes with stages = None
        resp.stages = None;
        let bare = encode_reply(&Reply::Ok(resp));
        assert_eq!(bare.len(), frame.len() - 1 - 4 * N_SPANS);
        let (op_, payload) = strip(&bare);
        match decode_reply(op_, payload).unwrap() {
            Reply::Ok(r) => assert_eq!(r.stages, None),
            _ => panic!("wrong arm"),
        }
    }

    #[test]
    fn stage_echo_grad_reply_round_trips() {
        let spans: StageSpans = [0, 0, 12, 0, 500, 1];
        let g = GradientResponse {
            id: 8,
            x: vec![1.0],
            grad_q: vec![0.1],
            grad_b: vec![],
            grad_h: vec![0.2],
            prim_residual: 0.0,
            k_used: 12,
            batch_size: 1,
            latency: 0.001,
            backend: "native-sparse",
            stamps: StageStamps::off(),
            stages: Some(spans),
        };
        let frame = encode_reply(&Reply::Grad(g));
        let (op_, payload) = strip(&frame);
        match decode_reply(op_, payload).unwrap() {
            Reply::Grad(g) => assert_eq!(g.stages, Some(spans)),
            _ => panic!("wrong arm"),
        }
        // a malformed stage-echo tag is a Protocol error, not a panic
        let mut bad = payload.to_vec();
        let tail = bad.len() - 1 - 4 * N_SPANS;
        bad[tail] = 7;
        assert!(decode_reply(op_, &bad).is_err());
    }
}
