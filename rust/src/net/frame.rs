//! Frame assembly/flushing for nonblocking sockets.
//!
//! A frame is an 8-byte header followed by a payload:
//!
//! ```text
//!   byte 0      magic  0xAD
//!   byte 1      protocol version (currently 1)
//!   byte 2      opcode (see `proto::op`)
//!   byte 3      reserved, must be 0
//!   bytes 4..8  payload length, u32 little-endian (≤ MAX_PAYLOAD)
//! ```
//!
//! [`FrameReader`] accumulates whatever bytes the socket had ready and
//! yields complete frames; [`WriteBuf`] holds encoded frames that the
//! kernel was not ready to accept and flushes them as the socket drains
//! (per-connection write backpressure). Both validate eagerly: a bad
//! magic/version/length is an error *before* any payload allocation, so
//! a hostile peer cannot make the server reserve `u32::MAX` bytes.

use crate::error::{AltDiffError, Result};
use std::io::{Read, Write};

/// First header byte of every frame.
pub const MAGIC: u8 = 0xAD;
/// Wire protocol version this build speaks.
pub const VERSION: u8 = 1;
/// Header length in bytes.
pub const HEADER_LEN: usize = 8;
/// Hard cap on payload length — decoders reject anything larger before
/// allocating. Generous for the QP sizes served here (a 16 MiB frame
/// holds a dense n=1024, p=1024 Jacobian reply — 8 MiB of `jx` — with
/// room to spare; larger layers should use the adjoint path, whose
/// replies are O(n+m+p)).
pub const MAX_PAYLOAD: u32 = 16 * 1024 * 1024;

/// Render the 8-byte header for `(opcode, payload_len)`.
pub fn header(op: u8, payload_len: usize) -> [u8; HEADER_LEN] {
    debug_assert!(payload_len as u64 <= MAX_PAYLOAD as u64);
    let len = payload_len as u32;
    let lb = len.to_le_bytes();
    [MAGIC, VERSION, op, 0, lb[0], lb[1], lb[2], lb[3]]
}

/// Parse and validate a header; returns `(opcode, payload_len)`.
pub fn parse_header(h: &[u8]) -> Result<(u8, usize)> {
    if h.len() < HEADER_LEN {
        return Err(AltDiffError::Protocol(format!(
            "short header: {} bytes",
            h.len()
        )));
    }
    if h[0] != MAGIC {
        return Err(AltDiffError::Protocol(format!(
            "bad magic byte 0x{:02x}",
            h[0]
        )));
    }
    if h[1] != VERSION {
        return Err(AltDiffError::Protocol(format!(
            "unsupported protocol version {} (this build speaks {})",
            h[1], VERSION
        )));
    }
    if h[3] != 0 {
        return Err(AltDiffError::Protocol(format!(
            "nonzero reserved header byte 0x{:02x}",
            h[3]
        )));
    }
    let len = u32::from_le_bytes([h[4], h[5], h[6], h[7]]);
    if len > MAX_PAYLOAD {
        return Err(AltDiffError::Protocol(format!(
            "frame payload {len} bytes exceeds limit {MAX_PAYLOAD}"
        )));
    }
    Ok((h[2], len as usize))
}

/// One complete inbound frame.
#[derive(Debug)]
pub struct Frame {
    /// Opcode from the header.
    pub op: u8,
    /// Payload bytes (header stripped).
    pub payload: Vec<u8>,
}

/// Incremental frame reader for a nonblocking stream: feed it whatever
/// bytes arrived, pull out complete frames. Partial frames stay
/// buffered until their remainder shows up; header validation happens
/// as soon as 8 bytes exist, so garbage is rejected without waiting for
/// (or allocating) a bogus payload.
#[derive(Default)]
pub struct FrameReader {
    buf: Vec<u8>,
    read_pos: usize,
}

impl FrameReader {
    /// Empty reader.
    pub fn new() -> Self {
        FrameReader::default()
    }

    /// Append bytes received from the socket.
    pub fn extend(&mut self, bytes: &[u8]) {
        // compact lazily: only when the consumed prefix dominates
        if self.read_pos > 4096 && self.read_pos * 2 > self.buf.len() {
            self.buf.drain(..self.read_pos);
            self.read_pos = 0;
        }
        self.buf.extend_from_slice(bytes);
    }

    /// Bytes currently buffered but not yet consumed as frames.
    pub fn buffered(&self) -> usize {
        self.buf.len() - self.read_pos
    }

    /// Try to extract the next complete frame. `Ok(None)` means "need
    /// more bytes"; `Err` means the stream is unrecoverably malformed
    /// (close the connection — framing cannot be resynchronized).
    pub fn next_frame(&mut self) -> Result<Option<Frame>> {
        let avail = &self.buf[self.read_pos..];
        if avail.len() < HEADER_LEN {
            return Ok(None);
        }
        let (op, len) = parse_header(&avail[..HEADER_LEN])?;
        if avail.len() < HEADER_LEN + len {
            return Ok(None);
        }
        let payload =
            avail[HEADER_LEN..HEADER_LEN + len].to_vec();
        self.read_pos += HEADER_LEN + len;
        Ok(Some(Frame { op, payload }))
    }
}

/// Outbound byte queue with partial-write support. `flush` writes as
/// much as the kernel accepts and keeps the rest; `len` is the
/// backpressure signal — the server stops *reading* from a connection
/// whose write buffer is over budget, so a slow consumer throttles
/// itself instead of ballooning server memory.
#[derive(Default)]
pub struct WriteBuf {
    buf: Vec<u8>,
    write_pos: usize,
}

impl WriteBuf {
    /// Empty buffer.
    pub fn new() -> Self {
        WriteBuf::default()
    }

    /// Queue one already-encoded frame (header + payload).
    pub fn push(&mut self, frame_bytes: &[u8]) {
        if self.write_pos == self.buf.len() {
            self.buf.clear();
            self.write_pos = 0;
        } else if self.write_pos > 4096
            && self.write_pos * 2 > self.buf.len()
        {
            // compact the consumed prefix: a connection that is never
            // momentarily idle must not accumulate every byte it ever
            // sent (same lazy policy as `FrameReader::extend`)
            self.buf.drain(..self.write_pos);
            self.write_pos = 0;
        }
        self.buf.extend_from_slice(frame_bytes);
    }

    /// Bytes still waiting to be written.
    pub fn len(&self) -> usize {
        self.buf.len() - self.write_pos
    }

    /// True when everything queued has reached the kernel.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Write as much as the stream accepts without blocking. Returns
    /// `Ok(true)` when the buffer fully drained, `Ok(false)` when bytes
    /// remain (kernel said `WouldBlock`), `Err` on a dead connection.
    pub fn flush<W: Write>(&mut self, w: &mut W) -> std::io::Result<bool> {
        while self.write_pos < self.buf.len() {
            match w.write(&self.buf[self.write_pos..]) {
                Ok(0) => {
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::WriteZero,
                        "connection closed mid-frame",
                    ))
                }
                Ok(n) => self.write_pos += n,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    return Ok(false)
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => return Err(e),
            }
        }
        self.buf.clear();
        self.write_pos = 0;
        Ok(true)
    }
}

/// Blocking helpers for client sockets (the server side never blocks).
pub mod blocking {
    use super::*;

    /// Read exactly one frame from a blocking stream.
    ///
    /// Note: if the stream has a read timeout and it fires mid-frame,
    /// the partially-read bytes are lost and the stream desyncs — use
    /// [`read_frame_buffered`] (as the clients do) when timeouts are
    /// in play.
    pub fn read_frame<R: Read>(r: &mut R) -> Result<Frame> {
        let mut hdr = [0u8; HEADER_LEN];
        r.read_exact(&mut hdr)?;
        let (op, len) = parse_header(&hdr)?;
        let mut payload = vec![0u8; len];
        r.read_exact(&mut payload)?;
        Ok(Frame { op, payload })
    }

    /// Read one frame via a caller-held [`FrameReader`], so a read
    /// timeout that fires mid-frame keeps the partial bytes buffered —
    /// the next call resumes where the stream left off instead of
    /// desyncing.
    pub fn read_frame_buffered<R: Read>(
        r: &mut R,
        fr: &mut FrameReader,
    ) -> Result<Frame> {
        let mut buf = [0u8; 16 * 1024];
        loop {
            if let Some(f) = fr.next_frame()? {
                return Ok(f);
            }
            match r.read(&mut buf) {
                Ok(0) => {
                    return Err(AltDiffError::Io(std::io::Error::new(
                        std::io::ErrorKind::UnexpectedEof,
                        "connection closed mid-frame",
                    )))
                }
                Ok(n) => fr.extend(&buf[..n]),
                Err(e)
                    if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => return Err(AltDiffError::Io(e)),
            }
        }
    }

    /// Write one frame (header + payload) to a blocking stream.
    pub fn write_frame<W: Write>(w: &mut W, bytes: &[u8]) -> Result<()> {
        w.write_all(bytes)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn header_round_trips() {
        let h = header(3, 1234);
        let (op, len) = parse_header(&h).unwrap();
        assert_eq!((op, len), (3, 1234));
    }

    #[test]
    fn bad_headers_are_rejected() {
        assert!(parse_header(&[0u8; 4]).is_err()); // short
        let mut h = header(1, 10);
        h[0] = 0x00;
        assert!(parse_header(&h).is_err()); // magic
        let mut h = header(1, 10);
        h[1] = 99;
        assert!(parse_header(&h).is_err()); // version
        let mut h = header(1, 10);
        h[3] = 1;
        assert!(parse_header(&h).is_err()); // reserved
        let mut h = header(1, 0);
        h[4..8].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(parse_header(&h).is_err()); // oversized
    }

    #[test]
    fn reader_reassembles_split_frames() {
        let mut bytes = header(7, 5).to_vec();
        bytes.extend_from_slice(b"hello");
        let mut r = FrameReader::new();
        for chunk in bytes.chunks(3) {
            r.extend(chunk);
        }
        let f = r.next_frame().unwrap().expect("complete frame");
        assert_eq!(f.op, 7);
        assert_eq!(f.payload, b"hello");
        assert!(r.next_frame().unwrap().is_none());
        assert_eq!(r.buffered(), 0);
    }

    #[test]
    fn reader_yields_back_to_back_frames() {
        let mut bytes = header(1, 2).to_vec();
        bytes.extend_from_slice(b"ab");
        bytes.extend_from_slice(&header(2, 0));
        let mut r = FrameReader::new();
        r.extend(&bytes);
        assert_eq!(r.next_frame().unwrap().unwrap().op, 1);
        assert_eq!(r.next_frame().unwrap().unwrap().op, 2);
        assert!(r.next_frame().unwrap().is_none());
    }

    #[test]
    fn reader_errors_on_garbage_without_panicking() {
        let mut r = FrameReader::new();
        r.extend(&[0xFFu8; 64]);
        assert!(r.next_frame().is_err());
    }

    #[test]
    fn buffered_read_survives_midframe_timeouts() {
        // a reader that delivers 5 bytes, then "times out", then the rest
        struct Chunky {
            data: Vec<u8>,
            pos: usize,
            timeouts_left: usize,
        }
        impl std::io::Read for Chunky {
            fn read(&mut self, b: &mut [u8]) -> std::io::Result<usize> {
                if self.pos == 5 && self.timeouts_left > 0 {
                    self.timeouts_left -= 1;
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::WouldBlock,
                        "timed out",
                    ));
                }
                let n = (self.data.len() - self.pos).min(b.len()).min(5);
                b[..n].copy_from_slice(&self.data[self.pos..self.pos + n]);
                self.pos += n;
                Ok(n)
            }
        }
        let mut bytes = header(7, 4).to_vec();
        bytes.extend_from_slice(b"data");
        let mut r = Chunky { data: bytes, pos: 0, timeouts_left: 1 };
        let mut fr = FrameReader::new();
        // first attempt: mid-frame timeout surfaces as Err, partial
        // bytes stay buffered in `fr`
        assert!(blocking::read_frame_buffered(&mut r, &mut fr).is_err());
        // second attempt resumes and completes the same frame
        let f = blocking::read_frame_buffered(&mut r, &mut fr).unwrap();
        assert_eq!(f.op, 7);
        assert_eq!(f.payload, b"data");
    }

    #[test]
    fn write_buf_tracks_partial_writes() {
        struct Trickle(Vec<u8>, usize);
        impl std::io::Write for Trickle {
            fn write(&mut self, b: &[u8]) -> std::io::Result<usize> {
                if self.1 == 0 {
                    self.1 += 1;
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::WouldBlock,
                        "later",
                    ));
                }
                let n = b.len().min(2);
                self.0.extend_from_slice(&b[..n]);
                Ok(n)
            }
            fn flush(&mut self) -> std::io::Result<()> {
                Ok(())
            }
        }
        let mut wb = WriteBuf::new();
        wb.push(b"abcdef");
        let mut t = Trickle(Vec::new(), 0);
        assert!(!wb.flush(&mut t).unwrap()); // WouldBlock
        assert_eq!(wb.len(), 6);
        assert!(wb.flush(&mut t).unwrap()); // drains in 2-byte writes
        assert!(wb.is_empty());
        assert_eq!(t.0, b"abcdef");
    }
}
