//! L4: zero-dependency network serving front end.
//!
//! Everything below this module is in-process; `net` is the layer that
//! turns the repo from a library into a service. It exposes the full
//! coordinator API — solve, gradient/VJP, metrics — over TCP with
//! nothing but `std::net` and nonblocking sockets (the crate's
//! no-external-deps contract; no tokio, no serde):
//!
//! - [`frame`]: length-prefixed frames with a versioned 8-byte header,
//!   incremental reassembly for nonblocking reads, and a partial-write
//!   buffer for backpressured writes;
//! - [`proto`]: the binary codec between frames and the coordinator's
//!   [`Request`](crate::coordinator::Request)/
//!   [`Reply`](crate::coordinator::Reply) types, plus admin ops (stats,
//!   layer discovery, graceful stop) — hostile input comes back as
//!   [`AltDiffError::Protocol`](crate::error::AltDiffError), never a
//!   panic;
//! - [`server`]: the poll-based event loop multiplexing N connections
//!   onto one [`Coordinator`](crate::coordinator::Coordinator), with an
//!   in-flight admission budget (overload → explicit
//!   `Failure::Overloaded` replies, never stalls or drops), per-
//!   connection write backpressure, and a graceful drain that says
//!   goodbye;
//! - [`client`]: blocking and pipelined clients plus the
//!   multi-connection load generator ([`client::run_loadgen`]), both
//!   with bounded [`client::RetryPolicy`] backoff and a default
//!   end-to-end op deadline;
//! - [`chaos`]: a deterministic fault-injection TCP proxy
//!   ([`chaos::ChaosProxy`]) that tears frames, stalls mid-frame,
//!   throttles readers, and resets connections mid-solve — the harness
//!   behind `tests/chaos_net.rs` and `loadgen --chaos`.
//!
//! See `DESIGN.md` §4b for the frame layout and the admission-control /
//! backpressure semantics, §4c for priorities/deadlines and the chaos
//! harness.

pub mod chaos;
pub mod client;
pub mod frame;
pub mod proto;
pub mod server;

pub use chaos::{ChaosConfig, ChaosProxy, ChaosStats};
pub use client::{
    run_loadgen, Client, LoadgenOpts, LoadgenReport, PipelinedClient,
    RetryPolicy, TimedReply, DEFAULT_OP_TIMEOUT,
};
pub use proto::LayerInfo;
pub use server::{NetConfig, NetServer};
