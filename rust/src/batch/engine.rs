//! The batched dense-QP Alt-Diff engine.
//!
//! Registration shares the [`DenseAltDiff`] Cholesky/H⁻¹ caches (no
//! second n³); every iteration is then batch-major GEMM work:
//!
//!   forward (5a): RHS = C_q − Λ A − N G + ρ(H_θ − S) G;  X = RHS H⁻¹
//!   backward (7a): J_x = −H⁻¹ (Aᵀ J_λ + Gᵀ J_ν + ρGᵀ J_s + ∂θ-const)
//!
//! with per-element truncation handled by the row/column masks (see the
//! module docs in [`super`]). FP note: the masked kernels preserve the
//! serial accumulation order per output entry, and the (5a) solve uses
//! the cached explicit H⁻¹ (like the dense backward), so per-element
//! results agree with `DenseAltDiff` to solver tolerance.

use super::mask::ActiveSet;
use super::{BatchSolution, BatchVjp, BatchVjpSolution};
use crate::altdiff::{BackwardMode, DenseAltDiff, Options, Param};
use crate::error::Result;
use crate::linalg::{
    axpy_cols, gemm_acc, gemm_acc_cols, gemm_acc_rows, gemv, norm2,
    par_gemm_acc, Mat,
};
use crate::obs::IterObserver;
use crate::prob::Qp;
use crate::warm::{AdjointSeed, WarmStart};

/// A registered QP structure ready to solve B right-hand sides per
/// launch.
///
/// ```
/// use altdiff::altdiff::Options;
/// use altdiff::batch::BatchedAltDiff;
/// use altdiff::prob::dense_qp;
///
/// // register once (factors H), then launch batches of per-request θ
/// let engine = BatchedAltDiff::new(dense_qp(6, 3, 1, 7), 1.0).unwrap();
/// let q2: Vec<f64> = engine.qp.q.iter().map(|v| 0.5 * v).collect();
/// let qs: Vec<&[f64]> = vec![&engine.qp.q, &q2];
/// let sol = engine.solve_batch(Some(&qs), None, None, &Options::default());
/// assert_eq!(sol.len(), 2);
/// assert!(sol.xs.iter().flatten().all(|v| v.is_finite()));
/// // per-element Jacobians ∂x/∂b ride the same launch
/// assert_eq!(sol.jacobians.as_ref().unwrap()[0].cols, 1);
/// ```
pub struct BatchedAltDiff {
    /// The registered problem.
    pub qp: Qp,
    /// ADMM penalty ρ (registration-time).
    pub rho: f64,
    /// explicit H⁻¹ shared by forward (5a) and backward (7a)
    hinv: Mat,
    at: Mat, // Aᵀ (n,p)
    gt: Mat, // Gᵀ (n,m)
}

impl BatchedAltDiff {
    /// Register from scratch (factors H once, like `DenseAltDiff::new`).
    pub fn new(qp: Qp, rho: f64) -> Result<Self> {
        let dense = DenseAltDiff::new(qp, rho)?;
        Ok(Self::from_dense(&dense))
    }

    /// Share an already-registered layer's factorization caches — the
    /// cheap path for the server, which keeps both engines per layer.
    pub fn from_dense(solver: &DenseAltDiff) -> Self {
        BatchedAltDiff {
            qp: solver.qp.clone(),
            rho: solver.rho,
            hinv: solver.hinv_cache.clone(),
            at: solver.at.clone(),
            gt: solver.gt.clone(),
        }
    }

    /// Solve + differentiate B instances in one launch. Each of
    /// `qs`/`bs`/`hs` is either one slice per element or `None` to
    /// broadcast the registered parameter; the batch size is inferred
    /// from whichever is provided (1 if none are).
    pub fn solve_batch(
        &self,
        qs: Option<&[&[f64]]>,
        bs: Option<&[&[f64]]>,
        hs: Option<&[&[f64]]>,
        opts: &Options,
    ) -> BatchSolution {
        self.solve_batch_from(qs, bs, hs, None, opts)
    }

    /// [`Self::solve_batch`] with per-element warm starts: element e
    /// resumes the alternation from `warms[e]` when present and starts
    /// cold otherwise — a batch may freely mix warm and cold members,
    /// and per-element truncation (the existing [`ActiveSet`] masks)
    /// lets the warm ones converge, freeze, and stop consuming flops
    /// while cold ones keep iterating. Warm slacks are re-derived via
    /// the (6) projection like
    /// [`DenseAltDiff::solve_from`](crate::altdiff::DenseAltDiff::solve_from);
    /// `warms = None` (or all-`None` elements) is bit-identical to the
    /// cold [`Self::solve_batch`]. Warm elements with forward-mode
    /// Jacobians require `tol = 0` (asserted — see DESIGN.md §5).
    pub fn solve_batch_from(
        &self,
        qs: Option<&[&[f64]]>,
        bs: Option<&[&[f64]]>,
        hs: Option<&[&[f64]]>,
        warms: Option<&[Option<WarmStart>]>,
        opts: &Options,
    ) -> BatchSolution {
        self.solve_batch_observed(qs, bs, hs, warms, opts, None)
    }

    /// [`Self::solve_batch_from`] with a per-iteration
    /// [`IterObserver`] hook — the serving tracing plane's entry point.
    /// KKT residuals are computed only for elements the observer
    /// claims via [`IterObserver::wants`]; `observer = None` costs one
    /// branch per live element per iteration and allocates nothing,
    /// and the returned solution is bit-identical to
    /// [`Self::solve_batch_from`] either way (the observer never feeds
    /// back into the iteration).
    pub fn solve_batch_observed(
        &self,
        qs: Option<&[&[f64]]>,
        bs: Option<&[&[f64]]>,
        hs: Option<&[&[f64]]>,
        warms: Option<&[Option<WarmStart>]>,
        opts: &Options,
        mut observer: Option<&mut dyn IterObserver>,
    ) -> BatchSolution {
        let n = self.qp.n();
        let m = self.qp.m_ineq();
        let p = self.qp.p_eq();
        let rho = self.rho; // registration-time, like DenseAltDiff
        let bsz = qs
            .map(|v| v.len())
            .or_else(|| bs.map(|v| v.len()))
            .or_else(|| hs.map(|v| v.len()))
            .or_else(|| warms.map(|v| v.len()))
            .unwrap_or(1);
        assert!(bsz > 0, "empty batch");

        // batch-major parameter matrices (broadcast registered θ)
        let qm = gather(qs, &self.qp.q, bsz, n);
        let bm = gather(bs, &self.qp.b, bsz, p);
        let hm = gather(hs, &self.qp.h, bsz, m);

        // θ-constant part of the (5a) rhs: −q + ρAᵀb, per element
        let mut cq = qm;
        cq.scale(-1.0);
        par_gemm_acc(&mut cq, rho, &bm, &self.qp.a);

        // iterates, batch-major
        let mut x = Mat::zeros(bsz, n);
        let mut s = Mat::zeros(bsz, m);
        let mut lam = Mat::zeros(bsz, p);
        let mut nu = Mat::zeros(bsz, m);
        let mut xprev = Mat::zeros(bsz, n);
        let mut rhs = Mat::zeros(bsz, n);
        let mut hms = Mat::zeros(bsz, m);
        let mut gx = Mat::zeros(bsz, m);
        let mut ax = Mat::zeros(bsz, p);

        if let Some(ws_) = warms {
            assert_eq!(ws_.len(), bsz, "warm-start arity");
            if ws_.iter().any(|w| w.is_some()) {
                assert!(
                    opts.backward.forward_param().is_none()
                        || opts.tol == 0.0,
                    "warm starts with forward-mode Jacobians require \
                     tol = 0 (fixed-k); use BackwardMode::None/Adjoint \
                     for truncated warm solves"
                );
            }
            for (e, w) in ws_.iter().enumerate() {
                let Some(w) = w else { continue };
                assert_eq!(w.dims(), (n, p, m), "warm-start dimensions");
                x.row_mut(e).copy_from_slice(&w.x);
                lam.row_mut(e).copy_from_slice(&w.lam);
                nu.row_mut(e).copy_from_slice(&w.nu);
                // warm slack via the (6) projection at the warm point
                let gx0 = gemv(&self.qp.g, &w.x);
                let hr = hm.row(e);
                let nur = nu.row(e);
                let sr = s.row_mut(e);
                for i in 0..m {
                    sr[i] = (-nur[i] / rho - (gx0[i] - hr[i])).max(0.0);
                }
            }
        }

        // Jacobian state: per-element (n×d) blocks stacked along columns
        let param = opts.backward.forward_param();
        let d = param.map(|pm| pm.dim(n, m, p));
        let mut jac = d.map(|d| JacState::new(n, m, p, bsz, d));

        let mut act = ActiveSet::new(bsz);
        let mut iters = vec![0usize; bsz];
        let mut step_rel = vec![f64::INFINITY; bsz];
        let mut live: Vec<usize> = Vec::with_capacity(bsz);

        for k in 0..opts.max_iter {
            if act.all_done() {
                break;
            }
            live.clear();
            live.extend(act.iter());
            for &e in &live {
                iters[e] = k + 1;
                xprev.row_mut(e).copy_from_slice(x.row(e));
            }

            // ---- forward (5a): H x = −q − Aᵀλ − Gᵀν + ρAᵀb + ρGᵀ(h−s)
            for &e in &live {
                rhs.row_mut(e).copy_from_slice(cq.row(e));
                let hr = hm.row(e);
                let sr = s.row(e);
                let out = hms.row_mut(e);
                for i in 0..m {
                    out[i] = hr[i] - sr[i];
                }
            }
            gemm_acc_rows(&mut rhs, -1.0, &lam, &self.qp.a, act.flags());
            gemm_acc_rows(&mut rhs, -1.0, &nu, &self.qp.g, act.flags());
            gemm_acc_rows(&mut rhs, rho, &hms, &self.qp.g, act.flags());
            for &e in &live {
                x.row_mut(e).fill(0.0);
            }
            gemm_acc_rows(&mut x, 1.0, &rhs, &self.hinv, act.flags());

            // ---- (6): slack, (5c)/(5d): duals
            for &e in &live {
                gx.row_mut(e).fill(0.0);
                ax.row_mut(e).fill(0.0);
            }
            gemm_acc_rows(&mut gx, 1.0, &x, &self.gt, act.flags());
            gemm_acc_rows(&mut ax, 1.0, &x, &self.at, act.flags());
            for &e in &live {
                let gxr = gx.row(e);
                let hr = hm.row(e);
                let sr = s.row_mut(e);
                let nur = nu.row(e);
                for i in 0..m {
                    sr[i] =
                        (-nur[i] / rho - (gxr[i] - hr[i])).max(0.0);
                }
            }
            for &e in &live {
                let axr = ax.row(e);
                let br = bm.row(e);
                let lr = lam.row_mut(e);
                for i in 0..p {
                    lr[i] += rho * (axr[i] - br[i]);
                }
                let gxr = gx.row(e);
                let hr = hm.row(e);
                let sr = s.row(e);
                let nur = nu.row_mut(e);
                for i in 0..m {
                    nur[i] += rho * (gxr[i] + sr[i] - hr[i]);
                }
            }

            // ---- backward (7a)-(7d), only active column blocks
            if let Some(jac) = jac.as_mut() {
                jac.step(self, param.unwrap(), &s, &act, &live, rho);
            }

            // ---- per-element truncation (Algorithm 1 condition)
            for &e in &live {
                let xr = x.row(e);
                let xp = xprev.row(e);
                let dx: f64 = xr
                    .iter()
                    .zip(xp)
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum::<f64>()
                    .sqrt();
                // sampled-trace hook: gx/ax/s hold the k+1 iterate here,
                // so the KKT residual is free of extra matvecs
                if let Some(obs) = observer.as_deref_mut() {
                    if obs.wants(e) {
                        let mut pr = 0.0;
                        let axr = ax.row(e);
                        let br = bm.row(e);
                        for i in 0..p {
                            let v = axr[i] - br[i];
                            pr += v * v;
                        }
                        let gxr = gx.row(e);
                        let sr = s.row(e);
                        let hr = hm.row(e);
                        for i in 0..m {
                            let v = gxr[i] + sr[i] - hr[i];
                            pr += v * v;
                        }
                        obs.on_iter(e, k, pr.sqrt(), rho * dx);
                    }
                }
                let step = dx / norm2(xp).max(1.0);
                step_rel[e] = step;
                if step < opts.tol {
                    act.deactivate(e);
                }
            }
        }

        // unpack batch-major state into per-element vectors
        let rows = |mat: &Mat| -> Vec<Vec<f64>> {
            (0..bsz).map(|e| mat.row(e).to_vec()).collect()
        };
        let jacobians = jac.map(|j| j.unstack(n, bsz));
        BatchSolution {
            xs: rows(&x),
            ss: rows(&s),
            lams: rows(&lam),
            nus: rows(&nu),
            jacobians,
            iters,
            step_rel,
        }
    }

    /// Batched reverse-mode backward: B adjoint vectors advance as one
    /// (B, ·) panel per state, so every iteration of the transposed
    /// recursion is one GEMM launch against the shared H⁻¹/A/G — cost
    /// per iteration O(B·(n² + nm + np)), independent of d. `slacks` are
    /// the per-element final slacks of the forward launch (the (7b) gate
    /// pattern), `vs` the per-element incoming gradients dL/dx*ₑ.
    /// Per-element truncation mirrors the forward engine: a converged
    /// element's rows freeze and stop consuming flops (`opts.tol`;
    /// `tol = 0` runs exactly `opts.max_iter` iterations).
    pub fn batch_vjp(
        &self,
        slacks: &[&[f64]],
        vs: &[&[f64]],
        opts: &Options,
    ) -> BatchVjp {
        self.batch_vjp_from(slacks, vs, None, opts).0
    }

    /// [`Self::batch_vjp`] with per-element warm adjoint seeds, also
    /// returning every element's final adjoint state for the next
    /// backward to resume from — the batched sibling of
    /// [`DenseAltDiff::vjp_from`](crate::altdiff::DenseAltDiff::vjp_from).
    /// A batch may mix seeded and cold elements; `warms = None` is
    /// bit-identical to the cold [`Self::batch_vjp`].
    pub fn batch_vjp_from(
        &self,
        slacks: &[&[f64]],
        vs: &[&[f64]],
        warms: Option<&[Option<AdjointSeed>]>,
        opts: &Options,
    ) -> (BatchVjp, Vec<AdjointSeed>) {
        let n = self.qp.n();
        let m = self.qp.m_ineq();
        let p = self.qp.p_eq();
        let rho = self.rho;
        let bsz = vs.len();
        assert!(bsz > 0, "empty batch");
        assert_eq!(slacks.len(), bsz, "slack arity");

        // gates σ (B, m) from the forward launch's final slacks
        let mut gates = Mat::zeros(bsz, m);
        for (e, s) in slacks.iter().enumerate() {
            assert_eq!(s.len(), m, "slack dimension");
            let gr = gates.row_mut(e);
            for i in 0..m {
                gr[i] = if s[i] > 0.0 { 1.0 } else { 0.0 };
            }
        }

        // T = −V H⁻¹ (row-major stacked t's) and the seeds
        // (Vₛ, V_λ, V_ν) = (ρ·T Gᵀ, T Aᵀ, T Gᵀ)
        let vmat = gather(Some(vs), &[], bsz, n);
        let mut t = Mat::zeros(bsz, n);
        par_gemm_acc(&mut t, -1.0, &vmat, &self.hinv);
        let mut vn = Mat::zeros(bsz, m);
        par_gemm_acc(&mut vn, 1.0, &t, &self.gt);
        let mut vl = Mat::zeros(bsz, p);
        par_gemm_acc(&mut vl, 1.0, &t, &self.at);

        // W₁ = V (per element, unless a seed resumes the series)
        let mut ws = vn.clone();
        ws.scale(rho);
        let mut wl = vl.clone();
        let mut wn = vn.clone();

        let mut z = Mat::zeros(bsz, n);
        let mut seeded = vec![false; bsz];
        if let Some(seeds) = warms {
            assert_eq!(seeds.len(), bsz, "adjoint-seed arity");
            for (e, seed) in seeds.iter().enumerate() {
                let Some(seed) = seed else { continue };
                assert_eq!(
                    seed.dims(),
                    (n, p, m),
                    "adjoint-seed dimensions"
                );
                ws.row_mut(e).copy_from_slice(&seed.ws);
                wl.row_mut(e).copy_from_slice(&seed.wl);
                wn.row_mut(e).copy_from_slice(&seed.wn);
                z.row_mut(e).copy_from_slice(&seed.z);
                seeded[e] = true;
            }
        }
        let mut zprev = Mat::zeros(bsz, n);
        let mut rhs = Mat::zeros(bsz, n);
        let mut dws = Mat::zeros(bsz, m);
        let mut ewn = Mat::zeros(bsz, m);
        let mut gz = Mat::zeros(bsz, m);
        let mut az = Mat::zeros(bsz, p);

        let mut act = ActiveSet::new(bsz);
        let mut iters = vec![1usize; bsz];
        let mut step_rel = vec![f64::INFINITY; bsz];
        let mut live: Vec<usize> = Vec::with_capacity(bsz);

        for k in 1..opts.max_iter {
            if act.all_done() {
                break;
            }
            live.clear();
            live.extend(act.iter());
            // z = H⁻¹(Gᵀ(σ⊙wₛ) − ρAᵀw_λ − ρGᵀ((1−σ)⊙w_ν)), one GEMM
            // per term over the live rows only
            for &e in &live {
                zprev.row_mut(e).copy_from_slice(z.row(e));
                let gr = gates.row(e);
                let wsr = ws.row(e);
                let wnr = wn.row(e);
                let dr = dws.row_mut(e);
                for i in 0..m {
                    dr[i] = gr[i] * wsr[i];
                }
                let er = ewn.row_mut(e);
                for i in 0..m {
                    er[i] = (1.0 - gr[i]) * wnr[i];
                }
                rhs.row_mut(e).fill(0.0);
            }
            gemm_acc_rows(&mut rhs, 1.0, &dws, &self.qp.g, act.flags());
            gemm_acc_rows(&mut rhs, -rho, &wl, &self.qp.a, act.flags());
            gemm_acc_rows(&mut rhs, -rho, &ewn, &self.qp.g, act.flags());
            for &e in &live {
                z.row_mut(e).fill(0.0);
            }
            gemm_acc_rows(&mut z, 1.0, &rhs, &self.hinv, act.flags());

            // W ← MᵀW + V
            for &e in &live {
                gz.row_mut(e).fill(0.0);
                az.row_mut(e).fill(0.0);
            }
            gemm_acc_rows(&mut gz, 1.0, &z, &self.gt, act.flags());
            gemm_acc_rows(&mut az, 1.0, &z, &self.at, act.flags());
            for &e in &live {
                iters[e] = k + 1;
                let gr = gates.row(e);
                let gzr = gz.row(e);
                let vnr = vn.row(e);
                // order matters: w_ν reads the OLD wₛ
                {
                    let wsr = ws.row(e);
                    let wnr = wn.row_mut(e);
                    for i in 0..m {
                        wnr[i] = (1.0 - gr[i]) * wnr[i] + gzr[i]
                            - gr[i] * wsr[i] / rho
                            + vnr[i];
                    }
                }
                let wsr = ws.row_mut(e);
                for i in 0..m {
                    wsr[i] = rho * gzr[i] + rho * vnr[i];
                }
                let azr = az.row(e);
                let vlr = vl.row(e);
                let wlr = wl.row_mut(e);
                for i in 0..p {
                    wlr[i] += azr[i] + vlr[i];
                }
                // per-element truncation on the adjoint iterate z. A
                // seeded element's first iteration reproduces its
                // harvested z exactly (zero step under unchanged
                // gates), so it must take one genuine step before the
                // criterion is trusted.
                let zr = z.row(e);
                let zp = zprev.row(e);
                let dz: f64 = zr
                    .iter()
                    .zip(zp)
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum::<f64>()
                    .sqrt();
                let step = dz / norm2(zp).max(1.0);
                step_rel[e] = step;
                if step < opts.tol && (k > 1 || !seeded[e]) {
                    act.deactivate(e);
                }
            }
        }

        // final z at every element's converged adjoint state
        let all = vec![true; bsz];
        for e in 0..bsz {
            let gr = gates.row(e);
            let wsr = ws.row(e);
            let wnr = wn.row(e);
            let dr = dws.row_mut(e);
            for i in 0..m {
                dr[i] = gr[i] * wsr[i];
            }
            let er = ewn.row_mut(e);
            for i in 0..m {
                er[i] = (1.0 - gr[i]) * wnr[i];
            }
        }
        rhs.data.fill(0.0);
        gemm_acc_rows(&mut rhs, 1.0, &dws, &self.qp.g, &all);
        gemm_acc_rows(&mut rhs, -rho, &wl, &self.qp.a, &all);
        gemm_acc_rows(&mut rhs, -rho, &ewn, &self.qp.g, &all);
        z.data.fill(0.0);
        par_gemm_acc(&mut z, 1.0, &rhs, &self.hinv);

        // reusable adjoint states, harvested before the projection
        // consumes z and the w's
        let seeds_out: Vec<AdjointSeed> = (0..bsz)
            .map(|e| AdjointSeed {
                z: z.row(e).to_vec(),
                ws: ws.row(e).to_vec(),
                wl: wl.row(e).to_vec(),
                wn: wn.row(e).to_vec(),
            })
            .collect();

        // project out all three gradients per element
        let mut zt = z;
        zt.axpy(1.0, &t);
        let mut gb = wl;
        gb.scale(-rho);
        gemm_acc(&mut gb, -rho, &zt, &self.at);
        let mut gh = Mat::zeros(bsz, m);
        for e in 0..bsz {
            let gr = gates.row(e);
            let wsr = ws.row(e);
            let wnr = wn.row(e);
            let ghr = gh.row_mut(e);
            for i in 0..m {
                ghr[i] =
                    gr[i] * wsr[i] - rho * (1.0 - gr[i]) * wnr[i];
            }
        }
        gemm_acc(&mut gh, -rho, &zt, &self.gt);

        let rows = |mat: &Mat| -> Vec<Vec<f64>> {
            (0..bsz).map(|e| mat.row(e).to_vec()).collect()
        };
        (
            BatchVjp {
                grads_q: rows(&zt),
                grads_b: rows(&gb),
                grads_h: rows(&gh),
                iters,
                step_rel,
            },
            seeds_out,
        )
    }

    /// Forward batch solve + batched reverse-mode backward in one call:
    /// the minibatch training entry point. No Jacobian is ever
    /// materialized — peak gradient state is O(B·(n+m+p)) instead of the
    /// forward-mode O(B·n·d).
    ///
    /// ```
    /// use altdiff::altdiff::Options;
    /// use altdiff::batch::BatchedAltDiff;
    /// use altdiff::prob::dense_qp;
    ///
    /// let engine = BatchedAltDiff::new(dense_qp(6, 3, 1, 7), 1.0).unwrap();
    /// let q2: Vec<f64> = engine.qp.q.iter().map(|v| 0.5 * v).collect();
    /// let qs: Vec<&[f64]> = vec![&engine.qp.q, &q2];
    /// let vs: Vec<&[f64]> = vec![&[1.0; 6], &[1.0; 6]]; // dL/dx* per element
    /// let out = engine.solve_batch_vjp(
    ///     Some(&qs), None, None, &vs, &Options::with_tol(1e-9));
    /// assert_eq!(out.vjp.grads_q.len(), 2);
    /// assert!(out.forward.jacobians.is_none()); // never materialized
    /// ```
    pub fn solve_batch_vjp(
        &self,
        qs: Option<&[&[f64]]>,
        bs: Option<&[&[f64]]>,
        hs: Option<&[&[f64]]>,
        vs: &[&[f64]],
        opts: &Options,
    ) -> BatchVjpSolution {
        let fopts =
            Options { backward: BackwardMode::None, ..opts.clone() };
        let forward = self.solve_batch(qs, bs, hs, &fopts);
        let vjp = self.batch_vjp(&forward.slack_refs(), vs, opts);
        BatchVjpSolution { forward, vjp }
    }
}

/// Batch-major parameter matrix: provided per-element slices or the
/// registered fallback broadcast to every row. Shared with the batched
/// ADMM engine, which gathers θ the same way.
pub(crate) fn gather(
    rows: Option<&[&[f64]]>,
    fallback: &[f64],
    bsz: usize,
    dim: usize,
) -> Mat {
    let mut m = Mat::zeros(bsz, dim);
    match rows {
        Some(rs) => {
            assert_eq!(rs.len(), bsz, "batch arity");
            for (e, r) in rs.iter().enumerate() {
                assert_eq!(r.len(), dim, "θ dimension");
                m.row_mut(e).copy_from_slice(r);
            }
        }
        None => {
            for e in 0..bsz {
                m.row_mut(e).copy_from_slice(fallback);
            }
        }
    }
    m
}

/// Column-stacked Jacobian recursion state: J_x (n, B·d), J_s (m, B·d),
/// J_λ (p, B·d), J_ν (m, B·d), plus the work buffers the step reuses.
struct JacState {
    d: usize,
    jx: Mat,
    js: Mat,
    jl: Mat,
    jn: Mat,
    lxt: Mat,
    gjx: Mat,
    ajx: Mat,
}

/// Zero the given column ranges of every row (live-block reset between
/// masked GEMM accumulations). Shared with the batched ADMM engine.
pub(crate) fn zero_cols(mat: &mut Mat, ranges: &[(usize, usize)]) {
    for i in 0..mat.rows {
        let row = mat.row_mut(i);
        for &(j0, j1) in ranges {
            row[j0..j1].fill(0.0);
        }
    }
}

impl JacState {
    fn new(n: usize, m: usize, p: usize, bsz: usize, d: usize) -> Self {
        let bd = bsz * d;
        JacState {
            d,
            jx: Mat::zeros(n, bd),
            js: Mat::zeros(m, bd),
            jl: Mat::zeros(p, bd),
            jn: Mat::zeros(m, bd),
            lxt: Mat::zeros(n, bd),
            gjx: Mat::zeros(m, bd),
            ajx: Mat::zeros(p, bd),
        }
    }

    /// One batched backward update (7a)-(7d); mirrors
    /// `DenseAltDiff::jacobian_step` per column block. `slack` is the
    /// freshly updated batch-major slack matrix.
    fn step(
        &mut self,
        eng: &BatchedAltDiff,
        param: Param,
        slack: &Mat,
        act: &ActiveSet,
        live: &[usize],
        rho: f64,
    ) {
        let d = self.d;
        let n = eng.qp.n();
        let m = eng.qp.m_ineq();
        let p = eng.qp.p_eq();
        let ranges = act.col_ranges(d);

        // ∇_{x,θ}L = Aᵀ Jλ + Gᵀ Jν + ρGᵀ Js + const(θ)
        zero_cols(&mut self.lxt, &ranges);
        gemm_acc_cols(&mut self.lxt, 1.0, &eng.at, &self.jl, &ranges);
        gemm_acc_cols(&mut self.lxt, 1.0, &eng.gt, &self.jn, &ranges);
        gemm_acc_cols(&mut self.lxt, rho, &eng.gt, &self.js, &ranges);
        match param {
            Param::Q => {
                // + I per element block (from ∂q)
                for &e in live {
                    let base = e * d;
                    for i in 0..n.min(d) {
                        self.lxt[(i, base + i)] += 1.0;
                    }
                }
            }
            Param::B => {
                // − ρAᵀ per element block
                for i in 0..n {
                    let arow = eng.at.row(i);
                    let row = self.lxt.row_mut(i);
                    for &e in live {
                        let base = e * d;
                        for (c, &v) in arow.iter().enumerate() {
                            row[base + c] -= rho * v;
                        }
                    }
                }
            }
            Param::H => {
                // − ρGᵀ per element block (from ρGᵀ(s−h) term)
                for i in 0..n {
                    let grow = eng.gt.row(i);
                    let row = self.lxt.row_mut(i);
                    for &e in live {
                        let base = e * d;
                        for (c, &v) in grow.iter().enumerate() {
                            row[base + c] -= rho * v;
                        }
                    }
                }
            }
        }

        // (7a): Jx = −H⁻¹ ∇L — one blocked gemm over every live block
        zero_cols(&mut self.jx, &ranges);
        gemm_acc_cols(&mut self.jx, -1.0, &eng.hinv, &self.lxt, &ranges);

        // (7b): Js = sgn(s⁺) ⊙ (−1/ρ)(Jν + ρ(G Jx − ∂h/∂θ))
        zero_cols(&mut self.gjx, &ranges);
        gemm_acc_cols(&mut self.gjx, 1.0, &eng.qp.g, &self.jx, &ranges);
        if param == Param::H {
            for &e in live {
                let base = e * d;
                for i in 0..m.min(d) {
                    self.gjx[(i, base + i)] -= 1.0;
                }
            }
        }
        for i in 0..m {
            let jnr = self.jn.row(i);
            let gjr = self.gjx.row(i);
            let jsr = self.js.row_mut(i);
            for &e in live {
                let gate =
                    if slack[(e, i)] > 0.0 { 1.0 } else { 0.0 };
                let base = e * d;
                for c in base..base + d {
                    jsr[c] = gate
                        * (-(1.0 / rho))
                        * (jnr[c] + rho * gjr[c]);
                }
            }
        }

        // (7c): Jλ += ρ(A Jx − ∂b/∂θ)
        zero_cols(&mut self.ajx, &ranges);
        gemm_acc_cols(&mut self.ajx, 1.0, &eng.qp.a, &self.jx, &ranges);
        axpy_cols(&mut self.jl, rho, &self.ajx, &ranges);
        if param == Param::B {
            for &e in live {
                let base = e * d;
                for i in 0..p.min(d) {
                    self.jl[(i, base + i)] -= rho;
                }
            }
        }

        // (7d): Jν += ρ(G Jx + Js − ∂h/∂θ)  [gjx already holds GJx − ∂h]
        axpy_cols(&mut self.jn, rho, &self.gjx, &ranges);
        axpy_cols(&mut self.jn, rho, &self.js, &ranges);
    }

    /// Split the stacked (n, B·d) Jacobian back into per-element mats.
    fn unstack(&self, n: usize, bsz: usize) -> Vec<Mat> {
        let d = self.d;
        let bd = bsz * d;
        (0..bsz)
            .map(|e| {
                let mut jm = Mat::zeros(n, d);
                for i in 0..n {
                    jm.row_mut(i).copy_from_slice(
                        &self.jx.data[i * bd + e * d..i * bd + (e + 1) * d],
                    );
                }
                jm
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prob::dense_qp;

    fn engines(
        n: usize,
        m: usize,
        p: usize,
        seed: u64,
    ) -> (DenseAltDiff, BatchedAltDiff) {
        let dense = DenseAltDiff::new(dense_qp(n, m, p, seed), 1.0).unwrap();
        let batched = BatchedAltDiff::from_dense(&dense);
        (dense, batched)
    }

    #[test]
    fn broadcast_batch_matches_dense_solve() {
        let (dense, batched) = engines(14, 7, 3, 21);
        let opts = Options {
            tol: 1e-10,
            max_iter: 50_000,
            backward: BackwardMode::Forward(Param::B),
            ..Default::default()
        };
        let sd = dense.solve(&opts);
        let sb = batched.solve_batch(None, None, None, &opts);
        assert_eq!(sb.len(), 1);
        for i in 0..14 {
            assert!((sb.xs[0][i] - sd.x[i]).abs() < 1e-8, "x[{i}]");
        }
        let jb = &sb.jacobians.as_ref().unwrap()[0];
        let jd = sd.jacobian.as_ref().unwrap();
        assert!(jb.max_abs_diff(jd) < 1e-8);
        assert_eq!(sb.iters[0], sd.iters);
    }

    #[test]
    fn fixed_k_runs_every_element_exactly_k() {
        let (_, batched) = engines(10, 5, 2, 22);
        let q2: Vec<f64> =
            batched.qp.q.iter().map(|&v| 2.0 * v).collect();
        let qs: Vec<&[f64]> = vec![&batched.qp.q, &q2];
        let opts = Options {
            tol: 0.0,
            max_iter: 17,
            backward: BackwardMode::Forward(Param::Q),
            ..Default::default()
        };
        let sb = batched.solve_batch(Some(&qs), None, None, &opts);
        assert_eq!(sb.iters, vec![17, 17]);
        assert!(sb.xs.iter().all(|x| x.iter().all(|v| v.is_finite())));
    }

    #[test]
    fn vjp_matches_explicit_product() {
        let (_, batched) = engines(8, 4, 2, 23);
        let sb = batched.solve_batch(None, None, None, &Options::default());
        let g: Vec<f64> = (0..8).map(|i| (i as f64) - 3.5).collect();
        let v = sb.vjp(0, &g);
        let j = &sb.jacobians.as_ref().unwrap()[0];
        for c in 0..2 {
            let want: f64 = (0..8).map(|i| g[i] * j[(i, c)]).sum();
            assert!((v[c] - want).abs() < 1e-12);
        }
        let sol = sb.element(0);
        assert_eq!(sol.iters, sb.iters[0]);
        assert_eq!(sol.x, sb.xs[0]);
    }
}
