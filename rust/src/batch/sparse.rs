//! The batched sparse-QP Alt-Diff engine: B Table-4-style instances per
//! launch.
//!
//! Where the dense batch engine turns gemvs into GEMMs, the sparse one
//! turns CSR traversals into multi-RHS traversals: iterates live in
//! *element-major* blocks of shape (n, B) — column `e` is element `e`,
//! so the B values of one coordinate are contiguous — and every
//! constraint product is one [`crate::sparse::Csr::spmm_acc`] /
//! [`spmm_t_acc`](crate::sparse::Csr::spmm_t_acc) sweep that decodes
//! each nonzero once and feeds B contiguous lanes. The x-update engine
//! is inherited from the sequential registration
//! ([`SparseAltDiff`](crate::altdiff::SparseAltDiff)):
//!
//! 1. **Batched Sherman–Morrison** for the sparsemax structure
//!    H = D + ρaaᵀ: per launch one (n, B) fused pass — `dinv`/`u` are
//!    loaded once per coordinate and amortized over the whole batch
//!    (the sequential path re-reads them per element). O(nB) per solve.
//! 2. **Blocked Jacobi-preconditioned CG** otherwise
//!    ([`block_cg`](crate::sparse::block_cg())): all B systems advance
//!    together, each column stops at its own tolerance via the
//!    [`ActiveSet`] mask, warm-started from the previous ADMM iterate.
//!
//! Truncation (§4.3) is per element exactly as in the dense engine: a
//! converged element's column (and its Jacobian column block in the
//! (n, B·d) stacked state) is frozen and excluded from every kernel
//! via column ranges. Per element, the arithmetic matches
//! [`SparseAltDiff::solve_with`](crate::altdiff::SparseAltDiff::solve_with)
//! operation-for-operation (see `tests/prop_batched_sparse.rs`).

use super::mask::ActiveSet;
use super::{BatchSolution, BatchVjp, BatchVjpSolution};
use crate::altdiff::sparse::Engine;
use crate::altdiff::{BackwardMode, Options, Param, SparseAltDiff};
use crate::error::Result;
use crate::linalg::Mat;
use crate::obs::IterObserver;
use crate::prob::SparseQp;
use crate::sparse::block_cg::zero_cols;
use crate::sparse::{block_cg, BlockHessianOp};
use crate::warm::{AdjointSeed, WarmStart};

/// A registered sparse QP structure ready to solve B instances per
/// launch.
///
/// Construct with [`Self::new`], or [`Self::from_sparse`] to share a
/// sequential layer's registration (engine pick + Sherman–Morrison
/// caches) without re-deriving them.
pub struct BatchedSparseAltDiff {
    /// The registered problem (CSR constraints, diagonal P).
    pub qp: SparseQp,
    /// ADMM penalty ρ (registration-time, like every other engine).
    pub rho: f64,
    engine: Engine,
    /// diag(P), the diagonal part of the CG operator.
    hdiag_p: Vec<f64>,
}

impl BatchedSparseAltDiff {
    /// Register from scratch (same engine auto-pick as
    /// [`SparseAltDiff::new`]).
    pub fn new(qp: SparseQp, rho: f64) -> Result<Self> {
        let seq = SparseAltDiff::new(qp, rho)?;
        Ok(Self::from_sparse(&seq))
    }

    /// Share an already-registered sequential layer's caches — the
    /// cheap path for the server, which keeps both engines per layer.
    pub fn from_sparse(solver: &SparseAltDiff) -> Self {
        BatchedSparseAltDiff {
            qp: solver.qp.clone(),
            rho: solver.rho,
            engine: solver.engine.clone(),
            hdiag_p: solver.hdiag_p.clone(),
        }
    }

    /// True when the batched Sherman–Morrison fast path is active.
    pub fn uses_sherman_morrison(&self) -> bool {
        matches!(self.engine, Engine::ShermanMorrison { .. })
    }

    /// Apply H⁻¹ to every column of `rhs` inside `ranges` (batched
    /// Sherman–Morrison), or solve H X = rhs by blocked CG with `x` as
    /// warm start (`flags` masks live columns). `ur` is a caller-owned
    /// scratch of width `rhs.cols`. Errors surface blocked-CG failures
    /// (Sherman–Morrison is direct and cannot fail).
    fn hsolve_block(
        &self,
        rhs: &Mat,
        x: &mut Mat,
        op: Option<&BlockHessianOp<'_>>,
        ranges: &[(usize, usize)],
        flags: &[bool],
        ur: &mut [f64],
    ) -> Result<()> {
        match &self.engine {
            Engine::ShermanMorrison { dinv, u, denom, rho } => {
                // (D + ρaaᵀ)⁻¹R = D⁻¹R − u·(ρ aᵀD⁻¹R)/denom, with
                // u = D⁻¹a and aᵀD⁻¹R = uᵀR, all columns in one pass.
                for &(c0, c1) in ranges {
                    ur[c0..c1].fill(0.0);
                }
                for (i, &ui) in u.iter().enumerate() {
                    let rr = rhs.row(i);
                    for &(c0, c1) in ranges {
                        for c in c0..c1 {
                            ur[c] += ui * rr[c];
                        }
                    }
                }
                for &(c0, c1) in ranges {
                    for c in c0..c1 {
                        ur[c] = rho * ur[c] / denom;
                    }
                }
                for i in 0..x.rows {
                    let di = dinv[i];
                    let ui = u[i];
                    let rr = rhs.row(i);
                    let xr = x.row_mut(i);
                    for &(c0, c1) in ranges {
                        for c in c0..c1 {
                            xr[c] = di * rr[c] - ur[c] * ui;
                        }
                    }
                }
                Ok(())
            }
            Engine::Cg { cg_tol, cg_max } => {
                let op = op.expect("CG engine requires a block operator");
                block_cg(op, rhs, x, *cg_tol, *cg_max, Some(flags))?;
                Ok(())
            }
        }
    }

    /// Solve + differentiate B instances in one launch, panicking if
    /// the blocked-CG inner solver fails (cannot happen on the
    /// Sherman–Morrison path). Convenience wrapper over
    /// [`Self::try_solve_batch`] for callers that own their problem
    /// data (tests, training loops).
    pub fn solve_batch(
        &self,
        qs: Option<&[&[f64]]>,
        bs: Option<&[&[f64]]>,
        hs: Option<&[&[f64]]>,
        opts: &Options,
    ) -> BatchSolution {
        self.try_solve_batch(qs, bs, hs, opts)
            .expect("batched sparse solve failed")
    }

    /// Solve + differentiate B instances in one launch. Each of
    /// `qs`/`bs`/`hs` is either one slice per element or `None` to
    /// broadcast the registered parameter; the batch size is inferred
    /// from whichever is provided (1 if none are). Semantics mirror
    /// [`super::BatchedAltDiff::solve_batch`]: per-element truncation
    /// at `opts.tol` (`tol = 0` → every element runs exactly
    /// `opts.max_iter` iterations, the serving contract).
    ///
    /// Errors only on the CG engine, when an inner blocked-CG solve
    /// fails ([`crate::AltDiffError::NotSpd`] /
    /// [`crate::AltDiffError::NoConvergence`]) — the server maps this
    /// to per-request failure replies instead of panicking a worker.
    pub fn try_solve_batch(
        &self,
        qs: Option<&[&[f64]]>,
        bs: Option<&[&[f64]]>,
        hs: Option<&[&[f64]]>,
        opts: &Options,
    ) -> Result<BatchSolution> {
        self.try_solve_batch_from(qs, bs, hs, None, opts)
    }

    /// [`Self::try_solve_batch`] with per-element warm starts — the
    /// sparse sibling of
    /// [`super::BatchedAltDiff::solve_batch_from`]: element e resumes
    /// from `warms[e]` when present (column e of the element-major
    /// iterate blocks is seeded, and on the CG engine it warm-starts
    /// the first inner H-solve), cold otherwise; mixed batches truncate
    /// per element through the existing [`ActiveSet`] masks. Warm
    /// slacks come from the (6) projection; `warms = None` is
    /// bit-identical to the cold path; warm + forward-mode Jacobians
    /// require `tol = 0` (asserted — see DESIGN.md §5).
    pub fn try_solve_batch_from(
        &self,
        qs: Option<&[&[f64]]>,
        bs: Option<&[&[f64]]>,
        hs: Option<&[&[f64]]>,
        warms: Option<&[Option<WarmStart>]>,
        opts: &Options,
    ) -> Result<BatchSolution> {
        self.try_solve_batch_observed(qs, bs, hs, warms, opts, None)
    }

    /// [`Self::try_solve_batch_from`] with a per-iteration
    /// [`IterObserver`] hook (see
    /// [`BatchedAltDiff::solve_batch_observed`](super::BatchedAltDiff::solve_batch_observed)
    /// for the contract): residuals are computed only for claimed
    /// elements, `observer = None` is the unsampled fast path, and the
    /// returned solution is identical either way.
    pub fn try_solve_batch_observed(
        &self,
        qs: Option<&[&[f64]]>,
        bs: Option<&[&[f64]]>,
        hs: Option<&[&[f64]]>,
        warms: Option<&[Option<WarmStart>]>,
        opts: &Options,
        mut observer: Option<&mut dyn IterObserver>,
    ) -> Result<BatchSolution> {
        let n = self.qp.n();
        let m = self.qp.h.len();
        let p = self.qp.b.len();
        let rho = self.rho; // registration-time, like SparseAltDiff
        let bsz = qs
            .map(|v| v.len())
            .or_else(|| bs.map(|v| v.len()))
            .or_else(|| hs.map(|v| v.len()))
            .or_else(|| warms.map(|v| v.len()))
            .unwrap_or(1);
        assert!(bsz > 0, "empty batch");

        // element-major parameter blocks (broadcast registered θ)
        let qm = gather_cols(qs, &self.qp.q, bsz, n);
        let bm = gather_cols(bs, &self.qp.b, bsz, p);
        let hm = gather_cols(hs, &self.qp.h, bsz, m);

        // θ-constant part of the (5a) rhs: −q + ρAᵀb, per element
        let mut cq = qm;
        cq.scale(-1.0);
        let full = [(0usize, bsz)];
        self.qp.a.spmm_t_acc(&mut cq, rho, &bm, &full);

        // iterates, element-major (coordinate rows × element columns)
        let mut x = Mat::zeros(n, bsz);
        let mut s = Mat::zeros(m, bsz);
        let mut lam = Mat::zeros(p, bsz);
        let mut nu = Mat::zeros(m, bsz);
        let mut xprev = Mat::zeros(n, bsz);
        let mut rhs = Mat::zeros(n, bsz);
        let mut hms = Mat::zeros(m, bsz);
        let mut gx = Mat::zeros(m, bsz);
        let mut ax = Mat::zeros(p, bsz);
        let mut ur = vec![0.0; bsz];

        if let Some(ws_) = warms {
            assert_eq!(ws_.len(), bsz, "warm-start arity");
            if ws_.iter().any(|w| w.is_some()) {
                assert!(
                    opts.backward.forward_param().is_none()
                        || opts.tol == 0.0,
                    "warm starts with forward-mode Jacobians require \
                     tol = 0 (fixed-k); use BackwardMode::None/Adjoint \
                     for truncated warm solves"
                );
            }
            for (e, w) in ws_.iter().enumerate() {
                let Some(w) = w else { continue };
                assert_eq!(w.dims(), (n, p, m), "warm-start dimensions");
                for i in 0..n {
                    x[(i, e)] = w.x[i];
                }
                for i in 0..p {
                    lam[(i, e)] = w.lam[i];
                }
                for i in 0..m {
                    nu[(i, e)] = w.nu[i];
                }
                // warm slack via the (6) projection at the warm point
                let mut gx0 = vec![0.0; m];
                self.qp.g.spmv_acc(&mut gx0, 1.0, &w.x);
                for i in 0..m {
                    s[(i, e)] = (-w.nu[i] / rho
                        - (gx0[i] - hm[(i, e)]))
                        .max(0.0);
                }
            }
        }

        let is_cg = !self.uses_sherman_morrison();
        let op_fwd = is_cg.then(|| {
            BlockHessianOp::new(
                &self.hdiag_p,
                &self.qp.a,
                &self.qp.g,
                rho,
                bsz,
            )
        });

        // Jacobian state: per-element (rows × d) blocks stacked along
        // columns, like the dense batch engine
        let param = opts.backward.forward_param();
        let d = param.map(|pm| pm.dim(n, m, p));
        let mut jac = d.map(|d| JacState::new(n, m, p, bsz, d));
        let op_bwd = match (is_cg, d) {
            (true, Some(d)) => Some(BlockHessianOp::new(
                &self.hdiag_p,
                &self.qp.a,
                &self.qp.g,
                rho,
                bsz * d,
            )),
            _ => None,
        };

        let mut act = ActiveSet::new(bsz);
        let mut iters = vec![0usize; bsz];
        let mut step_rel = vec![f64::INFINITY; bsz];
        let mut live: Vec<usize> = Vec::with_capacity(bsz);

        for k in 0..opts.max_iter {
            if act.all_done() {
                break;
            }
            live.clear();
            live.extend(act.iter());
            let ranges = act.col_ranges(1);
            for &e in &live {
                iters[e] = k + 1;
            }
            copy_cols(&mut xprev, &x, &ranges);

            // ---- forward (5a): H x = −q − Aᵀλ − Gᵀν + ρAᵀb + ρGᵀ(h−s)
            copy_cols(&mut rhs, &cq, &ranges);
            for i in 0..m {
                let hr = hm.row(i);
                let sr = s.row(i);
                let out = hms.row_mut(i);
                for &(c0, c1) in &ranges {
                    for c in c0..c1 {
                        out[c] = hr[c] - sr[c];
                    }
                }
            }
            self.qp.a.spmm_t_acc(&mut rhs, -1.0, &lam, &ranges);
            self.qp.g.spmm_t_acc(&mut rhs, -1.0, &nu, &ranges);
            self.qp.g.spmm_t_acc(&mut rhs, rho, &hms, &ranges);
            self.hsolve_block(
                &rhs,
                &mut x,
                op_fwd.as_ref(),
                &ranges,
                act.flags(),
                &mut ur,
            )?;

            // ---- (6): slack, (5c)/(5d): duals
            zero_cols(&mut gx, &ranges);
            zero_cols(&mut ax, &ranges);
            self.qp.g.spmm_acc(&mut gx, 1.0, &x, &ranges);
            self.qp.a.spmm_acc(&mut ax, 1.0, &x, &ranges);
            for i in 0..m {
                let gxr = gx.row(i);
                let hr = hm.row(i);
                let nur = nu.row(i);
                let sr = s.row_mut(i);
                for &(c0, c1) in &ranges {
                    for c in c0..c1 {
                        sr[c] =
                            (-nur[c] / rho - (gxr[c] - hr[c])).max(0.0);
                    }
                }
            }
            for i in 0..p {
                let axr = ax.row(i);
                let br = bm.row(i);
                let lr = lam.row_mut(i);
                for &(c0, c1) in &ranges {
                    for c in c0..c1 {
                        lr[c] += rho * (axr[c] - br[c]);
                    }
                }
            }
            for i in 0..m {
                let gxr = gx.row(i);
                let hr = hm.row(i);
                let sr = s.row(i);
                let nur = nu.row_mut(i);
                for &(c0, c1) in &ranges {
                    for c in c0..c1 {
                        nur[c] += rho * (gxr[c] + sr[c] - hr[c]);
                    }
                }
            }

            // ---- backward (7a)-(7d), only live column blocks
            if let Some(jac) = jac.as_mut() {
                jac.step(
                    self,
                    op_bwd.as_ref(),
                    param.unwrap(),
                    &s,
                    &act,
                    &live,
                    rho,
                )?;
            }

            // ---- per-element truncation (Algorithm 1 condition)
            for &e in &live {
                let mut dx2 = 0.0;
                let mut xp2 = 0.0;
                for i in 0..n {
                    let xv = x[(i, e)];
                    let pv = xprev[(i, e)];
                    dx2 += (xv - pv) * (xv - pv);
                    xp2 += pv * pv;
                }
                // sampled-trace hook: ax/gx/s hold the k+1 iterate here
                if let Some(obs) = observer.as_deref_mut() {
                    if obs.wants(e) {
                        let mut pr = 0.0;
                        for i in 0..p {
                            let v = ax[(i, e)] - bm[(i, e)];
                            pr += v * v;
                        }
                        for i in 0..m {
                            let v =
                                gx[(i, e)] + s[(i, e)] - hm[(i, e)];
                            pr += v * v;
                        }
                        obs.on_iter(e, k, pr.sqrt(), rho * dx2.sqrt());
                    }
                }
                let step = dx2.sqrt() / xp2.sqrt().max(1.0);
                step_rel[e] = step;
                if step < opts.tol {
                    act.deactivate(e);
                }
            }
        }

        // unpack element-major state into per-element vectors
        let cols = |mat: &Mat| -> Vec<Vec<f64>> {
            (0..bsz).map(|e| mat.col(e)).collect()
        };
        let jacobians = jac.map(|j| j.unstack(n, bsz));
        Ok(BatchSolution {
            xs: cols(&x),
            ss: cols(&s),
            lams: cols(&lam),
            nus: cols(&nu),
            jacobians,
            iters,
            step_rel,
        })
    }

    /// Batched reverse-mode backward, panicking on blocked-CG breakdown
    /// (cannot happen on the Sherman–Morrison path). Convenience wrapper
    /// over [`Self::try_batch_vjp`].
    pub fn batch_vjp(
        &self,
        slacks: &[&[f64]],
        vs: &[&[f64]],
        opts: &Options,
    ) -> BatchVjp {
        self.try_batch_vjp(slacks, vs, opts)
            .expect("batched sparse adjoint failed")
    }

    /// Batched reverse-mode backward: B adjoint vectors advance as one
    /// element-major (state, B) panel, so every iteration of the
    /// transposed recursion is one multi-RHS SpMM sweep per constraint
    /// product plus one blocked H⁻¹ apply (batched Sherman–Morrison or
    /// [`block_cg`](crate::sparse::block_cg()) at width B — never B·d).
    /// `slacks` are the
    /// per-element final slacks of the forward launch, `vs` the incoming
    /// gradients dL/dx*ₑ. Per-element truncation freezes converged
    /// adjoint columns through the same [`ActiveSet`] masks the forward
    /// engine uses. Errors only on the CG engine, like
    /// [`Self::try_solve_batch`].
    pub fn try_batch_vjp(
        &self,
        slacks: &[&[f64]],
        vs: &[&[f64]],
        opts: &Options,
    ) -> Result<BatchVjp> {
        Ok(self.try_batch_vjp_from(slacks, vs, None, opts)?.0)
    }

    /// [`Self::try_batch_vjp`] with per-element warm adjoint seeds,
    /// also returning every element's final adjoint state for reuse —
    /// the sparse sibling of
    /// [`super::BatchedAltDiff::batch_vjp_from`]. Seeded columns resume
    /// the transposed recursion (and warm-start the inner CG solves);
    /// `warms = None` is bit-identical to the cold path.
    pub fn try_batch_vjp_from(
        &self,
        slacks: &[&[f64]],
        vs: &[&[f64]],
        warms: Option<&[Option<AdjointSeed>]>,
        opts: &Options,
    ) -> Result<(BatchVjp, Vec<AdjointSeed>)> {
        let n = self.qp.n();
        let m = self.qp.h.len();
        let p = self.qp.b.len();
        let rho = self.rho;
        let bsz = vs.len();
        assert!(bsz > 0, "empty batch");
        assert_eq!(slacks.len(), bsz, "slack arity");

        // gates σ, element-major (m, B)
        let mut gates = Mat::zeros(m, bsz);
        for (e, s) in slacks.iter().enumerate() {
            assert_eq!(s.len(), m, "slack dimension");
            for i in 0..m {
                gates[(i, e)] = if s[i] > 0.0 { 1.0 } else { 0.0 };
            }
        }

        let is_cg = !self.uses_sherman_morrison();
        let op = is_cg.then(|| {
            BlockHessianOp::new(
                &self.hdiag_p,
                &self.qp.a,
                &self.qp.g,
                rho,
                bsz,
            )
        });
        let full = [(0usize, bsz)];
        let all_flags = vec![true; bsz];
        let mut ur = vec![0.0; bsz];

        // T = −H⁻¹V and seeds (Vₛ, V_λ, V_ν) = (ρGT, AT, GT)
        let mut negv = Mat::zeros(n, bsz);
        for (e, v) in vs.iter().enumerate() {
            assert_eq!(v.len(), n, "v dimension");
            for i in 0..n {
                negv[(i, e)] = -v[i];
            }
        }
        let mut t = Mat::zeros(n, bsz);
        self.hsolve_block(
            &negv, &mut t, op.as_ref(), &full, &all_flags, &mut ur,
        )?;
        let mut vn = Mat::zeros(m, bsz);
        self.qp.g.spmm_acc(&mut vn, 1.0, &t, &full);
        let mut vl = Mat::zeros(p, bsz);
        self.qp.a.spmm_acc(&mut vl, 1.0, &t, &full);

        // W₁ = V (per element, unless a seed resumes the series)
        let mut ws = vn.clone();
        ws.scale(rho);
        let mut wl = vl.clone();
        let mut wn = vn.clone();

        let mut z = Mat::zeros(n, bsz);
        let mut seeded = vec![false; bsz];
        if let Some(seeds) = warms {
            assert_eq!(seeds.len(), bsz, "adjoint-seed arity");
            for (e, seed) in seeds.iter().enumerate() {
                let Some(seed) = seed else { continue };
                assert_eq!(
                    seed.dims(),
                    (n, p, m),
                    "adjoint-seed dimensions"
                );
                for i in 0..m {
                    ws[(i, e)] = seed.ws[i];
                    wn[(i, e)] = seed.wn[i];
                }
                for i in 0..p {
                    wl[(i, e)] = seed.wl[i];
                }
                for i in 0..n {
                    z[(i, e)] = seed.z[i];
                }
                seeded[e] = true;
            }
        }
        let mut zprev = Mat::zeros(n, bsz);
        let mut rhs = Mat::zeros(n, bsz);
        let mut dws = Mat::zeros(m, bsz);
        let mut ewn = Mat::zeros(m, bsz);
        let mut gz = Mat::zeros(m, bsz);
        let mut az = Mat::zeros(p, bsz);

        let mut act = ActiveSet::new(bsz);
        let mut iters = vec![1usize; bsz];
        let mut step_rel = vec![f64::INFINITY; bsz];
        let mut live: Vec<usize> = Vec::with_capacity(bsz);

        for k in 1..opts.max_iter {
            if act.all_done() {
                break;
            }
            live.clear();
            live.extend(act.iter());
            let ranges = act.col_ranges(1);
            copy_cols(&mut zprev, &z, &ranges);
            // z = H⁻¹(Gᵀ(σ⊙wₛ) − ρAᵀw_λ − ρGᵀ((1−σ)⊙w_ν)); z doubles
            // as the CG warm start across iterations
            for i in 0..m {
                let gr = gates.row(i);
                let wsr = ws.row(i);
                let wnr = wn.row(i);
                let dr = dws.row_mut(i);
                for &(c0, c1) in &ranges {
                    for c in c0..c1 {
                        dr[c] = gr[c] * wsr[c];
                    }
                }
                let er = ewn.row_mut(i);
                for &(c0, c1) in &ranges {
                    for c in c0..c1 {
                        er[c] = (1.0 - gr[c]) * wnr[c];
                    }
                }
            }
            zero_cols(&mut rhs, &ranges);
            self.qp.g.spmm_t_acc(&mut rhs, 1.0, &dws, &ranges);
            self.qp.a.spmm_t_acc(&mut rhs, -rho, &wl, &ranges);
            self.qp.g.spmm_t_acc(&mut rhs, -rho, &ewn, &ranges);
            self.hsolve_block(
                &rhs,
                &mut z,
                op.as_ref(),
                &ranges,
                act.flags(),
                &mut ur,
            )?;

            // W ← MᵀW + V
            zero_cols(&mut gz, &ranges);
            zero_cols(&mut az, &ranges);
            self.qp.g.spmm_acc(&mut gz, 1.0, &z, &ranges);
            self.qp.a.spmm_acc(&mut az, 1.0, &z, &ranges);
            for i in 0..m {
                let gr = gates.row(i);
                let gzr = gz.row(i);
                let vnr = vn.row(i);
                // order matters: w_ν reads the OLD wₛ
                {
                    let wsr = ws.row(i);
                    let wnr = wn.row_mut(i);
                    for &(c0, c1) in &ranges {
                        for c in c0..c1 {
                            wnr[c] = (1.0 - gr[c]) * wnr[c] + gzr[c]
                                - gr[c] * wsr[c] / rho
                                + vnr[c];
                        }
                    }
                }
                let wsr = ws.row_mut(i);
                for &(c0, c1) in &ranges {
                    for c in c0..c1 {
                        wsr[c] = rho * gzr[c] + rho * vnr[c];
                    }
                }
            }
            for i in 0..p {
                let azr = az.row(i);
                let vlr = vl.row(i);
                let wlr = wl.row_mut(i);
                for &(c0, c1) in &ranges {
                    for c in c0..c1 {
                        wlr[c] += azr[c] + vlr[c];
                    }
                }
            }
            // per-element truncation on the adjoint iterate z. A
            // seeded element's first iteration reproduces its
            // harvested z exactly (zero step under unchanged gates),
            // so it must take one genuine step before the criterion
            // is trusted.
            for &e in &live {
                iters[e] = k + 1;
                let mut dz2 = 0.0;
                let mut zp2 = 0.0;
                for i in 0..n {
                    let zv = z[(i, e)];
                    let pv = zprev[(i, e)];
                    dz2 += (zv - pv) * (zv - pv);
                    zp2 += pv * pv;
                }
                let step = dz2.sqrt() / zp2.sqrt().max(1.0);
                step_rel[e] = step;
                if step < opts.tol && (k > 1 || !seeded[e]) {
                    act.deactivate(e);
                }
            }
        }

        // final z at every element's converged adjoint state
        for i in 0..m {
            let gr = gates.row(i);
            let wsr = ws.row(i);
            let wnr = wn.row(i);
            let dr = dws.row_mut(i);
            let er = ewn.row_mut(i);
            for c in 0..bsz {
                dr[c] = gr[c] * wsr[c];
                er[c] = (1.0 - gr[c]) * wnr[c];
            }
        }
        rhs.data.fill(0.0);
        self.qp.g.spmm_t_acc(&mut rhs, 1.0, &dws, &full);
        self.qp.a.spmm_t_acc(&mut rhs, -rho, &wl, &full);
        self.qp.g.spmm_t_acc(&mut rhs, -rho, &ewn, &full);
        self.hsolve_block(
            &rhs, &mut z, op.as_ref(), &full, &all_flags, &mut ur,
        )?;

        // reusable adjoint states, harvested before the projection
        // consumes z and the w's (element-major: one column each)
        let seeds_out: Vec<AdjointSeed> = (0..bsz)
            .map(|e| AdjointSeed {
                z: z.col(e),
                ws: ws.col(e),
                wl: wl.col(e),
                wn: wn.col(e),
            })
            .collect();

        // project out all three gradients per element
        let mut zt = z;
        zt.axpy(1.0, &t);
        let mut gb = wl;
        gb.scale(-rho);
        self.qp.a.spmm_acc(&mut gb, -rho, &zt, &full);
        let mut gh = Mat::zeros(m, bsz);
        for i in 0..m {
            let gr = gates.row(i);
            let wsr = ws.row(i);
            let wnr = wn.row(i);
            let ghr = gh.row_mut(i);
            for c in 0..bsz {
                ghr[c] =
                    gr[c] * wsr[c] - rho * (1.0 - gr[c]) * wnr[c];
            }
        }
        self.qp.g.spmm_acc(&mut gh, -rho, &zt, &full);

        let cols = |mat: &Mat| -> Vec<Vec<f64>> {
            (0..bsz).map(|e| mat.col(e)).collect()
        };
        Ok((
            BatchVjp {
                grads_q: cols(&zt),
                grads_b: cols(&gb),
                grads_h: cols(&gh),
                iters,
                step_rel,
            },
            seeds_out,
        ))
    }

    /// Forward batch solve + batched reverse-mode backward in one call,
    /// panicking on blocked-CG breakdown. Convenience wrapper over
    /// [`Self::try_solve_batch_vjp`].
    pub fn solve_batch_vjp(
        &self,
        qs: Option<&[&[f64]]>,
        bs: Option<&[&[f64]]>,
        hs: Option<&[&[f64]]>,
        vs: &[&[f64]],
        opts: &Options,
    ) -> BatchVjpSolution {
        self.try_solve_batch_vjp(qs, bs, hs, vs, opts)
            .expect("batched sparse solve+vjp failed")
    }

    /// Forward batch solve + batched reverse-mode backward — the sparse
    /// minibatch training entry point, mirroring
    /// [`super::BatchedAltDiff::solve_batch_vjp`]. No Jacobian is ever
    /// materialized. Errors only on the CG engine (the server maps this
    /// to per-request failure replies).
    pub fn try_solve_batch_vjp(
        &self,
        qs: Option<&[&[f64]]>,
        bs: Option<&[&[f64]]>,
        hs: Option<&[&[f64]]>,
        vs: &[&[f64]],
        opts: &Options,
    ) -> Result<BatchVjpSolution> {
        let fopts =
            Options { backward: BackwardMode::None, ..opts.clone() };
        let forward = self.try_solve_batch(qs, bs, hs, &fopts)?;
        let vjp = self.try_batch_vjp(&forward.slack_refs(), vs, opts)?;
        Ok(BatchVjpSolution { forward, vjp })
    }
}

/// Element-major parameter block: provided per-element slices (columns)
/// or the registered fallback broadcast to every column.
fn gather_cols(
    cols: Option<&[&[f64]]>,
    fallback: &[f64],
    bsz: usize,
    dim: usize,
) -> Mat {
    let mut m = Mat::zeros(dim, bsz);
    match cols {
        Some(cs) => {
            assert_eq!(cs.len(), bsz, "batch arity");
            for (e, c) in cs.iter().enumerate() {
                assert_eq!(c.len(), dim, "θ dimension");
                for i in 0..dim {
                    m[(i, e)] = c[i];
                }
            }
        }
        None => {
            for (i, &v) in fallback.iter().enumerate() {
                m.row_mut(i).fill(v);
            }
        }
    }
    m
}

/// Copy `src` into `dst` restricted to the given column ranges.
fn copy_cols(dst: &mut Mat, src: &Mat, ranges: &[(usize, usize)]) {
    debug_assert_eq!((dst.rows, dst.cols), (src.rows, src.cols));
    for i in 0..dst.rows {
        let sr = src.row(i);
        let dr = dst.row_mut(i);
        for &(c0, c1) in ranges {
            dr[c0..c1].copy_from_slice(&sr[c0..c1]);
        }
    }
}

/// Column-stacked Jacobian recursion state: J_x (n, B·d), J_s (m, B·d),
/// J_λ (p, B·d), J_ν (m, B·d), plus the work buffers the step reuses.
/// Element e owns columns [e·d, (e+1)·d).
struct JacState {
    d: usize,
    jx: Mat,
    js: Mat,
    jl: Mat,
    jn: Mat,
    lxt: Mat,
    gjx: Mat,
    ajx: Mat,
    /// CG solve buffer / warm start (−J_x), and SM output buffer
    xw: Mat,
    /// live-column flags at B·d granularity (block CG mask)
    flags_d: Vec<bool>,
    /// Sherman–Morrison per-column scratch
    ur: Vec<f64>,
}

impl JacState {
    fn new(n: usize, m: usize, p: usize, bsz: usize, d: usize) -> Self {
        let bd = bsz * d;
        JacState {
            d,
            jx: Mat::zeros(n, bd),
            js: Mat::zeros(m, bd),
            jl: Mat::zeros(p, bd),
            jn: Mat::zeros(m, bd),
            lxt: Mat::zeros(n, bd),
            gjx: Mat::zeros(m, bd),
            ajx: Mat::zeros(p, bd),
            xw: Mat::zeros(n, bd),
            flags_d: vec![false; bd],
            ur: vec![0.0; bd],
        }
    }

    /// One batched backward update (7a)-(7d); mirrors
    /// `SparseAltDiff::jacobian_step` per column block. `slack` is the
    /// freshly updated element-major slack block. Errors propagate
    /// blocked-CG failures from the (7a) solve.
    fn step(
        &mut self,
        eng: &BatchedSparseAltDiff,
        op: Option<&BlockHessianOp<'_>>,
        param: Param,
        slack: &Mat,
        act: &ActiveSet,
        live: &[usize],
        rho: f64,
    ) -> Result<()> {
        let d = self.d;
        let n = eng.qp.n();
        let m = eng.qp.h.len();
        let p = eng.qp.b.len();
        let ranges = act.col_ranges(d);
        self.flags_d.fill(false);
        for &e in live {
            self.flags_d[e * d..(e + 1) * d].fill(true);
        }

        // ∇_{x,θ}L = Aᵀ Jλ + Gᵀ Jν + ρGᵀ Js + const(θ)
        zero_cols(&mut self.lxt, &ranges);
        eng.qp.a.spmm_t_acc(&mut self.lxt, 1.0, &self.jl, &ranges);
        eng.qp.g.spmm_t_acc(&mut self.lxt, 1.0, &self.jn, &ranges);
        eng.qp.g.spmm_t_acc(&mut self.lxt, rho, &self.js, &ranges);
        match param {
            Param::Q => {
                // + I per element block (from ∂q)
                for &e in live {
                    let base = e * d;
                    for i in 0..n.min(d) {
                        self.lxt[(i, base + i)] += 1.0;
                    }
                }
            }
            Param::B => {
                // − ρAᵀ per element block: column c of the block is
                // −ρ·(row c of A) scattered
                for r in 0..eng.qp.a.rows.min(d) {
                    for k in eng.qp.a.indptr[r]..eng.qp.a.indptr[r + 1] {
                        let i = eng.qp.a.indices[k];
                        let v = rho * eng.qp.a.values[k];
                        for &e in live {
                            self.lxt[(i, e * d + r)] -= v;
                        }
                    }
                }
            }
            Param::H => {
                // − ρGᵀ per element block (from ρGᵀ(s−h) term)
                for r in 0..eng.qp.g.rows.min(d) {
                    for k in eng.qp.g.indptr[r]..eng.qp.g.indptr[r + 1] {
                        let i = eng.qp.g.indices[k];
                        let v = rho * eng.qp.g.values[k];
                        for &e in live {
                            self.lxt[(i, e * d + r)] -= v;
                        }
                    }
                }
            }
        }

        // (7a): Jx = −H⁻¹ ∇L (SM: one fused pass; CG: blocked, warm-
        // started from the previous −Jx column block — the SM path
        // writes xw outright and never reads it, so skip the build)
        if !eng.uses_sherman_morrison() {
            for i in 0..n {
                let jr = self.jx.row(i);
                let xr = self.xw.row_mut(i);
                for &(c0, c1) in &ranges {
                    for c in c0..c1 {
                        xr[c] = -jr[c];
                    }
                }
            }
        }
        eng.hsolve_block(
            &self.lxt,
            &mut self.xw,
            op,
            &ranges,
            &self.flags_d,
            &mut self.ur,
        )?;
        for i in 0..n {
            let xr = self.xw.row(i);
            let jr = self.jx.row_mut(i);
            for &(c0, c1) in &ranges {
                for c in c0..c1 {
                    jr[c] = -xr[c];
                }
            }
        }

        // (7b): Js = sgn(s⁺) ⊙ (−1/ρ)(Jν + ρ(G Jx − ∂h/∂θ))
        zero_cols(&mut self.gjx, &ranges);
        eng.qp.g.spmm_acc(&mut self.gjx, 1.0, &self.jx, &ranges);
        if param == Param::H {
            for &e in live {
                let base = e * d;
                for i in 0..m.min(d) {
                    self.gjx[(i, base + i)] -= 1.0;
                }
            }
        }
        for i in 0..m {
            let jnr = self.jn.row(i);
            let gjr = self.gjx.row(i);
            let jsr = self.js.row_mut(i);
            for &e in live {
                let gate = if slack[(i, e)] > 0.0 { 1.0 } else { 0.0 };
                let base = e * d;
                for c in base..base + d {
                    jsr[c] =
                        gate * (-(1.0 / rho)) * (jnr[c] + rho * gjr[c]);
                }
            }
        }

        // (7c): Jλ += ρ(A Jx − ∂b/∂θ)
        zero_cols(&mut self.ajx, &ranges);
        eng.qp.a.spmm_acc(&mut self.ajx, 1.0, &self.jx, &ranges);
        for i in 0..p {
            let ar = self.ajx.row(i);
            let jr = self.jl.row_mut(i);
            for &(c0, c1) in &ranges {
                for c in c0..c1 {
                    jr[c] += rho * ar[c];
                }
            }
        }
        if param == Param::B {
            for &e in live {
                let base = e * d;
                for i in 0..p.min(d) {
                    self.jl[(i, base + i)] -= rho;
                }
            }
        }

        // (7d): Jν += ρ(G Jx + Js − ∂h/∂θ)  [gjx already holds GJx − ∂h;
        // two passes to match the sequential engine's accumulation order]
        for i in 0..m {
            let gjr = self.gjx.row(i);
            let jnr = self.jn.row_mut(i);
            for &(c0, c1) in &ranges {
                for c in c0..c1 {
                    jnr[c] += rho * gjr[c];
                }
            }
        }
        for i in 0..m {
            let jsr = self.js.row(i);
            let jnr = self.jn.row_mut(i);
            for &(c0, c1) in &ranges {
                for c in c0..c1 {
                    jnr[c] += rho * jsr[c];
                }
            }
        }
        Ok(())
    }

    /// Split the stacked (n, B·d) Jacobian back into per-element mats.
    fn unstack(&self, n: usize, bsz: usize) -> Vec<Mat> {
        let d = self.d;
        let bd = bsz * d;
        (0..bsz)
            .map(|e| {
                let mut jm = Mat::zeros(n, d);
                for i in 0..n {
                    jm.row_mut(i).copy_from_slice(
                        &self.jx.data[i * bd + e * d..i * bd + (e + 1) * d],
                    );
                }
                jm
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prob::{sparse_qp, sparsemax_qp};

    #[test]
    fn engine_pick_is_inherited() {
        let sm =
            BatchedSparseAltDiff::new(sparsemax_qp(30, 1), 1.0).unwrap();
        assert!(sm.uses_sherman_morrison());
        let cg =
            BatchedSparseAltDiff::new(sparse_qp(20, 8, 3, 0.2, 2), 1.0)
                .unwrap();
        assert!(!cg.uses_sherman_morrison());
    }

    #[test]
    fn broadcast_batch_matches_sequential_solve() {
        for (sq, label) in [
            (sparsemax_qp(24, 3), "sherman-morrison"),
            (sparse_qp(16, 7, 3, 0.3, 4), "cg"),
        ] {
            let seq = SparseAltDiff::new(sq.clone(), 1.0).unwrap();
            let batched = BatchedSparseAltDiff::from_sparse(&seq);
            let opts = Options {
                tol: 1e-10,
                max_iter: 50_000,
                backward: BackwardMode::Forward(Param::B),
                ..Default::default()
            };
            let ss = seq.solve(&opts);
            let sb = batched.solve_batch(None, None, None, &opts);
            assert_eq!(sb.len(), 1);
            for i in 0..sq.n() {
                assert!(
                    (sb.xs[0][i] - ss.x[i]).abs() < 1e-8,
                    "{label}: x[{i}]"
                );
            }
            let jb = &sb.jacobians.as_ref().unwrap()[0];
            let jd = ss.jacobian.as_ref().unwrap();
            assert!(jb.max_abs_diff(jd) < 1e-8, "{label}: jacobian");
            // identical stopping rule; ±1 iteration slack for the
            // blocked-kernel vs unrolled-dot rounding at the threshold
            assert!(
                (sb.iters[0] as i64 - ss.iters as i64).abs() <= 1,
                "{label}: {} vs {} iters",
                sb.iters[0],
                ss.iters
            );
        }
    }

    #[test]
    fn fixed_k_runs_every_element_exactly_k() {
        let batched =
            BatchedSparseAltDiff::new(sparsemax_qp(12, 5), 1.0).unwrap();
        let q2: Vec<f64> =
            batched.qp.q.iter().map(|&v| 0.5 * v).collect();
        let qs: Vec<&[f64]> = vec![&batched.qp.q, &q2];
        let opts = Options {
            tol: 0.0,
            max_iter: 13,
            backward: BackwardMode::Forward(Param::Q),
            ..Default::default()
        };
        let sb = batched.solve_batch(Some(&qs), None, None, &opts);
        assert_eq!(sb.iters, vec![13, 13]);
        assert!(sb.xs.iter().all(|x| x.iter().all(|v| v.is_finite())));
    }

    #[test]
    fn vjp_and_element_accessors_work() {
        let batched =
            BatchedSparseAltDiff::new(sparse_qp(10, 4, 2, 0.3, 9), 1.0)
                .unwrap();
        let sb = batched.solve_batch(None, None, None, &Options::default());
        let g: Vec<f64> = (0..10).map(|i| (i as f64) - 4.5).collect();
        let v = sb.vjp(0, &g);
        let j = &sb.jacobians.as_ref().unwrap()[0];
        for c in 0..2 {
            let want: f64 = (0..10).map(|i| g[i] * j[(i, c)]).sum();
            assert!((v[c] - want).abs() < 1e-12);
        }
        let sol = sb.element(0);
        assert_eq!(sol.iters, sb.iters[0]);
        assert_eq!(sol.x, sb.xs[0]);
    }
}
