//! Native batched Alt-Diff: solve B structurally identical QP layers per
//! launch.
//!
//! Alt-Diff's forward (eq. 5) and backward (eq. 7) updates are products
//! against *fixed* layer matrices (H⁻¹, A, G): a batch of B instances
//! sharing structure but differing in θ = (q, b, h) turns every
//! matrix-vector product into a matrix-matrix product — the same batching
//! leverage OptNet exploits for its batched-KKT path. Layout:
//!
//! - iterates are batch-major matrices: X, S, Λ, N of shape (B, n|m|p),
//!   updated with one blocked GEMM per term instead of B gemvs;
//! - per-element Jacobians are stacked as column blocks: J_x is
//!   (n, B·d) with element e owning columns [e·d, (e+1)·d), so the
//!   backward recursion (7a)–(7d) is one GEMM with B·d columns;
//! - truncation (§4.3) is per element: an [`mask::ActiveSet`] freezes
//!   converged elements' rows/column blocks, and the row/column-masked
//!   kernels in [`crate::linalg`] skip their flops entirely.
//!
//! One shared Cholesky of H (inherited from registration, paper
//! Appendix B.1) serves the whole batch; per-element results match
//! [`crate::altdiff::DenseAltDiff`] run element-by-element (see
//! `tests/prop_batched.rs`).
//!
//! The sparse path ([`sparse::BatchedSparseAltDiff`]) carries the same
//! contract into the Table 4 regime: element-major (n, B) blocks,
//! multi-RHS SpMM on the CSR constraints, a batched Sherman–Morrison
//! fast path for sparsemax-structured Hessians, and blocked CG
//! ([`block_cg`](crate::sparse::block_cg())) otherwise — per-element
//! truncation via the same [`ActiveSet`].

pub mod engine;
pub mod mask;
pub mod sparse;

pub use engine::BatchedAltDiff;
pub use mask::ActiveSet;
pub use sparse::BatchedSparseAltDiff;

use crate::altdiff::Solution;
use crate::linalg::Mat;

/// Per-element results of one batched launch.
#[derive(Clone, Debug)]
pub struct BatchSolution {
    /// primal iterates, one Vec per element
    pub xs: Vec<Vec<f64>>,
    /// slacks
    pub ss: Vec<Vec<f64>>,
    /// equality duals λ
    pub lams: Vec<Vec<f64>>,
    /// inequality duals ν
    pub nus: Vec<Vec<f64>>,
    /// ∂x/∂θ per element (n × dim(θ)) when requested
    pub jacobians: Option<Vec<Mat>>,
    /// iterations each element actually ran before its truncation
    /// criterion fired (or `max_iter`)
    pub iters: Vec<usize>,
    /// final relative step per element
    pub step_rel: Vec<f64>,
}

impl BatchSolution {
    /// Batch size.
    pub fn len(&self) -> usize {
        self.xs.len()
    }

    /// True for a zero-element solution.
    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    /// Vector-Jacobian product gᵀ(∂x/∂θ) for element `e`.
    pub fn vjp(&self, e: usize, g: &[f64]) -> Vec<f64> {
        let jacs =
            self.jacobians.as_ref().expect("no jacobian tracked");
        crate::linalg::gemv_t(&jacs[e], g)
    }

    /// Copy element `e` out as a standalone [`Solution`] (trace-less).
    pub fn element(&self, e: usize) -> Solution {
        Solution {
            x: self.xs[e].clone(),
            s: self.ss[e].clone(),
            lam: self.lams[e].clone(),
            nu: self.nus[e].clone(),
            jacobian: self.jacobians.as_ref().map(|j| j[e].clone()),
            iters: self.iters[e],
            step_rel: self.step_rel[e],
            trace: Vec::new(),
        }
    }

    /// Per-element slack slices — the gate input the batched adjoint
    /// backward ([`BatchedAltDiff::batch_vjp`] /
    /// [`BatchedSparseAltDiff::batch_vjp`]) needs from a forward launch.
    pub fn slack_refs(&self) -> Vec<&[f64]> {
        self.ss.iter().map(|s| s.as_slice()).collect()
    }

    /// Harvest element `e`'s iterate triple for the warm-start cache
    /// (see [`crate::warm`]) — the input a later
    /// [`BatchedAltDiff::solve_batch_from`] /
    /// [`BatchedSparseAltDiff::try_solve_batch_from`] resumes from.
    pub fn warm_start(&self, e: usize) -> crate::warm::WarmStart {
        crate::warm::WarmStart::new(
            self.xs[e].clone(),
            self.lams[e].clone(),
            self.nus[e].clone(),
        )
    }
}

/// Per-element results of one batched reverse-mode (adjoint) backward:
/// every element's gradients of vₑᵀx*ₑ w.r.t. all three parameters —
/// computed without ever materializing a Jacobian (O(B·n) state instead
/// of O(B·n·d)).
#[derive(Clone, Debug)]
pub struct BatchVjp {
    /// vᵀ(∂x*/∂q) per element, each length n.
    pub grads_q: Vec<Vec<f64>>,
    /// vᵀ(∂x*/∂b) per element, each length p.
    pub grads_b: Vec<Vec<f64>>,
    /// vᵀ(∂x*/∂h) per element, each length m.
    pub grads_h: Vec<Vec<f64>>,
    /// Adjoint iterations each element ran before truncation fired.
    pub iters: Vec<usize>,
    /// Final relative adjoint step per element.
    pub step_rel: Vec<f64>,
}

impl BatchVjp {
    /// Batch size.
    pub fn len(&self) -> usize {
        self.grads_q.len()
    }

    /// True for a zero-element result.
    pub fn is_empty(&self) -> bool {
        self.grads_q.is_empty()
    }

    /// Copy element `e` out as a standalone [`crate::altdiff::Vjp`].
    pub fn element(&self, e: usize) -> crate::altdiff::Vjp {
        crate::altdiff::Vjp {
            grad_q: self.grads_q[e].clone(),
            grad_b: self.grads_b[e].clone(),
            grad_h: self.grads_h[e].clone(),
            iters: self.iters[e],
            step_rel: self.step_rel[e],
        }
    }
}

/// Forward batch solution plus the batched adjoint backward, as returned
/// by the `solve_batch_vjp` entry points.
#[derive(Clone, Debug)]
pub struct BatchVjpSolution {
    /// The forward launch (no Jacobians are ever materialized).
    pub forward: BatchSolution,
    /// Per-element gradients of vₑᵀx*ₑ w.r.t. q, b, and h.
    pub vjp: BatchVjp,
}
