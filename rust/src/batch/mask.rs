//! Active-set bookkeeping for per-element truncation.
//!
//! The batch engine runs every element of a batch through the same ADMM +
//! Jacobian iteration. An element whose truncation criterion (paper §4.3)
//! fires is *deactivated*: its iterate rows and its Jacobian column block
//! are frozen at their final values, and every subsequent masked kernel
//! launch ([`crate::linalg::gemm_acc_rows`] /
//! [`crate::linalg::gemm_acc_cols`]) skips its flops entirely. This is
//! what keeps a mixed-convergence batch as cheap as its slowest member,
//! not its slowest member times B.

/// Which batch elements are still iterating.
pub struct ActiveSet {
    flags: Vec<bool>,
    remaining: usize,
}

impl ActiveSet {
    /// All `size` elements start active.
    pub fn new(size: usize) -> Self {
        ActiveSet { flags: vec![true; size], remaining: size }
    }

    /// Total batch size (active + frozen).
    pub fn len(&self) -> usize {
        self.flags.len()
    }

    /// True when the batch has zero elements.
    pub fn is_empty(&self) -> bool {
        self.flags.is_empty()
    }

    /// Elements still iterating.
    pub fn remaining(&self) -> usize {
        self.remaining
    }

    /// True when every element has been deactivated.
    pub fn all_done(&self) -> bool {
        self.remaining == 0
    }

    /// Whether element `e` is still iterating.
    pub fn is_active(&self, e: usize) -> bool {
        self.flags[e]
    }

    /// Freeze element `e` (idempotent).
    pub fn deactivate(&mut self, e: usize) {
        if self.flags[e] {
            self.flags[e] = false;
            self.remaining -= 1;
        }
    }

    /// Row mask for [`crate::linalg::gemm_acc_rows`].
    pub fn flags(&self) -> &[bool] {
        &self.flags
    }

    /// Indices of active elements, ascending.
    pub fn iter(&self) -> impl Iterator<Item = usize> + '_ {
        self.flags
            .iter()
            .enumerate()
            .filter(|(_, &f)| f)
            .map(|(i, _)| i)
    }

    /// Active column ranges when each element owns `block` consecutive
    /// columns (adjacent active elements merge into one range) — the
    /// argument for [`crate::linalg::gemm_acc_cols`].
    pub fn col_ranges(&self, block: usize) -> Vec<(usize, usize)> {
        let mut out: Vec<(usize, usize)> = Vec::new();
        for e in self.iter() {
            let (j0, j1) = (e * block, (e + 1) * block);
            match out.last_mut() {
                Some(last) if last.1 == j0 => last.1 = j1,
                _ => out.push((j0, j1)),
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deactivation_counts_down_once() {
        let mut a = ActiveSet::new(3);
        assert_eq!(a.remaining(), 3);
        a.deactivate(1);
        a.deactivate(1); // idempotent
        assert_eq!(a.remaining(), 2);
        assert!(!a.is_active(1));
        assert!(a.is_active(0) && a.is_active(2));
        a.deactivate(0);
        a.deactivate(2);
        assert!(a.all_done());
    }

    #[test]
    fn col_ranges_merge_adjacent_blocks() {
        let mut a = ActiveSet::new(5);
        // active: 0, 1, 3  → with block 4: [0,8) and [12,16)
        a.deactivate(2);
        a.deactivate(4);
        assert_eq!(a.col_ranges(4), vec![(0, 8), (12, 16)]);
        assert_eq!(a.iter().collect::<Vec<_>>(), vec![0, 1, 3]);
    }

    #[test]
    fn full_and_empty_ranges() {
        let mut a = ActiveSet::new(3);
        assert_eq!(a.col_ranges(2), vec![(0, 6)]);
        for e in 0..3 {
            a.deactivate(e);
        }
        assert!(a.col_ranges(2).is_empty());
        assert_eq!(a.len(), 3);
        assert!(!a.is_empty());
    }
}
