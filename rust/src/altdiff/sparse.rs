//! Sparse Alt-Diff: the Table 4 path (constrained sparsemax & friends).
//!
//! Two x-update engines, picked automatically:
//!
//! 1. **Sherman–Morrison** when H = D + ρ·aaᵀ for diagonal D and a single
//!    dense equality row a (exactly the sparsemax/softmax structure of
//!    paper Table 3: H = (2+2ρ)I + ρ11ᵀ). O(n) per solve.
//! 2. **Matrix-free CG** otherwise: H = diag(P) + ρAᵀA + ρGᵀG applied via
//!    three spmv's, Jacobi-preconditioned, warm-started from the previous
//!    iterate (ADMM iterates drift slowly, so warm starts cut CG counts
//!    dramatically — the sparse analogue of "inheriting" the Hessian).

use super::{Options, Param, Solution, TraceEntry};
use crate::error::Result;
use crate::linalg::{dot, norm2, Mat};
use crate::prob::SparseQp;
use crate::sparse::{cg, Csr, HessianOp};

/// x-update engine. `pub(crate)` so [`crate::batch::BatchedSparseAltDiff`]
/// can inherit the registration-time pick (and the Sherman–Morrison
/// caches) instead of re-deriving them.
#[derive(Clone)]
pub(crate) enum Engine {
    /// H = diag(d) + ρ a aᵀ ; cached: dinv, u = dinv*a, denom = 1 + ρ aᵀu.
    ShermanMorrison { dinv: Vec<f64>, u: Vec<f64>, denom: f64, rho: f64 },
    /// Matrix-free CG on the assembled operator.
    Cg { cg_tol: f64, cg_max: usize },
}

/// A registered sparse QP layer.
pub struct SparseAltDiff {
    /// The registered problem (CSR constraints, diagonal P).
    pub qp: SparseQp,
    /// ADMM penalty ρ (fixed at registration, like the dense path).
    pub rho: f64,
    pub(crate) engine: Engine,
    /// diag(P) (assembled into the CG operator's diagonal together with
    /// the ρ·diag(AᵀA/GᵀG) terms).
    pub(crate) hdiag_p: Vec<f64>,
}

impl SparseAltDiff {
    /// Register: pick the x-update engine from the constraint structure
    /// (Sherman–Morrison for the sparsemax shape, matrix-free CG
    /// otherwise).
    pub fn new(qp: SparseQp, rho: f64) -> Result<Self> {
        let n = qp.n();
        let engine = Self::pick_engine(&qp, rho);
        let hdiag_p = qp.pdiag.clone();
        assert_eq!(hdiag_p.len(), n);
        Ok(SparseAltDiff { qp, rho, engine, hdiag_p })
    }

    /// Detect the Sherman–Morrison structure: G has exactly one nonzero
    /// per row with value ±1 (box rows → GᵀG diagonal), and A is a single
    /// dense row. This is precisely the sparsemax/softmax constraint set.
    fn pick_engine(qp: &SparseQp, rho: f64) -> Engine {
        let n = qp.n();
        let box_like = qp.g.rows > 0
            && (0..qp.g.rows).all(|i| {
                let lo = qp.g.indptr[i];
                let hi = qp.g.indptr[i + 1];
                hi - lo == 1 && qp.g.values[lo].abs() == 1.0
            });
        if box_like && qp.a.rows == 1 && qp.a.nnz() == n {
            // d_i = P_ii + rho * (#box rows touching i)
            let mut d = qp.pdiag.clone();
            for &j in &qp.g.indices {
                d[j] += rho;
            }
            let arow: Vec<f64> = {
                let mut v = vec![0.0; n];
                for k in 0..qp.a.nnz() {
                    v[qp.a.indices[k]] = qp.a.values[k];
                }
                v
            };
            let dinv: Vec<f64> = d.iter().map(|&v| 1.0 / v).collect();
            let u: Vec<f64> =
                dinv.iter().zip(&arow).map(|(di, ai)| di * ai).collect();
            let denom = 1.0 + rho * dot(&arow, &u);
            return Engine::ShermanMorrison { dinv, u, denom, rho };
        }
        Engine::Cg { cg_tol: 1e-10, cg_max: 10 * n }
    }

    /// Apply H⁻¹ to `rhs` (in/out `x` doubles as CG warm start).
    fn hsolve(&self, rhs: &[f64], x: &mut [f64]) {
        match &self.engine {
            Engine::ShermanMorrison { dinv, u, denom, rho } => {
                // (D + ρ a aᵀ)⁻¹ r = D⁻¹r − u (ρ aᵀ D⁻¹ r)/denom
                //   with u = D⁻¹a; note aᵀD⁻¹r = uᵀr.
                let ur = dot(u, rhs);
                let coef = rho * ur / denom;
                for i in 0..x.len() {
                    x[i] = dinv[i] * rhs[i] - coef * u[i];
                }
            }
            Engine::Cg { cg_tol, cg_max } => {
                let op = HessianOp::new(
                    &self.hdiag_p,
                    &self.qp.a,
                    &self.qp.g,
                    self.rho,
                );
                // warm start from incoming x
                cg(&op, rhs, x, *cg_tol, *cg_max)
                    .expect("CG failed on SPD Hessian");
            }
        }
    }

    /// Solve + differentiate. Mirrors
    /// [`DenseAltDiff::solve_with`](super::DenseAltDiff::solve_with).
    pub fn solve_with(
        &self,
        q: Option<&[f64]>,
        b: Option<&[f64]>,
        h: Option<&[f64]>,
        opts: &Options,
    ) -> Solution {
        let n = self.qp.n();
        let m = self.qp.h.len();
        let p = self.qp.b.len();
        let rho = self.rho;
        let q = q.unwrap_or(&self.qp.q);
        let b = b.unwrap_or(&self.qp.b);
        let h = h.unwrap_or(&self.qp.h);

        let mut x = vec![0.0; n];
        let mut s = vec![0.0; m];
        let mut lam = vec![0.0; p];
        let mut nu = vec![0.0; m];

        let d = opts.jacobian.map(|pm| pm.dim(n, m, p));
        let mut jx = d.map(|d| Mat::zeros(n, d));
        let mut js = d.map(|d| Mat::zeros(m, d));
        let mut jl = d.map(|d| Mat::zeros(p, d));
        let mut jn = d.map(|d| Mat::zeros(m, d));

        let mut trace = Vec::new();
        let mut rhs = vec![0.0; n];
        let mut xprev = vec![0.0; n];
        let mut iters = 0;
        let mut step_rel = f64::INFINITY;

        for k in 0..opts.max_iter {
            iters = k + 1;
            xprev.copy_from_slice(&x);

            // forward (5a)
            for i in 0..n {
                rhs[i] = -q[i];
            }
            self.qp.a.spmv_t_acc(&mut rhs, -1.0, &lam);
            self.qp.g.spmv_t_acc(&mut rhs, -1.0, &nu);
            self.qp.a.spmv_t_acc(&mut rhs, rho, b);
            let hms: Vec<f64> =
                h.iter().zip(&s).map(|(hi, si)| hi - si).collect();
            self.qp.g.spmv_t_acc(&mut rhs, rho, &hms);
            self.hsolve(&rhs, &mut x);

            // (6), (5c), (5d)
            let gx = self.qp.g.spmv(&x);
            for i in 0..m {
                s[i] = (-nu[i] / rho - (gx[i] - h[i])).max(0.0);
            }
            let ax = self.qp.a.spmv(&x);
            for i in 0..p {
                lam[i] += rho * (ax[i] - b[i]);
            }
            for i in 0..m {
                nu[i] += rho * (gx[i] + s[i] - h[i]);
            }

            // backward (7)
            if let (Some(jx), Some(js), Some(jl), Some(jn)) =
                (jx.as_mut(), js.as_mut(), jl.as_mut(), jn.as_mut())
            {
                self.jacobian_step(
                    opts.jacobian.unwrap(),
                    &s,
                    jx,
                    js,
                    jl,
                    jn,
                    rho,
                );
            }

            let dx: f64 = x
                .iter()
                .zip(&xprev)
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f64>()
                .sqrt();
            step_rel = dx / norm2(&xprev).max(1.0);
            if opts.trace {
                trace.push(TraceEntry {
                    iter: k,
                    step_rel,
                    jac_norm: jx.as_ref().map(|j| j.fro()).unwrap_or(0.0),
                });
            }
            if step_rel < opts.tol {
                break;
            }
        }

        Solution { x, s, lam, nu, jacobian: jx, iters, step_rel, trace }
    }

    /// Convenience: solve with the registered parameters θ.
    pub fn solve(&self, opts: &Options) -> Solution {
        self.solve_with(None, None, None, opts)
    }

    fn jacobian_step(
        &self,
        param: Param,
        s1: &[f64],
        jx: &mut Mat,
        js: &mut Mat,
        jl: &mut Mat,
        jn: &mut Mat,
        rho: f64,
    ) {
        let n = self.qp.n();
        let d = jx.cols;
        // lxt = Aᵀ Jλ + Gᵀ Jν + ρGᵀ Js + const(θ), built column-wise with
        // spmv_t (CSR has no gemm; d is small in the sparse regimes).
        let mut lxt = Mat::zeros(n, d);
        let mut coljl = vec![0.0; jl.rows];
        let mut coljn = vec![0.0; jn.rows];
        let mut coljs = vec![0.0; js.rows];
        for c in 0..d {
            for i in 0..jl.rows {
                coljl[i] = jl[(i, c)];
            }
            for i in 0..jn.rows {
                coljn[i] = jn[(i, c)];
            }
            for i in 0..js.rows {
                coljs[i] = js[(i, c)];
            }
            let mut col = vec![0.0; n];
            self.qp.a.spmv_t_acc(&mut col, 1.0, &coljl);
            self.qp.g.spmv_t_acc(&mut col, 1.0, &coljn);
            self.qp.g.spmv_t_acc(&mut col, rho, &coljs);
            lxt.set_col(c, &col);
        }
        match param {
            Param::Q => {
                for i in 0..n.min(d) {
                    lxt[(i, i)] += 1.0;
                }
            }
            Param::B => {
                // -ρAᵀ : column c is -ρ * (row c of A) scattered
                for r in 0..self.qp.a.rows.min(d) {
                    for k in self.qp.a.indptr[r]..self.qp.a.indptr[r + 1] {
                        lxt[(self.qp.a.indices[k], r)] -=
                            rho * self.qp.a.values[k];
                    }
                }
            }
            Param::H => {
                for r in 0..self.qp.g.rows.min(d) {
                    for k in self.qp.g.indptr[r]..self.qp.g.indptr[r + 1] {
                        lxt[(self.qp.g.indices[k], r)] -=
                            rho * self.qp.g.values[k];
                    }
                }
            }
        }
        // (7a): column-wise H⁻¹ apply (SM: O(nd); CG: warm-started per col)
        let mut newjx = Mat::zeros(n, d);
        let mut colbuf = vec![0.0; n];
        let mut xcol = vec![0.0; n];
        for c in 0..d {
            for i in 0..n {
                colbuf[i] = lxt[(i, c)];
                xcol[i] = -jx[(i, c)]; // warm start from previous -Jx col
            }
            self.hsolve(&colbuf, &mut xcol);
            for i in 0..n {
                newjx[(i, c)] = -xcol[i];
            }
        }
        *jx = newjx;

        // (7b)
        let mut gjx = Mat::zeros(js.rows, d);
        let mut jxcol = vec![0.0; n];
        for c in 0..d {
            for i in 0..n {
                jxcol[i] = jx[(i, c)];
            }
            let g = self.qp.g.spmv(&jxcol);
            gjx.set_col(c, &g);
        }
        if param == Param::H {
            for i in 0..gjx.rows.min(d) {
                gjx[(i, i)] -= 1.0;
            }
        }
        for i in 0..js.rows {
            let gate = if s1[i] > 0.0 { 1.0 } else { 0.0 };
            for c in 0..d {
                js[(i, c)] = gate
                    * (-(1.0 / rho))
                    * (jn[(i, c)] + rho * gjx[(i, c)]);
            }
        }
        // (7c)
        for c in 0..d {
            for i in 0..n {
                jxcol[i] = jx[(i, c)];
            }
            let a = self.qp.a.spmv(&jxcol);
            for i in 0..jl.rows {
                jl[(i, c)] += rho * a[i];
            }
        }
        if param == Param::B {
            for i in 0..jl.rows.min(d) {
                jl[(i, i)] -= rho;
            }
        }
        // (7d)
        jn.axpy(rho, &gjx);
        jn.axpy(rho, js);
    }

    /// True when the Sherman–Morrison fast path is active.
    pub fn uses_sherman_morrison(&self) -> bool {
        matches!(self.engine, Engine::ShermanMorrison { .. })
    }
}

/// Build a sparse layer directly from CSR parts (public convenience).
pub fn sparse_layer(
    pdiag: Vec<f64>,
    q: Vec<f64>,
    a: Csr,
    b: Vec<f64>,
    g: Csr,
    h: Vec<f64>,
    rho: f64,
) -> Result<SparseAltDiff> {
    SparseAltDiff::new(SparseQp { pdiag, q, a, b, g, h }, rho)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::altdiff::DenseAltDiff;
    use crate::prob::{sparse_qp, sparsemax_qp};

    #[test]
    fn sparsemax_uses_sherman_morrison() {
        let s = SparseAltDiff::new(sparsemax_qp(50, 1), 1.0).unwrap();
        assert!(s.uses_sherman_morrison());
        let r = SparseAltDiff::new(sparse_qp(30, 10, 4, 0.1, 1), 1.0)
            .unwrap();
        assert!(!r.uses_sherman_morrison());
    }

    #[test]
    fn sparsemax_solution_is_simplex_point() {
        let s = SparseAltDiff::new(sparsemax_qp(40, 2), 1.0).unwrap();
        let sol = s.solve(&Options {
            tol: 1e-10,
            max_iter: 50_000,
            jacobian: None,
            ..Default::default()
        });
        let sum: f64 = sol.x.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6, "simplex sum {sum}");
        for (i, &xi) in sol.x.iter().enumerate() {
            assert!(xi >= -1e-7, "x[{i}]={xi} below 0");
            assert!(xi <= s.qp.h[40 + i] + 1e-6, "x[{i}] above cap");
        }
    }

    #[test]
    fn sparse_matches_dense_solution_and_jacobian() {
        let sq = sparse_qp(18, 9, 4, 0.3, 3);
        let dense = DenseAltDiff::new(sq.to_dense(), 1.0).unwrap();
        let sparse = SparseAltDiff::new(sq, 1.0).unwrap();
        let opts = Options {
            tol: 1e-11,
            max_iter: 40_000,
            jacobian: Some(Param::B),
            ..Default::default()
        };
        let sd = dense.solve(&opts);
        let ss = sparse.solve(&opts);
        for i in 0..18 {
            assert!(
                (sd.x[i] - ss.x[i]).abs() < 1e-6,
                "x[{i}] {} vs {}",
                sd.x[i],
                ss.x[i]
            );
        }
        let jd = sd.jacobian.unwrap();
        let js = ss.jacobian.unwrap();
        assert!(jd.max_abs_diff(&js) < 1e-5);
    }

    #[test]
    fn sherman_morrison_matches_cg_on_same_structure() {
        // force CG by perturbing one G row to two entries, compare with a
        // dense assembly of the SM problem
        let sq = sparsemax_qp(12, 4);
        let dense = DenseAltDiff::new(sq.to_dense(), 1.0).unwrap();
        let sm = SparseAltDiff::new(sq, 1.0).unwrap();
        assert!(sm.uses_sherman_morrison());
        let opts = Options {
            tol: 1e-11,
            max_iter: 60_000,
            jacobian: Some(Param::B),
            ..Default::default()
        };
        let a = sm.solve(&opts);
        let b = dense.solve(&opts);
        for i in 0..12 {
            assert!((a.x[i] - b.x[i]).abs() < 1e-6);
        }
        assert!(a
            .jacobian
            .unwrap()
            .max_abs_diff(&b.jacobian.unwrap())
            < 1e-5);
    }

    #[test]
    fn jacobian_b_finite_difference_sparse() {
        let sq = sparse_qp(14, 7, 3, 0.25, 5);
        let s = SparseAltDiff::new(sq, 1.0).unwrap();
        let opts = Options {
            tol: 1e-11,
            max_iter: 40_000,
            jacobian: Some(Param::B),
            ..Default::default()
        };
        let sol = s.solve(&opts);
        let j = sol.jacobian.unwrap();
        let fopts = Options { jacobian: None, ..opts };
        let eps = 1e-5;
        for c in 0..3 {
            let mut bp = s.qp.b.clone();
            bp[c] += eps;
            let mut bm = s.qp.b.clone();
            bm[c] -= eps;
            let xp = s.solve_with(None, Some(&bp), None, &fopts).x;
            let xm = s.solve_with(None, Some(&bm), None, &fopts).x;
            for i in 0..14 {
                let fd = (xp[i] - xm[i]) / (2.0 * eps);
                assert!(
                    (j[(i, c)] - fd).abs() < 2e-3 * (1.0 + fd.abs()),
                    "J[{i},{c}]={} fd={fd}",
                    j[(i, c)]
                );
            }
        }
    }
}
